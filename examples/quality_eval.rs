//! Quality evaluation (paper Table 4 + Figures 13/14): run the DCGAN and
//! FST generators end to end with every deconvolution conversion approach
//! and score each against the native transposed convolution with SSIM.
//! Also writes side-by-side PGM images (the Figure 13/14 panels).
//!
//! Run: cargo run --release --example quality_eval [fst_div]
//! (fst_div divides FST's 256x256 resolution; default 2 -> 128x128.)

use std::io::Write as _;

use split_deconv::metrics::ssim_tensor;
use split_deconv::report::quality::{dcgan_image, fst_image, DeconvImpl};
use split_deconv::tensor::Tensor;

fn write_pgm(path: &str, img: &Tensor) -> std::io::Result<()> {
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "P2\n{} {}\n255", img.w, img.h)?;
    for y in 0..img.h {
        let row: Vec<String> = (0..img.w)
            .map(|x| {
                let g: f32 = (0..img.c).map(|c| img.at(0, y, x, c)).sum::<f32>() / img.c as f32;
                format!("{}", ((g * 0.5 + 0.5) * 255.0).clamp(0.0, 255.0) as u8)
            })
            .collect();
        writeln!(f, "{}", row.join(" "))?;
    }
    Ok(())
}

fn main() {
    let fst_div: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);

    println!("Table 4: SSIM of deconvolution conversions vs native deconvolution");
    println!("(paper: SD 1.000/1.000, Shi 0.568/0.939, Chang 0.534/0.742)\n");
    println!("{:<10} {:>8} {:>10} {:>12}", "Benchmark", "SD", "Shi [30]", "Chang [31]");

    // DCGAN (64x64) — Figure 13 panels
    let native = dcgan_image(DeconvImpl::Native, 1, 2).expect("dcgan forward");
    let approaches = [
        (DeconvImpl::Sd, "dcgan_sd"),
        (DeconvImpl::Shi, "dcgan_shi"),
        (DeconvImpl::Chang, "dcgan_chang"),
    ];
    let mut ssims = Vec::new();
    write_pgm("fig13_dcgan_native.pgm", &native).unwrap();
    for (imp, name) in approaches {
        let img = dcgan_image(imp, 1, 2).expect("dcgan forward");
        ssims.push(ssim_tensor(&img, &native, 2.0));
        write_pgm(&format!("fig13_{name}.pgm"), &img).unwrap();
    }
    println!(
        "{:<10} {:>8.3} {:>10.3} {:>12.3}",
        "DCGAN", ssims[0], ssims[1], ssims[2]
    );

    // FST (256/fst_div) — Figure 14 panels
    let native = fst_image(DeconvImpl::Native, 1, fst_div).expect("fst forward");
    let approaches = [
        (DeconvImpl::Sd, "fst_sd"),
        (DeconvImpl::Shi, "fst_shi"),
        (DeconvImpl::Chang, "fst_chang"),
    ];
    let mut fssims = Vec::new();
    write_pgm("fig14_fst_native.pgm", &native).unwrap();
    for (imp, name) in approaches {
        let img = fst_image(imp, 1, fst_div).expect("fst forward");
        fssims.push(ssim_tensor(&img, &native, 2.0));
        write_pgm(&format!("fig14_{name}.pgm"), &img).unwrap();
    }
    println!(
        "{:<10} {:>8.3} {:>10.3} {:>12.3}",
        "FST", fssims[0], fssims[1], fssims[2]
    );

    println!("\nwrote Figure 13/14 panels as fig13_*.pgm / fig14_*.pgm");
    assert!(ssims[0] > 0.999 && fssims[0] > 0.999, "SD must be exact");
    assert!(
        fssims[1] > ssims[1],
        "Shi's wrong padding must hurt the small DCGAN images more than FST"
    );
    println!("orderings hold: SD exact; Shi/Chang degrade, worse on small images.");
}
