//! Quickstart: the split-deconvolution transform in five minutes.
//!
//! Builds a DCGAN-style deconvolution layer, converts it with SD, verifies
//! bit-exactness against the direct transposed convolution, counts the
//! MACs each implementation pays, and runs both through the simulated 2D
//! PE array.
//!
//! Run: cargo run --release --example quickstart

use split_deconv::nn::LayerSpec;
use split_deconv::sd::{sd_deconv2d, split_filters, SdGeometry};
use split_deconv::sim::workload::{lower_layer, Lowering};
use split_deconv::sim::{pe2d, ProcessorConfig, SkipPolicy};
use split_deconv::tensor::{deconv2d, Filter, Tensor};
use split_deconv::util::rng::Rng;

fn main() {
    // A DCGAN generator layer: 16x16x128 -> 32x32x64, 5x5 deconv, stride 2.
    let spec = LayerSpec::deconv("dcgan.deconv2", 16, 16, 128, 64, 5, 2, 2, 1);
    let mut rng = Rng::new(7);
    let x = Tensor::randn(1, spec.in_h, spec.in_w, spec.in_c, &mut rng);
    let w = Filter::randn(spec.k, spec.k, spec.in_c, spec.out_c, &mut rng);

    // 1. The geometry of the conversion (paper Eqs. 1-3, 9).
    let g = SdGeometry::new(spec.k, spec.s, spec.p);
    println!("split deconvolution of k{} s{}:", spec.k, spec.s);
    println!("  split filter side K_T = {}", g.k_t);
    println!("  filter zero-pad P_K  = {} (top & left)", g.p_k);
    println!("  input zero-pad  P_I  = {} (all sides)", g.p_i);
    println!("  number of splits     = {}", g.n_splits());

    // 2. Split the filter into s^2 small convolution filters.
    let splits = split_filters(&w, spec.s);
    println!(
        "  {} filters of {}x{}x{}x{}",
        splits.len(),
        splits[0].kh,
        splits[0].kw,
        splits[0].ic,
        splits[0].oc
    );

    // 3. Run both implementations; they must agree bit-for-bit.
    let direct = deconv2d(&x, &w, spec.s, spec.p, spec.op);
    let sd = sd_deconv2d(&x, &w, spec.s, spec.p, spec.op);
    println!(
        "\nexactness: out {}x{}x{}, max |SD - direct| = {:.2e}",
        sd.h,
        sd.w,
        sd.c,
        sd.max_abs_diff(&direct)
    );
    assert!(sd.allclose(&direct, 1e-3));

    // 4. What each implementation costs (paper Table 2 convention).
    println!("\nMAC counts (M):");
    println!("  original deconv : {:>8.2}", spec.macs() as f64 / 1e6);
    println!("  NZP conversion  : {:>8.2}", spec.nzp_macs() as f64 / 1e6);
    println!("  SD conversion   : {:>8.2}", spec.sd_macs() as f64 / 1e6);

    // 5. Simulated execution on an unmodified 2D PE array.
    let cfg = ProcessorConfig::default();
    let mut rng = Rng::new(8);
    let nzp_ops = lower_layer(&spec, Lowering::Nzp, &mut rng).unwrap();
    let sd_ops = lower_layer(&spec, Lowering::Sd, &mut rng).unwrap();
    let nzp_stats = pe2d::simulate(&nzp_ops, &cfg, SkipPolicy::None);
    let sd_stats = pe2d::simulate(&sd_ops, &cfg, SkipPolicy::AWSparse);
    println!("\nsimulated 2D PE array (32x7, 800 MHz):");
    println!(
        "  NZP          : {:>10} cycles  ({:.1} us)",
        nzp_stats.cycles,
        nzp_stats.time_us(cfg.freq_mhz)
    );
    println!(
        "  SD-WAsparse  : {:>10} cycles  ({:.1} us)  -> {:.2}x speedup",
        sd_stats.cycles,
        sd_stats.time_us(cfg.freq_mhz),
        nzp_stats.cycles as f64 / sd_stats.cycles as f64
    );
    println!("\nok — see `repro report all` for every table & figure.");
}
