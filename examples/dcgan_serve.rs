//! End-to-end driver (paper Figure 12, the DCGAN demo): serve a real
//! generative model through the full three-layer stack.
//!
//! Layer 1 (Pallas conv kernel) and Layer 2 (JAX DCGAN generator using the
//! SD transform) were AOT-compiled by `make artifacts` into HLO text; this
//! binary is Layer 3: it loads the artifacts via PJRT, stands up the
//! coordinator (dynamic batcher + bounded queue), drives a batched request
//! workload, verifies the SD path against the direct-deconvolution artifact
//! on live traffic, and reports latency/throughput — then writes one
//! generated image as a PGM file, our stand-in for the paper's face demo.
//!
//! Run: make artifacts && cargo run --release --example dcgan_serve

use std::io::Write as _;
use std::time::{Duration, Instant};

use split_deconv::coordinator::{Server, ServerConfig};
use split_deconv::runtime::{artifacts_available, default_artifact_dir, Engine};
use split_deconv::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    if !artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // --- exactness on live traffic: SD artifact vs direct-deconv artifact
    println!("== exactness check (SD vs direct deconvolution, via PJRT) ==");
    let mut engine = Engine::new(default_artifact_dir())?;
    println!("platform: {}", engine.platform());
    let mut rng = Rng::new(99);
    let mut worst = 0.0f32;
    for _ in 0..4 {
        let z = rng.normal_vec(100);
        let sd = engine.load("dcgan_sd_b1")?.run(&z)?;
        let rf = engine.load("dcgan_ref_b1")?.run(&z)?;
        let d = sd
            .iter()
            .zip(&rf)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        worst = worst.max(d);
    }
    println!("max |SD - direct| over 4 fresh latents: {worst:.2e}");
    assert!(worst < 1e-3);
    drop(engine);

    // --- serving workload
    println!("\n== serving workload: 64 requests through the dynamic batcher ==");
    let server = Server::start_pjrt(
        ServerConfig {
            max_batch: 4,
            batch_timeout: Duration::from_millis(2),
            queue_cap: 128,
            model: "dcgan".to_string(),
            ..ServerConfig::default()
        },
        default_artifact_dir(),
        "dcgan_sd".into(),
    )?;

    let n = 64;
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for _ in 0..n {
        rxs.push(server.submit_blocking(rng.normal_vec(100))?);
    }
    let mut first_image = None;
    for rx in rxs {
        let resp = rx.recv()?;
        if first_image.is_none() {
            first_image = Some(resp.image);
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    println!("{}", m.summary());
    println!(
        "throughput: {:.1} images/s over {:.2}s wall",
        n as f64 / wall,
        wall
    );
    server.shutdown();

    // --- write a generated sample as PGM (grayscale) — the "demo face"
    let img = first_image.unwrap();
    let (h, w) = (64usize, 64usize);
    let path = "dcgan_sample.pgm";
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "P2\n{w} {h}\n255")?;
    for y in 0..h {
        let row: Vec<String> = (0..w)
            .map(|x| {
                // tanh output in [-1,1]; mean over RGB -> gray
                let base = (y * w + x) * 3;
                let g = (img[base] + img[base + 1] + img[base + 2]) / 3.0;
                format!("{}", ((g * 0.5 + 0.5) * 255.0).clamp(0.0, 255.0) as u8)
            })
            .collect();
        writeln!(f, "{}", row.join(" "))?;
    }
    println!("wrote generated sample to {path}");
    println!("\nend-to-end OK: Pallas kernel -> JAX model -> HLO artifact -> PJRT -> batcher.");
    Ok(())
}
