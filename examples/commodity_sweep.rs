//! Commodity-device sweep (paper Section 5.3): regenerate the Edge TPU and
//! NCS2 efficiency tables, sweep SD-vs-NZP across every benchmark and both
//! devices, and explore how the speedup responds to kernel geometry — the
//! paper's "if the neural network processors improve [their] computing
//! efficiency for smaller convolution kernel sizes, the performance speedup
//! of SD over NZP will be higher accordingly".
//!
//! Run: cargo run --release --example commodity_sweep

use split_deconv::commodity::{
    edge_tpu::EdgeTpu, layer_times_s, ncs2::Ncs2, EfficiencyModel,
};
use split_deconv::networks;
use split_deconv::nn::LayerSpec;
use split_deconv::report;

fn main() {
    report::print_eff_table("Edge TPU: GMACPS vs filter size (Table 5)", &report::table6(), "k");
    report::print_eff_table("Edge TPU: GMACPS vs feature map (Table 6)", &report::table5(), "px");
    report::print_eff_table("NCS2: GMACPS vs feature map (Table 7)", &report::table7(), "px");
    report::print_eff_table("NCS2: GMACPS vs filter size (Table 8)", &report::table8(), "k");

    println!();
    let f15 = report::fig15();
    report::print_speedup_figure("Figure 15: Edge TPU", &f15);
    println!("average {:.2}x (paper 1.51x)\n", report::average_speedup(&f15, "SD"));

    let f17 = report::fig17();
    report::print_speedup_figure("Figure 17: Intel NCS2", &f17);
    println!("average {:.2}x over NZP (paper 1.67x)\n", report::average_speedup(&f17, "SD"));

    // per-layer breakdown: where does the speedup come from?
    println!("per-layer SD speedup on Edge TPU (DCGAN):");
    let tpu = EdgeTpu;
    for l in networks::dcgan().deconv_layers() {
        let (nzp, sd) = layer_times_s(&tpu, l, report::HOST_REORG_GBPS);
        println!(
            "  {:<10} {}x{}x{} k{} -> {:.3}ms vs {:.3}ms = {:.2}x",
            l.name,
            l.in_h,
            l.in_w,
            l.in_c,
            l.k,
            nzp * 1e3 / tpu.nzp_derate().recip(),
            sd * 1e3,
            nzp / sd
        );
    }

    // geometry exploration: SD speedup vs (k, s) on a fixed layer
    println!("\nSD speedup vs kernel geometry (64x64x64 -> 64, Edge TPU model):");
    print!("{:>6}", "k\\s");
    for s in 2..=4 {
        print!("{s:>8}");
    }
    println!();
    for k in 2..=7 {
        print!("{k:>6}");
        for s in 2..=4usize {
            if k < s {
                print!("{:>8}", "-");
                continue;
            }
            let l = LayerSpec::deconv("probe", 64, 64, 64, 64, k, s, 0, 0);
            let (nzp, sd) = layer_times_s(&tpu, &l, report::HOST_REORG_GBPS);
            print!("{:>7.2}x", nzp / sd);
        }
        println!();
    }
    println!("\n(k divisible by s maximizes SD's advantage: no filter expansion.)");

    // NCS2 native-vs-SD per benchmark
    println!("\nNCS2: SD vs native deconvolution hardware:");
    let _ = Ncs2; // model exercised through fig17 above
    for row in &f17 {
        let sp = row.speedups();
        let native = sp.iter().find(|(l, _)| *l == "Native").unwrap().1;
        let sd = sp.iter().find(|(l, _)| *l == "SD").unwrap().1;
        println!("  {:<10} SD/native = {:.2}x", row.name, sd / native);
    }
    println!("(paper: 1.10x average — software SD beats the dedicated deconv path)");
}
