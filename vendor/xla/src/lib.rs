//! Offline stub of the `xla` crate (PJRT/XLA bindings).
//!
//! The real PJRT runtime links a multi-hundred-megabyte native XLA build
//! that the offline environment cannot fetch. This stub keeps the
//! `runtime::Engine` code compiling unchanged: every type and method the
//! serving stack calls exists with the same signature, construction of the
//! CPU client succeeds (so `Engine::new` can report a platform name), and
//! anything that would need the native runtime — compiling an HLO module or
//! executing it — returns a descriptive [`Error`]. All artifact-gated tests
//! and benches skip before reaching those paths, so a fresh checkout builds
//! and tests green without XLA; swapping this path dependency for the real
//! `xla` crate re-enables the PJRT backend with no source changes.

use std::fmt;

/// Error type matching the shape of the real crate's error.
#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// `Result` with the stub's [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT/XLA is unavailable in this build (offline xla stub); \
         link the real xla crate to enable artifact execution"
    )))
}

/// Host literal: a flat f32 buffer plus a shape.
#[derive(Clone, Debug)]
pub struct Literal {
    data: Vec<f32>,
    shape: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 literal from a slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal {
            data: data.to_vec(),
            shape: vec![data.len() as i64],
        }
    }

    /// Reshape without changing the element count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape: {} elements into shape {dims:?}",
                self.data.len()
            )));
        }
        Ok(Literal {
            data: self.data.clone(),
            shape: dims.to_vec(),
        })
    }

    /// First element of a tuple literal.
    pub fn to_tuple1(self) -> Result<Literal> {
        Ok(self)
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    /// The literal's shape.
    pub fn shape(&self) -> &[i64] {
        &self.shape
    }
}

/// Parsed HLO module (stub: parsing requires the native runtime).
pub struct HloModuleProto;

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        unavailable(&format!("HloModuleProto::from_text_file({path})"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    /// Transfer the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with the given arguments.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
pub struct PjRtClient;

impl PjRtClient {
    /// Construct the CPU client (always succeeds in the stub).
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    /// Platform identifier.
    pub fn platform_name(&self) -> String {
        "cpu (offline xla stub)".to_string()
    }

    /// Compile a computation.
    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_reshape_checks_numel() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[2, 2]).is_ok());
        assert!(l.reshape(&[3, 2]).is_err());
        assert_eq!(l.shape(), &[4]);
    }

    #[test]
    fn runtime_paths_error_cleanly() {
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        assert!(client.compile(&XlaComputation).is_err());
    }
}
