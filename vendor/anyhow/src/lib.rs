//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! implements exactly the subset of the real `anyhow` API this workspace
//! uses: [`Error`], [`Result`], the [`anyhow!`] and [`bail!`] macros, and
//! the [`Context`] extension trait. Semantics match `anyhow` where the two
//! overlap: any `std::error::Error + Send + Sync` converts via `?`, context
//! wraps the cause chain, and `{:#}` renders the full chain separated by
//! `": "`.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with this crate's [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A dynamically typed error, holding a cause chain.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            inner: Box::new(Message(message.to_string())),
        }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            inner: Box::new(Wrapped {
                context: context.to_string(),
                source: self.inner,
            }),
        }
    }

    /// Iterate the cause chain, outermost first.
    pub fn chain(&self) -> Chain<'_> {
        let head: &(dyn StdError + 'static) = self.inner.as_ref();
        Chain { next: Some(head) }
    }

    /// Downcast to a concrete error type anywhere in the cause chain
    /// (context wrappers are transparent, as with real `anyhow`).
    pub fn downcast_ref<E: StdError + 'static>(&self) -> Option<&E> {
        self.chain().find_map(|e| e.downcast_ref::<E>())
    }

    /// Whether the cause chain contains an `E` (see [`Error::downcast_ref`]).
    pub fn is<E: StdError + 'static>(&self) -> bool {
        self.downcast_ref::<E>().is_some()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        if f.alternate() {
            let mut source = self.inner.source();
            while let Some(s) = source {
                write!(f, ": {s}")?;
                source = s.source();
            }
        }
        Ok(())
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(s) = source {
            write!(f, "\n    {s}")?;
            source = s.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error { inner: Box::new(e) }
    }
}

/// Iterator over an [`Error`]'s cause chain.
pub struct Chain<'a> {
    next: Option<&'a (dyn StdError + 'static)>,
}

impl<'a> Iterator for Chain<'a> {
    type Item = &'a (dyn StdError + 'static);

    fn next(&mut self) -> Option<Self::Item> {
        let current = self.next?;
        self.next = current.source();
        Some(current)
    }
}

struct Message(String);

impl fmt::Display for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Message {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl StdError for Message {}

struct Wrapped {
    context: String,
    source: Box<dyn StdError + Send + Sync + 'static>,
}

impl fmt::Display for Wrapped {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.context)
    }
}

impl fmt::Debug for Wrapped {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.context)
    }
}

impl StdError for Wrapped {
    fn source(&self) -> Option<&(dyn StdError + 'static)> {
        let src: &(dyn StdError + 'static) = self.source.as_ref();
        Some(src)
    }
}

/// Attach context to the error of a `Result`, like `anyhow::Context`.
pub trait Context<T, E> {
    /// Wrap the error with a context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;

    /// Wrap the error with a lazily evaluated context message.
    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display + Send + Sync + 'static, F: FnOnce() -> C>(
        self,
        f: F,
    ) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Error = Err::<(), _>(io_err())
            .with_context(|| "reading manifest".to_string())
            .unwrap_err();
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn downcast_sees_through_context() {
        let e: Error = Err::<(), _>(io_err()).context("loading artifact").unwrap_err();
        let io = e.downcast_ref::<std::io::Error>().expect("chain downcast");
        assert_eq!(io.kind(), std::io::ErrorKind::NotFound);
        assert!(e.is::<std::io::Error>());
    }

    #[test]
    fn macros() {
        let e = anyhow!("bad thing {}", 7);
        assert_eq!(format!("{e}"), "bad thing 7");
        fn f() -> Result<()> {
            bail!("nope");
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope");
    }
}
