"""Pure-jnp reference oracles for convolution and deconvolution.

These are the CORE correctness signals for the repo: every Pallas kernel and
every split-deconvolution (SD) variant is checked against these references
by pytest (see python/tests/).

Conventions (used throughout python/ and mirrored in rust/src/tensor):
  activations : NHWC  float32
  conv weight : HWIO  (KH, KW, IC, OC), cross-correlation (no flip)
  deconv weight: HWIO (KH, KW, IC, OC), *scatter* semantics:
      out[n, i*s+kh, j*s+kw, oc] += x[n, i, j, ic] * w[kh, kw, ic, oc]
  which matches torch.nn.ConvTranspose2d / the paper's Algorithm 1 DECONV.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
from jax import lax

__all__ = [
    "conv2d",
    "deconv2d",
    "deconv2d_numpy",
    "zero_insert",
    "nzp_deconv2d",
    "deconv_out_size",
]


def deconv_out_size(i: int, k: int, s: int, p: int) -> int:
    """Output spatial size of a transposed convolution (no output padding)."""
    return (i - 1) * s + k - 2 * p


def conv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int = 1, padding: int = 0) -> jnp.ndarray:
    """Standard cross-correlation conv. x: NHWC, w: HWIO."""
    return lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def deconv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int, padding: int = 0) -> jnp.ndarray:
    """Transposed conv with scatter semantics (torch ConvTranspose2d).

    Implemented as an input-dilated convolution with the 180-degree rotated
    filter:  deconv(x, w, s, p) == conv(dilate_s(x), rot180(w), pad=K-1-p).
    """
    k = w.shape[0]
    assert w.shape[1] == k, "square filters only in reference"
    w_flip = w[::-1, ::-1, :, :]
    pad = k - 1 - padding
    return lax.conv_general_dilated(
        x,
        w_flip,
        window_strides=(1, 1),
        padding=[(pad, pad), (pad, pad)],
        lhs_dilation=(stride, stride),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def deconv2d_numpy(x: np.ndarray, w: np.ndarray, stride: int, padding: int = 0) -> np.ndarray:
    """Literal scatter-loop deconvolution (the paper's Figure 4(b)).

    Slow; used only in tests to validate `deconv2d` itself.
    x: NHWC, w: HWIO.
    """
    n, ih, iw, ic = x.shape
    kh, kw, _, oc = w.shape
    full_h = (ih - 1) * stride + kh
    full_w = (iw - 1) * stride + kw
    out = np.zeros((n, full_h, full_w, oc), dtype=np.float64)
    for b in range(n):
        for i in range(ih):
            for j in range(iw):
                # (ic,) @ (kh, kw, ic, oc) -> (kh, kw, oc)
                contrib = np.einsum(
                    "c,hwco->hwo", x[b, i, j].astype(np.float64), w.astype(np.float64)
                )
                out[b, i * stride : i * stride + kh, j * stride : j * stride + kw] += contrib
    if padding > 0:
        out = out[:, padding:-padding, padding:-padding, :]
    return out.astype(x.dtype)


def zero_insert(x: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Insert (stride-1) zeros between activations: the NZP dilation step.

    x: NHWC -> NHWC with H' = (H-1)*s + 1.
    """
    if stride == 1:
        return x
    n, h, w, c = x.shape
    out = jnp.zeros((n, (h - 1) * stride + 1, (w - 1) * stride + 1, c), dtype=x.dtype)
    return out.at[:, ::stride, ::stride, :].set(x)


def nzp_deconv2d(x: jnp.ndarray, w: jnp.ndarray, stride: int, padding: int = 0) -> jnp.ndarray:
    """Naive Zero-Padding deconvolution (the paper's baseline, Fig 1(b)).

    Materializes the zero-inserted feature map, then runs a standard stride-1
    convolution with the rotated filter. Numerically identical to deconv2d;
    computationally it performs the full dense conv over the zero-inflated
    map, which is exactly the ~s^2 redundancy the paper attacks.
    """
    k = w.shape[0]
    xd = zero_insert(x, stride)
    pad = k - 1 - padding
    xp = jnp.pad(xd, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    w_flip = w[::-1, ::-1, :, :]
    return conv2d(xp, w_flip, stride=1, padding=0)
