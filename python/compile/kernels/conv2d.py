"""L1 Pallas kernel: stride-1 valid convolution, MXU-shaped.

The SD transform converts every deconvolution into s^2 of exactly these
stride-1 convolutions, so this kernel is the compute hot-spot of the whole
system. The inner loop is a (OW x IC) @ (IC x OC) matmul per filter tap —
the shape the TPU MXU systolic array wants (contraction over channels),
rather than the scalar scatter-accumulate a raw deconvolution performs.

TPU mapping (documented for the real-TPU variant; we run interpret=True on
CPU per the image constraints):
  * grid = (N, ceil(OH / TILE_OH)): one VMEM-resident row-band per step.
  * x block: full W x IC rows [oh*TILE_OH, oh*TILE_OH + TILE_OH + KH - 1]
    -- expressed here by passing the whole image and slicing inside the
    kernel (Pallas block index maps cannot express overlapping halo blocks
    directly; a production TPU kernel would use a halo-exchange BlockSpec).
  * w block: whole filter (K_T is tiny after SD splitting: ceil(K/s)).
  * accumulation in f32; per-tap jnp.dot drives the MXU.

VMEM footprint estimate (see DESIGN.md section 9 / EXPERIMENTS.md #Perf):
  bytes = 4 * (TILE_X_ROWS * W * IC + KH*KW*IC*OC + TILE_OH * OW * OC).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["conv2d_pallas", "DEFAULT_TILE_OH"]

DEFAULT_TILE_OH = 16


def _conv_kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int, ow: int, tile_oh: int):
    """Compute a TILE_OH-row band of the output.

    x_ref: (1, H, W, IC) full input image (one batch element)
    w_ref: (KH, KW, IC, OC)
    o_ref: (1, TILE_OH, OW, OC) output band
    """
    t = pl.program_id(1)
    oh0 = t * tile_oh
    acc = jnp.zeros(o_ref.shape[1:], dtype=jnp.float32)
    for dh in range(kh):
        for dw in range(kw):
            # rows [oh0+dh, oh0+dh+tile_oh), cols [dw, dw+ow)
            xs = x_ref[0, pl.dslice(oh0 + dh, tile_oh), pl.dslice(dw, ow), :]  # (tile_oh, ow, ic)
            wt = w_ref[dh, dw]  # (ic, oc)
            acc = acc + jax.lax.dot_general(
                xs,
                wt,
                (((2,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            )
    o_ref[0] = acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("tile_oh",))
def conv2d_pallas(x: jnp.ndarray, w: jnp.ndarray, tile_oh: int | None = None) -> jnp.ndarray:
    """Stride-1 valid conv via Pallas. x: NHWC, w: HWIO -> NHWC.

    Output height is padded up to a multiple of the row-band tile and
    cropped afterwards, so any shape is accepted.
    """
    n, h, width, ic = x.shape
    kh, kw, wic, oc = w.shape
    assert wic == ic, f"channel mismatch {wic} != {ic}"
    oh, ow = h - kh + 1, width - kw + 1
    assert oh >= 1 and ow >= 1, "filter larger than input"

    # Tile policy (#Perf iteration 2): small outputs run as ONE row-band —
    # grid/dispatch overhead and pad-to-tile waste dominate tiny layers
    # (DCGAN 8x8..32x32); large outputs keep bounded bands for VMEM.
    t = tile_oh or (oh if oh <= 40 else DEFAULT_TILE_OH)
    n_tiles = -(-oh // t)  # ceil
    # pad input rows so every band is full
    pad_rows = n_tiles * t + kh - 1 - h
    if pad_rows > 0:
        x = jnp.pad(x, ((0, 0), (0, pad_rows), (0, 0), (0, 0)))

    kernel = functools.partial(_conv_kernel, kh=kh, kw=kw, ow=ow, tile_oh=t)
    out = pl.pallas_call(
        kernel,
        grid=(n, n_tiles),
        in_specs=[
            pl.BlockSpec((1, x.shape[1], width, ic), lambda b, i: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, ic, oc), lambda b, i: (0, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, t, ow, oc), lambda b, i: (b, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n_tiles * t, ow, oc), x.dtype),
        interpret=True,  # CPU image: real-TPU lowering emits Mosaic custom-calls
    )(x, w)
    return out[:, :oh]


def vmem_bytes(h: int, w: int, ic: int, kh: int, kw: int, oc: int, tile_oh: int) -> int:
    """Static VMEM footprint estimate for one grid step (f32)."""
    x_bytes = h * w * ic * 4  # full image resident (interpret-mode layout)
    w_bytes = kh * kw * ic * oc * 4
    o_bytes = tile_oh * (w - kw + 1) * oc * 4
    return x_bytes + w_bytes + o_bytes
