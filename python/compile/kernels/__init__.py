# L1: Pallas kernels + references for the paper's compute hot-spot.
from . import ref  # noqa: F401
from . import sd  # noqa: F401
from . import conv2d  # noqa: F401
