"""Split Deconvolution (SD) — the paper's Section 4 transform, in JAX.

Converts a K x K / stride-s deconvolution into s^2 standard stride-1
convolutions plus an output interleave. Bit-exact with `ref.deconv2d`.

Verified geometry (see DESIGN.md section 2 and python/tests/test_sd.py):
  K_T = ceil(K / s)          split filter size            (paper Eq. 2)
  P_K = s * K_T - K          filter zero-pad, top & left  (paper Eq. 1)
  P_I = K_T - 1              input zero-pad, all sides    (paper Eq. 9)
  N   = s^2                  number of split convolutions (paper Eq. 3)
  split n (r=n//s, c=n%s):  W_n = rot180(padded_W[r::s, c::s])   (Eq. 4-8)
  interleave: big[r::s, c::s] = ConvO_n                          (Eq. 10-11)
  full deconv output, side R=(I-1)*s+K, sits at offset P_K (top/left)
  in the interleaved grid; layer padding p crops a further p per side.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List

import jax.numpy as jnp

from . import ref

__all__ = ["SDGeometry", "sd_geometry", "split_filters", "interleave", "sd_deconv2d"]


@dataclass(frozen=True)
class SDGeometry:
    """All derived sizes of one SD conversion."""

    k: int  # original deconv filter size
    s: int  # stride
    p: int  # layer padding of the deconv
    k_t: int  # split filter size, ceil(k/s)
    p_k: int  # filter zero-pad (top & left)
    p_i: int  # input feature zero-pad (all sides)
    n_splits: int  # s^2

    def conv_out(self, i: int) -> int:
        """Spatial side of each split convolution output for input side i."""
        return i + 2 * self.p_i - self.k_t + 1  # == i + k_t - 1

    def big_out(self, i: int) -> int:
        """Side of the interleaved (pre-crop) output grid."""
        return self.s * self.conv_out(i)

    def final_out(self, i: int) -> int:
        """Side of the equivalent deconvolution output."""
        return ref.deconv_out_size(i, self.k, self.s, self.p)

    def crop(self) -> int:
        """Top/left crop applied to the interleaved grid."""
        return self.p_k + self.p


def sd_geometry(k: int, s: int, p: int = 0) -> SDGeometry:
    k_t = math.ceil(k / s)
    return SDGeometry(k=k, s=s, p=p, k_t=k_t, p_k=s * k_t - k, p_i=k_t - 1, n_splits=s * s)


def split_filters(w: jnp.ndarray, stride: int) -> List[jnp.ndarray]:
    """Split a deconv filter (HWIO) into s^2 conv filters (HWIO, K_T x K_T).

    Step 1 (paper): zero-expand the filter on the TOP and LEFT so its side
    is divisible by s.  Step 2: sample with stride s and rotate 180 degrees.
    """
    k = w.shape[0]
    g = sd_geometry(k, stride)
    wp = jnp.pad(w, ((g.p_k, 0), (g.p_k, 0), (0, 0), (0, 0)))
    out = []
    for n in range(g.n_splits):
        r, c = n // stride, n % stride
        sub = wp[r::stride, c::stride, :, :]
        out.append(sub[::-1, ::-1, :, :])  # rotate 180 (spatial axes only)
    return out


def interleave(convs: jnp.ndarray, stride: int) -> jnp.ndarray:
    """Reorganize split conv outputs into the deconv grid (paper Eq. 10-13).

    convs: (N, s*s, OH1, OW1, OC) stacked on axis 1 -> (N, s*OH1, s*OW1, OC)
    with big[..., r::s, c::s, :] = convs[:, r*s+c].
    """
    b, n_splits, oh, ow, oc = convs.shape
    assert n_splits == stride * stride
    x = convs.reshape(b, stride, stride, oh, ow, oc)
    # (b, r, c, oh, ow, oc) -> (b, oh, r, ow, c, oc)
    x = x.transpose(0, 3, 1, 4, 2, 5)
    return x.reshape(b, oh * stride, ow * stride, oc)


def sd_deconv2d(
    x: jnp.ndarray,
    w: jnp.ndarray,
    stride: int,
    padding: int = 0,
    conv_fn=ref.conv2d,
) -> jnp.ndarray:
    """Full SD pipeline: pad input -> s^2 convs -> interleave -> crop.

    `conv_fn(x, w)` performs the stride-1 valid convolution; pass the Pallas
    kernel (kernels.conv2d.conv2d_pallas) to exercise the L1 hot path, or
    leave the default pure-jnp oracle.
    """
    i = x.shape[1]
    g = sd_geometry(w.shape[0], stride, padding)
    filters = split_filters(w, stride)
    xp = jnp.pad(x, ((0, 0), (g.p_i, g.p_i), (g.p_i, g.p_i), (0, 0)))
    convs = jnp.stack([conv_fn(xp, f) for f in filters], axis=1)
    big = interleave(convs, stride)
    c0 = g.crop()
    r = g.final_out(i)
    return big[:, c0 : c0 + r, c0 : c0 + r, :]
