"""L2: benchmark generator models in JAX, calling the L1 Pallas kernels.

Network configurations are reverse-engineered from the paper's Tables 1-3 so
that the deconvolution MAC and parameter counts match the published numbers
(DCGAN / SNGAN / GP-GAN / ArtGAN / MDE exactly; FST exactly; see
EXPERIMENTS.md for the row-by-row comparison). The same tables are mirrored
in rust/src/networks/ — keep the two in sync.

Every deconv layer can be built three ways:
  ref : direct transposed convolution (oracle)
  nzp : naive zero-padding conversion (baseline, Fig 1(b))
  sd  : split deconvolution (the paper's contribution, Section 4)
The nzp/sd paths run their stride-1 convolutions through the Pallas kernel
so the AOT artifacts exercise the L1 hot path.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from .kernels import ref, sd
from .kernels.conv2d import conv2d_pallas


# --------------------------------------------------------------------------
# Layer / network specifications
# --------------------------------------------------------------------------


@dataclass(frozen=True)
class LayerSpec:
    """One layer of a benchmark network (spatial sizes may be rectangular)."""

    name: str
    kind: str  # "deconv" | "conv" | "dense"
    in_h: int
    in_w: int
    in_c: int
    out_c: int
    k: int = 0
    s: int = 1
    p: int = 0
    op: int = 0  # output_padding (deconv only)

    @property
    def out_h(self) -> int:
        if self.kind == "deconv":
            return (self.in_h - 1) * self.s + self.k - 2 * self.p + self.op
        if self.kind == "conv":
            return (self.in_h + 2 * self.p - self.k) // self.s + 1
        return 1

    @property
    def out_w(self) -> int:
        if self.kind == "deconv":
            return (self.in_w - 1) * self.s + self.k - 2 * self.p + self.op
        if self.kind == "conv":
            return (self.in_w + 2 * self.p - self.k) // self.s + 1
        return 1

    def macs(self) -> int:
        """Multiply-add count, paper Table 1/2 convention (scatter for deconv)."""
        if self.kind == "deconv":
            return self.in_h * self.in_w * self.k * self.k * self.in_c * self.out_c
        if self.kind == "conv":
            return self.out_h * self.out_w * self.k * self.k * self.in_c * self.out_c
        return self.in_h * self.in_w * self.in_c * self.out_c  # dense: in->out

    def params(self) -> int:
        if self.kind == "dense":
            return self.in_h * self.in_w * self.in_c * self.out_c
        return self.k * self.k * self.in_c * self.out_c


def d(name, ih, iw, ic, oc, k, s, p, op=0) -> LayerSpec:
    return LayerSpec(name, "deconv", ih, iw, ic, oc, k=k, s=s, p=p, op=op)


def c(name, ih, iw, ic, oc, k, s, p) -> LayerSpec:
    return LayerSpec(name, "conv", ih, iw, ic, oc, k=k, s=s, p=p)


def fc(name, n_in, n_out) -> LayerSpec:
    return LayerSpec(name, "dense", 1, 1, n_in, n_out)


@dataclass
class NetworkSpec:
    name: str
    layers: List[LayerSpec] = field(default_factory=list)

    def deconv_layers(self) -> List[LayerSpec]:
        return [l for l in self.layers if l.kind == "deconv"]

    def total_macs(self) -> int:
        return sum(l.macs() for l in self.layers)

    def deconv_macs(self) -> int:
        return sum(l.macs() for l in self.deconv_layers())


# DCGAN on CelebA, 64x64 output. Deconv MACs 109.77M / params 1.03M — exact.
DCGAN = NetworkSpec(
    "DCGAN",
    [
        fc("project", 100, 8 * 8 * 256),
        d("deconv1", 8, 8, 256, 128, k=5, s=2, p=2, op=1),
        d("deconv2", 16, 16, 128, 64, k=5, s=2, p=2, op=1),
        d("deconv3", 32, 32, 64, 3, k=5, s=2, p=2, op=1),
    ],
)

# SNGAN on CIFAR-10, 32x32. Deconv MACs 100.66M — exact.
SNGAN = NetworkSpec(
    "SNGAN",
    [
        d("deconv1", 4, 4, 512, 256, k=4, s=2, p=1),
        d("deconv2", 8, 8, 256, 128, k=4, s=2, p=1),
        d("deconv3", 16, 16, 128, 64, k=4, s=2, p=1),
        c("to_rgb", 32, 32, 64, 3, k=1, s=1, p=0),
    ],
)

# ArtGAN on CIFAR-10, 32x32. Deconv MACs 822.08M / NZP 2030.04M — exact.
ARTGAN = NetworkSpec(
    "ArtGAN",
    [
        fc("project", 100, 4 * 4 * 1024),
        d("deconv1", 4, 4, 1024, 512, k=4, s=2, p=1),
        d("deconv2", 8, 8, 512, 256, k=4, s=2, p=1),
        d("deconv3", 16, 16, 256, 256, k=5, s=1, p=2),
        d("deconv4", 16, 16, 256, 128, k=4, s=2, p=1),
        c("conv1", 32, 32, 128, 128, k=3, s=1, p=1),
        c("conv2", 32, 32, 128, 128, k=3, s=1, p=1),
        c("conv3", 32, 32, 128, 64, k=3, s=1, p=1),
        c("to_rgb", 32, 32, 64, 3, k=3, s=1, p=1),
    ],
)

# GP-GAN blending auto-encoder, 64x64. Deconv MACs 103.81M / params 2.76M — exact.
GPGAN = NetworkSpec(
    "GP-GAN",
    [
        c("enc1", 64, 64, 3, 64, k=4, s=2, p=1),
        c("enc2", 32, 32, 64, 128, k=4, s=2, p=1),
        c("enc3", 16, 16, 128, 256, k=4, s=2, p=1),
        c("enc4", 8, 8, 256, 512, k=4, s=2, p=1),
        fc("bottleneck", 4 * 4 * 512, 4000),
        d("dec1", 4, 4, 512, 256, k=4, s=2, p=1),
        d("dec2", 8, 8, 256, 128, k=4, s=2, p=1),
        d("dec3", 16, 16, 128, 64, k=4, s=2, p=1),
        d("dec4", 32, 32, 64, 3, k=4, s=2, p=1),
    ],
)

# Monocular Depth Estimation (Godard et al.), KITTI 128x256 mode.
# Deconv (upconv) MACs 830.4M vs paper 849.35M (-2.2%); params 3.93M — exact.
MDE = NetworkSpec(
    "MDE",
    [
        # VGG encoder (Godard monodepth style), 128x256 input
        c("enc1a", 128, 256, 3, 32, k=7, s=2, p=3),
        c("enc1b", 64, 128, 32, 32, k=7, s=1, p=3),
        c("enc2a", 64, 128, 32, 64, k=5, s=2, p=2),
        c("enc2b", 32, 64, 64, 64, k=5, s=1, p=2),
        c("enc3a", 32, 64, 64, 128, k=3, s=2, p=1),
        c("enc3b", 16, 32, 128, 128, k=3, s=1, p=1),
        c("enc4a", 16, 32, 128, 256, k=3, s=2, p=1),
        c("enc4b", 8, 16, 256, 256, k=3, s=1, p=1),
        c("enc5a", 8, 16, 256, 512, k=3, s=2, p=1),
        c("enc5b", 4, 8, 512, 512, k=3, s=1, p=1),
        # upconv decoder, all k3 s2 (the paper's "filter expansion" case)
        d("upconv6", 4, 8, 512, 512, k=3, s=2, p=1, op=1),
        c("iconv6", 8, 16, 512, 512, k=3, s=1, p=1),
        d("upconv5", 8, 16, 512, 256, k=3, s=2, p=1, op=1),
        c("iconv5", 16, 32, 256, 256, k=3, s=1, p=1),
        d("upconv4", 16, 32, 256, 128, k=3, s=2, p=1, op=1),
        c("iconv4", 32, 64, 128, 32, k=3, s=1, p=1),
        d("upconv3", 32, 64, 128, 64, k=3, s=2, p=1, op=1),
        d("upconv2", 64, 128, 64, 32, k=3, s=2, p=1, op=1),
        d("upconv1", 128, 256, 32, 16, k=3, s=2, p=1, op=1),
        c("disp", 256, 512, 16, 1, k=3, s=1, p=1),
    ],
)

# Fast-Style-Transfer transform net, 256x256. Deconv MACs 603.98M / 0.09M — exact.
FST = NetworkSpec(
    "FST",
    [
        c("conv1", 256, 256, 3, 32, k=9, s=1, p=4),
        c("conv2", 256, 256, 32, 64, k=3, s=2, p=1),
        c("conv3", 128, 128, 64, 128, k=3, s=2, p=1),
        *[
            c(f"res{i}{ab}", 64, 64, 128, 128, k=3, s=1, p=1)
            for i in range(1, 6)
            for ab in ("a", "b")
        ],
        d("deconv1", 64, 64, 128, 64, k=3, s=2, p=1, op=1),
        d("deconv2", 128, 128, 64, 32, k=3, s=2, p=1, op=1),
        c("to_rgb", 256, 256, 32, 3, k=9, s=1, p=4),
    ],
)

NETWORKS = {n.name: n for n in (DCGAN, SNGAN, ARTGAN, GPGAN, MDE, FST)}


# --------------------------------------------------------------------------
# Layer execution (three deconvolution implementations)
# --------------------------------------------------------------------------


def init_weight(spec: LayerSpec, seed: int) -> jnp.ndarray:
    rng = np.random.default_rng(seed)
    if spec.kind == "dense":
        n_in = spec.in_h * spec.in_w * spec.in_c
        w = rng.standard_normal((n_in, spec.out_c), dtype=np.float32)
        return jnp.asarray(w * (1.0 / np.sqrt(n_in)))
    w = rng.standard_normal((spec.k, spec.k, spec.in_c, spec.out_c), dtype=np.float32)
    return jnp.asarray(w * (1.0 / np.sqrt(spec.k * spec.k * spec.in_c)))


def _crop_op(y: jnp.ndarray, spec: LayerSpec) -> jnp.ndarray:
    """Apply output_padding: keep `op` extra rows/cols on the bottom/right."""
    return y


def deconv_ref(x: jnp.ndarray, w: jnp.ndarray, spec: LayerSpec) -> jnp.ndarray:
    """Oracle transposed conv, honoring output_padding via asymmetric crop."""
    full = ref.deconv2d(x, w, spec.s, padding=0)  # full (I-1)s+K
    oh, ow = spec.out_h, spec.out_w
    return full[:, spec.p : spec.p + oh, spec.p : spec.p + ow, :]


def deconv_nzp(x: jnp.ndarray, w: jnp.ndarray, spec: LayerSpec, conv_fn=conv2d_pallas) -> jnp.ndarray:
    """NZP: zero-insert + dense stride-1 conv (Pallas) + crop."""
    k = spec.k
    xd = ref.zero_insert(x, spec.s)
    pad = k - 1
    xp = jnp.pad(xd, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    w_flip = w[::-1, ::-1, :, :]
    full = conv_fn(xp, w_flip)  # == full deconv output
    oh, ow = spec.out_h, spec.out_w
    return full[:, spec.p : spec.p + oh, spec.p : spec.p + ow, :]


def deconv_sd(x: jnp.ndarray, w: jnp.ndarray, spec: LayerSpec, conv_fn=conv2d_pallas) -> jnp.ndarray:
    """Split deconvolution through the Pallas conv kernel + strided interleave.

    Perf note (EXPERIMENTS.md #Perf): the s^2 split convolutions are FUSED
    into a single convolution whose output channels are the s^2 stacked
    phases, followed by a depth-to-space interleave — one kernel launch and
    one (OW x IC) @ (IC x s^2*OC) contraction per tap instead of s^2 small
    ones. This is the optimization that took the measured host-CPU (Fig 16)
    SD path past NZP on every benchmark.
    """
    g = sd.sd_geometry(spec.k, spec.s, spec.p)
    filters = sd.split_filters(w, spec.s)  # s^2 x (K_T, K_T, IC, OC)
    stacked = jnp.concatenate(filters, axis=-1)  # (K_T, K_T, IC, s^2*OC)
    xp = jnp.pad(x, ((0, 0), (g.p_i, g.p_i), (g.p_i, g.p_i), (0, 0)))
    fused = conv_fn(xp, stacked)  # (N, H', W', s^2*OC)
    b, oh, ow, _ = fused.shape
    s = spec.s
    # depth-to-space: channel block n = r*s + c lands at phase (r, c)
    big = (
        fused.reshape(b, oh, ow, s, s, spec.out_c)
        .transpose(0, 1, 3, 2, 4, 5)
        .reshape(b, oh * s, ow * s, spec.out_c)
    )
    c0 = g.crop()
    return big[:, c0 : c0 + spec.out_h, c0 : c0 + spec.out_w, :]


DECONV_IMPLS: dict[str, Callable] = {
    "ref": deconv_ref,
    "nzp": deconv_nzp,
    "sd": deconv_sd,
}


def run_layer(x: jnp.ndarray, w: jnp.ndarray, spec: LayerSpec, impl: str) -> jnp.ndarray:
    if spec.kind == "deconv":
        return DECONV_IMPLS[impl](x, w, spec)
    if spec.kind == "conv":
        xp = jnp.pad(x, ((0, 0), (spec.p, spec.p), (spec.p, spec.p), (0, 0)))
        return ref.conv2d(xp, w, stride=spec.s)
    # dense
    b = x.shape[0]
    return (x.reshape(b, -1) @ w).reshape(b, 1, 1, spec.out_c)


# --------------------------------------------------------------------------
# Full generator forward passes (AOT targets)
# --------------------------------------------------------------------------


def dcgan_generator(z: jnp.ndarray, weights: List[jnp.ndarray], impl: str) -> jnp.ndarray:
    """DCGAN generator: z (B, 100) -> image (B, 64, 64, 3) in [-1, 1]."""
    spec = DCGAN.layers[0]
    h = (z @ weights[0]).reshape(z.shape[0], 8, 8, 256)
    h = jax.nn.relu(h)
    for spec, w in zip(DCGAN.layers[1:], weights[1:]):
        h = run_layer(h, w, spec, impl)
        if spec.name != "deconv3":
            h = jax.nn.relu(h)
    return jnp.tanh(h)


def dcgan_weights(seed: int = 0) -> List[jnp.ndarray]:
    return [init_weight(l, seed + i) for i, l in enumerate(DCGAN.layers)]


def make_dcgan_fn(impl: str, weights: List[jnp.ndarray]):
    """Close over constant weights so the HLO artifact embeds them."""

    def fn(z):
        return (dcgan_generator(z, weights, impl),)

    return fn


def make_layer_fn(spec: LayerSpec, impl: str, weight: jnp.ndarray):
    """Single deconv layer as a standalone AOT unit (Fig 16 timing)."""

    def fn(x):
        return (run_layer(x, weight, spec, impl),)

    return fn
