"""AOT pipeline: lower L2 jax models (calling L1 Pallas kernels) to HLO text.

Emits, under artifacts/:
  <name>.hlo.txt       HLO text (NOT serialized proto — the image's
                       xla_extension 0.5.1 rejects jax>=0.5's 64-bit-id
                       protos; the text parser reassigns ids cleanly).
  <name>.in<i>.bin     raw f32 little-endian golden inputs (params + data)
  <name>.out.bin       golden output (computed by the same jitted fn)
  manifest.json        index: shapes, dtypes, roles, network/layer metadata

The rust runtime (rust/src/runtime) loads the manifest, compiles each HLO
module once on the PJRT CPU client, and cross-checks numerics against the
goldens in integration tests.

Weights are passed as runtime *arguments* (not embedded constants) so the
HLO stays small and the same artifact can serve any checkpoint.

Usage: python -m compile.aot --out-dir ../artifacts [--only PATTERN]
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import os
from typing import Callable, List

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _write_bin(path: str, arr: np.ndarray) -> None:
    np.asarray(arr, dtype=np.float32).tofile(path)


class Artifact:
    def __init__(self, name: str, fn: Callable, inputs: List[np.ndarray], meta: dict):
        self.name = name
        self.fn = fn
        self.inputs = [np.asarray(x, dtype=np.float32) for x in inputs]
        self.meta = meta

    def emit(self, out_dir: str) -> dict:
        specs = [jax.ShapeDtypeStruct(x.shape, jnp.float32) for x in self.inputs]
        jitted = jax.jit(self.fn)
        lowered = jitted.lower(*specs)
        hlo = to_hlo_text(lowered)
        hlo_path = os.path.join(out_dir, f"{self.name}.hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(hlo)
        in_paths = []
        for i, x in enumerate(self.inputs):
            p = os.path.join(out_dir, f"{self.name}.in{i}.bin")
            _write_bin(p, x)
            in_paths.append(os.path.basename(p))
        out = np.asarray(jitted(*[jnp.asarray(x) for x in self.inputs])[0])
        out_path = os.path.join(out_dir, f"{self.name}.out.bin")
        _write_bin(out_path, out)
        entry = {
            "name": self.name,
            "hlo": os.path.basename(hlo_path),
            "inputs": [
                {"shape": list(x.shape), "dtype": "f32", "bin": p}
                for x, p in zip(self.inputs, in_paths)
            ],
            "output": {"shape": list(out.shape), "dtype": "f32", "bin": os.path.basename(out_path)},
            **self.meta,
        }
        print(f"  {self.name}: hlo {len(hlo)/1e3:.0f}kB  out{list(out.shape)}")
        return entry


def rng_input(shape, seed):
    return np.random.default_rng(seed).standard_normal(shape).astype(np.float32) * 0.5


def dcgan_artifacts() -> List[Artifact]:
    """Full DCGAN generator, all three deconv implementations, batch 1 and 4."""
    weights = [np.asarray(w) for w in M.dcgan_weights(seed=42)]
    arts = []
    for impl in ("sd", "nzp", "ref"):
        for b in (1, 4):
            if impl != "sd" and b != 1:
                continue  # batch variants only needed on the serving (SD) path

            def fn(z, *ws, impl=impl):
                return (M.dcgan_generator(z, list(ws), impl),)

            z = rng_input((b, 100), seed=100 + b)
            arts.append(
                Artifact(
                    f"dcgan_{impl}_b{b}",
                    fn,
                    [z, *weights],
                    {"kind": "model", "network": "DCGAN", "impl": impl, "batch": b},
                )
            )
    return arts


# Per-deconv-layer units for the host-CPU Fig 16 experiment. Large layers
# (MDE upconv1/2, FST) are included: they dominate the wall-clock ratio.
def layer_artifacts(nets: List[str]) -> List[Artifact]:
    arts = []
    for net_name in nets:
        net = M.NETWORKS[net_name]
        for li, spec in enumerate(net.layers):
            if spec.kind != "deconv":
                continue
            w = np.asarray(M.init_weight(spec, seed=1000 + li))
            x = rng_input((1, spec.in_h, spec.in_w, spec.in_c), seed=li)
            for impl in ("sd", "nzp"):

                def fn(x, w, spec=spec, impl=impl):
                    return (M.run_layer(x, w, spec, impl),)

                safe = net_name.lower().replace("-", "")
                arts.append(
                    Artifact(
                        f"layer_{safe}_{spec.name}_{impl}",
                        fn,
                        [x, w],
                        {
                            "kind": "layer",
                            "network": net_name,
                            "layer": spec.name,
                            "impl": impl,
                            "k": spec.k,
                            "s": spec.s,
                            "p": spec.p,
                            "op": spec.op,
                            "in_hw": [spec.in_h, spec.in_w],
                            "in_c": spec.in_c,
                            "out_c": spec.out_c,
                            "macs": spec.macs(),
                        },
                    )
                )
    return arts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="fnmatch pattern over artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    arts = dcgan_artifacts() + layer_artifacts(list(M.NETWORKS.keys()))
    if args.only:
        arts = [a for a in arts if fnmatch.fnmatch(a.name, args.only)]

    entries = []
    for a in arts:
        entries.append(a.emit(args.out_dir))

    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest to {args.out_dir}")


if __name__ == "__main__":
    main()
