"""L2 model tests: network table invariants + full-generator impl equivalence."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M


# Paper Table 1 / 2 / 3 targets (millions). DESIGN.md documents the
# reverse-engineered configs; tolerances reflect where the paper's own
# numbers were recoverable exactly vs approximately.
PAPER = {
    # name: (total, deconv, nzp, sd, params) in M, tol fraction
    "DCGAN": (111.41, 109.77, 439.09, 158.07, 1.03, 0.01),
    "SNGAN": (100.86, 100.66, 402.65, 100.66, 2.63, 0.05),
    "ArtGAN": (1268.77, 822.08, 2030.04, 822.08, 11.01, 0.16),
    "GP-GAN": (240.39, 103.81, 415.23, 103.81, 2.76, 0.01),
    "MDE": (2638.22, 849.35, 3397.39, 1509.95, 3.93, 0.03),
}


def nzp_macs(net):
    return sum(l.out_h * l.out_w * l.k * l.k * l.in_c * l.out_c for l in net.deconv_layers())


def sd_macs(net):
    from compile.kernels import sd

    total = 0
    for l in net.deconv_layers():
        g = sd.sd_geometry(l.k, l.s, l.p)
        total += l.in_h * l.in_w * (l.s * g.k_t) ** 2 * l.in_c * l.out_c
    return total


@pytest.mark.parametrize("name", list(PAPER.keys()))
def test_network_counts_match_paper(name):
    net = M.NETWORKS[name]
    total, deconv, nzp, sdm, params, tol = PAPER[name]
    assert net.total_macs() / 1e6 == pytest.approx(total, rel=tol)
    assert net.deconv_macs() / 1e6 == pytest.approx(deconv, rel=0.03)
    assert nzp_macs(net) / 1e6 == pytest.approx(nzp, rel=0.03)
    assert sd_macs(net) / 1e6 == pytest.approx(sdm, rel=0.03)
    assert sum(l.params() for l in net.deconv_layers()) / 1e6 == pytest.approx(params, rel=tol)


def test_fst_deconv_exact():
    """FST deconv/NZP/SD MACs are exact; the paper's *total* includes the
    (training-only) VGG loss network and is reported separately — see
    EXPERIMENTS.md."""
    net = M.NETWORKS["FST"]
    assert net.deconv_macs() / 1e6 == pytest.approx(603.98, rel=1e-3)
    assert nzp_macs(net) / 1e6 == pytest.approx(2415.92, rel=1e-3)
    assert sd_macs(net) / 1e6 == pytest.approx(1073.74, rel=1e-3)


def test_layer_shapes_consistent():
    """Each layer's input must match the previous layer's output (chain check
    along the main path; encoder/decoder boundaries via dense are exempt)."""
    for net in M.NETWORKS.values():
        prev = None
        for l in net.layers:
            if prev is not None and l.kind != "dense" and prev.kind != "dense":
                # skip explicit branches (iconv tap points in MDE)
                if l.in_c == prev.out_c:
                    assert (l.in_h, l.in_w) == (prev.out_h, prev.out_w), (
                        f"{net.name}.{l.name}: in {l.in_h}x{l.in_w} != "
                        f"prev out {prev.out_h}x{prev.out_w}"
                    )
            prev = l


@pytest.mark.parametrize("impl", ["nzp", "sd"])
def test_dcgan_generator_impls_match_ref(impl):
    weights = M.dcgan_weights(seed=7)
    z = jnp.asarray(np.random.default_rng(5).standard_normal((2, 100), dtype=np.float32))
    want = M.dcgan_generator(z, weights, "ref")
    got = M.dcgan_generator(z, weights, impl)
    assert got.shape == (2, 64, 64, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


def test_dcgan_output_range():
    weights = M.dcgan_weights(seed=7)
    z = jnp.asarray(np.random.default_rng(5).standard_normal((1, 100), dtype=np.float32))
    img = np.asarray(M.dcgan_generator(z, weights, "sd"))
    assert img.min() >= -1.0 and img.max() <= 1.0


@pytest.mark.parametrize(
    "name,li",
    [("MDE", "upconv6"), ("FST", "deconv1"), ("ArtGAN", "deconv3"), ("SNGAN", "deconv1")],
)
def test_single_layer_impls_agree(name, li):
    net = M.NETWORKS[name]
    spec = next(l for l in net.layers if l.name == li)
    w = M.init_weight(spec, seed=3)
    x = jnp.asarray(
        np.random.default_rng(4).standard_normal(
            (1, spec.in_h, spec.in_w, spec.in_c), dtype=np.float32
        )
    )
    want = M.run_layer(x, w, spec, "ref")
    assert want.shape == (1, spec.out_h, spec.out_w, spec.out_c)
    for impl in ("nzp", "sd"):
        got = M.run_layer(x, w, spec, impl)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)
