"""SD transform correctness: split deconvolution == scatter deconvolution.

This is the paper's central claim (bit-exactness, Table 4 SSIM == 1.0).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sd


def rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape, dtype=np.float32))


CASES = [
    # (k, s, p, i, ic, oc) — includes every benchmark deconv geometry class:
    (4, 2, 1, 4, 8, 4),  # DCGAN / GP-GAN style
    (3, 2, 1, 6, 4, 4),  # MDE upconv, K not divisible by s
    (5, 2, 2, 5, 4, 2),  # SNGAN-ish 5x5
    (2, 2, 0, 7, 3, 5),  # K == s
    (3, 1, 1, 5, 2, 2),  # stride 1 degenerate
    (9, 4, 0, 3, 2, 2),  # large K, s=4
    (5, 3, 0, 4, 2, 3),  # s=3
    (4, 4, 0, 3, 2, 2),  # K == s == 4 (FST-style upsample)
]


@pytest.mark.parametrize("k,s,p,i,ic,oc", CASES)
def test_sd_matches_deconv(k, s, p, i, ic, oc):
    x = rand((2, i, i, ic), seed=k * 100 + s)
    w = rand((k, k, ic, oc), seed=k * 7 + s)
    want = ref.deconv2d(x, w, s, p)
    got = sd.sd_deconv2d(x, w, s, p)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("k,s,p,i,ic,oc", CASES[:4])
def test_nzp_matches_deconv(k, s, p, i, ic, oc):
    x = rand((1, i, i, ic), seed=1)
    w = rand((k, k, ic, oc), seed=2)
    want = ref.deconv2d(x, w, s, p)
    got = ref.nzp_deconv2d(x, w, s, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_deconv_ref_matches_scatter_loop():
    """Validate the jnp oracle itself against the literal scatter loop."""
    x = np.random.default_rng(3).standard_normal((2, 4, 4, 3), dtype=np.float32)
    w = np.random.default_rng(4).standard_normal((4, 4, 3, 5), dtype=np.float32)
    for p in (0, 1):
        want = ref.deconv2d_numpy(x, w, 2, p)
        got = np.asarray(ref.deconv2d(jnp.asarray(x), jnp.asarray(w), 2, p))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_geometry_fields():
    g = sd.sd_geometry(5, 2, 2)
    assert (g.k_t, g.p_k, g.p_i, g.n_splits) == (3, 1, 2, 4)
    assert g.final_out(5) == (5 - 1) * 2 + 5 - 4
    g2 = sd.sd_geometry(4, 2, 1)
    assert (g2.k_t, g2.p_k, g2.p_i) == (2, 0, 1)


def test_split_filters_partition():
    """Every original weight appears in exactly one split filter; zeros pad."""
    w = rand((5, 5, 1, 1), seed=9)
    filters = sd.split_filters(w, 2)
    total = sum(float(jnp.sum(jnp.abs(f))) for f in filters)
    np.testing.assert_allclose(total, float(jnp.sum(jnp.abs(w))), rtol=1e-5)
    assert all(f.shape[:2] == (3, 3) for f in filters)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(2, 6),
    s=st.integers(1, 4),
    i=st.integers(2, 6),
    ic=st.integers(1, 4),
    oc=st.integers(1, 4),
    pad=st.integers(0, 2),
    seed=st.integers(0, 2**16),
)
def test_sd_property(k, s, i, ic, oc, pad, seed):
    p = min(pad, k - 1)  # valid layer padding
    if (i - 1) * s + k - 2 * p < 1:
        return
    x = rand((1, i, i, ic), seed=seed)
    w = rand((k, k, ic, oc), seed=seed + 1)
    want = ref.deconv2d(x, w, s, p)
    got = sd.sd_deconv2d(x, w, s, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)
