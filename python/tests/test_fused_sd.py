"""The fused SD formulation (one OC-stacked conv + depth-to-space, the #Perf
optimization in model.deconv_sd) must stay bit-equivalent to both the
unfused SD pipeline and the direct transposed convolution."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref, sd


def rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape, dtype=np.float32))


@settings(max_examples=12, deadline=None)
@given(
    k=st.integers(2, 5),
    s=st.integers(2, 3),
    i=st.integers(3, 7),
    ic=st.integers(1, 5),
    oc=st.integers(1, 5),
    seed=st.integers(0, 2**16),
)
def test_fused_equals_unfused_and_ref(k, s, i, ic, oc, seed):
    p = min(1, k - 1)
    op = 1 if s > 1 else 0
    spec = M.LayerSpec("t", "deconv", i, i, ic, oc, k=k, s=s, p=p, op=op)
    x = rand((1, i, i, ic), seed)
    w = rand((k, k, ic, oc), seed + 1)
    want = M.deconv_ref(x, w, spec)
    fused = M.deconv_sd(x, w, spec, conv_fn=ref.conv2d)  # fused path
    unfused = sd.sd_deconv2d(x, w, s, p)  # unfused pipeline (p=0 op handling differs)
    assert fused.shape == want.shape
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want), rtol=1e-3, atol=1e-4)
    # the unfused pipeline agrees with the oracle on its own output window
    ref_nop = ref.deconv2d(x, w, s, p)
    np.testing.assert_allclose(np.asarray(unfused), np.asarray(ref_nop), rtol=1e-3, atol=1e-4)


def test_fused_channel_order_is_phase_major():
    """Phase n = r*s + c must land at output (r, c) — a regression guard for
    the depth-to-space reshape order."""
    s, k, i = 2, 2, 3
    spec = M.LayerSpec("t", "deconv", i, i, 1, 1, k=k, s=s, p=0, op=0)
    x = jnp.ones((1, i, i, 1), dtype=jnp.float32)
    # filter with distinct value per tap: deconv output phase pattern known
    w = jnp.asarray(np.arange(1, 5, dtype=np.float32).reshape(2, 2, 1, 1))
    want = M.deconv_ref(x, w, spec)
    got = M.deconv_sd(x, w, spec, conv_fn=ref.conv2d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)
