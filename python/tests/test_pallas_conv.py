"""Pallas conv kernel vs pure-jnp oracle, plus SD pipeline through Pallas."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, sd
from compile.kernels.conv2d import conv2d_pallas, vmem_bytes


def rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape, dtype=np.float32))


@pytest.mark.parametrize(
    "n,h,w,ic,kh,kw,oc",
    [
        (1, 8, 8, 4, 3, 3, 8),
        (2, 10, 10, 3, 2, 2, 5),
        (1, 16, 16, 8, 4, 4, 16),
        (1, 5, 5, 1, 5, 5, 1),  # output 1x1
        (2, 9, 7, 2, 3, 2, 3),  # non-square input & filter
        (1, 33, 33, 4, 3, 3, 4),  # oh not divisible by tile
    ],
)
def test_pallas_conv_matches_ref(n, h, w, ic, kh, kw, oc):
    x = rand((n, h, w, ic), seed=h * 10 + kh)
    wt = rand((kh, kw, ic, oc), seed=kh)
    want = ref.conv2d(x, wt)
    got = conv2d_pallas(x, wt)
    assert got.shape == want.shape
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(4, 20),
    ic=st.integers(1, 6),
    k=st.integers(1, 4),
    oc=st.integers(1, 6),
    tile=st.integers(1, 9),
    seed=st.integers(0, 2**16),
)
def test_pallas_conv_property(h, ic, k, oc, tile, seed):
    x = rand((1, h, h, ic), seed=seed)
    wt = rand((k, k, ic, oc), seed=seed + 1)
    want = ref.conv2d(x, wt)
    got = conv2d_pallas(x, wt, tile_oh=min(tile, h - k + 1))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("k,s,p,i", [(4, 2, 1, 4), (3, 2, 1, 6), (5, 2, 2, 5)])
def test_sd_through_pallas(k, s, p, i):
    """Full SD pipeline with the Pallas kernel as the split-conv engine."""
    x = rand((1, i, i, 4), seed=3)
    w = rand((k, k, 4, 6), seed=4)
    want = ref.deconv2d(x, w, s, p)
    got = sd.sd_deconv2d(x, w, s, p, conv_fn=conv2d_pallas)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_vmem_estimate_positive_and_monotone():
    a = vmem_bytes(32, 32, 64, 3, 3, 64, 8)
    b = vmem_bytes(64, 64, 64, 3, 3, 64, 8)
    assert 0 < a < b
