//! Helpers shared by the integration-test binaries (each test file pulls
//! this in with `mod common;` — the directory form keeps cargo from
//! treating it as a test target of its own).

use split_deconv::nn::{LayerSpec, NetworkSpec};

/// A small-but-real generator chain — dense 16 -> 4x4x8, then two
/// stride-2 SD deconvolutions up to 16x16x3 — so concurrency/packing
/// suites drive the production engine path at high request counts without
/// benchmark-scale debug-build compute. ONE definition, shared by
/// coordinator_stress.rs and batch_packing.rs, so the two suites cannot
/// drift apart.
pub fn tiny_net() -> NetworkSpec {
    NetworkSpec {
        name: "tiny",
        layers: vec![
            LayerSpec::dense("fc", 16, 4 * 4 * 8),
            LayerSpec::deconv("up1", 4, 4, 8, 4, 4, 2, 1, 0),
            LayerSpec::deconv("up2", 8, 8, 4, 3, 4, 2, 1, 0),
        ],
    }
}
