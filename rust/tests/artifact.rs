//! Round-trip and corruption tests for the `.sdprog` compiled-Program
//! artifact format (`engine::artifact`).
//!
//! Contracts proved here:
//! * compile -> serialize -> load is **bit-identical**: re-serializing a
//!   loaded program reproduces the original artifact byte-for-byte, in
//!   both [`LoadMode::Copy`] and [`LoadMode::ZeroCopy`], for f32 and
//!   int8 programs of real registry networks;
//! * a zero-copy-loaded program EXECUTES bit-identically to the freshly
//!   compiled one (the borrowed panels feed the same GEMMs);
//! * `save`/`load` round-trips through a real file;
//! * every corruption mode — truncation, a flipped payload byte, an
//!   unsupported format version, a manifest length that disagrees with
//!   the blob geometry — fails `Program::load` with a **typed**
//!   [`ArtifactError`] (downcastable through `anyhow`), never a panic
//!   and never a partially-initialized program.

use std::sync::{Arc, OnceLock};

use split_deconv::engine::artifact::BLOB_ALIGN;
use split_deconv::engine::{ArtifactError, DeconvImpl, LoadMode, Plan, Precision, Program};
use split_deconv::networks;
use split_deconv::util::json;
use split_deconv::util::rng::Rng;
use split_deconv::util::sha256;

/// Compile a registry network at the given precision.
fn compile(name: &str, precision: Precision) -> Arc<Program> {
    let net = networks::by_name(name).unwrap();
    Arc::new(Program::from_seed_prec(&net, DeconvImpl::Sd, 7, precision).unwrap())
}

/// dcgan/f32 program + artifact bytes, compiled once and shared by the
/// corruption tests (debug-build compiles dominate this suite's cost).
fn dcgan_f32() -> &'static (Arc<Program>, Vec<u8>) {
    static CACHE: OnceLock<(Arc<Program>, Vec<u8>)> = OnceLock::new();
    CACHE.get_or_init(|| {
        let p = compile("dcgan", Precision::F32);
        let bytes = p.to_artifact_bytes().unwrap();
        (p, bytes)
    })
}

/// Split an artifact into (header bytes, manifest text, blob region) so
/// corruption tests can rewrite the manifest and reassemble a file the
/// loader will still frame correctly.
fn split_artifact(bytes: &[u8]) -> ([u8; 8], String, Vec<u8>) {
    let magic: [u8; 8] = bytes[..8].try_into().unwrap();
    let mlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    let manifest = String::from_utf8(bytes[16..16 + mlen].to_vec()).unwrap();
    let region_start = (16 + mlen).div_ceil(BLOB_ALIGN) * BLOB_ALIGN;
    (magic, manifest, bytes[region_start..].to_vec())
}

fn join_artifact(magic: &[u8; 8], manifest: &str, region: &[u8]) -> Vec<u8> {
    let region_start = (16 + manifest.len()).div_ceil(BLOB_ALIGN) * BLOB_ALIGN;
    let mut out = Vec::with_capacity(region_start + region.len());
    out.extend_from_slice(magic);
    out.extend_from_slice(&(manifest.len() as u64).to_le_bytes());
    out.extend_from_slice(manifest.as_bytes());
    out.resize(region_start, 0);
    out.extend_from_slice(region);
    out
}

fn typed(err: &anyhow::Error) -> &ArtifactError {
    err.downcast_ref::<ArtifactError>()
        .unwrap_or_else(|| panic!("corruption must surface a typed ArtifactError, got: {err:#}"))
}

#[test]
fn round_trip_is_bit_identical_for_f32_and_int8() {
    // DCGAN covers dense + sd_deconv (and their int8 lowerings); SNGAN
    // adds a plain conv step. Together: every serializable op kind.
    for name in ["dcgan", "sngan"] {
        for precision in [Precision::F32, Precision::Int8] {
            let p = compile(name, precision);
            let bytes = p.to_artifact_bytes().unwrap();
            for mode in [LoadMode::Copy, LoadMode::ZeroCopy] {
                let loaded = Program::from_artifact_bytes(&bytes, mode).unwrap();
                assert_eq!(loaded.name(), p.name());
                assert_eq!(loaded.precision(), precision);
                assert_eq!(loaded.input_len(), p.input_len());
                assert_eq!(loaded.output_len(), p.output_len());
                assert_eq!(
                    loaded.to_artifact_bytes().unwrap(),
                    bytes,
                    "{name}/{}/{mode:?}: reloaded program must re-serialize bit-identically",
                    precision.label(),
                );
            }
        }
    }
}

#[test]
fn zero_copy_loaded_program_executes_bit_identically() {
    let (p, bytes) = dcgan_f32();
    let z = Rng::new(3).normal_vec(p.input_len());
    let want = Plan::from_program(p.clone()).execute_batch(&[z.clone()]).unwrap();
    for mode in [LoadMode::Copy, LoadMode::ZeroCopy] {
        let loaded = Arc::new(Program::from_artifact_bytes(bytes, mode).unwrap());
        let got = Plan::from_program(loaded).execute_batch(&[z.clone()]).unwrap();
        assert_eq!(got[0], want[0], "{mode:?}: loaded program computed different bits");
    }
}

#[test]
fn save_and_load_round_trip_through_a_file() {
    let (p, _) = dcgan_f32();
    let dir = std::env::temp_dir().join(format!("sdprog_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("dcgan_f32.sdprog");
    p.save(&path).unwrap();
    let loaded = Program::load(&path).unwrap();
    assert_eq!(
        loaded.to_artifact_bytes().unwrap(),
        p.to_artifact_bytes().unwrap(),
        "file round trip must be bit-identical"
    );
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_artifact_fails_typed() {
    let (_, bytes) = dcgan_f32();

    // header-level truncation
    let err = Program::from_artifact_bytes(&bytes[..7], LoadMode::Copy).unwrap_err();
    assert!(matches!(typed(&err), ArtifactError::Truncated { .. }), "{err:#}");

    // a blob the manifest promises is cut off mid-payload
    let cut = &bytes[..bytes.len() - 1024];
    for mode in [LoadMode::Copy, LoadMode::ZeroCopy] {
        let err = Program::from_artifact_bytes(cut, mode).unwrap_err();
        assert!(
            matches!(
                typed(&err),
                ArtifactError::Truncated { .. } | ArtifactError::BlobOutOfBounds { .. }
            ),
            "{mode:?}: {err:#}"
        );
    }
}

#[test]
fn flipped_payload_byte_fails_the_checksum() {
    let (_, bytes) = dcgan_f32();
    let (_, manifest, _) = split_artifact(bytes);
    let region_start = (16 + manifest.len()).div_ceil(BLOB_ALIGN) * BLOB_ALIGN;

    // flip one byte of the FIRST blob's payload (blob offsets are
    // region-relative, the first starts at 0)
    let mut bad = bytes.clone();
    bad[region_start] ^= 0xff;
    for mode in [LoadMode::Copy, LoadMode::ZeroCopy] {
        let err = Program::from_artifact_bytes(&bad, mode).unwrap_err();
        assert!(
            matches!(typed(&err), ArtifactError::ChecksumMismatch { .. }),
            "{mode:?}: {err:#}"
        );
    }
}

#[test]
fn unsupported_format_version_fails_before_anything_else() {
    let (_, bytes) = dcgan_f32();
    let (magic, manifest, region) = split_artifact(bytes);
    assert!(manifest.contains("\"format_version\":1"), "manifest shape changed?");
    let future = manifest.replacen("\"format_version\":1", "\"format_version\":99", 1);
    let bad = join_artifact(&magic, &future, &region);
    let err = Program::from_artifact_bytes(&bad, LoadMode::Copy).unwrap_err();
    assert!(
        matches!(typed(&err), ArtifactError::UnsupportedVersion { found: 99 }),
        "{err:#}"
    );
}

#[test]
fn manifest_blob_length_disagreement_fails_typed() {
    let (_, bytes) = dcgan_f32();
    let (magic, manifest, region) = split_artifact(bytes);

    // find the first step's packed panel descriptor and shrink its
    // declared length by one alignment quantum, re-hashing the shortened
    // span so the CHECKSUM still passes — the only thing wrong with the
    // rewritten manifest is that the length no longer matches the
    // geometry (k, n) the named network requires
    let m = json::parse(&manifest).unwrap();
    let desc = m.get("steps").and_then(|s| s.as_arr()).unwrap()[0]
        .get("packed")
        .and_then(|pk| pk.as_arr())
        .unwrap()[0]
        .clone();
    let offset = desc.get("offset").and_then(|v| v.as_usize()).unwrap();
    let len = desc.get("len").and_then(|v| v.as_usize()).unwrap();
    let sha = desc.get("sha256").and_then(|v| v.as_str()).unwrap().to_string();
    assert!(len > BLOB_ALIGN && offset == 0);

    let short = len - BLOB_ALIGN;
    let short_sha = sha256::hex_digest(&region[..short]);
    let lied = manifest
        .replacen(&format!("\"len\":{len}"), &format!("\"len\":{short}"), 1)
        .replacen(&sha, &short_sha, 1);
    assert_ne!(lied, manifest, "the rewrite must have changed the manifest");
    let bad = join_artifact(&magic, &lied, &region);
    for mode in [LoadMode::Copy, LoadMode::ZeroCopy] {
        let err = Program::from_artifact_bytes(&bad, mode).unwrap_err();
        assert!(
            matches!(typed(&err), ArtifactError::SpecMismatch(_)),
            "{mode:?}: a length/geometry disagreement must be typed, got {err:#}"
        );
    }
}

#[test]
fn unknown_network_and_garbage_manifest_fail_typed() {
    let (_, bytes) = dcgan_f32();
    let (magic, manifest, region) = split_artifact(bytes);

    let renamed = manifest.replacen("\"network\":\"DCGAN\"", "\"network\":\"NOPE\"", 1);
    assert_ne!(renamed, manifest);
    let bad = join_artifact(&magic, &renamed, &region);
    let err = Program::from_artifact_bytes(&bad, LoadMode::Copy).unwrap_err();
    assert!(matches!(typed(&err), ArtifactError::UnknownNetwork(_)), "{err:#}");

    let bad = join_artifact(&magic, "not json", &region);
    let err = Program::from_artifact_bytes(&bad, LoadMode::Copy).unwrap_err();
    assert!(matches!(typed(&err), ArtifactError::BadManifest(_)), "{err:#}");
}
