//! Property tests for the im2col + GEMM convolution hot path: bit-exact
//! agreement with the retained scalar oracle (`conv2d_naive`) across random
//! geometries — stride > 1, non-square inputs, rectangular filters,
//! multi-channel, multi-batch — in both the single-thread and worker-pool
//! regimes, plus the SD pipeline running end to end through the new kernel.
//!
//! Bit-exactness (not just allclose) holds because the GEMM micro-kernel
//! accumulates every output element in ascending-k order with a single f32
//! accumulator — the same operation sequence as the oracle's
//! (dy, dx, ic) loops.

use split_deconv::sd::sd_deconv2d;
use split_deconv::tensor::{conv2d_gemm, conv2d_naive, conv2d_valid, deconv2d, Filter, Tensor};
use split_deconv::util::rng::Rng;

#[test]
fn gemm_bit_exact_200_random_geometries() {
    let mut rng = Rng::new(0x6E44);
    for case in 0..200 {
        let s = 1 + rng.below(3); // stride 1..=3
        let kh = 1 + rng.below(5);
        let kw = 1 + rng.below(5); // rectangular filters
        let ic = 1 + rng.below(6); // multi-channel
        let oc = 1 + rng.below(9);
        let h = kh + rng.below(12);
        let w = kw + rng.below(14); // non-square inputs
        let n = 1 + rng.below(3); // multi-batch
        let x = Tensor::randn(n, h, w, ic, &mut rng);
        let f = Filter::randn(kh, kw, ic, oc, &mut rng);
        let got = conv2d_valid(&x, &f, s);
        let want = conv2d_naive(&x, &f, s);
        assert_eq!(
            got.shape(),
            want.shape(),
            "case {case}: n{n} {h}x{w}x{ic} k{kh}x{kw} s{s} oc{oc}"
        );
        assert_eq!(
            got.max_abs_diff(&want),
            0.0,
            "case {case}: n{n} {h}x{w}x{ic} k{kh}x{kw} s{s} oc{oc} not bit-exact"
        );
    }
}

#[test]
fn gemm_bit_exact_in_worker_pool_regime() {
    // Large enough to cross the parallel threshold: the scoped worker pool
    // must produce the same bits as the single-thread path and the oracle
    // (each output element is owned by exactly one tile).
    let mut rng = Rng::new(0x9A11);
    let x = Tensor::randn(2, 40, 40, 32, &mut rng);
    let f = Filter::randn(3, 3, 32, 64, &mut rng);
    let got = conv2d_gemm(&x, &f, 1);
    let want = conv2d_naive(&x, &f, 1);
    assert_eq!(got.max_abs_diff(&want), 0.0, "worker pool not bit-exact");
}

#[test]
fn gemm_bit_exact_strided_on_large_input() {
    let mut rng = Rng::new(0x51DE);
    let x = Tensor::randn(1, 37, 53, 24, &mut rng);
    let f = Filter::randn(4, 3, 24, 48, &mut rng);
    for s in [2, 3] {
        let got = conv2d_gemm(&x, &f, s);
        let want = conv2d_naive(&x, &f, s);
        assert_eq!(got.max_abs_diff(&want), 0.0, "stride {s} not bit-exact");
    }
}

#[test]
fn gemm_edge_geometries() {
    let mut rng = Rng::new(0xED6E);
    // 1x1 filter (pure channel mix), filter == input (single output pixel),
    // single channel, single output channel
    for (h, w, ic, kh, kw, oc, s) in [
        (7, 9, 5, 1, 1, 8, 1),
        (5, 4, 3, 5, 4, 2, 1),
        (6, 6, 1, 2, 2, 1, 2),
        (1, 8, 4, 1, 3, 3, 2),
    ] {
        let x = Tensor::randn(1, h, w, ic, &mut rng);
        let f = Filter::randn(kh, kw, ic, oc, &mut rng);
        let got = conv2d_valid(&x, &f, s);
        let want = conv2d_naive(&x, &f, s);
        assert_eq!(
            got.max_abs_diff(&want),
            0.0,
            "{h}x{w}x{ic} k{kh}x{kw} s{s} oc{oc}"
        );
    }
}

#[test]
fn sd_pipeline_exact_through_gemm_kernel() {
    // The SD transform's split convolutions run through conv2d_valid (the
    // GEMM path); the pipeline must stay exact vs the scatter deconvolution
    // on the DCGAN geometry.
    let mut rng = Rng::new(0x5D5D);
    let x = Tensor::randn(2, 8, 8, 32, &mut rng);
    let f = Filter::randn(5, 5, 32, 16, &mut rng);
    let want = deconv2d(&x, &f, 2, 2, 1);
    let got = sd_deconv2d(&x, &f, 2, 2, 1);
    assert_eq!(got.shape(), want.shape());
    assert!(
        got.allclose(&want, 1e-4),
        "SD via GEMM diff {}",
        got.max_abs_diff(&want)
    );
}
