//! Property tests for the im2col + GEMM convolution hot path against the
//! retained scalar oracle (`conv2d_naive`) across random geometries —
//! stride > 1, non-square inputs, rectangular filters, multi-channel,
//! multi-batch — in both the single-thread and worker-pool regimes, plus
//! the SD pipeline running end to end through the kernel.
//!
//! Numerics policy (see `tensor::gemm` and DESIGN.md §10): on the scalar
//! backend the GEMM is **bit-exact** with the oracle (identical
//! per-element operation sequence); on the AVX2+FMA backend it matches the
//! oracle to the documented ULP bound (FMA re-rounds each step, never
//! reorders k). Thread count never changes a bit on either backend — the
//! f64-referenced sweeps live in rust/tests/gemm_numerics.rs.

use split_deconv::sd::sd_deconv2d;
use split_deconv::tensor::{
    active_backend, conv2d_gemm, conv2d_naive, conv2d_valid, deconv2d, gemm, Filter, GemmBackend,
    Tensor,
};
use split_deconv::util::rng::Rng;

/// Policy assertion (DESIGN.md §10): bit-exact vs the f32 oracle on the
/// scalar backend; on SIMD, every element within the rigorous forward
/// bound `k·ε·Σ|aᵢbᵢ|` of an f64 reference, and well-conditioned elements
/// (Σ|aᵢbᵢ| ≤ 8·|ref|) additionally ULP-close. The conditioning filter
/// matters: near-cancelling sums legitimately amplify the FMA-vs-mul+add
/// rounding difference without bounding it in ULPs of the tiny result.
fn assert_matches_oracle(got: &Tensor, x: &Tensor, f: &Filter, stride: usize, ctx: &str) {
    let want = conv2d_naive(x, f, stride);
    assert_eq!(got.shape(), want.shape(), "{ctx}");
    if active_backend() == GemmBackend::Scalar {
        assert_eq!(got.max_abs_diff(&want), 0.0, "{ctx}: scalar backend not bit-exact");
        return;
    }
    let kdim = f.kh * f.kw * f.ic;
    let eps = f32::EPSILON as f64;
    let ulp_budget = 8 * gemm::ulp_bound(kdim);
    let (oh, ow) = (want.h, want.w);
    let mut i = 0;
    for n in 0..want.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for o in 0..f.oc {
                    let mut refv = 0.0f64;
                    let mut sa = 0.0f64;
                    for dy in 0..f.kh {
                        for dx in 0..f.kw {
                            for ic in 0..f.ic {
                                let term = x.at(n, oy * stride + dy, ox * stride + dx, ic) as f64
                                    * f.at(dy, dx, ic, o) as f64;
                                refv += term;
                                sa += term.abs();
                            }
                        }
                    }
                    let g = got.data[i];
                    let err = (g as f64 - refv).abs();
                    let bound = kdim as f64 * eps * sa + f64::from(f32::MIN_POSITIVE);
                    assert!(
                        err <= bound,
                        "{ctx}: elem {i}: |{g} - {refv}| = {err} > forward bound {bound}"
                    );
                    if sa <= 8.0 * refv.abs() {
                        let d = gemm::ulp_distance(g, refv as f32);
                        assert!(
                            d <= ulp_budget,
                            "{ctx}: elem {i}: {g} vs f64-ref {refv}: {d} ulps > {ulp_budget}"
                        );
                    }
                    i += 1;
                }
            }
        }
    }
}

#[test]
fn gemm_matches_oracle_200_random_geometries() {
    let mut rng = Rng::new(0x6E44);
    for case in 0..200 {
        let s = 1 + rng.below(3); // stride 1..=3
        let kh = 1 + rng.below(5);
        let kw = 1 + rng.below(5); // rectangular filters
        let ic = 1 + rng.below(6); // multi-channel
        let oc = 1 + rng.below(9);
        let h = kh + rng.below(12);
        let w = kw + rng.below(14); // non-square inputs
        let n = 1 + rng.below(3); // multi-batch
        let x = Tensor::randn(n, h, w, ic, &mut rng);
        let f = Filter::randn(kh, kw, ic, oc, &mut rng);
        let got = conv2d_valid(&x, &f, s);
        assert_matches_oracle(
            &got,
            &x,
            &f,
            s,
            &format!("case {case}: n{n} {h}x{w}x{ic} k{kh}x{kw} s{s} oc{oc}"),
        );
    }
}

#[test]
fn gemm_worker_pool_regime_is_thread_invariant_and_tracks_oracle() {
    // Large enough to cross the parallel threshold: the persistent worker
    // pool must produce the same bits as the single-thread path (each
    // output element is owned by exactly one tile, and per-element
    // accumulation order is tile-independent), and both must track the
    // scalar oracle per the policy.
    let mut rng = Rng::new(0x9A11);
    let x = Tensor::randn(2, 40, 40, 32, &mut rng);
    let f = Filter::randn(3, 3, 32, 64, &mut rng);
    let got = conv2d_gemm(&x, &f, 1);
    assert_matches_oracle(&got, &x, &f, 1, "worker pool regime");
    // and across runs: the pool must be deterministic, not just close
    let again = conv2d_gemm(&x, &f, 1);
    assert_eq!(got.max_abs_diff(&again), 0.0, "two runs disagree bitwise");
}

#[test]
fn gemm_strided_on_large_input_tracks_oracle() {
    let mut rng = Rng::new(0x51DE);
    let x = Tensor::randn(1, 37, 53, 24, &mut rng);
    let f = Filter::randn(4, 3, 24, 48, &mut rng);
    for s in [2, 3] {
        let got = conv2d_gemm(&x, &f, s);
        assert_matches_oracle(&got, &x, &f, s, &format!("stride {s}"));
    }
}

#[test]
fn gemm_edge_geometries() {
    let mut rng = Rng::new(0xED6E);
    // 1x1 filter (pure channel mix), filter == input (single output pixel),
    // single channel, single output channel
    for (h, w, ic, kh, kw, oc, s) in [
        (7, 9, 5, 1, 1, 8, 1),
        (5, 4, 3, 5, 4, 2, 1),
        (6, 6, 1, 2, 2, 1, 2),
        (1, 8, 4, 1, 3, 3, 2),
    ] {
        let x = Tensor::randn(1, h, w, ic, &mut rng);
        let f = Filter::randn(kh, kw, ic, oc, &mut rng);
        let got = conv2d_valid(&x, &f, s);
        assert_matches_oracle(&got, &x, &f, s, &format!("{h}x{w}x{ic} k{kh}x{kw} s{s} oc{oc}"));
    }
}

#[test]
fn sd_pipeline_exact_through_gemm_kernel() {
    // The SD transform's split convolutions run through conv2d_valid (the
    // GEMM path); the pipeline must stay exact vs the scatter deconvolution
    // on the DCGAN geometry.
    let mut rng = Rng::new(0x5D5D);
    let x = Tensor::randn(2, 8, 8, 32, &mut rng);
    let f = Filter::randn(5, 5, 32, 16, &mut rng);
    let want = deconv2d(&x, &f, 2, 2, 1);
    let got = sd_deconv2d(&x, &f, 2, 2, 1);
    assert_eq!(got.shape(), want.shape());
    assert!(
        got.allclose(&want, 1e-4),
        "SD via GEMM diff {}",
        got.max_abs_diff(&want)
    );
}
