//! Quant subsystem acceptance tests: the scheme's property bounds, the
//! int8 GEMM's zero-tolerance oracles, the SSIM accuracy gate of the
//! quantized engine (>= 0.97 vs f32 on all six benchmarks), and the
//! quantized serving mode end to end.
//!
//! The big benchmarks run spatially scaled (same factors as
//! rust/tests/engine_equivalence.rs) so the debug-mode suite stays
//! minutes-scale; scaling changes resolutions only — layer kinds, channel
//! mixes, SD geometries, and the quantization scheme are identical, and
//! DCGAN is additionally gated at full scale. Full-resolution SSIM numbers
//! are recorded in EXPERIMENTS.md (#Quantization) from release runs of
//! `repro report quant`.

use std::sync::Arc;
use std::time::Duration;

use split_deconv::coordinator::{Server, ServerConfig};
use split_deconv::engine::{DeconvImpl, Plan, Precision, Program};
use split_deconv::networks;
use split_deconv::nn::NetworkSpec;
use split_deconv::quant::{
    absmax, conv2d_i8_into, conv2d_i8_naive, pack_sd_splits, quantize_filter, quantize_into,
    scale_for_absmax, Epilogue, QFilter, QTensor,
};
use split_deconv::report::quality;
use split_deconv::tensor::{Filter, Tensor};
use split_deconv::util::rng::Rng;

// ---------------------------------------------------------------------------
// Scheme property tests
// ---------------------------------------------------------------------------

#[test]
fn quantize_dequantize_roundtrip_error_at_most_half_a_step() {
    let mut rng = Rng::new(41);
    for (n, h, w, c) in [(1, 3, 3, 2), (2, 7, 5, 9), (1, 1, 1, 64), (3, 4, 4, 1)] {
        let x = Tensor::randn(n, h, w, c, &mut rng);
        let scale = scale_for_absmax(absmax(&x.data));
        let mut q = QTensor::empty();
        quantize_into(&x, scale, &mut q);
        for (&v, &qv) in x.data.iter().zip(&q.data) {
            let err = (v - qv as f32 * scale).abs();
            assert!(
                err <= scale / 2.0 + scale * 1e-5,
                "[{n},{h},{w},{c}] v={v}: round-trip error {err} > scale/2 = {}",
                scale / 2.0
            );
        }
    }
}

#[test]
fn per_channel_scales_are_monotone_in_channel_absmax() {
    // scale[o] = absmax_o / 127: a channel with a larger dynamic range must
    // never get a smaller quantization step
    let mut rng = Rng::new(42);
    for trial in 0..8 {
        let f = Filter::randn(3, 3, 4, 10, &mut rng);
        let qf = quantize_filter(&f);
        let mut chan_absmax = vec![0.0f32; f.oc];
        for row in f.data.chunks_exact(f.oc) {
            for (m, &v) in chan_absmax.iter_mut().zip(row) {
                *m = m.max(v.abs());
            }
        }
        let mut order: Vec<usize> = (0..f.oc).collect();
        order.sort_by(|&a, &b| chan_absmax[a].total_cmp(&chan_absmax[b]));
        for pair in order.windows(2) {
            assert!(
                qf.scales[pair[0]] <= qf.scales[pair[1]] + f32::EPSILON,
                "trial {trial}: scales not monotone in channel absmax"
            );
        }
    }
}

// ---------------------------------------------------------------------------
// Int8 GEMM oracles
// ---------------------------------------------------------------------------

/// Widened-f32 reference: the same contraction with every i8 operand
/// widened to f32. All products are integers <= 127*127 and every partial
/// sum here stays below 2^24 (k*k*ic <= 1000 in the shapes used), the
/// range where f32 integer arithmetic is exact — so this must agree with
/// the i32 kernel bit for bit.
fn conv2d_i8_widened_f32(x: &QTensor, f: &QFilter, stride: usize) -> Tensor {
    let oh = (x.h - f.kh) / stride + 1;
    let ow = (x.w - f.kw) / stride + 1;
    let colscale: Vec<f32> = f.scales.iter().map(|&s| x.scale * s).collect();
    let fidx =
        |kh: usize, kw: usize, ic: usize, oc: usize| ((kh * f.kw + kw) * f.ic + ic) * f.oc + oc;
    let mut out = Tensor::zeros(x.n, oh, ow, f.oc);
    for n in 0..x.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for o in 0..f.oc {
                    let mut acc = 0.0f32;
                    for dy in 0..f.kh {
                        for dx in 0..f.kw {
                            for i in 0..x.c {
                                let xv =
                                    x.data[x.idx(n, oy * stride + dy, ox * stride + dx, i)] as f32;
                                let wv = f.data[fidx(dy, dx, i, o)] as f32;
                                acc += xv * wv;
                            }
                        }
                    }
                    *out.at_mut(n, oy, ox, o) = acc * colscale[o];
                }
            }
        }
    }
    out
}

#[test]
fn i8_gemm_bit_exact_with_widened_f32_reference_on_random_shapes() {
    let mut rng = Rng::new(77);
    // k*k*ic kept <= 1000 so the widened-f32 sums stay exactly representable
    for &(h, w, ic, k, oc, s) in &[
        (7usize, 9usize, 8usize, 3usize, 5usize, 1usize),
        (6, 6, 24, 2, 9, 2),
        (10, 10, 4, 5, 6, 1),
        (5, 8, 100, 3, 7, 2),
    ] {
        let x = Tensor::randn(2, h, w, ic, &mut rng);
        let f = Filter::randn(k, k, ic, oc, &mut rng);
        let mut qx = QTensor::empty();
        quantize_into(&x, scale_for_absmax(absmax(&x.data)), &mut qx);
        let qf = quantize_filter(&f);
        let mut got = Tensor::zeros(0, 0, 0, 0);
        conv2d_i8_into(&qx, &qf, s, Epilogue::none(), &mut got);
        let want = conv2d_i8_widened_f32(&qx, &qf, s);
        assert_eq!(got.shape(), want.shape());
        assert_eq!(
            got.max_abs_diff(&want),
            0.0,
            "shape ({h},{w},{ic},k{k},oc{oc},s{s}) not bit-exact vs widened f32"
        );
    }
}

#[test]
fn i8_gemm_bit_exact_with_naive_oracle_on_packed_sd_splits() {
    // the engine's real operands: SD sub-filters of the expansion case
    // carry structural zero rows (nz_rows skip) and the padded input halo
    // carries quantized-zero activations (value skip) — both skips must
    // leave the result bit-identical to the unskipped naive oracle
    let mut rng = Rng::new(55);
    let f = Filter::randn(5, 5, 6, 4, &mut rng); // DCGAN-style k5 s2
    let splits = pack_sd_splits(&f, 2);
    assert_eq!(splits.len(), 4);
    assert!(
        splits.iter().any(|q| q.nz_rows.len() < q.kh * q.kw * q.ic),
        "expansion-case splits must expose structural zero rows to skip"
    );
    let x = Tensor::randn(2, 6, 6, 6, &mut rng);
    let mut relu_x = x.clone();
    split_deconv::tensor::relu(&mut relu_x); // realistic zero-rich input
    let mut qx = QTensor::empty();
    quantize_into(&relu_x, scale_for_absmax(absmax(&relu_x.data)), &mut qx);
    let mut qpad = QTensor::empty();
    qx.pad_into(2, 2, 2, 2, &mut qpad); // SD halo: p_i = k_t - 1 = 2
    for (i, qf) in splits.iter().enumerate() {
        let mut got = Tensor::zeros(0, 0, 0, 0);
        conv2d_i8_into(&qpad, qf, 1, Epilogue::none(), &mut got);
        let want = conv2d_i8_naive(&qpad, qf, 1, Epilogue::none());
        assert_eq!(got.max_abs_diff(&want), 0.0, "split {i} not bit-exact");
    }
}

#[test]
fn quantized_filter_preserves_structural_zeros() {
    // Eq. 2 expansion zeros must survive quantization exactly (symmetric
    // scheme: 0 -> 0), or the Wsparse skip would be unsound
    let mut rng = Rng::new(60);
    let f = Filter::randn(5, 5, 3, 4, &mut rng);
    for (split, qsplit) in split_deconv::sd::split_filters(&f, 2)
        .iter()
        .zip(pack_sd_splits(&f, 2))
    {
        for (&v, &q) in split.data.iter().zip(&qsplit.data) {
            if v == 0.0 {
                assert_eq!(q, 0, "structural zero quantized to {q}");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// SSIM accuracy gate (the acceptance bar of the quantized engine)
// ---------------------------------------------------------------------------

/// Debug-scale variants of all six benchmarks (same factors as
/// engine_equivalence) plus full-scale DCGAN.
fn gate_nets() -> Vec<NetworkSpec> {
    vec![
        networks::dcgan(),
        networks::scaled(&networks::dcgan(), 2),
        networks::scaled(&networks::sngan(), 2),
        networks::scaled(&networks::artgan(), 8),
        networks::scaled(&networks::gpgan(), 4),
        networks::scaled(&networks::mde(), 8),
        networks::scaled(&networks::fst(), 16),
    ]
}

#[test]
fn int8_engine_ssim_vs_f32_at_least_0_97_on_all_six_nets() {
    for net in gate_nets() {
        let ssim = quality::int8_vs_f32_ssim(&net, 5, 23).unwrap();
        assert!(
            ssim >= 0.97,
            "{}: int8-vs-f32 SSIM {ssim:.4} below the 0.97 gate",
            net.name
        );
        assert!(ssim <= 1.0 + 1e-9, "{}: SSIM {ssim} out of range", net.name);
    }
}

// ---------------------------------------------------------------------------
// Quantized serving mode
// ---------------------------------------------------------------------------

#[test]
fn quantized_serving_matches_the_int8_plan_bit_for_bit() {
    // a 2-worker pool over a shared int8 Program must serve exactly what a
    // single-threaded int8 plan computes (calibrated scales are compile-
    // time constants, so batching and worker identity cannot leak in)
    let net = networks::scaled(&networks::dcgan(), 2);
    let program =
        Arc::new(Program::from_seed_prec(&net, DeconvImpl::Sd, 7, Precision::Int8).unwrap());
    assert_eq!(program.precision(), Precision::Int8);
    let cfg = ServerConfig {
        max_batch: 2,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 16,
        workers: 2,
        precision: Precision::Int8,
        ..ServerConfig::default()
    };
    let server = Server::start_native_program(cfg, program.clone()).unwrap();
    let mut plan = Plan::from_program(program);
    let mut rng = Rng::new(5);
    let zs: Vec<Vec<f32>> = (0..6).map(|_| rng.normal_vec(plan.input_len())).collect();
    let rxs: Vec<_> = zs
        .iter()
        .map(|z| server.submit_blocking(z.clone()).unwrap())
        .collect();
    for (z, rx) in zs.iter().zip(rxs) {
        let resp = rx.recv().unwrap();
        let want = plan.execute_batch(std::slice::from_ref(z)).unwrap();
        assert_eq!(resp.image, want[0], "served int8 image != int8 plan output");
    }
    server.shutdown();
}

#[test]
fn serve_native_int8_smoke_on_full_scale_models() {
    // the ServerConfig.precision knob end to end through start_native's
    // by-name routing (full-scale compile + calibration + serve); the
    // remaining four models go through the same code path and are covered
    // at full scale by the CI serve --precision int8 step
    for model in ["dcgan", "sngan"] {
        let cfg = ServerConfig {
            max_batch: 2,
            batch_timeout: Duration::from_millis(1),
            queue_cap: 8,
            model: model.to_string(),
            workers: 1,
            precision: Precision::Int8,
            record_spans: true,
            journal: None,
            watchdog: None,
            chaos: None,
            breaker: None,
        };
        let net = networks::by_name(model).unwrap();
        let server = Server::start_native(cfg, 3).unwrap();
        let mut rng = Rng::new(9);
        let rx = server
            .submit_blocking(rng.normal_vec(net.input_elems()))
            .unwrap();
        let resp = rx.recv().unwrap();
        assert!(!resp.image.is_empty(), "{model}: empty int8 image");
        assert!(
            resp.image.iter().all(|v| v.is_finite() && v.abs() <= 1.0 + 1e-5),
            "{model}: int8 tanh output out of range"
        );
        server.shutdown();
    }
}

// ---------------------------------------------------------------------------
// Epilogue fusion property
// ---------------------------------------------------------------------------

#[test]
fn fused_relu_epilogue_equals_requantize_then_relu() {
    let mut rng = Rng::new(91);
    let x = Tensor::randn(1, 8, 8, 5, &mut rng);
    let f = Filter::randn(3, 3, 5, 6, &mut rng);
    let mut qx = QTensor::empty();
    quantize_into(&x, scale_for_absmax(absmax(&x.data)), &mut qx);
    let qf: QFilter = quantize_filter(&f);
    let mut fused = Tensor::zeros(0, 0, 0, 0);
    conv2d_i8_into(&qx, &qf, 1, Epilogue::relu(), &mut fused);
    let mut plain = conv2d_i8_naive(&qx, &qf, 1, Epilogue::none());
    split_deconv::tensor::relu(&mut plain);
    assert_eq!(fused.max_abs_diff(&plain), 0.0);
}
