//! Property tests for dynamic-batch packing: `plan_batch` +
//! `chunk_batches` (the PJRT-style chunk / zero-pad logic) over arbitrary
//! (supported, n) pairs, and the native path over every odd batch length.
//!
//! Properties locked down:
//! * chunks partition `0..n` exactly — no request crosses a chunk
//!   boundary, none is dropped or executed twice;
//! * every chunk runs on a supported executable size, chosen as the
//!   smallest covering size (`plan_batch` agreement);
//! * zero-padding lanes never leak into returned images — neither in a
//!   faithful mock of the PJRT pack/run/unpack path nor through the
//!   `NativeExecutor` at odd batch lengths 1..17.

use std::sync::Arc;

use split_deconv::coordinator::{chunk_batches, plan_batch, BatchExecutor, NativeExecutor};
use split_deconv::engine::{DeconvImpl, Program};
use split_deconv::util::rng::Rng;

mod common;
use common::tiny_net;

#[test]
fn chunks_partition_every_request_exactly_once() {
    let mut rng = Rng::new(5);
    for _ in 0..500 {
        // arbitrary supported set: 1..=4 distinct ascending sizes in 1..=32
        let mut supported: Vec<usize> = (0..1 + rng.below(4)).map(|_| 1 + rng.below(32)).collect();
        supported.sort_unstable();
        supported.dedup();
        let n = rng.below(100);
        let chunks = chunk_batches(&supported, n);
        let total: usize = chunks.iter().map(|(take, _)| take).sum();
        assert_eq!(total, n, "chunks of {supported:?} x {n} do not cover every request once");
        for &(take, b) in &chunks {
            assert!((1..=b).contains(&take), "chunk ({take}, {b}) malformed");
            assert!(supported.contains(&b), "{b} not a supported size of {supported:?}");
            // the chunk runs on the smallest covering executable
            assert_eq!(b, plan_batch(&supported, take), "{supported:?} x {n}");
        }
    }
}

/// Faithful mock of the PJRT executable path: pack `take` requests into a
/// `b`-lane zero-padded buffer, "run" it (identity per lane), unpack only
/// the first `take` lanes — exactly the `PjrtExecutor::execute` shape.
fn pjrt_style_roundtrip(supported: &[usize], reqs: &[Vec<f32>], z_len: usize) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(reqs.len());
    let mut cursor = 0;
    for (take, b) in chunk_batches(supported, reqs.len()) {
        let mut z = vec![0.0f32; b * z_len];
        for (i, req) in reqs[cursor..cursor + take].iter().enumerate() {
            z[i * z_len..(i + 1) * z_len].copy_from_slice(req);
        }
        let flat = z; // identity executable: lane j returns its own input
        for i in 0..take {
            out.push(flat[i * z_len..(i + 1) * z_len].to_vec());
        }
        cursor += take;
    }
    out
}

#[test]
fn padding_lanes_never_leak_into_returned_images() {
    let mut rng = Rng::new(9);
    let z_len = 4;
    for _ in 0..200 {
        let mut supported: Vec<usize> = (0..1 + rng.below(3)).map(|_| 1 + rng.below(8)).collect();
        supported.sort_unstable();
        supported.dedup();
        let n = rng.below(20);
        // strictly positive latents: any all-zero output would be a
        // padding lane leaking through
        let reqs: Vec<Vec<f32>> =
            (0..n).map(|_| (0..z_len).map(|_| 1.0 + rng.uniform()).collect()).collect();
        let out = pjrt_style_roundtrip(&supported, &reqs, z_len);
        assert_eq!(out.len(), n, "one image per request, no padding lane returned");
        for (i, (got, want)) in out.iter().zip(&reqs).enumerate() {
            assert_eq!(got, want, "request {i} image corrupted by packing");
        }
    }
}

#[test]
fn native_executor_odd_batch_lengths_match_singles_bitwise() {
    // the native path takes ANY batch length with no padding or chunking;
    // every length 1..17 (crossing each advisory supported size) must
    // return one image per request, bit-identical to a batch-1 run
    let program = Arc::new(Program::from_seed(&tiny_net(), DeconvImpl::Sd, 4).unwrap());
    let mut exec = NativeExecutor::from_program(program);
    let mut rng = Rng::new(12);
    for n in 1..17 {
        let reqs: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(16)).collect();
        let batched = exec.execute(&reqs).unwrap();
        assert_eq!(batched.len(), n, "batch length {n}: one image per request");
        for (i, req) in reqs.iter().enumerate() {
            let single = exec.execute(std::slice::from_ref(req)).unwrap();
            assert_eq!(
                batched[i], single[0],
                "batch length {n}, request {i}: batched image differs from single"
            );
        }
    }
}
