//! Property tests for dynamic-batch packing: `plan_batch` +
//! `chunk_batches` (the PJRT-style chunk / zero-pad logic) over arbitrary
//! (supported, n) pairs, the native path over every odd batch length, and
//! the CONTINUOUS batcher (`LaneQueue::fill`) that forms serve-path
//! batches.
//!
//! Properties locked down:
//! * chunks partition `0..n` exactly — no request crosses a chunk
//!   boundary, none is dropped or executed twice;
//! * every chunk runs on a supported executable size, chosen as the
//!   smallest covering size (`plan_batch` agreement);
//! * zero-padding lanes never leak into returned images — neither in a
//!   faithful mock of the PJRT pack/run/unpack path nor through the
//!   `NativeExecutor` at odd batch lengths 1..17;
//! * continuous batch formation: batches never exceed `max_batch`, queued
//!   items are taken greedily (no idle wait when work is ready), the fill
//!   budget is honored within tolerance even under a straggler trickle
//!   (the deadline is absolute), per-producer FIFO order survives
//!   batching, and a straggler arriving inside the window joins the batch
//!   instead of starving.

use std::sync::Arc;
use std::time::{Duration, Instant};

use split_deconv::coordinator::{
    chunk_batches, plan_batch, BatchExecutor, LaneQueue, NativeExecutor,
};
use split_deconv::engine::{DeconvImpl, Program};
use split_deconv::util::rng::Rng;

mod common;
use common::tiny_net;

#[test]
fn chunks_partition_every_request_exactly_once() {
    let mut rng = Rng::new(5);
    for _ in 0..500 {
        // arbitrary supported set: 1..=4 distinct ascending sizes in 1..=32
        let mut supported: Vec<usize> = (0..1 + rng.below(4)).map(|_| 1 + rng.below(32)).collect();
        supported.sort_unstable();
        supported.dedup();
        let n = rng.below(100);
        let chunks = chunk_batches(&supported, n);
        let total: usize = chunks.iter().map(|(take, _)| take).sum();
        assert_eq!(total, n, "chunks of {supported:?} x {n} do not cover every request once");
        for &(take, b) in &chunks {
            assert!((1..=b).contains(&take), "chunk ({take}, {b}) malformed");
            assert!(supported.contains(&b), "{b} not a supported size of {supported:?}");
            // the chunk runs on the smallest covering executable
            assert_eq!(b, plan_batch(&supported, take), "{supported:?} x {n}");
        }
    }
}

/// Faithful mock of the PJRT executable path: pack `take` requests into a
/// `b`-lane zero-padded buffer, "run" it (identity per lane), unpack only
/// the first `take` lanes — exactly the `PjrtExecutor::execute` shape.
fn pjrt_style_roundtrip(supported: &[usize], reqs: &[Vec<f32>], z_len: usize) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(reqs.len());
    let mut cursor = 0;
    for (take, b) in chunk_batches(supported, reqs.len()) {
        let mut z = vec![0.0f32; b * z_len];
        for (i, req) in reqs[cursor..cursor + take].iter().enumerate() {
            z[i * z_len..(i + 1) * z_len].copy_from_slice(req);
        }
        let flat = z; // identity executable: lane j returns its own input
        for i in 0..take {
            out.push(flat[i * z_len..(i + 1) * z_len].to_vec());
        }
        cursor += take;
    }
    out
}

#[test]
fn padding_lanes_never_leak_into_returned_images() {
    let mut rng = Rng::new(9);
    let z_len = 4;
    for _ in 0..200 {
        let mut supported: Vec<usize> = (0..1 + rng.below(3)).map(|_| 1 + rng.below(8)).collect();
        supported.sort_unstable();
        supported.dedup();
        let n = rng.below(20);
        // strictly positive latents: any all-zero output would be a
        // padding lane leaking through
        let reqs: Vec<Vec<f32>> =
            (0..n).map(|_| (0..z_len).map(|_| 1.0 + rng.uniform()).collect()).collect();
        let out = pjrt_style_roundtrip(&supported, &reqs, z_len);
        assert_eq!(out.len(), n, "one image per request, no padding lane returned");
        for (i, (got, want)) in out.iter().zip(&reqs).enumerate() {
            assert_eq!(got, want, "request {i} image corrupted by packing");
        }
    }
}

/// Drain a pre-loaded lane the way a dispatcher does (pop_any + fill) and
/// return the batches in formation order.
fn drain_in_batches(q: &LaneQueue<u32>, max_batch: usize, budget: Duration) -> Vec<Vec<u32>> {
    let mut batches = Vec::new();
    // only take more work while some is queued — pop_any blocks otherwise
    while !q.is_empty() {
        let Some((lane, first)) = q.pop_any() else { break };
        let mut batch = vec![first];
        q.fill(lane, &mut batch, max_batch, Instant::now() + budget);
        batches.push(batch);
    }
    batches
}

#[test]
fn continuous_fill_never_exceeds_max_batch_and_preserves_fifo() {
    let mut rng = Rng::new(21);
    for _ in 0..100 {
        let n = rng.below(48);
        let max_batch = 1 + rng.below(9);
        let q: LaneQueue<u32> = LaneQueue::new(1, 64);
        for i in 0..n {
            q.try_push(0, i as u32).ok().unwrap();
        }
        let batches = drain_in_batches(&q, max_batch, Duration::ZERO);
        let flat: Vec<u32> = batches.iter().flatten().copied().collect();
        assert_eq!(flat, (0..n as u32).collect::<Vec<_>>(), "drain must be lossless FIFO");
        for (bi, b) in batches.iter().enumerate() {
            assert!(b.len() <= max_batch, "batch {bi} has {} > max_batch {max_batch}", b.len());
            // greedy: a batch below max_batch is only allowed when it
            // drained the queue (it was the last one)
            if b.len() < max_batch {
                assert_eq!(bi, batches.len() - 1, "short batch {bi} while work was queued");
            }
        }
    }
}

#[test]
fn elapsed_budget_still_dispatches_queued_items_without_blocking() {
    // Property: a zero or already-elapsed fill budget bounds only the
    // wait for NOT-YET-ARRIVED items — everything already queued is
    // dispatched immediately, and an empty lane returns at once rather
    // than parking on the condvar.
    let mut rng = Rng::new(33);
    for round in 0..100 {
        let n = 1 + rng.below(32);
        let max_batch = 1 + rng.below(12);
        let q: LaneQueue<u32> = LaneQueue::new(1, 64);
        for i in 0..n {
            q.try_push(0, i as u32).ok().unwrap();
        }
        // a deadline firmly in the past: the budget is spent before fill
        // is even called
        let now = Instant::now();
        let stale = now.checked_sub(Duration::from_secs(5)).unwrap_or(now);
        let (lane, first) = q.pop_any().unwrap();
        let mut batch = vec![first];
        let t0 = Instant::now();
        let appended = q.fill(lane, &mut batch, max_batch, stale);
        let elapsed = t0.elapsed();
        let want = max_batch.min(n) - 1; // first already popped
        assert_eq!(
            appended, want,
            "round {round} (n={n}, max_batch={max_batch}): stale budget must take ready work"
        );
        assert_eq!(batch.len(), 1 + want, "never an empty/short batch while work sits queued");
        assert_eq!(batch, (0..batch.len() as u32).collect::<Vec<_>>(), "drain stays FIFO");
        assert!(elapsed < Duration::from_millis(250), "elapsed budget must not block: {elapsed:?}");
    }

    // empty lane + elapsed budget: return 0 immediately, no condvar park
    let q: LaneQueue<u32> = LaneQueue::new(1, 8);
    let mut batch: Vec<u32> = Vec::new();
    let now = Instant::now();
    let stale = now.checked_sub(Duration::from_millis(1)).unwrap_or(now);
    let t0 = Instant::now();
    let appended = q.fill(0, &mut batch, 4, stale);
    assert_eq!(appended, 0);
    assert!(batch.is_empty());
    assert!(t0.elapsed() < Duration::from_millis(250), "empty lane must not block on stale budget");
}

#[test]
fn continuous_fill_budget_is_absolute_even_under_straggler_trickle() {
    let q: Arc<LaneQueue<u32>> = Arc::new(LaneQueue::new(1, 1024));
    q.try_push(0, 0).ok().unwrap();
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let (q2, stop2) = (q.clone(), stop.clone());
    // a trickle of stragglers, each arriving well inside the budget: a
    // RELATIVE timeout would be re-armed by every arrival and never fire
    let trickler = std::thread::spawn(move || {
        let mut i = 1u32;
        while !stop2.load(std::sync::atomic::Ordering::SeqCst) {
            let _ = q2.try_push(0, i);
            i += 1;
            std::thread::sleep(Duration::from_millis(5));
        }
    });

    let (_, first) = q.pop_any().unwrap();
    let mut batch = vec![first];
    let budget = Duration::from_millis(60);
    let t0 = Instant::now();
    q.fill(0, &mut batch, usize::MAX, t0 + budget);
    let elapsed = t0.elapsed();
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    trickler.join().unwrap();

    assert!(
        elapsed >= Duration::from_millis(55),
        "fill returned at {elapsed:?}, before its {budget:?} budget"
    );
    assert!(
        elapsed < Duration::from_millis(300),
        "fill ran {elapsed:?}: the trickle extended the absolute {budget:?} budget"
    );
    assert!(batch.len() >= 2, "stragglers inside the window must join the batch");
    // FIFO within the batch
    for w in batch.windows(2) {
        assert!(w[0] < w[1], "batch out of arrival order: {batch:?}");
    }
}

#[test]
fn continuous_fill_includes_stragglers_instead_of_starving_them() {
    let q: Arc<LaneQueue<u32>> = Arc::new(LaneQueue::new(1, 8));
    q.try_push(0, 1).ok().unwrap();
    let q2 = q.clone();
    let straggler = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(40));
        q2.try_push(0, 2).ok().unwrap();
    });
    let (_, first) = q.pop_any().unwrap();
    let mut batch = vec![first];
    let t0 = Instant::now();
    // budget far beyond the straggler's arrival; max_batch 2 means the
    // straggler's arrival completes the batch EARLY (no waiting out the
    // full budget once the batch is full)
    q.fill(0, &mut batch, 2, t0 + Duration::from_secs(5));
    let elapsed = t0.elapsed();
    straggler.join().unwrap();
    assert_eq!(batch, vec![1, 2], "the straggler must join the in-formation batch");
    assert!(elapsed < Duration::from_secs(2), "a full batch must dispatch immediately");
}

#[test]
fn concurrent_producers_keep_per_producer_fifo_through_batching() {
    const PRODUCERS: u32 = 4;
    const PER_PRODUCER: u32 = 64;
    let q: Arc<LaneQueue<u32>> = Arc::new(LaneQueue::new(1, 16));
    let producers: Vec<_> = (0..PRODUCERS)
        .map(|p| {
            let q = q.clone();
            std::thread::spawn(move || {
                for seq in 0..PER_PRODUCER {
                    // tag = producer in the high bits, sequence in the low
                    q.push(0, (p << 16) | seq).ok().unwrap();
                }
            })
        })
        .collect();

    // single consumer forming continuous batches while producers run
    let mut flat: Vec<u32> = Vec::new();
    while flat.len() < (PRODUCERS * PER_PRODUCER) as usize {
        let (lane, first) = q.pop_any().expect("queue never closes during the test");
        let mut batch = vec![first];
        q.fill(lane, &mut batch, 7, Instant::now() + Duration::from_millis(1));
        assert!(batch.len() <= 7);
        flat.extend(batch);
    }
    for p in producers {
        p.join().unwrap();
    }

    // per-producer order must survive: each producer's sequence numbers
    // appear strictly increasing in the drained stream
    for p in 0..PRODUCERS {
        let seqs: Vec<u32> = flat.iter().filter(|v| *v >> 16 == p).map(|v| v & 0xffff).collect();
        assert_eq!(seqs.len(), PER_PRODUCER as usize, "producer {p} lost items");
        for (i, w) in seqs.windows(2).enumerate() {
            assert!(w[0] < w[1], "producer {p} reordered at {i}: {w:?}");
        }
    }
}

#[test]
fn native_executor_odd_batch_lengths_match_singles_bitwise() {
    // the native path takes ANY batch length with no padding or chunking;
    // every length 1..17 (crossing each advisory supported size) must
    // return one image per request, bit-identical to a batch-1 run
    let program = Arc::new(Program::from_seed(&tiny_net(), DeconvImpl::Sd, 4).unwrap());
    let mut exec = NativeExecutor::from_program(program);
    let mut rng = Rng::new(12);
    for n in 1..17 {
        let reqs: Vec<Vec<f32>> = (0..n).map(|_| rng.normal_vec(16)).collect();
        let batched = exec.execute(&reqs).unwrap();
        assert_eq!(batched.len(), n, "batch length {n}: one image per request");
        for (i, req) in reqs.iter().enumerate() {
            let single = exec.execute(std::slice::from_ref(req)).unwrap();
            assert_eq!(
                batched[i], single[0],
                "batch length {n}, request {i}: batched image differs from single"
            );
        }
    }
}
