//! End-to-end tests for the flight recorder (DESIGN.md §14): bounded
//! memory under a multi-threaded emit storm, a schema-valid Perfetto
//! export from a REAL serving stack, and the stall watchdog catching an
//! injected wedged-executor fault.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use split_deconv::coordinator::{BatchExecutor, Server, ServerConfig, WatchdogConfig};
use split_deconv::engine::{DeconvImpl, Precision, Program};
use split_deconv::obs::{
    chrome_trace_json, validate_chrome_trace, EventKind, Journal, JournalConfig,
};
use split_deconv::util::rng::Rng;

mod common;
use common::tiny_net;

/// Millions of events from many threads into a small journal: memory
/// stays FIXED (the rings are allocated once, wraparound evicts the
/// oldest), nothing is lost from the retained window, and a concurrent
/// reader never observes a torn event.
#[test]
fn journal_memory_is_bounded_under_a_multithreaded_emit_storm() {
    const THREADS: usize = 8;
    const PER_THREAD: u64 = 250_000; // 2M events total
    let j = Journal::new(JournalConfig {
        rings: 4,
        ring_capacity: 1024,
    });
    let footprint_before = j.footprint_bytes();
    assert!(
        footprint_before < (1 << 20),
        "a 4x1024 journal is well under a megabyte, got {footprint_before}"
    );

    let stop_reader = Arc::new(AtomicBool::new(false));
    let reader = {
        let j = j.clone();
        let stop = stop_reader.clone();
        std::thread::spawn(move || {
            // hammer snapshots WHILE writers wrap the rings: the seq
            // protocol must never surface a torn event (every decoded
            // event has a valid kind by construction; a torn read would
            // surface as a mismatched seq and be skipped, never invented)
            let mut reads = 0u64;
            while !stop.load(Ordering::Relaxed) {
                let events = j.snapshot();
                assert!(
                    events.len() <= j.capacity_events(),
                    "snapshot may never exceed the ring capacity"
                );
                for w in events.windows(2) {
                    assert!(w[0].ts_us <= w[1].ts_us, "snapshot is ts-sorted");
                }
                reads += 1;
            }
            reads
        })
    };

    std::thread::scope(|s| {
        for t in 0..THREADS {
            let j = &j;
            s.spawn(move || {
                for i in 0..PER_THREAD {
                    j.emit(EventKind::Enqueue, (t % 4) as u16, 0, i, t as u64 + 1);
                }
            });
        }
    });
    stop_reader.store(true, Ordering::Relaxed);
    let reads = reader.join().unwrap();
    assert!(reads > 0, "the concurrent reader must have run");

    assert_eq!(
        j.emitted(),
        THREADS as u64 * PER_THREAD,
        "every emit claims a slot, even the overwritten ones"
    );
    assert_eq!(
        j.footprint_bytes(),
        footprint_before,
        "2M events through a fixed-size journal must not grow it"
    );
    let events = j.snapshot();
    assert!(!events.is_empty() && events.len() <= j.capacity_events());
    // the retained window is the NEWEST events: with per-thread counters
    // as args, every ring holds a dense tail of each writer's sequence
    let max_arg = events.iter().map(|e| e.arg).max().unwrap();
    assert!(
        max_arg >= PER_THREAD - 1,
        "the final events of the storm must be retained, max arg {max_arg}"
    );
}

/// A real native server (tiny net, 2 workers) under a journal: the
/// Chrome trace export passes the schema gate, grows one track per
/// emitting thread plus the lane track, and every request's
/// admission→respond flow arrow resolves.
#[test]
fn real_server_timeline_exports_schema_valid_chrome_trace() {
    const REQUESTS: usize = 12;
    let net = tiny_net();
    let program = Arc::new(Program::from_seed(&net, DeconvImpl::Sd, 4).unwrap());
    let journal = Journal::new(JournalConfig {
        rings: 4,
        ring_capacity: 4096,
    });
    let cfg = ServerConfig {
        max_batch: 4,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 64,
        model: "tiny".to_string(),
        workers: 2,
        precision: Precision::F32,
        record_spans: true,
        journal: Some(journal.clone()),
        watchdog: None,
        chaos: None,
        breaker: None,
    };
    let server = Server::start_native_program(cfg, program).unwrap();
    let mut rng = Rng::new(3);
    let rxs: Vec<_> = (0..REQUESTS)
        .map(|_| server.submit_blocking(rng.normal_vec(16)).unwrap())
        .collect();
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
    }
    server.shutdown();

    let events = journal.snapshot();
    let kinds: Vec<EventKind> = events.iter().map(|e| e.kind).collect();
    for want in [EventKind::Enqueue, EventKind::Dispatch, EventKind::ComputeEnd, EventKind::Respond]
    {
        assert!(kinds.contains(&want), "journal missing {want:?} events");
    }

    let json = chrome_trace_json(&events, &journal.thread_names(), &["tiny".to_string()]);
    let stats = validate_chrome_trace(&json).expect("server timeline must pass the schema gate");
    assert!(stats.events > 0, "{stats:?}");
    assert!(stats.tracks >= 2, "dispatcher track(s) + lane track: {stats:?}");
    assert_eq!(
        stats.flows, REQUESTS,
        "every served request's enqueue->respond flow must resolve: {stats:?}"
    );
    assert!(json.contains("lane:tiny"), "lane track must be named");
    assert!(json.contains("sd-dispatcher-"), "dispatcher tracks carry thread names");
}

/// An executor wedged mid-batch while more work is queued: the watchdog
/// must flag the silent dispatcher (and the over-age in-flight request)
/// within a few scan intervals, counted in `watchdog_stalls`.
struct WedgedExec {
    release: Arc<AtomicBool>,
}

impl BatchExecutor for WedgedExec {
    fn supported_batches(&self) -> &[usize] {
        &[1]
    }
    fn z_len(&self) -> usize {
        4
    }
    fn image_len(&self) -> usize {
        4
    }
    fn execute(&mut self, batch: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        while !self.release.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(5));
        }
        Ok(batch.to_vec())
    }
}

#[test]
fn watchdog_flags_an_injected_stalled_worker() {
    let release = Arc::new(AtomicBool::new(false));
    let journal = Journal::new(JournalConfig {
        rings: 2,
        ring_capacity: 1024,
    });
    let cfg = ServerConfig {
        max_batch: 1,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 8,
        model: "wedged".to_string(),
        workers: 1,
        precision: Precision::F32,
        record_spans: true,
        journal: Some(journal.clone()),
        watchdog: Some(WatchdogConfig {
            interval: Duration::from_millis(30),
            stall_after: Duration::from_millis(50),
            max_request_age: Duration::from_millis(50),
        }),
        chaos: None,
        breaker: None,
    };
    let factory_release = release.clone();
    let server = Server::start_with(cfg, move |_worker| {
        Ok(WedgedExec {
            release: factory_release.clone(),
        })
    })
    .unwrap();

    // request A wedges the single worker inside execute(); request B
    // queues behind it, arming the "silent while work is queued" rule
    let rx_a = server.submit_blocking(vec![1.0; 4]).unwrap();
    std::thread::sleep(Duration::from_millis(20));
    let rx_b = server.submit_blocking(vec![2.0; 4]).unwrap();

    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        if server.metrics().watchdog_stalls > 0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "watchdog never flagged the wedged worker: {}",
            server.metrics().summary()
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    // un-wedge: both requests complete and shutdown stays clean
    release.store(true, Ordering::SeqCst);
    rx_a.recv_timeout(Duration::from_secs(10)).unwrap();
    rx_b.recv_timeout(Duration::from_secs(10)).unwrap();
    server.shutdown();
    assert!(server.metrics().watchdog_stalls > 0);
}
