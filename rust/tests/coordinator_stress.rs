//! Concurrency stress suite for the multi-worker native serving stack:
//! 8 producer threads x 200 submits against a 4-worker server whose
//! workers share ONE `Arc<Program>` (each with its own `Scratch`).
//!
//! Asserted under contention:
//! * every accepted request gets exactly ONE response carrying its own
//!   image (latents are id-tagged by drawing from a small pool whose
//!   expected images are precomputed single-threaded — any cross-request
//!   buffer reuse bug in the shared program would mismatch);
//! * observed queue depth never exceeds `queue_cap`;
//! * `shutdown()` mid-flight neither deadlocks nor drops a request that
//!   `submit` had already accepted (close-then-drain).
//!
//! The generator is a small-but-real chain (dense -> two SD deconvs on
//! the GEMM kernel) so the suite drives the production engine path at
//! 1600 requests without benchmark-scale debug-build compute. CI runs
//! this file in its own step under a watchdog timeout, so a deadlock
//! fails fast instead of hanging the workflow.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use split_deconv::coordinator::{Server, ServerConfig};
use split_deconv::engine::{DeconvImpl, Program, Scratch};
use split_deconv::util::rng::Rng;

mod common;
use common::tiny_net;

const PRODUCERS: usize = 8;
const PER_PRODUCER: usize = 200;
const POOL: usize = 8;

#[test]
fn stress_8x200_exactly_one_tagged_response_each() {
    let program = Arc::new(Program::from_seed(&tiny_net(), DeconvImpl::Sd, 5).unwrap());
    // id-tagged latents: a pool of distinct latents with single-threaded
    // reference images; every response must bit-match its own tag's image
    let mut rng = Rng::new(1);
    let pool: Vec<Vec<f32>> = (0..POOL).map(|_| rng.normal_vec(16)).collect();
    let mut scratch = Scratch::new();
    let expected: Vec<Vec<f32>> = pool
        .iter()
        .map(|z| {
            let mut out = program.execute_batch(std::slice::from_ref(z), &mut scratch).unwrap();
            out.remove(0)
        })
        .collect();

    let cfg = ServerConfig {
        max_batch: 8,
        batch_timeout: Duration::from_micros(200),
        queue_cap: 32,
        workers: 4,
        ..ServerConfig::default()
    };
    let server = Server::start_native_program(cfg, program).unwrap();
    let ids = Mutex::new(HashSet::new());
    std::thread::scope(|s| {
        for p in 0..PRODUCERS {
            let server = &server;
            let ids = &ids;
            let pool = &pool;
            let expected = &expected;
            s.spawn(move || {
                for i in 0..PER_PRODUCER {
                    let k = (p * PER_PRODUCER + i) % POOL;
                    let rx = server.submit_blocking(pool[k].clone()).unwrap();
                    let r = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                    assert_eq!(r.image, expected[k], "producer {p} request {i}: wrong image");
                    assert!(ids.lock().unwrap().insert(r.id), "duplicate id {}", r.id);
                }
            });
        }
    });
    assert_eq!(ids.into_inner().unwrap().len(), PRODUCERS * PER_PRODUCER);
    let m = server.metrics();
    assert_eq!(m.served as usize, PRODUCERS * PER_PRODUCER);
    assert_eq!(m.errors, 0);
    assert!(m.max_queue_depth <= 32, "queue depth {} exceeded queue_cap", m.max_queue_depth);
    assert_eq!(m.worker_batches.len(), 4);
    assert_eq!(m.worker_served.iter().sum::<u64>(), m.served);
    server.shutdown();
}

#[test]
fn stress_shutdown_mid_flight_drops_nothing_accepted() {
    let program = Arc::new(Program::from_seed(&tiny_net(), DeconvImpl::Sd, 6).unwrap());
    let cfg = ServerConfig {
        max_batch: 4,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 64,
        workers: 4,
        ..ServerConfig::default()
    };
    let server = Server::start_native_program(cfg, program).unwrap();
    let accepted = Mutex::new(Vec::new());
    let submitted = AtomicUsize::new(0);
    const PER_PRODUCER_SUBMITS: usize = 100;
    const TOTAL_SUBMITS: usize = PRODUCERS * PER_PRODUCER_SUBMITS;
    std::thread::scope(|s| {
        for p in 0..PRODUCERS {
            let server = &server;
            let accepted = &accepted;
            let submitted = &submitted;
            s.spawn(move || {
                let mut rng = Rng::new(100 + p as u64);
                for _ in 0..PER_PRODUCER_SUBMITS {
                    // non-blocking submit: backpressure rejections and
                    // post-shutdown rejections owe no response
                    if let Ok(rx) = server.submit(rng.normal_vec(16)) {
                        accepted.lock().unwrap().push(rx);
                    }
                    submitted.fetch_add(1, Ordering::Relaxed);
                }
            });
        }
        // deterministically mid-flight: wait until at least half the
        // submits happened (producers are still looping), THEN shut down
        // concurrently with the rest — must neither deadlock nor drop an
        // already-accepted request
        while submitted.load(Ordering::Relaxed) < TOTAL_SUBMITS / 2 {
            std::thread::yield_now();
        }
        server.shutdown();
    });
    assert_eq!(submitted.load(Ordering::Relaxed), TOTAL_SUBMITS);
    let accepted = accepted.into_inner().unwrap();
    for (i, rx) in accepted.iter().enumerate() {
        let r = rx
            .recv_timeout(Duration::from_secs(30))
            .unwrap_or_else(|e| panic!("accepted request {i} dropped at shutdown: {e}"));
        assert!(!r.image.is_empty());
    }
    let m = server.metrics();
    assert_eq!(m.served as usize, accepted.len(), "served != accepted");
}
