//! Engine acceptance tests: the compiled `engine::Plan` must be
//! **bit-identical** (zero tolerance) to the retained `run_network_with`
//! interpreter oracle on every benchmark network and batch size, and the
//! serving executor must route every model name.
//!
//! The big benchmarks run spatially scaled (`networks::scaled`) so the
//! debug-mode suite stays minutes-scale on small machines; scaling changes
//! resolutions only — layer kinds, channel mixes, and SD geometries (the
//! things the engine compiles) are identical, and DCGAN is additionally
//! checked at full scale.

use std::sync::Arc;

use split_deconv::coordinator::{BatchExecutor, NativeExecutor, Server, ServerConfig};
use split_deconv::engine::{build_weights, chain_gaps, DeconvImpl, Plan, Program, Scratch};
use split_deconv::networks;
use split_deconv::nn::NetworkSpec;
use split_deconv::report::quality::run_network_with;
use split_deconv::tensor::Tensor;
use split_deconv::util::rng::Rng;

/// Test-scale variants of all six benchmarks. Scaling clamps can open
/// extra (bridged) chain gaps beyond the two canonical ones; that is fine
/// for engine-vs-oracle equivalence (both share the bridge, and every op
/// is still validated against its own layer spec), but the suite keeps two
/// scaled networks *provably gap-free* — asserted below — plus full-scale
/// DCGAN, so the pure-chain path is exercised end to end as well.
fn test_nets() -> Vec<NetworkSpec> {
    let sngan = networks::scaled(&networks::sngan(), 2);
    let fst = networks::scaled(&networks::fst(), 16);
    assert!(chain_gaps(&sngan).is_empty(), "scaled SNGAN must stay a pure chain");
    assert!(chain_gaps(&fst).is_empty(), "scaled FST must stay a pure chain");
    vec![
        networks::scaled(&networks::dcgan(), 2),
        sngan,
        networks::scaled(&networks::artgan(), 8),
        networks::scaled(&networks::gpgan(), 4),
        networks::scaled(&networks::mde(), 8),
        fst,
    ]
}

fn input_for(net: &NetworkSpec, batch: usize, seed: u64) -> Tensor {
    let l0 = &net.layers[0];
    let mut rng = Rng::new(seed);
    Tensor::randn(batch, l0.in_h, l0.in_w, l0.in_c, &mut rng)
}

#[test]
fn engine_bit_identical_to_oracle_all_networks_and_batches() {
    for net in test_nets() {
        let weights = build_weights(&net, 5);
        let mut plan = Plan::build(&net, &weights, DeconvImpl::Sd).unwrap();
        for batch in [1usize, 3, 4] {
            let input = input_for(&net, batch, 100 + batch as u64);
            let want = run_network_with(&net, DeconvImpl::Sd, &weights, &input).unwrap();
            let got = plan.forward(&input).unwrap();
            assert_eq!(got.shape(), want.shape(), "{} b{batch}", net.name);
            assert_eq!(
                got.max_abs_diff(&want),
                0.0,
                "{} b{batch}: engine not bit-identical to the oracle",
                net.name
            );
        }
    }
}

#[test]
fn engine_bit_identical_to_oracle_full_scale_dcgan() {
    let net = networks::dcgan();
    let weights = build_weights(&net, 9);
    let mut plan = Plan::build(&net, &weights, DeconvImpl::Sd).unwrap();
    let input = input_for(&net, 1, 42);
    let want = run_network_with(&net, DeconvImpl::Sd, &weights, &input).unwrap();
    let got = plan.forward(&input).unwrap();
    assert_eq!(got.shape(), [1, 64, 64, 3]);
    assert_eq!(got.max_abs_diff(&want), 0.0);
}

#[test]
fn engine_bit_identical_to_oracle_for_every_deconv_impl() {
    // every conversion approach runs through the same engine path the
    // quality evaluation (Table 4) uses
    let net = networks::scaled(&networks::dcgan(), 2);
    let weights = build_weights(&net, 11);
    let input = input_for(&net, 1, 7);
    for imp in [
        DeconvImpl::Native,
        DeconvImpl::Sd,
        DeconvImpl::Nzp,
        DeconvImpl::Shi,
        DeconvImpl::Chang,
    ] {
        let want = run_network_with(&net, imp, &weights, &input).unwrap();
        let got = Plan::build(&net, &weights, imp).unwrap().forward(&input).unwrap();
        assert_eq!(
            got.max_abs_diff(&want),
            0.0,
            "{:?}: engine not bit-identical to the oracle",
            imp
        );
    }
}

#[test]
fn plan_forward_is_batch_invariant_per_request() {
    // a request's image must not depend on which batch carried it
    let net = networks::scaled(&networks::sngan(), 2);
    let mut plan = Plan::from_seed(&net, DeconvImpl::Sd, 3).unwrap();
    let mut rng = Rng::new(17);
    let zs: Vec<Vec<f32>> = (0..4).map(|_| rng.normal_vec(net.input_elems())).collect();
    let b4 = plan.execute_batch(&zs).unwrap();
    let b3 = plan.execute_batch(&zs[..3]).unwrap();
    let b1 = plan.execute_batch(&zs[..1]).unwrap();
    assert_eq!(b4[..3], b3[..]);
    assert_eq!(b3[..1], b1[..]);
}

#[test]
fn only_the_documented_chain_gaps_bridge() {
    // the canonical layer tables must bridge at exactly the two documented
    // points (GP-GAN's fc bottleneck reshape, MDE's skip-concat input) —
    // a table typo that opened a new silent gap fails here
    for net in networks::all() {
        let want: &[&str] = match net.name {
            "GP-GAN" => &["dec1"],
            "MDE" => &["upconv3"],
            _ => &[],
        };
        assert_eq!(chain_gaps(&net), want, "{}: unexpected chain gaps", net.name);
    }
}

#[test]
fn native_executor_builds_plans_for_all_six_models() {
    for name in networks::names() {
        let exec = NativeExecutor::for_model(name, 1)
            .unwrap_or_else(|e| panic!("{name}: plan build failed: {e:#}"));
        let net = networks::by_name(name).unwrap();
        assert_eq!(exec.z_len(), net.input_elems(), "{name} input length");
        assert!(exec.image_len() > 0, "{name} output length");
    }
    assert!(NativeExecutor::for_model("resnet", 1).is_err());
}

#[test]
fn concurrent_workers_on_shared_program_match_oracle_bit_exactly() {
    // two workers executing concurrently on the SAME Arc<Program> (each
    // with its own Scratch) must both stay bit-identical to the
    // single-threaded interpreter oracle — the soundness claim behind
    // sharing one compile across the worker pool
    let net = networks::scaled(&networks::dcgan(), 2);
    let weights = build_weights(&net, 5);
    let program = Arc::new(Program::build(&net, &weights, DeconvImpl::Sd).unwrap());
    let inputs: Vec<Tensor> = (0..4).map(|i| input_for(&net, 1, 300 + i)).collect();
    let want: Vec<Tensor> = inputs
        .iter()
        .map(|z| run_network_with(&net, DeconvImpl::Sd, &weights, z).unwrap())
        .collect();
    std::thread::scope(|s| {
        for worker in 0..2 {
            let program = &program;
            let inputs = &inputs;
            let want = &want;
            s.spawn(move || {
                let mut scratch = Scratch::new();
                for round in 0..3 {
                    for (z, w) in inputs.iter().zip(want) {
                        let got = program.forward(z, &mut scratch).unwrap();
                        assert_eq!(
                            got.max_abs_diff(w),
                            0.0,
                            "worker {worker} round {round}: concurrent execution \
                             not bit-identical to the oracle"
                        );
                    }
                }
            });
        }
    });
}

#[test]
fn coordinator_routes_models_by_name() {
    // end-to-end: a non-DCGAN model served through the dynamic batcher,
    // with two workers sharing the compiled program
    let cfg = ServerConfig {
        max_batch: 2,
        batch_timeout: std::time::Duration::from_millis(1),
        queue_cap: 16,
        model: "sngan".to_string(),
        workers: 2,
        ..ServerConfig::default()
    };
    let net = networks::sngan();
    let server = Server::start_native(cfg, 3).unwrap();
    let mut rng = Rng::new(5);
    let rxs: Vec<_> = (0..2)
        .map(|_| server.submit_blocking(rng.normal_vec(net.input_elems())).unwrap())
        .collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert_eq!(resp.image.len(), 32 * 32 * 3);
    }
    server.shutdown();

    // unknown model names fail server startup, not request time
    let bad = ServerConfig { model: "alexnet".to_string(), ..ServerConfig::default() };
    assert!(Server::start_native(bad, 3).is_err());
}
