//! Simulator invariants, property-tested over random layer geometries:
//! * cycle conservation: issued + skipped is policy-independent;
//! * monotonicity: stronger skip policies never add cycles; more work never
//!   removes cycles;
//! * the paper's headline orderings hold across the whole benchmark suite.

use split_deconv::networks;
use split_deconv::nn::LayerSpec;
use split_deconv::sim::energy::{energy, EnergyModel};
use split_deconv::sim::workload::{lower_layer, lower_network_deconvs, Lowering};
use split_deconv::sim::{dot_array, fcn_engine, pe2d, ProcessorConfig, SkipPolicy};
use split_deconv::util::rng::Rng;

fn random_deconv(rng: &mut Rng) -> LayerSpec {
    let s = 1 + rng.below(3);
    let k = (s + rng.below(4)).min(6).max(2);
    let p = rng.below(k.min(2));
    let i = 3 + rng.below(8);
    let ic = 8 << rng.below(3);
    let oc = 8 << rng.below(3);
    LayerSpec::deconv("rand", i, i, ic, oc, k, s, p, 0)
}

#[test]
fn cycle_conservation_pe2d() {
    let mut rng = Rng::new(1);
    let cfg = ProcessorConfig::default();
    for _ in 0..20 {
        let spec = random_deconv(&mut rng);
        for how in [Lowering::Nzp, Lowering::Sd] {
            let ops = lower_layer(&spec, how, &mut rng).unwrap();
            let totals: Vec<u64> = [
                SkipPolicy::None,
                SkipPolicy::ASparse,
                SkipPolicy::WSparse,
                SkipPolicy::AWSparse,
            ]
            .iter()
            .map(|p| {
                let st = pe2d::simulate(&ops, &cfg, *p);
                st.cycles + st.cycles_skipped
            })
            .collect();
            assert!(
                totals.windows(2).all(|w| w[0] == w[1]),
                "conservation violated: {totals:?} for {spec:?}"
            );
        }
    }
}

#[test]
fn stronger_policy_never_slower() {
    let mut rng = Rng::new(2);
    let cfg = ProcessorConfig::default();
    for _ in 0..20 {
        let spec = random_deconv(&mut rng);
        let ops = lower_layer(&spec, Lowering::Sd, &mut rng).unwrap();
        let none = pe2d::simulate(&ops, &cfg, SkipPolicy::None).cycles;
        let a = pe2d::simulate(&ops, &cfg, SkipPolicy::ASparse).cycles;
        let w = pe2d::simulate(&ops, &cfg, SkipPolicy::WSparse).cycles;
        let aw = pe2d::simulate(&ops, &cfg, SkipPolicy::AWSparse).cycles;
        assert!(a <= none && w <= none && aw <= a && aw <= w, "{spec:?}");
    }
}

#[test]
fn more_channels_more_cycles() {
    let mut rng = Rng::new(3);
    let cfg = ProcessorConfig::default();
    let small = LayerSpec::deconv("s", 8, 8, 32, 32, 4, 2, 1, 0);
    let big = LayerSpec::deconv("b", 8, 8, 64, 64, 4, 2, 1, 0);
    for how in [Lowering::Nzp, Lowering::Sd] {
        let small_ops = lower_layer(&small, how, &mut rng).unwrap();
        let big_ops = lower_layer(&big, how, &mut rng).unwrap();
        let cs = dot_array::simulate(&small_ops, &cfg, SkipPolicy::None);
        let cb = dot_array::simulate(&big_ops, &cfg, SkipPolicy::None);
        assert!(cb.cycles > cs.cycles);
    }
}

#[test]
fn paper_speedup_band_dot_array() {
    // Figure 8: SD ~2.5x over NZP on average (dense); band 1.5-6x per net
    let cfg = ProcessorConfig::default();
    let mut speedups = Vec::new();
    for net in networks::all() {
        let nzp = dot_array::simulate(
            &lower_network_deconvs(&net, Lowering::Nzp, 42).unwrap(),
            &cfg,
            SkipPolicy::None,
        );
        let sd = dot_array::simulate(
            &lower_network_deconvs(&net, Lowering::Sd, 42).unwrap(),
            &cfg,
            SkipPolicy::None,
        );
        let s = nzp.cycles as f64 / sd.cycles as f64;
        assert!(s > 1.2 && s < 6.5, "{}: {s}", net.name);
        speedups.push(s);
    }
    let avg = split_deconv::util::geomean(&speedups);
    assert!(avg > 1.8 && avg < 4.5, "avg {avg}");
}

#[test]
fn paper_speedup_band_pe2d() {
    // Figure 9: SD-WAsparse 2.41x-4.34x over NZP
    let cfg = ProcessorConfig::default();
    let mut speedups = Vec::new();
    for net in networks::all() {
        let nzp = pe2d::simulate(
            &lower_network_deconvs(&net, Lowering::Nzp, 42).unwrap(),
            &cfg,
            SkipPolicy::None,
        );
        let sd = pe2d::simulate(
            &lower_network_deconvs(&net, Lowering::Sd, 42).unwrap(),
            &cfg,
            SkipPolicy::AWSparse,
        );
        speedups.push(nzp.cycles as f64 / sd.cycles as f64);
    }
    let avg = split_deconv::util::geomean(&speedups);
    assert!(avg > 2.0 && avg < 5.0, "avg {avg} ({speedups:?})");
}

#[test]
fn sd_wasparse_on_par_with_fcn() {
    // Figure 9: "the performance of SD-WAsparse is on par with that of FCN"
    let cfg = ProcessorConfig::default();
    for net in networks::all() {
        let sd = pe2d::simulate(
            &lower_network_deconvs(&net, Lowering::Sd, 42).unwrap(),
            &cfg,
            SkipPolicy::AWSparse,
        );
        let fcn = fcn_engine::simulate_network(&net, &cfg);
        let ratio = sd.cycles as f64 / fcn.cycles as f64;
        assert!(
            ratio > 0.4 && ratio < 2.5,
            "{}: SD/FCN cycle ratio {ratio}",
            net.name
        );
    }
}

#[test]
fn energy_reduction_band() {
    // Section 5.2.3 / conclusion: SD cuts energy 27.7%-54.5% vs NZP
    let cfg = ProcessorConfig::default();
    let m = EnergyModel::default();
    let mut reductions = Vec::new();
    for net in networks::all() {
        let nzp = pe2d::simulate(
            &lower_network_deconvs(&net, Lowering::Nzp, 42).unwrap(),
            &cfg,
            SkipPolicy::None,
        );
        let sd = pe2d::simulate(
            &lower_network_deconvs(&net, Lowering::Sd, 42).unwrap(),
            &cfg,
            SkipPolicy::AWSparse,
        );
        let r = 1.0 - energy(&sd, &m).total_uj() / energy(&nzp, &m).total_uj();
        reductions.push(r);
    }
    let avg = reductions.iter().sum::<f64>() / reductions.len() as f64;
    assert!(avg > 0.15 && avg < 0.65, "avg reduction {avg} ({reductions:?})");
}

#[test]
fn fcn_energy_exceeds_sd_wasparse() {
    // Section 5.2.3: FCN's extra column buffers make it costlier than SD
    let cfg = ProcessorConfig::default();
    let m = EnergyModel::default();
    let mut fcn_higher = 0;
    let nets = networks::all();
    for net in &nets {
        let sd = pe2d::simulate(
            &lower_network_deconvs(net, Lowering::Sd, 42).unwrap(),
            &cfg,
            SkipPolicy::AWSparse,
        );
        let fcn = fcn_engine::simulate_network(net, &cfg);
        if energy(&fcn, &m).total_uj() > energy(&sd, &m).total_uj() {
            fcn_higher += 1;
        }
    }
    assert!(
        fcn_higher >= nets.len() - 1,
        "FCN energy should exceed SD-WAsparse on (nearly) all benchmarks: {fcn_higher}/{}",
        nets.len()
    );
}

#[test]
fn dram_independent_of_scheme() {
    // Section 5.2.3: DRAM access volume ~same across approaches
    let cfg = ProcessorConfig::default();
    for net in networks::all() {
        let nzp = pe2d::simulate(
            &lower_network_deconvs(&net, Lowering::Nzp, 42).unwrap(),
            &cfg,
            SkipPolicy::None,
        );
        let sd = pe2d::simulate(
            &lower_network_deconvs(&net, Lowering::Sd, 42).unwrap(),
            &cfg,
            SkipPolicy::AWSparse,
        );
        let ratio = nzp.dram_bytes as f64 / sd.dram_bytes as f64;
        assert!(
            (0.6..1.7).contains(&ratio),
            "{}: DRAM ratio {ratio}",
            net.name
        );
    }
}
