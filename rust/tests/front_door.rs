//! Socket-level tests for the network front door
//! (`split_deconv::server::FrontDoor`): every test talks to a REAL TCP
//! listener on an ephemeral port — nothing is mocked below the HTTP
//! client.
//!
//! Contracts proved here:
//! * responses over the socket are bit-exact with direct `engine::Plan`
//!   execution, and multi-tenant routing sends each request to its own
//!   model's program;
//! * fault injection at the socket boundary: malformed bytes get an
//!   explicit 400, a client hanging up mid-request (or mid-response)
//!   leaves the server healthy for the next connection;
//! * admission control: a full lane answers an explicit 503 shed —
//!   counted in `Metrics.shed`, never a hang or a silent drop — and an
//!   expired deadline answers 504 WITHOUT the request ever reaching the
//!   executor (`Metrics.expired`);
//! * graceful shutdown over the socket: a mid-flight request accepted
//!   before `shutdown()` still gets its full 200 response before the
//!   listener goes away (close-then-drain end to end).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use split_deconv::coordinator::{BatchExecutor, ModelLane, Server, ServerConfig};
use split_deconv::engine::{DeconvImpl, Plan, Program};
use split_deconv::server::client::{request_once, Client};
use split_deconv::server::http::{bytes_to_f32s, f32s_to_bytes};
use split_deconv::server::{FrontDoor, FrontDoorConfig, Route};
use split_deconv::util::rng::Rng;

mod common;
use common::tiny_net;

const TIMEOUT: Duration = Duration::from_secs(20);

fn scfg() -> ServerConfig {
    ServerConfig {
        max_batch: 4,
        batch_timeout: Duration::from_millis(2),
        queue_cap: 64,
        model: "tiny".to_string(),
        workers: 2,
        precision: split_deconv::engine::Precision::F32,
        record_spans: true,
        journal: None,
        watchdog: None,
        chaos: None,
        breaker: None,
    }
}

fn fcfg() -> FrontDoorConfig {
    FrontDoorConfig::default()
}

/// Two-lane multi-tenant door over the shared tiny net at two different
/// weight seeds: same shapes, different programs — so routing mistakes
/// change the bits of the response.
fn tiny_door(
    scfg: ServerConfig,
    fcfg: FrontDoorConfig,
) -> (FrontDoor, Arc<Program>, Arc<Program>) {
    let net = tiny_net();
    let p1 = Arc::new(Program::from_seed(&net, DeconvImpl::Sd, 4).unwrap());
    let p2 = Arc::new(Program::from_seed(&net, DeconvImpl::Sd, 9).unwrap());
    let routes = vec![
        Route {
            name: "tiny".to_string(),
            z_len: p1.input_len(),
            image_len: p1.output_len(),
        },
        Route {
            name: "tiny2".to_string(),
            z_len: p2.input_len(),
            image_len: p2.output_len(),
        },
    ];
    let server = Server::start_multi_with(
        scfg,
        vec![
            ModelLane::native("tiny", p1.clone()),
            ModelLane::native("tiny2", p2.clone()),
        ],
    )
    .unwrap();
    let door = FrontDoor::start(fcfg, server, routes).unwrap();
    (door, p1, p2)
}

#[test]
fn socket_responses_are_bit_exact_with_direct_plan_execution() {
    let (door, p1, p2) = tiny_door(scfg(), fcfg());
    let mut rng = Rng::new(3);
    let mut client = Client::connect(door.addr(), TIMEOUT).unwrap();
    for i in 0..4 {
        let z = rng.normal_vec(16);
        let body = f32s_to_bytes(&z);
        let r1 = client.request("POST", "/v1/generate/tiny", &[], &body).unwrap();
        assert_eq!(r1.status, 200, "tiny request {i}: {}", r1.text());
        assert_eq!(r1.header("x-model"), Some("tiny"));
        assert!(r1.header("x-request-id").is_some());
        let got1 = bytes_to_f32s(&r1.body).unwrap();
        let want1 = Plan::from_program(p1.clone()).execute_batch(&[z.clone()]).unwrap();
        assert_eq!(got1, want1[0], "request {i}: socket response != direct Plan execution");

        // same latent through the OTHER lane: different program, so a
        // routing mistake would be caught bit-for-bit
        let r2 = client.request("POST", "/v1/generate/tiny2", &[], &body).unwrap();
        assert_eq!(r2.status, 200, "tiny2 request {i}: {}", r2.text());
        assert_eq!(r2.header("x-model"), Some("tiny2"));
        let got2 = bytes_to_f32s(&r2.body).unwrap();
        let want2 = Plan::from_program(p2.clone()).execute_batch(&[z.clone()]).unwrap();
        assert_eq!(got2, want2[0], "request {i}: tiny2 response != its own Plan");
        assert_ne!(got1, got2, "the two lanes must serve different programs");
    }
    door.shutdown();
}

#[test]
fn seed_query_draws_the_documented_latent_server_side() {
    let (door, p1, _p2) = tiny_door(scfg(), fcfg());
    let r = request_once(door.addr(), TIMEOUT, "POST", "/v1/generate/tiny?seed=7", &[], &[])
        .unwrap();
    assert_eq!(r.status, 200, "{}", r.text());
    let got = bytes_to_f32s(&r.body).unwrap();
    let z = Rng::new(7).normal_vec(16);
    let want = Plan::from_program(p1).execute_batch(&[z]).unwrap();
    assert_eq!(got, want[0], "?seed=N must draw Rng::new(N).normal_vec(z_len)");
    door.shutdown();
}

#[test]
fn discovery_endpoints_answer() {
    let (door, _p1, _p2) = tiny_door(scfg(), fcfg());
    let mut client = Client::connect(door.addr(), TIMEOUT).unwrap();
    let health = client.get("/healthz").unwrap();
    assert_eq!(health.status, 200);
    assert!(health.text().contains("\"status\":\"ok\""), "{}", health.text());
    let models = client.get("/v1/models").unwrap();
    assert_eq!(models.status, 200);
    let text = models.text();
    assert!(text.contains("\"tiny\"") && text.contains("\"tiny2\""), "{text}");
    assert!(text.contains("\"z_len\":16"), "{text}");
    let metrics = client.get("/metrics").unwrap();
    assert_eq!(metrics.status, 200);
    let text = metrics.text();
    assert!(text.contains("\"shed\":") && text.contains("\"expired\":"), "{text}");
    door.shutdown();
}

#[test]
fn malformed_bytes_get_400_and_the_server_keeps_serving() {
    let (door, _p1, _p2) = tiny_door(scfg(), fcfg());

    // raw garbage: explicit 400, then the connection closes
    let mut raw = TcpStream::connect(door.addr()).unwrap();
    raw.set_read_timeout(Some(TIMEOUT)).unwrap();
    raw.write_all(b"squeamish ossifrage\r\n\r\n").unwrap();
    let mut buf = Vec::new();
    raw.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8_lossy(&buf);
    assert!(text.starts_with("HTTP/1.1 400"), "garbage bytes must answer 400, got {text:?}");

    // protocol-level mistakes: each gets its own explicit status
    let addr = door.addr();
    let wrong_len = request_once(addr, TIMEOUT, "POST", "/v1/generate/tiny", &[], &[1, 2, 3])
        .unwrap();
    assert_eq!(wrong_len.status, 400, "ragged latent: {}", wrong_len.text());
    assert!(wrong_len.text().contains("bad_latent"));

    let no_latent = request_once(addr, TIMEOUT, "POST", "/v1/generate/tiny", &[], &[]).unwrap();
    assert_eq!(no_latent.status, 400);
    assert!(no_latent.text().contains("missing_latent"));

    let wrong_method = request_once(addr, TIMEOUT, "GET", "/v1/generate/tiny", &[], &[]).unwrap();
    assert_eq!(wrong_method.status, 405);

    let unknown = request_once(addr, TIMEOUT, "POST", "/v1/generate/nope?seed=1", &[], &[])
        .unwrap();
    assert_eq!(unknown.status, 404);
    assert!(unknown.text().contains("unknown_model"));

    let lost = request_once(addr, TIMEOUT, "GET", "/lost", &[], &[]).unwrap();
    assert_eq!(lost.status, 404);

    // ...and after all that abuse, real work still succeeds
    let ok = request_once(addr, TIMEOUT, "POST", "/v1/generate/tiny?seed=1", &[], &[]).unwrap();
    assert_eq!(ok.status, 200);
    door.shutdown();
}

#[test]
fn content_length_abuse_is_rejected_without_hanging_the_server() {
    let (door, _p1, _p2) = tiny_door(scfg(), fcfg());
    let addr = door.addr();

    // one raw request -> the status line of the response
    let raw_status = |req: &[u8]| -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(TIMEOUT)).unwrap();
        s.write_all(req).unwrap();
        let mut buf = Vec::new();
        s.read_to_end(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf).into_owned();
        text.lines().next().unwrap_or_default().to_string()
    };

    // a Content-Length that overflows usize must be a clean 400 — not a
    // panic in parse, not an attempted allocation
    let overflow = raw_status(
        b"POST /v1/generate/tiny HTTP/1.1\r\nContent-Length: 99999999999999999999999999\r\n\r\n",
    );
    assert!(overflow.starts_with("HTTP/1.1 400"), "overflowing length: {overflow:?}");

    // duplicate Content-Length headers that disagree are a request
    // smuggling vector: reject, never silently pick one
    let dup = raw_status(
        b"POST /v1/generate/tiny HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 5\r\n\r\nabcde",
    );
    assert!(dup.starts_with("HTTP/1.1 400"), "conflicting lengths: {dup:?}");

    // signed/garnished numbers are rejected (a bare parse::<usize> would
    // admit "+3")
    let signed = raw_status(b"POST /v1/generate/tiny HTTP/1.1\r\nContent-Length: +3\r\n\r\nabc");
    assert!(signed.starts_with("HTTP/1.1 400"), "signed length: {signed:?}");

    // a bodied method with no Content-Length at all answers 411 — not a
    // hang waiting for bytes that never come
    let none = raw_status(b"POST /v1/generate/tiny HTTP/1.1\r\n\r\n");
    assert!(none.starts_with("HTTP/1.1 411"), "missing length: {none:?}");

    // ...and none of that abuse took the listener down
    let ok = request_once(addr, TIMEOUT, "POST", "/v1/generate/tiny?seed=4", &[], &[]).unwrap();
    assert_eq!(ok.status, 200, "{}", ok.text());
    door.shutdown();
}

#[test]
fn oversized_body_answers_413_and_the_server_keeps_serving() {
    let fcfg = FrontDoorConfig {
        max_body_bytes: 64,
        ..FrontDoorConfig::default()
    };
    let (door, _p1, _p2) = tiny_door(scfg(), fcfg);
    let addr = door.addr();

    // declared length over the cap: typed 413 BEFORE any body byte is
    // buffered, then the connection closes
    let mut s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(TIMEOUT)).unwrap();
    s.write_all(b"POST /v1/generate/tiny HTTP/1.1\r\nContent-Length: 4096\r\n\r\n")
        .unwrap();
    let mut buf = Vec::new();
    s.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8_lossy(&buf);
    assert!(text.starts_with("HTTP/1.1 413"), "oversized body: {text:?}");
    assert!(text.contains("body_too_large"), "typed error body expected: {text}");

    // a request at exactly the cap (16 f32s = 64 bytes) still serves
    let z = Rng::new(6).normal_vec(16);
    let ok = request_once(addr, TIMEOUT, "POST", "/v1/generate/tiny", &[], &f32s_to_bytes(&z))
        .unwrap();
    assert_eq!(ok.status, 200, "{}", ok.text());
    door.shutdown();
}

#[test]
fn client_disconnect_mid_request_leaves_the_server_healthy() {
    let (door, _p1, _p2) = tiny_door(scfg(), fcfg());

    // promise a 64-byte body, send 10, hang up
    {
        let mut raw = TcpStream::connect(door.addr()).unwrap();
        raw.write_all(b"POST /v1/generate/tiny HTTP/1.1\r\nContent-Length: 64\r\n\r\n")
            .unwrap();
        raw.write_all(&[0u8; 10]).unwrap();
    } // dropped: TCP FIN mid-body

    // hang up while a response may be in flight
    {
        let mut raw = TcpStream::connect(door.addr()).unwrap();
        raw.write_all(b"POST /v1/generate/tiny?seed=2 HTTP/1.1\r\nContent-Length: 0\r\n\r\n")
            .unwrap();
    } // dropped without reading the response

    // the pool and the acceptor must both still be fine
    for _ in 0..3 {
        let ok = request_once(door.addr(), TIMEOUT, "POST", "/v1/generate/tiny?seed=3", &[], &[])
            .unwrap();
        assert_eq!(ok.status, 200, "{}", ok.text());
    }
    assert_eq!(door.metrics().errors, 0, "disconnects must not count as batch errors");
    door.shutdown();
}

/// A deliberately slow executor so tests can hold the worker busy and
/// control queue occupancy; counts executed requests so deadline tests
/// can prove a dropped request NEVER reached compute.
struct SlowExec {
    delay: Duration,
    batches: Vec<usize>,
    executed: Arc<AtomicUsize>,
}

impl BatchExecutor for SlowExec {
    fn supported_batches(&self) -> &[usize] {
        &self.batches
    }
    fn z_len(&self) -> usize {
        4
    }
    fn image_len(&self) -> usize {
        4
    }
    fn execute(&mut self, batch: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        std::thread::sleep(self.delay);
        self.executed.fetch_add(batch.len(), Ordering::SeqCst);
        Ok(batch.iter().map(|z| z.iter().map(|v| v + 1.0).collect()).collect())
    }
}

/// One-lane door over [`SlowExec`]; returns the shared executed-request
/// counter alongside the door.
fn slow_door(scfg: ServerConfig, delay: Duration) -> (FrontDoor, Arc<AtomicUsize>) {
    let executed = Arc::new(AtomicUsize::new(0));
    let executed2 = executed.clone();
    let lane = ModelLane {
        name: "slow".to_string(),
        factory: Box::new(move |_worker| {
            Ok(Box::new(SlowExec {
                delay,
                batches: vec![1, 2, 4, 8],
                executed: executed2.clone(),
            }) as Box<dyn BatchExecutor>)
        }),
    };
    let routes = vec![Route {
        name: "slow".to_string(),
        z_len: 4,
        image_len: 4,
    }];
    let server = Server::start_multi_with(scfg, vec![lane]).unwrap();
    let door = FrontDoor::start(fcfg(), server, routes).unwrap();
    (door, executed)
}

#[test]
fn queue_full_sheds_explicitly_and_every_request_is_answered() {
    let cfg = ServerConfig {
        max_batch: 1,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 1,
        model: "slow".to_string(),
        workers: 1,
        precision: split_deconv::engine::Precision::F32,
        record_spans: true,
        journal: None,
        watchdog: None,
        chaos: None,
        breaker: None,
    };
    let (door, _executed) = slow_door(cfg, Duration::from_millis(100));
    let addr = door.addr();

    // 12 concurrent one-shot clients against capacity ~1 in flight + 1
    // queued: most must shed, ALL must be answered
    let clients: Vec<_> = (0..12)
        .map(|i| {
            std::thread::spawn(move || {
                let z = vec![i as f32; 4];
                request_once(addr, TIMEOUT, "POST", "/v1/generate/slow", &[], &f32s_to_bytes(&z))
                    .expect("every request gets an answer — shed is a response, not a hang")
            })
        })
        .collect();
    let mut ok = 0u64;
    let mut shed = 0u64;
    for c in clients {
        let resp = c.join().unwrap();
        match resp.status {
            200 => ok += 1,
            503 => {
                assert!(resp.text().contains("shed"), "{}", resp.text());
                // jittered Retry-After: always present, always 1..=4 s,
                // so a synchronized client herd spreads its retries
                let ra: u64 = resp
                    .header("retry-after")
                    .expect("503 shed must carry Retry-After")
                    .parse()
                    .expect("Retry-After must be whole seconds");
                assert!((1..=4).contains(&ra), "Retry-After {ra} outside the 1..=4 jitter band");
                shed += 1;
            }
            other => panic!("unexpected status {other}: {}", resp.text()),
        }
    }
    assert_eq!(ok + shed, 12, "no request may vanish");
    assert!(ok >= 1, "at least the first request must be served");
    assert!(shed >= 1, "overload must shed");
    let m = door.metrics();
    assert_eq!(m.shed, shed, "every 503 shed must be counted exactly once");
    assert_eq!(m.served, ok, "every 200 is a served request");
    door.shutdown();
}

#[test]
fn expired_deadline_answers_504_without_reaching_compute() {
    let cfg = ServerConfig {
        max_batch: 1,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 8,
        model: "slow".to_string(),
        workers: 1,
        precision: split_deconv::engine::Precision::F32,
        record_spans: true,
        journal: None,
        watchdog: None,
        chaos: None,
        breaker: None,
    };
    let (door, executed) = slow_door(cfg, Duration::from_millis(120));
    let addr = door.addr();

    // request A occupies the single worker for ~120ms
    let a = std::thread::spawn(move || {
        request_once(addr, TIMEOUT, "POST", "/v1/generate/slow?seed=1", &[], &[]).unwrap()
    });
    std::thread::sleep(Duration::from_millis(30));

    // request B queues behind A with a 1ms deadline: by the time the
    // worker reaches it the deadline has long passed — it must be
    // dropped BEFORE compute and answered 504
    let hdr = [("X-Deadline-Ms", "1".to_string())];
    let b = request_once(addr, TIMEOUT, "POST", "/v1/generate/slow?seed=2", &hdr, &[]).unwrap();
    assert_eq!(b.status, 504, "{}", b.text());
    assert!(b.text().contains("deadline_expired"), "{}", b.text());

    let a = a.join().unwrap();
    assert_eq!(a.status, 200, "the occupying request still completes: {}", a.text());

    let m = door.metrics();
    assert_eq!(m.expired, 1, "the dropped deadline must be counted");
    assert_eq!(
        executed.load(Ordering::SeqCst),
        1,
        "only request A may reach the executor — B was dropped pre-compute"
    );
    door.shutdown();
}

#[test]
fn graceful_shutdown_flushes_inflight_responses_before_the_listener_dies() {
    let cfg = ServerConfig {
        max_batch: 1,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 8,
        model: "slow".to_string(),
        workers: 1,
        precision: split_deconv::engine::Precision::F32,
        record_spans: true,
        journal: None,
        watchdog: None,
        chaos: None,
        breaker: None,
    };
    let (door, _executed) = slow_door(cfg, Duration::from_millis(150));
    let addr = door.addr();

    // a request that will still be computing when shutdown starts
    let inflight = std::thread::spawn(move || {
        let z = vec![2.5f32; 4];
        request_once(addr, TIMEOUT, "POST", "/v1/generate/slow", &[], &f32s_to_bytes(&z)).unwrap()
    });
    // give the front door time to ACCEPT the request (it is then either
    // queued or mid-compute — both must survive shutdown)
    std::thread::sleep(Duration::from_millis(60));

    let t0 = Instant::now();
    door.shutdown();
    let drained_in = t0.elapsed();

    // close-then-drain over the socket: the accepted request got its full
    // response even though shutdown was called mid-flight
    let resp = inflight.join().unwrap();
    assert_eq!(resp.status, 200, "mid-flight request must be flushed: {}", resp.text());
    assert_eq!(
        bytes_to_f32s(&resp.body).unwrap(),
        vec![3.5f32; 4],
        "flushed response must be the request's own image"
    );
    assert_eq!(door.metrics().served, 1);
    assert!(drained_in < TIMEOUT, "shutdown must not hang");

    // ...and the listener is really gone afterwards
    let gone = match Client::connect(addr, Duration::from_millis(500)) {
        Err(_) => true, // refused: the usual outcome
        Ok(mut c) => c.get("/healthz").is_err(),
    };
    assert!(gone, "the listener must be closed after shutdown");
    // idempotent
    door.shutdown();
}

/// First sample value for an exactly-named Prometheus series (no labels).
fn prom_sample(text: &str, name: &str) -> Option<f64> {
    for l in text.lines() {
        if let Some(rest) = l.strip_prefix(name) {
            if let Some(v) = rest.strip_prefix(' ') {
                return v.trim().parse().ok();
            }
        }
    }
    None
}

#[test]
fn prometheus_exposition_parses_and_matches_the_json_snapshot() {
    let (door, _p1, _p2) = tiny_door(scfg(), fcfg());
    let addr = door.addr();
    for seed in 1..=3 {
        let path = format!("/v1/generate/tiny?seed={seed}");
        let r = request_once(addr, TIMEOUT, "POST", &path, &[], &[]).unwrap();
        assert_eq!(r.status, 200);
    }

    let mut client = Client::connect(addr, TIMEOUT).unwrap();
    let prom = client.request("GET", "/metrics?format=prom", &[], &[]).unwrap();
    assert_eq!(prom.status, 200);
    let ct = prom.header("content-type").unwrap_or("");
    assert!(ct.starts_with("text/plain"), "prom exposition content type: {ct}");
    let text = prom.text();

    // every counter/gauge family must be present
    for name in [
        "repro_served_total",
        "repro_batches_total",
        "repro_errors_total",
        "repro_shed_total",
        "repro_expired_total",
        "repro_max_queue_depth",
    ] {
        assert!(
            text.contains(&format!("# TYPE {name} ")),
            "missing TYPE line for {name}:\n{text}"
        );
        assert!(prom_sample(&text, name).is_some(), "missing sample for {name}");
    }
    assert!(text.contains("repro_lane_served_total{model=\"tiny\"}"), "{text}");
    assert!(text.contains("repro_lane_served_total{model=\"tiny2\"}"), "{text}");
    assert!(text.contains("repro_worker_batches_total{worker=\"0\"}"), "{text}");
    assert!(text.contains("repro_worker_served_total{worker=\"0\"}"), "{text}");
    assert_eq!(prom_sample(&text, "repro_served_total"), Some(3.0));

    // the latency histogram: cumulative buckets must be monotone
    // nondecreasing and end at the +Inf count == _count == served
    let mut buckets: Vec<(String, f64)> = Vec::new();
    for l in text.lines() {
        if let Some(rest) = l.strip_prefix("repro_request_latency_seconds_bucket{le=\"") {
            let le = rest.split('"').next().unwrap().to_string();
            let v: f64 = l.rsplit(' ').next().unwrap().parse().unwrap();
            buckets.push((le, v));
        }
    }
    assert!(buckets.len() > 10, "expected the full bucket table, got {}", buckets.len());
    for w in buckets.windows(2) {
        assert!(w[1].1 >= w[0].1, "cumulative buckets must be nondecreasing: {w:?}");
    }
    assert_eq!(buckets.last().unwrap().0, "+Inf");
    let count = prom_sample(&text, "repro_request_latency_seconds_count").unwrap();
    assert_eq!(buckets.last().unwrap().1, count, "+Inf bucket must equal _count");
    assert_eq!(count, 3.0, "three served requests -> three latency observations");
    let sum = prom_sample(&text, "repro_request_latency_seconds_sum").unwrap();
    assert!(sum > 0.0, "latency sum must be positive");
    // the other two decomposition histograms ride along
    assert!(prom_sample(&text, "repro_queue_wait_seconds_count").is_some());
    assert!(prom_sample(&text, "repro_compute_seconds_count").is_some());

    // consistency with the JSON snapshot of the SAME metrics
    let json = client.get("/metrics").unwrap();
    assert_eq!(json.status, 200);
    let parsed = split_deconv::util::json::parse(&json.text()).unwrap();
    assert_eq!(parsed.get("served").and_then(|v| v.as_f64()), Some(count));

    // content negotiation: Accept: text/plain also selects the prom form
    let via_accept = client
        .request("GET", "/metrics", &[("Accept", "text/plain".to_string())], &[])
        .unwrap();
    assert!(via_accept.text().contains("# TYPE repro_served_total counter"));
    door.shutdown();
}

#[test]
fn traced_response_is_bit_identical_and_carries_the_trailer() {
    let (door, p1, _p2) = tiny_door(scfg(), fcfg());
    let addr = door.addr();
    let z = Rng::new(5).normal_vec(16);
    let body = f32s_to_bytes(&z);

    let plain = request_once(addr, TIMEOUT, "POST", "/v1/generate/tiny", &[], &body).unwrap();
    assert_eq!(plain.status, 200);
    let image_bytes = plain.body.clone();
    assert_eq!(image_bytes.len(), p1.output_len() * 4);
    assert!(plain.header("x-trace-result").is_none(), "untraced responses carry no trailer");

    let hdr = [
        ("X-Trace", "1".to_string()),
        ("X-Request-Id", "424242".to_string()),
    ];
    let traced = request_once(addr, TIMEOUT, "POST", "/v1/generate/tiny", &hdr, &body).unwrap();
    assert_eq!(traced.status, 200, "{}", traced.text());
    assert_eq!(traced.header("x-trace-id"), Some("424242"), "X-Request-Id becomes the trace id");

    // X-Trace-Result points at the trailer; everything before it must be
    // BIT-IDENTICAL to the untraced response (tracing never changes the
    // output bytes)
    let offset: usize = traced
        .header("x-trace-result")
        .expect("traced response must carry X-Trace-Result")
        .parse()
        .unwrap();
    assert_eq!(offset, image_bytes.len());
    assert_eq!(&traced.body[..offset], &image_bytes[..], "traced image bytes must be identical");

    let trailer = std::str::from_utf8(&traced.body[offset..]).unwrap();
    let t = split_deconv::util::json::parse(trailer).unwrap();
    assert_eq!(t.get("trace_id").and_then(|v| v.as_f64()), Some(424242.0));
    let span = t.get("span").expect("trailer carries the span");
    assert_eq!(span.get("trace_id").and_then(|v| v.as_f64()), Some(424242.0));
    for k in ["queue_us", "batch_form_us", "compute_us", "respond_us"] {
        assert!(span.get(k).and_then(|v| v.as_f64()).is_some(), "span field {k} missing");
    }
    let stages = t.get("stages").and_then(|v| v.as_arr()).expect("native backend fills stages");
    assert!(!stages.is_empty(), "per-layer stage rows must be present");
    for row in stages {
        assert!(row.get("layer").and_then(|v| v.as_str()).is_some());
        for k in ["im2col_us", "gemm_us", "epilogue_us", "interleave_us", "total_us"] {
            assert!(row.get(k).and_then(|v| v.as_f64()).is_some(), "stage field {k} missing");
        }
    }
    door.shutdown();
}

#[test]
fn healthz_reports_per_model_readiness_over_the_socket() {
    let (door, _p1, _p2) = tiny_door(scfg(), fcfg());
    let addr = door.addr();
    let r = request_once(addr, TIMEOUT, "POST", "/v1/generate/tiny?seed=9", &[], &[]).unwrap();
    assert_eq!(r.status, 200);

    let health = request_once(addr, TIMEOUT, "GET", "/healthz", &[], &[]).unwrap();
    assert_eq!(health.status, 200);
    let h = split_deconv::util::json::parse(&health.text()).unwrap();
    assert_eq!(h.get("status").and_then(|v| v.as_str()), Some("ok"));
    assert_eq!(h.get("draining").and_then(|v| v.as_bool()), Some(false));
    assert_eq!(h.get("precision").and_then(|v| v.as_str()), Some("f32"));
    assert_eq!(h.get("served").and_then(|v| v.as_f64()), Some(1.0));
    assert!(h.get("in_flight").and_then(|v| v.as_f64()).is_some());
    assert!(h.get("watchdog_stalls").and_then(|v| v.as_f64()).is_some());
    let models = h.get("models").and_then(|v| v.as_arr()).expect("models array");
    assert_eq!(models.len(), 2, "one entry per route");
    for (m, name) in models.iter().zip(["tiny", "tiny2"]) {
        assert_eq!(m.get("name").and_then(|v| v.as_str()), Some(name));
        assert_eq!(m.get("ready").and_then(|v| v.as_bool()), Some(true));
        assert_eq!(m.get("cap").and_then(|v| v.as_f64()), Some(64.0));
        assert_eq!(m.get("depth").and_then(|v| v.as_f64()), Some(0.0), "idle lanes are empty");
        for k in ["served", "shed", "expired"] {
            assert!(m.get(k).and_then(|v| v.as_f64()).is_some(), "per-model field {k}");
        }
    }
    let tiny_served = models[0].get("served").and_then(|v| v.as_f64());
    assert_eq!(tiny_served, Some(1.0), "the served request lands on its own lane");
    door.shutdown();
}

#[test]
fn debug_trace_exports_a_valid_chrome_timeline_over_the_socket() {
    let mut cfg = scfg();
    cfg.journal = Some(split_deconv::obs::Journal::with_defaults());
    let (door, _p1, _p2) = tiny_door(cfg, fcfg());
    let addr = door.addr();
    for seed in 1..=4 {
        let path = format!("/v1/generate/tiny?seed={seed}");
        let r = request_once(addr, TIMEOUT, "POST", &path, &[], &[]).unwrap();
        assert_eq!(r.status, 200);
    }
    let trace = request_once(addr, TIMEOUT, "GET", "/debug/trace?ms=60000", &[], &[]).unwrap();
    assert_eq!(trace.status, 200, "{}", trace.text());
    let stats = split_deconv::obs::validate_chrome_trace(&trace.text())
        .expect("/debug/trace must export schema-valid Chrome trace JSON");
    assert!(stats.events > 0, "the journal saw the serving traffic: {stats:?}");
    assert!(stats.tracks >= 2, "dispatcher + lane tracks expected: {stats:?}");
    // a window in the past contains nothing but still validates
    let empty = request_once(addr, TIMEOUT, "GET", "/debug/trace?ms=0", &[], &[]).unwrap();
    assert_eq!(empty.status, 200);
    split_deconv::obs::validate_chrome_trace(&empty.text()).unwrap();
    door.shutdown();
}

#[test]
fn debug_trace_is_404_without_a_journal() {
    let (door, _p1, _p2) = tiny_door(scfg(), fcfg());
    let r = request_once(door.addr(), TIMEOUT, "GET", "/debug/trace", &[], &[]).unwrap();
    assert_eq!(r.status, 404, "{}", r.text());
    assert!(r.text().contains("no_journal"), "{}", r.text());
    door.shutdown();
}

#[test]
fn prometheus_exposition_carries_the_live_gauges_and_lane_labels() {
    let mut cfg = scfg();
    cfg.journal = Some(split_deconv::obs::Journal::with_defaults());
    let (door, _p1, _p2) = tiny_door(cfg, fcfg());
    let addr = door.addr();
    let r = request_once(addr, TIMEOUT, "POST", "/v1/generate/tiny?seed=2", &[], &[]).unwrap();
    assert_eq!(r.status, 200);

    let mut client = Client::connect(addr, TIMEOUT).unwrap();
    let text = client.request("GET", "/metrics?format=prom", &[], &[]).unwrap().text();
    for needle in [
        "repro_shed_total{model=\"tiny\"} 0",
        "repro_shed_total{model=\"tiny2\"} 0",
        "repro_expired_total{model=\"tiny\"} 0",
        "repro_lane_queue_depth{model=\"tiny\"} 0",
        "repro_lane_queue_depth{model=\"tiny2\"} 0",
        "repro_in_flight 0",
        "repro_watchdog_stalls_total 0",
        // journal-backed: only dispatchers that have emitted appear, and
        // either of the two workers may have taken the one batch
        "repro_worker_busy_fraction{worker=\"",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    // the JSON exposition mirrors the same gauges
    let json = client.get("/metrics").unwrap();
    let parsed = split_deconv::util::json::parse(&json.text()).unwrap();
    assert_eq!(parsed.get("in_flight").and_then(|v| v.as_f64()), Some(0.0));
    assert!(parsed.get("lane_depth").and_then(|v| v.get("tiny")).is_some(), "{}", json.text());
    assert!(parsed.get("worker_busy_window").is_some(), "journal-backed rolling window rides along");
    door.shutdown();
}

#[test]
fn keep_alive_connection_serves_many_requests_in_fifo_order() {
    let (door, p1, _p2) = tiny_door(scfg(), fcfg());
    let mut client = Client::connect(door.addr(), TIMEOUT).unwrap();
    let mut rng = Rng::new(11);
    let mut plan = Plan::from_program(p1);
    for i in 0..10 {
        let z = rng.normal_vec(16);
        let r = client
            .request("POST", "/v1/generate/tiny", &[], &f32s_to_bytes(&z))
            .unwrap();
        assert_eq!(r.status, 200, "request {i}");
        // per-client FIFO: response i on this connection answers request i
        // (bit-exactness against request i's own latent proves no
        // reordering or cross-wiring)
        let want = plan.execute_batch(&[z]).unwrap();
        assert_eq!(bytes_to_f32s(&r.body).unwrap(), want[0], "request {i} got another's image");
    }
    door.shutdown();
}
