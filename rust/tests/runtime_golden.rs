//! Runtime integration: load AOT artifacts via PJRT, execute, and
//! cross-check against the python-recorded goldens. These tests require
//! `make artifacts` to have run; they skip (pass with a notice) otherwise so
//! `cargo test` works in a fresh checkout.

use split_deconv::coordinator::{BatchExecutor, PjrtExecutor};
use split_deconv::runtime::{artifacts_available, default_artifact_dir, Engine};
use split_deconv::util::rng::Rng;

fn engine_or_skip() -> Option<Engine> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ missing; run `make artifacts`");
        return None;
    }
    Some(Engine::new(default_artifact_dir()).expect("engine"))
}

#[test]
fn manifest_loads_and_is_complete() {
    let Some(engine) = engine_or_skip() else { return };
    let m = engine.manifest();
    // 4 model artifacts + 22 deconv layers x 2 impls
    assert!(m.artifacts.len() >= 40, "only {} artifacts", m.artifacts.len());
    for a in &m.artifacts {
        assert!(a.hlo.exists(), "{} missing hlo", a.name);
        assert!(a.output.bin.exists(), "{} missing golden", a.name);
        assert!(!a.inputs.is_empty());
    }
    // every network contributed layer artifacts in both impls
    for net in ["DCGAN", "SNGAN", "ArtGAN", "GP-GAN", "MDE", "FST"] {
        for impl_ in ["sd", "nzp"] {
            assert!(
                !m.select(|a| a.kind == "layer" && a.network == net && a.impl_ == impl_)
                    .is_empty(),
                "no {impl_} layer artifacts for {net}"
            );
        }
    }
}

#[test]
fn dcgan_model_artifacts_match_goldens() {
    let Some(mut engine) = engine_or_skip() else { return };
    for name in ["dcgan_sd_b1", "dcgan_nzp_b1", "dcgan_ref_b1"] {
        let err = engine.verify(name).expect(name);
        assert!(err < 1e-3, "{name}: max err {err}");
    }
}

#[test]
fn sd_and_ref_models_agree_on_fresh_input() {
    // beyond goldens: same z through the SD artifact and the direct-deconv
    // artifact must produce the same image (the paper's exactness claim,
    // verified end-to-end through the AOT + PJRT stack).
    let Some(mut engine) = engine_or_skip() else { return };
    let mut rng = Rng::new(123);
    let z = rng.normal_vec(100);
    let sd = engine.load("dcgan_sd_b1").unwrap().run(&z).unwrap();
    let rf = engine.load("dcgan_ref_b1").unwrap().run(&z).unwrap();
    assert_eq!(sd.len(), rf.len());
    let max = sd
        .iter()
        .zip(&rf)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 1e-3, "SD vs ref max diff {max}");
}

#[test]
fn layer_artifacts_sample_verifies() {
    let Some(mut engine) = engine_or_skip() else { return };
    // one small layer per network (full sweep runs in `repro verify`)
    let names: Vec<String> = {
        let m = engine.manifest();
        ["DCGAN", "SNGAN", "ArtGAN", "GP-GAN"]
            .iter()
            .filter_map(|net| {
                m.select(|a| a.kind == "layer" && a.network == *net)
                    .first()
                    .map(|a| a.name.clone())
            })
            .collect()
    };
    assert!(!names.is_empty());
    for name in names {
        let err = engine.verify(&name).expect(&name);
        assert!(err < 1e-3, "{name}: max err {err}");
    }
}

#[test]
fn pjrt_executor_batches_and_pads() {
    let Some(_) = engine_or_skip() else { return };
    let mut exec = PjrtExecutor::new(default_artifact_dir(), "dcgan_sd").expect("executor");
    assert_eq!(exec.supported_batches(), &[1, 4]);
    assert_eq!(exec.z_len(), 100);
    let mut rng = Rng::new(5);
    let zs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(100)).collect();
    let imgs = exec.execute(&zs).expect("batch of 3 via b4 with padding");
    assert_eq!(imgs.len(), 3);
    assert_eq!(imgs[0].len(), 64 * 64 * 3);
    // batch results must equal single-request results (padding is inert)
    let single = exec.execute(&zs[..1]).unwrap();
    let max = imgs[0]
        .iter()
        .zip(&single[0])
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max < 1e-4, "batch vs single diff {max}");
}
