//! Coordinator invariants, tested against a mock executor (no artifacts
//! needed): no request is dropped or duplicated, responses carry the right
//! payload, batch sizes respect the config, backpressure bounds the queue,
//! and failures surface as disconnects rather than hangs.

use std::collections::HashSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};
use split_deconv::coordinator::{BatchExecutor, Server, ServerConfig};

/// Mock backend: "image" = [sum(z), len(z), batch_marker]; records batches.
struct MockExec {
    batches: Arc<AtomicUsize>,
    max_seen: Arc<AtomicUsize>,
    fail_every: usize,
    calls: usize,
    delay: Duration,
}

impl BatchExecutor for MockExec {
    fn supported_batches(&self) -> &[usize] {
        &[1, 4]
    }

    fn z_len(&self) -> usize {
        8
    }

    fn image_len(&self) -> usize {
        3
    }

    fn execute(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.calls += 1;
        if self.fail_every > 0 && self.calls % self.fail_every == 0 {
            bail!("injected failure");
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        self.batches.fetch_add(1, Ordering::SeqCst);
        self.max_seen.fetch_max(batch.len(), Ordering::SeqCst);
        Ok(batch
            .iter()
            .map(|z| vec![z.iter().sum::<f32>(), z.len() as f32, batch.len() as f32])
            .collect())
    }
}

fn server(
    cfg: ServerConfig,
    fail_every: usize,
    delay_ms: u64,
) -> (Server, Arc<AtomicUsize>, Arc<AtomicUsize>) {
    let batches = Arc::new(AtomicUsize::new(0));
    let max_seen = Arc::new(AtomicUsize::new(0));
    let (b2, m2) = (batches.clone(), max_seen.clone());
    // the factory runs once per worker; the counters are shared
    let s = Server::start_with(cfg, move |_worker| {
        Ok(MockExec {
            batches: b2.clone(),
            max_seen: m2.clone(),
            fail_every,
            calls: 0,
            delay: Duration::from_millis(delay_ms),
        })
    })
    .unwrap();
    (s, batches, max_seen)
}

#[test]
fn every_request_gets_its_own_answer() {
    let (s, _, _) = server(ServerConfig::default(), 0, 0);
    let mut rxs = Vec::new();
    for i in 0..40 {
        let z = vec![i as f32; 8];
        rxs.push((i, s.submit_blocking(z).unwrap()));
    }
    let mut ids = HashSet::new();
    for (i, rx) in rxs {
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        // payload identity: sum of z = 8*i
        assert_eq!(r.image[0], (8 * i) as f32);
        assert_eq!(r.image[1], 8.0);
        assert!(ids.insert(r.id), "duplicate response id {}", r.id);
    }
    assert_eq!(ids.len(), 40);
    let m = s.metrics();
    assert_eq!(m.served, 40);
    assert_eq!(m.errors, 0);
    s.shutdown();
}

#[test]
fn batching_happens_under_load() {
    let cfg = ServerConfig {
        max_batch: 4,
        batch_timeout: Duration::from_millis(20),
        queue_cap: 64,
        ..ServerConfig::default()
    };
    let (s, batches, max_seen) = server(cfg, 0, 1);
    let mut rxs = Vec::new();
    for i in 0..16 {
        rxs.push(s.submit_blocking(vec![i as f32; 8]).unwrap());
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
    }
    let nb = batches.load(Ordering::SeqCst);
    assert!(nb < 16, "no batching happened ({nb} batches for 16 reqs)");
    assert!(max_seen.load(Ordering::SeqCst) <= 4, "batch size exceeded max");
    s.shutdown();
}

#[test]
fn batch_size_never_exceeds_config() {
    let cfg = ServerConfig {
        max_batch: 2,
        batch_timeout: Duration::from_millis(10),
        queue_cap: 64,
        ..ServerConfig::default()
    };
    let (s, _, max_seen) = server(cfg, 0, 1);
    let mut rxs = Vec::new();
    for i in 0..20 {
        rxs.push(s.submit_blocking(vec![i as f32; 8]).unwrap());
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
    }
    assert!(max_seen.load(Ordering::SeqCst) <= 2);
    s.shutdown();
}

#[test]
fn backpressure_rejects_when_full() {
    let cfg = ServerConfig {
        max_batch: 1,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 2,
        ..ServerConfig::default()
    };
    // slow backend: 50ms per call, so the queue fills
    let (s, _, _) = server(cfg, 0, 50);
    let mut accepted = 0;
    let mut rejected = 0;
    let mut rxs = Vec::new();
    for i in 0..30 {
        match s.submit(vec![i as f32; 8]) {
            Ok(rx) => {
                accepted += 1;
                rxs.push(rx);
            }
            Err(_) => rejected += 1,
        }
    }
    assert!(rejected > 0, "queue_cap=2 with slow backend must reject");
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(10)).unwrap();
    }
    assert_eq!(s.metrics().served, accepted);
    s.shutdown();
}

#[test]
fn failed_batch_disconnects_not_hangs() {
    let cfg = ServerConfig {
        max_batch: 1,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 8,
        ..ServerConfig::default()
    };
    let (s, _, _) = server(cfg, 2, 0); // every 2nd call fails
    let mut disconnects = 0;
    let mut ok = 0;
    for i in 0..10 {
        let rx = s.submit_blocking(vec![i as f32; 8]).unwrap();
        match rx.recv_timeout(Duration::from_secs(5)) {
            Ok(_) => ok += 1,
            Err(_) => disconnects += 1,
        }
    }
    assert!(ok > 0 && disconnects > 0, "ok {ok} disc {disconnects}");
    assert_eq!(s.metrics().errors as usize, disconnects);
    s.shutdown();
}

#[test]
fn metrics_latency_percentiles_ordered() {
    let (s, _, _) = server(ServerConfig::default(), 0, 1);
    let mut rxs = Vec::new();
    for i in 0..25 {
        rxs.push(s.submit_blocking(vec![i as f32; 8]).unwrap());
    }
    for rx in rxs {
        rx.recv_timeout(Duration::from_secs(5)).unwrap();
    }
    let m = s.metrics();
    assert!(m.p50_us <= m.p95_us && m.p95_us <= m.p99_us);
    assert!(m.throughput_rps > 0.0);
    s.shutdown();
}

#[test]
fn shutdown_is_idempotent_and_fast() {
    let (s, _, _) = server(ServerConfig::default(), 0, 0);
    let t0 = std::time::Instant::now();
    s.shutdown();
    s.shutdown(); // second call must be a no-op, not a hang
    assert!(t0.elapsed() < Duration::from_secs(2));
}

#[test]
fn queue_time_accounts_for_batch_wait() {
    // Regression test for the queue_us accounting bug: a lone request
    // waits out the FULL batch timeout before its (slow) batch runs. The
    // old `elapsed - compute_us.min(elapsed)` dance re-sampled elapsed()
    // and could report queue_us == 0 for exactly this case; the fixed
    // accounting samples total_us once and derives
    // queue_us = total_us.saturating_sub(compute_us).
    let cfg = ServerConfig {
        max_batch: 4,
        batch_timeout: Duration::from_millis(60),
        queue_cap: 8,
        ..ServerConfig::default()
    };
    let (s, _, _) = server(cfg, 0, 25); // slow mock: 25ms per batch
    let rx = s.submit_blocking(vec![1.0; 8]).unwrap();
    let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
    assert!(r.compute_us >= 25_000, "compute_us {} below the mock delay", r.compute_us);
    // the 60ms batcher wait must land in queue_us, not vanish (generous
    // scheduler slack below the configured timeout)
    assert!(r.queue_us >= 40_000, "queue_us {} lost the batcher wait", r.queue_us);
    s.shutdown();
}

#[test]
fn multi_worker_pool_preserves_invariants() {
    // the single-dispatcher invariants hold at workers=4: exactly one
    // response per request, correct payloads, bounded batches, and the
    // per-worker counters reconcile with the totals
    let cfg = ServerConfig {
        max_batch: 4,
        batch_timeout: Duration::from_millis(5),
        queue_cap: 64,
        workers: 4,
        ..ServerConfig::default()
    };
    let (s, _, max_seen) = server(cfg, 0, 1);
    let mut rxs = Vec::new();
    for i in 0..80 {
        rxs.push((i, s.submit_blocking(vec![i as f32; 8]).unwrap()));
    }
    let mut ids = HashSet::new();
    for (i, rx) in rxs {
        let r = rx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert_eq!(r.image[0], (8 * i) as f32, "request {i} got someone else's image");
        assert!(ids.insert(r.id), "duplicate response id {}", r.id);
    }
    assert_eq!(ids.len(), 80);
    assert!(max_seen.load(Ordering::SeqCst) <= 4);
    let m = s.metrics();
    assert_eq!(m.served, 80);
    assert_eq!(m.errors, 0);
    assert_eq!(m.worker_batches.len(), 4, "one batch counter per worker");
    assert_eq!(m.worker_batches.iter().sum::<u64>(), m.batches);
    assert_eq!(m.worker_served.iter().sum::<u64>(), m.served);
    assert!(m.max_queue_depth <= 64);
    s.shutdown();
}
