//! Chaos suite (DESIGN.md §15): seeded fault injection against a real
//! worker pool, asserting the containment contract end to end —
//!
//! * **no stranded receivers**: every submitted request resolves (image,
//!   typed fault, or disconnect) under any mix of injected panics,
//!   executor errors, and stalls;
//! * **pool strength**: the supervisor + in-loop containment keep
//!   `live_workers` at the configured count no matter how many batches
//!   panic;
//! * **blast radius**: a poison-pill request is quarantined with a typed
//!   fault while its batchmates and the rest of the lane keep serving;
//! * **circuit breaker**: consecutive failures open a lane
//!   (`SubmitError::LaneDown`), a half-open probe closes it again;
//! * **watchdog honesty**: slow injection below the stall threshold must
//!   NOT count as a stall.
//!
//! Opt-in (`cargo test --test chaos`): CI runs it in a dedicated step
//! under `timeout`, like the other fault suites.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use split_deconv::coordinator::{
    BatchExecutor, BreakerConfig, BreakerState, FaultKind, FaultPlan, Server, ServerConfig,
    SubmitError, WatchdogConfig,
};
use split_deconv::engine::{DeconvImpl, Precision, Program};
use split_deconv::obs::{EventKind, Journal, JournalConfig};
use split_deconv::util::rng::Rng;

mod common;
use common::tiny_net;

const RECV_TIMEOUT: Duration = Duration::from_secs(20);

/// Trivial echo backend (z + 1) so chaos is the ONLY failure source.
struct EchoExec {
    batches: Vec<usize>,
}

impl BatchExecutor for EchoExec {
    fn supported_batches(&self) -> &[usize] {
        &self.batches
    }
    fn z_len(&self) -> usize {
        4
    }
    fn image_len(&self) -> usize {
        4
    }
    fn execute(&mut self, batch: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        Ok(batch.iter().map(|z| z.iter().map(|v| v + 1.0).collect()).collect())
    }
}

fn echo_cfg(workers: usize, max_batch: usize) -> ServerConfig {
    ServerConfig {
        max_batch,
        batch_timeout: Duration::from_millis(2),
        queue_cap: 256,
        model: "echo".to_string(),
        workers,
        ..ServerConfig::default()
    }
}

fn echo_server(cfg: ServerConfig) -> Server {
    Server::start_with(cfg, |_worker| {
        Ok(EchoExec {
            batches: vec![1, 2, 4],
        })
    })
    .unwrap()
}

/// The headline gate: under a seeded mix of panic/error/slow injection,
/// every one of N submitted requests resolves — as an image, a typed
/// fault, or a disconnect — and the accounting balances exactly
/// (`in_flight` back to 0, pool at full strength).
#[test]
fn mixed_chaos_strands_no_receivers() {
    const N: usize = 64;
    let mut cfg = echo_cfg(2, 4);
    let plan = FaultPlan::new(42, 20, 10, 10).with_ticks(24).with_slow(Duration::from_millis(5));
    cfg.chaos = Some(Arc::new(plan));
    let server = echo_server(cfg);

    let rxs: Vec<_> = (0..N)
        .map(|i| server.submit_blocking(vec![i as f32; 4]).unwrap())
        .collect();
    let (mut ok, mut faulted, mut disconnected) = (0usize, 0usize, 0usize);
    for (i, rx) in rxs.into_iter().enumerate() {
        match rx.recv_timeout(RECV_TIMEOUT) {
            Ok(resp) => match resp.fault {
                None => {
                    assert_eq!(resp.image, vec![i as f32 + 1.0; 4], "request {i} got wrong image");
                    ok += 1;
                }
                Some(f) => {
                    assert!(resp.image.is_empty(), "faulted response {i} must carry no image");
                    assert!(!f.msg.is_empty(), "fault must carry its panic detail");
                    faulted += 1;
                }
            },
            Err(_) => disconnected += 1, // injected executor error
        }
    }
    assert_eq!(ok + faulted + disconnected, N, "every receiver must resolve");
    assert!(ok > 0, "the quiet tail of the plan must serve normally");

    let m = server.metrics();
    assert_eq!(m.in_flight, 0, "accounting must balance after chaos");
    assert_eq!(m.live_workers, 2, "pool at full strength while serving");
    assert_eq!(
        m.served as usize, ok,
        "served counts exactly the image-carrying responses"
    );
    server.shutdown();
}

/// Panic containment in isolation: with `panic=100%` for the first K
/// ticks and single-request batches, every panicked batch is retried
/// solo (retries never draw chaos) — so ALL requests still come back
/// with images, `worker_panics` counts exactly K, nothing is
/// quarantined, and the pool stays at strength. The journal records one
/// WorkerPanic + one WorkerRespawn per injection.
#[test]
fn pool_returns_to_strength_after_every_batch_panics() {
    const N: usize = 16;
    const K: u64 = 6;
    let journal = Journal::new(JournalConfig {
        rings: 2,
        ring_capacity: 4096,
    });
    let mut cfg = echo_cfg(2, 1);
    cfg.journal = Some(journal.clone());
    cfg.chaos = Some(Arc::new(FaultPlan::new(5, 100, 0, 0).with_ticks(K)));
    let server = echo_server(cfg);

    let rxs: Vec<_> = (0..N)
        .map(|i| server.submit_blocking(vec![i as f32; 4]).unwrap())
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(RECV_TIMEOUT).unwrap();
        assert!(resp.fault.is_none(), "request {i}: solo retry must succeed");
        assert_eq!(resp.image, vec![i as f32 + 1.0; 4], "request {i} image");
    }

    let m = server.metrics();
    assert_eq!(m.worker_panics, K, "one contained panic per chaos tick");
    assert_eq!(m.quarantined, 0, "retries are chaos-free, nothing quarantines");
    assert_eq!(m.errors, 0, "panics are contained, not counted as batch errors");
    assert_eq!(m.in_flight, 0);
    assert_eq!(m.live_workers, 2, "pool back to configured strength");
    server.shutdown();

    let events = journal.snapshot();
    let panics = events.iter().filter(|e| e.kind == EventKind::WorkerPanic).count();
    let respawns = events.iter().filter(|e| e.kind == EventKind::WorkerRespawn).count();
    assert_eq!(panics as u64, K, "journal records every contained panic");
    assert_eq!(respawns as u64, K, "every panic rebuilds the executor");
}

/// Panics iff a request's first latent element is the poison marker.
struct PoisonExec;

impl BatchExecutor for PoisonExec {
    fn supported_batches(&self) -> &[usize] {
        &[1, 2, 4]
    }
    fn z_len(&self) -> usize {
        4
    }
    fn image_len(&self) -> usize {
        4
    }
    fn execute(&mut self, batch: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        for z in batch {
            assert!(z[0] != 666.0, "poison pill in batch");
        }
        Ok(batch.iter().map(|z| z.iter().map(|v| v + 1.0).collect()).collect())
    }
}

/// Blast-radius containment: a request that panics the worker on its own
/// (twice — once in its batch, once on the solo retry) is quarantined
/// with a typed fault; its batchmates are served via the solo retry and
/// the lane keeps serving fresh requests afterwards.
#[test]
fn poison_pill_is_quarantined_and_the_lane_keeps_serving() {
    let cfg = ServerConfig {
        max_batch: 4,
        batch_timeout: Duration::from_millis(30),
        queue_cap: 64,
        model: "poison".to_string(),
        workers: 1,
        ..ServerConfig::default()
    };
    let server = Server::start_with(cfg, |_worker| Ok(PoisonExec)).unwrap();

    // three good requests + the poison pill, submitted back to back so
    // they MAY share a batch (containment must be correct either way)
    let good: Vec<_> = (0..3)
        .map(|i| server.submit_blocking(vec![i as f32; 4]).unwrap())
        .collect();
    let poison = server.submit_blocking(vec![666.0; 4]).unwrap();

    for (i, rx) in good.into_iter().enumerate() {
        let resp = rx.recv_timeout(RECV_TIMEOUT).unwrap();
        assert!(resp.fault.is_none(), "good request {i} must be served");
        assert_eq!(resp.image, vec![i as f32 + 1.0; 4], "good request {i} image");
    }
    let resp = poison.recv_timeout(RECV_TIMEOUT).unwrap();
    let fault = resp.fault.expect("the poison pill gets a typed fault, not a hang");
    assert_eq!(fault.kind, FaultKind::Quarantined);
    assert!(resp.image.is_empty());

    let m = server.metrics();
    assert_eq!(m.quarantined, 1, "exactly the poison pill is quarantined");
    assert!(
        m.worker_panics >= 2,
        "batch panic + solo-retry panic, got {}",
        m.worker_panics
    );
    assert_eq!(m.live_workers, 1);

    // the lane is still alive for everyone else
    for i in 10..14 {
        let rx = server.submit_blocking(vec![i as f32; 4]).unwrap();
        let resp = rx.recv_timeout(RECV_TIMEOUT).unwrap();
        assert!(resp.fault.is_none(), "post-quarantine request {i} must serve");
        assert_eq!(resp.image, vec![i as f32 + 1.0; 4]);
    }
    assert_eq!(server.metrics().in_flight, 0);
    server.shutdown();
}

/// Fails every batch while the flag is up; serves normally once lowered.
struct FlakyExec {
    failing: Arc<AtomicBool>,
}

impl BatchExecutor for FlakyExec {
    fn supported_batches(&self) -> &[usize] {
        &[1]
    }
    fn z_len(&self) -> usize {
        4
    }
    fn image_len(&self) -> usize {
        4
    }
    fn execute(&mut self, batch: &[Vec<f32>]) -> anyhow::Result<Vec<Vec<f32>>> {
        if self.failing.load(Ordering::SeqCst) {
            anyhow::bail!("injected executor failure");
        }
        Ok(batch.to_vec())
    }
}

/// The breaker lifecycle over a real pool: `threshold` consecutive batch
/// failures open the lane (submits bounce with `LaneDown`, counted in
/// `lane_down`), the cooldown admits exactly one half-open probe, and a
/// successful probe closes the breaker again.
#[test]
fn breaker_opens_on_consecutive_failures_and_recovers_via_probe() {
    let cooldown = Duration::from_millis(80);
    let failing = Arc::new(AtomicBool::new(true));
    let failing2 = failing.clone();
    let cfg = ServerConfig {
        max_batch: 1,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 16,
        model: "flaky".to_string(),
        workers: 1,
        breaker: Some(BreakerConfig {
            threshold: 3,
            cooldown,
        }),
        ..ServerConfig::default()
    };
    let server = Server::start_with(cfg, move |_worker| {
        Ok(FlakyExec {
            failing: failing2.clone(),
        })
    })
    .unwrap();

    // three failing batches: receivers observe the legacy disconnect,
    // the breaker counts the consecutive failures
    for i in 0..3 {
        let rx = server.submit_to(0, vec![1.0; 4], None).unwrap();
        assert!(rx.recv_timeout(RECV_TIMEOUT).is_err(), "failing batch {i} disconnects");
    }
    assert_eq!(server.breaker_states().unwrap()[0], BreakerState::Open);

    // open lane: submits bounce fast with the typed error
    match server.submit_to(0, vec![1.0; 4], None) {
        Err(SubmitError::LaneDown) => {}
        other => panic!("open breaker must answer LaneDown, got {other:?}"),
    }
    assert!(server.metrics().lane_down >= 1, "rejections are counted");

    // heal the executor, wait out the cooldown: the next submit is the
    // half-open probe, and its success closes the breaker
    failing.store(false, Ordering::SeqCst);
    std::thread::sleep(cooldown + Duration::from_millis(40));
    let probe = server.submit_to(0, vec![2.0; 4], None).expect("probe admitted half-open");
    let resp = probe.recv_timeout(RECV_TIMEOUT).expect("probe must be served");
    assert!(resp.fault.is_none());
    assert_eq!(resp.image, vec![2.0; 4]);
    // the success lands synchronously before the response is sent
    assert_eq!(server.breaker_states().unwrap()[0], BreakerState::Closed);

    // closed again: normal serving resumes
    let rx = server.submit_to(0, vec![3.0; 4], None).unwrap();
    assert_eq!(rx.recv_timeout(RECV_TIMEOUT).unwrap().image, vec![3.0; 4]);
    assert_eq!(server.metrics().in_flight, 0);
    server.shutdown();
}

/// Watchdog honesty under slow injection: stalls BELOW `stall_after`
/// must not be flagged — chaos slow ticks are latency, not wedges.
#[test]
fn slow_injection_below_the_stall_threshold_is_not_flagged() {
    let journal = Journal::new(JournalConfig {
        rings: 2,
        ring_capacity: 4096,
    });
    let mut cfg = echo_cfg(1, 1);
    cfg.journal = Some(journal);
    cfg.watchdog = Some(WatchdogConfig {
        interval: Duration::from_millis(20),
        stall_after: Duration::from_millis(500),
        max_request_age: Duration::from_millis(500),
    });
    // every tick stalls 25ms — an order of magnitude under stall_after
    let plan = FaultPlan::new(9, 0, 0, 100).with_slow(Duration::from_millis(25));
    cfg.chaos = Some(Arc::new(plan));
    let server = echo_server(cfg);

    for i in 0..8 {
        let rx = server.submit_blocking(vec![i as f32; 4]).unwrap();
        let resp = rx.recv_timeout(RECV_TIMEOUT).unwrap();
        assert!(resp.fault.is_none(), "slow is not a failure");
        assert_eq!(resp.image, vec![i as f32 + 1.0; 4]);
    }
    // several watchdog scan intervals pass over the slow traffic above
    // (8 x 25ms of injected stall >> 20ms interval); none may be flagged
    let m = server.metrics();
    assert_eq!(
        m.watchdog_stalls, 0,
        "sub-threshold slow injection must not trip the watchdog"
    );
    server.shutdown();
}

/// Containment over the REAL native backend at int8: injected panics
/// against a quantized compiled program are contained and retried just
/// like the mock path, and the recovered lane still serves quantized
/// images.
#[test]
fn int8_native_lane_recovers_from_injected_panics() {
    const K: u64 = 2;
    let net = tiny_net();
    let program =
        Arc::new(Program::from_seed_prec(&net, DeconvImpl::Sd, 4, Precision::Int8).unwrap());
    let cfg = ServerConfig {
        max_batch: 1,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 32,
        model: "tiny-int8".to_string(),
        workers: 1,
        precision: Precision::Int8,
        chaos: Some(Arc::new(FaultPlan::new(3, 100, 0, 0).with_ticks(K))),
        ..ServerConfig::default()
    };
    let server = Server::start_native_program(cfg, program.clone()).unwrap();
    let mut rng = Rng::new(11);
    let rxs: Vec<_> = (0..6)
        .map(|_| server.submit_blocking(rng.normal_vec(16)).unwrap())
        .collect();
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(RECV_TIMEOUT).unwrap();
        assert!(resp.fault.is_none(), "request {i}: containment retry must serve");
        assert_eq!(resp.image.len(), program.output_len(), "request {i} image length");
    }
    let m = server.metrics();
    assert_eq!(m.worker_panics, K);
    assert_eq!(m.live_workers, 1);
    assert_eq!(m.in_flight, 0);
    server.shutdown();
}
