//! Error-path coverage for the multi-worker pool: a backend that fails
//! every k-th batch must disconnect exactly its own requests' responders
//! (never deliver a wrong image), count each failed batch in
//! `MetricsSnapshot.errors`, and leave the pool serving subsequent
//! batches.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{bail, Result};
use split_deconv::coordinator::{BatchExecutor, Server, ServerConfig};

/// Mock backend failing every `fail_every`-th call *of each worker's own
/// instance*; shared counters record exactly how many batches/requests
/// were failed across the pool.
struct FlakyExec {
    calls: usize,
    fail_every: usize,
    failed_batches: Arc<AtomicUsize>,
    failed_requests: Arc<AtomicUsize>,
}

impl BatchExecutor for FlakyExec {
    fn supported_batches(&self) -> &[usize] {
        &[1, 4]
    }

    fn z_len(&self) -> usize {
        8
    }

    fn image_len(&self) -> usize {
        2
    }

    fn execute(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.calls += 1;
        if self.fail_every > 0 && self.calls % self.fail_every == 0 {
            self.failed_batches.fetch_add(1, Ordering::SeqCst);
            self.failed_requests.fetch_add(batch.len(), Ordering::SeqCst);
            bail!("injected failure (call {})", self.calls);
        }
        Ok(batch
            .iter()
            .map(|z| vec![z.iter().sum::<f32>(), z.len() as f32])
            .collect())
    }
}

fn flaky_server(
    workers: usize,
    fail_every: usize,
) -> (Server, Arc<AtomicUsize>, Arc<AtomicUsize>) {
    let failed_batches = Arc::new(AtomicUsize::new(0));
    let failed_requests = Arc::new(AtomicUsize::new(0));
    let (fb, fr) = (failed_batches.clone(), failed_requests.clone());
    let cfg = ServerConfig {
        max_batch: 2,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 32,
        workers,
        ..ServerConfig::default()
    };
    let s = Server::start_with(cfg, move |_worker| {
        Ok(FlakyExec {
            calls: 0,
            fail_every,
            failed_batches: fb.clone(),
            failed_requests: fr.clone(),
        })
    })
    .unwrap();
    (s, failed_batches, failed_requests)
}

#[test]
fn failed_batches_disconnect_their_requests_and_pool_keeps_serving() {
    let (s, failed_batches, failed_requests) = flaky_server(4, 3);
    let mut ok = 0usize;
    let mut disconnected = 0usize;
    let total = 200usize;
    for i in 0..total {
        let z = vec![i as f32; 8];
        let rx = s.submit_blocking(z).unwrap();
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(r) => {
                // a surviving response must carry ITS OWN image — a failed
                // batch can never leak someone else's payload
                assert_eq!(r.image[0], (8 * i) as f32, "request {i} got a wrong image");
                assert_eq!(r.image[1], 8.0);
                ok += 1;
            }
            Err(RecvTimeoutError::Disconnected) => disconnected += 1,
            Err(RecvTimeoutError::Timeout) => panic!("request {i} hung"),
        }
    }
    assert_eq!(ok + disconnected, total, "every request resolves exactly once");
    assert!(ok > 0, "pool must keep serving around failures");
    assert!(disconnected > 0, "fail_every=3 must fail some batches");
    // failed requests observe disconnection 1:1, and errors count batches
    assert_eq!(disconnected, failed_requests.load(Ordering::SeqCst));
    let m = s.metrics();
    assert_eq!(m.errors as usize, failed_batches.load(Ordering::SeqCst));
    assert_eq!(m.served as usize, ok);
    s.shutdown();
}

#[test]
fn pool_survives_a_worker_whose_backend_always_fails() {
    // fail_every=1: every batch of every worker fails; requests must all
    // disconnect (not hang), errors must count every batch
    let (s, failed_batches, _) = flaky_server(2, 1);
    let mut disconnected = 0;
    for i in 0..20 {
        let rx = s.submit_blocking(vec![i as f32; 8]).unwrap();
        match rx.recv_timeout(Duration::from_secs(10)) {
            Ok(_) => panic!("fail_every=1 must never produce a response"),
            Err(RecvTimeoutError::Disconnected) => disconnected += 1,
            Err(RecvTimeoutError::Timeout) => panic!("request {i} hung"),
        }
    }
    assert_eq!(disconnected, 20);
    let m = s.metrics();
    assert_eq!(m.errors as usize, failed_batches.load(Ordering::SeqCst));
    assert_eq!(m.served, 0);
    s.shutdown();
}
