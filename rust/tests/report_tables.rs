//! End-to-end checks that the report generators reproduce the paper's
//! published numbers (Tables 1-3 near-exactly; figures by band/ordering).

use split_deconv::report;
use split_deconv::sim::energy::EnergyModel;

fn find<'a, T>(rows: &'a [T], name: &str, get: impl Fn(&T) -> &'static str) -> &'a T {
    rows.iter().find(|r| get(r) == name).unwrap()
}

#[test]
fn table1_matches_paper() {
    let rows = report::table1();
    let cases = [
        ("DCGAN", 111.41, 109.77, 0.01),
        ("SNGAN", 100.86, 100.66, 0.01),
        ("GP-GAN", 240.39, 103.81, 0.01),
        ("ArtGAN", 1268.77, 822.08, 0.16),
        ("MDE", 2638.22, 849.35, 0.03),
    ];
    for (name, total, deconv, tol) in cases {
        let r = find(&rows, name, |r| r.name);
        assert!((r.total_m - total).abs() / total < tol, "{name} total {}", r.total_m);
        assert!(
            (r.deconv_m - deconv).abs() / deconv < 0.03,
            "{name} deconv {}",
            r.deconv_m
        );
    }
}

#[test]
fn table2_matches_paper() {
    let rows = report::table2();
    let cases = [
        ("DCGAN", 109.77, 439.09, 158.07),
        ("ArtGAN", 822.08, 2030.04, 822.08),
        ("SNGAN", 100.66, 402.65, 100.66),
        ("GP-GAN", 103.81, 415.23, 103.81),
        ("MDE", 849.35, 3397.39, 1509.95),
        ("FST", 603.98, 2415.92, 1073.74),
    ];
    for (name, orig, nzp, sd) in cases {
        let r = find(&rows, name, |r| r.name);
        assert!((r.original_m - orig).abs() / orig < 0.03, "{name} orig {}", r.original_m);
        assert!((r.nzp_m - nzp).abs() / nzp < 0.03, "{name} nzp {}", r.nzp_m);
        assert!((r.sd_m - sd).abs() / sd < 0.03, "{name} sd {}", r.sd_m);
    }
}

#[test]
fn table3_matches_paper() {
    let rows = report::table3();
    // (name, orig, general SD, tol)
    let cases = [
        ("DCGAN", 1.03, 1.48, 0.05),
        ("SNGAN", 2.63, 2.63, 0.05),
        ("GP-GAN", 2.76, 2.76, 0.01),
        ("MDE", 3.93, 6.99, 0.03),
        ("FST", 0.09, 0.15, 0.1),
    ];
    for (name, orig, sd_gen, tol) in cases {
        let r = find(&rows, name, |r| r.name);
        assert!((r.original_m - orig).abs() / orig < tol, "{name} orig {}", r.original_m);
        assert!(
            (r.sd_general_m - sd_gen).abs() / sd_gen < tol,
            "{name} general {}",
            r.sd_general_m
        );
        // compressed ~= original (paper: "most of the redundant values
        // have been removed after the compression")
        assert!((r.sd_compressed_m - r.original_m).abs() / r.original_m < 0.01);
    }
}

#[test]
fn table4_ssim_ordering() {
    // paper: SD == 1.0 both rows; Shi and Chang below 1; both baselines do
    // better on FST (larger images) than on DCGAN.
    let rows = report::quality::table4(4).unwrap(); // fast config: FST at 64x64
    let dcgan = &rows[0];
    let fst = &rows[1];
    assert!(dcgan.ssim_sd > 0.999, "SD must be exact: {}", dcgan.ssim_sd);
    assert!(fst.ssim_sd > 0.999);
    assert!(dcgan.ssim_shi < 0.95, "shi should err: {}", dcgan.ssim_shi);
    assert!(dcgan.ssim_chang < 0.95);
    assert!(
        fst.ssim_shi > dcgan.ssim_shi,
        "larger images tolerate the wrong padding better: {} vs {}",
        fst.ssim_shi,
        dcgan.ssim_shi
    );
}

#[test]
fn sim_figures_have_expected_schemes_and_ordering() {
    let f8 = report::fig8(42).unwrap();
    assert_eq!(f8.len(), 6);
    for row in &f8 {
        let perf = row.normalized_perf();
        assert_eq!(perf[0].0, "NZP");
        assert!((perf[0].1 - 1.0).abs() < 1e-9);
        // SD >= NZP, SD-Asparse >= SD
        assert!(perf[1].1 > 1.0, "{}: SD {}", row.name, perf[1].1);
        assert!(perf[2].1 >= perf[1].1 * 0.99, "{}: Asparse regressed", row.name);
    }
    let f9 = report::fig9(42).unwrap();
    for row in &f9 {
        let perf = row.normalized_perf();
        let wasparse = perf.iter().find(|(l, _)| *l == "SD-WAsparse").unwrap().1;
        assert!(wasparse > 1.5, "{}: SD-WAsparse {wasparse}", row.name);
    }
}

#[test]
fn energy_figures_reduce_vs_nzp() {
    let m = EnergyModel::default();
    for row in report::fig11(42).unwrap() {
        let e = row.normalized_energy(&m);
        let wasparse = e.iter().find(|(l, _, _)| *l == "SD-WAsparse").unwrap().2;
        assert!(wasparse < 0.95, "{}: SD-WAsparse energy {wasparse}", row.name);
    }
}

#[test]
fn commodity_tables_match_paper_anchors() {
    let t5 = report::table5();
    assert!((t5.last().unwrap().normalized - 1.98).abs() < 0.02);
    let t6 = report::table6();
    assert!((t6.last().unwrap().normalized - 5.72).abs() < 0.06);
    let t7 = report::table7();
    assert!((t7.last().unwrap().normalized - 15.45).abs() < 0.16);
    let t8 = report::table8();
    assert!((t8.last().unwrap().normalized - 5.22).abs() < 0.06);
}

#[test]
fn fig15_fig17_speedups_in_band() {
    let f15 = report::fig15();
    let avg15 = report::average_speedup(&f15, "SD");
    assert!(avg15 > 1.2 && avg15 < 2.4, "fig15 avg {avg15}"); // paper 1.51x
    let f17 = report::fig17();
    let avg17 = report::average_speedup(&f17, "SD");
    assert!(avg17 > 1.2 && avg17 < 2.6, "fig17 avg {avg17}"); // paper 1.67x
    let nat = report::average_speedup(&f17, "Native");
    assert!(nat < avg17, "SD should beat native deconv on average");
}
