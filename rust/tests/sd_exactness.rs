//! Property tests: split deconvolution is bit-exact with the scatter
//! transposed convolution over a broad random geometry sweep — the paper's
//! central claim. (The offline registry has no proptest; this is a seeded
//! random-case sweep with shrink-free reporting of the failing geometry.)

use split_deconv::sd::{interleave, interleave_crop_into, nzp::nzp_deconv2d, sd_deconv2d};
use split_deconv::sd::{split_filters, SdGeometry};
use split_deconv::sd::{chang::chang_deconv2d, shi::shi_deconv2d};
use split_deconv::tensor::{deconv2d, Filter, Tensor};
use split_deconv::util::rng::Rng;

struct Case {
    i_h: usize,
    i_w: usize,
    k: usize,
    s: usize,
    p: usize,
    op: usize,
    ic: usize,
    oc: usize,
}

fn random_case(rng: &mut Rng) -> Case {
    let s = 1 + rng.below(4); // 1..=4
    let k = (1 + rng.below(7)).max(s.min(7)); // 1..=7, >= enough for p
    let p = rng.below(k); // 0..k-1
    let op = if s > 1 { rng.below(s.min(2) + 1).min(s - 1) } else { 0 };
    let mut c = Case {
        i_h: 1 + rng.below(8),
        i_w: 1 + rng.below(8),
        k,
        s,
        p,
        op,
        ic: 1 + rng.below(5),
        oc: 1 + rng.below(5),
    };
    // ensure positive output
    while (c.i_h - 1) * c.s + c.k <= 2 * c.p {
        c.i_h += 1;
    }
    while (c.i_w - 1) * c.s + c.k <= 2 * c.p {
        c.i_w += 1;
    }
    c
}

#[test]
fn sd_equals_deconv_300_random_geometries() {
    let mut rng = Rng::new(0xC0FFEE);
    for case_idx in 0..300 {
        let c = random_case(&mut rng);
        let x = Tensor::randn(1 + rng.below(2), c.i_h, c.i_w, c.ic, &mut rng);
        let f = Filter::randn(c.k, c.k, c.ic, c.oc, &mut rng);
        let want = deconv2d(&x, &f, c.s, c.p, c.op);
        let got = sd_deconv2d(&x, &f, c.s, c.p, c.op);
        assert_eq!(
            got.shape(),
            want.shape(),
            "case {case_idx}: k{} s{} p{} op{} i{}x{}",
            c.k, c.s, c.p, c.op, c.i_h, c.i_w
        );
        let d = got.max_abs_diff(&want);
        assert!(
            d < 2e-3,
            "case {case_idx}: k{} s{} p{} op{} i{}x{} ic{} oc{}: diff {d}",
            c.k, c.s, c.p, c.op, c.i_h, c.i_w, c.ic, c.oc
        );
    }
}

#[test]
fn nzp_equals_deconv_100_random_geometries() {
    let mut rng = Rng::new(0xBEEF);
    for case_idx in 0..100 {
        let c = random_case(&mut rng);
        let x = Tensor::randn(1, c.i_h, c.i_w, c.ic, &mut rng);
        let f = Filter::randn(c.k, c.k, c.ic, c.oc, &mut rng);
        let want = deconv2d(&x, &f, c.s, c.p, c.op);
        let got = nzp_deconv2d(&x, &f, c.s, c.p, c.op);
        let d = got.max_abs_diff(&want);
        assert!(d < 2e-3, "case {case_idx}: diff {d}");
    }
}

#[test]
fn split_filter_count_and_shape_invariants() {
    let mut rng = Rng::new(0xF00D);
    for _ in 0..50 {
        let s = 1 + rng.below(4);
        let k = 1 + rng.below(7);
        let (ic, oc) = (1 + rng.below(4), 1 + rng.below(4));
        let f = Filter::randn(k, k, ic, oc, &mut rng);
        let g = SdGeometry::new(k, s, 0);
        let splits = split_filters(&f, s);
        assert_eq!(splits.len(), s * s);
        // each split filter is K_T x K_T, channels preserved
        for sp in &splits {
            assert_eq!((sp.kh, sp.kw, sp.ic, sp.oc), (g.k_t, g.k_t, ic, oc));
        }
        // weight partition: every original weight appears exactly once
        let total: usize = splits.iter().map(|sp| sp.nonzero_params()).sum();
        assert_eq!(total, f.nonzero_params());
    }
}

#[test]
fn wrong_baselines_are_wrong_but_exact_ones_exact() {
    // table-4 precondition: SD/NZP exact; Shi/Chang not (for s>1 geometries)
    let mut rng = Rng::new(0xDEAD);
    for _ in 0..30 {
        let s = 2 + rng.below(2);
        let k = (s + rng.below(4)).min(7);
        let p = rng.below(k.min(3));
        let i = 4 + rng.below(6);
        let x = Tensor::randn(1, i, i, 3, &mut rng);
        let f = Filter::randn(k, k, 3, 2, &mut rng);
        let want = deconv2d(&x, &f, s, p, 0);
        assert!(sd_deconv2d(&x, &f, s, p, 0).allclose(&want, 2e-3));
        let shi = shi_deconv2d(&x, &f, s, p, 0);
        let chang = chang_deconv2d(&x, &f, s, p, 0);
        assert_eq!(shi.shape(), want.shape());
        assert_eq!(chang.shape(), want.shape());
        assert!(chang.max_abs_diff(&want) > 1e-3, "chang exact at k{k} s{s} p{p}");
    }
}

#[test]
fn interleave_crop_roundtrips_against_nzp_geometry() {
    // Property sweep: (1) interleave places every phase where the stride
    // write demands (so phases round-trip exactly); (2) the SD crop window
    // (Eq. 9 offset + final_out extent) matches the geometry of the naive
    // zero-padding conversion — zero-inserted side (i-1)s+1, conv pad
    // k-1-p, stride 1, plus output padding; (3) the engine's fused
    // interleave+crop pass is bit-identical to interleave + crop_padded.
    let mut rng = Rng::new(0x1EAF);
    for case_idx in 0..100 {
        let c = random_case(&mut rng);
        let g = SdGeometry::new(c.k, c.s, c.p);
        let (co_h, co_w) = (g.conv_out(c.i_h), g.conv_out(c.i_w));
        let convs: Vec<Tensor> = (0..c.s * c.s)
            .map(|_| Tensor::randn(1, co_h, co_w, c.ic, &mut rng))
            .collect();
        let big = interleave(&convs, c.s);
        for y in 0..big.h {
            for x in 0..big.w {
                let split = &convs[(y % c.s) * c.s + (x % c.s)];
                for ch in 0..c.ic {
                    assert_eq!(
                        big.at(0, y, x, ch),
                        split.at(0, y / c.s, x / c.s, ch),
                        "case {case_idx}: phase misplaced at ({y},{x})"
                    );
                }
            }
        }
        let (oh, ow) = (g.final_out(c.i_h, c.op), g.final_out(c.i_w, c.op));
        let nzp_oh = (c.i_h - 1) * c.s + 1 + 2 * (c.k - 1 - c.p) - c.k + 1 + c.op;
        let nzp_ow = (c.i_w - 1) * c.s + 1 + 2 * (c.k - 1 - c.p) - c.k + 1 + c.op;
        assert_eq!((oh, ow), (nzp_oh, nzp_ow), "case {case_idx}: crop extent != NZP output");
        let want = big.crop_padded(g.crop(), oh, g.crop(), ow);
        let mut fused = Tensor::zeros(0, 0, 0, 0);
        interleave_crop_into(&convs, c.s, g.crop(), oh, ow, &mut fused);
        assert_eq!(fused.shape(), want.shape(), "case {case_idx}");
        assert_eq!(
            fused.max_abs_diff(&want),
            0.0,
            "case {case_idx}: fused interleave+crop != two-step (k{} s{} p{} op{})",
            c.k,
            c.s,
            c.p,
            c.op
        );
    }
}

#[test]
fn sd_linear_in_input() {
    // deconv is linear: SD(a*x) == a*SD(x); catches accumulation bugs
    let mut rng = Rng::new(0xAB);
    let x = Tensor::randn(1, 5, 5, 3, &mut rng);
    let f = Filter::randn(4, 4, 3, 2, &mut rng);
    let y1 = sd_deconv2d(&x, &f, 2, 1, 0);
    let mut x2 = x.clone();
    for v in &mut x2.data {
        *v *= 3.0;
    }
    let y2 = sd_deconv2d(&x2, &f, 2, 1, 0);
    for (a, b) in y1.data.iter().zip(&y2.data) {
        assert!((3.0 * a - b).abs() < 1e-3);
    }
}
