//! Numerics-policy acceptance tests for the microkernel GEMM
//! (`tensor::gemm`, DESIGN.md §10):
//!
//! * **Determinism** — engine outputs are bit-identical for any worker
//!   count (`SD_CONV_THREADS` ∈ {1, 2, 8}, exercised through the policy's
//!   override hook) and across repeated runs, on all six benchmark
//!   networks, at f32 and int8 precision.
//! * **Accuracy** — the fast path matches an f64-referenced result on the
//!   paper's DCGAN/FST SD layer shapes: every element obeys the rigorous
//!   forward bound `|ŷ − y| ≤ k·ε·Σ|aᵢbᵢ|`, and well-conditioned elements
//!   stay within a small multiple of `gemm::ulp_bound(k)` ULPs. On the
//!   scalar backend the kernel is additionally bit-exact vs `conv2d_naive`
//!   (rust/tests/conv_gemm.rs covers the broad geometry sweep).

use split_deconv::engine::{DeconvImpl, Plan, Precision};
use split_deconv::networks;
use split_deconv::nn::NetworkSpec;
use split_deconv::tensor::{
    active_backend, conv2d_valid, dense, gemm, set_worker_override, Filter, GemmBackend, Tensor,
};
use split_deconv::util::rng::Rng;

/// Test-scale variants of all six benchmarks (the engine_equivalence
/// factors), so the determinism sweep stays minutes-scale in debug mode.
fn test_nets() -> Vec<NetworkSpec> {
    vec![
        networks::scaled(&networks::dcgan(), 2),
        networks::scaled(&networks::sngan(), 2),
        networks::scaled(&networks::artgan(), 8),
        networks::scaled(&networks::gpgan(), 4),
        networks::scaled(&networks::mde(), 8),
        networks::scaled(&networks::fst(), 16),
    ]
}

#[test]
fn engine_bits_identical_across_worker_counts_all_six_nets_f32_and_int8() {
    // SD_CONV_THREADS must never change an output bit: tiles are claimed
    // by exactly one cursor winner and per-element accumulation order is
    // schedule-independent. The override hook stands in for the env var
    // (same policy function, checked first). The hook is process-global,
    // so the f32 sweep and the int8 sweep live in this ONE test — two
    // tests mutating it on parallel test threads would race each other
    // into unintended widths and silently stop covering {1, 2, 8}.
    for net in test_nets() {
        let mut plan = Plan::from_seed(&net, DeconvImpl::Sd, 5).unwrap();
        let mut rng = Rng::new(1000);
        let zs: Vec<Vec<f32>> = (0..2).map(|_| rng.normal_vec(net.input_elems())).collect();
        set_worker_override(Some(1));
        let want = plan.execute_batch(&zs).unwrap();
        for threads in [2usize, 8] {
            set_worker_override(Some(threads));
            let got = plan.execute_batch(&zs).unwrap();
            assert_eq!(
                got, want,
                "{}: {threads}-thread output differs from single-thread",
                net.name
            );
        }
        // and run-to-run at a fixed width
        set_worker_override(Some(8));
        let again = plan.execute_batch(&zs).unwrap();
        set_worker_override(None);
        assert_eq!(again, want, "{}: repeated run differs", net.name);
    }

    // the int8 kernel accumulates exactly, so its sweep must hold
    // trivially — but it guards the tile/cursor plumbing of the quantized
    // driver too
    let net = networks::scaled(&networks::dcgan(), 2);
    let mut plan = Plan::from_seed_prec(&net, DeconvImpl::Sd, 5, Precision::Int8).unwrap();
    let mut rng = Rng::new(2000);
    let zs: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(net.input_elems())).collect();
    set_worker_override(Some(1));
    let want = plan.execute_batch(&zs).unwrap();
    for threads in [2usize, 8] {
        set_worker_override(Some(threads));
        let got = plan.execute_batch(&zs).unwrap();
        assert_eq!(got, want, "int8 {threads}-thread output differs");
    }
    set_worker_override(None);
}

/// f64-referenced convolution plus per-element `Σ|aᵢbᵢ|` (the
/// conditioning denominator of the forward bound).
fn conv2d_ref_f64(x: &Tensor, f: &Filter, stride: usize) -> (Vec<f64>, Vec<f64>) {
    let oh = (x.h - f.kh) / stride + 1;
    let ow = (x.w - f.kw) / stride + 1;
    let mut refv = Vec::with_capacity(x.n * oh * ow * f.oc);
    let mut sumabs = Vec::with_capacity(refv.capacity());
    for n in 0..x.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for o in 0..f.oc {
                    let mut acc = 0.0f64;
                    let mut sa = 0.0f64;
                    for dy in 0..f.kh {
                        for dx in 0..f.kw {
                            for i in 0..x.c {
                                let term = x.at(n, oy * stride + dy, ox * stride + dx, i) as f64
                                    * f.at(dy, dx, i, o) as f64;
                                acc += term;
                                sa += term.abs();
                            }
                        }
                    }
                    refv.push(acc);
                    sumabs.push(sa);
                }
            }
        }
    }
    (refv, sumabs)
}

/// The documented accuracy assertion (see `tensor::gemm`): rigorous
/// forward bound everywhere, tight ULP bound where conditioning allows.
fn assert_f64_policy(got: &Tensor, refv: &[f64], sumabs: &[f64], kdim: usize, ctx: &str) {
    assert_eq!(got.data.len(), refv.len(), "{ctx}: length");
    let eps = f32::EPSILON as f64;
    let ulp_budget = 8 * gemm::ulp_bound(kdim);
    for (i, (&g, (&r, &sa))) in got.data.iter().zip(refv.iter().zip(sumabs)).enumerate() {
        let err = (g as f64 - r).abs();
        let bound = kdim as f64 * eps * sa + f64::from(f32::MIN_POSITIVE);
        assert!(
            err <= bound,
            "{ctx}: elem {i}: |{g} - {r}| = {err} > forward bound {bound}"
        );
        if sa <= 8.0 * r.abs() {
            // condition number <= 8: the result must be ULP-close too
            let d = gemm::ulp_distance(g, r as f32);
            assert!(
                d <= ulp_budget,
                "{ctx}: elem {i}: {g} vs f64-ref {r}: {d} ulps > {ulp_budget}"
            );
        }
    }
}

#[test]
fn simd_kernel_within_ulp_bound_of_f64_reference_on_dcgan_fst_shapes() {
    // the stride-1 split convolutions the SD-lowered DCGAN / FST deconv
    // layers actually execute (channel-scaled to keep the f64 reference
    // affordable in debug builds; kdim stays in the hundreds)
    let shapes: &[(&str, usize, usize, usize, usize, usize)] = &[
        ("DCGAN deconv1 split 12x12x64 k3 -> 32", 12, 12, 64, 3, 32),
        ("DCGAN deconv2 split 20x20x32 k3 -> 16", 20, 20, 32, 3, 16),
        ("FST deconv1 split 33x33x32 k2 -> 16", 33, 33, 32, 2, 16),
    ];
    let mut rng = Rng::new(0xF64);
    for &(name, h, w, ic, k, oc) in shapes {
        let x = Tensor::randn(1, h, w, ic, &mut rng);
        let f = Filter::randn(k, k, ic, oc, &mut rng);
        let got = conv2d_valid(&x, &f, 1);
        let (refv, sumabs) = conv2d_ref_f64(&x, &f, 1);
        assert_f64_policy(&got, &refv, &sumabs, k * k * ic, name);
    }
}

#[test]
fn dense_gemm_within_ulp_bound_of_f64_reference() {
    let mut rng = Rng::new(0xDE45E);
    let (batch, n_in, n_out) = (4usize, 200usize, 96usize);
    let x = Tensor::randn(batch, 1, 1, n_in, &mut rng);
    let w: Vec<f32> = (0..n_in * n_out).map(|_| rng.normal()).collect();
    let got = dense(&x, &w, n_out).unwrap();
    let mut refv = Vec::with_capacity(batch * n_out);
    let mut sumabs = Vec::with_capacity(batch * n_out);
    for b in 0..batch {
        for o in 0..n_out {
            let mut acc = 0.0f64;
            let mut sa = 0.0f64;
            for i in 0..n_in {
                let term = x.data[b * n_in + i] as f64 * w[i * n_out + o] as f64;
                acc += term;
                sa += term.abs();
            }
            refv.push(acc);
            sumabs.push(sa);
        }
    }
    assert_f64_policy(&got, &refv, &sumabs, n_in, "dense 200 -> 96");
}

#[test]
fn scalar_backend_reports_and_is_bit_exact_with_naive() {
    // whatever the machine detects, the label must be coherent, and when
    // the detected backend IS scalar the broad bit-exactness suite in
    // conv_gemm.rs applies in full
    let be = active_backend();
    assert!(matches!(be, GemmBackend::Scalar | GemmBackend::Avx2));
    assert!(!be.label().is_empty());
}
