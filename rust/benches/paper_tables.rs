//! Bench target: regenerate the paper's Tables 1-4 (and time their
//! generation). Run: `cargo bench --bench paper_tables`.

#[path = "harness.rs"]
mod harness;

use split_deconv::report;

fn main() {
    harness::section("Paper tables (counts vs the published values)");
    report::print_table1();
    println!();
    report::print_table2();
    println!();
    report::print_table3();
    println!();
    report::print_table4(2).expect("table4"); // FST at 128x128 for tractable wall-clock
    println!();

    harness::section("Generation cost");
    harness::bench("tables 1-3 (pure counting)", 50, || {
        let _ = report::table1();
        let _ = report::table2();
        let _ = report::table3();
    });
    harness::bench("table 4 (full generator quality eval)", 3, || {
        let _ = report::quality::table4(4).expect("table4");
    });
}
