//! Bench target: multi-worker serving throughput — a closed-loop load
//! generator over the native worker pool, the end-to-end payoff of the
//! `Program`/`Scratch` split (one compile shared by N dispatcher workers).
//!
//! For every benchmark network and workers ∈ {1, 2, 4}: compile the model
//! ONCE into an `Arc<Program>`, stand up a `Server` with that many
//! dispatcher workers, and drive it with 8 closed-loop clients (each
//! submits, waits for its response, submits again) until the request
//! budget is spent. Reported per configuration: aggregate throughput
//! (req/s), latency percentiles (p50/p95/p99 from the server's own
//! metrics), mean batch size, and the per-worker batch spread.
//!
//! The GEMM kernel is pinned to ONE thread (`SD_CONV_THREADS=1`) for the
//! whole bench: intra-op parallelism would let a single worker saturate
//! the machine and mask the quantity under test, which is *inter-request*
//! scaling of the worker pool. Identical bits either way — threading never
//! changes results.
//!
//! After the closed-loop matrix, an OPEN-LOOP section (DCGAN, 4 workers)
//! drives the server with Poisson arrivals — seeded exponential
//! inter-arrival times on an absolute schedule, so pacing error cannot
//! accumulate — at 0.5x / 0.9x / 1.5x of the closed-loop capacity
//! estimate, and reports p50/p95/p99 latency vs offered load plus the
//! admission-control shed count per row.
//!
//! Acceptance (enforced with a nonzero exit code):
//! * 4-worker aggregate throughput strictly above the 1-worker
//!   configuration for DCGAN and FST (MDE and FST run at reduced
//!   resolution — structure and code path identical — to keep the bench
//!   minutes-scale);
//! * at overload (1.5x capacity) the server SHEDS rather than hangs:
//!   shed count > 0 (one retry at 3x before failing) and every accepted
//!   request is answered within the bounded wait;
//! * trace spans are effectively free when unsampled: DCGAN 4-worker
//!   throughput with `record_spans` ON (but no request asking for stage
//!   traces) must stay within 2% of the spans-OFF configuration
//!   (best-of-3 each, one retry — the DESIGN.md §12 zero-overhead
//!   contract as a CI gate);
//! * the flight recorder is effectively free: DCGAN 4-worker throughput
//!   with a journal attached (but nobody exporting traces) must stay
//!   within 2% of the journal-off configuration (best-of-3 each, one
//!   retry — the DESIGN.md §14 wait-free emit path as a CI gate).
//!
//! `cargo bench --bench serving -- --json BENCH_serving.json` writes the
//! per-configuration times/speedups and the open-loop rows for cross-PR
//! tracking; `-- --smoke` runs a reduced matrix (2 nets, workers {1, 4},
//! same open-loop section) as a CI gate.

#[path = "harness.rs"]
mod harness;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use split_deconv::coordinator::{MetricsSnapshot, Server, ServerConfig, SubmitError};
use split_deconv::engine::{DeconvImpl, Program};
use split_deconv::networks;
use split_deconv::obs::Journal;
use split_deconv::nn::NetworkSpec;
use split_deconv::util::rng::Rng;

/// Closed-loop client threads (in-flight ceiling), independent of the
/// worker count so every configuration sees the same offered load.
const CLIENTS: usize = 8;

/// (network, label, gated): `gated` nets enforce the 4-vs-1-worker
/// acceptance check.
fn bench_nets(smoke: bool) -> Vec<(NetworkSpec, &'static str, bool)> {
    if smoke {
        return vec![
            (networks::dcgan(), "DCGAN 64x64", true),
            (networks::scaled(&networks::fst(), 4), "FST 64x64 (1/4 res)", true),
        ];
    }
    vec![
        (networks::dcgan(), "DCGAN 64x64", true),
        (networks::artgan(), "ArtGAN 32x32", false),
        (networks::sngan(), "SNGAN 32x32", false),
        (networks::gpgan(), "GP-GAN 64x64", false),
        (networks::scaled(&networks::mde(), 2), "MDE 64x128 (1/2 res)", false),
        (networks::scaled(&networks::fst(), 2), "FST 128x128 (1/2 res)", true),
    ]
}

/// Drive `total` requests through the server from `CLIENTS` closed-loop
/// clients; returns once every response has been received.
fn closed_loop(server: &Server, total: usize, z_len: usize) {
    let issued = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for c in 0..CLIENTS {
            let issued = &issued;
            s.spawn(move || {
                let mut rng = Rng::new(1000 + c as u64);
                loop {
                    if issued.fetch_add(1, Ordering::Relaxed) >= total {
                        return;
                    }
                    let rx = server.submit_blocking(rng.normal_vec(z_len)).expect("submit");
                    // bounded wait: a hung pool must fail the bench (and
                    // its CI gate) fast, not block forever in recv()
                    let _ = rx
                        .recv_timeout(Duration::from_secs(120))
                        .expect("response within 120s");
                }
            });
        }
    });
}

/// One configuration: a fresh server over the SHARED program with
/// `workers` dispatchers; warm-up round, then a timed closed-loop run.
/// Returns (throughput req/s, wall seconds, metrics snapshot).
fn measure(
    program: &Arc<Program>,
    model: &str,
    workers: usize,
    total: usize,
    record_spans: bool,
    journal: bool,
) -> (f64, f64, MetricsSnapshot) {
    // max_batch 4 (not 8): with 8 closed-loop clients this yields more
    // executable calls per run, so the throughput sample the gate judges
    // is averaged over more events
    let cfg = ServerConfig {
        max_batch: 4,
        batch_timeout: Duration::from_millis(1),
        queue_cap: 64,
        model: model.to_string(),
        workers,
        record_spans,
        journal: if journal { Some(Journal::with_defaults()) } else { None },
        ..ServerConfig::default()
    };
    let z_len = program.input_len();
    let server = Server::start_native_program(cfg, program.clone()).expect("server start");
    // warm-up: one round per client. Its CLIENTS cold samples stay in the
    // metrics snapshot (percentiles are reported over warm-up + timed run;
    // the request budget keeps them a small minority), while the reported
    // THROUGHPUT is wall-clocked over the timed run only.
    closed_loop(&server, CLIENTS, z_len);
    let t0 = Instant::now();
    closed_loop(&server, total, z_len);
    let wall = t0.elapsed().as_secs_f64();
    let m = server.metrics();
    server.shutdown();
    (total as f64 / wall, wall, m)
}

/// One open-loop load point: submit `n` requests with Poisson arrivals at
/// `offered_rps` (exponential gaps on an ABSOLUTE schedule — if the
/// generator falls behind it submits immediately rather than letting
/// sleep overshoot depress the rate), never blocking on a full queue:
/// `SubmitError::Full` is counted as a shed. Every accepted request is
/// then awaited with a bounded timeout — an unanswered one panics, which
/// is exactly the "sheds, not hangs" overload gate. Returns
/// (achieved submit rps, accepted, shed, metrics).
fn open_loop_point(
    program: &Arc<Program>,
    model: &str,
    offered_rps: f64,
    n: usize,
    seed: u64,
) -> (f64, usize, u64, MetricsSnapshot) {
    let cfg = ServerConfig {
        max_batch: 4,
        batch_timeout: Duration::from_millis(1),
        // small lane: overload must become visible as sheds within the
        // point's request budget, not hide in a deep queue
        queue_cap: 16,
        model: model.to_string(),
        workers: 4,
        ..ServerConfig::default()
    };
    let z_len = program.input_len();
    let server = Server::start_native_program(cfg, program.clone()).expect("server start");
    // warm-up (same convention as the closed-loop section: the handful of
    // cold samples stay a small minority of the percentile snapshot)
    closed_loop(&server, CLIENTS, z_len);

    let mut rng = Rng::new(seed);
    let mut pending = Vec::with_capacity(n);
    let mut shed = 0u64;
    let t0 = Instant::now();
    let mut next = t0;
    for _ in 0..n {
        let u = rng.uniform() as f64;
        next += Duration::from_secs_f64(-(1.0 - u).ln() / offered_rps);
        let now = Instant::now();
        if next > now {
            std::thread::sleep(next - now);
        }
        match server.submit_to(0, rng.normal_vec(z_len), None) {
            Ok(rx) => pending.push(rx),
            Err(SubmitError::Full) => shed += 1,
            Err(e) => panic!("unexpected submit error: {e}"),
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let accepted = pending.len();
    for (i, rx) in pending.into_iter().enumerate() {
        rx.recv_timeout(Duration::from_secs(120)).unwrap_or_else(|_| {
            panic!("accepted request {i} was never answered — the server hung under load")
        });
    }
    let m = server.metrics();
    server.shutdown();
    (n as f64 / wall, accepted, shed, m)
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut sink = harness::JsonSink::from_args();
    // pin the conv kernel to one thread: the bench measures worker-pool
    // scaling, not intra-op parallelism (see module docs)
    std::env::set_var("SD_CONV_THREADS", "1");
    let worker_counts: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4] };
    // 64 requests at max_batch 4 ≈ 16+ executable calls per configuration:
    // the gate judges a mean over many batch events rather than a handful,
    // and the 8 warm-up samples are a ~11% minority of the percentile
    // snapshot
    let total = 64;

    let mut failures: Vec<String> = Vec::new();
    // closed-loop DCGAN capacity at 4 workers — the open-loop section's
    // load factors are anchored to it
    let mut dcgan_cap: Option<f64> = None;
    for (net, label, gated) in bench_nets(smoke) {
        harness::section(label);
        let program =
            Arc::new(Program::from_seed(&net, DeconvImpl::Sd, 7).expect("program compiles"));
        let mut baseline: Option<harness::BenchResult> = None;
        let mut tp_by_workers: Vec<(usize, f64)> = Vec::new();
        for &w in worker_counts {
            let (tp, wall, m) = measure(&program, net.name, w, total, true, false);
            tp_by_workers.push((w, tp));
            let spread: Vec<String> = m.worker_batches.iter().map(|b| b.to_string()).collect();
            let r = harness::BenchResult {
                name: format!("serving {label} w{w}"),
                iters: total,
                mean_s: wall / total as f64,
                min_s: wall / total as f64,
                stddev_s: 0.0,
            };
            println!(
                "  workers={w}: {tp:7.2} req/s  p50={:7.0}us p95={:7.0}us p99={:7.0}us \
                 mean_batch={:.2} worker_batches=[{}]",
                m.p50_us,
                m.p95_us,
                m.p99_us,
                m.mean_batch,
                spread.join(",")
            );
            if let Some(b) = &baseline {
                sink.record_speedup(b, &r);
            } else {
                sink.record(&r);
                baseline = Some(r);
            }
        }
        if label.starts_with("DCGAN") {
            dcgan_cap = tp_by_workers.iter().find(|(w, _)| *w == 4).map(|(_, t)| *t);
        }
        if gated {
            let tp1 = tp_by_workers.iter().find(|(w, _)| *w == 1).map(|(_, t)| *t);
            let tp4 = tp_by_workers.iter().find(|(w, _)| *w == 4).map(|(_, t)| *t);
            if let (Some(mut tp1), Some(mut tp4)) = (tp1, tp4) {
                println!("  -> 4-worker vs 1-worker throughput: {:.2}x", tp4 / tp1);
                if tp4 <= tp1 {
                    // one fresh re-measurement of both sides before
                    // failing: on small shared CI runners a single sample
                    // can be decided by scheduler noise, and a flaky
                    // required gate is worse than a retried one. The gate
                    // stays strict on the retry.
                    println!("  gate miss — re-measuring once to rule out scheduler noise");
                    tp1 = measure(&program, net.name, 1, total, true, false).0;
                    tp4 = measure(&program, net.name, 4, total, true, false).0;
                    println!("  -> retry: 4-worker vs 1-worker throughput: {:.2}x", tp4 / tp1);
                }
                if tp4 <= tp1 {
                    failures.push(format!(
                        "{label}: 4-worker throughput {tp4:.2} req/s not above \
                         1-worker {tp1:.2} req/s"
                    ));
                }
            }
        }
    }

    harness::section("open-loop Poisson serving (DCGAN, 4 workers)");
    {
        let net = networks::dcgan();
        let program =
            Arc::new(Program::from_seed(&net, DeconvImpl::Sd, 7).expect("program compiles"));
        let cap = dcgan_cap.expect("DCGAN is always in the closed-loop matrix");
        println!("  capacity estimate (closed-loop, 4 workers): {cap:7.2} req/s");
        for factor in [0.5, 0.9, 1.5] {
            let offered = cap * factor;
            // ~3 seconds of offered load per point, clamped to keep the
            // lightest and heaviest points comparable in sample count
            let n = ((offered * 3.0).ceil() as usize).clamp(24, 400);
            let (achieved, accepted, mut shed, m) =
                open_loop_point(&program, net.name, offered, n, 77);
            println!(
                "  {factor:.1}x: offered={offered:7.2} achieved={achieved:7.2} req/s  \
                 accepted={accepted:<4} shed={shed:<4} p50={:7.0}us p95={:7.0}us p99={:7.0}us",
                m.p50_us, m.p95_us, m.p99_us
            );
            sink.record_fields(
                &format!("serving open-loop DCGAN {factor:.1}x"),
                &[
                    ("offered_rps", offered),
                    ("achieved_rps", achieved),
                    ("accepted", accepted as f64),
                    ("shed", shed as f64),
                    ("p50_us", m.p50_us),
                    ("p95_us", m.p95_us),
                    ("p99_us", m.p99_us),
                ],
            );
            if factor > 1.0 {
                if shed == 0 {
                    // the 1.5x point should overload, but capacity is an
                    // estimate from another run — retry once at 3x before
                    // calling the admission-control gate a failure
                    println!("  overload produced no sheds — retrying once at 3x capacity");
                    let (_, _, shed3, _) = open_loop_point(&program, net.name, cap * 3.0, n, 78);
                    shed = shed3;
                }
                if shed == 0 {
                    failures.push(
                        "open-loop overload: no admission-control sheds at 1.5x/3x capacity"
                            .to_string(),
                    );
                } else {
                    println!("  -> overload sheds explicitly (shed={shed}), no hangs: gate PASS");
                }
            }
        }
    }

    harness::section("tracing overhead (DCGAN, 4 workers, spans on but unsampled)");
    {
        // the DESIGN.md §12 zero-overhead contract as a gate: span
        // recording ON but with NO request opting into stage traces must
        // cost < 2% throughput vs spans OFF. Best-of-3 per side — the
        // quantity under test is the code path's cost, not scheduler luck.
        let net = networks::dcgan();
        let program =
            Arc::new(Program::from_seed(&net, DeconvImpl::Sd, 7).expect("program compiles"));
        let best = |record_spans: bool| {
            (0..3)
                .map(|_| measure(&program, net.name, 4, total, record_spans, false).0)
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let mut disabled = best(false);
        let mut enabled = best(true);
        let mut ratio = enabled / disabled;
        println!(
            "  spans off: {disabled:7.2} req/s   spans on (unsampled): {enabled:7.2} req/s   \
             ratio {ratio:.4}"
        );
        if ratio < 0.98 {
            // same retry convention as the other gates: one fresh pair of
            // measurements before failing, strict on the retry
            println!("  gate miss — re-measuring once to rule out scheduler noise");
            disabled = best(false);
            enabled = best(true);
            ratio = enabled / disabled;
            println!(
                "  retry: spans off {disabled:7.2} req/s  on {enabled:7.2} req/s  ratio {ratio:.4}"
            );
        }
        sink.record_fields(
            "serving tracing-overhead DCGAN w4",
            &[
                ("disabled_rps", disabled),
                ("enabled_rps", enabled),
                ("ratio", ratio),
            ],
        );
        if ratio < 0.98 {
            failures.push(format!(
                "tracing overhead: spans-on throughput is {:.1}% of spans-off (gate: >= 98%)",
                ratio * 100.0
            ));
        } else {
            println!("  -> unsampled span recording costs < 2% throughput: gate PASS");
        }
    }

    harness::section("journal overhead (DCGAN, 4 workers, flight recorder attached, unsampled)");
    {
        // the DESIGN.md §14 wait-free emit path as a gate: a journal
        // ATTACHED to the server (every admission/batch/respond event
        // recorded into the rings) but with nobody exporting traces must
        // cost < 2% throughput vs no journal at all. Best-of-3 per side —
        // the quantity under test is the emit path's cost, not scheduler
        // luck. Spans stay ON on both sides so the only delta is the
        // recorder itself.
        let net = networks::dcgan();
        let program =
            Arc::new(Program::from_seed(&net, DeconvImpl::Sd, 7).expect("program compiles"));
        let best = |journal: bool| {
            (0..3)
                .map(|_| measure(&program, net.name, 4, total, true, journal).0)
                .fold(f64::NEG_INFINITY, f64::max)
        };
        let mut off = best(false);
        let mut on = best(true);
        let mut ratio = on / off;
        println!(
            "  journal off: {off:7.2} req/s   journal on (unsampled): {on:7.2} req/s   \
             ratio {ratio:.4}"
        );
        if ratio < 0.98 {
            // same retry convention as the other gates: one fresh pair of
            // measurements before failing, strict on the retry
            println!("  gate miss — re-measuring once to rule out scheduler noise");
            off = best(false);
            on = best(true);
            ratio = on / off;
            println!(
                "  retry: journal off {off:7.2} req/s  on {on:7.2} req/s  ratio {ratio:.4}"
            );
        }
        sink.record_fields(
            "serving journal-overhead DCGAN w4",
            &[("off_rps", off), ("on_rps", on), ("ratio", ratio)],
        );
        if ratio < 0.98 {
            failures.push(format!(
                "journal overhead: journal-on throughput is {:.1}% of journal-off (gate: >= 98%)",
                ratio * 100.0
            ));
        } else {
            println!("  -> the flight recorder costs < 2% throughput: gate PASS");
        }
    }

    harness::section("summary");
    if failures.is_empty() {
        println!(
            "serving acceptance (4w > 1w on every gated network; overload sheds, \
             never hangs; unsampled tracing < 2% overhead; flight recorder < 2% \
             overhead): PASS"
        );
    } else {
        for f in &failures {
            println!("FAIL: {f}");
        }
    }
    sink.write("serving");
    if !failures.is_empty() {
        // real gate: a FAIL is a nonzero exit, visible to CI and scripts
        std::process::exit(1);
    }
}
