//! Bench target: Figure 8 — deconvolutional layers of all six benchmarks on
//! the dot-production PE array (NZP vs SD vs SD-Asparse), plus an ablation
//! with NZP under idealized group-skip.

#[path = "harness.rs"]
mod harness;

use split_deconv::report;
use split_deconv::sim::workload::{lower_network_deconvs, Lowering};
use split_deconv::sim::{dot_array, ProcessorConfig, SkipPolicy};
use split_deconv::{networks, util};

fn main() {
    harness::section("Figure 8: dot-production PE array (normalized to NZP)");
    let rows = report::fig8(42).expect("fig8 lowering");
    report::print_sim_figure("", &rows);
    let speedups: Vec<f64> = rows
        .iter()
        .map(|r| r.normalized_perf().last().unwrap().1)
        .collect();
    println!(
        "SD-Asparse average speedup over NZP: {:.2}x (paper: ~2.5x SD boost)",
        util::geomean(&speedups)
    );

    harness::section("Ablation: NZP with idealized group-aligned Asparse");
    let cfg = ProcessorConfig::default();
    for net in networks::all() {
        let ops = lower_network_deconvs(&net, Lowering::Nzp, 42).expect("NZP lowering");
        let dense = dot_array::simulate(&ops, &cfg, SkipPolicy::None);
        let skip = dot_array::simulate(&ops, &cfg, SkipPolicy::ASparse);
        println!(
            "{:<10} NZP-Asparse recovers {:.0}% of cycles (skippable zeros are group-aligned only)",
            net.name,
            100.0 * (1.0 - skip.cycles as f64 / dense.cycles as f64)
        );
    }

    harness::section("Simulator throughput");
    let net = networks::dcgan();
    let ops = lower_network_deconvs(&net, Lowering::Sd, 42).expect("SD lowering");
    let macs: u64 = ops.iter().map(|o| o.dense_macs()).sum();
    let r = harness::bench("simulate DCGAN SD deconvs (dot array)", 10, || {
        let _ = dot_array::simulate(&ops, &cfg, SkipPolicy::ASparse);
    });
    println!(
        "simulated-MAC throughput: {:.0} MMAC/s",
        macs as f64 / r.min_s / 1e6
    );
}
