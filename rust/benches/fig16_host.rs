//! Bench target: Figure 16 — NZP vs SD deconvolution layers measured on the
//! host CPU through the AOT-compiled Pallas artifacts via PJRT. This is a
//! real wall-clock measurement, not a model (requires `make artifacts`).

#[path = "harness.rs"]
mod harness;

use split_deconv::commodity::host;
use split_deconv::runtime::{artifacts_available, default_artifact_dir, Engine};

fn main() {
    if !artifacts_available() {
        println!("SKIP fig16: artifacts/ missing — run `make artifacts` first");
        return;
    }
    harness::section("Figure 16: host CPU, measured wall-clock (PJRT + Pallas kernels)");
    let mut engine = Engine::new(default_artifact_dir()).expect("engine");
    println!("platform: {}", engine.platform());
    let rows = host::measure_fig16(&mut engine, 3).expect("measure");
    host::print_fig16(&rows);
    println!("(paper, Intel i7-7700: SD 3.04x average, up to 3.60x on GP-GAN)");
}
