//! Minimal benchmark harness (the offline registry has no criterion).
//! Provides warm-up + repeated timed runs with mean / min / stddev
//! reporting, and a figure/table printing convention shared by every bench
//! target. Each bench is a `harness = false` binary run by `cargo bench`.

#![allow(dead_code)] // shared by all bench targets; each uses a subset

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub stddev_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters={:<4} mean={:>10.3}ms  min={:>10.3}ms  sd={:>8.3}ms",
            self.name,
            self.iters,
            self.mean_s * 1e3,
            self.min_s * 1e3,
            self.stddev_s * 1e3
        );
    }
}

/// Time `f` with 1 warm-up + `iters` measured runs.
pub fn bench(name: &str, iters: usize, mut f: impl FnMut()) -> BenchResult {
    f(); // warm-up
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / iters as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        min_s: min,
        stddev_s: var.sqrt(),
    };
    r.report();
    r
}

pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Machine-readable bench output. Every bench target accepts
/// `cargo bench --bench <target> -- --json <path>` and writes a
/// `BENCH_*.json`-style file with per-entry times (and speedups where the
/// bench computes one), so the repo's perf trajectory can be tracked across
/// PRs. Without the flag, `write` is a no-op.
pub struct JsonSink {
    path: Option<String>,
    entries: Vec<String>,
}

impl JsonSink {
    /// Parse `--json <path>` from the bench binary's argv. A missing or
    /// flag-like path (starting with `-`) is diagnosed loudly rather than
    /// silently disabling output or writing to a file named like a flag.
    pub fn from_args() -> JsonSink {
        let args: Vec<String> = std::env::args().collect();
        let path = match args.iter().position(|a| a == "--json") {
            None => None,
            Some(i) => match args.get(i + 1) {
                Some(p) if !p.starts_with('-') => Some(p.clone()),
                _ => {
                    eprintln!("warning: --json needs a file path argument; JSON output disabled");
                    None
                }
            },
        };
        JsonSink { path, entries: Vec::new() }
    }

    /// Record one benchmark result.
    pub fn record(&mut self, r: &BenchResult) {
        self.push_entry(r, None, None);
    }

    /// Record an optimized result together with its speedup over a baseline
    /// (min-over-iters ratio, the same number the bench prints). A
    /// non-finite ratio (zero-time denominator) drops the speedup field
    /// rather than emitting invalid JSON.
    pub fn record_speedup(&mut self, baseline: &BenchResult, optimized: &BenchResult) {
        let s = baseline.min_s / optimized.min_s;
        self.push_entry(optimized, if s.is_finite() { Some(s) } else { None }, None);
    }

    /// Record an entry with arbitrary numeric fields — for rows whose
    /// tracked quantities are not a single time (e.g. the open-loop
    /// serving bench's offered/achieved rps + latency percentiles).
    pub fn record_fields(&mut self, name: &str, fields: &[(&str, f64)]) {
        let mut e = format!("{{\"name\":\"{}\"", json_escape(name));
        for (k, v) in fields {
            if v.is_finite() {
                e.push_str(&format!(",\"{}\":{v:.4}", json_escape(k)));
            }
        }
        e.push('}');
        self.entries.push(e);
    }

    /// Record a result with its achieved GFLOP/s (from min-over-iters).
    pub fn record_gflops(&mut self, r: &BenchResult, gflops: f64) {
        self.push_entry(r, None, if gflops.is_finite() { Some(gflops) } else { None });
    }

    /// Record a result with both a speedup over `baseline` and its
    /// achieved GFLOP/s — the hotpath GEMM table's row shape.
    pub fn record_speedup_gflops(
        &mut self,
        baseline: &BenchResult,
        optimized: &BenchResult,
        gflops: f64,
    ) {
        let s = baseline.min_s / optimized.min_s;
        self.push_entry(
            optimized,
            if s.is_finite() { Some(s) } else { None },
            if gflops.is_finite() { Some(gflops) } else { None },
        );
    }

    fn push_entry(&mut self, r: &BenchResult, speedup: Option<f64>, gflops: Option<f64>) {
        let mut e = format!(
            "{{\"name\":\"{}\",\"iters\":{},\"mean_ms\":{:.6},\"min_ms\":{:.6}",
            json_escape(&r.name),
            r.iters,
            r.mean_s * 1e3,
            r.min_s * 1e3
        );
        if let Some(s) = speedup {
            e.push_str(&format!(",\"speedup\":{s:.4}"));
        }
        if let Some(g) = gflops {
            e.push_str(&format!(",\"gflops\":{g:.3}"));
        }
        e.push('}');
        self.entries.push(e);
    }

    /// Write `{"bench": ..., "results": [...]}` to the `--json` path, if set.
    pub fn write(&self, bench: &str) {
        let Some(path) = &self.path else { return };
        let body = format!(
            "{{\"bench\":\"{}\",\"results\":[\n  {}\n]}}\n",
            json_escape(bench),
            self.entries.join(",\n  ")
        );
        match std::fs::write(path, body) {
            Ok(()) => println!("\nwrote bench JSON to {path}"),
            Err(e) => eprintln!("failed to write bench JSON {path}: {e}"),
        }
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}
