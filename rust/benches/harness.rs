//! Minimal benchmark harness (the offline registry has no criterion).
//! Provides warm-up + repeated timed runs with mean / min / stddev
//! reporting, and a figure/table printing convention shared by every bench
//! target. Each bench is a `harness = false` binary run by `cargo bench`.

#![allow(dead_code)] // shared by all bench targets; each uses a subset

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub stddev_s: f64,
}

impl BenchResult {
    pub fn report(&self) {
        println!(
            "bench {:<44} iters={:<4} mean={:>10.3}ms  min={:>10.3}ms  sd={:>8.3}ms",
            self.name,
            self.iters,
            self.mean_s * 1e3,
            self.min_s * 1e3,
            self.stddev_s * 1e3
        );
    }
}

/// Time `f` with 1 warm-up + `iters` measured runs.
pub fn bench(name: &str, iters: usize, mut f: impl FnMut()) -> BenchResult {
    f(); // warm-up
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let mean = times.iter().sum::<f64>() / iters as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / iters as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        min_s: min,
        stddev_s: var.sqrt(),
    };
    r.report();
    r
}

pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
