//! Bench target: Tables 5-8 and Figures 15/17 — the commodity-device
//! models (Edge TPU, Intel NCS2), including the tables-only ablation that
//! exposes the NZP activation-inflation derate assumption.

#[path = "harness.rs"]
mod harness;

use split_deconv::commodity::{
    edge_tpu::EdgeTpu, ncs2, nzp_time_s_derated, sd_time_s, EfficiencyModel,
};
use split_deconv::{networks, report};

fn main() {
    harness::section("Tables 5/6: Edge TPU efficiency curves");
    report::print_eff_table("Table 5 (filter sweep @ fmap 128):", &report::table6(), "k");
    report::print_eff_table("Table 6 (fmap sweep @ k3):", &report::table5(), "px");

    harness::section("Tables 7/8: NCS2 efficiency curves");
    report::print_eff_table("Table 7 (fmap sweep @ k3):", &report::table7(), "px");
    report::print_eff_table("Table 8 (filter sweep @ fmap 128):", &report::table8(), "k");

    harness::section("Figure 15: Edge TPU");
    let f15 = report::fig15();
    report::print_speedup_figure("", &f15);
    println!(
        "average SD speedup: {:.2}x (paper: 1.51x, max 1.65x on FST)",
        report::average_speedup(&f15, "SD")
    );

    harness::section("Figure 17: Intel NCS2");
    let f17 = report::fig17();
    report::print_speedup_figure("", &f17);
    println!(
        "average SD speedup over NZP: {:.2}x (paper: 1.67x); over native: {:.2}x (paper: 1.10x)",
        report::average_speedup(&f17, "SD"),
        report::average_speedup(&f17, "SD") / report::average_speedup(&f17, "Native")
    );

    harness::section("Ablation: tables-only prediction (derate = 1.0)");
    let tpu = EdgeTpu;
    for net in networks::all() {
        let nzp_model = nzp_time_s_derated(&tpu, &net, 1.0);
        let nzp_cal = nzp_time_s_derated(&tpu, &net, tpu.nzp_derate());
        let sd = sd_time_s(&tpu, &net, report::HOST_REORG_GBPS);
        println!(
            "{:<10} tables-only SD speedup {:.2}x | calibrated {:.2}x",
            net.name,
            nzp_model / sd,
            nzp_cal / sd
        );
    }
    println!("(tables alone under-predict the measured SD advantage — see commodity/mod.rs)");

    harness::section("Generation cost");
    harness::bench("fig15+fig17 regeneration", 100, || {
        let _ = report::fig15();
        let _ = report::fig17();
    });
    let _ = ncs2::native_deconv_time_s(&networks::dcgan());
}
