//! Bench target: compiled-plan engine vs the per-call interpreter paths,
//! across all six benchmark networks — the serving hot path the `engine`
//! subsystem optimizes.
//!
//! Three bars per network (batch 1, min over iters):
//! * **per-call**   — `quality::run_network`: weights rebuilt, SD filters
//!   re-split, plan recompiled on every forward call (the pre-engine
//!   serving cost profile the ISSUE calls "the interpreter");
//! * **interpreter** — the retained `run_network_with` oracle: weights
//!   cached, but SD filters re-split and every intermediate re-allocated
//!   per call;
//! * **plan-cached** — `engine::Plan::forward` on a plan built once
//!   (filters pre-split + packed, shapes precomputed, buffer arena reused).
//! * **int8-plan** — the same plan compiled at `Precision::Int8` (weights
//!   quantized per-output-channel, SD sub-filters packed int8, activation
//!   scales calibrated at build): the quantized serving mode's forward.
//!
//! Acceptance (enforced with a nonzero exit code): plan-cached beats the
//! **per-call** path on EVERY network; the weight-cached interpreter
//! comparison is reported as an informational bar, as is the int8-vs-f32
//! plan ratio (the *gated* int8-vs-f32 comparison is the GEMM-level one in
//! `cargo bench --bench hotpath`, whose rows CI publishes as
//! BENCH_quant.json). MDE and FST run at half resolution (structure and
//! code path identical) to keep the bench minutes-scale; the other four
//! are full scale.
//!
//! A second section times the `.sdprog` cold-start path on all six
//! FULL-SCALE networks at both precisions: compile-from-seed vs loading
//! the serialized artifact back (both [`LoadMode`]s), asserting the
//! reload is bit-identical and gating (nonzero exit) on zero-copy load
//! time < 10% of compile time — the artifact's reason to exist.
//!
//! `cargo bench --bench engine -- --json BENCH_engine.json` writes the
//! per-network times/speedups plus the compile-vs-load rows for cross-PR
//! tracking.

#[path = "harness.rs"]
mod harness;

use std::time::Instant;

use split_deconv::engine::{build_weights, DeconvImpl, LoadMode, Plan, Precision, Program};
use split_deconv::networks;
use split_deconv::nn::NetworkSpec;
use split_deconv::report::quality::{run_network, run_network_with};
use split_deconv::tensor::Tensor;
use split_deconv::util::rng::Rng;

fn bench_nets() -> Vec<(NetworkSpec, &'static str)> {
    vec![
        (networks::dcgan(), "DCGAN 64x64"),
        (networks::artgan(), "ArtGAN 32x32"),
        (networks::sngan(), "SNGAN 32x32"),
        (networks::gpgan(), "GP-GAN 64x64"),
        (networks::scaled(&networks::mde(), 2), "MDE 64x128 (1/2 res)"),
        (networks::scaled(&networks::fst(), 2), "FST 128x128 (1/2 res)"),
    ]
}

/// Min-of-3 `from_artifact_bytes` wall time for one load mode, returning
/// the last loaded program for the bit-identity check.
fn timed_load(bytes: &[u8], mode: LoadMode) -> (Program, f64) {
    let mut min = f64::INFINITY;
    let mut loaded = None;
    for _ in 0..3 {
        let t0 = Instant::now();
        let p = Program::from_artifact_bytes(bytes, mode).expect("artifact loads");
        min = min.min(t0.elapsed().as_secs_f64());
        loaded = Some(p);
    }
    (loaded.unwrap(), min)
}

fn main() {
    let mut sink = harness::JsonSink::from_args();
    let mut rng = Rng::new(11);
    let seed = 7u64;
    let iters = 3;
    let mut worst_per_call = f64::INFINITY;
    let mut worst_interp = f64::INFINITY;
    let mut worst_int8 = f64::INFINITY;

    for (net, label) in bench_nets() {
        harness::section(label);
        let l0 = &net.layers[0];
        let input = Tensor::randn(1, l0.in_h, l0.in_w, l0.in_c, &mut rng);
        let weights = build_weights(&net, seed);
        let mut plan = Plan::build(&net, &weights, DeconvImpl::Sd).expect("plan compiles");
        let mut i8_plan =
            Plan::build_owned_prec(&net, weights.clone(), DeconvImpl::Sd, Precision::Int8)
                .expect("int8 plan compiles");

        let per_call = harness::bench(&format!("per-call      {label}"), iters, || {
            let _ = run_network(&net, DeconvImpl::Sd, seed, &input).expect("per-call forward");
        });
        let interp = harness::bench(&format!("interpreter   {label}"), iters, || {
            let _ = run_network_with(&net, DeconvImpl::Sd, &weights, &input)
                .expect("interpreter forward");
        });
        let cached = harness::bench(&format!("plan-cached   {label}"), iters, || {
            let _ = plan.forward(&input).expect("plan forward");
        });
        let int8 = harness::bench(&format!("int8-plan     {label}"), iters, || {
            let _ = i8_plan.forward(&input).expect("int8 plan forward");
        });

        let s_per_call = per_call.min_s / cached.min_s;
        let s_interp = interp.min_s / cached.min_s;
        let s_int8 = cached.min_s / int8.min_s;
        worst_per_call = worst_per_call.min(s_per_call);
        worst_interp = worst_interp.min(s_interp);
        worst_int8 = worst_int8.min(s_int8);
        println!(
            "  -> plan-cached speedup: {s_per_call:.2}x vs per-call, {s_interp:.2}x vs \
             interpreter; int8 plan {s_int8:.2}x vs f32 plan"
        );
        sink.record(&per_call);
        sink.record(&interp);
        sink.record_speedup(&per_call, &cached);
        sink.record_speedup(&cached, &int8);
    }

    harness::section("artifact compile vs load (.sdprog, full-scale nets)");
    let mut worst_load_ratio: f64 = 0.0;
    for name in networks::names() {
        let net = networks::by_name(name).expect("registry network");
        for precision in [Precision::F32, Precision::Int8] {
            let label = format!("{name}_{}", precision.label());
            let t0 = Instant::now();
            let program = Program::from_seed_prec(&net, DeconvImpl::Sd, seed, precision)
                .expect("program compiles");
            let compile_s = t0.elapsed().as_secs_f64();
            let bytes = program.to_artifact_bytes().expect("program serializes");

            let (copy, load_copy_s) = timed_load(&bytes, LoadMode::Copy);
            let (zc, load_zerocopy_s) = timed_load(&bytes, LoadMode::ZeroCopy);
            // bit-identity gate: a loaded program must re-serialize to the
            // exact artifact it came from, in both modes
            assert_eq!(
                copy.to_artifact_bytes().expect("reload serializes"),
                bytes,
                "{label}: copy-mode reload is not bit-identical"
            );
            assert_eq!(
                zc.to_artifact_bytes().expect("reload serializes"),
                bytes,
                "{label}: zero-copy reload is not bit-identical"
            );

            let ratio = load_zerocopy_s / compile_s;
            worst_load_ratio = worst_load_ratio.max(ratio);
            println!(
                "artifact {label:<12} {:>7.1} MB  compile {:>8.1}ms  load(copy) {:>7.2}ms  \
                 load(0copy) {:>7.2}ms  ratio {:.3}",
                bytes.len() as f64 / 1e6,
                compile_s * 1e3,
                load_copy_s * 1e3,
                load_zerocopy_s * 1e3,
                ratio
            );
            sink.record_fields(
                &format!("artifact {label}"),
                &[
                    ("compile_s", compile_s),
                    ("load_copy_s", load_copy_s),
                    ("load_zerocopy_s", load_zerocopy_s),
                    ("artifact_mb", bytes.len() as f64 / 1e6),
                    ("load_ratio", ratio),
                ],
            );
        }
    }

    harness::section("summary");
    let pass = worst_per_call > 1.0;
    println!(
        "worst plan-cached speedup: {worst_per_call:.2}x vs per-call interpreter \
         (acceptance: > 1x on every network) {}",
        if pass { "PASS" } else { "FAIL" }
    );
    println!(
        "worst plan-cached speedup vs weight-cached interpreter: {worst_interp:.2}x {}",
        if worst_interp > 1.0 { "PASS" } else { "(informational)" }
    );
    println!(
        "worst int8-vs-f32 plan ratio: {worst_int8:.2}x {}",
        if worst_int8 > 1.0 { "PASS" } else { "(informational; gated at GEMM level in hotpath)" }
    );
    let load_pass = worst_load_ratio < 0.10;
    println!(
        "worst artifact load/compile ratio: {worst_load_ratio:.3} \
         (acceptance: zero-copy load < 10% of compile on every net/precision) {}",
        if load_pass { "PASS" } else { "FAIL" }
    );
    sink.write("engine");
    if !pass || !load_pass {
        // real gate: a FAIL is a nonzero exit, visible to CI and scripts
        std::process::exit(1);
    }
}
