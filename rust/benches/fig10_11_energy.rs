//! Bench target: Figures 10 and 11 — energy of the deconvolutional layers
//! on both simulated processors, with the PE/buffer/DRAM breakdown that
//! drives the paper's Section 5.2.3 analysis.

#[path = "harness.rs"]
mod harness;

use split_deconv::report;
use split_deconv::sim::energy::EnergyModel;
use split_deconv::util;

fn main() {
    harness::section("Figure 10: energy, dot-production PE array");
    let f10 = report::fig10(42).expect("fig10");
    report::print_energy_figure("", &f10);

    harness::section("Figure 11: energy, regular 2D PE array");
    let f11 = report::fig11(42).expect("fig11");
    report::print_energy_figure("", &f11);

    let m = EnergyModel::default();
    let mut reductions = Vec::new();
    for row in &f11 {
        let e = row.normalized_energy(&m);
        let wasparse = e.iter().find(|(l, _, _)| *l == "SD-WAsparse").unwrap().2;
        reductions.push(1.0 - wasparse);
    }
    println!(
        "\nSD-WAsparse energy reduction vs NZP: avg {:.1}% (paper band 27.7%-54.5%), per-net {:?}",
        100.0 * (reductions.iter().sum::<f64>() / reductions.len() as f64),
        reductions
            .iter()
            .map(|r| format!("{:.0}%", r * 100.0))
            .collect::<Vec<_>>()
    );

    // FCN-vs-SD energy (paper: FCN higher on all benchmarks)
    harness::section("FCN-Engine vs SD-WAsparse energy");
    for row in &f11 {
        let e = row.normalized_energy(&m);
        let sd = e.iter().find(|(l, _, _)| *l == "SD-WAsparse").unwrap().2;
        let fcn = e.iter().find(|(l, _, _)| *l == "FCN").unwrap().2;
        println!(
            "{:<10} SD-WAsparse {:.2}  FCN {:.2}  (FCN/SD = {:.2}x)",
            row.name,
            sd,
            fcn,
            fcn / sd
        );
    }

    harness::section("Generation cost");
    harness::bench("fig10+fig11 full regeneration", 3, || {
        let _ = report::fig10(42).expect("fig10");
        let _ = report::fig11(42).expect("fig11");
    });
    let _ = util::geomean(&reductions); // keep util linked
}
