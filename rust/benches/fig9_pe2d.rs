//! Bench target: Figure 9 — deconvolutional layers on the regular 2D PE
//! array (NZP / SD-Asparse / SD-Wsparse / SD-WAsparse / FCN-Engine), plus
//! the sparse-policy ablation the paper discusses (22% Wsparse->WAsparse
//! redundancy reduction; 75-80% for expansion workloads).

#[path = "harness.rs"]
mod harness;

use split_deconv::report;
use split_deconv::sim::workload::{lower_network_deconvs, Lowering};
use split_deconv::sim::{pe2d, ProcessorConfig, SkipPolicy};
use split_deconv::{networks, util};

fn main() {
    harness::section("Figure 9: regular 2D PE array (normalized to NZP)");
    let rows = report::fig9(42).expect("fig9 lowering");
    report::print_sim_figure("", &rows);
    let wasparse: Vec<f64> = rows
        .iter()
        .map(|r| {
            r.normalized_perf()
                .iter()
                .find(|(l, _)| *l == "SD-WAsparse")
                .unwrap()
                .1
        })
        .collect();
    println!(
        "SD-WAsparse average speedup over NZP: {:.2}x (paper band: 2.41x-4.34x)",
        util::geomean(&wasparse)
    );

    harness::section("Ablation: what each skip policy buys on SD");
    let cfg = ProcessorConfig::default();
    for net in networks::all() {
        let ops = lower_network_deconvs(&net, Lowering::Sd, 42).expect("SD lowering");
        let dense = pe2d::simulate(&ops, &cfg, SkipPolicy::None).cycles as f64;
        let a = pe2d::simulate(&ops, &cfg, SkipPolicy::ASparse).cycles as f64;
        let w = pe2d::simulate(&ops, &cfg, SkipPolicy::WSparse).cycles as f64;
        let aw = pe2d::simulate(&ops, &cfg, SkipPolicy::AWSparse).cycles as f64;
        println!(
            "{:<10} Asparse -{:.0}%  Wsparse -{:.0}%  WAsparse -{:.0}%  (Wsparse->WAsparse -{:.0}%)",
            net.name,
            100.0 * (1.0 - a / dense),
            100.0 * (1.0 - w / dense),
            100.0 * (1.0 - aw / dense),
            100.0 * (1.0 - aw / w),
        );
    }

    harness::section("Simulator throughput");
    let net = networks::mde();
    let ops = lower_network_deconvs(&net, Lowering::Sd, 42).expect("SD lowering");
    let macs: u64 = ops.iter().map(|o| o.dense_macs()).sum();
    let r = harness::bench("simulate MDE SD deconvs (2D array, WAsparse)", 5, || {
        let _ = pe2d::simulate(&ops, &cfg, SkipPolicy::AWSparse);
    });
    println!(
        "simulated-MAC throughput: {:.0} MMAC/s",
        macs as f64 / r.min_s / 1e6
    );
}
