//! Bench target: hot-path microbenchmarks for the section-Perf optimization
//! pass — the rust conv core (SIMD-vs-scalar microkernel gate, GFLOP/s and
//! packing-time columns, int8-vs-f32 gate), the SD transform pipeline, the
//! interleave (stride-write) step, the simulators' counting loops, and
//! (when artifacts exist) the serving path end-to-end. CI publishes the
//! `--json` rows as BENCH_hotpath.json at the repo root.

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use split_deconv::coordinator::{Server, ServerConfig};
use split_deconv::quant::{
    absmax, conv2d_i8_into, pack_sd_splits, quantize_into, scale_for_absmax, Epilogue, QPackedB,
    QTensor,
};
use split_deconv::runtime::{artifacts_available, default_artifact_dir};
use split_deconv::sd::{interleave, sd_deconv2d, split_filters, SdGeometry};
use split_deconv::sim::workload::{lower_network_deconvs, Lowering};
use split_deconv::sim::{dot_array, pe2d, ProcessorConfig, SkipPolicy};
use split_deconv::tensor::{
    active_backend, conv2d_naive, conv2d_valid, conv2d_valid_into, deconv2d, force_backend, relu,
    Filter, GemmBackend, PackedB, Tensor,
};
use split_deconv::util::rng::Rng;
use split_deconv::networks;

fn main() {
    let mut sink = harness::JsonSink::from_args();
    let mut rng = Rng::new(1);

    harness::section("tensor conv core (the quality-eval hot loop)");
    let x = Tensor::randn(1, 34, 34, 128, &mut rng);
    let f = Filter::randn(3, 3, 128, 64, &mut rng);
    let macs = (32 * 32 * 9 * 128 * 64) as f64;
    let r = harness::bench("conv2d_valid 32x32x128 -> 64 k3", 10, || {
        let _ = conv2d_valid(&x, &f, 1);
    });
    println!("  -> {:.2} GMAC/s", macs / r.min_s / 1e9);
    sink.record(&r);

    harness::section("GEMM microkernel: SIMD vs retained scalar kernel (paper layer shapes)");
    // The stride-1 split convolutions each SD-lowered deconv layer actually
    // executes: DCGAN (k5 s2 -> K_T=3 splits) and FST (k3 s2 -> K_T=2).
    // Columns per shape: naive oracle, plan-time packing cost, scalar
    // kernel GFLOP/s, SIMD kernel GFLOP/s + speedup. Gate (the PR-5
    // acceptance bar, enforced with a nonzero exit like the int8 gate
    // below, one retry for scheduler noise): SIMD >= 2x scalar on every
    // shape when AVX2+FMA is available.
    let simd_available = active_backend() == GemmBackend::Avx2;
    println!("active GEMM backend: {}", active_backend().label());
    let shapes: &[(&str, usize, usize, usize, usize, usize)] = &[
        ("DCGAN deconv1 split 12x12x256 k3 -> 128", 12, 12, 256, 3, 128),
        ("DCGAN deconv2 split 20x20x128 k3 -> 64", 20, 20, 128, 3, 64),
        ("FST deconv1 split 65x65x128 k2 -> 64", 65, 65, 128, 2, 64),
    ];
    let mut simd_failures: Vec<String> = Vec::new();
    for &(name, h, w, ic, k, oc) in shapes {
        let x = Tensor::randn(1, h, w, ic, &mut rng);
        let f = Filter::randn(k, k, ic, oc, &mut rng);
        let kdim = k * k * ic;
        let (oh, ow) = (h - k + 1, w - k + 1);
        let flops = (2 * oh * ow * kdim * oc) as f64;
        let naive = harness::bench(&format!("naive {name}"), 3, || {
            let _ = conv2d_naive(&x, &f, 1);
        });
        sink.record(&naive);
        // plan-time packing cost (what the engine pays once per weight at
        // Program compile time, and direct callers pay per call)
        let pack = harness::bench(&format!("pack  {name}"), 50, || {
            let _ = PackedB::pack(&f.data, kdim, oc);
        });
        sink.record(&pack);
        force_backend(Some(GemmBackend::Scalar));
        let mut scalar = harness::bench(&format!("scalar {name}"), 10, || {
            let _ = conv2d_valid(&x, &f, 1);
        });
        force_backend(None);
        println!(
            "  -> scalar kernel {0:.2} GFLOP/s; naive-vs-scalar {1:.1}x; packing {2:.3} ms",
            flops / scalar.min_s / 1e9,
            naive.min_s / scalar.min_s,
            pack.min_s * 1e3
        );
        if simd_available {
            force_backend(Some(GemmBackend::Avx2));
            let mut simd = harness::bench(&format!("simd   {name}"), 20, || {
                let _ = conv2d_valid(&x, &f, 1);
            });
            force_backend(None);
            let mut speedup = scalar.min_s / simd.min_s;
            if speedup < 2.0 {
                println!("  gate miss — re-measuring once to rule out scheduler noise");
                force_backend(Some(GemmBackend::Scalar));
                let s2 = harness::bench(&format!("scalar {name} (retry)"), 10, || {
                    let _ = conv2d_valid(&x, &f, 1);
                });
                force_backend(Some(GemmBackend::Avx2));
                let v2 = harness::bench(&format!("simd   {name} (retry)"), 20, || {
                    let _ = conv2d_valid(&x, &f, 1);
                });
                force_backend(None);
                speedup = s2.min_s / v2.min_s;
                // the retried pair replaces the noisy one everywhere:
                // gate, printed ratio, AND the published JSON rows, so
                // BENCH_hotpath.json can never contradict the exit code
                scalar = s2;
                simd = v2;
            }
            sink.record_gflops(&scalar, flops / scalar.min_s / 1e9);
            let simd_gflops = flops / simd.min_s / 1e9;
            sink.record_speedup_gflops(&scalar, &simd, simd_gflops);
            println!(
                "  -> SIMD kernel {simd_gflops:.2} GFLOP/s; SIMD-vs-scalar {speedup:.2}x"
            );
            if speedup < 2.0 {
                simd_failures.push(format!(
                    "{name}: SIMD {speedup:.2}x of scalar (gate: >= 2x)"
                ));
            }
        } else {
            sink.record_gflops(&scalar, flops / scalar.min_s / 1e9);
        }
    }
    if simd_available {
        println!(
            "SIMD-vs-scalar GEMM gate (>= 2x on DCGAN + FST SD layers): {}",
            if simd_failures.is_empty() { "PASS" } else { "FAIL" }
        );
        for f in &simd_failures {
            println!("FAIL: {f}");
        }
    } else {
        println!("SIMD-vs-scalar GEMM gate: SKIP (no AVX2+FMA on this machine)");
    }

    harness::section("int8 GEMM vs f32 GEMM (quantized SD layers, DCGAN + FST)");
    // The engine's real quantized workload per SD deconv layer: the s^2
    // pre-split sub-filters run stride-1 over the padded (ReLU-zero-rich)
    // input. The f32 side runs the f32 splits through conv2d_valid, the
    // int8 side quantizes the input and runs the packed int8 splits
    // (structural-zero rows skipped — the Wsparse edge). Both sides run
    // their SIMD microkernels where available. Gate: int8 beats f32 on
    // every one of these layers (one re-measure to absorb scheduler
    // noise), enforced with a nonzero exit code; rows land in the --json
    // output (CI publishes BENCH_hotpath.json).
    let i8_layers: &[(&str, usize, usize, usize, usize)] = &[
        // (label, input side, ic, k, oc) — deconv stride 2 throughout
        ("DCGAN deconv1 8x8x256 k5 -> 128", 8, 256, 5, 128),
        ("DCGAN deconv2 16x16x128 k5 -> 64", 16, 128, 5, 64),
        ("FST deconv1 64x64x128 k3 -> 64", 64, 128, 3, 64),
    ];
    let mut i8_failures: Vec<String> = Vec::new();
    for &(name, side, ic, k, oc) in i8_layers {
        let g = SdGeometry::new(k, 2, k / 2);
        let mut x = Tensor::randn(1, side, side, ic, &mut rng);
        relu(&mut x); // post-ReLU zeros, as the engine sees mid-network
        let xp = x.pad(g.p_i, g.p_i, g.p_i, g.p_i);
        let f = Filter::randn(k, k, ic, oc, &mut rng);
        let f32_splits = split_filters(&f, 2);
        let i8_splits = pack_sd_splits(&f, 2);
        // plan-time int8 packing cost (pair-interleave + structural-zero
        // compression of every split, what the int8 engine pays at compile)
        let qpack = harness::bench(&format!("pack  int8 splits {name}"), 50, || {
            for qf in &i8_splits {
                let _ = QPackedB::pack(qf);
            }
        });
        sink.record(&qpack);
        let in_scale = scale_for_absmax(absmax(&xp.data));
        let mut out = Tensor::zeros(0, 0, 0, 0);
        let mut qx = QTensor::empty();
        let run_gate = |f32r: &harness::BenchResult, i8r: &harness::BenchResult| {
            f32r.min_s / i8r.min_s
        };
        let mut f32r = harness::bench(&format!("f32  splits {name}"), 10, || {
            for w in &f32_splits {
                conv2d_valid_into(&xp, w, 1, &mut out);
            }
        });
        let mut i8r = harness::bench(&format!("int8 splits {name}"), 10, || {
            quantize_into(&xp, in_scale, &mut qx);
            for w in &i8_splits {
                conv2d_i8_into(&qx, w, 1, Epilogue::none(), &mut out);
            }
        });
        let mut speedup = run_gate(&f32r, &i8r);
        println!("  -> int8-vs-f32 GEMM speedup: {speedup:.2}x");
        if speedup <= 1.0 {
            println!("  gate miss — re-measuring once to rule out scheduler noise");
            f32r = harness::bench(&format!("f32  splits {name} (retry)"), 10, || {
                for w in &f32_splits {
                    conv2d_valid_into(&xp, w, 1, &mut out);
                }
            });
            i8r = harness::bench(&format!("int8 splits {name} (retry)"), 10, || {
                quantize_into(&xp, in_scale, &mut qx);
                for w in &i8_splits {
                    conv2d_i8_into(&qx, w, 1, Epilogue::none(), &mut out);
                }
            });
            speedup = run_gate(&f32r, &i8r);
            println!("  -> retry: int8-vs-f32 GEMM speedup: {speedup:.2}x");
        }
        sink.record(&f32r);
        sink.record_speedup(&f32r, &i8r);
        if speedup <= 1.0 {
            i8_failures.push(format!("{name}: int8 GEMM {speedup:.2}x of f32 (needs > 1x)"));
        }
    }
    println!(
        "int8-vs-f32 GEMM gate (int8 > f32 on DCGAN + FST SD layers): {}",
        if i8_failures.is_empty() { "PASS" } else { "FAIL" }
    );
    for f in &i8_failures {
        println!("FAIL: {f}");
    }

    harness::section("SD transform pipeline vs direct deconv (DCGAN deconv2)");
    let x = Tensor::randn(1, 16, 16, 128, &mut rng);
    let w = Filter::randn(5, 5, 128, 64, &mut rng);
    harness::bench("direct deconv2d k5 s2", 10, || {
        let _ = deconv2d(&x, &w, 2, 2, 1);
    });
    harness::bench("sd_deconv2d k5 s2 (split+4conv+interleave)", 10, || {
        let _ = sd_deconv2d(&x, &w, 2, 2, 1);
    });
    harness::bench("split_filters k5 s2", 100, || {
        let _ = split_filters(&w, 2);
    });
    let convs: Vec<Tensor> = (0..4).map(|_| Tensor::randn(1, 17, 17, 64, &mut rng)).collect();
    harness::bench("interleave (stride-write) 4x17x17x64", 200, || {
        let _ = interleave(&convs, 2);
    });

    harness::section("simulator counting loops");
    let cfg = ProcessorConfig::default();
    let ops_sd = lower_network_deconvs(&networks::fst(), Lowering::Sd, 42).expect("SD lowering");
    let ops_nzp =
        lower_network_deconvs(&networks::fst(), Lowering::Nzp, 42).expect("NZP lowering");
    harness::bench("pe2d FST SD WAsparse", 5, || {
        let _ = pe2d::simulate(&ops_sd, &cfg, SkipPolicy::AWSparse);
    });
    harness::bench("dot_array FST NZP Asparse", 5, || {
        let _ = dot_array::simulate(&ops_nzp, &cfg, SkipPolicy::ASparse);
    });

    harness::section("serving path (CPU-native engine backend, end to end)");
    {
        let server = Server::start_native(
            ServerConfig {
                max_batch: 4,
                batch_timeout: Duration::from_millis(1),
                queue_cap: 256,
                model: "dcgan".to_string(),
                ..ServerConfig::default()
            },
            7,
        )
        .expect("native server");
        let mut zrng = Rng::new(3);
        let serve = harness::bench("serve 8 requests (batched, native DCGAN)", 3, || {
            let rxs: Vec<_> = (0..8)
                .map(|_| server.submit_blocking(zrng.normal_vec(100)).unwrap())
                .collect();
            for rx in rxs {
                let _ = rx.recv().unwrap();
            }
        });
        sink.record(&serve);
        println!("{}", server.metrics().summary());
        server.shutdown();
    }

    if artifacts_available() {
        harness::section("serving path (PJRT DCGAN, end to end)");
        let server = Server::start_pjrt(
            ServerConfig {
                max_batch: 4,
                batch_timeout: Duration::from_millis(1),
                queue_cap: 256,
                model: "dcgan".to_string(),
                ..ServerConfig::default()
            },
            default_artifact_dir(),
            "dcgan_sd".into(),
        )
        .expect("server");
        let mut rng = Rng::new(2);
        harness::bench("serve 16 requests (batched)", 5, || {
            let rxs: Vec<_> = (0..16)
                .map(|_| server.submit_blocking(rng.normal_vec(100)).unwrap())
                .collect();
            for rx in rxs {
                let _ = rx.recv().unwrap();
            }
        });
        println!("{}", server.metrics().summary());
        server.shutdown();
    } else {
        println!("\n(serving bench skipped: run `make artifacts`)");
    }
    sink.write("hotpath");
    if !i8_failures.is_empty() || !simd_failures.is_empty() {
        // real gates: a FAIL is a nonzero exit, visible to CI and scripts
        std::process::exit(1);
    }
}
