//! Bench target: hot-path microbenchmarks for the section-Perf optimization
//! pass — the rust conv core, the SD transform pipeline, the interleave
//! (stride-write) step, the simulators' counting loops, and (when artifacts
//! exist) the serving path end-to-end.

#[path = "harness.rs"]
mod harness;

use std::time::Duration;

use split_deconv::coordinator::{Server, ServerConfig};
use split_deconv::runtime::{artifacts_available, default_artifact_dir};
use split_deconv::sd::{interleave, sd_deconv2d, split_filters};
use split_deconv::sim::workload::{lower_network_deconvs, Lowering};
use split_deconv::sim::{dot_array, pe2d, ProcessorConfig, SkipPolicy};
use split_deconv::tensor::{conv2d_naive, conv2d_valid, deconv2d, Filter, Tensor};
use split_deconv::util::rng::Rng;
use split_deconv::networks;

fn main() {
    let mut sink = harness::JsonSink::from_args();
    let mut rng = Rng::new(1);

    harness::section("tensor conv core (the quality-eval hot loop)");
    let x = Tensor::randn(1, 34, 34, 128, &mut rng);
    let f = Filter::randn(3, 3, 128, 64, &mut rng);
    let macs = (32 * 32 * 9 * 128 * 64) as f64;
    let r = harness::bench("conv2d_valid 32x32x128 -> 64 k3", 10, || {
        let _ = conv2d_valid(&x, &f, 1);
    });
    println!("  -> {:.2} GMAC/s", macs / r.min_s / 1e9);
    sink.record(&r);

    harness::section("GEMM kernel vs retained naive oracle (paper layer shapes)");
    // The stride-1 split convolutions each SD-lowered deconv layer actually
    // executes: DCGAN (k5 s2 -> K_T=3 splits) and FST (k3 s2 -> K_T=2).
    let shapes: &[(&str, usize, usize, usize, usize, usize)] = &[
        ("DCGAN deconv1 split 12x12x256 k3 -> 128", 12, 12, 256, 3, 128),
        ("DCGAN deconv2 split 20x20x128 k3 -> 64", 20, 20, 128, 3, 64),
        ("FST deconv1 split 65x65x128 k2 -> 64", 65, 65, 128, 2, 64),
    ];
    let mut worst = f64::INFINITY;
    for &(name, h, w, ic, k, oc) in shapes {
        let x = Tensor::randn(1, h, w, ic, &mut rng);
        let f = Filter::randn(k, k, ic, oc, &mut rng);
        let naive = harness::bench(&format!("naive {name}"), 3, || {
            let _ = conv2d_naive(&x, &f, 1);
        });
        let gemm = harness::bench(&format!("gemm  {name}"), 20, || {
            let _ = conv2d_valid(&x, &f, 1);
        });
        let speedup = naive.min_s / gemm.min_s;
        worst = worst.min(speedup);
        println!("  -> GEMM speedup over naive: {speedup:.1}x");
        sink.record(&naive);
        sink.record_speedup(&naive, &gemm);
    }
    println!(
        "worst-case GEMM-vs-naive speedup: {worst:.1}x (acceptance target: >= 4x) {}",
        if worst >= 4.0 { "PASS" } else { "FAIL" }
    );

    harness::section("SD transform pipeline vs direct deconv (DCGAN deconv2)");
    let x = Tensor::randn(1, 16, 16, 128, &mut rng);
    let w = Filter::randn(5, 5, 128, 64, &mut rng);
    harness::bench("direct deconv2d k5 s2", 10, || {
        let _ = deconv2d(&x, &w, 2, 2, 1);
    });
    harness::bench("sd_deconv2d k5 s2 (split+4conv+interleave)", 10, || {
        let _ = sd_deconv2d(&x, &w, 2, 2, 1);
    });
    harness::bench("split_filters k5 s2", 100, || {
        let _ = split_filters(&w, 2);
    });
    let convs: Vec<Tensor> = (0..4).map(|_| Tensor::randn(1, 17, 17, 64, &mut rng)).collect();
    harness::bench("interleave (stride-write) 4x17x17x64", 200, || {
        let _ = interleave(&convs, 2);
    });

    harness::section("simulator counting loops");
    let cfg = ProcessorConfig::default();
    let ops_sd = lower_network_deconvs(&networks::fst(), Lowering::Sd, 42).expect("SD lowering");
    let ops_nzp =
        lower_network_deconvs(&networks::fst(), Lowering::Nzp, 42).expect("NZP lowering");
    harness::bench("pe2d FST SD WAsparse", 5, || {
        let _ = pe2d::simulate(&ops_sd, &cfg, SkipPolicy::AWSparse);
    });
    harness::bench("dot_array FST NZP Asparse", 5, || {
        let _ = dot_array::simulate(&ops_nzp, &cfg, SkipPolicy::ASparse);
    });

    harness::section("serving path (CPU-native engine backend, end to end)");
    {
        let server = Server::start_native(
            ServerConfig {
                max_batch: 4,
                batch_timeout: Duration::from_millis(1),
                queue_cap: 256,
                model: "dcgan".to_string(),
                workers: 1,
            },
            7,
        )
        .expect("native server");
        let mut zrng = Rng::new(3);
        let serve = harness::bench("serve 8 requests (batched, native DCGAN)", 3, || {
            let rxs: Vec<_> = (0..8)
                .map(|_| server.submit_blocking(zrng.normal_vec(100)).unwrap())
                .collect();
            for rx in rxs {
                let _ = rx.recv().unwrap();
            }
        });
        sink.record(&serve);
        println!("{}", server.metrics().summary());
        server.shutdown();
    }

    if artifacts_available() {
        harness::section("serving path (PJRT DCGAN, end to end)");
        let server = Server::start_pjrt(
            ServerConfig {
                max_batch: 4,
                batch_timeout: Duration::from_millis(1),
                queue_cap: 256,
                model: "dcgan".to_string(),
                workers: 1,
            },
            default_artifact_dir(),
            "dcgan_sd".into(),
        )
        .expect("server");
        let mut rng = Rng::new(2);
        harness::bench("serve 16 requests (batched)", 5, || {
            let rxs: Vec<_> = (0..16)
                .map(|_| server.submit_blocking(rng.normal_vec(100)).unwrap())
                .collect();
            for rx in rxs {
                let _ = rx.recv().unwrap();
            }
        });
        println!("{}", server.metrics().summary());
        server.shutdown();
    } else {
        println!("\n(serving bench skipped: run `make artifacts`)");
    }
    sink.write("hotpath");
}
