//! The flight recorder: a process-wide, fixed-memory event journal.
//!
//! `Journal` is a pool of lock-free ring buffers. Each emitting thread is
//! lazily assigned a ring (its own while rings are free, hash-shared once
//! the pool is exhausted — the claim protocol stays correct under multiple
//! writers) and appends compact binary events with a wait-free
//! `fetch_add` + field stores + a `Release` sequence publish. Memory is
//! bounded at construction: once a ring laps, the oldest events are
//! overwritten in place — the recorder always holds the most recent
//! window, which is exactly what a post-incident timeline needs.
//!
//! Every event carries a monotonic microsecond timestamp (shared process
//! epoch, see [`monotonic_us`]), the emitting thread's compact id, a lane
//! index, an event kind, and two payload words (`aux`/`arg`/`trace_id`).
//! `snapshot()` is a reader-side scan that validates per-slot sequence
//! numbers, so a concurrent writer can at worst cause a slot to be
//! skipped, never a torn event to be returned. (One theoretical
//! exception: a writer stalled mid-store for a full ring lap can leave
//! one event attributed to the wrong sequence — acceptable for a
//! diagnostic recorder, impossible to hit in practice at 4096-slot
//! rings.)
//!
//! The zero-overhead contract (DESIGN.md §12/§14): the serving stack
//! holds the journal as `Option<Arc<Journal>>` and checks it **before**
//! taking any timestamp. No journal configured ⇒ no clock reads, no
//! atomics, no allocation.
//!
//! On top of the raw event stream:
//! * [`chrome_trace_json`] exports a snapshot as Chrome trace-event JSON
//!   (loadable in Perfetto / `chrome://tracing`): one track per thread,
//!   one per lane, duration slices for batch-form/compute/engine stages,
//!   flow arrows admission→respond keyed by trace id, and a queue-depth
//!   counter track.
//! * [`validate_chrome_trace`] is the schema check CI runs on captured
//!   traces (valid JSON, monotone `ts` per track, every flow id
//!   resolves).

use crate::util::json::{self, Json};
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// The shared monotonic epoch: first call wins, everything in the
/// process (journal timestamps, `obs::log` `ts_us` prefixes) measures
/// from it. `main` touches it on startup so "since process start" is
/// accurate, but any first caller anchors it correctly for tests.
pub fn process_epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Microseconds since [`process_epoch`]. Monotonic, process-wide.
pub fn monotonic_us() -> u64 {
    process_epoch().elapsed().as_micros() as u64
}

/// Lane value for events that are not tied to a model lane.
pub const NO_LANE: u16 = u16::MAX;

/// What happened. Kept to one byte in the packed slot word.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum EventKind {
    /// Front door accepted a TCP connection.
    Accept = 1,
    /// Front door admitted a generate request into the coordinator.
    Admit = 2,
    /// Request rejected at queue-full (503). `lane` is the target lane.
    Shed = 3,
    /// Front door returned a 4xx/5xx without reaching compute.
    /// `aux` = HTTP status.
    HttpError = 4,
    /// Request enqueued on a lane. `arg` = queue depth after the push,
    /// `trace_id` set.
    Enqueue = 5,
    /// Dispatcher began forming a batch on `lane`.
    BatchFormBegin = 6,
    /// Batch formed. `aux` = batch size, `arg` = form duration (µs).
    BatchFormEnd = 7,
    /// Request dropped before compute: its deadline passed in queue.
    DeadlineExpire = 8,
    /// Batch handed to the executor. `aux` = batch size.
    Dispatch = 9,
    /// Executor returned. `aux` = batch size, `arg` = compute µs.
    ComputeEnd = 10,
    /// Response sent back to the submitter. `arg` = total latency µs,
    /// `trace_id` set.
    Respond = 11,
    /// Request terminated without a response (batch execution error).
    Disconnect = 12,
    /// One engine stage of one layer, from the `StageSink` rows.
    /// `aux` = `layer_idx << 2 | stage` (stage: 0 im2col, 1 gemm,
    /// 2 epilogue, 3 interleave), `arg` = stage µs.
    Stage = 13,
    /// A dispatcher caught a panic out of an executing batch. `aux` = 0
    /// for a contained batch panic, 1 for a quarantining retry panic,
    /// 2 for a dispatcher-loop panic caught by the supervisor.
    WorkerPanic = 14,
    /// The supervised worker rebuilt its executor(s) and resumed; the
    /// pool is back at configured strength.
    WorkerRespawn = 15,
}

impl EventKind {
    pub fn from_u8(v: u8) -> Option<EventKind> {
        use EventKind::*;
        Some(match v {
            1 => Accept,
            2 => Admit,
            3 => Shed,
            4 => HttpError,
            5 => Enqueue,
            6 => BatchFormBegin,
            7 => BatchFormEnd,
            8 => DeadlineExpire,
            9 => Dispatch,
            10 => ComputeEnd,
            11 => Respond,
            12 => Disconnect,
            13 => Stage,
            14 => WorkerPanic,
            15 => WorkerRespawn,
            _ => return None,
        })
    }

    pub fn label(self) -> &'static str {
        use EventKind::*;
        match self {
            Accept => "accept",
            Admit => "admit",
            Shed => "shed",
            HttpError => "http_error",
            Enqueue => "enqueue",
            BatchFormBegin => "batch_form_begin",
            BatchFormEnd => "batch_form_end",
            DeadlineExpire => "deadline_expire",
            Dispatch => "dispatch",
            ComputeEnd => "compute_end",
            Respond => "respond",
            Disconnect => "disconnect",
            Stage => "stage",
            WorkerPanic => "worker_panic",
            WorkerRespawn => "worker_respawn",
        }
    }
}

/// One decoded journal event.
#[derive(Clone, Copy, Debug)]
pub struct Event {
    /// Microseconds since [`process_epoch`].
    pub ts_us: u64,
    pub kind: EventKind,
    /// Compact per-journal thread id (see [`Journal::thread_names`]).
    pub tid: u16,
    /// Lane index, or [`NO_LANE`].
    pub lane: u16,
    /// Kind-specific small payload (batch size, HTTP status, …).
    pub aux: u16,
    /// Kind-specific wide payload (durations in µs, queue depth, …).
    pub arg: u64,
    /// End-to-end request trace id, or 0.
    pub trace_id: u64,
}

/// `kind | tid | lane | aux` packed into one atomic word so a slot is
/// five `AtomicU64` stores and the reader can validate with one load.
fn pack_meta(kind: EventKind, tid: u16, lane: u16, aux: u16) -> u64 {
    (kind as u64) | ((tid as u64) << 8) | ((lane as u64) << 24) | ((aux as u64) << 40)
}

fn unpack_meta(meta: u64) -> Option<(EventKind, u16, u16, u16)> {
    let kind = EventKind::from_u8((meta & 0xff) as u8)?;
    Some((
        kind,
        ((meta >> 8) & 0xffff) as u16,
        ((meta >> 24) & 0xffff) as u16,
        ((meta >> 40) & 0xffff) as u16,
    ))
}

/// One event slot. `seq` is written last with `Release`: a reader that
/// observes `seq == pos + 1` with `Acquire` sees the other four fields
/// of that write.
struct Slot {
    seq: AtomicU64,
    ts: AtomicU64,
    meta: AtomicU64,
    arg: AtomicU64,
    trace: AtomicU64,
}

impl Slot {
    fn empty() -> Slot {
        Slot {
            seq: AtomicU64::new(0),
            ts: AtomicU64::new(0),
            meta: AtomicU64::new(0),
            arg: AtomicU64::new(0),
            trace: AtomicU64::new(0),
        }
    }
}

/// One ring. `head` counts claims forever; slot index is `pos % cap`.
/// The head is padded to a cache line so rings assigned to different
/// threads never false-share their hot counter.
struct Ring {
    head: AtomicU64,
    _pad: [u64; 7],
    slots: Box<[Slot]>,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring {
            head: AtomicU64::new(0),
            _pad: [0; 7],
            slots: (0..cap).map(|_| Slot::empty()).collect(),
        }
    }
}

/// Journal sizing. Defaults hold the last ~128k events in ~5 MB.
#[derive(Clone, Copy, Debug)]
pub struct JournalConfig {
    /// Number of rings in the pool (threads beyond this share).
    pub rings: usize,
    /// Slots per ring; the retained window per thread.
    pub ring_capacity: usize,
}

impl Default for JournalConfig {
    fn default() -> JournalConfig {
        JournalConfig {
            rings: 32,
            ring_capacity: 4096,
        }
    }
}

/// The flight recorder. Construct once, share as `Arc<Journal>`; see
/// the module docs for the writer/reader protocol.
pub struct Journal {
    /// Distinguishes journals so a thread's cached ring assignment from
    /// a dropped journal is never applied to a new one.
    id: u64,
    rings: Vec<Ring>,
    next_tid: AtomicU32,
    names: Mutex<Vec<(u16, String)>>,
}

impl std::fmt::Debug for Journal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Journal")
            .field("rings", &self.rings.len())
            .field("ring_capacity", &self.ring_capacity())
            .field("emitted", &self.emitted())
            .finish()
    }
}

thread_local! {
    /// Per-thread cache of (journal id → (tid, ring index)). A thread
    /// touches at most a couple of journals (production: one), so a
    /// linear scan beats any map.
    static RING_OF: RefCell<Vec<(u64, u16, usize)>> = const { RefCell::new(Vec::new()) };
}

impl Journal {
    pub fn new(cfg: JournalConfig) -> Arc<Journal> {
        static IDS: AtomicU64 = AtomicU64::new(1);
        let rings = cfg.rings.max(1);
        let cap = cfg.ring_capacity.max(8);
        Arc::new(Journal {
            id: IDS.fetch_add(1, Ordering::Relaxed),
            rings: (0..rings).map(|_| Ring::new(cap)).collect(),
            next_tid: AtomicU32::new(0),
            names: Mutex::new(Vec::new()),
        })
    }

    pub fn with_defaults() -> Arc<Journal> {
        Journal::new(JournalConfig::default())
    }

    fn ring_capacity(&self) -> usize {
        self.rings[0].slots.len()
    }

    /// Register the calling thread (first emit does this implicitly).
    /// Returns (tid, ring index).
    fn register(&self) -> (u16, usize) {
        let tid = self.next_tid.fetch_add(1, Ordering::Relaxed).min(0xfffe) as u16;
        let ring = (tid as usize) % self.rings.len();
        let name = std::thread::current()
            .name()
            .map(str::to_string)
            .unwrap_or_else(|| format!("thread-{tid}"));
        self.names.lock().unwrap().push((tid, name));
        (tid, ring)
    }

    /// Append one event. Wait-free on the hot path: a thread-local
    /// lookup, one clock read, one `fetch_add`, five stores.
    pub fn emit(&self, kind: EventKind, lane: u16, aux: u16, arg: u64, trace_id: u64) {
        let (tid, ring_idx) = RING_OF.with(|cache| {
            let mut cache = cache.borrow_mut();
            if let Some(&(_, tid, ring)) = cache.iter().find(|&&(id, _, _)| id == self.id) {
                return (tid, ring);
            }
            let (tid, ring) = self.register();
            cache.push((self.id, tid, ring));
            (tid, ring)
        });
        let ts = monotonic_us();
        let ring = &self.rings[ring_idx];
        let pos = ring.head.fetch_add(1, Ordering::Relaxed);
        let slot = &ring.slots[(pos % ring.slots.len() as u64) as usize];
        slot.ts.store(ts, Ordering::Relaxed);
        slot.meta.store(pack_meta(kind, tid, lane, aux), Ordering::Relaxed);
        slot.arg.store(arg, Ordering::Relaxed);
        slot.trace.store(trace_id, Ordering::Relaxed);
        slot.seq.store(pos + 1, Ordering::Release);
    }

    /// Decode every retained event, sorted by timestamp. Safe against
    /// concurrent writers: slots whose sequence number does not match
    /// the expected position (mid-write or already overwritten) are
    /// skipped.
    pub fn snapshot(&self) -> Vec<Event> {
        let cap = self.ring_capacity() as u64;
        let mut out = Vec::new();
        for ring in &self.rings {
            let head = ring.head.load(Ordering::Acquire);
            let start = head.saturating_sub(cap);
            for pos in start..head {
                let slot = &ring.slots[(pos % cap) as usize];
                if slot.seq.load(Ordering::Acquire) != pos + 1 {
                    continue;
                }
                let ts = slot.ts.load(Ordering::Relaxed);
                let meta = slot.meta.load(Ordering::Relaxed);
                let arg = slot.arg.load(Ordering::Relaxed);
                let trace = slot.trace.load(Ordering::Relaxed);
                // Re-validate: if a writer lapped us mid-read the fields
                // above may be torn — drop the slot.
                if slot.seq.load(Ordering::Acquire) != pos + 1 {
                    continue;
                }
                if let Some((kind, tid, lane, aux)) = unpack_meta(meta) {
                    out.push(Event {
                        ts_us: ts,
                        kind,
                        tid,
                        lane,
                        aux,
                        arg,
                        trace_id: trace,
                    });
                }
            }
        }
        out.sort_by_key(|e| e.ts_us);
        out
    }

    /// Events with `ts_us >= since_us`, sorted by timestamp.
    pub fn snapshot_since(&self, since_us: u64) -> Vec<Event> {
        let mut events = self.snapshot();
        events.retain(|e| e.ts_us >= since_us);
        events
    }

    /// (tid, thread name) for every thread that has emitted.
    pub fn thread_names(&self) -> Vec<(u16, String)> {
        self.names.lock().unwrap().clone()
    }

    /// Total events ever claimed across all rings (including those
    /// already overwritten).
    pub fn emitted(&self) -> u64 {
        self.rings
            .iter()
            .map(|r| r.head.load(Ordering::Relaxed))
            .sum()
    }

    /// Upper bound on retained events (rings × capacity).
    pub fn capacity_events(&self) -> usize {
        self.rings.len() * self.ring_capacity()
    }

    /// Fixed memory footprint of the slot arrays — the O(1)-RSS bound
    /// the wraparound property test asserts against.
    pub fn footprint_bytes(&self) -> usize {
        self.capacity_events() * std::mem::size_of::<Slot>()
            + self.rings.len() * std::mem::size_of::<Ring>()
    }

    /// Rolling busy fraction per worker thread over `[now-window, now]`:
    /// the sum of batch-form and compute slice durations (clipped to the
    /// window) divided by the window. Keyed by journal tid.
    pub fn busy_fractions(&self, window_us: u64, now_us: u64) -> BTreeMap<u16, f64> {
        let start = now_us.saturating_sub(window_us);
        let mut busy: BTreeMap<u16, u64> = BTreeMap::new();
        for e in self.snapshot_since(start.saturating_sub(window_us)) {
            let dur = match e.kind {
                EventKind::ComputeEnd | EventKind::BatchFormEnd => e.arg,
                _ => continue,
            };
            let end = e.ts_us.min(now_us);
            let begin = e.ts_us.saturating_sub(dur).max(start);
            if end > begin {
                *busy.entry(e.tid).or_insert(0) += end - begin;
            }
        }
        busy.iter()
            .map(|(&tid, &us)| (tid, (us as f64 / window_us.max(1) as f64).min(1.0)))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event export (Perfetto / chrome://tracing)
// ---------------------------------------------------------------------------

/// Synthetic track ids for lane tracks (real thread tids are compact
/// small integers, so this base cannot collide).
const LANE_TID_BASE: u64 = 50_000;

const STAGE_NAMES: [&str; 4] = ["im2col", "gemm", "epilogue", "interleave"];

fn obj(fields: Vec<(&str, Json)>) -> Json {
    Json::Obj(
        fields
            .into_iter()
            .map(|(k, v)| (k.to_string(), v))
            .collect::<BTreeMap<String, Json>>(),
    )
}

fn num(n: u64) -> Json {
    Json::Num(n as f64)
}

fn meta_thread_name(tid: u64, name: &str) -> Json {
    obj(vec![
        ("ph", Json::Str("M".into())),
        ("name", Json::Str("thread_name".into())),
        ("pid", num(1)),
        ("tid", num(tid)),
        (
            "args",
            obj(vec![("name", Json::Str(name.to_string()))]),
        ),
    ])
}

fn lane_name(lanes: &[String], lane: u16) -> String {
    lanes
        .get(lane as usize)
        .cloned()
        .unwrap_or_else(|| format!("lane{lane}"))
}

/// Export a journal snapshot as Chrome trace-event JSON.
///
/// * one named track per emitting thread (`threads` from
///   [`Journal::thread_names`]) and per lane (`lanes` = model names in
///   lane order);
/// * `X` duration slices for batch-form, compute, and per-layer engine
///   stages (stages re-timed sequentially from the compute slice start);
/// * `s`/`f` flow arrows from `Enqueue` to `Respond`, emitted only for
///   trace ids with both endpoints in the snapshot so every flow id in
///   the output resolves;
/// * a `C` queue-depth counter per lane, instants for
///   shed/expire/accept/admit/http-error.
pub fn chrome_trace_json(events: &[Event], threads: &[(u16, String)], lanes: &[String]) -> String {
    let mut out: Vec<(u64, Json)> = Vec::with_capacity(events.len() + 16);
    let mut meta: Vec<Json> = Vec::new();

    meta.push(obj(vec![
        ("ph", Json::Str("M".into())),
        ("name", Json::Str("process_name".into())),
        ("pid", num(1)),
        ("tid", num(0)),
        ("args", obj(vec![("name", Json::Str("repro".into()))])),
    ]));
    for (tid, name) in threads {
        meta.push(meta_thread_name(*tid as u64, name));
    }
    let mut lanes_seen: Vec<u16> = events
        .iter()
        .filter(|e| e.lane != NO_LANE)
        .map(|e| e.lane)
        .collect();
    lanes_seen.sort_unstable();
    lanes_seen.dedup();
    for lane in &lanes_seen {
        meta.push(meta_thread_name(
            LANE_TID_BASE + *lane as u64,
            &format!("lane:{}", lane_name(lanes, *lane)),
        ));
    }

    // Flow endpoints: only ids that both enqueued and responded resolve.
    let mut enq: BTreeMap<u64, (u64, u16)> = BTreeMap::new();
    let mut rsp: BTreeMap<u64, (u64, u16)> = BTreeMap::new();
    for e in events {
        if e.trace_id == 0 {
            continue;
        }
        match e.kind {
            EventKind::Enqueue => {
                enq.entry(e.trace_id).or_insert((e.ts_us, e.tid));
            }
            EventKind::Respond => {
                rsp.entry(e.trace_id).or_insert((e.ts_us, e.tid));
            }
            _ => {}
        }
    }

    // Stage slices are journaled after their ComputeEnd; re-time them
    // sequentially from the owning compute slice's start, per thread.
    let mut stage_cursor: BTreeMap<u16, u64> = BTreeMap::new();

    for e in events {
        let tid = e.tid as u64;
        let lane_tid = LANE_TID_BASE + e.lane as u64;
        let lname = lane_name(lanes, e.lane);
        match e.kind {
            EventKind::Accept | EventKind::Admit => {
                out.push((
                    e.ts_us,
                    obj(vec![
                        ("ph", Json::Str("i".into())),
                        ("s", Json::Str("t".into())),
                        ("name", Json::Str(e.kind.label().into())),
                        ("cat", Json::Str("frontdoor".into())),
                        ("pid", num(1)),
                        ("tid", num(tid)),
                        ("ts", num(e.ts_us)),
                    ]),
                ));
            }
            EventKind::HttpError => {
                out.push((
                    e.ts_us,
                    obj(vec![
                        ("ph", Json::Str("i".into())),
                        ("s", Json::Str("t".into())),
                        ("name", Json::Str(format!("http {}", e.aux))),
                        ("cat", Json::Str("frontdoor".into())),
                        ("pid", num(1)),
                        ("tid", num(tid)),
                        ("ts", num(e.ts_us)),
                    ]),
                ));
            }
            EventKind::Shed | EventKind::DeadlineExpire => {
                out.push((
                    e.ts_us,
                    obj(vec![
                        ("ph", Json::Str("i".into())),
                        ("s", Json::Str("t".into())),
                        ("name", Json::Str(e.kind.label().into())),
                        ("cat", Json::Str("lane".into())),
                        ("pid", num(1)),
                        ("tid", num(lane_tid)),
                        ("ts", num(e.ts_us)),
                    ]),
                ));
            }
            EventKind::Enqueue => {
                out.push((
                    e.ts_us,
                    obj(vec![
                        ("ph", Json::Str("C".into())),
                        ("name", Json::Str(format!("queue_depth:{lname}"))),
                        ("pid", num(1)),
                        ("tid", num(0)),
                        ("ts", num(e.ts_us)),
                        ("args", obj(vec![("depth", num(e.arg))])),
                    ]),
                ));
                if let (Some(_), Some(_)) = (enq.get(&e.trace_id), rsp.get(&e.trace_id)) {
                    out.push((
                        e.ts_us,
                        obj(vec![
                            ("ph", Json::Str("s".into())),
                            ("name", Json::Str("request".into())),
                            ("cat", Json::Str("flow".into())),
                            ("id", num(e.trace_id)),
                            ("pid", num(1)),
                            ("tid", num(tid)),
                            ("ts", num(e.ts_us)),
                        ]),
                    ));
                }
            }
            EventKind::BatchFormBegin | EventKind::Dispatch => {
                // Subsumed by the duration slices below; skip.
            }
            EventKind::BatchFormEnd => {
                out.push((
                    e.ts_us.saturating_sub(e.arg),
                    obj(vec![
                        ("ph", Json::Str("X".into())),
                        ("name", Json::Str(format!("batch_form {lname}"))),
                        ("cat", Json::Str("coordinator".into())),
                        ("pid", num(1)),
                        ("tid", num(tid)),
                        ("ts", num(e.ts_us.saturating_sub(e.arg))),
                        ("dur", num(e.arg.max(1))),
                        ("args", obj(vec![("batch", num(e.aux as u64))])),
                    ]),
                ));
            }
            EventKind::ComputeEnd => {
                let start = e.ts_us.saturating_sub(e.arg);
                stage_cursor.insert(e.tid, start);
                out.push((
                    start,
                    obj(vec![
                        ("ph", Json::Str("X".into())),
                        ("name", Json::Str(format!("compute {lname}"))),
                        ("cat", Json::Str("coordinator".into())),
                        ("pid", num(1)),
                        ("tid", num(tid)),
                        ("ts", num(start)),
                        ("dur", num(e.arg.max(1))),
                        ("args", obj(vec![("batch", num(e.aux as u64))])),
                    ]),
                ));
                // Mirror the batch on the lane track so a lane's whole
                // history reads top to bottom on one track.
                out.push((
                    start,
                    obj(vec![
                        ("ph", Json::Str("X".into())),
                        ("name", Json::Str(format!("batch n={}", e.aux))),
                        ("cat", Json::Str("lane".into())),
                        ("pid", num(1)),
                        ("tid", num(lane_tid)),
                        ("ts", num(start)),
                        ("dur", num(e.arg.max(1))),
                    ]),
                ));
            }
            EventKind::Stage => {
                let cursor = stage_cursor.entry(e.tid).or_insert(e.ts_us);
                let layer = e.aux >> 2;
                let stage = STAGE_NAMES[(e.aux & 3) as usize];
                if e.arg > 0 {
                    out.push((
                        *cursor,
                        obj(vec![
                            ("ph", Json::Str("X".into())),
                            ("name", Json::Str(format!("L{layer} {stage}"))),
                            ("cat", Json::Str("stage".into())),
                            ("pid", num(1)),
                            ("tid", num(tid)),
                            ("ts", num(*cursor)),
                            ("dur", num(e.arg)),
                        ]),
                    ));
                    *cursor += e.arg;
                }
            }
            EventKind::Respond => {
                out.push((
                    e.ts_us,
                    obj(vec![
                        ("ph", Json::Str("X".into())),
                        ("name", Json::Str("respond".into())),
                        ("cat", Json::Str("coordinator".into())),
                        ("pid", num(1)),
                        ("tid", num(tid)),
                        ("ts", num(e.ts_us)),
                        ("dur", num(1)),
                        ("args", obj(vec![("total_us", num(e.arg))])),
                    ]),
                ));
                if let (Some(_), Some(_)) = (enq.get(&e.trace_id), rsp.get(&e.trace_id)) {
                    out.push((
                        e.ts_us,
                        obj(vec![
                            ("ph", Json::Str("f".into())),
                            ("bp", Json::Str("e".into())),
                            ("name", Json::Str("request".into())),
                            ("cat", Json::Str("flow".into())),
                            ("id", num(e.trace_id)),
                            ("pid", num(1)),
                            ("tid", num(tid)),
                            ("ts", num(e.ts_us)),
                        ]),
                    ));
                }
            }
            EventKind::Disconnect | EventKind::WorkerPanic | EventKind::WorkerRespawn => {
                out.push((
                    e.ts_us,
                    obj(vec![
                        ("ph", Json::Str("i".into())),
                        ("s", Json::Str("t".into())),
                        ("name", Json::Str(e.kind.label().into())),
                        ("cat", Json::Str("coordinator".into())),
                        ("pid", num(1)),
                        ("tid", num(tid)),
                        ("ts", num(e.ts_us)),
                    ]),
                ));
            }
        }
    }

    // Global ts sort ⇒ per-track monotone ts, the schema invariant.
    out.sort_by_key(|(ts, _)| *ts);
    let mut all = meta;
    all.extend(out.into_iter().map(|(_, j)| j));
    obj(vec![
        ("displayTimeUnit", Json::Str("ms".into())),
        ("traceEvents", Json::Arr(all)),
    ])
    .encode()
}

/// Stats returned by a successful [`validate_chrome_trace`].
#[derive(Clone, Copy, Debug, Default)]
pub struct TraceStats {
    pub events: usize,
    pub tracks: usize,
    pub flows: usize,
}

/// The Perfetto schema check: `json` must parse, hold a `traceEvents`
/// array, every non-metadata event must carry numeric `ts` (and `dur`
/// for `X`), `ts` must be monotone non-decreasing per `(pid, tid)`
/// track in array order, and every flow start (`s`) id must have a
/// matching finish (`f`) and vice versa.
pub fn validate_chrome_trace(src: &str) -> Result<TraceStats, String> {
    let root = json::parse(src).map_err(|e| format!("invalid JSON: {e:?}"))?;
    let events = root
        .get("traceEvents")
        .and_then(|v| v.as_arr())
        .ok_or("missing traceEvents array")?;
    let mut last_ts: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    let mut starts: BTreeMap<u64, usize> = BTreeMap::new();
    let mut finishes: BTreeMap<u64, usize> = BTreeMap::new();
    let mut stats = TraceStats {
        events: events.len(),
        ..TraceStats::default()
    };
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(|v| v.as_str())
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        if ph == "M" {
            continue;
        }
        let ts = ev
            .get("ts")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| format!("event {i}: missing numeric ts"))?;
        let pid = ev.get("pid").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        let tid = ev.get("tid").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64;
        if let Some(&prev) = last_ts.get(&(pid, tid)) {
            if ts < prev {
                return Err(format!(
                    "event {i}: ts {ts} < {prev} on track ({pid},{tid}) — not monotone"
                ));
            }
        }
        last_ts.insert((pid, tid), ts);
        match ph {
            "X" => {
                ev.get("dur")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("event {i}: X without numeric dur"))?;
            }
            "s" | "f" => {
                let id = ev
                    .get("id")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| format!("event {i}: flow without id"))? as u64;
                let m = if ph == "s" { &mut starts } else { &mut finishes };
                *m.entry(id).or_insert(0) += 1;
            }
            _ => {}
        }
    }
    for id in starts.keys() {
        if !finishes.contains_key(id) {
            return Err(format!("flow id {id} starts but never finishes"));
        }
    }
    for id in finishes.keys() {
        if !starts.contains_key(id) {
            return Err(format!("flow id {id} finishes but never starts"));
        }
    }
    stats.tracks = last_ts.len();
    stats.flows = starts.len();
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn emit_snapshot_round_trip() {
        let j = Journal::new(JournalConfig {
            rings: 2,
            ring_capacity: 64,
        });
        j.emit(EventKind::Enqueue, 1, 0, 3, 42);
        j.emit(EventKind::ComputeEnd, 1, 4, 1500, 0);
        let events = j.snapshot();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].kind, EventKind::Enqueue);
        assert_eq!(events[0].lane, 1);
        assert_eq!(events[0].arg, 3);
        assert_eq!(events[0].trace_id, 42);
        assert_eq!(events[1].kind, EventKind::ComputeEnd);
        assert_eq!(events[1].aux, 4);
        assert!(events[1].ts_us >= events[0].ts_us, "sorted by ts");
        assert_eq!(j.emitted(), 2);
        let names = j.thread_names();
        assert_eq!(names.len(), 1, "one emitting thread registered once");
    }

    #[test]
    fn wraparound_keeps_only_the_latest_window() {
        let j = Journal::new(JournalConfig {
            rings: 1,
            ring_capacity: 16,
        });
        for i in 0..100u64 {
            j.emit(EventKind::Admit, NO_LANE, 0, i, 0);
        }
        let events = j.snapshot();
        assert_eq!(events.len(), 16, "ring retains exactly its capacity");
        let args: Vec<u64> = events.iter().map(|e| e.arg).collect();
        assert_eq!(args, (84..100).collect::<Vec<u64>>(), "latest events win");
        assert_eq!(j.emitted(), 100);
    }

    #[test]
    fn meta_packing_round_trips() {
        let m = pack_meta(EventKind::Stage, 513, 7, (12 << 2) | 1);
        let (kind, tid, lane, aux) = unpack_meta(m).unwrap();
        assert_eq!(kind, EventKind::Stage);
        assert_eq!(tid, 513);
        assert_eq!(lane, 7);
        assert_eq!(aux >> 2, 12);
        assert_eq!(aux & 3, 1);
        assert!(unpack_meta(0).is_none(), "kind 0 is invalid");
    }

    #[test]
    fn chrome_export_validates_and_flows_resolve() {
        let j = Journal::new(JournalConfig {
            rings: 1,
            ring_capacity: 64,
        });
        // A request that completes (id 7) and one that only enqueued
        // (id 9, still in flight at snapshot time): only id 7 may
        // produce flow events.
        j.emit(EventKind::Accept, NO_LANE, 0, 0, 0);
        j.emit(EventKind::Enqueue, 0, 0, 1, 7);
        j.emit(EventKind::Enqueue, 0, 0, 2, 9);
        j.emit(EventKind::BatchFormEnd, 0, 1, 5, 7);
        j.emit(EventKind::ComputeEnd, 0, 1, 900, 0);
        j.emit(EventKind::Stage, 0, 1, 600, 0); // layer 0, stage 1 = gemm
        j.emit(EventKind::Respond, 0, 0, 950, 7);
        let json = chrome_trace_json(&j.snapshot(), &j.thread_names(), &["dcgan".to_string()]);
        let stats = validate_chrome_trace(&json).expect("export passes its own schema check");
        assert!(stats.events > 5);
        assert_eq!(stats.flows, 1, "only the completed request flows");
        assert!(json.contains("lane:dcgan"));
        assert!(json.contains("L0 gemm"));
    }

    #[test]
    fn validator_rejects_broken_traces() {
        assert!(validate_chrome_trace("not json").is_err());
        assert!(validate_chrome_trace("{}").is_err());
        let non_monotone = r#"{"traceEvents":[
            {"ph":"X","name":"a","pid":1,"tid":1,"ts":10,"dur":1},
            {"ph":"X","name":"b","pid":1,"tid":1,"ts":5,"dur":1}]}"#;
        assert!(validate_chrome_trace(non_monotone)
            .unwrap_err()
            .contains("not monotone"));
        let dangling_flow = r#"{"traceEvents":[
            {"ph":"s","name":"r","id":3,"pid":1,"tid":1,"ts":1}]}"#;
        assert!(validate_chrome_trace(dangling_flow)
            .unwrap_err()
            .contains("never finishes"));
        let no_dur = r#"{"traceEvents":[{"ph":"X","name":"a","pid":1,"tid":1,"ts":1}]}"#;
        assert!(validate_chrome_trace(no_dur).unwrap_err().contains("dur"));
    }

    #[test]
    fn busy_fraction_clips_to_window() {
        let j = Journal::new(JournalConfig {
            rings: 1,
            ring_capacity: 16,
        });
        // One 1000us compute slice ending "now".
        j.emit(EventKind::ComputeEnd, 0, 1, 1000, 0);
        let now = j.snapshot()[0].ts_us;
        let busy = j.busy_fractions(2000, now);
        let f = *busy.values().next().unwrap();
        assert!((0.45..=0.55).contains(&f), "1000us of a 2000us window: {f}");
        // Window smaller than the slice: clipped, never > 1.
        let busy = j.busy_fractions(500, now);
        assert!(*busy.values().next().unwrap() <= 1.0);
    }
}
