//! Lock-free, fixed-footprint, log-scaled latency histograms.
//!
//! [`Histogram`] replaces the coordinator's old `latencies_us: Vec<u64>`
//! (unbounded growth + O(n log n) sort under the metrics mutex) with a
//! fixed array of atomic counters over geometrically spaced buckets:
//!
//! * bucket upper bounds grow by a factor of ~[`GROWTH`] (1.25) starting
//!   at 1us, covering at least 1us..=60s ([`MAX_TRACKED_US`]) before a
//!   final `+Inf` overflow bucket;
//! * [`Histogram::record`] is wait-free: one binary search over the
//!   static bound table plus three `Relaxed` `fetch_add`s — no lock, no
//!   allocation, O(1) memory forever;
//! * [`HistogramSnapshot`]s are plain bucket-count vectors: they merge
//!   exactly (bucket-wise addition over the shared bound table), and
//!   quantile queries return **exact bounds**, not estimates — see
//!   [`HistogramSnapshot::quantile_bounds`].
//!
//! # Quantile error bound
//!
//! For any quantile `q`, the true order statistic `t` lies in
//! `(lo, hi]` where `(lo, hi)` are the adjacent bucket bounds returned
//! by [`HistogramSnapshot::quantile_bounds`]. Reporting `hi` therefore
//! overestimates by at most one bucket width: since `hi <= ceil(lo *
//! 1.25) + 1`, the relative error is bounded by the bucket growth
//! factor, i.e. `hi <= t * 1.25 + 1us`. That is the documented contract
//! for the `p50_us`/`p95_us`/`p99_us` fields in the coordinator's
//! metrics snapshot.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

/// Geometric growth factor between consecutive bucket upper bounds.
pub const GROWTH: f64 = 1.25;

/// Smallest bucket upper bound, in microseconds.
pub const MIN_TRACKED_US: u64 = 1;

/// The bound table is guaranteed to reach at least this far (60s).
pub const MAX_TRACKED_US: u64 = 60_000_000;

/// Number of finite buckets. 96 geometric steps of 1.25 from 1us reach
/// ~2.1e9us (~35min), comfortably past [`MAX_TRACKED_US`]; the table
/// generator asserts this at first use.
pub const NUM_BUCKETS: usize = 96;

/// Finite bucket upper bounds in microseconds, strictly increasing.
/// `bounds()[i]` is the inclusive upper bound of bucket `i`; bucket
/// `NUM_BUCKETS` (the last counter slot) is the `+Inf` overflow bucket.
pub fn bounds() -> &'static [u64; NUM_BUCKETS] {
    static BOUNDS: OnceLock<[u64; NUM_BUCKETS]> = OnceLock::new();
    BOUNDS.get_or_init(|| {
        let mut b = [0u64; NUM_BUCKETS];
        let mut prev = 0u64;
        for (i, slot) in b.iter_mut().enumerate() {
            let geometric = if i == 0 {
                MIN_TRACKED_US
            } else {
                (prev as f64 * GROWTH).ceil() as u64
            };
            // Strictly increasing even in the integer-rounded low range
            // (1, 2, 3, 4, 5, 7, ...).
            prev = geometric.max(prev + 1);
            *slot = prev;
        }
        assert!(
            b[NUM_BUCKETS - 1] >= MAX_TRACKED_US,
            "bucket table must cover {MAX_TRACKED_US}us, reached only {}us",
            b[NUM_BUCKETS - 1]
        );
        b
    })
}

/// Index of the bucket a `us` observation falls in: the first bucket
/// whose upper bound is `>= us`, or the overflow slot `NUM_BUCKETS`.
pub fn bucket_index(us: u64) -> usize {
    bounds().partition_point(|&bound| bound < us)
}

/// A lock-free histogram of microsecond durations.
///
/// `record` never blocks and never allocates; `snapshot` reads the
/// counters with `Relaxed` loads (monotone per-bucket, so a concurrent
/// snapshot is a valid histogram of *some* prefix-interleaving of the
/// recorded events).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; NUM_BUCKETS + 1],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_us: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    /// Record one observation. Wait-free, O(1) memory.
    pub fn record(&self, us: u64) {
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Total observations recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Copy the counters out. O(NUM_BUCKETS), no lock.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            count: self.count.load(Ordering::Relaxed),
        }
    }

    /// The fixed in-memory footprint of one histogram, independent of
    /// how many observations have been recorded. Used by the O(1)-memory
    /// regression test.
    pub const fn footprint_bytes() -> usize {
        std::mem::size_of::<Histogram>()
    }
}

/// An owned, mergeable copy of a [`Histogram`]'s counters.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistogramSnapshot {
    /// `NUM_BUCKETS + 1` counts; the last entry is the `+Inf` overflow
    /// bucket. Empty for a default-constructed snapshot.
    pub buckets: Vec<u64>,
    /// Exact sum of all recorded observations, in microseconds.
    pub sum_us: u64,
    /// Exact number of observations.
    pub count: u64,
}

impl HistogramSnapshot {
    /// Bucket-wise merge. Exact: both snapshots index the same static
    /// bound table, so merged quantile bounds are as tight as if every
    /// observation had been recorded into one histogram.
    pub fn merge(&mut self, other: &HistogramSnapshot) {
        if other.buckets.is_empty() {
            return;
        }
        if self.buckets.is_empty() {
            self.buckets = vec![0; other.buckets.len()];
        }
        assert_eq!(self.buckets.len(), other.buckets.len(), "bucket layout mismatch");
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
        self.sum_us += other.sum_us;
        self.count += other.count;
    }

    /// Total observations visible in the bucket counters themselves,
    /// including the overflow slot. On a quiescent histogram this equals
    /// [`HistogramSnapshot::count`]; a snapshot torn by a concurrent
    /// `record` can briefly see the two disagree, and the bucket total is
    /// the one consistent with `buckets` — quantiles and the Prometheus
    /// cumulative series derive from it so they never exceed what the
    /// buckets can account for.
    pub fn total(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Exact bounds on the `q`-quantile (0.0..=1.0): the true order
    /// statistic `t` of rank `ceil(q * total)` satisfies `lo < t <= hi`,
    /// where `total` is the bucket-counter total ([`Self::total`] — not
    /// the separately-updated `count`, which a torn snapshot can tear
    /// ahead of the buckets). `lo` is the previous bucket's upper bound
    /// (0 for the first bucket); `hi` is the containing bucket's upper
    /// bound. When the quantile lands in the `+Inf` overflow slot both
    /// bounds are reported as the last finite table bound (the overflow
    /// bucket's lower bound) — a defined value, never a fabricated one.
    /// Returns `None` when no bucket holds any observation.
    pub fn quantile_bounds(&self, q: f64) -> Option<(u64, u64)> {
        let total = self.total();
        if total == 0 {
            return None;
        }
        let rank = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let table = bounds();
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= rank {
                let lo = if i == 0 { 0 } else { table[(i - 1).min(NUM_BUCKETS - 1)] };
                let hi = if i < NUM_BUCKETS { table[i] } else { table[NUM_BUCKETS - 1] };
                return Some((lo, hi));
            }
        }
        // Unreachable: `rank <= total` and the loop accumulates `total`.
        None
    }

    /// Upper quantile bound as f64 microseconds (0.0 when empty) — the
    /// value exported as `p50_us`/`p95_us`/`p99_us`. Overestimates the
    /// true quantile by at most one bucket width (<= 25% + 1us).
    pub fn quantile_us(&self, q: f64) -> f64 {
        self.quantile_bounds(q).map(|(_, hi)| hi as f64).unwrap_or(0.0)
    }

    /// Mean in microseconds (exact: `sum_us` is exact).
    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Cumulative `(upper_bound_us, cumulative_count)` pairs over the
    /// finite buckets, in increasing bound order — the shape Prometheus
    /// `_bucket{le=...}` series want. The `+Inf` cumulative count is
    /// [`HistogramSnapshot::total`] (the finite cumulative plus the
    /// overflow slot), which keeps the emitted series monotone even for
    /// a torn snapshot.
    pub fn cumulative(&self) -> Vec<(u64, u64)> {
        let table = bounds();
        let mut out = Vec::with_capacity(NUM_BUCKETS);
        let mut cum = 0u64;
        for i in 0..NUM_BUCKETS {
            cum += self.buckets.get(i).copied().unwrap_or(0);
            out.push((table[i], cum));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_are_strictly_increasing_and_cover_range() {
        let b = bounds();
        assert_eq!(b[0], 1);
        for w in b.windows(2) {
            assert!(w[1] > w[0], "bounds must be strictly increasing: {w:?}");
            // Growth factor never exceeds ceil(x * 1.25), i.e. the
            // documented <= 25% + 1us relative bucket width.
            assert!(w[1] <= (w[0] as f64 * GROWTH).ceil() as u64 + 1);
        }
        assert!(b[NUM_BUCKETS - 1] >= MAX_TRACKED_US);
    }

    #[test]
    fn bucket_index_matches_bounds() {
        let b = bounds();
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        for (i, &bound) in b.iter().enumerate() {
            assert_eq!(bucket_index(bound), i, "upper bound is inclusive");
            assert_eq!(bucket_index(bound + 1), i + 1);
        }
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS);
    }

    #[test]
    fn quantiles_are_exact_bounds() {
        let h = Histogram::new();
        for v in [10u64, 20, 30] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 3);
        assert_eq!(s.sum_us, 60);
        let (lo, hi) = s.quantile_bounds(0.5).unwrap();
        assert!(lo < 20 && 20 <= hi, "p50 bounds {lo}..{hi} must bracket 20");
        assert!(hi as f64 <= 20.0 * GROWTH + 1.0);
        let (lo, hi) = s.quantile_bounds(0.99).unwrap();
        assert!(lo < 30 && 30 <= hi, "p99 bounds {lo}..{hi} must bracket 30");
    }

    #[test]
    fn merge_is_exact() {
        let a = Histogram::new();
        let b = Histogram::new();
        let all = Histogram::new();
        for v in [5u64, 50, 500, 5_000] {
            a.record(v);
            all.record(v);
        }
        for v in [7u64, 70, 700_000, 70_000_000_000] {
            b.record(v);
            all.record(v);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        assert_eq!(merged, all.snapshot());
    }

    #[test]
    fn overflow_bucket_catches_out_of_range() {
        let h = Histogram::new();
        h.record(u64::MAX);
        let s = h.snapshot();
        assert_eq!(s.buckets[NUM_BUCKETS], 1);
        assert_eq!(s.cumulative().last().unwrap().1, 0, "finite cum excludes overflow");
        assert_eq!(s.count, 1);
    }

    #[test]
    fn empty_histogram_quantile_is_defined() {
        let s = HistogramSnapshot::default();
        assert_eq!(s.quantile_bounds(0.5), None);
        assert_eq!(s.quantile_us(0.5), 0.0);
        // Same for a fresh histogram whose bucket vector exists but is
        // all zeros.
        let s = Histogram::new().snapshot();
        assert_eq!(s.quantile_bounds(0.99), None);
        assert_eq!(s.quantile_us(0.99), 0.0);
        assert_eq!(s.total(), 0);
    }

    #[test]
    fn overflow_only_quantile_is_overflow_lower_bound() {
        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX - 1);
        let s = h.snapshot();
        let last = bounds()[NUM_BUCKETS - 1];
        assert_eq!(s.quantile_bounds(0.5), Some((last, last)));
        assert_eq!(s.quantile_us(0.99), last as f64);
        assert_eq!(s.total(), 2);
    }

    #[test]
    fn torn_snapshot_quantile_uses_bucket_totals() {
        // A snapshot torn by a concurrent record() can see `count` ahead
        // of the bucket counters. Quantiles must come from the buckets
        // actually seen — never a fabricated top-of-table bound.
        let mut s = Histogram::new().snapshot();
        s.count = 5;
        assert_eq!(s.quantile_bounds(0.5), None, "no bucket data yet");
        assert_eq!(s.quantile_us(0.5), 0.0);
        s.buckets[bucket_index(10)] = 1;
        assert_eq!(s.total(), 1);
        let (lo, hi) = s.quantile_bounds(0.99).unwrap();
        assert!(lo < 10 && 10 <= hi, "bounds {lo}..{hi} must bracket the one sample");
    }

    #[test]
    fn footprint_is_constant() {
        // ~(96 + 1 + 2) * 8 bytes. The point is that it is a compile-time
        // constant, not proportional to observation count.
        assert!(Histogram::footprint_bytes() < 1024);
    }
}
