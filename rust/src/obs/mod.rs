//! Observability: lock-free histograms, per-request trace spans with
//! per-layer engine stage breakdowns, and a leveled structured logger.
//!
//! This is the cross-cutting layer the serving stack reports through
//! (DESIGN.md §12):
//!
//! * [`histogram`] — fixed-footprint log-scaled latency histograms that
//!   back the coordinator's `Metrics` (O(1) memory per observation,
//!   wait-free `record`, mergeable snapshots with exact quantile
//!   bounds) and the Prometheus `_bucket/_sum/_count` exposition.
//! * [`trace`] — `TraceId` minting, the per-request
//!   `{queue, batch_form, compute, respond}` [`trace::Span`], and the
//!   optional per-layer [`trace::StageSink`] the engine fills with
//!   im2col/GEMM/epilogue/interleave timings when a caller sets
//!   `X-Trace: 1` (zero-cost when disabled: every site checks the
//!   `Option` before touching the clock).
//! * [`log`] — `REPRO_LOG`-leveled `key=value` records on stderr, each
//!   prefixed with a monotonic `ts_us` (shared process epoch) and the
//!   emitting `thread`.
//! * [`journal`] — the flight recorder (DESIGN.md §14): per-thread
//!   lock-free ring buffers of compact binary events fed by the front
//!   door, the coordinator, and the engine's stage sink; snapshots
//!   export as Perfetto-loadable Chrome trace-event JSON, and the
//!   serving watchdog scans them for stalled workers.

pub mod histogram;
pub mod journal;
pub mod log;
pub mod trace;

pub use histogram::{Histogram, HistogramSnapshot};
pub use journal::{
    chrome_trace_json, monotonic_us, validate_chrome_trace, Event, EventKind, Journal,
    JournalConfig, NO_LANE,
};
pub use trace::{LayerStages, Span, StageSink};
