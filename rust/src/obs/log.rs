//! Minimal leveled, structured, std-only logger.
//!
//! Replaces the coordinator's bare `eprintln!` diagnostics with
//! `ts_us=… thread=… level=… target=… msg=… key=value…` lines on
//! stderr, filtered by the `REPRO_LOG` environment variable
//! (`error|warn|info|debug`, default `warn`; `off` silences
//! everything). The level is read once per process and cached, so the
//! per-call cost of a suppressed log line is one relaxed atomic-free
//! comparison against a `OnceLock`ed enum.
//!
//! `ts_us` is microseconds since process start on the same monotonic
//! epoch as the flight recorder ([`super::journal::process_epoch`]), so
//! log lines correlate 1:1 with journal timelines. `thread` is the OS
//! thread name (or a compact `t<n>` for unnamed threads).
//!
//! ```text
//! ts_us=1042 thread=sd-dispatcher-0 level=error target=coordinator msg="batch execution failed: …" worker=1 lane=dcgan
//! ```

use std::io::Write;
use std::sync::OnceLock;

/// Log severity, most severe first.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

impl Level {
    /// Parse a `REPRO_LOG` value. `None` for unrecognized strings.
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

/// The active max level: `REPRO_LOG` env var, default `warn`.
/// `REPRO_LOG=off|none|0` disables all output ([`max_level`] returns
/// `None`); any other unrecognized value falls back to the default.
pub fn max_level() -> Option<Level> {
    static MAX: OnceLock<Option<Level>> = OnceLock::new();
    *MAX.get_or_init(|| match std::env::var("REPRO_LOG") {
        Ok(v) if matches!(v.trim().to_ascii_lowercase().as_str(), "off" | "none" | "0") => None,
        Ok(v) => Some(Level::parse(&v).unwrap_or(Level::Warn)),
        Err(_) => Some(Level::Warn),
    })
}

/// Would a record at `level` be emitted?
pub fn enabled(level: Level) -> bool {
    matches!(max_level(), Some(max) if level <= max)
}

/// Render one record as a `key=value` line (no trailing newline).
/// `msg` and any field value containing spaces, quotes or `=` is quoted
/// with `"` and backslash-escaped, so lines stay machine-splittable.
pub fn format_line(level: Level, target: &str, msg: &str, fields: &[(&str, String)]) -> String {
    let mut out = String::with_capacity(64 + msg.len());
    out.push_str("level=");
    out.push_str(level.label());
    out.push_str(" target=");
    push_value(&mut out, target);
    out.push_str(" msg=");
    push_value(&mut out, msg);
    for (k, v) in fields {
        out.push(' ');
        out.push_str(k);
        out.push('=');
        push_value(&mut out, v);
    }
    out
}

fn push_value(out: &mut String, v: &str) {
    let needs_quotes =
        v.is_empty() || v.chars().any(|c| c.is_whitespace() || c == '"' || c == '=' || c == '\\');
    if !needs_quotes {
        out.push_str(v);
        return;
    }
    out.push('"');
    for c in v.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// The `thread=` value for the calling thread: its OS name, or a
/// compact process-wide `t<n>` for unnamed threads (stable per thread).
pub fn thread_label() -> String {
    if let Some(name) = std::thread::current().name() {
        return name.to_string();
    }
    use std::cell::Cell;
    use std::sync::atomic::{AtomicU32, Ordering};
    static NEXT: AtomicU32 = AtomicU32::new(0);
    thread_local! {
        static ID: Cell<u32> = const { Cell::new(u32::MAX) };
    }
    let n = ID.with(|id| {
        if id.get() == u32::MAX {
            id.set(NEXT.fetch_add(1, Ordering::Relaxed));
        }
        id.get()
    });
    format!("t{n}")
}

/// [`format_line`] with the `ts_us=… thread=…` prefix — the exact line
/// [`log`] writes (minus the newline).
pub fn stamped_line(level: Level, target: &str, msg: &str, fields: &[(&str, String)]) -> String {
    let mut out = String::with_capacity(96 + msg.len());
    out.push_str("ts_us=");
    out.push_str(&super::journal::monotonic_us().to_string());
    out.push_str(" thread=");
    push_value(&mut out, &thread_label());
    out.push(' ');
    out.push_str(&format_line(level, target, msg, fields));
    out
}

/// Emit one record to stderr if `level` passes the filter.
pub fn log(level: Level, target: &str, msg: &str, fields: &[(&str, String)]) {
    if !enabled(level) {
        return;
    }
    let line = stamped_line(level, target, msg, fields);
    // One write_all per record keeps concurrent workers' lines whole.
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "{line}");
}

pub fn error(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Error, target, msg, fields);
}

pub fn warn(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Warn, target, msg, fields);
}

pub fn info(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Info, target, msg, fields);
}

pub fn debug(target: &str, msg: &str, fields: &[(&str, String)]) {
    log(Level::Debug, target, msg, fields);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("error"), Some(Level::Error));
        assert_eq!(Level::parse(" WARN "), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("verbose"), None);
        assert!(Level::Error < Level::Debug, "more severe orders first");
    }

    #[test]
    fn format_line_quotes_only_when_needed() {
        let line = format_line(
            Level::Error,
            "coordinator",
            "batch execution failed: boom",
            &[("worker", "1".to_string()), ("lane", "dcgan".to_string())],
        );
        assert_eq!(
            line,
            "level=error target=coordinator msg=\"batch execution failed: boom\" worker=1 lane=dcgan"
        );
    }

    #[test]
    fn format_line_escapes_quotes_and_newlines() {
        let line = format_line(
            Level::Warn,
            "server",
            "bad \"header\"\nline",
            &[("peer", "127.0.0.1:80".to_string())],
        );
        assert!(line.contains("msg=\"bad \\\"header\\\"\\nline\""));
        assert!(line.ends_with("peer=127.0.0.1:80"));
    }

    #[test]
    fn stamped_line_prefixes_ts_and_thread() {
        let line = stamped_line(
            Level::Info,
            "server",
            "listening",
            &[("addr", "127.0.0.1:8787".to_string())],
        );
        // ts_us=<digits> thread=<label> level=info target=server …
        let mut parts = line.split(' ');
        let ts = parts.next().unwrap();
        assert!(ts.starts_with("ts_us="), "line starts with ts_us: {line}");
        assert!(
            ts["ts_us=".len()..].chars().all(|c| c.is_ascii_digit()),
            "ts_us value is a bare integer: {line}"
        );
        let thread = parts.next().unwrap();
        assert!(thread.starts_with("thread="), "thread field second: {line}");
        assert!(
            line.ends_with("level=info target=server msg=listening addr=127.0.0.1:8787"),
            "suffix stays the parseable format_line record: {line}"
        );
        // Monotone across calls on the same epoch.
        let t0: u64 = ts["ts_us=".len()..].parse().unwrap();
        let second = stamped_line(Level::Info, "server", "again", &[]);
        let t1: u64 = second.split(' ').next().unwrap()["ts_us=".len()..]
            .parse()
            .unwrap();
        assert!(t1 >= t0, "ts_us monotone: {t0} then {t1}");
    }

    #[test]
    fn thread_label_is_stable() {
        assert_eq!(thread_label(), thread_label());
    }
}
