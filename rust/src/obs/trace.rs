//! Per-request trace spans and per-layer engine stage breakdowns.
//!
//! A trace id is minted at admission (or taken from the caller's
//! `X-Request-Id` header) and rides `coordinator::Request` end to end.
//! Each dispatcher records a [`Span`] — where the request's wall time
//! went between the socket and the response channel — and, when the
//! caller opted in with `X-Trace: 1`, the engine fills a [`StageSink`]
//! with one [`LayerStages`] row per op: the paper's latency-decomposition
//! table (im2col / GEMM / epilogue / interleave+crop) measured live.
//!
//! The zero-overhead contract: every timing site checks an
//! `Option`/`bool` *before* calling `Instant::now()`, so an untraced
//! request takes no timestamps beyond the four per-batch/per-request
//! samples the coordinator has always taken for metrics.

use std::sync::atomic::{AtomicU64, Ordering};

/// Mint a fresh process-unique trace id (nonzero).
pub fn mint_trace_id() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Derive a trace id from a caller-supplied `X-Request-Id` header:
/// decimal u64s pass through verbatim, anything else is FNV-1a hashed
/// (stable across runs, so a retried request keeps its id).
pub fn trace_id_from_header(value: &str) -> u64 {
    let v = value.trim();
    if let Ok(n) = v.parse::<u64>() {
        return n;
    }
    const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
    const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;
    let mut h = FNV_OFFSET;
    for b in v.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Where one request's wall time went, socket to response channel.
///
/// `queue_us + batch_form_us + compute_us + respond_us` accounts for the
/// request's total in-coordinator time (up to saturating rounding).
/// `queue_us` here is pure lane-queue wait; the coordinator's public
/// `Response::queue_us` keeps its historical meaning (total minus
/// compute, i.e. queue wait *plus* batch formation) for compatibility.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Span {
    /// Trace id (minted or caller-supplied); 0 when spans are disabled.
    pub trace_id: u64,
    /// Time spent waiting in the lane queue before a dispatcher popped it.
    pub queue_us: u64,
    /// Time the continuous batcher spent filling the batch after pop.
    pub batch_form_us: u64,
    /// Executor time for the batch this request rode in.
    pub compute_us: u64,
    /// Time from batch completion to this request's response send.
    pub respond_us: u64,
}

impl Span {
    /// Compact JSON object (no trailing newline).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"trace_id\":{},\"queue_us\":{},\"batch_form_us\":{},\"compute_us\":{},\"respond_us\":{}}}",
            self.trace_id, self.queue_us, self.batch_form_us, self.compute_us, self.respond_us
        )
    }
}

/// Stage timings for one engine op (one network layer), in microseconds.
///
/// Stage taxonomy, mapped onto the kernels of DESIGN.md §8–§10:
/// * `im2col_us` — explicit input preparation: zero-padding into the
///   scratch arena and (int8) activation quantization. The im2col
///   *gather* itself is fused into the GEMM microkernel loop and is
///   accounted under `gemm_us`.
/// * `gemm_us` — the packed GEMM kernel calls: dense, direct conv, or
///   every stride-1 SD sub-convolution of a split deconv.
/// * `epilogue_us` — the activation pass (ReLU/tanh) applied after the
///   kernel. The int8 path's fused requantize+bias+ReLU epilogue runs
///   inside the kernel and lands in `gemm_us`.
/// * `interleave_us` — `sd::interleave_crop_into`: scattering the s²
///   sub-convolution outputs back into the deconv output and cropping.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LayerStages {
    /// Step name from the compiled program (e.g. `"deconv1"`).
    pub layer: &'static str,
    pub im2col_us: u64,
    pub gemm_us: u64,
    pub epilogue_us: u64,
    pub interleave_us: u64,
}

impl LayerStages {
    pub fn total_us(&self) -> u64 {
        self.im2col_us + self.gemm_us + self.epilogue_us + self.interleave_us
    }

    /// Accumulate another measurement of the same layer.
    pub fn accumulate(&mut self, other: &LayerStages) {
        self.im2col_us += other.im2col_us;
        self.gemm_us += other.gemm_us;
        self.epilogue_us += other.epilogue_us;
        self.interleave_us += other.interleave_us;
    }

    /// Compact JSON object.
    pub fn to_json(&self) -> String {
        format!(
            "{{\"layer\":{},\"im2col_us\":{},\"gemm_us\":{},\"epilogue_us\":{},\"interleave_us\":{},\"total_us\":{}}}",
            json_string(self.layer),
            self.im2col_us,
            self.gemm_us,
            self.epilogue_us,
            self.interleave_us,
            self.total_us()
        )
    }
}

/// Collector for per-layer stage timings across one (or many) forward
/// passes. Passing `None` instead of a sink skips every timing site.
#[derive(Clone, Debug, Default)]
pub struct StageSink {
    pub layers: Vec<LayerStages>,
}

impl StageSink {
    pub fn new() -> StageSink {
        StageSink::default()
    }

    /// Start (or continue) a row for `layer` and return it for the
    /// engine's timing macro to add into. Rows accumulate by name, so a
    /// sink reused across N runs holds per-layer totals over N runs.
    pub fn layer_mut(&mut self, layer: &'static str) -> &mut LayerStages {
        if let Some(i) = self.layers.iter().position(|l| l.layer == layer) {
            return &mut self.layers[i];
        }
        self.layers.push(LayerStages {
            layer,
            ..LayerStages::default()
        });
        self.layers.last_mut().unwrap()
    }

    /// Sum of all per-layer totals.
    pub fn total_us(&self) -> u64 {
        self.layers.iter().map(|l| l.total_us()).sum()
    }

    /// JSON array of per-layer rows, in execution order.
    pub fn to_json(&self) -> String {
        let rows: Vec<String> = self.layers.iter().map(|l| l.to_json()).collect();
        format!("[{}]", rows.join(","))
    }
}

/// Quote + escape a string as a JSON string literal.
fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_id_header_parses_decimal_and_hashes_strings() {
        assert_eq!(trace_id_from_header("42"), 42);
        assert_eq!(trace_id_from_header(" 42 "), 42);
        let h1 = trace_id_from_header("req-abc");
        let h2 = trace_id_from_header("req-abc");
        assert_eq!(h1, h2, "hash must be stable");
        assert_ne!(h1, trace_id_from_header("req-abd"));
    }

    #[test]
    fn minted_ids_are_unique() {
        let a = mint_trace_id();
        let b = mint_trace_id();
        assert_ne!(a, b);
        assert_ne!(a, 0);
    }

    #[test]
    fn sink_accumulates_by_layer_name() {
        let mut sink = StageSink::new();
        sink.layer_mut("conv1").gemm_us += 10;
        sink.layer_mut("conv2").gemm_us += 5;
        sink.layer_mut("conv1").im2col_us += 3;
        assert_eq!(sink.layers.len(), 2);
        assert_eq!(sink.layers[0].layer, "conv1");
        assert_eq!(sink.layers[0].gemm_us, 10);
        assert_eq!(sink.layers[0].im2col_us, 3);
        assert_eq!(sink.total_us(), 18);
    }

    #[test]
    fn json_shapes() {
        let span = Span {
            trace_id: 7,
            queue_us: 1,
            batch_form_us: 2,
            compute_us: 3,
            respond_us: 4,
        };
        assert_eq!(
            span.to_json(),
            "{\"trace_id\":7,\"queue_us\":1,\"batch_form_us\":2,\"compute_us\":3,\"respond_us\":4}"
        );
        let mut sink = StageSink::new();
        sink.layer_mut("d1").gemm_us = 9;
        assert!(sink.to_json().starts_with("[{\"layer\":\"d1\""));
        assert!(sink.to_json().contains("\"total_us\":9"));
    }
}
