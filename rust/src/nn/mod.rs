//! Layer / network descriptors and the MAC & parameter arithmetic behind the
//! paper's Tables 1–3. Counting conventions (validated against the paper's
//! published numbers, see python/tests/test_model.py and rust/tests):
//!
//! * deconv MACs (scatter): `IH*IW*K*K*IC*OC`
//! * conv MACs:             `OH*OW*K*K*IC*OC`
//! * NZP deconv MACs:       `OH*OW*K*K*IC*OC` (dense conv over the
//!                          zero-inserted map)
//! * SD deconv MACs:        `IH*IW*(s*K_T)^2*IC*OC` (Table 2 convention:
//!                          interior compute; boundary halo zeros excluded,
//!                          padded-filter zeros included)

use crate::sd::SdGeometry;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    Deconv,
    Dense,
}

/// One layer of a benchmark network. Spatial sizes may be rectangular.
#[derive(Clone, Debug)]
pub struct LayerSpec {
    pub name: &'static str,
    pub kind: LayerKind,
    pub in_h: usize,
    pub in_w: usize,
    pub in_c: usize,
    pub out_c: usize,
    pub k: usize,
    pub s: usize,
    pub p: usize,
    /// output padding (deconv only): out = (i-1)s + k - 2p + op
    pub op: usize,
}

impl LayerSpec {
    pub fn conv(
        name: &'static str,
        in_h: usize,
        in_w: usize,
        in_c: usize,
        out_c: usize,
        k: usize,
        s: usize,
        p: usize,
    ) -> Self {
        LayerSpec { name, kind: LayerKind::Conv, in_h, in_w, in_c, out_c, k, s, p, op: 0 }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn deconv(
        name: &'static str,
        in_h: usize,
        in_w: usize,
        in_c: usize,
        out_c: usize,
        k: usize,
        s: usize,
        p: usize,
        op: usize,
    ) -> Self {
        LayerSpec { name, kind: LayerKind::Deconv, in_h, in_w, in_c, out_c, k, s, p, op }
    }

    pub fn dense(name: &'static str, n_in: usize, n_out: usize) -> Self {
        LayerSpec {
            name,
            kind: LayerKind::Dense,
            in_h: 1,
            in_w: 1,
            in_c: n_in,
            out_c: n_out,
            k: 0,
            s: 1,
            p: 0,
            op: 0,
        }
    }

    pub fn out_h(&self) -> usize {
        match self.kind {
            LayerKind::Deconv => (self.in_h - 1) * self.s + self.k - 2 * self.p + self.op,
            LayerKind::Conv => (self.in_h + 2 * self.p - self.k) / self.s + 1,
            LayerKind::Dense => 1,
        }
    }

    pub fn out_w(&self) -> usize {
        match self.kind {
            LayerKind::Deconv => (self.in_w - 1) * self.s + self.k - 2 * self.p + self.op,
            LayerKind::Conv => (self.in_w + 2 * self.p - self.k) / self.s + 1,
            LayerKind::Dense => 1,
        }
    }

    /// Multiply-add count, paper Table 1 convention.
    pub fn macs(&self) -> u64 {
        let (k2, icoc) = (
            (self.k * self.k) as u64,
            (self.in_c * self.out_c) as u64,
        );
        match self.kind {
            LayerKind::Deconv => (self.in_h * self.in_w) as u64 * k2 * icoc,
            LayerKind::Conv => (self.out_h() * self.out_w()) as u64 * k2 * icoc,
            LayerKind::Dense => (self.in_h * self.in_w) as u64 * icoc,
        }
    }

    /// MACs of the NZP conversion of this deconv layer (Table 2, column 2).
    pub fn nzp_macs(&self) -> u64 {
        assert_eq!(self.kind, LayerKind::Deconv);
        (self.out_h() * self.out_w() * self.k * self.k * self.in_c * self.out_c) as u64
    }

    /// MACs of the SD conversion (Table 2, column 3 convention).
    pub fn sd_macs(&self) -> u64 {
        assert_eq!(self.kind, LayerKind::Deconv);
        let g = SdGeometry::new(self.k, self.s, self.p);
        let skt = self.s * g.k_t;
        (self.in_h * self.in_w * skt * skt * self.in_c * self.out_c) as u64
    }

    /// SD MACs as actually *executed* on a dense processor (includes the
    /// P_I input-halo overhead the Table-2 convention excludes). This is the
    /// number a no-skip processor pays.
    pub fn sd_exec_macs(&self) -> u64 {
        assert_eq!(self.kind, LayerKind::Deconv);
        let g = SdGeometry::new(self.k, self.s, self.p);
        let co_h = self.in_h + g.k_t - 1; // conv out per split, stride 1
        let co_w = self.in_w + g.k_t - 1;
        (self.s * self.s * co_h * co_w * g.k_t * g.k_t * self.in_c * self.out_c) as u64
    }

    /// Weight parameter count (original layer).
    pub fn params(&self) -> u64 {
        match self.kind {
            LayerKind::Dense => (self.in_h * self.in_w * self.in_c * self.out_c) as u64,
            _ => (self.k * self.k * self.in_c * self.out_c) as u64,
        }
    }

    /// Parameters after general SD splitting (padded filters, Table 3 col 2).
    pub fn sd_params(&self) -> u64 {
        assert_eq!(self.kind, LayerKind::Deconv);
        let g = SdGeometry::new(self.k, self.s, self.p);
        let side = self.s * g.k_t;
        (side * side * self.in_c * self.out_c) as u64
    }

    /// Parameters of compressed SD: padded zeros removed, small per-split
    /// metadata retained (one offset word per split filter; Table 3 col 3).
    pub fn sd_compressed_params(&self) -> u64 {
        assert_eq!(self.kind, LayerKind::Deconv);
        let g = SdGeometry::new(self.k, self.s, self.p);
        self.params() + (g.n_splits() as u64)
    }
}

/// A benchmark network: ordered layer list.
#[derive(Clone, Debug)]
pub struct NetworkSpec {
    pub name: &'static str,
    pub layers: Vec<LayerSpec>,
}

impl NetworkSpec {
    /// Flat per-request input element count: the first layer's input view
    /// (dense layers encode `n_in` as `1 x 1 x n_in`). This is the latent /
    /// image length a serving client must submit.
    pub fn input_elems(&self) -> usize {
        self.layers
            .first()
            .map(|l| l.in_h * l.in_w * l.in_c)
            .unwrap_or(0)
    }

    pub fn deconv_layers(&self) -> impl Iterator<Item = &LayerSpec> {
        self.layers.iter().filter(|l| l.kind == LayerKind::Deconv)
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    pub fn deconv_macs(&self) -> u64 {
        self.deconv_layers().map(|l| l.macs()).sum()
    }

    pub fn nzp_macs(&self) -> u64 {
        self.deconv_layers().map(|l| l.nzp_macs()).sum()
    }

    pub fn sd_macs(&self) -> u64 {
        self.deconv_layers().map(|l| l.sd_macs()).sum()
    }

    pub fn deconv_params(&self) -> u64 {
        self.deconv_layers().map(|l| l.params()).sum()
    }

    pub fn sd_params(&self) -> u64 {
        self.deconv_layers().map(|l| l.sd_params()).sum()
    }

    pub fn sd_compressed_params(&self) -> u64 {
        self.deconv_layers().map(|l| l.sd_compressed_params()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deconv_shapes() {
        let l = LayerSpec::deconv("d", 8, 8, 256, 128, 5, 2, 2, 1);
        assert_eq!((l.out_h(), l.out_w()), (16, 16));
        assert_eq!(l.macs(), 8 * 8 * 25 * 256 * 128);
        assert_eq!(l.nzp_macs(), 16 * 16 * 25 * 256 * 128);
        // k5 s2: K_T=3, sK_T=6 -> SD factor 36/25
        assert_eq!(l.sd_macs(), 8 * 8 * 36 * 256 * 128);
        assert_eq!(l.sd_params(), 36 * 256 * 128);
        assert_eq!(l.sd_compressed_params(), 25 * 256 * 128 + 4);
    }

    #[test]
    fn conv_shapes() {
        let l = LayerSpec::conv("c", 64, 128, 32, 64, 5, 2, 2);
        assert_eq!((l.out_h(), l.out_w()), (32, 64));
        assert_eq!(l.macs(), 32 * 64 * 25 * 32 * 64);
    }

    #[test]
    fn divisible_filter_sd_is_free() {
        let l = LayerSpec::deconv("d", 4, 4, 512, 256, 4, 2, 1, 0);
        assert_eq!(l.sd_macs(), l.macs());
        assert_eq!(l.sd_params(), l.params());
    }

    #[test]
    fn sd_exec_includes_halo() {
        let l = LayerSpec::deconv("d", 4, 4, 8, 8, 4, 2, 1, 0);
        assert!(l.sd_exec_macs() > l.sd_macs());
    }
}
