//! `repro` — CLI for the split-deconvolution reproduction.
//!
//! Subcommands:
//!   report <table1|table2|table3|table4|quant|fig8|fig9|fig10|fig11|
//!           table5|table6|table7|table8|fig15|fig16|fig17|all>
//!   verify  [--limit N]        golden-check AOT artifacts via PJRT
//!   compile [--model name|all] [--precision f32|int8|both] [--seed S]
//!           [-o path.sdprog | --out-dir DIR] [--verify]
//!           compile model(s) ONCE into serializable `.sdprog` program
//!           artifacts (packed weight panels + checksummed manifest;
//!           DESIGN.md section 13) that `serve --artifact-dir` loads for
//!           instant cold start. --verify reloads every written artifact
//!           in both load modes and gates on byte-for-byte re-encoding
//!           (the bit-identity check CI runs). Default output names are
//!           `<slug>_<precision>.sdprog` under --out-dir (default `.`).
//!   serve   [--requests N] [--batch B] [--native] [--workers W]
//!           [--model dcgan|artgan|sngan|gpgan|mde|fst]
//!           [--precision f32|int8] [--artifact-dir DIR]
//!           run the serving demo for any benchmark network (--native, or a
//!           missing artifacts/, compiles the model ONCE into an immutable
//!           engine::Program on the CPU-native GEMM backend instead of
//!           PJRT; --workers W drains the shared request queue with W
//!           dispatcher threads, each with its own Scratch; --precision
//!           int8 compiles the quantized program — int8 weights +
//!           activations, i32 accumulate, calibrated at compile time)
//!   serve --listen <addr> [--models all|csv] [--serve-secs N]
//!           [--deadline-ms D] [--workers W] [--batch B] [--queue-cap Q]
//!           [--precision f32|int8] [--artifact-dir DIR] [--chaos SPEC]
//!           network front door: serve every requested model (default: all
//!           six) from ONE process over HTTP/1.1 — one compiled program
//!           per model, one shared worker pool, per-model routing by
//!           request path (POST /v1/generate/<model>), explicit 503 sheds
//!           when a lane is full, 504 for requests whose --deadline-ms
//!           (or X-Deadline-Ms header) expires before compute. --serve-secs
//!           bounds the run (CI smoke); omit it to serve until killed.
//!           --chaos seed=N,panic=P,error=P,slow=P:MS,ticks=T (or the
//!           REPRO_CHAOS env var) arms seeded fault injection inside
//!           dispatcher batch execution — panics are contained, panicked
//!           batches retried solo, repeat offenders quarantined with a
//!           typed 500, and per-lane circuit breakers answer 503
//!           lane_down while a lane recovers (DESIGN.md section 15).
//!   profile [--model dcgan|artgan|sngan|gpgan|mde|fst] [--precision f32|int8]
//!           [--requests N] [--seed S] [--json path]
//!           run N seeded inferences through the native engine with the
//!           per-layer stage tracer attached and print where the time goes:
//!           one row per layer, im2col/GEMM/epilogue/interleave columns
//!           (mean us over N). --json writes BENCH_profile.json-style
//!           machine-readable rows via the shared bench harness sink.
//!   trace   [--model name] [--requests N] [--batch B] [--workers W]
//!           [--precision f32|int8] [-o path.json]
//!           run N requests through a journal-equipped native server and
//!           export the flight recorder as Chrome trace-event JSON
//!           (Perfetto / chrome://tracing; DESIGN.md section 14), or
//!   trace --check FILE [--min-events N]
//!           validate an exported trace (the CI schema gate): parses the
//!           JSON, checks per-track timestamp monotonicity and that every
//!           flow id resolves, and optionally enforces a minimum event
//!           count.
//!   simulate <network> <nzp|sd> [--policy P] [--arch dot|2d]
//!
//! (Arg parsing is hand-rolled: the offline registry has no clap.)

// The bench targets' shared JSON sink, reused so `repro profile --json`
// emits the same file shape the perf-tracking scripts already parse.
#[path = "../benches/harness.rs"]
mod harness;

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};
use split_deconv::coordinator::{BreakerConfig, FaultPlan, Server, ServerConfig, WatchdogConfig};
use split_deconv::engine::{DeconvImpl, LoadMode, Plan, Precision, Program};
use split_deconv::obs::{Journal, StageSink};
use split_deconv::report;
use split_deconv::runtime::{artifacts_available, default_artifact_dir, Engine};
use split_deconv::server::{FrontDoor, FrontDoorConfig};
use split_deconv::sim::workload::{lower_network_deconvs, Lowering};
use split_deconv::sim::{dot_array, pe2d, ProcessorConfig, SkipPolicy};
use split_deconv::util::rng::Rng;
use split_deconv::{commodity, networks};

fn main() {
    // Anchor the shared monotonic epoch (journal timestamps + obs::log
    // ts_us) at process start, before any thread exists.
    let _ = split_deconv::obs::monotonic_us();
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn flag_value<'a>(args: &'a [String], name: &str) -> Option<&'a str> {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
}

/// `--chaos seed=N,panic=P,error=P,slow=P:MS,ticks=T` (or the
/// `REPRO_CHAOS` env var when the flag is absent): the deterministic
/// fault-injection plan of DESIGN.md §15. `None` when neither is set.
fn chaos_plan(args: &[String]) -> Result<Option<Arc<FaultPlan>>> {
    let spec = match flag_value(args, "--chaos") {
        Some(s) => Some(s.to_string()),
        None => std::env::var("REPRO_CHAOS").ok().filter(|s| !s.is_empty()),
    };
    match spec {
        None => Ok(None),
        Some(s) => {
            let plan = FaultPlan::from_spec(&s)?;
            eprintln!("chaos injection armed: {}", plan.describe());
            Ok(Some(Arc::new(plan)))
        }
    }
}

fn run(args: &[String]) -> Result<()> {
    match args.first().map(String::as_str) {
        Some("report") => report_cmd(args.get(1).map(String::as_str).unwrap_or("all"), args),
        Some("verify") => verify_cmd(args),
        Some("compile") => compile_cmd(args),
        Some("serve") => serve_cmd(args),
        Some("profile") => profile_cmd(args),
        Some("trace") => trace_cmd(args),
        Some("simulate") => simulate_cmd(args),
        Some(other) => {
            bail!("unknown command {other}; try report/verify/compile/serve/profile/trace/simulate")
        }
        None => {
            println!("repro — split deconvolution reproduction");
            println!("usage: repro <report|verify|compile|serve|profile|trace|simulate> ...");
            Ok(())
        }
    }
}

fn report_cmd(which: &str, args: &[String]) -> Result<()> {
    let seed = flag_value(args, "--seed")
        .and_then(|s| s.parse().ok())
        .unwrap_or(42u64);
    let all = which == "all";
    if all || which == "table1" {
        report::print_table1();
        println!();
    }
    if all || which == "table2" {
        report::print_table2();
        println!();
    }
    if all || which == "table3" {
        report::print_table3();
        println!();
    }
    if all || which == "table4" {
        report::print_table4(2)?;
        println!();
    }
    if all || which == "quant" {
        report::print_quant_table(2)?;
        println!();
    }
    if all || which == "fig8" {
        report::print_sim_figure("Figure 8: dot-production PE array", &report::fig8(seed)?);
        println!();
    }
    if all || which == "fig9" {
        report::print_sim_figure("Figure 9: regular 2D PE array", &report::fig9(seed)?);
        println!();
    }
    if all || which == "fig10" {
        report::print_energy_figure(
            "Figure 10: energy, dot-production array",
            &report::fig10(seed)?,
        );
        println!();
    }
    if all || which == "fig11" {
        report::print_energy_figure("Figure 11: energy, 2D PE array", &report::fig11(seed)?);
        println!();
    }
    if all || which == "table5" {
        report::print_eff_table("Table 5 (reported as Table 6 sweep): Edge TPU GMACPS vs feature map", &report::table5(), "px");
        println!();
    }
    if all || which == "table6" {
        report::print_eff_table("Table 6: Edge TPU GMACPS vs filter size", &report::table6(), "k");
        println!();
    }
    if all || which == "table7" {
        report::print_eff_table("Table 7: NCS2 GMACPS vs feature map", &report::table7(), "px");
        println!();
    }
    if all || which == "table8" {
        report::print_eff_table("Table 8: NCS2 GMACPS vs filter size", &report::table8(), "k");
        println!();
    }
    if all || which == "fig15" {
        let rows = report::fig15();
        report::print_speedup_figure("Figure 15: Edge TPU", &rows);
        println!("average SD speedup {:.2}x", report::average_speedup(&rows, "SD"));
        println!();
    }
    if all || which == "fig17" {
        let rows = report::fig17();
        report::print_speedup_figure("Figure 17: Intel NCS2", &rows);
        println!("average SD speedup {:.2}x", report::average_speedup(&rows, "SD"));
        println!();
    }
    if which == "fig16" {
        let mut engine = Engine::new(default_artifact_dir())?;
        let rows = commodity::host::measure_fig16(&mut engine, 3)?;
        commodity::host::print_fig16(&rows);
    } else if all {
        println!("(fig16 runs real PJRT measurements: `repro report fig16`)");
    }
    Ok(())
}

fn verify_cmd(args: &[String]) -> Result<()> {
    let limit: usize = flag_value(args, "--limit")
        .and_then(|s| s.parse().ok())
        .unwrap_or(usize::MAX);
    let mut engine = Engine::new(default_artifact_dir())?;
    println!("platform: {}", engine.platform());
    let names: Vec<String> = engine
        .manifest()
        .artifacts
        .iter()
        .map(|a| a.name.clone())
        .take(limit)
        .collect();
    let mut worst = 0.0f32;
    for name in names {
        let err = engine.verify(&name)?;
        worst = worst.max(err);
        println!("{name:<28} max|err| = {err:.3e}");
    }
    println!("worst: {worst:.3e}");
    if worst > 1e-3 {
        bail!("golden check failed");
    }
    Ok(())
}

/// `repro compile`: compile model(s) into `.sdprog` program artifacts —
/// the build-time half of the instant-cold-start path (`serve
/// --artifact-dir` is the load-time half). With `--verify`, every written
/// artifact is reloaded in BOTH load modes and must re-encode to the
/// identical bytes: the bit-identity gate CI runs over all six networks.
fn compile_cmd(args: &[String]) -> Result<()> {
    let model = flag_value(args, "--model").unwrap_or("all");
    let seed: u64 = flag_value(args, "--seed")
        .and_then(|s| s.parse().ok())
        .unwrap_or(7);
    let precisions: Vec<Precision> = match flag_value(args, "--precision") {
        None => vec![Precision::F32],
        Some("both") => vec![Precision::F32, Precision::Int8],
        Some(p) => vec![Precision::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown precision {p}; expected f32/int8/both"))?],
    };
    let out_file = flag_value(args, "-o").or_else(|| flag_value(args, "--out"));
    let out_dir = PathBuf::from(flag_value(args, "--out-dir").unwrap_or("."));
    let verify = args.iter().any(|a| a == "--verify");
    let models: Vec<String> = if model == "all" {
        networks::names().iter().map(|s| s.to_string()).collect()
    } else {
        vec![model.to_string()]
    };
    if out_file.is_some() && models.len() * precisions.len() != 1 {
        bail!("-o names ONE output file; use --out-dir when compiling several artifacts");
    }
    if out_file.is_none() {
        std::fs::create_dir_all(&out_dir)?;
    }
    for model in &models {
        let net = networks::by_name_or_err(model)?;
        let slug = networks::slug(net.name);
        for &precision in &precisions {
            let t0 = Instant::now();
            let program = Program::from_seed_prec(&net, DeconvImpl::Sd, seed, precision)?;
            let compile_s = t0.elapsed().as_secs_f64();
            let bytes = program.to_artifact_bytes()?;
            let path = match out_file {
                Some(o) => PathBuf::from(o),
                None => out_dir.join(format!("{slug}_{}.sdprog", precision.label())),
            };
            std::fs::write(&path, &bytes)?;
            let mut line = format!(
                "{:<22} {:>5} {:>10} bytes  compile {:.3}s",
                path.display(),
                precision.label(),
                bytes.len(),
                compile_s
            );
            if verify {
                for mode in [LoadMode::Copy, LoadMode::ZeroCopy] {
                    let t1 = Instant::now();
                    let loaded = Program::load_with(&path, mode)?;
                    let load_s = t1.elapsed().as_secs_f64();
                    if loaded.to_artifact_bytes()? != bytes {
                        bail!(
                            "{}: {mode:?} load is not bit-identical to the fresh compile",
                            path.display()
                        );
                    }
                    line.push_str(&format!("  load[{mode:?}] {load_s:.3}s ok"));
                }
            }
            println!("{line}");
        }
    }
    Ok(())
}

fn serve_cmd(args: &[String]) -> Result<()> {
    if let Some(listen) = flag_value(args, "--listen") {
        return serve_listen_cmd(args, listen);
    }
    let n: usize = flag_value(args, "--requests")
        .and_then(|s| s.parse().ok())
        .unwrap_or(32);
    let max_batch: usize = flag_value(args, "--batch")
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let model = flag_value(args, "--model").unwrap_or("dcgan").to_string();
    let workers: usize = flag_value(args, "--workers")
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let precision = match flag_value(args, "--precision") {
        None => Precision::F32,
        Some(p) => Precision::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown precision {p}; expected f32 or int8"))?,
    };
    let net = networks::by_name_or_err(&model)?;
    let cfg = ServerConfig {
        max_batch,
        batch_timeout: Duration::from_millis(2),
        queue_cap: 128,
        model,
        workers,
        precision,
        record_spans: true,
        journal: None,
        watchdog: None,
        chaos: chaos_plan(args)?,
        breaker: None,
    };
    let artifact_dir = flag_value(args, "--artifact-dir");
    let native = args.iter().any(|a| a == "--native") || !artifacts_available();
    if precision == Precision::Int8 && !native && artifact_dir.is_none() {
        bail!("--precision int8 is a native-backend mode; add --native");
    }
    let z_len = net.input_elems();
    let server = if let Some(dir) = artifact_dir {
        // instant cold start: load the precompiled .sdprog program
        // (checksummed manifest + packed panels) instead of compiling
        let file = format!("{}_{}.sdprog", networks::slug(net.name), precision.label());
        let path = Path::new(dir).join(file);
        println!(
            "(CPU-native engine backend: {} {} Program loaded from {}, shared by \
             {workers} worker(s) with private Scratch)",
            net.name,
            precision.label(),
            path.display()
        );
        Server::start_native_program(cfg, Arc::new(Program::load(&path)?))?
    } else if native {
        println!(
            "(CPU-native engine backend: {} compiled once into a shared {} Program, \
             SD filters pre-split, {workers} worker(s) with private Scratch)",
            net.name,
            precision.label()
        );
        Server::start_native(cfg, 7)?
    } else {
        // artifact families are keyed by the canonical slug, not the raw
        // user spelling ("DC-GAN" must still find "dcgan_sd_b*")
        let prefix = format!("{}_sd", networks::slug(net.name));
        Server::start_pjrt(cfg, default_artifact_dir(), prefix)?
    };
    println!(
        "serving {} (SD path, {}) — {n} requests of {z_len} floats, max batch {max_batch}, \
         {workers} worker(s)",
        net.name,
        precision.label()
    );
    let mut rng = Rng::new(7);
    let mut pending = Vec::new();
    for _ in 0..n {
        pending.push(server.submit_blocking(rng.normal_vec(z_len))?);
    }
    for (i, rx) in pending.into_iter().enumerate() {
        let resp = rx.recv()?;
        if i == 0 {
            println!(
                "first image: {} floats, range [{:.2}, {:.2}]",
                resp.image.len(),
                resp.image.iter().cloned().fold(f32::INFINITY, f32::min),
                resp.image.iter().cloned().fold(f32::NEG_INFINITY, f32::max),
            );
        }
    }
    println!("{}", server.metrics().summary());
    server.shutdown();
    Ok(())
}

/// `serve --listen <addr>`: the network front door — every requested
/// model served from this one process over HTTP/1.1 (CPU-native backend;
/// one compiled program per model, one shared worker pool).
fn serve_listen_cmd(args: &[String], listen: &str) -> Result<()> {
    let max_batch: usize = flag_value(args, "--batch")
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let workers: usize = flag_value(args, "--workers")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let queue_cap: usize = flag_value(args, "--queue-cap")
        .and_then(|s| s.parse().ok())
        .unwrap_or(128);
    let precision = match flag_value(args, "--precision") {
        None => Precision::F32,
        Some(p) => Precision::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown precision {p}; expected f32 or int8"))?,
    };
    let models_arg = flag_value(args, "--models").unwrap_or("all");
    let models: Vec<String> = if models_arg == "all" {
        networks::names().iter().map(|s| s.to_string()).collect()
    } else {
        models_arg
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    };
    if models.is_empty() {
        bail!("--models needs at least one model (or 'all')");
    }
    let default_deadline = flag_value(args, "--deadline-ms")
        .and_then(|s| s.parse::<u64>().ok())
        .map(Duration::from_millis);
    let serve_secs: Option<u64> = flag_value(args, "--serve-secs").and_then(|s| s.parse().ok());

    // The network front door always flies with the recorder on: the
    // journal is fixed-memory and its emit path is wait-free, and it is
    // what makes `/debug/trace` + the stall watchdog available in
    // production (DESIGN.md §14).
    let journal = Journal::with_defaults();
    let scfg = ServerConfig {
        max_batch,
        batch_timeout: Duration::from_millis(2),
        queue_cap,
        model: models[0].clone(),
        workers,
        precision,
        record_spans: true,
        journal: Some(journal),
        watchdog: Some(WatchdogConfig::default()),
        chaos: chaos_plan(args)?,
        // the front door always flies with per-lane circuit breakers:
        // a lane that keeps failing answers 503 fast instead of burning
        // its queue (DESIGN.md §15)
        breaker: Some(BreakerConfig::default()),
    };
    let fcfg = FrontDoorConfig {
        listen: listen.to_string(),
        default_deadline,
        ..FrontDoorConfig::default()
    };
    let door = match flag_value(args, "--artifact-dir") {
        Some(dir) => {
            println!(
                "loading {} precompiled {} program(s) from {dir} (.sdprog artifacts, shared \
                 across {workers} worker(s))...",
                models.len(),
                precision.label()
            );
            FrontDoor::start_artifacts(fcfg, scfg, &models, Path::new(dir))?
        }
        None => {
            println!(
                "compiling {} model(s) at {} (SD filters pre-split, shared across {workers} \
                 worker(s))...",
                models.len(),
                precision.label()
            );
            FrontDoor::start_native(fcfg, scfg, &models, 7)?
        }
    };
    println!("listening on http://{}", door.addr());
    for r in door.routes() {
        println!(
            "  POST /v1/generate/{}  (latent {} f32s -> image {} f32s; try ?seed=7)",
            r.name, r.z_len, r.image_len
        );
    }
    println!("  GET  /v1/models | /metrics (JSON; ?format=prom for Prometheus) | /healthz");
    println!("  GET  /debug/trace?ms=N  (flight recorder as Chrome trace JSON — open in Perfetto)");
    match serve_secs {
        Some(secs) => {
            println!("serving for {secs}s (--serve-secs), then draining...");
            std::thread::sleep(Duration::from_secs(secs));
        }
        None => loop {
            std::thread::sleep(Duration::from_secs(3600));
        },
    }
    door.shutdown();
    println!("{}", door.metrics().summary());
    Ok(())
}

/// `repro profile`: the paper's latency-decomposition table measured
/// live — N seeded inferences through the native engine with a
/// [`StageSink`] attached, then one row per layer with mean per-stage
/// microseconds (im2col prep / GEMM kernels / activation epilogue /
/// SD interleave+crop).
fn profile_cmd(args: &[String]) -> Result<()> {
    let model = flag_value(args, "--model").unwrap_or("dcgan").to_string();
    let requests: usize = flag_value(args, "--requests")
        .and_then(|s| s.parse().ok())
        .unwrap_or(16)
        .max(1);
    let seed: u64 = flag_value(args, "--seed")
        .and_then(|s| s.parse().ok())
        .unwrap_or(7);
    let precision = match flag_value(args, "--precision") {
        None => Precision::F32,
        Some(p) => Precision::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown precision {p}; expected f32 or int8"))?,
    };
    let net = networks::by_name_or_err(&model)?;
    let mut plan = Plan::from_seed_prec(&net, DeconvImpl::Sd, 7, precision)?;
    let z_len = plan.input_len();
    println!(
        "profiling {} ({}, SD path): {requests} seeded inference(s), latent {z_len} floats",
        net.name,
        precision.label()
    );

    let mut rng = Rng::new(seed);
    // warm-up untraced: page in the packed weights and size the scratch
    let warm = rng.normal_vec(z_len);
    plan.execute_batch_traced(std::slice::from_ref(&warm), None)?;
    // one sink across all runs: rows accumulate by layer name, so each
    // row ends up holding per-stage TOTALS over the N runs
    let mut sink = StageSink::new();
    for _ in 0..requests {
        let z = rng.normal_vec(z_len);
        plan.execute_batch_traced(std::slice::from_ref(&z), Some(&mut sink))?;
    }

    let n = requests as f64;
    let grand_total = sink.total_us() as f64;
    println!(
        "\n{:<12} {:>11} {:>11} {:>12} {:>14} {:>10} {:>7}",
        "layer", "im2col_us", "gemm_us", "epilogue_us", "interleave_us", "total_us", "share"
    );
    let mut json = harness::JsonSink::from_args();
    for l in &sink.layers {
        let total = l.total_us() as f64;
        println!(
            "{:<12} {:>11.1} {:>11.1} {:>12.1} {:>14.1} {:>10.1} {:>6.1}%",
            l.layer,
            l.im2col_us as f64 / n,
            l.gemm_us as f64 / n,
            l.epilogue_us as f64 / n,
            l.interleave_us as f64 / n,
            total / n,
            if grand_total > 0.0 { 100.0 * total / grand_total } else { 0.0 },
        );
        json.record_fields(
            &format!("profile_{}_{}_{}", networks::slug(net.name), precision.label(), l.layer),
            &[
                ("im2col_us", l.im2col_us as f64 / n),
                ("gemm_us", l.gemm_us as f64 / n),
                ("epilogue_us", l.epilogue_us as f64 / n),
                ("interleave_us", l.interleave_us as f64 / n),
                ("total_us", total / n),
            ],
        );
    }
    println!(
        "{:<12} {:>11} {:>11} {:>12} {:>14} {:>10.1} {:>6.1}%",
        "TOTAL", "", "", "", "", grand_total / n, 100.0
    );
    json.write("profile");
    Ok(())
}

/// `repro trace`: the flight recorder end to end from the CLI. Without
/// `--check`, runs N requests through a journal-equipped native server
/// and writes the recorder's contents as Chrome trace-event JSON (open
/// the file in Perfetto / `chrome://tracing`). With `--check FILE`, acts
/// as the CI schema gate instead: validates an exported trace without
/// running anything.
fn trace_cmd(args: &[String]) -> Result<()> {
    if let Some(path) = flag_value(args, "--check") {
        let min_events: usize = flag_value(args, "--min-events")
            .and_then(|s| s.parse().ok())
            .unwrap_or(0);
        let src = std::fs::read_to_string(path)?;
        let stats = split_deconv::obs::validate_chrome_trace(&src)
            .map_err(|e| anyhow::anyhow!("{path}: invalid chrome trace: {e}"))?;
        println!(
            "{path}: valid chrome trace — {} events, {} tracks, {} flows",
            stats.events, stats.tracks, stats.flows
        );
        if stats.events < min_events {
            bail!("{path}: only {} events (< --min-events {min_events})", stats.events);
        }
        return Ok(());
    }

    let model = flag_value(args, "--model").unwrap_or("dcgan").to_string();
    let requests: usize = flag_value(args, "--requests")
        .and_then(|s| s.parse().ok())
        .unwrap_or(16)
        .max(1);
    let max_batch: usize = flag_value(args, "--batch")
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let workers: usize = flag_value(args, "--workers")
        .and_then(|s| s.parse().ok())
        .unwrap_or(2);
    let precision = match flag_value(args, "--precision") {
        None => Precision::F32,
        Some(p) => Precision::parse(p)
            .ok_or_else(|| anyhow::anyhow!("unknown precision {p}; expected f32 or int8"))?,
    };
    let net = networks::by_name_or_err(&model)?;
    let slug = networks::slug(net.name);
    let journal = Journal::with_defaults();
    let cfg = ServerConfig {
        max_batch,
        batch_timeout: Duration::from_millis(2),
        queue_cap: 128,
        model,
        workers,
        precision,
        record_spans: true,
        journal: Some(journal.clone()),
        watchdog: None,
        chaos: None,
        breaker: None,
    };
    let z_len = net.input_elems();
    eprintln!(
        "tracing {} ({}, SD path): {requests} request(s), max batch {max_batch}, \
         {workers} worker(s)",
        net.name,
        precision.label()
    );
    let server = Server::start_native(cfg, 7)?;
    let mut rng = Rng::new(7);
    let mut pending = Vec::with_capacity(requests);
    for _ in 0..requests {
        pending.push(server.submit_blocking(rng.normal_vec(z_len))?);
    }
    for rx in pending {
        rx.recv()?;
    }
    server.shutdown();

    let events = journal.snapshot();
    let lanes = vec![slug];
    let json = split_deconv::obs::chrome_trace_json(&events, &journal.thread_names(), &lanes);
    match flag_value(args, "-o").or_else(|| flag_value(args, "--out")) {
        Some(path) => {
            std::fs::write(path, json.as_bytes())?;
            eprintln!(
                "wrote {path}: {} events from the journal (open in Perfetto / chrome://tracing)",
                events.len()
            );
        }
        None => println!("{json}"),
    }
    Ok(())
}

fn simulate_cmd(args: &[String]) -> Result<()> {
    let net_name = args.get(1).map(String::as_str).unwrap_or("DCGAN");
    let how = match args.get(2).map(String::as_str).unwrap_or("sd") {
        "nzp" => Lowering::Nzp,
        "sd" => Lowering::Sd,
        other => bail!("unknown lowering {other}"),
    };
    let policy = match flag_value(args, "--policy").unwrap_or("awsparse") {
        "none" => SkipPolicy::None,
        "asparse" => SkipPolicy::ASparse,
        "wsparse" => SkipPolicy::WSparse,
        "awsparse" => SkipPolicy::AWSparse,
        other => bail!("unknown policy {other}"),
    };
    let arch = flag_value(args, "--arch").unwrap_or("2d");
    let net = networks::by_name(net_name)
        .ok_or_else(|| anyhow::anyhow!("unknown network {net_name}"))?;
    let ops = lower_network_deconvs(&net, how, 42)?;
    let cfg = ProcessorConfig::default();
    let stats = match arch {
        "dot" => dot_array::simulate(&ops, &cfg, policy),
        "2d" => pe2d::simulate(&ops, &cfg, policy),
        other => bail!("unknown arch {other}"),
    };
    println!(
        "{net_name} {how:?} {policy:?} on {arch}: cycles={} time={:.1}us util={:.1}% skipped={}",
        stats.cycles,
        stats.time_us(cfg.freq_mhz),
        100.0 * stats.utilization(),
        stats.cycles_skipped
    );
    let e = split_deconv::sim::energy::energy(&stats, &Default::default());
    println!(
        "energy: PE {:.1}uJ buffer {:.1}uJ DRAM {:.1}uJ total {:.1}uJ",
        e.pe_uj,
        e.buffer_uj,
        e.dram_uj,
        e.total_uj()
    );
    Ok(())
}
