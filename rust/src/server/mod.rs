//! Network front door: a real TCP/HTTP server over the [`crate::coordinator`]
//! worker pool — the process boundary of the serving stack.
//!
//! One process serves EVERY configured model (all six benchmark networks by
//! default): one compiled `Arc<Program>` per model, one shared dispatcher
//! pool, per-model routing by request path. The protocol is deliberately
//! tiny (std-only HTTP/1.1, see [`http`]):
//!
//! * `POST /v1/generate/<model>` — body = little-endian f32 latent vector
//!   (`z_len * 4` bytes), or empty body with `?seed=N` to have the server
//!   draw the latent itself (curl-friendly). Response 200 is the raw
//!   little-endian f32 image; `X-Request-Id`/`X-Batch-Size`/`X-Queue-Us`/
//!   `X-Compute-Us`/`X-Model` carry the serving metadata. An
//!   `X-Deadline-Ms` header sets the request's completion deadline.
//! * `GET /v1/models` — the route table as JSON.
//! * `GET /metrics` — coordinator metrics snapshot as JSON, or Prometheus
//!   text format (`?format=prom` or `Accept: text/plain`) with counters,
//!   gauges (including the live per-lane queue depth, in-flight count and
//!   worker busy fractions), and the latency/queue-wait/compute
//!   histograms as cumulative `_bucket`/`_sum`/`_count` series
//!   (DESIGN.md §12).
//! * `GET /healthz` — readiness: per-model lane depth/capacity and
//!   served/shed/expired counts, precision, and a `draining` flag that
//!   flips during close-then-drain shutdown.
//! * `GET /debug/trace?ms=N` — the last N milliseconds of the flight
//!   recorder (when the coordinator was started with a journal,
//!   DESIGN.md §14) as Chrome trace-event JSON loadable in Perfetto /
//!   `chrome://tracing`; 404 without a journal.
//!
//! Tracing: an `X-Request-Id` request header becomes the request's trace
//! id (decimal u64s pass through, other values are hashed); `X-Trace: 1`
//! opts into the per-layer engine stage breakdown. Traced 200 responses
//! keep the image bytes **bit-identical** and append a JSON trailer
//! (`{"trace_id":..,"span":..,"stages":..}`) after them; the
//! `X-Trace-Result` response header is the trailer's byte offset.
//!
//! Admission control is EXPLICIT at this boundary: a full lane answers
//! 503 `{"error":"shed"}` immediately (counted in `Metrics.shed` — never a
//! silent drop, never a hang), a lane whose circuit breaker is open
//! answers 503 `{"error":"lane_down"}` (DESIGN.md §15), and a request
//! whose deadline expires before compute answers 504 (dropped by the
//! dispatcher pre-compute, counted in `Metrics.expired`). Both 503 shapes
//! carry a deterministically jittered `Retry-After` (1-4 s) so a
//! synchronized client herd spreads its retries. A request whose batch
//! panicked gets a typed 500 (`worker_panic` after a failed solo retry,
//! `quarantined` for the request that panics alone) — panic containment
//! means a faulted request is answered, never stranded. Graceful
//! shutdown is close-then-drain end to end:
//! [`FrontDoor::shutdown`] stops the acceptor, lets the coordinator drain
//! every accepted request, and every connection handler flushes its
//! pending response before its socket closes (proved over real sockets in
//! rust/tests/front_door.rs).

pub mod client;
pub mod http;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::coordinator::{
    BreakerState, MetricsSnapshot, Server, ServerConfig, SubmitError, SubmitOpts,
};
use crate::engine::{DeconvImpl, Program};
use crate::obs::journal::{EventKind, Journal, NO_LANE};
use crate::obs::{self, HistogramSnapshot, LayerStages};
use crate::util::rng::Rng;

use http::{
    bytes_to_f32s, error_body, f32s_to_bytes, write_response, Conn, HttpRequest, ReadOutcome,
};

/// How often a blocked connection read wakes up to check the shutdown
/// flag. Bounds how long shutdown waits on idle keep-alive connections.
const IDLE_POLL: Duration = Duration::from_millis(200);

/// One routable model: lane order matches the coordinator's lanes.
#[derive(Clone, Debug)]
pub struct Route {
    /// canonical route key (a [`crate::networks::slug`] for native lanes)
    pub name: String,
    /// latent length — request bodies must be exactly `z_len * 4` bytes
    pub z_len: usize,
    /// flattened image length (response body is `image_len * 4` bytes)
    pub image_len: usize,
}

/// Front-door configuration (the coordinator has its own
/// [`ServerConfig`]).
#[derive(Clone, Debug)]
pub struct FrontDoorConfig {
    /// bind address; port 0 picks an ephemeral port (tests)
    pub listen: String,
    /// deadline applied to requests that carry no `X-Deadline-Ms` header
    pub default_deadline: Option<Duration>,
    /// largest accepted request body (latents are small; this is a
    /// hostile-client guard, not a tuning knob)
    pub max_body_bytes: usize,
    /// how long a connection handler waits for the coordinator's response
    /// before answering 500 (a liveness backstop — orders of magnitude
    /// above any real compute time)
    pub response_timeout: Duration,
}

impl Default for FrontDoorConfig {
    fn default() -> Self {
        FrontDoorConfig {
            listen: "127.0.0.1:0".to_string(),
            default_deadline: None,
            max_body_bytes: 4 << 20,
            response_timeout: Duration::from_secs(120),
        }
    }
}

/// A running front door: TCP acceptor + per-connection handler threads
/// over an owned coordinator [`Server`].
pub struct FrontDoor {
    addr: SocketAddr,
    server: Arc<Server>,
    routes: Arc<Vec<Route>>,
    cfg: Arc<FrontDoorConfig>,
    closing: Arc<AtomicBool>,
    acceptor: Mutex<Option<JoinHandle<()>>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl FrontDoor {
    /// Bind `cfg.listen` and start accepting. `routes` must match the
    /// coordinator's model lanes one-to-one, in lane order.
    pub fn start(cfg: FrontDoorConfig, server: Server, routes: Vec<Route>) -> Result<FrontDoor> {
        if routes.len() != server.models().len() {
            anyhow::bail!(
                "route table has {} entries for {} model lanes",
                routes.len(),
                server.models().len()
            );
        }
        let listener =
            TcpListener::bind(&cfg.listen).with_context(|| format!("bind {}", cfg.listen))?;
        let addr = listener.local_addr()?;
        let server = Arc::new(server);
        let routes = Arc::new(routes);
        let cfg = Arc::new(cfg);
        let closing = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));

        let acceptor = {
            let server = server.clone();
            let routes = routes.clone();
            let cfg = cfg.clone();
            let closing = closing.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("sd-acceptor".to_string())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if closing.load(Ordering::SeqCst) {
                            // the wake-up connection from shutdown() (or a
                            // late client) — drop it and stop accepting
                            break;
                        }
                        let stream = match stream {
                            Ok(s) => s,
                            Err(_) => continue,
                        };
                        if let Some(j) = server.journal() {
                            j.emit(EventKind::Accept, NO_LANE, 0, 0, 0);
                        }
                        let server = server.clone();
                        let routes = routes.clone();
                        let cfg = cfg.clone();
                        let closing = closing.clone();
                        let spawned = std::thread::Builder::new()
                            .name("sd-conn".to_string())
                            .spawn(move || {
                                handle_conn(stream, &server, &routes, &cfg, &closing);
                            });
                        // a handler that panicked while holding the lock
                        // must not kill the acceptor too
                        let mut conns = conns.lock().unwrap_or_else(PoisonError::into_inner);
                        // reap finished handlers so the vec stays bounded
                        // by the number of LIVE connections
                        conns.retain(|h| !h.is_finished());
                        if let Ok(h) = spawned {
                            conns.push(h);
                        }
                    }
                })?
        };

        Ok(FrontDoor {
            addr,
            server,
            routes,
            cfg,
            closing,
            acceptor: Mutex::new(Some(acceptor)),
            conns,
        })
    }

    /// Start the all-native multi-tenant front door: compile ONE
    /// `Program` per requested model (at `scfg.precision`), stand up one
    /// shared worker pool over all of them, and listen. `models` accepts
    /// any spelling [`crate::networks::by_name`] does; routes are keyed by
    /// canonical slug.
    pub fn start_native(
        cfg: FrontDoorConfig,
        scfg: ServerConfig,
        models: &[String],
        weight_seed: u64,
    ) -> Result<FrontDoor> {
        let mut programs: Vec<(String, Arc<Program>)> = Vec::with_capacity(models.len());
        let mut routes = Vec::with_capacity(models.len());
        for model in models {
            let net = crate::networks::by_name_or_err(model)?;
            let slug = crate::networks::slug(net.name);
            let program = Arc::new(Program::from_seed_prec(
                &net,
                DeconvImpl::Sd,
                weight_seed,
                scfg.precision,
            )?);
            routes.push(Route {
                name: slug.clone(),
                z_len: program.input_len(),
                image_len: program.output_len(),
            });
            programs.push((slug, program));
        }
        let server = Server::start_native_multi(scfg, programs)?;
        FrontDoor::start(cfg, server, routes)
    }

    /// [`FrontDoor::start_native`] with each program **loaded** from a
    /// pre-compiled `.sdprog` artifact instead of compiled in-process —
    /// the instant-cold-start path. Artifacts are looked up as
    /// `<slug>_<precision>.sdprog` under `artifact_dir` (the names
    /// `repro compile --out-dir` writes); every load validates the format
    /// version and every blob checksum before serving.
    pub fn start_artifacts(
        cfg: FrontDoorConfig,
        scfg: ServerConfig,
        models: &[String],
        artifact_dir: &std::path::Path,
    ) -> Result<FrontDoor> {
        let mut programs: Vec<(String, Arc<Program>)> = Vec::with_capacity(models.len());
        let mut routes = Vec::with_capacity(models.len());
        for model in models {
            let net = crate::networks::by_name_or_err(model)?;
            let slug = crate::networks::slug(net.name);
            let path = artifact_dir.join(format!("{slug}_{}.sdprog", scfg.precision.label()));
            let program = Arc::new(Program::load(&path)?);
            routes.push(Route {
                name: slug.clone(),
                z_len: program.input_len(),
                image_len: program.output_len(),
            });
            programs.push((slug, program));
        }
        let server = Server::start_native_multi(scfg, programs)?;
        FrontDoor::start(cfg, server, routes)
    }

    /// The bound address (resolves port 0 binds).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The route table, in lane order.
    pub fn routes(&self) -> &[Route] {
        &self.routes
    }

    /// The coordinator behind the door (for direct submits in tests and
    /// for metrics).
    pub fn coordinator(&self) -> &Server {
        &self.server
    }

    /// Coordinator metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.server.metrics()
    }

    /// Graceful close-then-drain shutdown: stop accepting, drain the
    /// coordinator queue (every accepted request computes), and wait for
    /// every connection handler to flush its final response and exit.
    /// Idempotent.
    pub fn shutdown(&self) {
        if self.closing.swap(true, Ordering::SeqCst) {
            return;
        }
        // the acceptor is blocked in accept(); a self-connection wakes it
        // so it can observe `closing` and exit
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.acceptor.lock().unwrap_or_else(PoisonError::into_inner).take() {
            let _ = h.join();
        }
        // drain: workers finish every queued request, so handlers blocked
        // on recv get their responses before we wait on them
        self.server.shutdown();
        let mut conns = self.conns.lock().unwrap_or_else(PoisonError::into_inner);
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *conns);
        drop(conns);
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for FrontDoor {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Per-connection loop: frame requests, serve them in arrival order
/// (keep-alive), exit on disconnect, protocol violation, or shutdown.
/// Sequential handling per connection + FIFO lanes + single-consumer
/// batches gives per-client FIFO response order end to end.
fn handle_conn(
    stream: TcpStream,
    server: &Server,
    routes: &[Route],
    cfg: &FrontDoorConfig,
    closing: &AtomicBool,
) {
    let _ = stream.set_nodelay(true);
    // short read timeout: a blocked read wakes up every IDLE_POLL to
    // check the shutdown flag, so idle keep-alive connections cannot
    // stall shutdown
    let _ = stream.set_read_timeout(Some(IDLE_POLL));
    let mut conn = Conn::new(stream);
    loop {
        match conn.read_request(cfg.max_body_bytes) {
            Err(bad) => {
                // fault-injection contract: malformed bytes get an
                // explicit 4xx (400; 411 for a bodied request with no
                // declared length; 413 for a body over the configured
                // cap), then the connection closes
                obs::log::warn("front_door", &format!("bad request: {}", bad.msg), &[]);
                if let Some(j) = server.journal() {
                    j.emit(EventKind::HttpError, NO_LANE, bad.status, 0, 0);
                }
                let kind = match bad.status {
                    411 => "length_required",
                    413 => "body_too_large",
                    _ => "bad_request",
                };
                let body = error_body(kind, &bad.msg);
                let _ = write_response(
                    conn.stream_mut(),
                    bad.status,
                    "application/json",
                    &[],
                    &body,
                    false,
                );
                return;
            }
            Ok(ReadOutcome::Eof) => return,
            Ok(ReadOutcome::IdleTimeout) => {
                if closing.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Ok(ReadOutcome::Request(req)) => {
                let keep = req.keep_alive && !closing.load(Ordering::SeqCst);
                let reply = handle_request(&req, server, routes, cfg, closing);
                if (400..500).contains(&reply.status) {
                    if let Some(j) = server.journal() {
                        j.emit(EventKind::HttpError, NO_LANE, reply.status, 0, 0);
                    }
                }
                if write_response(
                    conn.stream_mut(),
                    reply.status,
                    reply.content_type,
                    &reply.headers,
                    &reply.body,
                    keep,
                )
                .is_err()
                {
                    // client went away mid-response (fault injection);
                    // nothing to salvage on this connection
                    obs::log::debug(
                        "front_door",
                        "client disconnected mid-response",
                        &[("path", req.path.clone())],
                    );
                    return;
                }
                if !keep {
                    return;
                }
            }
        }
    }
}

struct Reply {
    status: u16,
    content_type: &'static str,
    headers: Vec<(&'static str, String)>,
    body: Vec<u8>,
}

impl Reply {
    fn json(status: u16, body: Vec<u8>) -> Reply {
        Reply {
            status,
            content_type: "application/json",
            headers: Vec::new(),
            body,
        }
    }
}

/// Route and serve one request.
fn handle_request(
    req: &HttpRequest,
    server: &Server,
    routes: &[Route],
    cfg: &FrontDoorConfig,
    closing: &AtomicBool,
) -> Reply {
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/healthz") => {
            let draining = closing.load(Ordering::SeqCst);
            let breakers = server.breaker_states();
            let body = healthz_json(
                &server.metrics(),
                routes,
                server.config(),
                draining,
                breakers.as_deref(),
            );
            Reply::json(200, body)
        }
        ("GET", "/v1/models") => Reply::json(200, models_json(routes)),
        ("GET", "/metrics") => {
            let prom = req.query_param("format") == Some("prom")
                || matches!(req.header("accept"), Some(a) if a.contains("text/plain"));
            let journal = server.journal().map(|j| j.as_ref());
            if prom {
                let breakers = server.breaker_states();
                Reply {
                    status: 200,
                    content_type: "text/plain; version=0.0.4",
                    headers: Vec::new(),
                    body: metrics_prom(&server.metrics(), routes, journal, breakers.as_deref()),
                }
            } else {
                Reply::json(200, metrics_json(&server.metrics(), routes, journal))
            }
        }
        ("GET", "/debug/trace") => match server.journal() {
            None => Reply::json(
                404,
                error_body("no_journal", "server started without a flight recorder"),
            ),
            Some(j) => {
                // ?ms=N: how far back the timeline reaches (default 1s)
                let ms = req
                    .query_param("ms")
                    .and_then(|v| v.parse::<u64>().ok())
                    .unwrap_or(1000);
                let now = obs::journal::monotonic_us();
                let events = j.snapshot_since(now.saturating_sub(ms.saturating_mul(1000)));
                let lanes: Vec<String> = routes.iter().map(|r| r.name.clone()).collect();
                let json = obs::journal::chrome_trace_json(&events, &j.thread_names(), &lanes);
                Reply::json(200, json.into_bytes())
            }
        },
        (_, path) if path.starts_with("/v1/generate/") => {
            let model = &path["/v1/generate/".len()..];
            if req.method != "POST" {
                let body = error_body("method_not_allowed", "generate requires POST");
                return Reply {
                    status: 405,
                    content_type: "application/json",
                    headers: vec![("Allow", "POST".to_string())],
                    body,
                };
            }
            generate(req, model, server, routes, cfg, closing)
        }
        _ => Reply::json(
            404,
            error_body("not_found", &format!("{} {}", req.method, req.path)),
        ),
    }
}

/// The serving path: resolve the lane, build the latent, submit with the
/// request's deadline, wait for the coordinator's answer.
fn generate(
    req: &HttpRequest,
    model: &str,
    server: &Server,
    routes: &[Route],
    cfg: &FrontDoorConfig,
    closing: &AtomicBool,
) -> Reply {
    let want = crate::networks::slug(model);
    let lane = match routes.iter().position(|r| r.name == want) {
        Some(i) => i,
        None => {
            let known: Vec<&str> = routes.iter().map(|r| r.name.as_str()).collect();
            let detail = format!("unknown model {model}; this server has {}", known.join("/"));
            return Reply::json(404, error_body("unknown_model", &detail));
        }
    };
    let route = &routes[lane];

    // latent: raw f32 LE body, or server-drawn from ?seed=N
    let z: Vec<f32> = if !req.body.is_empty() {
        match bytes_to_f32s(&req.body) {
            Some(z) if z.len() == route.z_len => z,
            _ => {
                let detail = format!(
                    "latent for {} must be exactly {} little-endian f32s ({} bytes), got {} bytes",
                    route.name,
                    route.z_len,
                    route.z_len * 4,
                    req.body.len()
                );
                return Reply::json(400, error_body("bad_latent", &detail));
            }
        }
    } else if let Some(seed) = req.query_param("seed") {
        match seed.parse::<u64>() {
            Ok(s) => Rng::new(s).normal_vec(route.z_len),
            Err(_) => {
                return Reply::json(400, error_body("bad_seed", "seed must be a u64"));
            }
        }
    } else {
        let detail = "request needs a latent body or a ?seed=N query parameter";
        return Reply::json(400, error_body("missing_latent", detail));
    };

    // deadline: per-request header wins, else the configured default
    let deadline_ms = match req.header("x-deadline-ms") {
        Some(v) => match v.parse::<u64>() {
            Ok(ms) => Some(Duration::from_millis(ms)),
            Err(_) => {
                return Reply::json(400, error_body("bad_deadline", "x-deadline-ms must be a u64"));
            }
        },
        None => cfg.default_deadline,
    };
    let deadline = deadline_ms.map(|d| Instant::now() + d);

    // tracing opt-ins: a caller-supplied X-Request-Id becomes the trace
    // id; X-Trace: 1 asks for the per-layer engine stage breakdown
    let trace_id = req.header("x-request-id").map(obs::trace::trace_id_from_header);
    let traced = matches!(req.header("x-trace"), Some(v) if v.trim() == "1");

    if closing.load(Ordering::SeqCst) {
        return shutting_down();
    }
    let opts = SubmitOpts {
        deadline,
        trace_id,
        trace_stages: traced,
    };
    let rx = match server.submit_opts(lane, z, opts) {
        Ok(rx) => {
            if let Some(j) = server.journal() {
                j.emit(EventKind::Admit, lane as u16, 0, 0, trace_id.unwrap_or(0));
            }
            rx
        }
        Err(SubmitError::Full) => {
            // admission-control shed: already counted in Metrics.shed by
            // submit_to; the client gets an explicit, immediate answer
            let body = error_body("shed", "queue_full");
            return Reply {
                status: 503,
                content_type: "application/json",
                headers: vec![("Retry-After", retry_after_secs().to_string())],
                body,
            };
        }
        Err(SubmitError::LaneDown) => {
            // circuit breaker open for this lane (DESIGN.md §15): fail
            // fast under the same 503 + Retry-After contract as a shed
            let body = error_body("lane_down", "circuit breaker open; lane is recovering");
            return Reply {
                status: 503,
                content_type: "application/json",
                headers: vec![("Retry-After", retry_after_secs().to_string())],
                body,
            };
        }
        Err(SubmitError::Closed) => return shutting_down(),
        Err(SubmitError::UnknownModel) => {
            return Reply::json(404, error_body("unknown_model", model));
        }
    };

    match rx.recv_timeout(cfg.response_timeout) {
        Ok(resp) => {
            if let Some(fault) = &resp.fault {
                // the batch panicked; containment answered this request
                // with a typed fault instead of an image (DESIGN.md §15)
                return Reply::json(500, error_body(fault.kind.label(), &fault.msg));
            }
            let mut headers = vec![
                ("X-Request-Id", resp.id.to_string()),
                ("X-Model", route.name.clone()),
                ("X-Batch-Size", resp.batch_size.to_string()),
                ("X-Queue-Us", resp.queue_us.to_string()),
                ("X-Compute-Us", resp.compute_us.to_string()),
            ];
            if resp.span.trace_id != 0 {
                headers.push(("X-Trace-Id", resp.span.trace_id.to_string()));
            }
            let mut body = f32s_to_bytes(&resp.image);
            if traced {
                // the image bytes stay bit-identical to an untraced
                // response; the trace rides as a JSON trailer AFTER them,
                // located by the X-Trace-Result byte offset
                let offset = body.len();
                let mut trailer = format!(
                    "{{\"trace_id\":{},\"span\":{}",
                    resp.span.trace_id,
                    resp.span.to_json()
                );
                if let Some(stages) = &resp.stages {
                    trailer.push_str(",\"stages\":");
                    trailer.push_str(&stages_json(stages));
                }
                trailer.push('}');
                body.extend_from_slice(trailer.as_bytes());
                headers.push(("X-Trace-Result", offset.to_string()));
            }
            Reply {
                status: 200,
                content_type: "application/octet-stream",
                headers,
                body,
            }
        }
        Err(_) => {
            // the responder disconnected (or the backstop timeout fired).
            // If this request's deadline has passed, the dispatcher
            // dropped it pre-compute: that is the 504 contract. Anything
            // else is a batch failure.
            let expired = match deadline {
                Some(d) => d <= Instant::now(),
                None => false,
            };
            if expired {
                Reply::json(504, error_body("deadline_expired", "dropped before compute"))
            } else {
                Reply::json(500, error_body("batch_failed", "execution failed; see server log"))
            }
        }
    }
}

fn shutting_down() -> Reply {
    Reply::json(503, error_body("shutting_down", "server is draining"))
}

/// Deterministic jittered `Retry-After` for 503 answers: 1..=4 seconds,
/// stepped per rejection by a splitmix64-style multiply so a synchronized
/// client herd de-synchronizes instead of retrying in lockstep. No clock,
/// no RNG state — the sequence is reproducible run to run (asserted over
/// a real socket in rust/tests/front_door.rs).
fn retry_after_secs() -> u64 {
    static REJECTIONS: AtomicU64 = AtomicU64::new(0);
    let n = REJECTIONS.fetch_add(1, Ordering::Relaxed);
    1 + (n.wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 60) % 4
}

fn models_json(routes: &[Route]) -> Vec<u8> {
    let mut out = String::from("{\"models\":[");
    for (i, r) in routes.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"z_len\":{},\"image_len\":{}}}",
            r.name, r.z_len, r.image_len
        ));
    }
    out.push_str("]}");
    out.into_bytes()
}

/// Enriched readiness probe: overall status + per-model lane state.
/// `draining` flips during close-then-drain shutdown (the front door
/// still answers health checks while the coordinator finishes accepted
/// work, so load balancers see `"draining"` instead of a dead socket).
fn healthz_json(
    s: &MetricsSnapshot,
    routes: &[Route],
    scfg: &ServerConfig,
    draining: bool,
    breakers: Option<&[BreakerState]>,
) -> Vec<u8> {
    let mut out = String::from("{");
    out.push_str(&format!(
        "\"status\":\"{}\",",
        if draining { "draining" } else { "ok" }
    ));
    out.push_str(&format!("\"draining\":{draining},"));
    out.push_str(&format!("\"precision\":\"{}\",", scfg.precision.label()));
    out.push_str(&format!("\"workers\":{},", s.worker_batches.len()));
    out.push_str(&format!("\"served\":{},", s.served));
    out.push_str(&format!("\"shed\":{},", s.shed));
    out.push_str(&format!("\"expired\":{},", s.expired));
    out.push_str(&format!("\"in_flight\":{},", s.in_flight));
    out.push_str(&format!("\"watchdog_stalls\":{},", s.watchdog_stalls));
    out.push_str(&format!("\"live_workers\":{},", s.live_workers));
    out.push_str(&format!("\"worker_panics\":{},", s.worker_panics));
    out.push_str(&format!("\"quarantined\":{},", s.quarantined));
    out.push_str("\"models\":[");
    for (i, r) in routes.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let ready = !draining;
        let depth = s.lane_depth.get(i).copied().unwrap_or(0);
        let served = s.lane_served.get(i).copied().unwrap_or(0);
        let shed = s.lane_shed.get(i).copied().unwrap_or(0);
        let expired = s.lane_expired.get(i).copied().unwrap_or(0);
        out.push_str(&format!(
            "{{\"name\":\"{}\",\"ready\":{ready},\"depth\":{depth},\"cap\":{},\
             \"served\":{served},\"shed\":{shed},\"expired\":{expired}",
            r.name, scfg.queue_cap
        ));
        // the breaker field only exists when the coordinator was started
        // with circuit breakers (ServerConfig.breaker)
        if let Some(st) = breakers.and_then(|states| states.get(i)) {
            out.push_str(&format!(",\"breaker\":\"{}\"", st.label()));
        }
        out.push('}');
    }
    out.push_str("]}");
    out.into_bytes()
}

/// Rolling-window busy fraction per dispatcher worker, from the flight
/// recorder's batch-duration events over the last second. Returns
/// `(worker index, fraction)` sorted by worker.
fn worker_busy_window(j: &Journal) -> Vec<(usize, f64)> {
    const WINDOW_US: u64 = 1_000_000;
    let now = obs::journal::monotonic_us();
    let by_tid = j.busy_fractions(WINDOW_US, now);
    let mut out: Vec<(usize, f64)> = j
        .thread_names()
        .into_iter()
        .filter_map(|(tid, name)| {
            let idx = name.strip_prefix("sd-dispatcher-")?.parse::<usize>().ok()?;
            Some((idx, by_tid.get(&tid).copied().unwrap_or(0.0)))
        })
        .collect();
    out.sort_by_key(|&(idx, _)| idx);
    out
}

fn json_lane_map(out: &mut String, key: &str, routes: &[Route], values: &[u64]) {
    out.push_str(&format!("\"{key}\":{{"));
    for (i, r) in routes.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let v = values.get(i).copied().unwrap_or(0);
        out.push_str(&format!("\"{}\":{}", r.name, v));
    }
    out.push_str("},");
}

fn metrics_json(s: &MetricsSnapshot, routes: &[Route], journal: Option<&Journal>) -> Vec<u8> {
    let mut out = String::from("{");
    out.push_str(&format!("\"served\":{},", s.served));
    out.push_str(&format!("\"batches\":{},", s.batches));
    out.push_str(&format!("\"errors\":{},", s.errors));
    out.push_str(&format!("\"shed\":{},", s.shed));
    out.push_str(&format!("\"expired\":{},", s.expired));
    out.push_str(&format!("\"in_flight\":{},", s.in_flight));
    out.push_str(&format!("\"watchdog_stalls\":{},", s.watchdog_stalls));
    out.push_str(&format!("\"worker_panics\":{},", s.worker_panics));
    out.push_str(&format!("\"quarantined\":{},", s.quarantined));
    out.push_str(&format!("\"lane_down\":{},", s.lane_down));
    out.push_str(&format!("\"live_workers\":{},", s.live_workers));
    out.push_str(&format!("\"uptime_s\":{:.3},", s.uptime_s));
    out.push_str(&format!("\"throughput_rps\":{:.3},", s.throughput_rps));
    out.push_str(&format!("\"mean_batch\":{:.3},", s.mean_batch));
    out.push_str(&format!("\"p50_us\":{:.1},", s.p50_us));
    out.push_str(&format!("\"p95_us\":{:.1},", s.p95_us));
    out.push_str(&format!("\"p99_us\":{:.1},", s.p99_us));
    out.push_str(&format!("\"max_queue_depth\":{},", s.max_queue_depth));
    json_lane_map(&mut out, "lane_depth", routes, &s.lane_depth);
    json_lane_map(&mut out, "lane_shed", routes, &s.lane_shed);
    json_lane_map(&mut out, "lane_expired", routes, &s.lane_expired);
    // lifetime busy fraction per worker (busy µs / uptime); the rolling
    // 1 s window rides alongside when a flight recorder is attached
    out.push_str("\"worker_busy\":[");
    for (i, &busy_us) in s.worker_busy_us.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let frac = if s.uptime_s > 0.0 {
            (busy_us as f64 / 1e6) / s.uptime_s
        } else {
            0.0
        };
        out.push_str(&format!("{frac:.4}"));
    }
    out.push_str("],");
    if let Some(j) = journal {
        out.push_str("\"worker_busy_window\":[");
        for (i, (_, frac)) in worker_busy_window(j).iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!("{frac:.4}"));
        }
        out.push_str("],");
    }
    out.push_str("\"lane_served\":{");
    for (i, r) in routes.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let served = s.lane_served.get(i).copied().unwrap_or(0);
        out.push_str(&format!("\"{}\":{}", r.name, served));
    }
    out.push_str("}}");
    out.into_bytes()
}

/// JSON array of per-layer stage rows (the traced-response trailer).
fn stages_json(layers: &[LayerStages]) -> String {
    let rows: Vec<String> = layers.iter().map(|l| l.to_json()).collect();
    format!("[{}]", rows.join(","))
}

fn prom_metric(out: &mut String, name: &str, kind: &str, help: &str) {
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
}

fn prom_value(out: &mut String, name: &str, labels: &str, v: u64) {
    if labels.is_empty() {
        out.push_str(&format!("{name} {v}\n"));
    } else {
        out.push_str(&format!("{name}{{{labels}}} {v}\n"));
    }
}

fn prom_value_f(out: &mut String, name: &str, labels: &str, v: f64) {
    if labels.is_empty() {
        out.push_str(&format!("{name} {v}\n"));
    } else {
        out.push_str(&format!("{name}{{{labels}}} {v}\n"));
    }
}

/// One histogram as a Prometheus cumulative series. Bucket bounds are the
/// shared microsecond table ([`crate::obs::histogram::bounds`]) converted
/// to seconds, as the `_seconds` unit convention wants.
fn prom_histogram(out: &mut String, name: &str, help: &str, h: &HistogramSnapshot) {
    prom_metric(out, name, "histogram", help);
    for (bound_us, cum) in h.cumulative() {
        let le = bound_us as f64 / 1e6;
        out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
    }
    // `+Inf` and `_count` both derive from the bucket totals — not the
    // separately-updated `count` atomic — so the cumulative series stays
    // monotone and `+Inf == _count` holds even for a torn snapshot or
    // one where every observation landed in the overflow slot.
    let total = h.total();
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {total}\n"));
    out.push_str(&format!("{name}_sum {}\n", h.sum_us as f64 / 1e6));
    out.push_str(&format!("{name}_count {total}\n"));
}

/// The Prometheus text-format (`version=0.0.4`) metrics exposition:
/// everything in [`metrics_json`] plus the full latency/queue-wait/compute
/// histograms and the per-worker counters.
fn metrics_prom(
    s: &MetricsSnapshot,
    routes: &[Route],
    journal: Option<&Journal>,
    breakers: Option<&[BreakerState]>,
) -> Vec<u8> {
    let mut out = String::with_capacity(8192);
    prom_metric(&mut out, "repro_served_total", "counter", "Requests served.");
    prom_value(&mut out, "repro_served_total", "", s.served);
    prom_metric(&mut out, "repro_batches_total", "counter", "Executable batches run.");
    prom_value(&mut out, "repro_batches_total", "", s.batches);
    prom_metric(&mut out, "repro_errors_total", "counter", "Failed batches.");
    prom_value(&mut out, "repro_errors_total", "", s.errors);
    prom_metric(
        &mut out,
        "repro_shed_total",
        "counter",
        "Requests shed by admission control (queue full).",
    );
    prom_value(&mut out, "repro_shed_total", "", s.shed);
    for (i, r) in routes.iter().enumerate() {
        let shed = s.lane_shed.get(i).copied().unwrap_or(0);
        prom_value(
            &mut out,
            "repro_shed_total",
            &format!("model=\"{}\"", r.name),
            shed,
        );
    }
    prom_metric(
        &mut out,
        "repro_expired_total",
        "counter",
        "Requests dropped pre-compute on an expired deadline.",
    );
    prom_value(&mut out, "repro_expired_total", "", s.expired);
    for (i, r) in routes.iter().enumerate() {
        let expired = s.lane_expired.get(i).copied().unwrap_or(0);
        prom_value(
            &mut out,
            "repro_expired_total",
            &format!("model=\"{}\"", r.name),
            expired,
        );
    }
    prom_metric(
        &mut out,
        "repro_lane_served_total",
        "counter",
        "Requests served per model lane.",
    );
    for (i, r) in routes.iter().enumerate() {
        let served = s.lane_served.get(i).copied().unwrap_or(0);
        prom_value(
            &mut out,
            "repro_lane_served_total",
            &format!("model=\"{}\"", r.name),
            served,
        );
    }
    prom_metric(
        &mut out,
        "repro_worker_batches_total",
        "counter",
        "Batches executed per dispatcher worker.",
    );
    for (w, &n) in s.worker_batches.iter().enumerate() {
        prom_value(&mut out, "repro_worker_batches_total", &format!("worker=\"{w}\""), n);
    }
    prom_metric(
        &mut out,
        "repro_worker_served_total",
        "counter",
        "Requests served per dispatcher worker.",
    );
    for (w, &n) in s.worker_served.iter().enumerate() {
        prom_value(&mut out, "repro_worker_served_total", &format!("worker=\"{w}\""), n);
    }
    prom_metric(
        &mut out,
        "repro_max_queue_depth",
        "gauge",
        "High-water mark of any lane's queue depth.",
    );
    prom_value(&mut out, "repro_max_queue_depth", "", s.max_queue_depth);
    prom_metric(
        &mut out,
        "repro_lane_queue_depth",
        "gauge",
        "Current queued requests per model lane.",
    );
    for (i, r) in routes.iter().enumerate() {
        let depth = s.lane_depth.get(i).copied().unwrap_or(0);
        prom_value(
            &mut out,
            "repro_lane_queue_depth",
            &format!("model=\"{}\"", r.name),
            depth,
        );
    }
    prom_metric(
        &mut out,
        "repro_in_flight",
        "gauge",
        "Requests currently inside the coordinator (accepted, unresolved).",
    );
    prom_value(&mut out, "repro_in_flight", "", s.in_flight);
    prom_metric(
        &mut out,
        "repro_watchdog_stalls_total",
        "counter",
        "Stall/over-age observations by the serving watchdog.",
    );
    prom_value(&mut out, "repro_watchdog_stalls_total", "", s.watchdog_stalls);
    prom_metric(
        &mut out,
        "repro_worker_panics_total",
        "counter",
        "Dispatcher panics contained by the supervisor (DESIGN.md §15).",
    );
    prom_value(&mut out, "repro_worker_panics_total", "", s.worker_panics);
    prom_metric(
        &mut out,
        "repro_quarantined_total",
        "counter",
        "Requests answered with a typed quarantine fault (panicked alone on retry).",
    );
    prom_value(&mut out, "repro_quarantined_total", "", s.quarantined);
    prom_metric(
        &mut out,
        "repro_lane_down_total",
        "counter",
        "Submits rejected because the lane's circuit breaker was open.",
    );
    prom_value(&mut out, "repro_lane_down_total", "", s.lane_down);
    prom_metric(
        &mut out,
        "repro_live_workers",
        "gauge",
        "Dispatcher workers currently running (supervisor keeps this at the configured strength).",
    );
    prom_value(&mut out, "repro_live_workers", "", s.live_workers);
    if let Some(states) = breakers {
        prom_metric(
            &mut out,
            "repro_breaker_state",
            "gauge",
            "Per-lane circuit breaker state: 0 closed, 1 half-open, 2 open.",
        );
        for (i, r) in routes.iter().enumerate() {
            let code = states.get(i).map(|st| st.code()).unwrap_or(0);
            prom_value(&mut out, "repro_breaker_state", &format!("model=\"{}\"", r.name), code);
        }
    }
    prom_metric(
        &mut out,
        "repro_worker_busy_fraction",
        "gauge",
        "Dispatcher busy fraction: rolling 1s window from the flight recorder when attached, lifetime busy-time/uptime otherwise.",
    );
    if let Some(j) = journal {
        for (idx, frac) in worker_busy_window(j) {
            prom_value_f(
                &mut out,
                "repro_worker_busy_fraction",
                &format!("worker=\"{idx}\""),
                frac,
            );
        }
    } else {
        for (w, &busy_us) in s.worker_busy_us.iter().enumerate() {
            let frac = if s.uptime_s > 0.0 {
                (busy_us as f64 / 1e6) / s.uptime_s
            } else {
                0.0
            };
            prom_value_f(
                &mut out,
                "repro_worker_busy_fraction",
                &format!("worker=\"{w}\""),
                frac,
            );
        }
    }
    prom_histogram(
        &mut out,
        "repro_request_latency_seconds",
        "End-to-end request latency (submit to response send).",
        &s.latency_hist,
    );
    prom_histogram(
        &mut out,
        "repro_queue_wait_seconds",
        "Queue + batch-formation wait (total latency minus compute).",
        &s.queue_hist,
    );
    prom_histogram(
        &mut out,
        "repro_compute_seconds",
        "Executable wall time of the batch each request rode in.",
        &s.compute_hist,
    );
    out.into_bytes()
}

#[cfg(test)]
mod tests {
    use super::{healthz_json, metrics_prom, prom_histogram, BreakerState, Route, ServerConfig};
    use crate::coordinator::Metrics;
    use crate::obs::histogram::Histogram;

    fn two_routes() -> Vec<Route> {
        vec![
            Route {
                name: "dcgan".to_string(),
                z_len: 100,
                image_len: 12288,
            },
            Route {
                name: "sngan".to_string(),
                z_len: 128,
                image_len: 3072,
            },
        ]
    }

    #[test]
    fn healthz_reports_per_model_state_and_draining() {
        let m = Metrics::with_lanes(2, 2);
        m.record_batch(0, 0, 3, 100, 120);
        m.record_shed(1);
        let mut snap = m.snapshot();
        snap.lane_depth = vec![4, 0];
        let scfg = ServerConfig::default();
        let routes = two_routes();

        let body = String::from_utf8(healthz_json(&snap, &routes, &scfg, false, None)).unwrap();
        assert!(body.starts_with("{\"status\":\"ok\",\"draining\":false,"), "{body}");
        assert!(body.contains("\"served\":3,"), "{body}");
        assert!(body.contains("\"shed\":1,"), "{body}");
        assert!(
            body.contains(&format!(
                "{{\"name\":\"dcgan\",\"ready\":true,\"depth\":4,\"cap\":{},\"served\":3,\"shed\":0,\"expired\":0}}",
                scfg.queue_cap
            )),
            "{body}"
        );
        assert!(body.contains("\"name\":\"sngan\",\"ready\":true,\"depth\":0,"), "{body}");
        assert!(body.contains("\"shed\":1,\"expired\":0}"), "{body}");

        let draining = String::from_utf8(healthz_json(&snap, &routes, &scfg, true, None)).unwrap();
        assert!(
            draining.starts_with("{\"status\":\"draining\",\"draining\":true,"),
            "{draining}"
        );
        assert!(draining.contains("\"ready\":false"), "{draining}");
    }

    #[test]
    fn prom_exposition_has_labeled_lane_series_and_gauges() {
        let m = Metrics::with_lanes(2, 2);
        m.record_batch(0, 0, 2, 50, 60);
        m.record_shed(0);
        m.record_expired(1);
        m.inc_in_flight();
        m.record_watchdog_stall();
        let mut snap = m.snapshot();
        snap.lane_depth = vec![7, 2];
        let text = String::from_utf8(metrics_prom(&snap, &two_routes(), None, None)).unwrap();
        assert!(text.contains("repro_shed_total 1\n"), "{text}");
        assert!(text.contains("repro_shed_total{model=\"dcgan\"} 1\n"), "{text}");
        assert!(text.contains("repro_shed_total{model=\"sngan\"} 0\n"), "{text}");
        assert!(text.contains("repro_expired_total{model=\"sngan\"} 1\n"), "{text}");
        assert!(text.contains("repro_lane_queue_depth{model=\"dcgan\"} 7\n"), "{text}");
        assert!(text.contains("repro_lane_queue_depth{model=\"sngan\"} 2\n"), "{text}");
        assert!(text.contains("repro_in_flight 1\n"), "{text}");
        assert!(text.contains("repro_watchdog_stalls_total 1\n"), "{text}");
        assert!(text.contains("repro_worker_busy_fraction{worker=\"0\"}"), "{text}");
        // one HELP/TYPE block per family even with labeled samples
        assert_eq!(text.matches("# TYPE repro_shed_total counter").count(), 1, "{text}");
    }

    #[test]
    fn fault_tolerance_fields_ride_healthz_and_prom() {
        let m = Metrics::with_lanes(2, 2);
        m.inc_live_workers();
        m.inc_live_workers();
        m.record_worker_panic();
        m.record_quarantined();
        m.record_lane_down();
        let snap = m.snapshot();
        let scfg = ServerConfig::default();
        let routes = two_routes();
        let states = [BreakerState::Closed, BreakerState::Open];

        let body =
            String::from_utf8(healthz_json(&snap, &routes, &scfg, false, Some(&states))).unwrap();
        assert!(body.contains("\"live_workers\":2,"), "{body}");
        assert!(body.contains("\"worker_panics\":1,"), "{body}");
        assert!(body.contains("\"quarantined\":1,"), "{body}");
        assert!(body.contains("\"breaker\":\"closed\""), "{body}");
        assert!(body.contains("\"breaker\":\"open\""), "{body}");
        // without breakers configured, the field is absent entirely
        let plain = String::from_utf8(healthz_json(&snap, &routes, &scfg, false, None)).unwrap();
        assert!(!plain.contains("breaker"), "{plain}");

        let text = String::from_utf8(metrics_prom(&snap, &routes, None, Some(&states))).unwrap();
        assert!(text.contains("repro_worker_panics_total 1\n"), "{text}");
        assert!(text.contains("repro_quarantined_total 1\n"), "{text}");
        assert!(text.contains("repro_lane_down_total 1\n"), "{text}");
        assert!(text.contains("repro_live_workers 2\n"), "{text}");
        assert!(text.contains("repro_breaker_state{model=\"dcgan\"} 0\n"), "{text}");
        assert!(text.contains("repro_breaker_state{model=\"sngan\"} 2\n"), "{text}");
        let no_breaker = String::from_utf8(metrics_prom(&snap, &routes, None, None)).unwrap();
        assert!(!no_breaker.contains("repro_breaker_state"), "{no_breaker}");
    }

    /// Parse every `name_bucket{le=...} v` / `name_count v` line and
    /// assert the series is monotone with `+Inf == _count`.
    fn check_prom(text: &str) -> (u64, u64) {
        let mut prev = 0u64;
        let mut inf = None;
        let mut count = None;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("h_bucket{le=") {
                let v: u64 = rest.rsplit(' ').next().unwrap().parse().unwrap();
                assert!(v >= prev, "non-monotone bucket series: {line}");
                prev = v;
                if rest.starts_with("\"+Inf\"") {
                    inf = Some(v);
                }
            } else if let Some(v) = line.strip_prefix("h_count ") {
                count = Some(v.parse().unwrap());
            }
        }
        (inf.expect("+Inf bucket emitted"), count.expect("_count emitted"))
    }

    #[test]
    fn prom_histogram_inf_equals_count_when_empty() {
        let mut out = String::new();
        prom_histogram(&mut out, "h", "help", &Histogram::new().snapshot());
        assert_eq!(check_prom(&out), (0, 0));
    }

    #[test]
    fn prom_histogram_inf_equals_count_with_overflow_only() {
        let h = Histogram::new();
        h.record(u64::MAX);
        h.record(u64::MAX);
        let mut out = String::new();
        prom_histogram(&mut out, "h", "help", &h.snapshot());
        assert_eq!(check_prom(&out), (2, 2));
    }

    #[test]
    fn prom_histogram_stays_monotone_on_torn_snapshot() {
        // `count` torn ahead of the bucket counters must not make +Inf
        // disagree with the finite cumulative series.
        let h = Histogram::new();
        h.record(5);
        let mut snap = h.snapshot();
        snap.count += 3;
        let mut out = String::new();
        prom_histogram(&mut out, "h", "help", &snap);
        assert_eq!(check_prom(&out), (1, 1));
    }
}
