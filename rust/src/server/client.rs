//! A small blocking HTTP/1.1 client over `std::net::TcpStream` — enough
//! for the socket-level test suite (rust/tests/front_door.rs), the CLI
//! and the open-loop serving bench to talk to the front door without any
//! external HTTP dependency. Supports keep-alive request/response cycles
//! and `Content-Length`-framed bodies (exactly what
//! [`super::http::write_response`] emits).

use std::io::{self, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Duration;

/// A parsed response.
#[derive(Clone, Debug)]
pub struct HttpResponse {
    pub status: u16,
    /// (lowercased name, trimmed value), in order
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    /// First header with this (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// Body as UTF-8 (lossy) — for JSON/error bodies in assertions.
    pub fn text(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// A keep-alive connection to the front door.
pub struct Client {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Client {
    /// Connect with `timeout` applied to connect, reads and writes.
    pub fn connect(addr: SocketAddr, timeout: Duration) -> io::Result<Client> {
        let stream = TcpStream::connect_timeout(&addr, timeout)?;
        stream.set_read_timeout(Some(timeout))?;
        stream.set_write_timeout(Some(timeout))?;
        stream.set_nodelay(true)?;
        Ok(Client {
            stream,
            buf: Vec::new(),
        })
    }

    /// The raw stream — for fault-injection tests that write malformed
    /// bytes or hang up mid-request.
    pub fn stream_mut(&mut self) -> &mut TcpStream {
        &mut self.stream
    }

    /// One request/response cycle on the kept-alive connection.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, String)],
        body: &[u8],
    ) -> io::Result<HttpResponse> {
        let mut out = Vec::with_capacity(256 + body.len());
        out.extend_from_slice(format!("{method} {path} HTTP/1.1\r\n").as_bytes());
        out.extend_from_slice(b"Host: sd\r\n");
        out.extend_from_slice(format!("Content-Length: {}\r\n", body.len()).as_bytes());
        for (name, value) in headers {
            out.extend_from_slice(format!("{name}: {value}\r\n").as_bytes());
        }
        out.extend_from_slice(b"\r\n");
        out.extend_from_slice(body);
        self.stream.write_all(&out)?;
        self.stream.flush()?;
        self.read_response()
    }

    /// GET with no body.
    pub fn get(&mut self, path: &str) -> io::Result<HttpResponse> {
        self.request("GET", path, &[], &[])
    }

    fn read_response(&mut self) -> io::Result<HttpResponse> {
        // 1. header block
        let head_end = loop {
            if let Some(pos) = find_subslice(&self.buf, b"\r\n\r\n") {
                break pos;
            }
            let mut tmp = [0u8; 4096];
            match self.stream.read(&mut tmp)? {
                0 => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "connection closed before a full response header",
                    ));
                }
                n => self.buf.extend_from_slice(&tmp[..n]),
            }
        };
        let head = String::from_utf8_lossy(&self.buf[..head_end]).into_owned();
        let mut lines = head.split("\r\n");
        let status_line = lines.next().unwrap_or("");
        let status = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidData,
                    format!("bad status line {status_line:?}"),
                )
            })?;
        let mut headers = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            if let Some((name, value)) = line.split_once(':') {
                headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
            }
        }
        let content_length = headers
            .iter()
            .find(|(n, _)| n == "content-length")
            .and_then(|(_, v)| v.parse::<usize>().ok())
            .unwrap_or(0);

        // 2. body
        let total = head_end + 4 + content_length;
        while self.buf.len() < total {
            let mut tmp = [0u8; 4096];
            match self.stream.read(&mut tmp)? {
                0 => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "connection closed mid response body",
                    ));
                }
                n => self.buf.extend_from_slice(&tmp[..n]),
            }
        }
        let body = self.buf[head_end + 4..total].to_vec();
        self.buf.drain(..total);
        Ok(HttpResponse {
            status,
            headers,
            body,
        })
    }
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// One-shot convenience: connect, send a `Connection: close` request,
/// return the response.
pub fn request_once(
    addr: SocketAddr,
    timeout: Duration,
    method: &str,
    path: &str,
    headers: &[(&str, String)],
    body: &[u8],
) -> io::Result<HttpResponse> {
    let mut client = Client::connect(addr, timeout)?;
    let mut all: Vec<(&str, String)> = vec![("Connection", "close".to_string())];
    all.extend(headers.iter().map(|(n, v)| (*n, v.clone())));
    client.request(method, path, &all, body)
}
