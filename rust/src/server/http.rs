//! Minimal HTTP/1.1 framing for the front door (the offline registry has
//! no hyper/tokio): a buffering request reader generic over any
//! `Read + Write` stream, and a one-write response serializer.
//!
//! Scope is deliberately small — exactly what the serving protocol needs:
//! request line + headers + `Content-Length` bodies, keep-alive, and a
//! clean three-way read outcome so the connection loop can distinguish
//! "a request arrived" from "the client went away" from "nothing yet —
//! check the shutdown flag and keep waiting" (the front door runs its
//! sockets with a short read timeout for exactly that reason). Chunked
//! transfer encoding, pipelining and HTTP/2 are out of scope.

use std::io::{self, Read, Write};

/// Hard cap on request-line + headers (a malformed or hostile client must
/// not grow the connection buffer unboundedly).
pub const MAX_HEADER_BYTES: usize = 16 * 1024;

/// A parsed request. Header names are lowercased at parse time; values
/// keep their spelling (trimmed).
#[derive(Clone, Debug)]
pub struct HttpRequest {
    /// uppercased method, e.g. "POST"
    pub method: String,
    /// target path without the query string, e.g. "/v1/generate/dcgan"
    pub path: String,
    /// decoded `k=v` query pairs, in order
    pub query: Vec<(String, String)>,
    /// (lowercased name, trimmed value), in order
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// HTTP/1.1 defaults to keep-alive; `Connection: close` (or HTTP/1.0
    /// without `Connection: keep-alive`) turns it off
    pub keep_alive: bool,
}

impl HttpRequest {
    /// First header with this (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(n, _)| n.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// First query parameter with this name.
    pub fn query_param(&self, name: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Outcome of one `read_request` attempt.
pub enum ReadOutcome {
    /// a complete request was framed
    Request(HttpRequest),
    /// the peer closed (or the connection errored) with no request bytes
    /// pending — the connection loop should end quietly
    Eof,
    /// the stream's read timeout fired; any partial bytes stay buffered
    /// and the next call resumes exactly where this one stopped
    IdleTimeout,
}

/// A protocol violation by the client — answer `status` and close.
#[derive(Debug)]
pub struct BadRequest {
    /// response status: 400, except 411 (Length Required) for a bodied
    /// request that declares no `Content-Length` and 413 (Payload Too
    /// Large) for a declared length over the configured body cap
    pub status: u16,
    pub msg: String,
}

impl BadRequest {
    fn new(msg: impl Into<String>) -> BadRequest {
        BadRequest { status: 400, msg: msg.into() }
    }
}

/// A buffering connection: owns the stream plus the carry-over buffer
/// that lets `read_request` survive read timeouts mid-request.
pub struct Conn<S> {
    stream: S,
    buf: Vec<u8>,
}

impl<S: Read + Write> Conn<S> {
    pub fn new(stream: S) -> Conn<S> {
        Conn {
            stream,
            buf: Vec::new(),
        }
    }

    /// The underlying stream, for writing responses.
    pub fn stream_mut(&mut self) -> &mut S {
        &mut self.stream
    }

    /// Read one request off the connection. Returns
    /// [`ReadOutcome::IdleTimeout`] whenever the stream's read timeout
    /// fires (partial bytes are kept for the next call), `Eof` on a clean
    /// disconnect, and `Err(BadRequest)` on a protocol violation.
    pub fn read_request(&mut self, max_body: usize) -> Result<ReadOutcome, BadRequest> {
        // 1. accumulate until the full header block is buffered
        let head_end = loop {
            if let Some(pos) = find_subslice(&self.buf, b"\r\n\r\n") {
                break pos;
            }
            if self.buf.len() > MAX_HEADER_BYTES {
                return Err(BadRequest::new("request header too large"));
            }
            match self.read_some() {
                ReadStep::Data => {}
                ReadStep::Timeout => return Ok(ReadOutcome::IdleTimeout),
                ReadStep::Closed => {
                    return if self.buf.is_empty() {
                        Ok(ReadOutcome::Eof)
                    } else {
                        Err(BadRequest::new("connection closed mid-header"))
                    };
                }
            }
        };

        // 2. parse request line + headers (bytes stay buffered until the
        //    body is complete too, so a timeout here loses nothing)
        let head = std::str::from_utf8(&self.buf[..head_end])
            .map_err(|_| BadRequest::new("non-UTF8 request header"))?;
        let mut lines = head.split("\r\n");
        let request_line = lines.next().unwrap_or("");
        let mut parts = request_line.split_whitespace();
        let method = parts
            .next()
            .ok_or_else(|| BadRequest::new("empty request line"))?
            .to_ascii_uppercase();
        let target = parts
            .next()
            .ok_or_else(|| BadRequest::new("request line missing target"))?
            .to_string();
        let version = parts
            .next()
            .ok_or_else(|| BadRequest::new("request line missing HTTP version"))?;
        if !version.starts_with("HTTP/1.") {
            return Err(BadRequest::new(format!("unsupported version {version}")));
        }
        let mut headers: Vec<(String, String)> = Vec::new();
        for line in lines {
            if line.is_empty() {
                continue;
            }
            let (name, value) = line
                .split_once(':')
                .ok_or_else(|| BadRequest::new(format!("malformed header line {line:?}")))?;
            headers.push((name.trim().to_ascii_lowercase(), value.trim().to_string()));
        }
        // Content-Length: digits only (`parse` alone would admit a "+"
        // sign), overflow is a plain 400 (never a panic or a stalled
        // read), and every copy of the header must agree — a disagreeing
        // duplicate is the classic request-smuggling shape.
        let mut declared_length: Option<usize> = None;
        for (_, v) in headers.iter().filter(|(n, _)| n == "content-length") {
            if v.is_empty() || !v.bytes().all(|b| b.is_ascii_digit()) {
                return Err(BadRequest::new(format!("bad content-length {v:?}")));
            }
            let parsed = v
                .parse::<usize>()
                .map_err(|_| BadRequest::new(format!("content-length {v:?} overflows")))?;
            match declared_length {
                Some(prev) if prev != parsed => {
                    return Err(BadRequest::new(format!(
                        "conflicting content-length headers ({prev} vs {parsed})"
                    )));
                }
                _ => declared_length = Some(parsed),
            }
        }
        let content_length = match declared_length {
            Some(len) => len,
            // a bodied request must declare its length (chunked encoding
            // is out of scope here): 411 Length Required, not a stalled
            // read waiting for bytes the client never frames
            None if matches!(method.as_str(), "POST" | "PUT" | "PATCH") => {
                return Err(BadRequest {
                    status: 411,
                    msg: format!("{method} request without content-length"),
                });
            }
            None => 0,
        };
        if content_length > max_body {
            // hostile-client guard: reject by DECLARED length before
            // reading a single body byte — 413, not an unbounded buffer
            return Err(BadRequest {
                status: 413,
                msg: format!("body of {content_length} bytes exceeds the {max_body}-byte limit"),
            });
        }
        let connection = headers
            .iter()
            .find(|(n, _)| n == "connection")
            .map(|(_, v)| v.to_ascii_lowercase());
        let keep_alive = if version == "HTTP/1.0" {
            connection.as_deref() == Some("keep-alive")
        } else {
            connection.as_deref() != Some("close")
        };

        // 3. accumulate the body
        let total = head_end + 4 + content_length;
        while self.buf.len() < total {
            match self.read_some() {
                ReadStep::Data => {}
                ReadStep::Timeout => return Ok(ReadOutcome::IdleTimeout),
                ReadStep::Closed => {
                    return Err(BadRequest::new("connection closed mid-body"));
                }
            }
        }
        let body = self.buf[head_end + 4..total].to_vec();
        self.buf.drain(..total);

        let (path, query) = split_target(&target);
        Ok(ReadOutcome::Request(HttpRequest {
            method,
            path,
            query,
            headers,
            body,
            keep_alive,
        }))
    }

    fn read_some(&mut self) -> ReadStep {
        let mut tmp = [0u8; 4096];
        loop {
            match self.stream.read(&mut tmp) {
                Ok(0) => return ReadStep::Closed,
                Ok(n) => {
                    self.buf.extend_from_slice(&tmp[..n]);
                    return ReadStep::Data;
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e)
                    if e.kind() == io::ErrorKind::WouldBlock
                        || e.kind() == io::ErrorKind::TimedOut =>
                {
                    return ReadStep::Timeout;
                }
                // a hard connection error mid-read: treat like a close
                Err(_) => return ReadStep::Closed,
            }
        }
    }
}

enum ReadStep {
    Data,
    Timeout,
    Closed,
}

/// Split a request target into (path, query pairs).
fn split_target(target: &str) -> (String, Vec<(String, String)>) {
    match target.split_once('?') {
        None => (target.to_string(), Vec::new()),
        Some((path, qs)) => {
            let query = qs
                .split('&')
                .filter(|kv| !kv.is_empty())
                .map(|kv| match kv.split_once('=') {
                    Some((k, v)) => (k.to_string(), v.to_string()),
                    None => (kv.to_string(), String::new()),
                })
                .collect();
            (path.to_string(), query)
        }
    }
}

fn find_subslice(haystack: &[u8], needle: &[u8]) -> Option<usize> {
    haystack.windows(needle.len()).position(|w| w == needle)
}

/// Serialize and send one response in a single `write_all` (status line,
/// `Content-Type`/`Content-Length`/`Connection`, extra headers, body).
pub fn write_response(
    w: &mut impl Write,
    status: u16,
    content_type: &str,
    extra_headers: &[(&str, String)],
    body: &[u8],
    keep_alive: bool,
) -> io::Result<()> {
    let mut out = Vec::with_capacity(256 + body.len());
    out.extend_from_slice(format!("HTTP/1.1 {status} {}\r\n", reason(status)).as_bytes());
    out.extend_from_slice(format!("Content-Type: {content_type}\r\n").as_bytes());
    out.extend_from_slice(format!("Content-Length: {}\r\n", body.len()).as_bytes());
    out.extend_from_slice(if keep_alive {
        b"Connection: keep-alive\r\n".as_slice()
    } else {
        b"Connection: close\r\n".as_slice()
    });
    for (name, value) in extra_headers {
        out.extend_from_slice(format!("{name}: {value}\r\n").as_bytes());
    }
    out.extend_from_slice(b"\r\n");
    out.extend_from_slice(body);
    w.write_all(&out)?;
    w.flush()
}

/// Reason phrase for the status codes the front door emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

/// `{"error": kind, "detail": detail}` — the uniform error body shape.
pub fn error_body(kind: &str, detail: &str) -> Vec<u8> {
    format!(
        "{{\"error\":\"{}\",\"detail\":\"{}\"}}",
        json_escape(kind),
        json_escape(detail)
    )
    .into_bytes()
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Little-endian f32 wire encoding of a latent/image vector.
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Inverse of [`f32s_to_bytes`]; `None` when the byte count is not a
/// multiple of 4.
pub fn bytes_to_f32s(b: &[u8]) -> Option<Vec<f32>> {
    if b.len() % 4 != 0 {
        return None;
    }
    Some(
        b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse_one(raw: &[u8]) -> Result<ReadOutcome, BadRequest> {
        Conn::new(Cursor::new(raw.to_vec())).read_request(1 << 20)
    }

    #[test]
    fn parses_post_with_body_and_query() {
        let raw = b"POST /v1/generate/dcgan?seed=7&x=1 HTTP/1.1\r\n\
                    Host: sd\r\nX-Deadline-Ms: 250\r\nContent-Length: 8\r\n\r\n\
                    ABCDEFGH";
        match parse_one(raw).unwrap() {
            ReadOutcome::Request(r) => {
                assert_eq!(r.method, "POST");
                assert_eq!(r.path, "/v1/generate/dcgan");
                assert_eq!(r.query_param("seed"), Some("7"));
                assert_eq!(r.query_param("x"), Some("1"));
                assert_eq!(r.header("x-deadline-ms"), Some("250"));
                assert_eq!(r.header("X-DEADLINE-MS"), Some("250"));
                assert_eq!(r.body, b"ABCDEFGH");
                assert!(r.keep_alive, "HTTP/1.1 defaults to keep-alive");
            }
            _ => panic!("expected a parsed request"),
        }
    }

    #[test]
    fn connection_close_disables_keep_alive() {
        let raw = b"GET /healthz HTTP/1.1\r\nConnection: Close\r\n\r\n";
        match parse_one(raw).unwrap() {
            ReadOutcome::Request(r) => assert!(!r.keep_alive),
            _ => panic!("expected a parsed request"),
        }
    }

    #[test]
    fn two_pipelined_requests_frame_separately() {
        let raw = b"GET /a HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi\
                    GET /b HTTP/1.1\r\n\r\n";
        let mut conn = Conn::new(Cursor::new(raw.to_vec()));
        match conn.read_request(1 << 20).unwrap() {
            ReadOutcome::Request(r) => {
                assert_eq!(r.path, "/a");
                assert_eq!(r.body, b"hi");
            }
            _ => panic!("first request"),
        }
        match conn.read_request(1 << 20).unwrap() {
            ReadOutcome::Request(r) => {
                assert_eq!(r.path, "/b");
                assert!(r.body.is_empty());
            }
            _ => panic!("second request"),
        }
    }

    #[test]
    fn malformed_inputs_are_bad_requests_not_panics() {
        assert!(parse_one(b"squeamish ossifrage\r\n\r\n").is_err());
        assert!(parse_one(b"GET /x HTTP/2.0\r\n\r\n").is_err());
        assert!(parse_one(b"GET /x HTTP/1.1\r\nbroken header line\r\n\r\n").is_err());
        assert!(parse_one(b"GET /x HTTP/1.1\r\nContent-Length: ten\r\n\r\n").is_err());
        // truncated: header never completes and the stream ends
        assert!(parse_one(b"GET /x HT").is_err());
        // body larger than the cap is refused before buffering it,
        // with the typed 413 status
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 99\r\n\r\n";
        let err = match Conn::new(Cursor::new(raw.to_vec())).read_request(10) {
            Err(e) => e,
            Ok(_) => panic!("oversized declared body must be rejected"),
        };
        assert_eq!(err.status, 413);
    }

    #[test]
    fn content_length_must_be_digits_only() {
        // usize::parse would admit these; the wire grammar must not
        assert!(parse_one(b"POST /x HTTP/1.1\r\nContent-Length: +3\r\n\r\nabc").is_err());
        assert!(parse_one(b"POST /x HTTP/1.1\r\nContent-Length: \r\n\r\n").is_err());
        // overflow of usize is a 400, not a panic or a stalled read
        let huge = b"POST /x HTTP/1.1\r\nContent-Length: 99999999999999999999999999\r\n\r\n";
        let err = match parse_one(huge) {
            Err(e) => e,
            Ok(_) => panic!("overflowing length must be rejected"),
        };
        assert_eq!(err.status, 400);
    }

    #[test]
    fn duplicate_content_length_must_agree() {
        // agreeing copies coalesce
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 3\r\n\r\nabc";
        match parse_one(raw).unwrap() {
            ReadOutcome::Request(r) => assert_eq!(r.body, b"abc"),
            _ => panic!("agreeing duplicates are fine"),
        }
        // disagreeing copies are the request-smuggling shape: reject
        let raw = b"POST /x HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 5\r\n\r\nabcde";
        let err = parse_one(raw).err().expect("disagreeing lengths rejected");
        assert_eq!(err.status, 400);
    }

    #[test]
    fn bodied_method_without_length_is_411() {
        let err = parse_one(b"POST /x HTTP/1.1\r\nHost: sd\r\n\r\n")
            .err()
            .expect("POST without content-length rejected");
        assert_eq!(err.status, 411);
        // GET without a length is a normal zero-body request
        match parse_one(b"GET /x HTTP/1.1\r\n\r\n").unwrap() {
            ReadOutcome::Request(r) => assert!(r.body.is_empty()),
            _ => panic!("GET without length is fine"),
        }
    }

    #[test]
    fn clean_eof_before_any_byte_is_eof() {
        match parse_one(b"").unwrap() {
            ReadOutcome::Eof => {}
            _ => panic!("expected Eof"),
        }
    }

    #[test]
    fn oversized_header_is_rejected() {
        let mut raw = Vec::from(&b"GET /x HTTP/1.1\r\nX-Pad: "[..]);
        raw.resize(raw.len() + MAX_HEADER_BYTES + 10, b'a');
        assert!(parse_one(&raw).is_err());
    }

    /// Read side that times out once, then yields data: the partial bytes
    /// must survive the timeout and the request must complete on resume.
    struct TimeoutOnce {
        chunks: Vec<Vec<u8>>,
        step: usize,
    }

    impl Read for TimeoutOnce {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            let step = self.step;
            self.step += 1;
            match self.chunks.get(step) {
                None => Ok(0),
                Some(c) if c.is_empty() => {
                    Err(io::Error::new(io::ErrorKind::WouldBlock, "timeout"))
                }
                Some(c) => {
                    out[..c.len()].copy_from_slice(c);
                    Ok(c.len())
                }
            }
        }
    }

    impl Write for TimeoutOnce {
        fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
            Ok(buf.len())
        }
        fn flush(&mut self) -> io::Result<()> {
            Ok(())
        }
    }

    #[test]
    fn partial_request_survives_a_read_timeout() {
        let stream = TimeoutOnce {
            chunks: vec![
                b"POST /x HTTP/1.1\r\nContent-".to_vec(),
                Vec::new(), // timeout fires here
                b"Length: 3\r\n\r\nabc".to_vec(),
            ],
            step: 0,
        };
        let mut conn = Conn::new(stream);
        match conn.read_request(1 << 20).unwrap() {
            ReadOutcome::IdleTimeout => {}
            _ => panic!("first attempt must surface the timeout"),
        }
        match conn.read_request(1 << 20).unwrap() {
            ReadOutcome::Request(r) => {
                assert_eq!(r.path, "/x");
                assert_eq!(r.body, b"abc");
            }
            _ => panic!("request must complete after the timeout"),
        }
    }

    #[test]
    fn f32_wire_roundtrip() {
        let v = vec![0.0f32, -1.5, 3.25e-3, f32::MAX];
        let b = f32s_to_bytes(&v);
        assert_eq!(b.len(), 16);
        assert_eq!(bytes_to_f32s(&b).unwrap(), v);
        assert!(bytes_to_f32s(&b[..7]).is_none(), "ragged byte count");
    }

    #[test]
    fn response_serialization_shape() {
        let mut out = Vec::new();
        write_response(
            &mut out,
            503,
            "application/json",
            &[("Retry-After", "0".to_string())],
            b"{\"error\":\"shed\"}",
            true,
        )
        .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        assert!(text.contains("Content-Length: 16\r\n"));
        assert!(text.contains("Retry-After: 0\r\n"));
        assert!(text.contains("Connection: keep-alive\r\n"));
        assert!(text.ends_with("\r\n\r\n{\"error\":\"shed\"}"));
    }
}
