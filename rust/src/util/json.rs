//! Minimal JSON parser and encoder — substrate for reading
//! `artifacts/manifest.json` and for the `.sdprog` artifact manifest.
//!
//! The offline registry carries no serde/serde_json, so this implements the
//! small subset of JSON the AOT manifest uses (objects, arrays, strings,
//! numbers, bools, null) with proper string escapes. Parse errors carry the
//! byte offset for debugging. The encoder is deterministic: object keys are
//! emitted in `BTreeMap` order, so the same `Json` value always serializes
//! to the same bytes — the property the artifact bit-identity gate rests on.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `obj.str_or(key, default)`.
    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Json::as_str).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Json::as_usize).unwrap_or(default)
    }

    /// Deterministic compact serialization: object keys emit in `BTreeMap`
    /// order, numbers via `f64`'s shortest-round-trip `Display` (integers
    /// print without a fractional part), strings with the escapes [`parse`]
    /// understands. `parse(v.encode())` reconstructs `v` exactly for every
    /// finite value.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.encode_into(&mut out);
        out
    }

    fn encode_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                // JSON has no Infinity/NaN; the manifest never produces
                // them, so map to null rather than emit invalid bytes.
                if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => encode_str(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.encode_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    encode_str(k, out);
                    out.push(':');
                    x.encode_into(out);
                }
                out.push('}');
            }
        }
    }
}

fn encode_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            '\u{8}' => out.push_str("\\b"),
            '\u{c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

pub fn parse(input: &str) -> Result<Json, ParseError> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing data"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.i,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (UTF-8 passes through)
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| {
                        self.err("invalid utf-8")
                    })?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let txt = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like() {
        let j = parse(
            r#"{"version":1,"artifacts":[{"name":"a","inputs":[{"shape":[1,100],"bin":"a.in0.bin"}],"macs":1.5e6,"ok":true,"x":null}]}"#,
        )
        .unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].str_or("name", ""), "a");
        let inp = &arts[0].get("inputs").unwrap().as_arr().unwrap()[0];
        let shape: Vec<usize> = inp
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .map(|v| v.as_usize().unwrap())
            .collect();
        assert_eq!(shape, vec![1, 100]);
        assert_eq!(arts[0].get("macs").unwrap().as_f64(), Some(1.5e6));
        assert_eq!(arts[0].get("ok"), Some(&Json::Bool(true)));
    }

    #[test]
    fn escapes() {
        let j = parse(r#""a\n\"bA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\n\"bA"));
    }

    #[test]
    fn rejects_trailing() {
        assert!(parse("{} x").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\":}").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("0").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn nested_empty() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn encode_round_trips_and_is_deterministic() {
        let src = r#"{"blobs":[{"len":1024,"sha256":"ab\"c","kind":"packed_b"}],"scale":0.0078125,"neg":-3.5e-9,"version":1,"nul":null,"ok":true,"esc":"a\n\tb"}"#;
        let v = parse(src).unwrap();
        let enc = v.encode();
        assert_eq!(parse(&enc).unwrap(), v, "parse(encode(v)) == v");
        assert_eq!(parse(&enc).unwrap().encode(), enc, "encode is a fixpoint");
        // integers print without a fractional part; keys are sorted
        assert_eq!(Json::Num(1.0).encode(), "1");
        let mut m = BTreeMap::new();
        m.insert("b".to_string(), Json::Num(2.0));
        m.insert("a".to_string(), Json::Num(1.0));
        assert_eq!(Json::Obj(m).encode(), r#"{"a":1,"b":2}"#);
    }

    #[test]
    fn encode_f32_scales_exactly() {
        // in_scale values are f32; f32 -> f64 -> Display -> parse -> f32
        // must be lossless for the bit-identity gate.
        for s in [0.003921569f32, 1.0 / 3.0, f32::MIN_POSITIVE, 127.0] {
            let enc = Json::Num(s as f64).encode();
            let back = parse(&enc).unwrap().as_f64().unwrap() as f32;
            assert_eq!(back.to_bits(), s.to_bits(), "{s} via {enc}");
        }
    }

    #[test]
    fn encode_control_chars() {
        let v = Json::Str("\u{1}x".to_string());
        assert_eq!(v.encode(), r#""\u0001x""#);
        assert_eq!(parse(&v.encode()).unwrap(), v);
    }
}
