//! Small substrates the offline environment lacks crates for:
//! deterministic RNG, a minimal JSON parser/encoder, SHA-256,
//! aligned blob storage, timing helpers.

pub mod blob;
pub mod json;
pub mod rng;
pub mod sha256;

use std::time::Instant;

/// Measure wall-clock of `f` over `iters` iterations, returning seconds/iter
/// (minimum over 3 repeats — robust to scheduler noise, standard practice).
pub fn time_it<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed().as_secs_f64() / iters as f64;
        best = best.min(dt);
    }
    best
}

/// Format a MAC count in human units (as in the paper's tables: millions).
pub fn fmt_macs(macs: u64) -> String {
    format!("{:.2}", macs as f64 / 1e6)
}

/// Geometric mean of a slice (used for figure-level speedup averages).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[2.0, 8.0]) - 4.0).abs() < 1e-9);
        assert_eq!(geomean(&[]), 0.0);
    }

    #[test]
    fn fmt_macs_millions() {
        assert_eq!(fmt_macs(109_770_000), "109.77");
    }
}
