//! Aligned shared byte buffers and typed views — the substrate of the
//! `.sdprog` artifact loader's zero-copy mode.
//!
//! [`AlignedBytes`] owns a byte buffer whose base address is at least
//! 8-byte aligned (it is backed by a `Vec<u64>`), so any blob placed at a
//! 64-byte-aligned *file* offset can be reinterpreted in place as `f32` /
//! `u32` / `i8` elements without copying. [`BlobVec<T>`] is the
//! owned-or-borrowed payload storage the packed GEMM operands
//! ([`crate::tensor::gemm::PackedB`], [`crate::quant::QPackedB`]) use: an
//! ordinary `Vec<T>` when packed in process, or an `Arc`-shared slice of a
//! loaded artifact's blob region when `Program::load` runs in zero-copy
//! mode.
//!
//! The in-place views read the bytes at **native** endianness; the
//! `.sdprog` format is little-endian, so the artifact loader only takes
//! the shared path on little-endian targets (the copy path decodes with
//! explicit `from_le_bytes` and works everywhere).

use std::io::Read;
use std::sync::Arc;

/// An immutable byte buffer with at least 8-byte base alignment.
pub struct AlignedBytes {
    /// backing storage; `u64` gives the 8-byte base alignment
    words: Vec<u64>,
    /// logical byte length (the tail of the last word is padding)
    len: usize,
}

impl AlignedBytes {
    /// Copy `bytes` into a fresh aligned buffer.
    pub fn from_bytes(bytes: &[u8]) -> AlignedBytes {
        let mut a = AlignedBytes::zeroed(bytes.len());
        a.bytes_mut().copy_from_slice(bytes);
        a
    }

    /// Read exactly `len` bytes from `r` into a fresh aligned buffer.
    pub fn read_exact_from(r: &mut impl Read, len: usize) -> std::io::Result<AlignedBytes> {
        let mut a = AlignedBytes::zeroed(len);
        r.read_exact(a.bytes_mut())?;
        Ok(a)
    }

    fn zeroed(len: usize) -> AlignedBytes {
        AlignedBytes {
            words: vec![0u64; len.div_ceil(std::mem::size_of::<u64>())],
            len,
        }
    }

    fn bytes_mut(&mut self) -> &mut [u8] {
        // SAFETY: the Vec<u64> owns at least `len` initialized bytes and
        // u8 has no alignment requirement.
        unsafe { std::slice::from_raw_parts_mut(self.words.as_mut_ptr() as *mut u8, self.len) }
    }

    pub fn as_bytes(&self) -> &[u8] {
        // SAFETY: as above, shared borrow.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.len) }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl std::fmt::Debug for AlignedBytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "AlignedBytes({} bytes)", self.len)
    }
}

/// Element types that may be viewed in place inside an [`AlignedBytes`].
///
/// # Safety
///
/// Implementors must be plain-old-data: any byte pattern is a valid value
/// and the type has no padding or drop glue.
pub unsafe trait BlobElem: Copy + 'static {}
unsafe impl BlobElem for f32 {}
unsafe impl BlobElem for i8 {}
unsafe impl BlobElem for u32 {}

/// Owned-or-shared element storage for packed operand payloads.
#[derive(Clone, Debug)]
pub enum BlobVec<T: BlobElem> {
    /// an ordinary in-process buffer (the pack-time form)
    Owned(Vec<T>),
    /// a borrowed window of a shared aligned buffer (the zero-copy
    /// artifact-load form); `off`/`len` are in elements of `T` over a
    /// construction-time-validated range
    Shared {
        buf: Arc<AlignedBytes>,
        off_bytes: usize,
        len: usize,
    },
}

impl<T: BlobElem> Default for BlobVec<T> {
    fn default() -> Self {
        BlobVec::Owned(Vec::new())
    }
}

impl<T: BlobElem> BlobVec<T> {
    /// Borrow `len` elements starting `off_bytes` into `buf`, without
    /// copying. `None` when the window is out of bounds or the element
    /// alignment does not hold at that address.
    pub fn shared(buf: Arc<AlignedBytes>, off_bytes: usize, len: usize) -> Option<BlobVec<T>> {
        let bytes = len.checked_mul(std::mem::size_of::<T>())?;
        let end = off_bytes.checked_add(bytes)?;
        if end > buf.len() {
            return None;
        }
        let addr = buf.as_bytes().as_ptr() as usize + off_bytes;
        if addr % std::mem::align_of::<T>() != 0 {
            return None;
        }
        Some(BlobVec::Shared { buf, off_bytes, len })
    }

    pub fn as_slice(&self) -> &[T] {
        match self {
            BlobVec::Owned(v) => v,
            BlobVec::Shared { buf, off_bytes, len } => {
                // SAFETY: bounds and alignment were validated in
                // `shared`; the Arc keeps the buffer alive for &self's
                // lifetime; T is plain-old-data (BlobElem contract).
                unsafe {
                    std::slice::from_raw_parts(
                        buf.as_bytes().as_ptr().add(*off_bytes) as *const T,
                        *len,
                    )
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        match self {
            BlobVec::Owned(v) => v.len(),
            BlobVec::Shared { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// The owned vector, converting a shared view into an owned copy
    /// first — the mutation entry point for the `pack_into` buffer-reuse
    /// paths (which only ever run on owned storage in practice).
    pub fn owned_mut(&mut self) -> &mut Vec<T> {
        if let BlobVec::Shared { .. } = self {
            *self = BlobVec::Owned(self.as_slice().to_vec());
        }
        match self {
            BlobVec::Owned(v) => v,
            BlobVec::Shared { .. } => unreachable!("converted to Owned above"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aligned_bytes_roundtrip_and_alignment() {
        let src: Vec<u8> = (0..100u8).collect();
        let a = AlignedBytes::from_bytes(&src);
        assert_eq!(a.as_bytes(), &src[..]);
        assert_eq!(a.len(), 100);
        assert_eq!(a.as_bytes().as_ptr() as usize % 8, 0, "8-byte base alignment");
    }

    #[test]
    fn shared_view_reads_in_place() {
        let floats = [1.0f32, -2.5, 3.25];
        let mut bytes = vec![0u8; 4]; // 4-byte offset keeps f32 alignment
        for f in floats {
            bytes.extend_from_slice(&f.to_le_bytes());
        }
        let buf = Arc::new(AlignedBytes::from_bytes(&bytes));
        let v: BlobVec<f32> = BlobVec::shared(buf.clone(), 4, 3).unwrap();
        if cfg!(target_endian = "little") {
            assert_eq!(v.as_slice(), &floats);
        }
        assert_eq!(v.len(), 3);
        // out of bounds and misaligned windows are refused
        assert!(BlobVec::<f32>::shared(buf.clone(), 4, 4).is_none());
        assert!(BlobVec::<f32>::shared(buf.clone(), 5, 1).is_none());
        // i8 has no alignment requirement
        assert!(BlobVec::<i8>::shared(buf, 5, 3).is_some());
    }

    #[test]
    fn owned_mut_detaches_shared_views() {
        let buf = Arc::new(AlignedBytes::from_bytes(&[1, 2, 3, 4]));
        let mut v: BlobVec<i8> = BlobVec::shared(buf, 0, 4).unwrap();
        let before: Vec<i8> = v.as_slice().to_vec();
        v.owned_mut().push(5);
        assert_eq!(&v.as_slice()[..4], &before[..]);
        assert_eq!(v.len(), 5);
    }
}
