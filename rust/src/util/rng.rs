//! Deterministic PRNG (xoshiro256**) — the offline registry has no `rand`,
//! and determinism matters: every experiment in EXPERIMENTS.md must be
//! reproducible bit-for-bit from a seed.

/// xoshiro256** by Blackman & Vigna (public domain reference algorithm).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so short/low-entropy seeds still fill the state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        // 24 high bits -> f32 mantissa precision
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached second value dropped: simple).
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-7);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.uniform();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let v: Vec<f32> = (0..n).map(|_| r.normal()).collect();
        let mean = v.iter().sum::<f32>() / n as f32;
        let var = v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(5);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }
}
