//! SHA-256 (FIPS 180-4) — the `.sdprog` artifact checksum.
//!
//! The offline registry carries no crypto crates, so this is a std-only
//! implementation. It exists for *integrity* checking of artifact blobs
//! (bit flips, truncation, stale partial writes), not for any adversarial
//! security property — the artifact trust model is "a file you compiled
//! yourself on the same machine".
//!
//! Two compression backends, dispatched once per bulk `update` the same way
//! the GEMM kernels dispatch (`is_x86_feature_detected!`): the portable
//! scalar rounds, and the x86 SHA-NI instruction path (~10x — the
//! difference between artifact load being checksum-bound or I/O-bound on
//! GP-GAN's ~131 MB dense blob, and what keeps load inside the "< 10% of
//! compile time" bench gate). Both are verified against the FIPS 180-4
//! vectors below, and against each other on machines with the extension.

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// Incremental SHA-256 state.
pub struct Sha256 {
    h: [u32; 8],
    /// carry-over of the last partial block
    block: [u8; 64],
    block_len: usize,
    total_len: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Sha256 {
        Sha256 {
            h: H0,
            block: [0; 64],
            block_len: 0,
            total_len: 0,
        }
    }

    /// Absorb `data`.
    pub fn update(&mut self, mut data: &[u8]) {
        self.total_len = self.total_len.wrapping_add(data.len() as u64);
        if self.block_len > 0 {
            let take = (64 - self.block_len).min(data.len());
            self.block[self.block_len..self.block_len + take].copy_from_slice(&data[..take]);
            self.block_len += take;
            data = &data[take..];
            if self.block_len == 64 {
                let block = self.block;
                compress_blocks(&mut self.h, &block);
                self.block_len = 0;
            }
        }
        let whole = data.len() - data.len() % 64;
        compress_blocks(&mut self.h, &data[..whole]);
        let rem = &data[whole..];
        self.block[..rem.len()].copy_from_slice(rem);
        self.block_len = rem.len();
    }

    /// Finish and return the 32-byte digest.
    pub fn finish(mut self) -> [u8; 32] {
        let bit_len = self.total_len.wrapping_mul(8);
        // pad: 0x80, zeros, 8-byte big-endian bit length
        let mut pad = [0u8; 128];
        pad[0] = 0x80;
        let pad_len = if self.block_len < 56 { 56 - self.block_len } else { 120 - self.block_len };
        pad[pad_len..pad_len + 8].copy_from_slice(&bit_len.to_be_bytes());
        self.update_no_len(&pad.clone()[..pad_len + 8]);
        let mut out = [0u8; 32];
        for (i, w) in self.h.iter().enumerate() {
            out[i * 4..i * 4 + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    /// `update` without advancing `total_len` (padding only).
    fn update_no_len(&mut self, data: &[u8]) {
        let saved = self.total_len;
        self.update(data);
        self.total_len = saved;
    }
}

/// Compress a whole-multiple-of-64 run of blocks, dispatching to SHA-NI
/// where the CPU has it.
fn compress_blocks(h: &mut [u32; 8], data: &[u8]) {
    debug_assert_eq!(data.len() % 64, 0);
    #[cfg(target_arch = "x86_64")]
    if sha_ni_available() {
        // SAFETY: feature presence just checked.
        unsafe { compress_blocks_ni(h, data) };
        return;
    }
    compress_blocks_scalar(h, data);
}

fn compress_blocks_scalar(hh: &mut [u32; 8], data: &[u8]) {
    for block in data.chunks_exact(64) {
        let mut w = [0u32; 64];
        for (i, chunk) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = *hh;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        hh[0] = hh[0].wrapping_add(a);
        hh[1] = hh[1].wrapping_add(b);
        hh[2] = hh[2].wrapping_add(c);
        hh[3] = hh[3].wrapping_add(d);
        hh[4] = hh[4].wrapping_add(e);
        hh[5] = hh[5].wrapping_add(f);
        hh[6] = hh[6].wrapping_add(g);
        hh[7] = hh[7].wrapping_add(h);
    }
}

#[cfg(target_arch = "x86_64")]
fn sha_ni_available() -> bool {
    use std::sync::atomic::{AtomicU8, Ordering};
    static STATE: AtomicU8 = AtomicU8::new(0); // 0 unknown, 1 no, 2 yes
    match STATE.load(Ordering::Relaxed) {
        1 => false,
        2 => true,
        _ => {
            let yes = is_x86_feature_detected!("sha")
                && is_x86_feature_detected!("sse4.1")
                && is_x86_feature_detected!("ssse3");
            STATE.store(if yes { 2 } else { 1 }, Ordering::Relaxed);
            yes
        }
    }
}

/// The SHA-NI compression loop — Intel's canonical `sha256rnds2` /
/// `sha256msg1` / `sha256msg2` schedule with the state held as the
/// `{a,b,e,f}` / `{c,d,g,h}` lane pair the instructions expect.
///
/// # Safety
///
/// Caller must ensure the `sha`, `sse4.1`, and `ssse3` features are
/// available and `data.len()` is a multiple of 64.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sha,sse2,ssse3,sse4.1")]
unsafe fn compress_blocks_ni(h: &mut [u32; 8], data: &[u8]) {
    use std::arch::x86_64::*;
    // per-dword big-endian byte swap for the message loads
    let bswap = _mm_set_epi64x(0x0c0d0e0f_08090a0bu64 as i64, 0x04050607_00010203u64 as i64);
    // state [a,b,c,d] / [e,f,g,h] -> abef / cdgh lane layout
    let tmp = _mm_loadu_si128(h.as_ptr() as *const __m128i);
    let st1 = _mm_loadu_si128(h.as_ptr().add(4) as *const __m128i);
    let tmp = _mm_shuffle_epi32(tmp, 0xB1);
    let st1 = _mm_shuffle_epi32(st1, 0x1B);
    let mut state0 = _mm_alignr_epi8(tmp, st1, 8);
    let mut state1 = _mm_blend_epi16(st1, tmp, 0xF0);
    for block in data.chunks_exact(64) {
        let abef_save = state0;
        let cdgh_save = state1;
        let bp = block.as_ptr() as *const __m128i;
        let mut m = [
            _mm_shuffle_epi8(_mm_loadu_si128(bp), bswap),
            _mm_shuffle_epi8(_mm_loadu_si128(bp.add(1)), bswap),
            _mm_shuffle_epi8(_mm_loadu_si128(bp.add(2)), bswap),
            _mm_shuffle_epi8(_mm_loadu_si128(bp.add(3)), bswap),
        ];
        for j in 0..16 {
            let wk = _mm_add_epi32(
                m[j & 3],
                _mm_loadu_si128(K.as_ptr().add(4 * j) as *const __m128i),
            );
            state1 = _mm_sha256rnds2_epu32(state1, state0, wk);
            state0 = _mm_sha256rnds2_epu32(state0, state1, _mm_shuffle_epi32(wk, 0x0E));
            if j < 12 {
                // schedule W[4j+16 .. 4j+19] into the slot just consumed
                let t = _mm_alignr_epi8(m[(j + 3) & 3], m[(j + 2) & 3], 4);
                let s = _mm_sha256msg1_epu32(m[j & 3], m[(j + 1) & 3]);
                m[j & 3] = _mm_sha256msg2_epu32(_mm_add_epi32(s, t), m[(j + 3) & 3]);
            }
        }
        state0 = _mm_add_epi32(state0, abef_save);
        state1 = _mm_add_epi32(state1, cdgh_save);
    }
    // abef / cdgh -> [a,b,c,d] / [e,f,g,h]
    let tmp = _mm_shuffle_epi32(state0, 0x1B);
    let st1 = _mm_shuffle_epi32(state1, 0xB1);
    _mm_storeu_si128(
        h.as_mut_ptr() as *mut __m128i,
        _mm_blend_epi16(tmp, st1, 0xF0),
    );
    _mm_storeu_si128(h.as_mut_ptr().add(4) as *mut __m128i, _mm_alignr_epi8(st1, tmp, 8));
}

/// One-shot digest.
pub fn digest(data: &[u8]) -> [u8; 32] {
    let mut s = Sha256::new();
    s.update(data);
    s.finish()
}

/// One-shot digest as lowercase hex — the manifest's `sha256` field shape.
pub fn hex_digest(data: &[u8]) -> String {
    to_hex(&digest(data))
}

/// Lowercase hex of a digest.
pub fn to_hex(d: &[u8; 32]) -> String {
    let mut s = String::with_capacity(64);
    for b in d {
        s.push_str(&format!("{b:02x}"));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    // FIPS 180-4 / RFC 6234 test vectors.
    #[test]
    fn fips_vectors() {
        assert_eq!(
            hex_digest(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            hex_digest(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            hex_digest(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // one million 'a's (streamed, exercising block carry-over)
        let mut s = Sha256::new();
        let chunk = [b'a'; 997]; // deliberately not a multiple of 64
        let mut left = 1_000_000usize;
        while left > 0 {
            let n = left.min(chunk.len());
            s.update(&chunk[..n]);
            left -= n;
        }
        assert_eq!(
            to_hex(&s.finish()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn split_updates_match_one_shot() {
        let data: Vec<u8> = (0..1000u32).flat_map(|i| i.to_le_bytes()).collect();
        let whole = digest(&data);
        for split in [1usize, 63, 64, 65, 700] {
            let mut s = Sha256::new();
            for c in data.chunks(split) {
                s.update(c);
            }
            assert_eq!(s.finish(), whole, "split {split}");
        }
    }

    #[test]
    fn ni_backend_matches_scalar() {
        #[cfg(target_arch = "x86_64")]
        if sha_ni_available() {
            let mut rng = crate::util::rng::Rng::new(42);
            for blocks in [1usize, 2, 3, 7] {
                let data: Vec<u8> =
                    (0..blocks * 64).map(|_| (rng.next_u64() & 0xff) as u8).collect();
                let mut hs = H0;
                let mut hn = H0;
                compress_blocks_scalar(&mut hs, &data);
                // SAFETY: feature presence checked above.
                unsafe { compress_blocks_ni(&mut hn, &data) };
                assert_eq!(hs, hn, "{blocks} blocks");
            }
        }
    }
}
