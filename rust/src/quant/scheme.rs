//! Quantization scheme: symmetric int8, per-output-channel for weights,
//! per-tensor for activations.
//!
//! The scheme is the one the paper's Section 5.3 deployment targets (Edge
//! TPU / NCS2 class int8 MAC arrays) actually use:
//!
//! * **Weights** — per-output-channel symmetric: channel `o` of a filter is
//!   mapped through `q = round(w / scale[o])` with
//!   `scale[o] = absmax_o / 127`, so every channel spends the full i8 range
//!   on its own dynamic range and the zero point is exactly 0 (padding and
//!   ReLU zeros stay exact).
//! * **Activations** — per-tensor symmetric: one scale for the whole
//!   feature map, calibrated at *compile* time from a seeded latent sweep
//!   through the f32 program (see `engine::Program::build_owned_prec`), so
//!   the serving hot path never inspects activation statistics.
//! * **Accumulation** — i32. The largest contraction in the six benchmarks
//!   (GP-GAN's 8192-wide bottleneck) peaks at `8192 * 127 * 127 < 2^28`,
//!   far inside i32.
//! * **Requantization** — `acc_i32 as f32 * (act_scale * scale[o])`, fused
//!   with bias add and ReLU into the GEMM epilogue ([`super::Epilogue`]).
//!
//! # Examples
//!
//! Round-trip error of the symmetric scheme is bounded by half a step:
//!
//! ```
//! use split_deconv::quant::{quantize_into, QTensor};
//! use split_deconv::tensor::Tensor;
//! let x = Tensor::from_vec(1, 1, 1, 4, vec![-1.27, -0.4, 0.004, 1.0]);
//! let scale = 1.27 / 127.0; // absmax / 127
//! let mut q = QTensor::empty();
//! quantize_into(&x, scale, &mut q);
//! assert_eq!(q.data, vec![-127, -40, 0, 100]);
//! for (v, qv) in x.data.iter().zip(&q.data) {
//!     assert!((v - *qv as f32 * scale).abs() <= scale / 2.0 + 1e-6);
//! }
//! ```
//!
//! Per-output-channel filter scales come from each channel's own absmax:
//!
//! ```
//! use split_deconv::quant::quantize_filter;
//! use split_deconv::tensor::Filter;
//! // 1x1x1x2 filter: channel 0 holds 0.5, channel 1 holds -2.0
//! let f = Filter::from_vec(1, 1, 1, 2, vec![0.5, -2.0]);
//! let qf = quantize_filter(&f);
//! assert_eq!(qf.data, vec![127, -127]); // both channels use the full range
//! assert!((qf.scales[0] - 0.5 / 127.0).abs() < 1e-9);
//! assert!((qf.scales[1] - 2.0 / 127.0).abs() < 1e-9);
//! ```

use crate::nn::LayerSpec;
use crate::sd::split_filters;
use crate::tensor::{Filter, Tensor};

/// Numeric precision of a compiled program / serving stack.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Precision {
    /// f32 end to end (the reference path)
    #[default]
    F32,
    /// int8 weights + activations, i32 accumulate, f32 requantize
    Int8,
}

impl Precision {
    /// Parse a CLI spelling (`f32` / `int8`, case-insensitive).
    pub fn parse(s: &str) -> Option<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float" => Some(Precision::F32),
            "int8" | "i8" => Some(Precision::Int8),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

/// Quantized activation tensor: NHWC i8 payload + the per-tensor scale that
/// maps it back to f32 (`v ~= q * scale`). Zero point is always 0
/// (symmetric), so spatial zero-padding needs no offset handling.
#[derive(Clone, Debug)]
pub struct QTensor {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub scale: f32,
    pub data: Vec<i8>,
}

impl QTensor {
    /// An empty (0-shaped) tensor — the arena slot form.
    pub fn empty() -> QTensor {
        QTensor { n: 0, h: 0, w: 0, c: 0, scale: 1.0, data: Vec::new() }
    }

    #[inline]
    pub fn idx(&self, n: usize, h: usize, w: usize, c: usize) -> usize {
        ((n * self.h + h) * self.w + w) * self.c + c
    }

    /// Zero-pad spatial dims into a caller-provided tensor (reshaped,
    /// resized, zeroed in place, reusing capacity) — mirror of
    /// [`Tensor::pad_into`]. Padding is exact: the symmetric scheme's zero
    /// point is 0.
    pub fn pad_into(
        &self,
        top: usize,
        bottom: usize,
        left: usize,
        right: usize,
        out: &mut QTensor,
    ) {
        out.n = self.n;
        out.h = self.h + top + bottom;
        out.w = self.w + left + right;
        out.c = self.c;
        out.scale = self.scale;
        out.data.clear();
        out.data.resize(out.n * out.h * out.w * out.c, 0);
        for n in 0..self.n {
            for h in 0..self.h {
                let src = self.idx(n, h, 0, 0);
                let dst = out.idx(n, h + top, left, 0);
                out.data[dst..dst + self.w * self.c]
                    .copy_from_slice(&self.data[src..src + self.w * self.c]);
            }
        }
    }
}

/// Quantized filter: HWIO i8 payload + per-output-channel scales. Exactly
/// like the f32 [`Filter`], the HWIO data *is* the `K x N` GEMM operand
/// (`K = kh*kw*ic` contiguous rows of `N = oc`), so the int8 conv kernel
/// consumes it with no repacking.
#[derive(Clone, Debug)]
pub struct QFilter {
    pub kh: usize,
    pub kw: usize,
    pub ic: usize,
    pub oc: usize,
    /// per-output-channel requantization scales, length `oc`
    pub scales: Vec<f32>,
    pub data: Vec<i8>,
    /// indices of the GEMM `K`-rows (`kh*kw*ic` taps) that are not entirely
    /// zero across the output channels. The int8 GEMM iterates only these:
    /// the paper's Wsparse skip policy applied in software. SD sub-filters
    /// of the expansion case carry whole rows/columns of structural zeros
    /// (`P_K > 0` — ~31% of DCGAN's split taps, ~44% of FST's), and the
    /// symmetric scheme maps exact zeros to exact zeros, so skipping them
    /// changes no bit of the i32 accumulation.
    pub nz_rows: Vec<u32>,
}

/// Quantize one f32 value at a given scale: round-to-nearest, clamped to
/// the symmetric i8 range [-127, 127] (-128 unused, keeping negation safe).
#[inline]
pub fn quantize_value(v: f32, inv_scale: f32) -> i8 {
    (v * inv_scale).round().clamp(-127.0, 127.0) as i8
}

/// Per-tensor activation scale for a given absolute maximum.
/// A zero (or non-finite) absmax maps to scale 1.0: the tensor is all
/// zeros, and any positive scale represents it exactly.
#[inline]
pub fn scale_for_absmax(absmax: f32) -> f32 {
    if absmax > 0.0 && absmax.is_finite() {
        absmax / 127.0
    } else {
        1.0
    }
}

/// Largest |v| over a slice.
pub fn absmax(data: &[f32]) -> f32 {
    data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
}

/// Quantize an f32 activation tensor at a fixed (calibrated) per-tensor
/// scale into a caller-provided [`QTensor`] (reshaped/resized in place,
/// reusing capacity). Values beyond `127 * scale` saturate — the calibrated
/// serving path's documented behavior for out-of-sweep outliers.
pub fn quantize_into(x: &Tensor, scale: f32, out: &mut QTensor) {
    out.n = x.n;
    out.h = x.h;
    out.w = x.w;
    out.c = x.c;
    out.scale = scale;
    out.data.clear();
    let inv = 1.0 / scale;
    out.data.extend(x.data.iter().map(|&v| quantize_value(v, inv)));
}

/// Quantize a filter with per-output-channel symmetric scales
/// (`scale[o] = absmax_o / 127`). Channels that are entirely zero get scale
/// 1.0 (and all-zero payload).
pub fn quantize_filter(f: &Filter) -> QFilter {
    let mut chan_absmax = vec![0.0f32; f.oc];
    for row in f.data.chunks_exact(f.oc) {
        for (m, &v) in chan_absmax.iter_mut().zip(row) {
            *m = m.max(v.abs());
        }
    }
    let scales: Vec<f32> = chan_absmax.iter().map(|&m| scale_for_absmax(m)).collect();
    let inv: Vec<f32> = scales.iter().map(|s| 1.0 / s).collect();
    let mut data = Vec::with_capacity(f.data.len());
    let mut nz_rows = Vec::new();
    for (r, row) in f.data.chunks_exact(f.oc).enumerate() {
        data.extend(row.iter().zip(&inv).map(|(&v, &i)| quantize_value(v, i)));
        if data[r * f.oc..(r + 1) * f.oc].iter().any(|&q| q != 0) {
            nz_rows.push(r as u32);
        }
    }
    QFilter { kh: f.kh, kw: f.kw, ic: f.ic, oc: f.oc, scales, data, nz_rows }
}

/// Quantize a dense weight matrix (`n_in x n_out` row-major) with
/// per-output-column scales. A dense layer *is* a 1x1 convolution over a
/// `1 x 1 x n_in` map, and the row-major matrix *is* that filter's HWIO
/// payload, so this reuses [`quantize_filter`] verbatim — the engine lowers
/// int8 dense layers onto the int8 conv kernel through this. Takes the
/// buffer by value: the engine owns it at lowering time, and GP-GAN's
/// bottleneck matrix (~131 MB) must not be copied just to be quantized.
pub fn quantize_dense(w: Vec<f32>, n_in: usize, n_out: usize) -> QFilter {
    assert_eq!(w.len(), n_in * n_out, "dense weight size");
    quantize_filter(&Filter::from_vec(1, 1, n_in, n_out, w))
}

/// Split a deconvolution filter into its `s*s` SD sub-filters and pack each
/// as int8 (per-output-channel scales per sub-filter) — the compile-time
/// step that makes the SD path itself run quantized: every split's packed
/// HWIO payload is the `K x N` operand of one int8 stride-1 convolution.
pub fn pack_sd_splits(f: &Filter, s: usize) -> Vec<QFilter> {
    split_filters(f, s).iter().map(quantize_filter).collect()
}

/// Geometry of the packed SD sub-filters of one deconvolution layer, read
/// off an **actual packing** (a unit-channel probe filter run through the
/// same [`split_filters`] path the engine compiles) rather than re-derived
/// from the closed-form `SdGeometry` equations. The `commodity` efficiency
/// models consume this, so their MAC-time estimates follow the filter
/// geometry the quantized engine really executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SdPackShape {
    /// packed sub-filter side (`K_T`)
    pub k_t: usize,
    /// number of sub-filters (`s*s`)
    pub n_splits: usize,
    /// per-split stride-1 conv output height (`in_h + K_T - 1`)
    pub conv_h: usize,
    /// per-split stride-1 conv output width (`in_w + K_T - 1`)
    pub conv_w: usize,
}

impl SdPackShape {
    /// Table-2-convention MACs of the split convolutions
    /// (`IH*IW * n_splits*K_T^2 * IC*OC` — interior compute, boundary halo
    /// excluded), derived from the packed sub-filter sizes.
    pub fn table_macs(&self, l: &LayerSpec) -> u64 {
        (l.in_h * l.in_w * self.n_splits * self.k_t * self.k_t * l.in_c * l.out_c) as u64
    }
}

/// [`SdPackShape`] of a deconvolution layer, obtained by actually packing a
/// probe filter of the layer's spatial shape (channels collapsed to 1x1 —
/// splitting is channel-independent).
pub fn sd_pack_shape(l: &LayerSpec) -> SdPackShape {
    let splits = split_filters(&Filter::zeros(l.k, l.k, 1, 1), l.s);
    let k_t = splits[0].kh;
    SdPackShape {
        k_t,
        n_splits: splits.len(),
        conv_h: l.in_h + k_t - 1,
        conv_w: l.in_w + k_t - 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn quantize_roundtrip_within_half_step() {
        let mut rng = Rng::new(13);
        let x = Tensor::randn(1, 5, 5, 7, &mut rng);
        let scale = scale_for_absmax(absmax(&x.data));
        let mut q = QTensor::empty();
        quantize_into(&x, scale, &mut q);
        for (&v, &qv) in x.data.iter().zip(&q.data) {
            let back = qv as f32 * scale;
            assert!(
                (v - back).abs() <= scale / 2.0 + scale * 1e-5,
                "v={v} back={back} scale={scale}"
            );
        }
    }

    #[test]
    fn zero_tensor_scale_is_safe() {
        let x = Tensor::zeros(1, 2, 2, 1);
        let scale = scale_for_absmax(absmax(&x.data));
        let mut q = QTensor::empty();
        quantize_into(&x, scale, &mut q);
        assert!(q.data.iter().all(|&v| v == 0));
    }

    #[test]
    fn filter_channels_use_full_range() {
        let mut rng = Rng::new(5);
        let f = Filter::randn(3, 3, 4, 6, &mut rng);
        let qf = quantize_filter(&f);
        // every channel's largest |q| is exactly 127 (its absmax maps there)
        for o in 0..f.oc {
            let maxq = (0..f.kh * f.kw * f.ic)
                .map(|r| (qf.data[r * f.oc + o] as i32).abs())
                .max()
                .unwrap();
            assert_eq!(maxq, 127, "channel {o}");
        }
    }

    #[test]
    fn qtensor_pad_matches_f32_pad() {
        let mut rng = Rng::new(8);
        let x = Tensor::randn(2, 3, 4, 2, &mut rng);
        let scale = scale_for_absmax(absmax(&x.data));
        let mut q = QTensor::empty();
        quantize_into(&x, scale, &mut q);
        let mut qp = QTensor::empty();
        q.pad_into(1, 2, 3, 0, &mut qp);
        let xp = x.pad(1, 2, 3, 0);
        assert_eq!([qp.n, qp.h, qp.w, qp.c], xp.shape());
        // padded zeros are exact zeros; interior cells match direct quant
        let mut qref = QTensor::empty();
        quantize_into(&xp, scale, &mut qref);
        assert_eq!(qp.data, qref.data);
    }

    #[test]
    fn sd_pack_shape_matches_real_packing() {
        use crate::sd::SdGeometry;
        for (k, s, p) in [(5, 2, 2), (4, 2, 1), (3, 2, 1), (2, 2, 0)] {
            let l = LayerSpec::deconv("d", 8, 6, 3, 4, k, s, p, 0);
            let shape = sd_pack_shape(&l);
            let g = SdGeometry::new(k, s, p);
            assert_eq!(shape.k_t, g.k_t);
            assert_eq!(shape.n_splits, g.n_splits());
            assert_eq!(shape.conv_h, g.conv_out(8));
            assert_eq!(shape.conv_w, g.conv_out(6));
            assert_eq!(shape.table_macs(&l), l.sd_macs());
        }
    }

    #[test]
    fn precision_parses_cli_spellings() {
        assert_eq!(Precision::parse("int8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("F32"), Some(Precision::F32));
        assert_eq!(Precision::parse("fp16"), None);
        assert_eq!(Precision::default(), Precision::F32);
    }
}
