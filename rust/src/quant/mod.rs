//! Int8 quantized inference: the numeric scheme, the int8 conv kernel, and
//! the packed-filter types the engine compiles quantized programs from.
//!
//! The paper's Section 5.3 deploys split deconvolution on commodity int8
//! processors (Edge TPU, NCS2) — this module is the software analogue of
//! that deployment: per-output-channel symmetric int8 weights, per-tensor
//! calibrated activations, an i8 im2col + i32-accumulate GEMM with a fused
//! requantize + bias + activation epilogue, and int8 packing of the
//! pre-split SD sub-filters so the SD path itself (not just plain
//! convolution) runs quantized end to end. HUGE² (arXiv 1907.11210) and the
//! FPGA deconv pipeline of Zhang et al. (arXiv 1705.02583) both get their
//! edge throughput from exactly this precision drop.
//!
//! Layering:
//!
//! * [`scheme`] — [`Precision`], [`QTensor`] / [`QFilter`], the
//!   quantize/requantize math (rustdoc examples double as the scheme's
//!   spec), SD sub-filter packing ([`pack_sd_splits`]), and the packed
//!   geometry probe ([`sd_pack_shape`]) the `commodity` models consume.
//! * [`gemm`] — [`conv2d_i8_into`], the int8 twin of the f32 hot path
//!   (same tiling, same persistent worker pool, same runtime SIMD
//!   dispatch; [`QPackedB`] is the compile-time-packed operand of the
//!   AVX2 microkernel), with [`conv2d_i8_naive`] as its zero-tolerance
//!   oracle on every backend.
//!
//! The engine threads a [`Precision`] knob through `Program::build*`:
//! `Precision::Int8` lowers dense layers and convolutions onto
//! [`conv2d_i8_into`] (a dense layer is a 1x1 conv over its `1x1xN` map,
//! so one kernel serves both) and SD deconvolutions onto per-split int8
//! convolutions, with all quantized constants prepared at compile time and
//! activation scales calibrated from a seeded latent sweep. Accuracy is
//! SSIM-gated against the f32 engine (>= 0.97 on all six benchmarks,
//! rust/tests/quant.rs).

pub mod gemm;
pub mod scheme;

pub use gemm::{
    conv2d_i8_into, conv2d_i8_naive, conv2d_i8_prepacked_into, conv2d_i8_scaled_into, Epilogue,
    QPackedB,
};
pub use scheme::{
    absmax, pack_sd_splits, quantize_dense, quantize_filter, quantize_into, quantize_value,
    scale_for_absmax, sd_pack_shape, Precision, QFilter, QTensor, SdPackShape,
};
