//! Int8 im2col + i32-accumulate convolution kernel with a fused
//! requantize + bias + activation epilogue — the quantized twin of
//! [`crate::tensor::conv2d_gemm_into`].
//!
//! Structure is deliberately identical to the f32 hot path: im2col packing
//! of the i8 activations into a per-thread panel, a cache-blocked GEMM
//! register-blocked `MR` output pixels at a time, work split into
//! batch x output-row tiles drained from a shared queue by a scoped worker
//! pool (`SD_CONV_THREADS` overrides the width). Differences:
//!
//! * the panel holds i8 (4x more rows fit in the same L2 budget);
//! * accumulation is i32 — exact, so tile order and register blocking can
//!   never change a result bit (integer addition is associative), which is
//!   why [`conv2d_i8_naive`] is a *zero-tolerance* oracle;
//! * the paper's AWSparse skip policy runs in software, and is *exact*
//!   here for the same reason: the `K` loop visits only the filter rows
//!   that are not structurally zero (`QFilter::nz_rows` — SD expansion
//!   zeros, Wsparse) and skips quantized-zero activation values (post-ReLU
//!   maps and the SD input halo, ASparse), because a zero i32 contribution
//!   is exactly nothing. This is the int8 kernel's structural edge over
//!   the f32 GEMM, which executes every MAC (skipping f32 terms is not
//!   bit-safe: adding 0.0 can flip a -0.0 accumulator);
//! * the epilogue requantizes each i32 accumulator straight to f32 through
//!   the precomputed per-column scale `act_scale * weight_scale[col]`,
//!   adding an optional per-channel bias and applying ReLU in the same
//!   pass ([`Epilogue`]) — no separate f32 requantization sweep over the
//!   output.

use crate::tensor::ops::{worker_count, PANEL_BYTES};
use crate::tensor::Tensor;

use super::scheme::{QFilter, QTensor};

/// Micro-kernel register-block height (output pixels per GEMM block).
const MR: usize = 4;

/// Fused epilogue of the int8 kernel: what happens to each i32 accumulator
/// on its way to the f32 output buffer. Requantization (the per-column
/// scale) always runs; bias and ReLU are optional and fused into the same
/// store.
#[derive(Clone, Copy, Default)]
pub struct Epilogue<'a> {
    /// per-output-channel bias added after requantization
    pub bias: Option<&'a [f32]>,
    /// clamp negatives to zero in the same pass (mid-layer ReLU)
    pub relu: bool,
}

impl<'a> Epilogue<'a> {
    /// Plain requantization: no bias, no activation.
    pub fn none() -> Epilogue<'a> {
        Epilogue::default()
    }

    /// Requantize + ReLU (the generator's mid-layer fusion).
    pub fn relu() -> Epilogue<'a> {
        Epilogue { bias: None, relu: true }
    }

    #[inline]
    fn apply(&self, col: usize, v: f32) -> f32 {
        let v = match self.bias {
            Some(b) => v + b[col],
            None => v,
        };
        if self.relu {
            v.max(0.0)
        } else {
            v
        }
    }
}

/// One worker job: a tile of output rows of one batch image, owning the
/// corresponding disjoint slice of the f32 output buffer.
struct Tile<'a> {
    n: usize,
    y0: usize,
    rows: usize,
    out: &'a mut [f32],
}

/// Per-thread scratch arena: the i8 im2col panel and the i32 accumulator
/// block — the int8 twins of the f32 kernel's `panel`/`acc`.
#[derive(Default)]
struct Scratch {
    panel: Vec<i8>,
    acc: Vec<i32>,
}

/// Valid int8 convolution into a caller-provided f32 tensor (reshaped and
/// resized in place, reusing capacity): i8 im2col panels, i32-accumulate
/// GEMM, fused requantize/bias/ReLU epilogue. Bit-identical to
/// [`conv2d_i8_naive`] (asserted with zero tolerance in
/// rust/tests/quant.rs). Computes the requantization scales
/// (`x.scale * f.scales[o]`) into a fresh buffer per call; hot-path
/// callers that can reuse one should use [`conv2d_i8_scaled_into`].
pub fn conv2d_i8_into(x: &QTensor, f: &QFilter, stride: usize, epi: Epilogue, out: &mut Tensor) {
    // requantization scales, one multiply per output element in the
    // epilogue: activation per-tensor scale x weight per-channel scale
    let colscale: Vec<f32> = f.scales.iter().map(|&s| x.scale * s).collect();
    conv2d_i8_scaled_into(x, f, stride, &colscale, epi, out);
}

/// [`conv2d_i8_into`] with the per-column requantization scales
/// precomputed by the caller (`colscale[o] = x.scale * f.scales[o]`,
/// length `f.oc`) — the engine's entry point: the products are
/// compile-time constants there, and writing them into a reused
/// `Scratch` buffer keeps per-layer allocation off the forward path.
pub fn conv2d_i8_scaled_into(
    x: &QTensor,
    f: &QFilter,
    stride: usize,
    colscale: &[f32],
    epi: Epilogue,
    out: &mut Tensor,
) {
    assert_eq!(x.c, f.ic, "channel mismatch");
    assert!(x.h >= f.kh && x.w >= f.kw, "filter larger than input");
    assert_eq!(colscale.len(), f.oc, "colscale length");
    if let Some(b) = epi.bias {
        assert_eq!(b.len(), f.oc, "bias length");
    }
    let oh = (x.h - f.kh) / stride + 1;
    let ow = (x.w - f.kw) / stride + 1;
    let kdim = f.kh * f.kw * f.ic;
    let n_out = f.oc;
    out.n = x.n;
    out.h = oh;
    out.w = ow;
    out.c = n_out;
    out.data.clear();
    out.data.resize(x.n * oh * ow * n_out, 0.0);
    if out.data.is_empty() {
        return;
    }

    let rows_per_tile = (PANEL_BYTES / (ow * kdim).max(1)).clamp(1, oh);
    let mut tiles: Vec<Tile> = Vec::new();
    for (n, img) in out.data.chunks_mut(oh * ow * n_out).enumerate() {
        for (t, slice) in img.chunks_mut(rows_per_tile * ow * n_out).enumerate() {
            tiles.push(Tile {
                n,
                y0: t * rows_per_tile,
                rows: slice.len() / (ow * n_out),
                out: slice,
            });
        }
    }

    let macs = x.n * oh * ow * kdim * n_out;
    let workers = worker_count(macs, tiles.len());
    if workers <= 1 {
        let mut scratch = Scratch::default();
        for tile in tiles {
            run_tile(x, f, stride, ow, colscale, epi, tile, &mut scratch);
        }
    } else {
        let queue = std::sync::Mutex::new(tiles);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    let mut scratch = Scratch::default();
                    loop {
                        let tile = queue.lock().unwrap().pop();
                        match tile {
                            Some(tile) => {
                                run_tile(x, f, stride, ow, colscale, epi, tile, &mut scratch)
                            }
                            None => break,
                        }
                    }
                });
            }
        });
    }
}

/// Pack one row tile's i8 im2col panel, then GEMM it against the i8 filter
/// with the requantizing epilogue into the tile's f32 output slice.
#[allow(clippy::too_many_arguments)] // mirrors the f32 kernel's tile runner
fn run_tile(
    x: &QTensor,
    f: &QFilter,
    stride: usize,
    ow: usize,
    colscale: &[f32],
    epi: Epilogue,
    tile: Tile,
    s: &mut Scratch,
) {
    let kdim = f.kh * f.kw * f.ic;
    let seg = f.kw * x.c; // one contiguous input-row segment per kernel row
    let m = tile.rows * ow;
    s.panel.resize(m * kdim, 0);
    for r in 0..tile.rows {
        let oy = tile.y0 + r;
        for ox in 0..ow {
            let dst_base = (r * ow + ox) * kdim;
            for dy in 0..f.kh {
                let src = x.idx(tile.n, oy * stride + dy, ox * stride, 0);
                let dst = dst_base + dy * seg;
                s.panel[dst..dst + seg].copy_from_slice(&x.data[src..src + seg]);
            }
        }
    }
    gemm_i8(&s.panel, &f.data, m, kdim, f.oc, &f.nz_rows, colscale, epi, tile.out, &mut s.acc);
}

/// `c = epilogue(a (m x k) . b (k x n))`: i8 operands, i32 accumulation,
/// f32 output through the per-column requantization scale. Register-blocked
/// MR rows at a time. The `K` loop walks only `nz_rows` — the filter rows
/// that are not entirely zero (the Wsparse structural-zero skip; see
/// [`super::QFilter::nz_rows`]). i32 accumulation is exact, so neither the
/// blocking nor the skip can change a bit of the result.
#[allow(clippy::too_many_arguments)] // GEMM argument list mirrors the f32 kernel
fn gemm_i8(
    a: &[i8],
    b: &[i8],
    m: usize,
    k: usize,
    n: usize,
    nz_rows: &[u32],
    colscale: &[f32],
    epi: Epilogue,
    c: &mut [f32],
    acc: &mut Vec<i32>,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    debug_assert_eq!(colscale.len(), n);
    if acc.len() != MR * n {
        acc.resize(MR * n, 0);
    }
    let mut row = 0;
    while row + MR <= m {
        acc.fill(0);
        {
            let (a0, rest) = acc.split_at_mut(n);
            let (a1, rest) = rest.split_at_mut(n);
            let (a2, a3) = rest.split_at_mut(n);
            let p0 = &a[row * k..(row + 1) * k];
            let p1 = &a[(row + 1) * k..(row + 2) * k];
            let p2 = &a[(row + 2) * k..(row + 3) * k];
            let p3 = &a[(row + 3) * k..(row + 4) * k];
            for &kk in nz_rows {
                let kk = kk as usize;
                let (v0, v1, v2, v3) =
                    (p0[kk] as i32, p1[kk] as i32, p2[kk] as i32, p3[kk] as i32);
                // activation-zero skip (the ASparse half of the paper's
                // AWSparse policy): post-ReLU maps and the SD input halo
                // quantize to exact zeros, and skipping a zero i32
                // contribution is exact
                if v0 == 0 && v1 == 0 && v2 == 0 && v3 == 0 {
                    continue;
                }
                let brow = &b[kk * n..(kk + 1) * n];
                for ((((&w, c0), c1), c2), c3) in brow
                    .iter()
                    .zip(a0.iter_mut())
                    .zip(a1.iter_mut())
                    .zip(a2.iter_mut())
                    .zip(a3.iter_mut())
                {
                    let w = w as i32;
                    *c0 += v0 * w;
                    *c1 += v1 * w;
                    *c2 += v2 * w;
                    *c3 += v3 * w;
                }
            }
        }
        for r in 0..MR {
            let crow = &mut c[(row + r) * n..(row + r + 1) * n];
            let arow = &acc[r * n..(r + 1) * n];
            for (col, ((cv, &av), &sc)) in
                crow.iter_mut().zip(arow).zip(colscale).enumerate()
            {
                *cv = epi.apply(col, av as f32 * sc);
            }
        }
        row += MR;
    }
    while row < m {
        let arow = &a[row * k..(row + 1) * k];
        let acc1 = &mut acc[..n];
        acc1.fill(0);
        for &kk in nz_rows {
            let kk = kk as usize;
            let v = arow[kk] as i32;
            if v == 0 {
                continue; // activation-zero skip, exact in i32
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &w) in acc1.iter_mut().zip(brow) {
                *cv += v * (w as i32);
            }
        }
        let crow = &mut c[row * n..(row + 1) * n];
        for (col, ((cv, &av), &sc)) in crow.iter_mut().zip(acc1.iter()).zip(colscale).enumerate()
        {
            *cv = epi.apply(col, av as f32 * sc);
        }
        row += 1;
    }
}

/// Scalar reference int8 convolution: the plain 7-deep loop with i32
/// accumulation and the identical epilogue expression — the zero-tolerance
/// oracle for [`conv2d_i8_into`] (i32 accumulation is exact, and the
/// epilogue computes `acc as f32 * (x.scale * f.scales[o])` in the same
/// operation order, so the two kernels agree bit for bit).
pub fn conv2d_i8_naive(x: &QTensor, f: &QFilter, stride: usize, epi: Epilogue) -> Tensor {
    assert_eq!(x.c, f.ic, "channel mismatch");
    assert!(x.h >= f.kh && x.w >= f.kw, "filter larger than input");
    let oh = (x.h - f.kh) / stride + 1;
    let ow = (x.w - f.kw) / stride + 1;
    let colscale: Vec<f32> = f.scales.iter().map(|&s| x.scale * s).collect();
    let fidx = |kh: usize, kw: usize, ic: usize, oc: usize| {
        ((kh * f.kw + kw) * f.ic + ic) * f.oc + oc
    };
    let mut out = Tensor::zeros(x.n, oh, ow, f.oc);
    for n in 0..x.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for o in 0..f.oc {
                    let mut acc: i32 = 0;
                    for dy in 0..f.kh {
                        for dx in 0..f.kw {
                            for i in 0..x.c {
                                let xv = x.data[x.idx(n, oy * stride + dy, ox * stride + dx, i)]
                                    as i32;
                                let wv = f.data[fidx(dy, dx, i, o)] as i32;
                                acc += xv * wv;
                            }
                        }
                    }
                    *out.at_mut(n, oy, ox, o) = epi.apply(o, acc as f32 * colscale[o]);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::scheme::{absmax, quantize_filter, quantize_into, scale_for_absmax};
    use super::*;
    use crate::tensor::Filter;
    use crate::util::rng::Rng;

    fn qpair(h: usize, w: usize, ic: usize, k: usize, oc: usize, seed: u64) -> (QTensor, QFilter) {
        let mut rng = Rng::new(seed);
        let x = Tensor::randn(2, h, w, ic, &mut rng);
        let f = Filter::randn(k, k, ic, oc, &mut rng);
        let mut qx = QTensor::empty();
        quantize_into(&x, scale_for_absmax(absmax(&x.data)), &mut qx);
        (qx, quantize_filter(&f))
    }

    #[test]
    fn blocked_kernel_is_bit_exact_with_naive() {
        for (i, &(h, w, ic, k, oc, s)) in
            [(6, 6, 3, 3, 4, 1), (9, 13, 5, 3, 7, 2), (5, 5, 1, 5, 1, 1)].iter().enumerate()
        {
            let (qx, qf) = qpair(h, w, ic, k, oc, 31 + i as u64);
            let mut got = Tensor::zeros(0, 0, 0, 0);
            conv2d_i8_into(&qx, &qf, s, Epilogue::none(), &mut got);
            let want = conv2d_i8_naive(&qx, &qf, s, Epilogue::none());
            assert_eq!(got.shape(), want.shape());
            assert_eq!(got.max_abs_diff(&want), 0.0, "case {i} not bit-exact");
        }
    }

    #[test]
    fn epilogue_fuses_bias_and_relu() {
        let (qx, qf) = qpair(6, 6, 3, 3, 4, 77);
        let bias: Vec<f32> = (0..4).map(|i| i as f32 - 1.5).collect();
        let epi = Epilogue { bias: Some(&bias), relu: true };
        let mut fused = Tensor::zeros(0, 0, 0, 0);
        conv2d_i8_into(&qx, &qf, 1, epi, &mut fused);
        // reference: plain requantize, then bias, then relu, separately
        let mut plain = Tensor::zeros(0, 0, 0, 0);
        conv2d_i8_into(&qx, &qf, 1, Epilogue::none(), &mut plain);
        for (i, v) in plain.data.iter_mut().enumerate() {
            *v = (*v + bias[i % 4]).max(0.0);
        }
        assert_eq!(fused.max_abs_diff(&plain), 0.0);
        assert!(fused.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn quantized_conv_tracks_f32_conv() {
        // not bit-exact (that is the point of quantization) but close:
        // the i8 result must stay within a few quantization steps of f32
        let mut rng = Rng::new(3);
        let x = Tensor::randn(1, 8, 8, 16, &mut rng);
        let f = Filter::randn(3, 3, 16, 8, &mut rng);
        let want = crate::tensor::conv2d_valid(&x, &f, 1);
        let mut qx = QTensor::empty();
        quantize_into(&x, scale_for_absmax(absmax(&x.data)), &mut qx);
        let mut got = Tensor::zeros(0, 0, 0, 0);
        conv2d_i8_into(&qx, &quantize_filter(&f), 1, Epilogue::none(), &mut got);
        let denom = absmax(&want.data).max(1e-6);
        let rel = got.max_abs_diff(&want) / denom;
        assert!(rel < 0.05, "relative error {rel}");
    }
}
