//! Int8 im2col + i32-accumulate convolution kernel with a fused
//! requantize + bias + activation epilogue — the quantized twin of
//! [`crate::tensor::conv2d_gemm_into`].
//!
//! Structure is deliberately identical to the f32 hot path: im2col packing
//! of the i8 activations into a per-thread panel, a microkernel GEMM
//! register-blocked `MR` output pixels at a time, work split into
//! batch x output-row tiles drained from a lock-free atomic cursor by the
//! persistent worker pool (`runtime::pool`; `SD_CONV_THREADS` overrides
//! the width through the shared `worker_count` policy). Like the f32 side,
//! the kernel is runtime-dispatched: an AVX2 `madd`-based microkernel over
//! a pre-packed operand ([`QPackedB`]) with the portable scalar loop as
//! fallback. Differences from f32:
//!
//! * the panel holds i8 (4x more rows fit in the same L2 budget);
//! * accumulation is i32 — **exact**, so backend, tile order, register
//!   blocking, and skip granularity can never change a result bit (integer
//!   addition is associative), which is why [`conv2d_i8_naive`] remains a
//!   *zero-tolerance* oracle for BOTH backends (unlike the f32 kernel,
//!   whose SIMD backend is ULP-bounded — see `tensor::gemm`);
//! * the paper's AWSparse skip policy runs in software, and is *exact*
//!   here for the same reason: structurally-zero filter rows
//!   ([`QFilter::nz_rows`] — SD expansion zeros, Wsparse) are skipped by
//!   the scalar kernel and **removed at pack time** by [`QPackedB`]
//!   (the SIMD kernel never visits them), and quantized-zero activation
//!   values (post-ReLU maps and the SD input halo, ASparse) are skipped at
//!   row-pair granularity, because a zero i32 contribution is exactly
//!   nothing. This is the int8 kernel's structural edge over the f32 GEMM,
//!   which executes every MAC (skipping f32 terms is not bit-safe: adding
//!   0.0 can flip a -0.0 accumulator);
//! * the epilogue requantizes each i32 accumulator straight to f32 through
//!   the precomputed per-column scale `act_scale * weight_scale[col]`,
//!   adding an optional per-channel bias and applying ReLU in the same
//!   pass ([`Epilogue`]); both backends store their accumulators and run
//!   the one scalar epilogue loop, so the f32 results are bit-identical
//!   across backends too.
//!
//! ## [`QPackedB`] layout
//!
//! The SIMD kernel processes **two** contraction rows per step with
//! `_mm256_madd_epi16` (i16 pair dot products into i32 lanes — exact: each
//! product is at most 127·127 and the pair sum at most 2·127², far inside
//! i32). The packed operand serves that shape directly: the non-zero
//! filter rows are paired `(k₀,k₁)` and each 16-column panel stores, per
//! pair, the 32 bytes `[b[k₀][c], b[k₁][c]]` interleaved per column. An
//! odd non-zero row count is padded with an all-zero partner row (exact).
//! The engine packs every quantized weight once at `Program` compile time
//! ([`conv2d_i8_prepacked_into`]); the direct call paths pack per call
//! into a reused thread-local.

use std::cell::RefCell;
use std::sync::atomic::Ordering;

use crate::tensor::gemm::{parallel_drain, SendPtr};
use crate::tensor::ops::{worker_count, TileMap};
use crate::tensor::Tensor;
use crate::util::blob::BlobVec;

use super::scheme::{QFilter, QTensor};

/// Micro-kernel register-block height (output pixels per GEMM block).
const MR: usize = 4;

/// Column width of one packed int8 panel (i32 lanes across two AVX regs).
const NR8: usize = 16;

/// Fused epilogue of the int8 kernel: what happens to each i32 accumulator
/// on its way to the f32 output buffer. Requantization (the per-column
/// scale) always runs; bias and ReLU are optional and fused into the same
/// store.
#[derive(Clone, Copy, Default)]
pub struct Epilogue<'a> {
    /// per-output-channel bias added after requantization
    pub bias: Option<&'a [f32]>,
    /// clamp negatives to zero in the same pass (mid-layer ReLU)
    pub relu: bool,
}

impl<'a> Epilogue<'a> {
    /// Plain requantization: no bias, no activation.
    pub fn none() -> Epilogue<'a> {
        Epilogue::default()
    }

    /// Requantize + ReLU (the generator's mid-layer fusion).
    pub fn relu() -> Epilogue<'a> {
        Epilogue { bias: None, relu: true }
    }

    #[inline]
    fn apply(&self, col: usize, v: f32) -> f32 {
        let v = match self.bias {
            Some(b) => v + b[col],
            None => v,
        };
        if self.relu {
            v.max(0.0)
        } else {
            v
        }
    }
}

/// A quantized filter's GEMM operand packed for the SIMD kernel:
/// structural-zero rows removed, surviving rows paired, 16-column panels
/// with per-column `(k₀,k₁)` byte interleave (see the module docs). Packed
/// once per weight at engine compile time, or per call into a thread-local
/// on the direct paths. On machines without AVX2 the scalar kernel reads
/// the plain [`QFilter`] payload instead and this operand is unused.
#[derive(Clone, Debug, Default)]
pub struct QPackedB {
    /// contraction length of the unpacked operand (`kh*kw*ic`)
    pub k: usize,
    /// logical column count (`oc`)
    pub n: usize,
    /// paired non-zero row indices, length `2 * pairs()`; an odd tail is
    /// padded with a repeat of the last index whose packed bytes are zero
    kidx: BlobVec<u32>,
    /// `panels() * pairs() * 32` bytes: panel `p`, pair `q`, column `j`,
    /// row-of-pair `w` at `(p*pairs + q)*32 + j*2 + w`
    data: BlobVec<i8>,
}

impl QPackedB {
    /// An empty operand — the reusable-slot form.
    pub fn empty() -> QPackedB {
        QPackedB::default()
    }

    /// Pack a quantized filter's `K x N` HWIO payload.
    pub fn pack(qf: &QFilter) -> QPackedB {
        let mut p = QPackedB::empty();
        p.pack_into(qf);
        p
    }

    /// [`QPackedB::pack`] reusing this instance's buffers.
    pub fn pack_into(&mut self, qf: &QFilter) {
        let k = qf.kh * qf.kw * qf.ic;
        let n = qf.oc;
        debug_assert_eq!(qf.data.len(), k * n);
        self.k = k;
        self.n = n;
        let nz = &qf.nz_rows;
        let pairs = nz.len().div_ceil(2);
        let kidx = self.kidx.owned_mut();
        kidx.clear();
        for q in 0..pairs {
            kidx.push(nz[2 * q]);
            // odd tail: partner index repeats, partner bytes stay zero —
            // a zero i32 contribution, so the pad is exact
            kidx.push(*nz.get(2 * q + 1).unwrap_or(&nz[2 * q]));
        }
        let panels = n.div_ceil(NR8);
        let data = self.data.owned_mut();
        data.clear();
        data.resize(panels * pairs * 32, 0);
        for p in 0..panels {
            let col0 = p * NR8;
            let cols = NR8.min(n - col0);
            for q in 0..pairs {
                let base = (p * pairs + q) * 32;
                let k0 = nz[2 * q] as usize;
                let k1 = nz.get(2 * q + 1).map(|&v| v as usize);
                for j in 0..cols {
                    data[base + 2 * j] = qf.data[k0 * n + col0 + j];
                    if let Some(k1) = k1 {
                        data[base + 2 * j + 1] = qf.data[k1 * n + col0 + j];
                    }
                }
            }
        }
    }

    /// Adopt already-packed payloads (the artifact loader's copy path).
    /// `None` when the lengths are inconsistent (`kidx` must be even and
    /// `data` exactly `panels * pairs * 32`) or a row index reaches `k` —
    /// the accumulation kernel indexes the im2col panel by `kidx` values
    /// without bounds checks, so the bound is enforced here, once, at
    /// construction.
    pub fn from_parts(k: usize, n: usize, kidx: Vec<u32>, data: Vec<i8>) -> Option<QPackedB> {
        if kidx.len() % 2 != 0 || kidx.iter().any(|&i| i as usize >= k) {
            return None;
        }
        if data.len() != QPackedB::packed_data_len(n, kidx.len() / 2) {
            return None;
        }
        Some(QPackedB {
            k,
            n,
            kidx: BlobVec::Owned(kidx),
            data: BlobVec::Owned(data),
        })
    }

    /// Borrow already-packed payloads in place from a shared artifact
    /// buffer (the zero-copy load path). Same validation as
    /// [`QPackedB::from_parts`]; `kidx_len` is in elements.
    pub fn from_shared(
        k: usize,
        n: usize,
        buf: std::sync::Arc<crate::util::blob::AlignedBytes>,
        kidx_off: usize,
        kidx_len: usize,
        data_off: usize,
    ) -> Option<QPackedB> {
        if kidx_len % 2 != 0 {
            return None;
        }
        let kidx: BlobVec<u32> = BlobVec::shared(buf.clone(), kidx_off, kidx_len)?;
        if kidx.as_slice().iter().any(|&i| i as usize >= k) {
            return None;
        }
        let data_len = QPackedB::packed_data_len(n, kidx_len / 2);
        let data: BlobVec<i8> = BlobVec::shared(buf, data_off, data_len)?;
        Some(QPackedB { k, n, kidx, data })
    }

    /// The paired row indices in their on-disk element order.
    pub fn raw_kidx(&self) -> &[u32] {
        self.kidx.as_slice()
    }

    /// The packed pair-interleaved payload in its on-disk byte order.
    pub fn raw_data(&self) -> &[i8] {
        self.data.as_slice()
    }

    /// Packed payload byte count the pair-interleaved layout requires for
    /// `n` columns and `pairs` row pairs — the artifact loader's length
    /// cross-check.
    pub fn packed_data_len(n: usize, pairs: usize) -> usize {
        n.div_ceil(NR8) * pairs * 32
    }

    /// Number of packed row pairs (non-zero rows, halved and rounded up).
    pub fn pairs(&self) -> usize {
        self.kidx.len() / 2
    }

    /// Number of 16-column panels.
    pub fn panels(&self) -> usize {
        self.n.div_ceil(NR8)
    }

    /// Packed payload size in bytes (the plan-time memory cost).
    pub fn bytes(&self) -> usize {
        self.data.len() + self.kidx.len() * std::mem::size_of::<u32>()
    }
}

/// Which operand the accumulation blocks read — the backend dispatch,
/// resolved once per conv call.
#[derive(Clone, Copy)]
enum I8Kernel<'a> {
    /// portable fallback: plain HWIO payload + non-zero row list
    Scalar { b: &'a [i8], nz: &'a [u32] },
    /// AVX2 madd microkernel over the packed pair-interleaved operand
    #[cfg_attr(not(target_arch = "x86_64"), allow(dead_code))]
    Packed { qp: &'a QPackedB },
}

/// Per-thread scratch arena: the i8 im2col panel and the i32 accumulator
/// block — the int8 twins of the f32 kernel's panel (the f32 SIMD path
/// accumulates in registers; the int8 path stages i32 accumulators here so
/// one scalar epilogue serves both backends bit-identically).
#[derive(Default)]
struct Scratch {
    panel: Vec<i8>,
    acc: Vec<i32>,
}

/// Valid int8 convolution into a caller-provided f32 tensor (reshaped and
/// resized in place, reusing capacity): i8 im2col panels, i32-accumulate
/// GEMM, fused requantize/bias/ReLU epilogue. Bit-identical to
/// [`conv2d_i8_naive`] on every backend (asserted with zero tolerance in
/// rust/tests/quant.rs). Computes the requantization scales
/// (`x.scale * f.scales[o]`) into a fresh buffer per call; hot-path
/// callers that can reuse one should use [`conv2d_i8_scaled_into`].
pub fn conv2d_i8_into(x: &QTensor, f: &QFilter, stride: usize, epi: Epilogue, out: &mut Tensor) {
    // requantization scales, one multiply per output element in the
    // epilogue: activation per-tensor scale x weight per-channel scale
    let colscale: Vec<f32> = f.scales.iter().map(|&s| x.scale * s).collect();
    conv2d_i8_scaled_into(x, f, stride, &colscale, epi, out);
}

thread_local! {
    /// Call-time weight packing slot of the direct (non-engine) int8
    /// paths, reused across calls on each thread.
    static QPACK_SLOT: RefCell<QPackedB> = RefCell::new(QPackedB::empty());

    /// Per-thread tile scratch (i8 panel + i32 accumulators), persistent
    /// across conv calls and pool jobs — mirrors the f32 driver's
    /// persistent panel.
    static TILE_SCRATCH: RefCell<Scratch> = RefCell::new(Scratch::default());
}

/// [`conv2d_i8_into`] with the per-column requantization scales
/// precomputed by the caller (`colscale[o] = x.scale * f.scales[o]`,
/// length `f.oc`). Packs the weight for the SIMD kernel per call (reused
/// thread-local); the engine pre-packs at compile time and calls
/// [`conv2d_i8_prepacked_into`].
pub fn conv2d_i8_scaled_into(
    x: &QTensor,
    f: &QFilter,
    stride: usize,
    colscale: &[f32],
    epi: Epilogue,
    out: &mut Tensor,
) {
    if use_simd_kernel() {
        QPACK_SLOT.with(|slot| {
            let mut packed = slot.borrow_mut();
            packed.pack_into(f);
            let qp: &QPackedB = &packed;
            conv_i8_driver(x, f, stride, I8Kernel::Packed { qp }, colscale, epi, out);
        });
    } else {
        let kernel = I8Kernel::Scalar { b: &f.data, nz: &f.nz_rows };
        conv_i8_driver(x, f, stride, kernel, colscale, epi, out);
    }
}

/// [`conv2d_i8_scaled_into`] against a weight **pre-packed** with
/// [`QPackedB::pack`] — the engine's entry point (all quantized constants,
/// including this packing, are prepared at `Program` compile time). On
/// machines without AVX2 the packed operand is ignored and the scalar
/// kernel reads the plain [`QFilter`]; results are bit-identical either
/// way.
pub fn conv2d_i8_prepacked_into(
    x: &QTensor,
    f: &QFilter,
    packed: &QPackedB,
    stride: usize,
    colscale: &[f32],
    epi: Epilogue,
    out: &mut Tensor,
) {
    debug_assert_eq!(packed.k, f.kh * f.kw * f.ic, "packed operand k mismatch");
    debug_assert_eq!(packed.n, f.oc, "packed operand n mismatch");
    let kernel = if use_simd_kernel() {
        I8Kernel::Packed { qp: packed }
    } else {
        I8Kernel::Scalar { b: &f.data, nz: &f.nz_rows }
    };
    conv_i8_driver(x, f, stride, kernel, colscale, epi, out);
}

/// True when the AVX2 int8 microkernel should run. Follows the f32
/// dispatch (including its bench/test override), so one `force_backend`
/// call pins both kernels.
fn use_simd_kernel() -> bool {
    crate::tensor::gemm::active_backend() == crate::tensor::gemm::GemmBackend::Avx2
}

/// Shared driver: shape math, tiling, worker policy, tile draining.
fn conv_i8_driver(
    x: &QTensor,
    f: &QFilter,
    stride: usize,
    kernel: I8Kernel,
    colscale: &[f32],
    epi: Epilogue,
    out: &mut Tensor,
) {
    assert_eq!(x.c, f.ic, "channel mismatch");
    assert!(x.h >= f.kh && x.w >= f.kw, "filter larger than input");
    assert_eq!(colscale.len(), f.oc, "colscale length");
    if let Some(b) = epi.bias {
        assert_eq!(b.len(), f.oc, "bias length");
    }
    let oh = (x.h - f.kh) / stride + 1;
    let ow = (x.w - f.kw) / stride + 1;
    let kdim = f.kh * f.kw * f.ic;
    let n_out = f.oc;
    out.n = x.n;
    out.h = oh;
    out.w = ow;
    out.c = n_out;
    // no clear(): resize only zero-fills a grown tail; every element is
    // overwritten by exactly one tile below
    out.data.resize(x.n * oh * ow * n_out, 0.0);
    if out.data.is_empty() {
        return;
    }

    let map = TileMap::new(x.n, oh, ow, kdim, std::mem::size_of::<i8>());
    let macs = x.n * oh * ow * kdim * n_out;
    let workers = worker_count(macs, map.tiles);
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    parallel_drain(workers, &|cursor| {
        // per-thread persistent scratch (tile tasks never re-enter a conv
        // kernel, so the borrow cannot conflict)
        TILE_SCRATCH.with(|slot| {
            let mut scratch = slot.borrow_mut();
            loop {
                let t = cursor.fetch_add(1, Ordering::Relaxed);
                if t >= map.tiles {
                    break;
                }
                let (img, y0, rows) = map.tile(t);
                // SAFETY: tile t was claimed by exactly one fetch_add
                // winner; its rows*ow x n_out output block is disjoint
                // from every other tile's, and the pool barrier keeps
                // `out` alive until all tiles finish.
                let c = unsafe {
                    std::slice::from_raw_parts_mut(
                        out_ptr.get().add((img * oh + y0) * ow * n_out),
                        rows * ow * n_out,
                    )
                };
                run_tile(x, f, stride, ow, img, y0, rows, kernel, colscale, epi, c, &mut scratch);
            }
        });
    });
}

/// Pack one row tile's i8 im2col panel, then GEMM it against the i8 filter
/// with the requantizing epilogue into the tile's f32 output slice.
#[allow(clippy::too_many_arguments)] // mirrors the f32 kernel's tile runner
fn run_tile(
    x: &QTensor,
    f: &QFilter,
    stride: usize,
    ow: usize,
    img: usize,
    y0: usize,
    rows: usize,
    kernel: I8Kernel,
    colscale: &[f32],
    epi: Epilogue,
    c: &mut [f32],
    s: &mut Scratch,
) {
    let kdim = f.kh * f.kw * f.ic;
    let seg = f.kw * x.c; // one contiguous input-row segment per kernel row
    let m = rows * ow;
    let n = f.oc;
    // no zero-fill: the packing loop overwrites every element
    s.panel.resize(m * kdim, 0);
    for r in 0..rows {
        let oy = y0 + r;
        for ox in 0..ow {
            let dst_base = (r * ow + ox) * kdim;
            for dy in 0..f.kh {
                let src = x.idx(img, oy * stride + dy, ox * stride, 0);
                let dst = dst_base + dy * seg;
                s.panel[dst..dst + seg].copy_from_slice(&x.data[src..src + seg]);
            }
        }
    }
    if s.acc.len() < MR * n {
        s.acc.resize(MR * n, 0);
    }
    let mut row = 0;
    while row < m {
        let rows_now = (m - row).min(MR);
        let acc = &mut s.acc[..rows_now * n];
        match kernel {
            I8Kernel::Scalar { b, nz } => {
                acc_block_scalar(&s.panel, row, rows_now, kdim, b, nz, n, acc)
            }
            I8Kernel::Packed { qp } => {
                #[cfg(target_arch = "x86_64")]
                // SAFETY: dispatch guarantees AVX2 (use_simd_kernel);
                // panel rows [row, row+rows_now) and acc[..rows_now*n]
                // are in bounds by construction above.
                unsafe {
                    acc_block_avx2(&s.panel, row, rows_now, kdim, qp, n, acc)
                };
                #[cfg(not(target_arch = "x86_64"))]
                unreachable!("the packed int8 kernel only dispatches on x86_64");
            }
        }
        // ONE epilogue for both backends: requantize + bias + ReLU, per
        // element, in a fixed operation order — so backend choice can
        // never change an output bit
        for r in 0..rows_now {
            let crow = &mut c[(row + r) * n..(row + r + 1) * n];
            let arow = &acc[r * n..(r + 1) * n];
            for (col, ((cv, &av), &sc)) in crow.iter_mut().zip(arow).zip(colscale).enumerate() {
                *cv = epi.apply(col, av as f32 * sc);
            }
        }
        row += rows_now;
    }
}

/// Portable accumulation block: `acc[r][*] = Σ_k a[row+r][k] * b[k][*]`
/// over the non-zero filter rows, with the activation-zero skip (the
/// ASparse half of the paper's AWSparse policy: post-ReLU maps and the SD
/// input halo quantize to exact zeros, and skipping a zero i32
/// contribution is exact).
#[allow(clippy::too_many_arguments)] // GEMM block arguments mirror the f32 kernel
fn acc_block_scalar(
    a: &[i8],
    row0: usize,
    rows: usize,
    k: usize,
    b: &[i8],
    nz: &[u32],
    n: usize,
    acc: &mut [i32],
) {
    acc.fill(0);
    for &kk in nz {
        let kk = kk as usize;
        let mut vs = [0i32; MR];
        let mut any = 0i32;
        for (r, v) in vs.iter_mut().enumerate().take(rows) {
            *v = a[(row0 + r) * k + kk] as i32;
            any |= *v;
        }
        if any == 0 {
            continue; // all MR activations quantized-zero: skip, exact
        }
        let brow = &b[kk * n..(kk + 1) * n];
        for (r, &v) in vs.iter().enumerate().take(rows) {
            if v == 0 {
                continue;
            }
            let accr = &mut acc[r * n..(r + 1) * n];
            for (av, &w) in accr.iter_mut().zip(brow) {
                *av += v * (w as i32);
            }
        }
    }
}

/// AVX2 accumulation block over the pair-interleaved packed operand:
/// `_mm256_madd_epi16` computes each column's exact two-row i32 dot
/// product; structural zeros were removed at pack time (Wsparse) and
/// all-zero activation pairs are skipped (ASparse) — both exact, so this
/// is bit-identical to [`acc_block_scalar`].
///
/// # Safety
///
/// Caller must ensure AVX2 is available, `a` holds at least
/// `(row0+rows)*k` elements, and `acc` holds `rows * n` elements.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn acc_block_avx2(
    a: &[i8],
    row0: usize,
    rows: usize,
    k: usize,
    qp: &QPackedB,
    n: usize,
    acc: &mut [i32],
) {
    use std::arch::x86_64::*;
    debug_assert_eq!(qp.k, k);
    debug_assert_eq!(qp.n, n);
    let pairs = qp.pairs();
    let ap = a.as_ptr();
    let kidx = qp.raw_kidx();
    let dp = qp.raw_data().as_ptr();
    for p in 0..qp.panels() {
        let col0 = p * NR8;
        let cols = NR8.min(n - col0);
        let mut accv = [[_mm256_setzero_si256(); 2]; MR];
        for q in 0..pairs {
            let k0 = *kidx.get_unchecked(2 * q) as usize;
            let k1 = *kidx.get_unchecked(2 * q + 1) as usize;
            // a-side pair per row, packed as [lo=a(k0), hi=a(k1)] i16s
            let mut avals = [0i32; MR];
            let mut any = 0i32;
            for (r, slot) in avals.iter_mut().enumerate().take(rows) {
                let a0 = *ap.add((row0 + r) * k + k0) as i32;
                let a1 = *ap.add((row0 + r) * k + k1) as i32;
                any |= a0 | a1;
                *slot = ((a1 & 0xffff) << 16) | (a0 & 0xffff);
            }
            if any == 0 {
                continue; // every activation of the pair is zero: exact skip
            }
            let raw = _mm256_loadu_si256(dp.add((p * pairs + q) * 32) as *const __m256i);
            // bytes -> i16 pairs: lanes [c0k0, c0k1, c1k0, ...] for
            // columns 0..7 (lo) and 8..15 (hi)
            let lo = _mm256_cvtepi8_epi16(_mm256_castsi256_si128(raw));
            let hi = _mm256_cvtepi8_epi16(_mm256_extracti128_si256::<1>(raw));
            for (r, accr) in accv.iter_mut().enumerate().take(rows) {
                let va = _mm256_set1_epi32(avals[r]);
                accr[0] = _mm256_add_epi32(accr[0], _mm256_madd_epi16(lo, va));
                accr[1] = _mm256_add_epi32(accr[1], _mm256_madd_epi16(hi, va));
            }
        }
        for (r, accr) in accv.iter().enumerate().take(rows) {
            if cols == NR8 {
                let dst = acc.as_mut_ptr().add(r * n + col0);
                _mm256_storeu_si256(dst as *mut __m256i, accr[0]);
                _mm256_storeu_si256(dst.add(8) as *mut __m256i, accr[1]);
            } else {
                let mut buf = [0i32; NR8];
                _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, accr[0]);
                _mm256_storeu_si256(buf.as_mut_ptr().add(8) as *mut __m256i, accr[1]);
                acc[r * n + col0..r * n + col0 + cols].copy_from_slice(&buf[..cols]);
            }
        }
    }
}

/// Scalar reference int8 convolution: the plain 7-deep loop with i32
/// accumulation and the identical epilogue expression — the zero-tolerance
/// oracle for [`conv2d_i8_into`] (i32 accumulation is exact, and the
/// epilogue computes `acc as f32 * (x.scale * f.scales[o])` in the same
/// operation order, so the kernels agree bit for bit on every backend).
pub fn conv2d_i8_naive(x: &QTensor, f: &QFilter, stride: usize, epi: Epilogue) -> Tensor {
    assert_eq!(x.c, f.ic, "channel mismatch");
    assert!(x.h >= f.kh && x.w >= f.kw, "filter larger than input");
    let oh = (x.h - f.kh) / stride + 1;
    let ow = (x.w - f.kw) / stride + 1;
    let colscale: Vec<f32> = f.scales.iter().map(|&s| x.scale * s).collect();
    let fidx = |kh: usize, kw: usize, ic: usize, oc: usize| {
        ((kh * f.kw + kw) * f.ic + ic) * f.oc + oc
    };
    let mut out = Tensor::zeros(x.n, oh, ow, f.oc);
    for n in 0..x.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for o in 0..f.oc {
                    let mut acc: i32 = 0;
                    for dy in 0..f.kh {
                        for dx in 0..f.kw {
                            for i in 0..x.c {
                                let xv = x.data[x.idx(n, oy * stride + dy, ox * stride + dx, i)]
                                    as i32;
                                let wv = f.data[fidx(dy, dx, i, o)] as i32;
                                acc += xv * wv;
                            }
                        }
                    }
                    *out.at_mut(n, oy, ox, o) = epi.apply(o, acc as f32 * colscale[o]);
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::scheme::{absmax, quantize_filter, quantize_into, scale_for_absmax};
    use super::*;
    use crate::tensor::Filter;
    use crate::util::rng::Rng;

    fn qpair(h: usize, w: usize, ic: usize, k: usize, oc: usize, seed: u64) -> (QTensor, QFilter) {
        let mut rng = Rng::new(seed);
        let x = Tensor::randn(2, h, w, ic, &mut rng);
        let f = Filter::randn(k, k, ic, oc, &mut rng);
        let mut qx = QTensor::empty();
        quantize_into(&x, scale_for_absmax(absmax(&x.data)), &mut qx);
        (qx, quantize_filter(&f))
    }

    #[test]
    fn blocked_kernel_is_bit_exact_with_naive() {
        for (i, &(h, w, ic, k, oc, s)) in
            [(6, 6, 3, 3, 4, 1), (9, 13, 5, 3, 7, 2), (5, 5, 1, 5, 1, 1)].iter().enumerate()
        {
            let (qx, qf) = qpair(h, w, ic, k, oc, 31 + i as u64);
            let mut got = Tensor::zeros(0, 0, 0, 0);
            conv2d_i8_into(&qx, &qf, s, Epilogue::none(), &mut got);
            let want = conv2d_i8_naive(&qx, &qf, s, Epilogue::none());
            assert_eq!(got.shape(), want.shape());
            assert_eq!(got.max_abs_diff(&want), 0.0, "case {i} not bit-exact");
        }
    }

    #[test]
    fn prepacked_entry_is_bit_exact_with_naive_and_scalar() {
        // oc = 21 exercises the partial tail panel; odd nz count exercises
        // the zero-padded pair tail
        let (qx, qf) = qpair(8, 9, 5, 3, 21, 97);
        let packed = QPackedB::pack(&qf);
        assert_eq!(packed.n, 21);
        assert_eq!(packed.panels(), 2);
        let colscale: Vec<f32> = qf.scales.iter().map(|&s| qx.scale * s).collect();
        let mut got = Tensor::zeros(0, 0, 0, 0);
        conv2d_i8_prepacked_into(&qx, &qf, &packed, 1, &colscale, Epilogue::none(), &mut got);
        let want = conv2d_i8_naive(&qx, &qf, 1, Epilogue::none());
        assert_eq!(got.shape(), want.shape());
        assert_eq!(got.max_abs_diff(&want), 0.0, "prepacked path not bit-exact");
    }

    #[test]
    fn packed_operand_drops_structural_zero_rows() {
        let mut rng = Rng::new(5);
        // SD expansion-case splits carry structurally zero rows
        let f = Filter::randn(5, 5, 3, 4, &mut rng);
        let splits = super::super::scheme::pack_sd_splits(&f, 2);
        let with_zeros = splits
            .iter()
            .find(|q| q.nz_rows.len() < q.kh * q.kw * q.ic)
            .expect("an expansion split with structural zeros");
        let packed = QPackedB::pack(with_zeros);
        assert_eq!(packed.pairs(), with_zeros.nz_rows.len().div_ceil(2));
        assert!(
            packed.pairs() * 2 < with_zeros.kh * with_zeros.kw * with_zeros.ic + 2,
            "packing must not reintroduce structurally-zero rows"
        );
    }

    #[test]
    fn epilogue_fuses_bias_and_relu() {
        let (qx, qf) = qpair(6, 6, 3, 3, 4, 77);
        let bias: Vec<f32> = (0..4).map(|i| i as f32 - 1.5).collect();
        let epi = Epilogue { bias: Some(&bias), relu: true };
        let mut fused = Tensor::zeros(0, 0, 0, 0);
        conv2d_i8_into(&qx, &qf, 1, epi, &mut fused);
        // reference: plain requantize, then bias, then relu, separately
        let mut plain = Tensor::zeros(0, 0, 0, 0);
        conv2d_i8_into(&qx, &qf, 1, Epilogue::none(), &mut plain);
        for (i, v) in plain.data.iter_mut().enumerate() {
            *v = (*v + bias[i % 4]).max(0.0);
        }
        assert_eq!(fused.max_abs_diff(&plain), 0.0);
        assert!(fused.data.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn quantized_conv_tracks_f32_conv() {
        // not bit-exact (that is the point of quantization) but close:
        // the i8 result must stay within a few quantization steps of f32
        let mut rng = Rng::new(3);
        let x = Tensor::randn(1, 8, 8, 16, &mut rng);
        let f = Filter::randn(3, 3, 16, 8, &mut rng);
        let want = crate::tensor::conv2d_valid(&x, &f, 1);
        let mut qx = QTensor::empty();
        quantize_into(&x, scale_for_absmax(absmax(&x.data)), &mut qx);
        let mut got = Tensor::zeros(0, 0, 0, 0);
        conv2d_i8_into(&qx, &quantize_filter(&f), 1, Epilogue::none(), &mut got);
        let denom = absmax(&want.data).max(1e-6);
        let rel = got.max_abs_diff(&want) / denom;
        assert!(rel < 0.05, "relative error {rel}");
    }
}
