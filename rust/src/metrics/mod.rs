//! Image-quality metrics: SSIM (Wang et al. [37]) for the paper's Table 4 /
//! Figures 13–14 deconvolution-conversion quality evaluation, plus summary
//! statistics helpers.

pub mod ssim;

pub use ssim::{ssim, ssim_tensor};

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Percentile (nearest-rank) of an unsorted slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_basics() {
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 50.0), 3.0);
        assert_eq!(percentile(&v, 100.0), 5.0);
    }

    #[test]
    fn mean_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }
}
