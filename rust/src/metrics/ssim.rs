//! SSIM — structural similarity index (Wang, Bovik, Sheikh, Simoncelli 2004),
//! the metric the paper uses in Table 4 to compare deconvolution conversion
//! approaches. Standard parameters: 11x11 gaussian window, sigma 1.5,
//! K1=0.01, K2=0.03, dynamic range L given by the caller.

use crate::tensor::Tensor;

const WIN: usize = 11;
const SIGMA: f64 = 1.5;
const K1: f64 = 0.01;
const K2: f64 = 0.03;

fn gaussian_kernel() -> [f64; WIN] {
    let mut k = [0.0; WIN];
    let c = (WIN / 2) as f64;
    let mut sum = 0.0;
    for (i, v) in k.iter_mut().enumerate() {
        let d = i as f64 - c;
        *v = (-d * d / (2.0 * SIGMA * SIGMA)).exp();
        sum += *v;
    }
    for v in &mut k {
        *v /= sum;
    }
    k
}

/// Separable gaussian blur of a single-channel image (valid region only).
fn blur(img: &[f64], h: usize, w: usize) -> (Vec<f64>, usize, usize) {
    let k = gaussian_kernel();
    let oh = h - WIN + 1;
    let ow = w - WIN + 1;
    // horizontal pass
    let mut tmp = vec![0.0; h * ow];
    for y in 0..h {
        for x in 0..ow {
            let mut acc = 0.0;
            for (i, kv) in k.iter().enumerate() {
                acc += img[y * w + x + i] * kv;
            }
            tmp[y * ow + x] = acc;
        }
    }
    // vertical pass
    let mut out = vec![0.0; oh * ow];
    for y in 0..oh {
        for x in 0..ow {
            let mut acc = 0.0;
            for (i, kv) in k.iter().enumerate() {
                acc += tmp[(y + i) * ow + x] * kv;
            }
            out[y * ow + x] = acc;
        }
    }
    (out, oh, ow)
}

/// SSIM between two single-channel images with dynamic range `l`.
/// Images smaller than the 11x11 window fall back to the global statistics
/// formula over the whole image.
pub fn ssim(a: &[f64], b: &[f64], h: usize, w: usize, l: f64) -> f64 {
    assert_eq!(a.len(), h * w);
    assert_eq!(b.len(), h * w);
    let c1 = (K1 * l) * (K1 * l);
    let c2 = (K2 * l) * (K2 * l);

    if h < WIN || w < WIN {
        // global SSIM
        let n = (h * w) as f64;
        let mu_a = a.iter().sum::<f64>() / n;
        let mu_b = b.iter().sum::<f64>() / n;
        let var_a = a.iter().map(|x| (x - mu_a) * (x - mu_a)).sum::<f64>() / n;
        let var_b = b.iter().map(|x| (x - mu_b) * (x - mu_b)).sum::<f64>() / n;
        let cov = a
            .iter()
            .zip(b)
            .map(|(x, y)| (x - mu_a) * (y - mu_b))
            .sum::<f64>()
            / n;
        return ((2.0 * mu_a * mu_b + c1) * (2.0 * cov + c2))
            / ((mu_a * mu_a + mu_b * mu_b + c1) * (var_a + var_b + c2));
    }

    let sq = |v: &[f64]| v.iter().map(|x| x * x).collect::<Vec<f64>>();
    let prod: Vec<f64> = a.iter().zip(b).map(|(x, y)| x * y).collect();

    let (mu_a, oh, ow) = blur(a, h, w);
    let (mu_b, _, _) = blur(b, h, w);
    let (e_a2, _, _) = blur(&sq(a), h, w);
    let (e_b2, _, _) = blur(&sq(b), h, w);
    let (e_ab, _, _) = blur(&prod, h, w);

    let mut total = 0.0;
    for i in 0..oh * ow {
        let (ma, mb) = (mu_a[i], mu_b[i]);
        let va = e_a2[i] - ma * ma;
        let vb = e_b2[i] - mb * mb;
        let cab = e_ab[i] - ma * mb;
        total += ((2.0 * ma * mb + c1) * (2.0 * cab + c2))
            / ((ma * ma + mb * mb + c1) * (va + vb + c2));
    }
    total / (oh * ow) as f64
}

/// Mean SSIM over batch and channels of two NHWC tensors. `l` is the dynamic
/// range of the data (2.0 for tanh outputs in [-1, 1]).
pub fn ssim_tensor(a: &Tensor, b: &Tensor, l: f64) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let mut vals = Vec::new();
    for n in 0..a.n {
        for c in 0..a.c {
            let pa: Vec<f64> = (0..a.h * a.w)
                .map(|i| a.data[((n * a.h + i / a.w) * a.w + i % a.w) * a.c + c] as f64)
                .collect();
            let pb: Vec<f64> = (0..b.h * b.w)
                .map(|i| b.data[((n * b.h + i / b.w) * b.w + i % b.w) * b.c + c] as f64)
                .collect();
            vals.push(ssim(&pa, &pb, a.h, a.w, l));
        }
    }
    vals.iter().sum::<f64>() / vals.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn identical_images_are_one() {
        let mut rng = Rng::new(20);
        let img: Vec<f64> = (0..64 * 64).map(|_| rng.uniform() as f64).collect();
        let s = ssim(&img, &img, 64, 64, 1.0);
        assert!((s - 1.0).abs() < 1e-9, "ssim {s}");
    }

    #[test]
    fn noise_reduces_ssim() {
        let mut rng = Rng::new(21);
        let img: Vec<f64> = (0..64 * 64).map(|_| rng.uniform() as f64).collect();
        let noisy: Vec<f64> = img
            .iter()
            .map(|v| v + 0.6 * (rng.uniform() as f64 - 0.5))
            .collect();
        let s = ssim(&img, &noisy, 64, 64, 1.0);
        assert!(s < 0.93 && s > 0.0, "ssim {s}");
    }

    #[test]
    fn shift_reduces_ssim_more_on_small_images() {
        // the effect behind the paper's DCGAN-vs-FST Shi SSIM gap
        let mk = |side: usize, shift: usize, rng: &mut Rng| {
            // smooth image: sum of a few sinusoids
            let f1 = 0.13 + rng.uniform() as f64 * 0.02;
            let img = |sh: usize| {
                (0..side * side)
                    .map(|i| {
                        let (y, x) = (i / side + sh, i % side + sh);
                        ((y as f64 * f1).sin() + (x as f64 * 0.07).cos()) * 0.5
                    })
                    .collect::<Vec<f64>>()
            };
            ssim(&img(0), &img(shift), side, side, 2.0)
        };
        let mut rng = Rng::new(22);
        let small = mk(32, 2, &mut rng);
        let large = mk(256, 2, &mut rng);
        assert!(small < large, "small {small} large {large}");
    }

    #[test]
    fn tensor_ssim_identity() {
        let mut rng = Rng::new(23);
        let t = crate::tensor::Tensor::randn(1, 32, 32, 3, &mut rng);
        assert!((ssim_tensor(&t, &t, 2.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn tiny_image_global_fallback() {
        let a = vec![0.5; 16];
        let b = vec![0.5; 16];
        assert!((ssim(&a, &b, 4, 4, 1.0) - 1.0).abs() < 1e-6);
    }
}
