//! Split Deconvolution — the paper's Section 4 contribution, in rust.
//!
//! `sd_deconv2d` is bit-exact with `tensor::deconv2d` (proven by
//! rust/tests/sd_exactness.rs property tests). The submodules implement the
//! prior-work baselines the paper compares against in Table 4:
//! [`shi`] (Shi et al. [30], wrong fixed padding) and [`chang`]
//! (Chang & Kang [31], approximate conversion).

pub mod chang;
pub mod nzp;
pub mod shi;

use crate::tensor::{conv2d_valid, Filter, Tensor};

/// Derived sizes of one SD conversion (paper Eqs. 1–3 and 9).
///
/// Splitting a `K x K`, stride-`S` deconvolution into `S*S` stride-1
/// convolutions requires three derived quantities:
///
/// * **Eq. 1** — split-filter side `K_T = ceil(K / S)`: the deconv filter is
///   sampled with stride `S` per output phase, so each sub-filter covers
///   `K_T` taps per axis.
/// * **Eq. 2** — filter zero-pad `P_K = S * K_T - K`, added to the *top and
///   left* of the original filter so its side becomes divisible by `S`
///   before sampling. These padded zeros are the "expansion zeros" the
///   Wsparse skip policy later elides.
/// * **Eq. 3** — input zero-pad `P_I = K_T - 1`, added to *all four sides*
///   of the input feature map so every split convolution (run "valid")
///   produces the full `I + K_T - 1` output side its phase needs.
/// * **Eq. 9** — interleave crop offset `P_K + P`: after the `S*S` outputs
///   are interleaved into the `S * (I + K_T - 1)` grid, the true
///   deconvolution output starts `P_K + P` pixels in from the top-left
///   (`P` is the deconvolution's own layer padding).
///
/// # Worked examples
///
/// The divisible case, SNGAN-style `K=4, S=2, P=1`:
///
/// ```
/// use split_deconv::sd::SdGeometry;
/// let g = SdGeometry::new(4, 2, 1);
/// assert_eq!(g.k_t, 2); // Eq. 1: ceil(4/2)
/// assert_eq!(g.p_k, 0); // Eq. 2: 2*2 - 4 — no expansion zeros
/// assert_eq!(g.p_i, 1); // Eq. 3: 2 - 1
/// assert_eq!(g.crop(), 1); // Eq. 9: 0 + 1
/// assert_eq!(g.n_splits(), 4);
/// // an 8x8 input: each split conv outputs 9x9, interleaved grid 18x18,
/// // final deconv output (8-1)*2 + 4 - 2*1 = 16 per side
/// assert_eq!(g.conv_out(8), 9);
/// assert_eq!(g.big_out(8), 18);
/// assert_eq!(g.final_out(8, 0), 16);
/// ```
///
/// The expansion case, DCGAN's `K=5, S=2, P=2` deconvolutions:
///
/// ```
/// use split_deconv::sd::SdGeometry;
/// let g = SdGeometry::new(5, 2, 2);
/// assert_eq!(g.k_t, 3); // Eq. 1: ceil(5/2)
/// assert_eq!(g.p_k, 1); // Eq. 2: 2*3 - 5 — one zero row+column of taps
/// assert_eq!(g.p_i, 2); // Eq. 3: 3 - 1
/// assert_eq!(g.crop(), 3); // Eq. 9: 1 + 2
/// // deconv1, 8x8 input with output padding 1: 16x16 output
/// assert_eq!(g.final_out(8, 1), 16);
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SdGeometry {
    /// original deconvolution filter side `K`
    pub k: usize,
    /// deconvolution stride `S`
    pub s: usize,
    /// deconvolution layer padding `P`
    pub p: usize,
    /// split filter side, `ceil(K/S)` (paper Eq. 1)
    pub k_t: usize,
    /// filter zero-pad on the top & left, `S*K_T - K` (paper Eq. 2)
    pub p_k: usize,
    /// input feature zero-pad on all sides, `K_T - 1` (paper Eq. 3)
    pub p_i: usize,
}

impl SdGeometry {
    pub fn new(k: usize, s: usize, p: usize) -> Self {
        let k_t = k.div_ceil(s);
        SdGeometry {
            k,
            s,
            p,
            k_t,
            p_k: s * k_t - k,
            p_i: k_t - 1,
        }
    }

    pub fn n_splits(&self) -> usize {
        self.s * self.s
    }

    /// Spatial side of each split convolution output for input side `i`.
    pub fn conv_out(&self, i: usize) -> usize {
        i + 2 * self.p_i - self.k_t + 1 // == i + k_t - 1
    }

    /// Side of the interleaved (pre-crop) grid.
    pub fn big_out(&self, i: usize) -> usize {
        self.s * self.conv_out(i)
    }

    /// Equivalent deconvolution output side (with output padding `op`).
    pub fn final_out(&self, i: usize, op: usize) -> usize {
        (i - 1) * self.s + self.k - 2 * self.p + op
    }

    /// Top/left crop into the interleaved grid, `P_K + P` (paper Eq. 9).
    pub fn crop(&self) -> usize {
        self.p_k + self.p
    }
}

/// Step 1 + 2 (paper Eqs. 1–8): zero-expand the deconv filter on the top and
/// left so its side is divisible by `s`, then sample with stride `s` and
/// rotate each sub-filter 180 degrees. Returns `s*s` conv filters of side
/// `K_T`, in row-major split order `n = r*s + c`.
pub fn split_filters(f: &Filter, s: usize) -> Vec<Filter> {
    assert_eq!(f.kh, f.kw, "square deconv filters only");
    let g = SdGeometry::new(f.kh, s, 0);
    let side = s * g.k_t;
    // padded filter: zeros on top & left
    let mut padded = Filter::zeros(side, side, f.ic, f.oc);
    for y in 0..f.kh {
        for x in 0..f.kw {
            for i in 0..f.ic {
                for o in 0..f.oc {
                    *padded.at_mut(y + g.p_k, x + g.p_k, i, o) = f.at(y, x, i, o);
                }
            }
        }
    }
    let mut out = Vec::with_capacity(s * s);
    for n in 0..s * s {
        let (r, c) = (n / s, n % s);
        let mut sub = Filter::zeros(g.k_t, g.k_t, f.ic, f.oc);
        for y in 0..g.k_t {
            for x in 0..g.k_t {
                for i in 0..f.ic {
                    for o in 0..f.oc {
                        // sample with stride s, then rotate 180
                        *sub.at_mut(g.k_t - 1 - y, g.k_t - 1 - x, i, o) =
                            padded.at(r + y * s, c + x * s, i, o);
                    }
                }
            }
        }
        out.push(sub);
    }
    out
}

/// Step 4 (paper Eqs. 10–13): interleave the `s*s` split-convolution outputs
/// into the deconvolution grid: `big[r::s, c::s] = convs[r*s+c]`.
/// This is the operation the paper maps to the processor's *stride write*
/// DMA instruction; here it is a strided memcpy.
pub fn interleave(convs: &[Tensor], s: usize) -> Tensor {
    assert_eq!(convs.len(), s * s);
    let t0 = &convs[0];
    let (n, oh, ow, oc) = (t0.n, t0.h, t0.w, t0.c);
    for t in convs {
        assert_eq!(t.shape(), [n, oh, ow, oc], "split outputs must agree");
    }
    let mut big = Tensor::zeros(n, oh * s, ow * s, oc);
    for (idx, t) in convs.iter().enumerate() {
        let (r, c) = (idx / s, idx % s);
        for b in 0..n {
            for y in 0..oh {
                for x in 0..ow {
                    let src = t.idx(b, y, x, 0);
                    let dst = big.idx(b, y * s + r, x * s + c, 0);
                    big.data[dst..dst + oc].copy_from_slice(&t.data[src..src + oc]);
                }
            }
        }
    }
    big
}

/// Fused steps 4 + 5 (Eqs. 10–13 + Eq. 9): interleave the `s*s` split
/// outputs and crop the deconvolution window in ONE pass, writing only the
/// surviving cells straight into `out` — the intermediate
/// `s * (I + K_T - 1)` grid of [`interleave`] is never materialized. `out`
/// is reshaped to `(n, oh, ow, oc)` in place (reusing capacity); cells past
/// the interleave grid (output padding overhang) are zero, exactly like
/// `crop_padded`. Bit-identical to
/// `interleave(convs, s).crop_padded(crop, oh, crop, ow)` — property-tested
/// in rust/tests/sd_exactness.rs. This runs on the engine's *per-request*
/// hot path (once per SD deconv layer per forward call).
pub fn interleave_crop_into(
    convs: &[Tensor],
    s: usize,
    crop: usize,
    oh: usize,
    ow: usize,
    out: &mut Tensor,
) {
    assert_eq!(convs.len(), s * s);
    let t0 = &convs[0];
    let (n, ch, cw, oc) = (t0.n, t0.h, t0.w, t0.c);
    for t in convs {
        assert_eq!(t.shape(), [n, ch, cw, oc], "split outputs must agree");
    }
    out.n = n;
    out.h = oh;
    out.w = ow;
    out.c = oc;
    out.data.clear();
    out.data.resize(n * oh * ow * oc, 0.0);
    for (idx, t) in convs.iter().enumerate() {
        let (r, c) = (idx / s, idx % s);
        for b in 0..n {
            for y in 0..ch {
                let ty = y * s + r;
                if ty < crop {
                    continue;
                }
                let ty = ty - crop;
                if ty >= oh {
                    break; // y ascending: every later row is cropped too
                }
                for x in 0..cw {
                    let tx = x * s + c;
                    if tx < crop {
                        continue;
                    }
                    let tx = tx - crop;
                    if tx >= ow {
                        break;
                    }
                    let src = t.idx(b, y, x, 0);
                    let dst = out.idx(b, ty, tx, 0);
                    out.data[dst..dst + oc].copy_from_slice(&t.data[src..src + oc]);
                }
            }
        }
    }
}

/// Full SD pipeline: pad input (step 3) -> s^2 stride-1 convs -> interleave
/// (step 4) -> crop. Bit-exact with `tensor::deconv2d(x, f, s, p, op)`.
/// The per-split stride-1 convolutions run on the im2col + GEMM hot path
/// ([`conv2d_valid`]) — the software analogue of mapping every split onto a
/// fully utilized dense convolution engine.
pub fn sd_deconv2d(x: &Tensor, f: &Filter, s: usize, p: usize, op: usize) -> Tensor {
    let g = SdGeometry::new(f.kh, s, p);
    let xp = x.pad(g.p_i, g.p_i, g.p_i, g.p_i);
    let convs: Vec<Tensor> = split_filters(f, s)
        .iter()
        .map(|w| conv2d_valid(&xp, w, 1))
        .collect();
    let big = interleave(&convs, s);
    let c0 = g.crop();
    let oh = g.final_out(x.h, op);
    let ow = (x.w - 1) * s + f.kw - 2 * p + op;
    big.crop_padded(c0, oh, c0, ow)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::deconv2d;
    use crate::util::rng::Rng;

    #[test]
    fn geometry_matches_paper_equations() {
        let g = SdGeometry::new(5, 2, 2);
        assert_eq!((g.k_t, g.p_k, g.p_i, g.n_splits()), (3, 1, 2, 4));
        let g = SdGeometry::new(4, 2, 1);
        assert_eq!((g.k_t, g.p_k, g.p_i), (2, 0, 1));
        let g = SdGeometry::new(3, 2, 1);
        assert_eq!((g.k_t, g.p_k, g.p_i), (2, 1, 1));
        let g = SdGeometry::new(3, 1, 1);
        assert_eq!((g.k_t, g.p_k), (3, 0));
    }

    #[test]
    fn split_preserves_weights() {
        let mut rng = Rng::new(4);
        let f = Filter::randn(5, 5, 2, 3, &mut rng);
        let splits = split_filters(&f, 2);
        assert_eq!(splits.len(), 4);
        let total: f32 = splits.iter().flat_map(|s| &s.data).map(|v| v.abs()).sum();
        let orig: f32 = f.data.iter().map(|v| v.abs()).sum();
        assert!((total - orig).abs() < 1e-4);
    }

    #[test]
    fn sd_exact_dcgan_layer() {
        let mut rng = Rng::new(5);
        let x = Tensor::randn(1, 8, 8, 16, &mut rng);
        let f = Filter::randn(5, 5, 16, 8, &mut rng);
        let want = deconv2d(&x, &f, 2, 2, 1);
        let got = sd_deconv2d(&x, &f, 2, 2, 1);
        assert_eq!(got.shape(), want.shape());
        assert!(got.allclose(&want, 1e-4), "diff {}", got.max_abs_diff(&want));
    }

    #[test]
    fn sd_exact_rectangular() {
        let mut rng = Rng::new(6);
        let x = Tensor::randn(2, 4, 8, 3, &mut rng);
        let f = Filter::randn(3, 3, 3, 5, &mut rng);
        let want = deconv2d(&x, &f, 2, 1, 1);
        let got = sd_deconv2d(&x, &f, 2, 1, 1);
        assert!(got.allclose(&want, 1e-4));
    }

    #[test]
    fn interleave_crop_into_matches_two_step() {
        let mut rng = Rng::new(31);
        let cases = [(2, 5, 7, 3, 1), (2, 4, 4, 1, 0), (3, 3, 3, 2, 2), (1, 6, 6, 2, 0)];
        for (s, ch, cw, crop, op) in cases {
            let convs: Vec<Tensor> =
                (0..s * s).map(|_| Tensor::randn(2, ch, cw, 3, &mut rng)).collect();
            let big = interleave(&convs, s);
            let (oh, ow) = (big.h - crop - 1 + op, big.w - crop + op);
            let want = big.crop_padded(crop, oh, crop, ow);
            let mut got = Tensor::zeros(0, 0, 0, 0);
            interleave_crop_into(&convs, s, crop, oh, ow, &mut got);
            assert_eq!(got.shape(), want.shape());
            assert_eq!(got.max_abs_diff(&want), 0.0, "s{s} crop{crop} op{op}");
        }
    }

    #[test]
    fn interleave_places_phases() {
        let mut t = Vec::new();
        for v in 0..4 {
            let mut x = Tensor::zeros(1, 2, 2, 1);
            x.data.fill(v as f32);
            t.push(x);
        }
        let big = interleave(&t, 2);
        assert_eq!(big.shape(), [1, 4, 4, 1]);
        assert_eq!(big.at(0, 0, 0, 0), 0.0); // split 0 at (even, even)
        assert_eq!(big.at(0, 0, 1, 0), 1.0); // split 1 at (even, odd)
        assert_eq!(big.at(0, 1, 0, 0), 2.0); // split 2 at (odd, even)
        assert_eq!(big.at(0, 3, 3, 0), 3.0); // split 3 at (odd, odd)
    }
}
