//! The approximate deconvolution-to-convolution conversion of Chang & Kang
//! [31] ("Optimizing FPGA-based CNN accelerator for image super-resolution"),
//! reproduced for the Table 4 / Figure 13-14 quality comparison.
//!
//! Their transform targets super-resolution, which tolerates computing
//! errors: instead of s^2 distinct split filters it derives ONE deformed
//! filter (the phase-average of the splits) and fills all s^2 output phases
//! from that single convolution. It also rearranges results on the host CPU
//! (which the paper under reproduction criticizes for the CPU<->accelerator
//! traffic — modeled in the commodity experiments).

use super::{split_filters, SdGeometry};
use crate::tensor::{conv2d_valid, Filter, Tensor};

/// Chang-style approximate conversion: average the split filters, run one
/// stride-1 convolution, replicate each output pixel into its s x s phase
/// block (nearest-phase fill).
pub fn chang_deconv2d(x: &Tensor, f: &Filter, s: usize, p: usize, op: usize) -> Tensor {
    let g = SdGeometry::new(f.kh, s, p);
    let splits = split_filters(f, s);
    // deformed filter = mean over phases (approximation)
    let mut avg = Filter::zeros(g.k_t, g.k_t, f.ic, f.oc);
    for sp in &splits {
        for (a, b) in avg.data.iter_mut().zip(&sp.data) {
            *a += b / (splits.len() as f32);
        }
    }
    let xp = x.pad(g.p_i, g.p_i, g.p_i, g.p_i);
    let conv = conv2d_valid(&xp, &avg, 1);
    // fill the s x s phases by bilinear interpolation of the single
    // convolution output (the smooth phase fill the approximation relies
    // on: exact for the aligned phase, interpolated for the rest)
    let mut big = Tensor::zeros(conv.n, conv.h * s, conv.w * s, conv.c);
    for n in 0..conv.n {
        for by in 0..big.h {
            let fy = by as f32 / s as f32;
            let y0 = (fy.floor() as usize).min(conv.h - 1);
            let y1 = (y0 + 1).min(conv.h - 1);
            let wy = fy - y0 as f32;
            for bx in 0..big.w {
                let fx = bx as f32 / s as f32;
                let x0 = (fx.floor() as usize).min(conv.w - 1);
                let x1 = (x0 + 1).min(conv.w - 1);
                let wx = fx - x0 as f32;
                for c in 0..conv.c {
                    let v00 = conv.at(n, y0, x0, c);
                    let v01 = conv.at(n, y0, x1, c);
                    let v10 = conv.at(n, y1, x0, c);
                    let v11 = conv.at(n, y1, x1, c);
                    *big.at_mut(n, by, bx, c) = v00 * (1.0 - wy) * (1.0 - wx)
                        + v01 * (1.0 - wy) * wx
                        + v10 * wy * (1.0 - wx)
                        + v11 * wy * wx;
                }
            }
        }
    }
    let c0 = g.crop();
    let oh = g.final_out(x.h, op);
    let ow = (x.w - 1) * s + f.kw - 2 * p + op;
    big.crop_padded(c0, oh, c0, ow)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::deconv2d;
    use crate::util::rng::Rng;

    #[test]
    fn chang_is_approximate() {
        let mut rng = Rng::new(13);
        let x = Tensor::randn(1, 8, 8, 4, &mut rng);
        let f = Filter::randn(4, 4, 4, 3, &mut rng);
        let want = deconv2d(&x, &f, 2, 1, 0);
        let got = chang_deconv2d(&x, &f, 2, 1, 0);
        assert_eq!(got.shape(), want.shape());
        assert!(got.max_abs_diff(&want) > 1e-2, "chang unexpectedly exact");
    }

    #[test]
    fn chang_preserves_dc_component() {
        // On a constant input, deconv output interior is constant = sum(w);
        // the phase-averaged filter preserves that mean, so interiors agree.
        let x = Tensor::from_vec(1, 8, 8, 1, vec![1.0; 64]);
        let mut f = Filter::zeros(4, 4, 1, 1);
        f.data.iter_mut().for_each(|v| *v = 0.25);
        let want = deconv2d(&x, &f, 2, 1, 0);
        let got = chang_deconv2d(&x, &f, 2, 1, 0);
        // compare a deep-interior pixel
        let c = want.h / 2;
        assert!(
            (got.at(0, c, c, 0) - want.at(0, c, c, 0)).abs() < 1e-4,
            "{} vs {}",
            got.at(0, c, c, 0),
            want.at(0, c, c, 0)
        );
    }
}
