//! Naive Zero-Padding deconvolution (the paper's Figure 1(b) baseline):
//! zero-insert the feature map, then run one dense stride-1 convolution
//! with the 180-rotated filter. Numerically exact, computationally ~s^2x
//! redundant — the inefficiency the paper attacks.

use crate::tensor::{conv2d, zero_insert, Filter, Tensor};

/// NZP-converted deconvolution: exact, but dense over the inflated map.
pub fn nzp_deconv2d(x: &Tensor, f: &Filter, s: usize, p: usize, op: usize) -> Tensor {
    let xd = zero_insert(x, s);
    let pad = f.kh - 1 - p;
    let full = conv2d(&xd, &f.rot180(), 1, pad);
    // conv output side: (i-1)s+1 + 2(k-1-p) - k + 1 = (i-1)s + k - 2p ... = out - op
    let oh = (x.h - 1) * s + f.kh - 2 * p + op;
    let ow = (x.w - 1) * s + f.kw - 2 * p + op;
    // output padding keeps `op` extra rows/cols at the bottom/right: they are
    // part of the *full* (uncropped) deconv output, so re-derive from full.
    if op == 0 {
        return full;
    }
    let fullpad = conv2d(&zero_insert(x, s), &f.rot180(), 1, f.kh - 1);
    fullpad.crop_padded(p, oh, p, ow)
}

/// The zero-inserted feature map itself (what the processor actually reads) —
/// used by the simulators to account buffer traffic and skip opportunities.
pub fn nzp_input(x: &Tensor, f: &Filter, s: usize, p: usize) -> Tensor {
    let xd = zero_insert(x, s);
    let pad = f.kh - 1 - p;
    xd.pad(pad, pad, pad, pad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::deconv2d;
    use crate::util::rng::Rng;

    #[test]
    fn nzp_exact() {
        let mut rng = Rng::new(7);
        for (i, k, s, p, op) in [
            (4, 4, 2, 1, 0),
            (8, 5, 2, 2, 1),
            (6, 3, 2, 1, 1),
            (5, 3, 1, 1, 0),
        ] {
            let x = Tensor::randn(1, i, i, 4, &mut rng);
            let f = Filter::randn(k, k, 4, 3, &mut rng);
            let want = deconv2d(&x, &f, s, p, op);
            let got = nzp_deconv2d(&x, &f, s, p, op);
            assert_eq!(got.shape(), want.shape());
            assert!(got.allclose(&want, 1e-4), "k{k} s{s}: {}", got.max_abs_diff(&want));
        }
    }

    #[test]
    fn nzp_input_sparsity() {
        // stride-2 zero insertion makes ~3/4 of the map zero (plus halo).
        let mut rng = Rng::new(8);
        let x = Tensor::randn(1, 8, 8, 2, &mut rng);
        let f = Filter::randn(4, 4, 2, 2, &mut rng);
        let xin = nzp_input(&x, &f, 2, 1);
        assert!(xin.sparsity() > 0.70, "sparsity {}", xin.sparsity());
    }
}
