//! The deconvolution-to-convolution conversion of Shi et al. [30]
//! ("Is the deconvolution layer the same as a convolutional layer?"),
//! reproduced *including its error*, for the Table 4 / Figure 13-14 quality
//! comparison.
//!
//! Shi et al. fix the input zero-padding to the RIGHT and BOTTOM of the
//! feature map and read the output from the top-left corner. As the paper
//! under reproduction points out (Section 2), that placement is only correct
//! for the first partition of the split: "the fixed zero-padding to the
//! right and bottom of the input features only works for the first partition
//! of the split deconvolution and it can cause errors when this zero-padding
//! is utilized for the deconvolution conversion. The correct padding must be
//! adapted to the deconvolution partition as well as the output feature
//! cropping strategies."
//!
//! Concretely: correct SD pads `P_I` on *all four* sides and crops at offset
//! `P_K + p`; this variant pads `2*P_I` on right/bottom only and crops at
//! offset 0, which misplaces every partition but the first by up to
//! `s*P_I` pixels — interior content is near-correct but shifted, borders
//! are wrong. Small feature maps (DCGAN) are hurt far more than large ones
//! (FST), exactly the SSIM ordering the paper reports.

use super::{interleave, split_filters, SdGeometry};
use crate::tensor::{conv2d_valid, Filter, Tensor};

/// Shi-style conversion: split filters as in SD, but with the *fixed*
/// (non-adapted) phase placement: the sub-convolution outputs are assigned
/// to output phases in raw sampling order, without the reversal that the
/// 180-degree filter rotation demands. As the paper puts it, the fixed
/// right/bottom placement "only works for the first partition of the split
/// deconvolution"; every other partition lands in the wrong sub-pixel
/// phase, producing a sub-pixel scramble of the image. Large images (FST)
/// mostly survive — the scramble is a sub-pixel perturbation of otherwise
/// correct content — while small images (DCGAN) degrade badly: the SSIM
/// ordering of the paper's Table 4.
pub fn shi_deconv2d(x: &Tensor, f: &Filter, s: usize, p: usize, op: usize) -> Tensor {
    let g = SdGeometry::new(f.kh, s, p);
    let xp = x.pad(g.p_i, g.p_i, g.p_i, g.p_i);
    let convs: Vec<Tensor> = split_filters(f, s)
        .iter()
        .map(|w| conv2d_valid(&xp, w, 1))
        .collect();
    // WRONG (reproduced): raw phase order — correct only for partition 0
    // when s is such that reversal is identity (s=1).
    let scrambled: Vec<Tensor> = (0..s * s)
        .map(|n| {
            let (r, c) = (n / s, n % s);
            convs[(s - 1 - r) * s + (s - 1 - c)].clone()
        })
        .collect();
    let big = interleave(&scrambled, s);
    let c0 = g.crop();
    let oh = g.final_out(x.h, op);
    let ow = (x.w - 1) * s + f.kw - 2 * p + op;
    big.crop_padded(c0, oh, c0, ow)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::deconv2d;
    use crate::util::rng::Rng;

    #[test]
    fn shi_is_wrong_but_shaped_right() {
        let mut rng = Rng::new(11);
        let x = Tensor::randn(1, 8, 8, 4, &mut rng);
        let f = Filter::randn(5, 5, 4, 3, &mut rng);
        let want = deconv2d(&x, &f, 2, 2, 1);
        let got = shi_deconv2d(&x, &f, 2, 2, 1);
        assert_eq!(got.shape(), want.shape());
        // The whole point: it does NOT match the true deconvolution.
        assert!(
            got.max_abs_diff(&want) > 1e-2,
            "shi variant unexpectedly exact"
        );
    }

    #[test]
    fn shi_is_a_sub_pixel_phase_scramble() {
        // Every shi pixel equals a native pixel at the predicted sub-pixel
        // offset: out_shi[t] = out_native[t + (s-1) - 2*((t+c0) % s)] per
        // axis (the phase-reversal relation), wherever that lands in range.
        let mut rng = Rng::new(12);
        let (s, p) = (2usize, 1usize);
        let x = Tensor::randn(1, 16, 16, 2, &mut rng);
        let f = Filter::randn(4, 4, 2, 2, &mut rng);
        let want = deconv2d(&x, &f, s, p, 0);
        let got = shi_deconv2d(&x, &f, s, p, 0);
        let c0 = crate::sd::SdGeometry::new(4, s, p).crop();
        let off = |t: usize| -> isize {
            t as isize + (s as isize - 1) - 2 * ((t + c0) % s) as isize
        };
        let mut checked = 0;
        for y in 0..want.h {
            let ny = off(y);
            if ny < 0 || ny >= want.h as isize {
                continue;
            }
            for x2 in 0..want.w {
                let nx = off(x2);
                if nx < 0 || nx >= want.w as isize {
                    continue;
                }
                let d = (got.at(0, y, x2, 0) - want.at(0, ny as usize, nx as usize, 0)).abs();
                assert!(d < 1e-4, "scramble relation broken at ({y},{x2}): {d}");
                checked += 1;
            }
        }
        assert!(checked > want.h * want.w / 2, "too few checked: {checked}");
    }

    #[test]
    fn shi_exact_for_stride_one() {
        // s = 1: the phase reversal is the identity, so shi degenerates to
        // the correct conversion.
        let mut rng = Rng::new(13);
        let x = Tensor::randn(1, 7, 7, 3, &mut rng);
        let f = Filter::randn(3, 3, 3, 2, &mut rng);
        let want = deconv2d(&x, &f, 1, 1, 0);
        let got = shi_deconv2d(&x, &f, 1, 1, 0);
        assert!(got.allclose(&want, 1e-4));
    }
}
