//! Lower a deconvolution layer + implementation choice into the convolution
//! operations a CNN processor actually executes, carrying the operand zero
//! structure (the thing skip policies act on).

use anyhow::{bail, Result};

use crate::nn::{LayerKind, LayerSpec};
use crate::sd::{split_filters, SdGeometry};
use crate::sim::ConvOp;
use crate::tensor::{Filter, Tensor};
use crate::util::rng::Rng;

/// Deconvolution lowering choice (plus Direct for plain conv layers).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lowering {
    /// naive zero-padding conversion
    Nzp,
    /// split deconvolution (the paper)
    Sd,
    /// plain convolution layer (no conversion)
    Direct,
}

fn zero_map(x: &Tensor) -> Vec<bool> {
    let mut m = vec![true; x.h * x.w];
    for n in 0..x.n {
        for h in 0..x.h {
            for w in 0..x.w {
                if m[h * x.w + w] {
                    let base = x.idx(n, h, w, 0);
                    if x.data[base..base + x.c].iter().any(|v| *v != 0.0) {
                        m[h * x.w + w] = false;
                    }
                }
            }
        }
    }
    m
}

fn wgt_tap_zero(f: &Filter) -> Vec<bool> {
    let mut m = vec![true; f.kh * f.kw * f.ic];
    for kh in 0..f.kh {
        for kw in 0..f.kw {
            for ic in 0..f.ic {
                let i = (kh * f.kw + kw) * f.ic + ic;
                m[i] = (0..f.oc).all(|oc| f.at(kh, kw, ic, oc) == 0.0);
            }
        }
    }
    m
}

fn op_from(x: &Tensor, f: &Filter, stride: usize, useful_macs: u64) -> ConvOp {
    ConvOp {
        in_h: x.h,
        in_w: x.w,
        ic: x.c,
        k: f.kh,
        stride,
        oc: f.oc,
        act_zero: zero_map(x),
        wgt_zero: wgt_tap_zero(f),
        useful_macs,
        charge_input: true,
    }
}

/// Build the ConvOps for one layer under the given lowering. Activations are
/// dense random (structural zeros come from the lowering itself); weights
/// are dense random before splitting/rotation (expansion zeros come from the
/// SD filter padding). A deconv layer with [`Lowering::Direct`] is an error
/// (legacy convolution processors cannot execute it), propagated to the
/// caller rather than panicking.
pub fn lower_layer(spec: &LayerSpec, how: Lowering, rng: &mut Rng) -> Result<Vec<ConvOp>> {
    Ok(match spec.kind {
        LayerKind::Dense => Vec::new(), // negligible; not simulated
        LayerKind::Conv => {
            let x = Tensor::randn(1, spec.in_h, spec.in_w, spec.in_c, rng)
                .pad(spec.p, spec.p, spec.p, spec.p);
            let f = Filter::randn(spec.k, spec.k, spec.in_c, spec.out_c, rng);
            vec![op_from(&x, &f, spec.s, spec.macs())]
        }
        LayerKind::Deconv => {
            let x = Tensor::randn(1, spec.in_h, spec.in_w, spec.in_c, rng);
            let f = Filter::randn(spec.k, spec.k, spec.in_c, spec.out_c, rng);
            match how {
                Lowering::Direct => bail!(
                    "deconv layer {} cannot lower as Direct: pick Nzp or Sd",
                    spec.name
                ),
                Lowering::Nzp => {
                    let xin = crate::sd::nzp::nzp_input(&x, &f, spec.s, spec.p);
                    vec![op_from(&xin, &f.rot180(), 1, spec.macs())]
                }
                Lowering::Sd => {
                    let g = SdGeometry::new(spec.k, spec.s, spec.p);
                    let xp = x.pad(g.p_i, g.p_i, g.p_i, g.p_i);
                    let per_split = spec.macs() / (g.n_splits() as u64);
                    split_filters(&f, spec.s)
                        .iter()
                        .enumerate()
                        .map(|(i, w)| {
                            let mut op = op_from(&xp, w, 1, per_split);
                            op.charge_input = i == 0; // shared input stream
                            op
                        })
                        .collect()
                }
            }
        }
    })
}

/// All ops for a whole network's deconv layers (the paper's figures evaluate
/// "the deconvolutional layers in" each benchmark).
pub fn lower_network_deconvs(
    net: &crate::nn::NetworkSpec,
    how: Lowering,
    seed: u64,
) -> Result<Vec<ConvOp>> {
    let mut rng = Rng::new(seed);
    let mut ops = Vec::new();
    for l in net.deconv_layers() {
        ops.extend(lower_layer(l, how, &mut rng)?);
    }
    Ok(ops)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LayerSpec;

    #[test]
    fn direct_lowering_of_deconv_is_an_error() {
        let spec = LayerSpec::deconv("d", 8, 8, 4, 4, 4, 2, 1, 0);
        let mut rng = Rng::new(7);
        let err = lower_layer(&spec, Lowering::Direct, &mut rng);
        assert!(err.is_err(), "Direct lowering of a deconv must error");
        // plain conv layers lower fine under Direct
        let conv = LayerSpec::conv("c", 8, 8, 4, 4, 3, 1, 1);
        assert_eq!(lower_layer(&conv, Lowering::Direct, &mut rng).unwrap().len(), 1);
    }

    #[test]
    fn nzp_op_has_structural_zeros() {
        let spec = LayerSpec::deconv("d", 8, 8, 4, 4, 4, 2, 1, 0);
        let mut rng = Rng::new(1);
        let ops = lower_layer(&spec, Lowering::Nzp, &mut rng).unwrap();
        assert_eq!(ops.len(), 1);
        let op = &ops[0];
        // zero-inserted + halo: most positions zero
        let zfrac = op.act_zero.iter().filter(|z| **z).count() as f64 / op.act_zero.len() as f64;
        assert!(zfrac > 0.6, "zfrac {zfrac}");
        // rotated dense filter: no zero taps
        assert!(op.wgt_zero.iter().all(|z| !z));
    }

    #[test]
    fn sd_ops_count_and_filter_zeros() {
        // k5 s2: 4 splits of side 3, with one zero row+col in some splits
        let spec = LayerSpec::deconv("d", 8, 8, 4, 4, 5, 2, 2, 1);
        let mut rng = Rng::new(2);
        let ops = lower_layer(&spec, Lowering::Sd, &mut rng).unwrap();
        assert_eq!(ops.len(), 4);
        let with_zero_taps = ops
            .iter()
            .filter(|o| o.wgt_zero.iter().any(|z| *z))
            .count();
        assert!(with_zero_taps >= 2, "expansion should zero some taps");
        // interior activations dense; only halo zero
        let op = &ops[0];
        assert!(op.az(0, 0));
        assert!(!op.az(op.in_h / 2, op.in_w / 2));
    }

    #[test]
    fn divisible_filter_no_zero_taps() {
        let spec = LayerSpec::deconv("d", 4, 4, 2, 2, 4, 2, 1, 0);
        let mut rng = Rng::new(3);
        let ops = lower_layer(&spec, Lowering::Sd, &mut rng).unwrap();
        for op in &ops {
            assert!(op.wgt_zero.iter().all(|z| !z), "k divisible by s: dense splits");
        }
    }

    #[test]
    fn network_lowering_counts() {
        let net = crate::networks::sngan();
        let nzp = lower_network_deconvs(&net, Lowering::Nzp, 1).unwrap();
        let sd = lower_network_deconvs(&net, Lowering::Sd, 1).unwrap();
        assert_eq!(nzp.len(), 3); // one op per deconv layer
        assert_eq!(sd.len(), 12); // s^2 = 4 per layer
    }
}
