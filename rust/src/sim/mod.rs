//! Cycle-accurate architectural simulators of the two CNN-processor classes
//! the paper evaluates on (Section 3 + Section 5.1):
//!
//! * [`dot_array`] — Diannao-style dot-production array: 16 neural
//!   processing units x 16 multipliers + adder tree, 800 MHz, 8-bit.
//! * [`pe2d`] — Eyeriss/TPU-style regular 2D PE array, 32x7,
//!   output-stationary dataflow, 800 MHz, 8-bit.
//! * [`fcn_engine`] — the FCN-Engine [5] modified-hardware baseline
//!   (bi-directional dataflow, native deconvolution).
//!
//! The simulators *count cycles from the modeled dataflow over real operand
//! zero patterns* rather than from analytic formulas, so zero-skip policies
//! interact with data exactly the way the paper describes: aligned dataflow
//! can only skip an operand group when the whole group is zero — which is
//! why NZP's interleaved zeros are largely unskippable while SD's boundary
//! halo zeros and expanded-filter zeros are.

pub mod dot_array;
pub mod energy;
pub mod fcn_engine;
pub mod memory;
pub mod pe2d;
pub mod workload;

/// Sparse-aware optimization methods (paper Section 5.2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SkipPolicy {
    /// no zero skipping (legacy processor)
    None,
    /// activation sparse optimization
    ASparse,
    /// weight sparse optimization
    WSparse,
    /// both
    AWSparse,
}

impl SkipPolicy {
    pub fn skips_act(&self) -> bool {
        matches!(self, SkipPolicy::ASparse | SkipPolicy::AWSparse)
    }

    pub fn skips_wgt(&self) -> bool {
        matches!(self, SkipPolicy::WSparse | SkipPolicy::AWSparse)
    }

    pub fn label(&self) -> &'static str {
        match self {
            SkipPolicy::None => "dense",
            SkipPolicy::ASparse => "Asparse",
            SkipPolicy::WSparse => "Wsparse",
            SkipPolicy::AWSparse => "WAsparse",
        }
    }
}

/// Counters produced by one simulated layer (or accumulated over a network).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RunStats {
    /// array-issue cycles
    pub cycles: u64,
    /// MAC slots issued (cycles x active lanes)
    pub macs_issued: u64,
    /// MAC slots doing useful (nonzero-operand) work
    pub macs_useful: u64,
    /// cycles eliminated by the skip policy
    pub cycles_skipped: u64,
    /// on-chip activation-buffer reads (bytes, 8-bit operands)
    pub buf_act_rd: u64,
    /// on-chip weight-buffer reads (bytes)
    pub buf_wgt_rd: u64,
    /// on-chip output/psum-buffer accesses (bytes)
    pub buf_out_rw: u64,
    /// DRAM traffic (bytes)
    pub dram_bytes: u64,
}

impl RunStats {
    pub fn add(&mut self, o: &RunStats) {
        self.cycles += o.cycles;
        self.macs_issued += o.macs_issued;
        self.macs_useful += o.macs_useful;
        self.cycles_skipped += o.cycles_skipped;
        self.buf_act_rd += o.buf_act_rd;
        self.buf_wgt_rd += o.buf_wgt_rd;
        self.buf_out_rw += o.buf_out_rw;
        self.dram_bytes += o.dram_bytes;
    }

    /// Wall-clock at the given core frequency.
    pub fn time_us(&self, freq_mhz: u64) -> f64 {
        self.cycles as f64 / freq_mhz as f64
    }

    /// Fraction of issued MAC slots that were useful.
    pub fn utilization(&self) -> f64 {
        if self.macs_issued == 0 {
            0.0
        } else {
            self.macs_useful as f64 / self.macs_issued as f64
        }
    }
}

/// Hardware configuration shared by the simulators (paper Section 5.1).
#[derive(Clone, Copy, Debug)]
pub struct ProcessorConfig {
    /// dot array: multipliers per unit; 2D array: (unused)
    pub d_in: usize,
    /// dot array: number of units
    pub d_out: usize,
    /// 2D array: rows (output channels in flight)
    pub rows: usize,
    /// 2D array: columns (output pixels in flight)
    pub cols: usize,
    pub freq_mhz: u64,
    pub io_buffer_bytes: usize,
    pub weight_buffer_bytes: usize,
}

impl Default for ProcessorConfig {
    fn default() -> Self {
        ProcessorConfig {
            d_in: 16,
            d_out: 16,
            rows: 32,
            cols: 7,
            freq_mhz: 800,
            io_buffer_bytes: 256 * 1024,
            weight_buffer_bytes: 416 * 1024,
        }
    }
}

/// A convolution operation as seen by a processor: operand zero structure +
/// dimensions. Built by [`workload`] from a layer + deconv implementation.
#[derive(Clone, Debug)]
pub struct ConvOp {
    /// input spatial dims (already padded/dilated as the impl requires)
    pub in_h: usize,
    pub in_w: usize,
    pub ic: usize,
    pub k: usize,
    pub stride: usize,
    pub oc: usize,
    /// zero-position map over the (padded) input: true = all channels zero
    pub act_zero: Vec<bool>, // in_h * in_w
    /// zero-tap map over the filter: true = w[kh,kw,ic,*] all zero
    pub wgt_zero: Vec<bool>, // k * k * ic
    /// original-layer useful MACs this op contributes (for utilization)
    pub useful_macs: u64,
    /// whether this op pays the input's DRAM fetch (the s^2 split
    /// convolutions of one SD layer share a single input stream: only the
    /// first charges it)
    pub charge_input: bool,
}

impl ConvOp {
    pub fn out_h(&self) -> usize {
        (self.in_h - self.k) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w - self.k) / self.stride + 1
    }

    #[inline]
    pub fn az(&self, y: usize, x: usize) -> bool {
        self.act_zero[y * self.in_w + x]
    }

    #[inline]
    pub fn wz(&self, kh: usize, kw: usize, ic: usize) -> bool {
        self.wgt_zero[(kh * self.k + kw) * self.ic + ic]
    }

    /// Dense MAC count of this op.
    pub fn dense_macs(&self) -> u64 {
        (self.out_h() * self.out_w() * self.k * self.k * self.ic * self.oc) as u64
    }
}
