//! Regular 2D PE array simulator (paper Fig. 3; Eyeriss/TPU/FCN-Engine
//! class), output-stationary dataflow:
//!
//! * array = `rows` x `cols` = 32 x 7
//! * each PE accumulates ONE output activation across all K*K*IC taps
//! * a row of PEs serves one output feature map (an output channel); the 32
//!   rows hold 32 output channels
//! * a column of PEs shares a broadcast input activation; the 7 columns hold
//!   7 consecutive output y-positions at the same output x
//! * weights stream from the left edge and flow across columns
//!
//! One cycle feeds one (kh, kw, ic) tap to the whole array. Skip policies
//! act at the array's alignment granularity:
//!
//! * Asparse: the tap cycle is elided iff the broadcast activation is zero
//!   for ALL `cols` concurrent y-positions. NZP's zero-inserted rows
//!   alternate with data rows, so a group of 7 consecutive rows is never
//!   all-zero — only the all-zero inserted *columns* (odd x phases) and the
//!   boundary halo are skippable: "a portion of the zero activations".
//! * Wsparse: the tap cycle is elided iff the weight tap is zero for ALL 32
//!   concurrent output channels. SD's expanded-filter zeros are exactly
//!   such all-channel zero taps.

use super::{ConvOp, ProcessorConfig, RunStats, SkipPolicy};

/// Simulate one convolution on the 2D PE array.
pub fn simulate_conv(op: &ConvOp, cfg: &ProcessorConfig, policy: SkipPolicy) -> RunStats {
    let (oh, ow) = (op.out_h(), op.out_w());
    let oc_tiles = op.oc.div_ceil(cfg.rows) as u64;
    let oy_tiles = oh.div_ceil(cfg.cols);

    let mut cycles: u64 = 0;
    let mut skipped: u64 = 0;

    // Weight-tap skip mask is identical across oc tiles (structural zeros
    // are all-channel), precompute count of live taps once.
    for ty in 0..oy_tiles {
        let y0 = ty * cfg.cols;
        let ys = (y0..(y0 + cfg.cols).min(oh)).collect::<Vec<_>>();
        for ox in 0..ow {
            for dy in 0..op.k {
                for dx in 0..op.k {
                    let ix = ox * op.stride + dx;
                    // activation skip: zero at this tap for all concurrent ys
                    let act_all_zero = policy.skips_act()
                        && ys.iter().all(|&oy| op.az(oy * op.stride + dy, ix));
                    if act_all_zero {
                        skipped += op.ic as u64;
                        continue;
                    }
                    if policy.skips_wgt() {
                        let base = (dy * op.k + dx) * op.ic;
                        for ic in 0..op.ic {
                            if op.wgt_zero[base + ic] {
                                skipped += 1;
                            } else {
                                cycles += 1;
                            }
                        }
                    } else {
                        cycles += op.ic as u64;
                    }
                }
            }
        }
    }
    cycles *= oc_tiles;
    skipped *= oc_tiles;

    let lanes = (cfg.rows * cfg.cols) as u64;
    let mut stats = RunStats {
        cycles,
        cycles_skipped: skipped,
        macs_issued: cycles * lanes,
        macs_useful: op.useful_macs,
        ..Default::default()
    };

    // Buffer traffic (8-bit): one broadcast activation per column per cycle
    // (cols bytes), one weight per row flowing in per cycle (rows bytes);
    // outputs written once per PE at tile end.
    stats.buf_act_rd = cycles * cfg.cols as u64;
    stats.buf_wgt_rd = cycles * cfg.rows as u64;
    stats.buf_out_rw = (oh * ow * op.oc) as u64;

    // weights once per activation tile, inputs once per weight tile (see
    // memory.rs for the loop-order rationale)
    stats.dram_bytes = super::memory::dram_bytes(op, cfg, (oh * ow * op.oc) as u64);

    stats
}

/// Simulate a sequence of ops; stats accumulate.
pub fn simulate(ops: &[ConvOp], cfg: &ProcessorConfig, policy: SkipPolicy) -> RunStats {
    let mut total = RunStats::default();
    for op in ops {
        total.add(&simulate_conv(op, cfg, policy));
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LayerSpec;
    use crate::sim::workload::{lower_layer, Lowering};
    use crate::util::rng::Rng;

    fn cfg() -> ProcessorConfig {
        ProcessorConfig::default()
    }

    #[test]
    fn dense_cycle_formula() {
        let spec = LayerSpec::conv("c", 16, 16, 8, 64, 3, 1, 0);
        let mut rng = Rng::new(1);
        let ops = lower_layer(&spec, Lowering::Direct, &mut rng).unwrap();
        let st = simulate(&ops, &cfg(), SkipPolicy::None);
        // oc_tiles=2, oy_tiles=ceil(14/7)=2, ow=14, taps=9*8
        assert_eq!(st.cycles, 2 * 2 * 14 * 9 * 8);
    }

    #[test]
    fn wsparse_recovers_sd_expansion() {
        // k5 s2 SD: padded filters have zero taps; Wsparse elides them.
        let spec = LayerSpec::deconv("d", 8, 8, 64, 32, 5, 2, 2, 1);
        let mut rng = Rng::new(2);
        let ops = lower_layer(&spec, Lowering::Sd, &mut rng).unwrap();
        let dense = simulate(&ops, &cfg(), SkipPolicy::None);
        let wsp = simulate(&ops, &cfg(), SkipPolicy::WSparse);
        let ratio = dense.cycles as f64 / wsp.cycles as f64;
        // 36 padded taps vs 25 real: ~1.44x recoverable
        assert!(ratio > 1.3, "ratio {ratio}");
    }

    #[test]
    fn nzp_asparse_skips_only_a_portion() {
        let spec = LayerSpec::deconv("d", 8, 8, 64, 32, 4, 2, 1, 0);
        let mut rng = Rng::new(3);
        let ops = lower_layer(&spec, Lowering::Nzp, &mut rng).unwrap();
        let dense = simulate(&ops, &cfg(), SkipPolicy::None);
        let asp = simulate(&ops, &cfg(), SkipPolicy::ASparse);
        let recovered = 1.0 - asp.cycles as f64 / dense.cycles as f64;
        // interleaved zeros: some skip (odd columns) but well below the 75%
        // actual zero fraction — the aligned-dataflow limitation.
        assert!(recovered > 0.2, "recovered {recovered}");
        assert!(recovered < 0.7, "recovered {recovered}");
    }

    #[test]
    fn sd_wasparse_beats_nzp_dense_by_papers_margin() {
        let spec = LayerSpec::deconv("d", 8, 8, 256, 128, 4, 2, 1, 0);
        let mut rng = Rng::new(4);
        let nzp = simulate(
            &lower_layer(&spec, Lowering::Nzp, &mut rng).unwrap(),
            &cfg(),
            SkipPolicy::None,
        );
        let sd = simulate(
            &lower_layer(&spec, Lowering::Sd, &mut rng).unwrap(),
            &cfg(),
            SkipPolicy::AWSparse,
        );
        let speedup = nzp.cycles as f64 / sd.cycles as f64;
        assert!(speedup > 2.4, "speedup {speedup}"); // paper band 2.41-4.34
        assert!(speedup < 6.0, "speedup {speedup}");
    }

    #[test]
    fn skip_never_changes_issue_plus_skip_total() {
        // conservation: cycles + skipped is policy-independent
        let spec = LayerSpec::deconv("d", 8, 8, 32, 32, 5, 2, 2, 1);
        let mut rng = Rng::new(5);
        let ops = lower_layer(&spec, Lowering::Sd, &mut rng).unwrap();
        let a = simulate(&ops, &cfg(), SkipPolicy::None);
        let b = simulate(&ops, &cfg(), SkipPolicy::AWSparse);
        assert_eq!(a.cycles + a.cycles_skipped, b.cycles + b.cycles_skipped);
    }
}
