//! Energy model (paper Section 5.2.3, CACTI-based in the original).
//!
//! The paper's qualitative findings, which the constants below encode:
//! * total energy is dominated by DRAM access, then on-chip buffer access;
//! * PE (MAC) energy is "too small to affect the overall deconvolution
//!   energy consumption";
//! * DRAM traffic is about the same across deconvolution approaches, so
//!   the differences come from buffer access counts.
//!
//! Constants are per-byte / per-MAC energies representative of a 40 nm
//! node (CACTI-P class numbers; absolute joules are not the reproduction
//! target — the *relative* distribution across PE / buffer / DRAM is).

use super::RunStats;

/// Per-event energies in picojoules.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// one 8-bit MAC
    pub pe_mac_pj: f64,
    /// one byte read/written from a large (256-416 KB) SRAM buffer
    pub buffer_byte_pj: f64,
    /// one byte of DRAM traffic
    pub dram_byte_pj: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            pe_mac_pj: 0.05,
            buffer_byte_pj: 1.5,
            dram_byte_pj: 60.0,
        }
    }
}

/// Energy breakdown of one run, in microjoules.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    pub pe_uj: f64,
    pub buffer_uj: f64,
    pub dram_uj: f64,
}

impl EnergyBreakdown {
    pub fn total_uj(&self) -> f64 {
        self.pe_uj + self.buffer_uj + self.dram_uj
    }
}

/// Compute the energy of a simulated run.
pub fn energy(stats: &RunStats, model: &EnergyModel) -> EnergyBreakdown {
    // only useful + issued-but-wasted MACs burn PE energy; skipped ones don't
    let pe = stats.macs_issued as f64 * model.pe_mac_pj;
    let buffer =
        (stats.buf_act_rd + stats.buf_wgt_rd + stats.buf_out_rw) as f64 * model.buffer_byte_pj;
    let dram = stats.dram_bytes as f64 * model.dram_byte_pj;
    EnergyBreakdown {
        pe_uj: pe / 1e6,
        buffer_uj: buffer / 1e6,
        dram_uj: dram / 1e6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LayerSpec;
    use crate::sim::workload::{lower_layer, Lowering};
    use crate::sim::{pe2d, ProcessorConfig, SkipPolicy};
    use crate::util::rng::Rng;

    #[test]
    fn dram_dominates_then_buffer_then_pe() {
        let spec = LayerSpec::deconv("d", 8, 8, 256, 128, 4, 2, 1, 0);
        let mut rng = Rng::new(1);
        let ops = lower_layer(&spec, Lowering::Sd, &mut rng).unwrap();
        let st = pe2d::simulate(&ops, &ProcessorConfig::default(), SkipPolicy::AWSparse);
        let e = energy(&st, &EnergyModel::default());
        assert!(e.pe_uj < e.buffer_uj, "pe {} buf {}", e.pe_uj, e.buffer_uj);
        assert!(e.pe_uj < e.dram_uj);
    }

    #[test]
    fn skipping_reduces_buffer_energy() {
        let spec = LayerSpec::deconv("d", 8, 8, 256, 128, 5, 2, 2, 1);
        let mut rng = Rng::new(2);
        let ops = lower_layer(&spec, Lowering::Sd, &mut rng).unwrap();
        let cfg = ProcessorConfig::default();
        let dense = energy(&pe2d::simulate(&ops, &cfg, SkipPolicy::None), &EnergyModel::default());
        let skip = energy(
            &pe2d::simulate(&ops, &cfg, SkipPolicy::AWSparse),
            &EnergyModel::default(),
        );
        assert!(skip.buffer_uj < dense.buffer_uj);
        // DRAM identical (paper 5.2.3)
        assert!((skip.dram_uj - dense.dram_uj).abs() < 1e-12);
    }

    #[test]
    fn nzp_energy_exceeds_sd() {
        let spec = LayerSpec::deconv("d", 8, 8, 256, 128, 4, 2, 1, 0);
        let mut rng = Rng::new(3);
        let cfg = ProcessorConfig::default();
        let m = EnergyModel::default();
        let nzp = energy(
            &pe2d::simulate(
                &lower_layer(&spec, Lowering::Nzp, &mut rng).unwrap(),
                &cfg,
                SkipPolicy::None,
            ),
            &m,
        );
        let sd = energy(
            &pe2d::simulate(
                &lower_layer(&spec, Lowering::Sd, &mut rng).unwrap(),
                &cfg,
                SkipPolicy::AWSparse,
            ),
            &m,
        );
        assert!(sd.total_uj() < nzp.total_uj());
        // and the reduction is buffer/PE-driven, in the paper's 27-55% band
        let reduction = 1.0 - sd.total_uj() / nzp.total_uj();
        assert!(reduction > 0.10 && reduction < 0.70, "reduction {reduction}");
    }
}
