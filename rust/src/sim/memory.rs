//! On-chip buffer capacity modeling: when a layer's working set exceeds the
//! I/O or weight buffers, the layer is tiled and operands are re-fetched
//! from DRAM. The paper's processors: 256 KB I/O buffer, 416 KB weight
//! buffer (Section 5.1).

use super::{ConvOp, ProcessorConfig};

/// Number of weight tiles: when the filter exceeds the weight buffer, the
/// weights are processed in tiles and the *activations* are re-read once
/// per weight tile (standard weight-tiled inference loop order).
pub fn weight_tiles(op: &ConvOp, cfg: &ProcessorConfig) -> u64 {
    let weight_bytes = (op.k * op.k * op.ic * op.oc) as u64; // 8-bit
    weight_bytes.div_ceil(cfg.weight_buffer_bytes as u64)
}

/// Number of activation tiles: when the (possibly zero-inflated) feature
/// map exceeds the I/O buffer, activations are tiled and the *weights* are
/// re-read once per activation tile.
pub fn act_tiles(op: &ConvOp, cfg: &ProcessorConfig) -> u64 {
    let act_bytes = (op.in_h * op.in_w * op.ic) as u64;
    act_bytes.div_ceil(cfg.io_buffer_bytes as u64)
}

/// Legacy combined factor (dominant re-fetch dimension); kept for callers
/// that want a single number.
pub fn refetch_factor(op: &ConvOp, cfg: &ProcessorConfig) -> u64 {
    weight_tiles(op, cfg).max(act_tiles(op, cfg))
}

/// DRAM bytes for one op: weights once per activation tile, (non-zero)
/// input once per weight tile, output once.
///
/// Weight traffic counts the *compressed* stream (zero taps elided): this
/// is the paper's "Compressed SD" storage format (Table 3), which removes
/// the expansion zeros SD pads into its split filters. Dense filters are
/// unaffected.
pub fn dram_bytes(op: &ConvOp, cfg: &ProcessorConfig, out_elems: u64) -> u64 {
    let nonzero_taps = op.wgt_zero.iter().filter(|z| !*z).count() as u64;
    let weight_bytes = nonzero_taps * op.oc as u64;
    let input_bytes = if op.charge_input {
        (op.act_zero.iter().filter(|z| !*z).count() * op.ic) as u64
    } else {
        0
    };
    weight_bytes * act_tiles(op, cfg) + input_bytes * weight_tiles(op, cfg) + out_elems
}

/// Whether the op runs without tiling.
pub fn fits_on_chip(op: &ConvOp, cfg: &ProcessorConfig) -> bool {
    refetch_factor(op, cfg) == 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::ConvOp;

    fn op(in_h: usize, in_w: usize, ic: usize, k: usize, oc: usize) -> ConvOp {
        ConvOp {
            in_h,
            in_w,
            ic,
            k,
            stride: 1,
            oc,
            act_zero: vec![false; in_h * in_w],
            wgt_zero: vec![false; k * k * ic],
            useful_macs: 0,
            charge_input: true,
        }
    }

    #[test]
    fn small_layer_fits() {
        let cfg = ProcessorConfig::default();
        assert!(fits_on_chip(&op(16, 16, 64, 3, 64), &cfg));
    }

    #[test]
    fn huge_weights_tile() {
        let cfg = ProcessorConfig::default();
        // 5x5x1024x512 = 13 MB >> 416 KB
        let f = refetch_factor(&op(8, 8, 1024, 5, 512), &cfg);
        assert!(f > 1, "factor {f}");
    }

    #[test]
    fn monotone_in_size() {
        let cfg = ProcessorConfig::default();
        let a = refetch_factor(&op(8, 8, 256, 3, 256), &cfg);
        let b = refetch_factor(&op(8, 8, 1024, 3, 1024), &cfg);
        assert!(b >= a);
    }
}
