//! Dot-production array simulator (paper Fig. 2; Diannao/Dadiannao/C-brain/
//! Cnvlutin class). `d_out` neural processing units, each performing a
//! `d_in`-wide dot product per cycle; the same `d_in` activations are
//! broadcast to every unit while each unit holds weights for one output
//! channel.
//!
//! Dataflow per output pixel: the filter window is streamed tap by tap,
//! `d_in` channels per cycle, for each group of `d_out` output channels.
//! Zero skipping (Asparse only — this architecture cannot skip weights, as
//! the paper notes in 5.2.2): a feed cycle is elided iff its whole `d_in`
//! activation group is zero. Structural zeros (NZP insertion, SD halo) are
//! zero across all channels, so they form skippable groups; but channel
//! groups mixing zero and nonzero positions cannot be elided — the aligned
//! dataflow limitation the paper describes.

use super::{ConvOp, ProcessorConfig, RunStats, SkipPolicy};

/// Simulate one convolution on the dot-production array.
pub fn simulate_conv(op: &ConvOp, cfg: &ProcessorConfig, policy: SkipPolicy) -> RunStats {
    let (oh, ow) = (op.out_h(), op.out_w());
    let oc_groups = op.oc.div_ceil(cfg.d_out) as u64;
    let ic_groups_per_tap = op.ic.div_ceil(cfg.d_in) as u64;
    let lanes = (cfg.d_in * cfg.d_out) as u64;

    let mut stats = RunStats::default();

    // Feed cycles for one output pixel = sum over taps of per-tap groups,
    // with whole-tap groups elided when the (all-channel) activation is zero.
    // The tap->group structure only depends on the window position, so count
    // surviving taps per output pixel.
    let mut fed_cycles_one_ocg: u64 = 0;
    let mut skipped_cycles: u64 = 0;
    for oy in 0..oh {
        for ox in 0..ow {
            for dy in 0..op.k {
                let iy = oy * op.stride + dy;
                for dx in 0..op.k {
                    let ix = ox * op.stride + dx;
                    if policy.skips_act() && op.az(iy, ix) {
                        skipped_cycles += ic_groups_per_tap;
                    } else {
                        fed_cycles_one_ocg += ic_groups_per_tap;
                    }
                }
            }
        }
    }

    stats.cycles = fed_cycles_one_ocg * oc_groups;
    stats.cycles_skipped = skipped_cycles * oc_groups;
    stats.macs_issued = stats.cycles * lanes;
    stats.macs_useful = op.useful_macs;

    // Buffer traffic (8-bit operands):
    // activations broadcast once per feed cycle (d_in bytes), weights are
    // per-unit (d_in * d_out bytes per cycle), outputs written once.
    stats.buf_act_rd = stats.cycles * cfg.d_in as u64;
    stats.buf_wgt_rd = stats.cycles * lanes;
    stats.buf_out_rw = (oh * ow * op.oc) as u64;

    // DRAM traffic: weights once per activation tile, (non-zero) inputs
    // once per weight tile, outputs once — nearly implementation-
    // independent, the paper's Section 5.2.3 observation.
    stats.dram_bytes = super::memory::dram_bytes(op, cfg, (oh * ow * op.oc) as u64);

    stats
}

/// Simulate a sequence of ops (e.g. all split convolutions of a layer, or a
/// network's deconv layers); stats accumulate.
pub fn simulate(ops: &[ConvOp], cfg: &ProcessorConfig, policy: SkipPolicy) -> RunStats {
    let mut total = RunStats::default();
    for op in ops {
        // this architecture cannot skip weights: downgrade the policy
        let eff = match policy {
            SkipPolicy::WSparse => SkipPolicy::None,
            SkipPolicy::AWSparse => SkipPolicy::ASparse,
            p => p,
        };
        total.add(&simulate_conv(op, cfg, eff));
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LayerSpec;
    use crate::sim::workload::{lower_layer, Lowering};
    use crate::util::rng::Rng;

    fn dcgan_layer() -> LayerSpec {
        LayerSpec::deconv("d", 8, 8, 256, 128, 5, 2, 2, 1)
    }

    #[test]
    fn dense_cycle_count_formula() {
        // no zeros anywhere: cycles = OH*OW*K^2*ceil(IC/16)*ceil(OC/16)
        let spec = LayerSpec::conv("c", 10, 10, 32, 32, 3, 1, 0);
        let mut rng = Rng::new(1);
        let ops = lower_layer(&spec, Lowering::Direct, &mut rng).unwrap();
        let st = simulate(&ops, &ProcessorConfig::default(), SkipPolicy::None);
        let want = (8 * 8 * 9 * 2 * 2) as u64;
        assert_eq!(st.cycles, want);
    }

    #[test]
    fn sd_beats_nzp() {
        let mut rng = Rng::new(2);
        let cfg = ProcessorConfig::default();
        let nzp = simulate(
            &lower_layer(&dcgan_layer(), Lowering::Nzp, &mut rng).unwrap(),
            &cfg,
            SkipPolicy::None,
        );
        let sd = simulate(
            &lower_layer(&dcgan_layer(), Lowering::Sd, &mut rng).unwrap(),
            &cfg,
            SkipPolicy::None,
        );
        // dense-vs-dense on k5 s2: exec-MAC ratio 6400/3600 ~ 1.78x (the
        // figure-level 2.5x average includes k4 nets at 2.56x and Asparse)
        let speedup = nzp.cycles as f64 / sd.cycles as f64;
        assert!(speedup > 1.4, "speedup {speedup}");
    }

    #[test]
    fn asparse_helps_nzp_partially() {
        // NZP + idealized group-skip recovers some but far from all redundancy
        let mut rng = Rng::new(3);
        let cfg = ProcessorConfig::default();
        let ops = lower_layer(&dcgan_layer(), Lowering::Nzp, &mut rng).unwrap();
        let dense = simulate(&ops, &cfg, SkipPolicy::None);
        let skip = simulate(&ops, &cfg, SkipPolicy::ASparse);
        assert!(skip.cycles < dense.cycles);
        assert!(skip.cycles_skipped > 0);
    }

    #[test]
    fn wsparse_downgraded() {
        // dot array cannot skip weights: WSparse == None
        let mut rng = Rng::new(4);
        let cfg = ProcessorConfig::default();
        let ops = lower_layer(&dcgan_layer(), Lowering::Sd, &mut rng).unwrap();
        let a = simulate(&ops, &cfg, SkipPolicy::WSparse);
        let b = simulate(&ops, &cfg, SkipPolicy::None);
        assert_eq!(a.cycles, b.cycles);
    }

    #[test]
    fn oc_underutilization_counted() {
        // OC=3 wastes 13/16 output lanes: issued >> useful
        let spec = LayerSpec::deconv("d", 8, 8, 64, 3, 4, 2, 1, 0);
        let mut rng = Rng::new(5);
        let ops = lower_layer(&spec, Lowering::Sd, &mut rng).unwrap();
        let st = simulate(&ops, &ProcessorConfig::default(), SkipPolicy::None);
        assert!(st.utilization() < 0.35, "util {}", st.utilization());
    }
}
