//! FCN-Engine baseline (Xu et al. [5], ICCAD'18): the paper's
//! modified-hardware comparator. A 2D PE array with a bi-directional
//! dataflow and small column buffers that lets the array run the *original*
//! deconvolution directly: input activations are multiplied with each
//! filter and overlapping partial products are exchanged between adjacent
//! PEs through the added column buffers.
//!
//! Behavioral model (from the FCN-Engine paper + this paper's Section
//! 5.2.2/5.2.3 characterization):
//! * computes on the original (never zero-inflated) input;
//! * produces the FULL (uncropped) deconvolution plane — "the output
//!   feature maps on edge are redundant and need to be cropped, which
//!   inevitably induces computing overhead, especially for smaller
//!   deconvolution layers";
//! * output rows advance in lockstep across the 7 concurrent y-positions,
//!   so a row tile pays the WORST per-phase tap count among its rows
//!   (ceil(K/s) kernel rows) — phase imbalance that SD avoids by giving
//!   each phase its own (Wsparse-compressible) filter;
//! * every cycle a partial product crosses a column buffer (read + write)
//!   instead of staying in a PE register — "FCN requires additional
//!   on-chip buffers ... so the overall energy consumption is higher than
//!   that of SD-WAsparse in all the benchmark networks".

use super::{ProcessorConfig, RunStats};
use crate::nn::{LayerKind, LayerSpec};

/// Kernel rows hitting full-plane output row `o` (phase-dependent).
fn taps_1d(o: usize, k: usize, s: usize, i: usize) -> u64 {
    (0..k)
        .filter(|&d| o >= d && (o - d) % s == 0 && (o - d) / s < i)
        .count() as u64
}

/// Simulate one deconvolution layer executed natively on FCN-Engine.
pub fn simulate_layer(spec: &LayerSpec, cfg: &ProcessorConfig) -> RunStats {
    assert_eq!(spec.kind, LayerKind::Deconv);
    // full (uncropped) output plane
    let full_h = (spec.in_h - 1) * spec.s + spec.k;
    let full_w = (spec.in_w - 1) * spec.s + spec.k;

    let row_taps: Vec<u64> = (0..full_h)
        .map(|y| taps_1d(y, spec.k, spec.s, spec.in_h))
        .collect();
    let col_taps: Vec<u64> = (0..full_w)
        .map(|x| taps_1d(x, spec.k, spec.s, spec.in_w))
        .collect();
    let col_total: u64 = col_taps.iter().sum();
    let col_max_total: u64 = {
        // columns also advance in lockstep within the array's x sweep at
        // the granularity of one output column: each column pays its own
        // tap count (x positions are sequential), no imbalance here.
        col_total
    };

    // y-tiles of `cols` lockstep rows: the tile pays max(row taps) per row.
    let mut tile_cost: u64 = 0; // sum over tiles of max_row_taps * rows_in_tile? no: lockstep => all rows wait
    let mut y = 0;
    while y < full_h {
        let end = (y + cfg.cols).min(full_h);
        let m = row_taps[y..end].iter().max().copied().unwrap_or(0);
        tile_cost += m;
        y = end;
    }

    let oc_tiles = spec.out_c.div_ceil(cfg.rows) as u64;
    let cycles = oc_tiles * tile_cost * col_max_total * spec.in_c as u64;

    let lanes = (cfg.rows * cfg.cols) as u64;
    let mut stats = RunStats {
        cycles,
        macs_issued: cycles * lanes,
        macs_useful: spec.macs(),
        ..Default::default()
    };

    // buffer traffic: activations + weights as in the OS array, plus the
    // column-buffer partial hand-off every cycle (one read + one write per
    // active column per cycle, 8-bit partials)
    stats.buf_act_rd = cycles * cfg.cols as u64;
    stats.buf_wgt_rd = cycles * cfg.rows as u64;
    stats.buf_out_rw = (full_h * full_w * spec.out_c) as u64 + 2 * cycles * cfg.cols as u64;

    let weight_bytes = (spec.k * spec.k * spec.in_c * spec.out_c) as u64;
    // the array computes (and writes back) the FULL uncropped plane; the
    // host crops afterwards — the edge redundancy also costs DRAM traffic
    stats.dram_bytes = (spec.in_h * spec.in_w * spec.in_c) as u64
        + weight_bytes
        + (full_h * full_w * spec.out_c) as u64;

    stats
}

/// All deconv layers of a network on FCN-Engine.
pub fn simulate_network(net: &crate::nn::NetworkSpec, cfg: &ProcessorConfig) -> RunStats {
    let mut total = RunStats::default();
    for l in net.deconv_layers() {
        total.add(&simulate_layer(l, cfg));
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::LayerSpec;

    #[test]
    fn taps_1d_k4_s2_interior() {
        // interior phases of k4 s2 alternate 2/2 kernel rows
        assert_eq!(taps_1d(4, 4, 2, 8), 2);
        assert_eq!(taps_1d(5, 4, 2, 8), 2);
        // edges see fewer
        assert_eq!(taps_1d(0, 4, 2, 8), 1);
    }

    #[test]
    fn taps_1d_k5_s2_phases() {
        // k5 s2: interior phases alternate 3 and 2 kernel rows
        let a = taps_1d(6, 5, 2, 8);
        let b = taps_1d(7, 5, 2, 8);
        assert_eq!(a.max(b), 3);
        assert_eq!(a.min(b), 2);
    }

    #[test]
    fn edge_overhead_hurts_small_layers_more() {
        let cfg = ProcessorConfig::default();
        let small = LayerSpec::deconv("s", 4, 4, 64, 64, 4, 2, 1, 0);
        let big = LayerSpec::deconv("b", 64, 64, 64, 64, 4, 2, 1, 0);
        let st_s = simulate_layer(&small, &cfg);
        let st_b = simulate_layer(&big, &cfg);
        let ov_s = st_s.cycles as f64 * 1e9 / st_s.macs_useful as f64;
        let ov_b = st_b.cycles as f64 * 1e9 / st_b.macs_useful as f64;
        assert!(ov_s > ov_b, "small {ov_s} big {ov_b}");
    }

    #[test]
    fn handoff_buffer_traffic_positive() {
        let spec = LayerSpec::deconv("d", 8, 8, 16, 8, 4, 2, 1, 0);
        let st = simulate_layer(&spec, &ProcessorConfig::default());
        assert!(st.buf_out_rw > (spec.out_h() * spec.out_w() * spec.out_c) as u64);
    }

    #[test]
    fn phase_imbalance_penalizes_expansion_kernels() {
        // k5 (phases 3/2) pays the max phase in lockstep; k4 (2/2) doesn't.
        let cfg = ProcessorConfig::default();
        let k5 = LayerSpec::deconv("a", 16, 16, 64, 64, 5, 2, 2, 1);
        let k4 = LayerSpec::deconv("b", 16, 16, 64, 64, 4, 2, 1, 0);
        let c5 = simulate_layer(&k5, &cfg).cycles as f64 / k5.macs() as f64;
        let c4 = simulate_layer(&k4, &cfg).cycles as f64 / k4.macs() as f64;
        assert!(c5 > c4, "k5 {c5} k4 {c4}");
    }
}
