//! NHWC f32 tensor substrate + the convolution/deconvolution ops every other
//! module builds on. Layout matches the python side (ref.py): activations
//! NHWC, filters HWIO, deconvolution uses scatter semantics. The GEMM
//! compute core under the ops (packed-B panels, runtime AVX2/FMA
//! microkernel dispatch, numerics policy) lives in [`gemm`].

pub mod gemm;
pub(crate) mod ops;

pub use gemm::{active_backend, force_backend, GemmBackend, PackedB};
pub use ops::*;

/// Dense 4-D tensor, NHWC layout, f32.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(n: usize, h: usize, w: usize, c: usize) -> Self {
        Tensor {
            n,
            h,
            w,
            c,
            data: vec![0.0; n * h * w * c],
        }
    }

    pub fn from_vec(n: usize, h: usize, w: usize, c: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), n * h * w * c, "data length mismatch");
        Tensor { n, h, w, c, data }
    }

    pub fn from_fn(n: usize, h: usize, w: usize, c: usize, mut f: impl FnMut() -> f32) -> Self {
        let data = (0..n * h * w * c).map(|_| f()).collect();
        Tensor { n, h, w, c, data }
    }

    pub fn randn(n: usize, h: usize, w: usize, c: usize, rng: &mut crate::util::rng::Rng) -> Self {
        Self::from_fn(n, h, w, c, || rng.normal())
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn idx(&self, n: usize, h: usize, w: usize, c: usize) -> usize {
        ((n * self.h + h) * self.w + w) * self.c + c
    }

    #[inline]
    pub fn at(&self, n: usize, h: usize, w: usize, c: usize) -> f32 {
        self.data[self.idx(n, h, w, c)]
    }

    #[inline]
    pub fn at_mut(&mut self, n: usize, h: usize, w: usize, c: usize) -> &mut f32 {
        let i = self.idx(n, h, w, c);
        &mut self.data[i]
    }

    pub fn shape(&self) -> [usize; 4] {
        [self.n, self.h, self.w, self.c]
    }

    /// Zero-pad spatial dims: (top, bottom, left, right).
    pub fn pad(&self, top: usize, bottom: usize, left: usize, right: usize) -> Tensor {
        let mut out = Tensor::zeros(0, 0, 0, 0);
        self.pad_into(top, bottom, left, right, &mut out);
        out
    }

    /// [`Tensor::pad`] into a caller-provided tensor (reshaped, resized,
    /// zeroed in place, reusing capacity) — the engine's arena-backed form.
    pub fn pad_into(&self, top: usize, bottom: usize, left: usize, right: usize, out: &mut Tensor) {
        out.n = self.n;
        out.h = self.h + top + bottom;
        out.w = self.w + left + right;
        out.c = self.c;
        out.data.clear();
        out.data.resize(out.n * out.h * out.w * out.c, 0.0);
        for n in 0..self.n {
            for h in 0..self.h {
                let src = self.idx(n, h, 0, 0);
                let dst = out.idx(n, h + top, left, 0);
                out.data[dst..dst + self.w * self.c]
                    .copy_from_slice(&self.data[src..src + self.w * self.c]);
            }
        }
    }

    /// Spatial crop: rows [h0, h0+nh), cols [w0, w0+nw).
    pub fn crop(&self, h0: usize, nh: usize, w0: usize, nw: usize) -> Tensor {
        assert!(h0 + nh <= self.h && w0 + nw <= self.w, "crop out of range");
        let mut out = Tensor::zeros(self.n, nh, nw, self.c);
        for n in 0..self.n {
            for h in 0..nh {
                let src = self.idx(n, h0 + h, w0, 0);
                let dst = out.idx(n, h, 0, 0);
                out.data[dst..dst + nw * self.c]
                    .copy_from_slice(&self.data[src..src + nw * self.c]);
            }
        }
        out
    }

    /// Spatial crop that zero-fills out-of-range regions (needed when a
    /// deconvolution's output_padding extends past the scatter grid, as
    /// torch's ConvTranspose2d allows for output_padding < stride).
    pub fn crop_padded(&self, h0: usize, nh: usize, w0: usize, nw: usize) -> Tensor {
        if h0 + nh <= self.h && w0 + nw <= self.w {
            return self.crop(h0, nh, w0, nw);
        }
        let mut out = Tensor::zeros(self.n, nh, nw, self.c);
        for n in 0..self.n {
            for h in 0..nh {
                let sh = h0 + h;
                if sh >= self.h {
                    continue;
                }
                let cols = nw.min(self.w.saturating_sub(w0));
                if cols == 0 {
                    continue;
                }
                let src = self.idx(n, sh, w0, 0);
                let dst = out.idx(n, h, 0, 0);
                out.data[dst..dst + cols * self.c]
                    .copy_from_slice(&self.data[src..src + cols * self.c]);
            }
        }
        out
    }

    /// Max |a-b| over all elements (shapes must match).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape(), other.shape(), "shape mismatch");
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.shape() == other.shape() && self.max_abs_diff(other) <= atol
    }

    /// Fraction of exactly-zero elements (drives the zero-skip simulators).
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|v| **v == 0.0).count() as f64 / self.data.len() as f64
    }
}

/// Convolution filter, HWIO layout, f32. Same layout for deconv filters.
#[derive(Clone, Debug, PartialEq)]
pub struct Filter {
    pub kh: usize,
    pub kw: usize,
    pub ic: usize,
    pub oc: usize,
    pub data: Vec<f32>,
}

impl Filter {
    pub fn zeros(kh: usize, kw: usize, ic: usize, oc: usize) -> Self {
        Filter {
            kh,
            kw,
            ic,
            oc,
            data: vec![0.0; kh * kw * ic * oc],
        }
    }

    pub fn from_vec(kh: usize, kw: usize, ic: usize, oc: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), kh * kw * ic * oc);
        Filter { kh, kw, ic, oc, data }
    }

    pub fn randn(kh: usize, kw: usize, ic: usize, oc: usize, rng: &mut crate::util::rng::Rng) -> Self {
        let scale = 1.0 / ((kh * kw * ic) as f32).sqrt();
        let data = (0..kh * kw * ic * oc).map(|_| rng.normal() * scale).collect();
        Filter { kh, kw, ic, oc, data }
    }

    #[inline]
    pub fn idx(&self, kh: usize, kw: usize, ic: usize, oc: usize) -> usize {
        ((kh * self.kw + kw) * self.ic + ic) * self.oc + oc
    }

    #[inline]
    pub fn at(&self, kh: usize, kw: usize, ic: usize, oc: usize) -> f32 {
        self.data[self.idx(kh, kw, ic, oc)]
    }

    #[inline]
    pub fn at_mut(&mut self, kh: usize, kw: usize, ic: usize, oc: usize) -> &mut f32 {
        let i = self.idx(kh, kw, ic, oc);
        &mut self.data[i]
    }

    /// Rotate 180 degrees in the spatial plane (channels untouched).
    pub fn rot180(&self) -> Filter {
        let mut out = Filter::zeros(self.kh, self.kw, self.ic, self.oc);
        for a in 0..self.kh {
            for b in 0..self.kw {
                for i in 0..self.ic {
                    for o in 0..self.oc {
                        *out.at_mut(self.kh - 1 - a, self.kw - 1 - b, i, o) = self.at(a, b, i, o);
                    }
                }
            }
        }
        out
    }

    pub fn params(&self) -> usize {
        self.data.len()
    }

    pub fn nonzero_params(&self) -> usize {
        self.data.iter().filter(|v| **v != 0.0).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn pad_and_crop_roundtrip() {
        let mut rng = Rng::new(1);
        let x = Tensor::randn(2, 3, 4, 2, &mut rng);
        let p = x.pad(1, 2, 3, 0);
        assert_eq!(p.shape(), [2, 6, 7, 2]);
        let back = p.crop(1, 3, 3, 4);
        assert!(back.allclose(&x, 0.0));
        // padding is zeros
        assert_eq!(p.at(0, 0, 0, 0), 0.0);
        assert_eq!(p.at(1, 5, 6, 1), 0.0);
    }

    #[test]
    fn rot180_involution() {
        let mut rng = Rng::new(2);
        let f = Filter::randn(3, 4, 2, 2, &mut rng);
        assert_eq!(f.rot180().rot180(), f);
        // corner check
        assert_eq!(f.rot180().at(0, 0, 1, 0), f.at(2, 3, 1, 0));
    }

    #[test]
    fn sparsity() {
        let mut x = Tensor::zeros(1, 2, 2, 1);
        x.data[0] = 1.0;
        assert!((x.sparsity() - 0.75).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_len() {
        Tensor::from_vec(1, 2, 2, 1, vec![0.0; 3]);
    }
}
