//! Cache-blocked MR x NR microkernel GEMM with packed-B panels and runtime
//! SIMD dispatch — the f32 compute core under every convolution and dense
//! layer (the int8 twin lives in `quant::gemm`).
//!
//! ## Structure
//!
//! * **Packing** ([`PackedB`]): the `K x N` right-hand operand (a filter's
//!   HWIO payload, or a dense weight matrix) is reorganized once into
//!   [`NR`]-wide column panels — panel `p`, row `kk` holds the `NR`
//!   contiguous values `b[kk][p*NR .. p*NR+NR]` (zero-padded past `n`).
//!   Every k-step of the microkernel then issues two aligned-stream loads
//!   instead of striding across the full `N` row, and the panel the kernel
//!   is working on stays cache-resident across all `M` rows. The engine
//!   packs **all** conv / dense / SD-split weights once at `Program` compile
//!   time; the non-engine call paths pack per call (O(K·N), amortized
//!   against the O(M·K·N) GEMM).
//! * **Microkernel**: an MR x [`NR`] register block — MR rows of A
//!   broadcast against two [`NR`]/2-wide B vectors, accumulating in
//!   registers across the whole K loop. The AVX2+FMA variant is selected at
//!   runtime behind one `is_x86_feature_detected!` gate ([`active_backend`])
//!   with a portable scalar fallback that doubles as the numerics oracle.
//!
//! ## Numerics policy
//!
//! Every output element is accumulated in **ascending-k order with a single
//! accumulator** in both kernels — per-element operation *order* never
//! depends on the element's position in the block, the tile, the batch, or
//! on how many worker threads participate. Consequences, in the order the
//! test suites rely on them:
//!
//! * **Determinism**: results are bit-identical for any `SD_CONV_THREADS`,
//!   any tile schedule, any batch packing, on every run (asserted across
//!   thread counts on all six benchmark networks in
//!   rust/tests/gemm_numerics.rs).
//! * **Scalar = oracle**: the scalar kernel performs `acc + a*b` with one
//!   rounding per multiply and per add, exactly the operation sequence of
//!   the seven-loop `conv2d_naive` reference — on machines without AVX2 the
//!   fast path remains *bit-exact* vs naive.
//! * **SIMD = ULP-bounded**: the AVX2 kernel uses FMA (`fl(a*b + acc)`,
//!   one rounding per step instead of two), so its results differ from the
//!   scalar oracle by rounding only. The documented bound, checked against
//!   an f64-referenced result in rust/tests/gemm_numerics.rs: the error
//!   obeys the standard forward bound `|ŷ − y| ≤ k·ε·Σ|aᵢbᵢ|`, and on
//!   well-conditioned elements the divergence stays within
//!   [`ulp_bound`]`(k)` ULPs of the f64 reference.
//!
//! See DESIGN.md §10 for the full layout / dispatch / policy writeup and
//! `cargo bench --bench hotpath` for achieved GFLOP/s vs the scalar kernel.

use crate::util::blob::BlobVec;
use std::sync::atomic::{AtomicU8, AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Microkernel panel width (output channels per packed panel). Two 8-lane
/// AVX registers; the scalar kernel uses the same width so both backends
/// walk identical panels.
pub const NR: usize = 16;

/// Microkernel register-block height (A rows per block) of the SIMD path:
/// 6 rows x 2 B vectors = 12 independent FMA chains, enough to cover FMA
/// latency on two issue ports.
const MR: usize = 6;

/// Scalar-kernel row block (kept at the old kernel's height; the scalar
/// path's accumulators live in stack arrays, not registers).
const MR_SCALAR: usize = 4;

/// Which microkernel implementation executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmBackend {
    /// portable mul+add kernel — bit-exact with `conv2d_naive`, retained as
    /// the numerics oracle and the bench baseline
    Scalar,
    /// AVX2 + FMA microkernel (runtime-detected)
    Avx2,
}

impl GemmBackend {
    pub fn label(&self) -> &'static str {
        match self {
            GemmBackend::Scalar => "scalar",
            GemmBackend::Avx2 => "avx2+fma",
        }
    }
}

/// 0 = auto (detected), 1 = force scalar, 2 = force avx2 (honored only when
/// detected). Bench/test hook — see [`force_backend`].
static BACKEND_OVERRIDE: AtomicU8 = AtomicU8::new(0);

fn detected_backend() -> GemmBackend {
    static DETECTED: OnceLock<GemmBackend> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
                return GemmBackend::Avx2;
            }
        }
        GemmBackend::Scalar
    })
}

/// The backend the GEMM entry points dispatch to: the runtime-detected one
/// (AVX2+FMA where available), unless a bench/test override is in force.
pub fn active_backend() -> GemmBackend {
    match BACKEND_OVERRIDE.load(Ordering::Relaxed) {
        1 => GemmBackend::Scalar,
        2 if detected_backend() == GemmBackend::Avx2 => GemmBackend::Avx2,
        _ => detected_backend(),
    }
}

/// Force a specific backend (`None` restores auto-detection). A forced
/// `Avx2` on a machine without AVX2 falls back to the detected backend.
/// This is the hotpath bench's SIMD-vs-scalar measurement hook and a test
/// hook; it is process-global, so callers must not rely on it across
/// concurrent measurements.
pub fn force_backend(backend: Option<GemmBackend>) {
    let v = match backend {
        None => 0,
        Some(GemmBackend::Scalar) => 1,
        Some(GemmBackend::Avx2) => 2,
    };
    BACKEND_OVERRIDE.store(v, Ordering::Relaxed);
}

/// A `K x N` GEMM right-hand operand packed into [`NR`]-wide column panels
/// (see the module docs). Packed once per weight at engine compile time, or
/// per call (into a reused thread-local) on the non-engine paths.
///
/// # On-disk layout (`.sdprog` `packed_b` blobs)
///
/// The payload's in-memory order **is** the artifact order: `panels() * k *
/// NR` little-endian `f32` values at `(p * k + kk) * NR + j`, zero past
/// column `n` — no header, `k`/`n` live in the artifact manifest. Blobs are
/// placed at 64-byte-aligned file offsets so a loaded buffer can be viewed
/// in place; storage is a [`BlobVec`] to permit exactly that borrow in the
/// zero-copy load mode.
#[derive(Clone, Debug, Default)]
pub struct PackedB {
    /// contraction length (rows of the unpacked operand)
    pub k: usize,
    /// logical column count (columns of the unpacked operand)
    pub n: usize,
    /// `panels() * k * NR` values: panel `p`, row `kk`, lane `j` at
    /// `(p * k + kk) * NR + j`, zero past column `n`
    data: BlobVec<f32>,
}

impl PackedB {
    /// An empty (0 x 0) operand — the reusable-slot form.
    pub fn empty() -> PackedB {
        PackedB::default()
    }

    /// Pack a row-major `k x n` matrix.
    pub fn pack(b: &[f32], k: usize, n: usize) -> PackedB {
        let mut p = PackedB::empty();
        p.pack_into(b, k, n);
        p
    }

    /// [`PackedB::pack`] reusing this instance's buffer capacity.
    pub fn pack_into(&mut self, b: &[f32], k: usize, n: usize) {
        assert_eq!(b.len(), k * n, "packed operand size");
        self.k = k;
        self.n = n;
        let panels = n.div_ceil(NR);
        let data = self.data.owned_mut();
        data.clear();
        data.resize(panels * k * NR, 0.0);
        for p in 0..panels {
            let col0 = p * NR;
            let cols = NR.min(n - col0);
            for kk in 0..k {
                let src = kk * n + col0;
                let dst = (p * k + kk) * NR;
                data[dst..dst + cols].copy_from_slice(&b[src..src + cols]);
                // lanes past `cols` stay zero: the kernel computes them and
                // the store step drops them
            }
        }
    }

    /// Adopt an already-packed payload (the artifact loader's copy path).
    /// `None` when `data.len()` is not the `panels * k * NR` the shape
    /// requires.
    pub fn from_parts(k: usize, n: usize, data: Vec<f32>) -> Option<PackedB> {
        if data.len() != PackedB::packed_len(k, n) {
            return None;
        }
        Some(PackedB {
            k,
            n,
            data: BlobVec::Owned(data),
        })
    }

    /// Borrow an already-packed payload in place from a shared artifact
    /// buffer (the zero-copy load path; caller has verified the checksum
    /// and that the bytes are native-endian `f32`s). `None` on a bounds,
    /// alignment, or length mismatch.
    pub fn from_shared(
        k: usize,
        n: usize,
        buf: std::sync::Arc<crate::util::blob::AlignedBytes>,
        off_bytes: usize,
    ) -> Option<PackedB> {
        let len = PackedB::packed_len(k, n);
        Some(PackedB {
            k,
            n,
            data: BlobVec::shared(buf, off_bytes, len)?,
        })
    }

    /// The packed payload in its on-disk element order (see the type docs).
    pub fn raw(&self) -> &[f32] {
        self.data.as_slice()
    }

    /// Packed element count the panel layout requires for a `k x n`
    /// operand — the artifact loader's length cross-check.
    pub fn packed_len(k: usize, n: usize) -> usize {
        n.div_ceil(NR) * k * NR
    }

    /// Number of [`NR`]-wide panels.
    pub fn panels(&self) -> usize {
        self.n.div_ceil(NR)
    }

    /// Packed payload size in bytes (the plan-time memory cost).
    pub fn bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Reconstruct the row-major `k x n` matrix (drops the zero padding).
    /// Used once at int8 lowering time, where the engine quantizes from the
    /// packed form instead of carrying a second f32 copy of the weights.
    pub fn unpack(&self) -> Vec<f32> {
        let mut b = vec![0.0f32; self.k * self.n];
        let data = self.data.as_slice();
        for p in 0..self.panels() {
            let col0 = p * NR;
            let cols = NR.min(self.n - col0);
            for kk in 0..self.k {
                let src = (p * self.k + kk) * NR;
                b[kk * self.n + col0..kk * self.n + col0 + cols]
                    .copy_from_slice(&data[src..src + cols]);
            }
        }
        b
    }

    /// One panel's `k * NR` slice.
    #[inline]
    fn panel(&self, p: usize) -> &[f32] {
        &self.data.as_slice()[p * self.k * NR..(p + 1) * self.k * NR]
    }
}

/// `c = a (m x k) . b (k x n)`, row-major `a`/`c`, `b` pre-packed; `c` is
/// fully overwritten. Dispatches to the active backend.
pub fn gemm_packed(a: &[f32], b: &PackedB, m: usize, c: &mut [f32]) {
    assert_eq!(a.len(), m * b.k, "gemm a size");
    assert_eq!(c.len(), m * b.n, "gemm c size");
    // SAFETY: `c` is exclusively borrowed and sized m x n; all panels are
    // written, each exactly once.
    unsafe { gemm_panels_raw(active_backend(), a, b, m, c.as_mut_ptr(), 0, b.panels()) }
}

/// [`gemm_packed`] computing only panels `[p_lo, p_hi)` — columns
/// `[p_lo*NR, min(p_hi*NR, n))` of every row of `c`. `c` is the base
/// pointer of the full `m x n` row-major output; the panel range's columns
/// are written, nothing else is touched.
///
/// This is the parallel building block: disjoint panel ranges write
/// disjoint columns, so worker threads share one output buffer without
/// locks (and, because each element's accumulation never leaves its panel,
/// without any effect on results).
///
/// # Safety
///
/// `c` must be valid for writes of `m * b.n` elements, and no other thread
/// may concurrently write the same panel range.
pub(crate) unsafe fn gemm_panels_raw(
    backend: GemmBackend,
    a: &[f32],
    b: &PackedB,
    m: usize,
    c: *mut f32,
    p_lo: usize,
    p_hi: usize,
) {
    debug_assert_eq!(a.len(), m * b.k);
    debug_assert!(p_hi <= b.panels());
    for p in p_lo..p_hi {
        let col0 = p * NR;
        let ncols = NR.min(b.n - col0);
        match backend {
            GemmBackend::Scalar => panel_scalar(a, b.k, m, b.panel(p), c, b.n, col0, ncols),
            GemmBackend::Avx2 => {
                #[cfg(target_arch = "x86_64")]
                panel_avx2(a, b.k, m, b.panel(p), c, b.n, col0, ncols);
                #[cfg(not(target_arch = "x86_64"))]
                panel_scalar(a, b.k, m, b.panel(p), c, b.n, col0, ncols);
            }
        }
    }
}

/// Portable panel kernel: [`MR_SCALAR`] rows at a time, per-element
/// ascending-k `acc + a*b` (two roundings per step) — the operation
/// sequence of `conv2d_naive`, hence bit-exact with it.
///
/// # Safety
///
/// `c` must be valid for writes of `m * n` elements (row-major).
unsafe fn panel_scalar(
    a: &[f32],
    k: usize,
    m: usize,
    panel: &[f32],
    c: *mut f32,
    n: usize,
    col0: usize,
    ncols: usize,
) {
    let mut row = 0;
    while row < m {
        let rows = (m - row).min(MR_SCALAR);
        let mut acc = [[0.0f32; NR]; MR_SCALAR];
        for kk in 0..k {
            let bvals = &panel[kk * NR..kk * NR + NR];
            for (r, accr) in acc.iter_mut().enumerate().take(rows) {
                let av = a[(row + r) * k + kk];
                for (dst, &bv) in accr.iter_mut().zip(bvals) {
                    *dst += av * bv;
                }
            }
        }
        for (r, accr) in acc.iter().enumerate().take(rows) {
            let dst = c.add((row + r) * n + col0);
            std::ptr::copy_nonoverlapping(accr.as_ptr(), dst, ncols);
        }
        row += rows;
    }
}

/// AVX2+FMA panel kernel: [`MR`] x [`NR`] register block, per-element
/// ascending-k `fma(a, b, acc)` (one rounding per step). Remainder rows run
/// one at a time through the same per-element operation sequence, so an
/// element's bits never depend on which block shape computed it.
///
/// # Safety
///
/// Caller must ensure AVX2+FMA are available (dispatch does) and that `c`
/// is valid for writes of `m * n` elements.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn panel_avx2(
    a: &[f32],
    k: usize,
    m: usize,
    panel: &[f32],
    c: *mut f32,
    n: usize,
    col0: usize,
    ncols: usize,
) {
    use std::arch::x86_64::*;

    let ap = a.as_ptr();
    let pp = panel.as_ptr();

    let mut row = 0;
    while row + MR <= m {
        let mut acc = [[_mm256_setzero_ps(); 2]; MR];
        for kk in 0..k {
            let b0 = _mm256_loadu_ps(pp.add(kk * NR));
            let b1 = _mm256_loadu_ps(pp.add(kk * NR + 8));
            for (r, accr) in acc.iter_mut().enumerate() {
                let av = _mm256_set1_ps(*ap.add((row + r) * k + kk));
                accr[0] = _mm256_fmadd_ps(av, b0, accr[0]);
                accr[1] = _mm256_fmadd_ps(av, b1, accr[1]);
            }
        }
        for (r, accr) in acc.iter().enumerate() {
            store_row(c, (row + r) * n + col0, ncols, accr[0], accr[1]);
        }
        row += MR;
    }
    while row < m {
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        for kk in 0..k {
            let b0 = _mm256_loadu_ps(pp.add(kk * NR));
            let b1 = _mm256_loadu_ps(pp.add(kk * NR + 8));
            let av = _mm256_set1_ps(*ap.add(row * k + kk));
            acc0 = _mm256_fmadd_ps(av, b0, acc0);
            acc1 = _mm256_fmadd_ps(av, b1, acc1);
        }
        store_row(c, row * n + col0, ncols, acc0, acc1);
        row += 1;
    }
}

/// Store one row's two accumulator vectors at `c[off..off+ncols]`
/// (full-width fast path, buffered tail for the last partial panel).
///
/// # Safety
///
/// Caller must ensure AVX is available and `c[off..off+ncols]` is valid
/// for writes.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn store_row(
    c: *mut f32,
    off: usize,
    ncols: usize,
    acc0: std::arch::x86_64::__m256,
    acc1: std::arch::x86_64::__m256,
) {
    use std::arch::x86_64::*;
    if ncols == NR {
        _mm256_storeu_ps(c.add(off), acc0);
        _mm256_storeu_ps(c.add(off + 8), acc1);
    } else {
        let mut buf = [0.0f32; NR];
        _mm256_storeu_ps(buf.as_mut_ptr(), acc0);
        _mm256_storeu_ps(buf.as_mut_ptr().add(8), acc1);
        std::ptr::copy_nonoverlapping(buf.as_ptr(), c.add(off), ncols);
    }
}

/// ULP budget of the SIMD kernel vs the f64-referenced result for a
/// k-long contraction, on well-conditioned elements (see the module docs'
/// numerics policy): `8 + 4·⌈√k⌉`, the random-walk rounding envelope with
/// 4x headroom.
pub fn ulp_bound(k: usize) -> u64 {
    8 + 4 * (k as f64).sqrt().ceil() as u64
}

/// Distance between two finite f32 values in units in the last place —
/// the number of representable floats between them (0 for identical
/// values; +0 and -0 are 0 apart).
pub fn ulp_distance(a: f32, b: f32) -> u64 {
    fn ord(x: f32) -> i64 {
        let bits = x.to_bits();
        if bits & 0x8000_0000 == 0 {
            bits as i64
        } else {
            -((bits & 0x7fff_ffff) as i64)
        }
    }
    (ord(a) - ord(b)).unsigned_abs()
}

/// A raw mutable pointer that asserts cross-thread shareability: the
/// parallel tile/panel drivers hand each worker a disjoint region of one
/// output buffer through this.
pub(crate) struct SendPtr<T>(pub *mut T);

// SAFETY: shareability is asserted by the drivers, which guarantee
// disjoint writes (each tile / panel range claimed by exactly one
// `fetch_add` winner) and joined lifetimes (the pool barrier).
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    #[inline]
    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// Run `worker` on the caller plus `workers - 1` threads of the persistent
/// pool ([`crate::runtime::pool`]). Every invocation receives the shared
/// tile cursor and drains it: `cursor.fetch_add(1)` until the caller's tile
/// count is exhausted — the lock-free replacement for the old
/// `Mutex<Vec<Tile>>` pop queue, and the reason results cannot depend on
/// `workers` (each tile index is claimed by exactly one winner and computed
/// by the same code whichever thread claims it).
pub(crate) fn parallel_drain(workers: usize, worker: &(dyn Fn(&AtomicUsize) + Sync)) {
    let cursor = AtomicUsize::new(0);
    if workers <= 1 {
        worker(&cursor);
        return;
    }
    crate::runtime::pool::global().run(workers - 1, &|| worker(&cursor));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn naive(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        for r in 0..m {
            for j in 0..n {
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += a[r * k + kk] * b[kk * n + j];
                }
                c[r * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn pack_unpack_roundtrips() {
        let mut rng = Rng::new(2);
        for (k, n) in [(1, 1), (3, 16), (5, 17), (7, 40), (2, 15)] {
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let packed = PackedB::pack(&b, k, n);
            assert_eq!(packed.panels(), n.div_ceil(NR));
            assert_eq!(packed.unpack(), b, "k{k} n{n}");
        }
    }

    #[test]
    fn scalar_backend_matches_naive_bitwise() {
        let mut rng = Rng::new(7);
        for (m, k, n) in [(1, 1, 1), (4, 9, 16), (6, 30, 17), (13, 25, 33), (3, 8, 5)] {
            let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
            let packed = PackedB::pack(&b, k, n);
            let mut c = vec![f32::NAN; m * n];
            // SAFETY: c is exclusively owned, sized m x n
            unsafe {
                gemm_panels_raw(
                    GemmBackend::Scalar,
                    &a,
                    &packed,
                    m,
                    c.as_mut_ptr(),
                    0,
                    packed.panels(),
                )
            };
            let want = naive(&a, &b, m, k, n);
            assert_eq!(c, want, "m{m} k{k} n{n}");
        }
    }

    #[test]
    fn active_backend_obeys_f64_forward_bound() {
        // the documented policy, per element: |c - ref64| <= k*eps*sum|ab|
        // (holds for both the mul+add scalar kernel and the FMA kernel;
        // the tighter conditioned-ULP sweep lives in
        // rust/tests/gemm_numerics.rs)
        let mut rng = Rng::new(11);
        let (m, k, n) = (23, 64, 37);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let packed = PackedB::pack(&b, k, n);
        let mut c = vec![f32::NAN; m * n];
        gemm_packed(&a, &packed, m, &mut c);
        let eps = f32::EPSILON as f64;
        for r in 0..m {
            for j in 0..n {
                let mut refv = 0.0f64;
                let mut sa = 0.0f64;
                for kk in 0..k {
                    let term = a[r * k + kk] as f64 * b[kk * n + j] as f64;
                    refv += term;
                    sa += term.abs();
                }
                let got = c[r * n + j] as f64;
                let err = (got - refv).abs();
                let bound = k as f64 * eps * sa + f64::from(f32::MIN_POSITIVE);
                assert!(err <= bound, "({r},{j}): |{got} - {refv}| = {err} > {bound}");
            }
        }
    }

    #[test]
    fn partial_panel_ranges_compose() {
        // computing panels in two disjoint calls equals one full call —
        // the property the parallel dense driver relies on
        let mut rng = Rng::new(23);
        let (m, k, n) = (5, 12, 50);
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal()).collect();
        let packed = PackedB::pack(&b, k, n);
        let mut whole = vec![0.0f32; m * n];
        gemm_packed(&a, &packed, m, &mut whole);
        let mut split = vec![0.0f32; m * n];
        let mid = packed.panels() / 2;
        // read the backend once: bit-compare below requires one kernel
        let be = active_backend();
        // SAFETY: exclusive buffer; the two ranges write disjoint columns
        unsafe {
            gemm_panels_raw(be, &a, &packed, m, split.as_mut_ptr(), 0, mid);
            gemm_panels_raw(be, &a, &packed, m, split.as_mut_ptr(), mid, packed.panels());
        }
        assert_eq!(whole, split);
    }

    #[test]
    fn ulp_distance_basics() {
        assert_eq!(ulp_distance(1.0, 1.0), 0);
        assert_eq!(ulp_distance(0.0, -0.0), 0);
        assert_eq!(ulp_distance(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        assert!(ulp_distance(-1e-3, 1e-3) > 1_000_000);
        assert!(ulp_bound(2304) > ulp_bound(9));
    }
}
