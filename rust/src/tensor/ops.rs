//! Convolution / deconvolution ops over [`Tensor`] / [`Filter`].
//!
//! `conv2d` is the hot path: every deconvolution implementation (SD, NZP,
//! Shi, Chang) lowers to it, the quality evaluation (Table 4, Figs 13/14)
//! runs entire generators through it, and the coordinator's CPU-native
//! executor serves batched DCGAN traffic on it. The core is
//! [`conv2d_gemm`]: im2col packing into a per-thread scratch arena followed
//! by a cache-blocked GEMM, parallelized over batch x output-row tiles with
//! a scoped worker pool. The scalar reference kernel is retained as
//! [`conv2d_naive`], the bit-exactness oracle (accumulation order in the
//! GEMM micro-kernel is ascending-k per output element, identical to the
//! oracle's loop order, so the two agree bit for bit). See EXPERIMENTS.md
//! #Perf for measurements and `cargo bench --bench hotpath` for the
//! GEMM-vs-naive speedup on the paper's DCGAN/FST layer shapes.

use super::{Filter, Tensor};

/// Standard cross-correlation convolution (stride, symmetric zero padding).
pub fn conv2d(x: &Tensor, f: &Filter, stride: usize, padding: usize) -> Tensor {
    assert_eq!(x.c, f.ic, "channel mismatch");
    let xp;
    let x = if padding > 0 {
        xp = x.pad(padding, padding, padding, padding);
        &xp
    } else {
        x
    };
    conv2d_valid(x, f, stride)
}

/// Valid convolution — the hot path. Dispatches to the im2col + GEMM kernel
/// ([`conv2d_gemm`]); results are bit-identical to [`conv2d_naive`].
pub fn conv2d_valid(x: &Tensor, f: &Filter, stride: usize) -> Tensor {
    conv2d_gemm(x, f, stride)
}

/// [`conv2d_valid`] writing into a caller-provided tensor (reshaped and
/// resized in place) — the engine's arena-backed entry point. Results are
/// bit-identical to [`conv2d_valid`]: same tiling, same micro-kernel, same
/// accumulation order; only the output buffer's provenance differs.
pub fn conv2d_valid_into(x: &Tensor, f: &Filter, stride: usize, out: &mut Tensor) {
    conv2d_gemm_into(x, f, stride, out)
}

/// Scalar reference convolution: the bit-exactness oracle for the GEMM
/// kernel (property-tested in rust/tests/conv_gemm.rs) and the baseline the
/// hotpath bench reports speedup over. Deliberately the plain 7-deep loop.
pub fn conv2d_naive(x: &Tensor, f: &Filter, stride: usize) -> Tensor {
    assert_eq!(x.c, f.ic, "channel mismatch");
    assert!(x.h >= f.kh && x.w >= f.kw, "filter larger than input");
    let oh = (x.h - f.kh) / stride + 1;
    let ow = (x.w - f.kw) / stride + 1;
    let mut out = Tensor::zeros(x.n, oh, ow, f.oc);
    for n in 0..x.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for o in 0..f.oc {
                    let mut acc = 0.0;
                    for dy in 0..f.kh {
                        for dx in 0..f.kw {
                            for i in 0..x.c {
                                acc += x.at(n, oy * stride + dy, ox * stride + dx, i)
                                    * f.at(dy, dx, i, o);
                            }
                        }
                    }
                    *out.at_mut(n, oy, ox, o) = acc;
                }
            }
        }
    }
    out
}

/// Per-thread im2col scratch target: keep one tile's panel ~L2-resident.
/// Shared with the int8 kernel (`quant::gemm`), which fits 4x the rows in
/// the same budget (i8 elements).
pub(crate) const PANEL_BYTES: usize = 256 * 1024;

/// Micro-kernel register-block height (output pixels per GEMM block).
const MR: usize = 4;

/// MAC count below which threading overhead outweighs the parallel win.
const PARALLEL_MIN_MACS: usize = 1 << 21;

/// One worker job: a tile of output rows of one batch image, owning the
/// corresponding disjoint slice of the output buffer.
struct Tile<'a> {
    n: usize,
    y0: usize,
    rows: usize,
    out: &'a mut [f32],
}

/// Per-thread scratch arena, reused across every tile a worker runs: the
/// im2col panel and the micro-kernel accumulator block.
#[derive(Default)]
struct Scratch {
    panel: Vec<f32>,
    acc: Vec<f32>,
}

/// Valid convolution as im2col + cache-blocked GEMM over a scoped worker
/// pool.
///
/// The filter's HWIO layout already *is* the K x N GEMM operand
/// (K = kh\*kw\*ic contiguous rows of N = oc), so only the activations are
/// packed: each output pixel's receptive field is kh contiguous
/// kw\*ic-float row segments, gathered into a panel held in the worker's
/// scratch arena. Work is split into batch x output-row tiles sized so one
/// panel stays ~L2-resident; tiles are drained from a shared queue by
/// `min(cores, tiles)` scoped threads (set `SD_CONV_THREADS` to override).
/// Every output element accumulates in ascending-k order with one f32
/// accumulator, exactly the order of [`conv2d_naive`] — the two kernels are
/// bit-identical, which rust/tests/conv_gemm.rs asserts with zero tolerance.
pub fn conv2d_gemm(x: &Tensor, f: &Filter, stride: usize) -> Tensor {
    let mut out = Tensor::zeros(0, 0, 0, 0);
    conv2d_gemm_into(x, f, stride, &mut out);
    out
}

/// [`conv2d_gemm`] into a caller-provided tensor: `out` is reshaped to the
/// convolution output shape and its buffer resized (reusing capacity);
/// every element is overwritten.
pub fn conv2d_gemm_into(x: &Tensor, f: &Filter, stride: usize, out: &mut Tensor) {
    assert_eq!(x.c, f.ic, "channel mismatch");
    assert!(x.h >= f.kh && x.w >= f.kw, "filter larger than input");
    let oh = (x.h - f.kh) / stride + 1;
    let ow = (x.w - f.kw) / stride + 1;
    let kdim = f.kh * f.kw * f.ic;
    let n_out = f.oc;
    out.n = x.n;
    out.h = oh;
    out.w = ow;
    out.c = n_out;
    out.data.clear();
    out.data.resize(x.n * oh * ow * n_out, 0.0);
    if out.data.is_empty() {
        return;
    }

    let rows_per_tile = (PANEL_BYTES / (ow * kdim * 4).max(1)).clamp(1, oh);
    let mut tiles: Vec<Tile> = Vec::new();
    for (n, img) in out.data.chunks_mut(oh * ow * n_out).enumerate() {
        for (t, slice) in img.chunks_mut(rows_per_tile * ow * n_out).enumerate() {
            tiles.push(Tile {
                n,
                y0: t * rows_per_tile,
                rows: slice.len() / (ow * n_out),
                out: slice,
            });
        }
    }

    let macs = x.n * oh * ow * kdim * n_out;
    let workers = worker_count(macs, tiles.len());
    if workers <= 1 {
        let mut scratch = Scratch::default();
        for tile in tiles {
            run_tile(x, f, stride, ow, tile, &mut scratch);
        }
    } else {
        let queue = std::sync::Mutex::new(tiles);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| {
                    let mut scratch = Scratch::default();
                    loop {
                        // take the lock only to pop, not across the tile run
                        let tile = queue.lock().unwrap().pop();
                        match tile {
                            Some(tile) => run_tile(x, f, stride, ow, tile, &mut scratch),
                            None => break,
                        }
                    }
                });
            }
        });
    }
}

/// Worker-pool size: 1 for small problems, else `SD_CONV_THREADS` or the
/// machine's available parallelism, capped by the tile count. ONE policy
/// for both the f32 and the int8 (`quant::gemm`) kernels, so f32-vs-int8
/// benches compare kernels, not thread policies.
pub(crate) fn worker_count(macs: usize, tiles: usize) -> usize {
    if tiles <= 1 || macs < PARALLEL_MIN_MACS {
        return 1;
    }
    std::env::var("SD_CONV_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .clamp(1, tiles)
}

/// Pack one row tile's im2col panel into the scratch arena, then GEMM it
/// against the filter into the tile's output slice.
fn run_tile(x: &Tensor, f: &Filter, stride: usize, ow: usize, tile: Tile, s: &mut Scratch) {
    let kdim = f.kh * f.kw * f.ic;
    let seg = f.kw * x.c; // one contiguous input-row segment per kernel row
    let m = tile.rows * ow;
    // no zero-fill: the packing loop below overwrites every element
    // (kh segments of kw*ic per pixel cover the full kdim)
    s.panel.resize(m * kdim, 0.0);
    for r in 0..tile.rows {
        let oy = tile.y0 + r;
        for ox in 0..ow {
            let dst_base = (r * ow + ox) * kdim;
            for dy in 0..f.kh {
                let src = x.idx(tile.n, oy * stride + dy, ox * stride, 0);
                let dst = dst_base + dy * seg;
                s.panel[dst..dst + seg].copy_from_slice(&x.data[src..src + seg]);
            }
        }
    }
    gemm(&s.panel, &f.data, m, kdim, f.oc, tile.out, &mut s.acc);
}

/// `c = a (m x k) . b (k x n)`, row-major, `c` overwritten. Register-blocked
/// MR rows at a time; per-element accumulation is ascending-k (bit-exact
/// with the scalar oracle).
fn gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, c: &mut [f32], acc: &mut Vec<f32>) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if acc.len() != MR * n {
        acc.resize(MR * n, 0.0);
    }
    let mut row = 0;
    while row + MR <= m {
        acc.fill(0.0);
        {
            let (a0, rest) = acc.split_at_mut(n);
            let (a1, rest) = rest.split_at_mut(n);
            let (a2, a3) = rest.split_at_mut(n);
            let p0 = &a[row * k..(row + 1) * k];
            let p1 = &a[(row + 1) * k..(row + 2) * k];
            let p2 = &a[(row + 2) * k..(row + 3) * k];
            let p3 = &a[(row + 3) * k..(row + 4) * k];
            for kk in 0..k {
                let (v0, v1, v2, v3) = (p0[kk], p1[kk], p2[kk], p3[kk]);
                let brow = &b[kk * n..(kk + 1) * n];
                for ((((&w, c0), c1), c2), c3) in brow
                    .iter()
                    .zip(a0.iter_mut())
                    .zip(a1.iter_mut())
                    .zip(a2.iter_mut())
                    .zip(a3.iter_mut())
                {
                    *c0 += v0 * w;
                    *c1 += v1 * w;
                    *c2 += v2 * w;
                    *c3 += v3 * w;
                }
            }
        }
        c[row * n..(row + MR) * n].copy_from_slice(&acc[..MR * n]);
        row += MR;
    }
    while row < m {
        let arow = &a[row * k..(row + 1) * k];
        let crow = &mut c[row * n..(row + 1) * n];
        crow.fill(0.0);
        for kk in 0..k {
            let v = arow[kk];
            let brow = &b[kk * n..(kk + 1) * n];
            for (cv, &w) in crow.iter_mut().zip(brow) {
                *cv += v * w;
            }
        }
        row += 1;
    }
}

/// Transposed convolution (scatter semantics, torch ConvTranspose2d),
/// with layer padding `p` and output padding `op`:
/// out side = (i-1)\*s + k - 2p + op.
pub fn deconv2d(x: &Tensor, f: &Filter, stride: usize, padding: usize, out_pad: usize) -> Tensor {
    let full_h = (x.h - 1) * stride + f.kh;
    let full_w = (x.w - 1) * stride + f.kw;
    let mut full = Tensor::zeros(x.n, full_h, full_w, f.oc);
    let oc = f.oc;
    for n in 0..x.n {
        for iy in 0..x.h {
            for ix in 0..x.w {
                let xbase = x.idx(n, iy, ix, 0);
                for dy in 0..f.kh {
                    for dx in 0..f.kw {
                        let obase = full.idx(n, iy * stride + dy, ix * stride + dx, 0);
                        let wbase = f.idx(dy, dx, 0, 0);
                        let acc = &mut full.data[obase..obase + oc];
                        for ic in 0..x.c {
                            let xv = x.data[xbase + ic];
                            if xv == 0.0 {
                                continue;
                            }
                            let ws = &f.data[wbase + ic * oc..wbase + ic * oc + oc];
                            for (a, &w) in acc.iter_mut().zip(ws) {
                                *a += xv * w;
                            }
                        }
                    }
                }
            }
        }
    }
    let out_h = full_h - 2 * padding + out_pad;
    let out_w = full_w - 2 * padding + out_pad;
    full.crop_padded(padding, out_h, padding, out_w)
}

/// Insert (stride-1) zeros between activations (NZP dilation step).
pub fn zero_insert(x: &Tensor, stride: usize) -> Tensor {
    if stride == 1 {
        return x.clone();
    }
    let mut out = Tensor::zeros(x.n, (x.h - 1) * stride + 1, (x.w - 1) * stride + 1, x.c);
    for n in 0..x.n {
        for h in 0..x.h {
            for w in 0..x.w {
                let src = x.idx(n, h, w, 0);
                let dst = out.idx(n, h * stride, w * stride, 0);
                out.data[dst..dst + x.c].copy_from_slice(&x.data[src..src + x.c]);
            }
        }
    }
    out
}

/// Dense (fully-connected) layer: x viewed as (N, H\*W\*C) @ w (in x out).
/// A weight buffer whose length disagrees with `n_in * n_out` is an error
/// (not a panic — the serving stack routes it through the coordinator's
/// failed-batch path).
pub fn dense(x: &Tensor, w: &[f32], n_out: usize) -> anyhow::Result<Tensor> {
    let mut out = Tensor::zeros(0, 0, 0, 0);
    dense_into(x, w, n_out, &mut out)?;
    Ok(out)
}

/// [`dense`] into a caller-provided tensor (reshaped, resized, zeroed in
/// place, reusing capacity). Accumulation order identical to [`dense`].
pub fn dense_into(x: &Tensor, w: &[f32], n_out: usize, out: &mut Tensor) -> anyhow::Result<()> {
    let n_in = x.h * x.w * x.c;
    if w.len() != n_in * n_out {
        anyhow::bail!(
            "dense weight length {} != n_in {} x n_out {}",
            w.len(),
            n_in,
            n_out
        );
    }
    out.n = x.n;
    out.h = 1;
    out.w = 1;
    out.c = n_out;
    out.data.clear();
    out.data.resize(x.n * n_out, 0.0);
    for n in 0..x.n {
        let xrow = &x.data[n * n_in..(n + 1) * n_in];
        let orow_base = n * n_out;
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * n_out..(i + 1) * n_out];
            let orow = &mut out.data[orow_base..orow_base + n_out];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    Ok(())
}

/// In-place ReLU.
pub fn relu(x: &mut Tensor) {
    for v in &mut x.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place tanh.
pub fn tanh(x: &mut Tensor) {
    for v in &mut x.data {
        *v = v.tanh();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn conv_matches_naive() {
        let mut rng = Rng::new(3);
        for (h, w, ic, kh, kw, oc, s) in [
            (6, 6, 3, 3, 3, 4, 1),
            (8, 7, 2, 2, 3, 5, 2),
            (5, 5, 1, 5, 5, 1, 1),
        ] {
            let x = Tensor::randn(2, h, w, ic, &mut rng);
            let f = Filter::randn(kh, kw, ic, oc, &mut rng);
            let a = conv2d_valid(&x, &f, s);
            let b = conv2d_naive(&x, &f, s);
            assert!(a.allclose(&b, 1e-4), "mismatch {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn gemm_is_bit_exact_with_naive() {
        let mut rng = Rng::new(17);
        let x = Tensor::randn(2, 9, 13, 5, &mut rng);
        let f = Filter::randn(3, 2, 5, 7, &mut rng);
        for s in [1, 2] {
            let a = conv2d_gemm(&x, &f, s);
            let b = conv2d_naive(&x, &f, s);
            assert_eq!(a.max_abs_diff(&b), 0.0, "stride {s} not bit-exact");
        }
    }

    #[test]
    fn deconv_known_values() {
        // 1x1 input, 2x2 filter, stride 2: output is just the filter scaled.
        let x = Tensor::from_vec(1, 1, 1, 1, vec![3.0]);
        let f = Filter::from_vec(2, 2, 1, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let y = deconv2d(&x, &f, 2, 0, 0);
        assert_eq!(y.shape(), [1, 2, 2, 1]);
        assert_eq!(y.data, vec![3.0, 6.0, 9.0, 12.0]);
    }

    #[test]
    fn deconv_overlap_accumulates() {
        // 2x1 input, k=3 s=2: rows 2 overlaps (0*2+2 == 1*2+0).
        let x = Tensor::from_vec(1, 2, 1, 1, vec![1.0, 1.0]);
        let f = Filter::from_vec(3, 1, 1, 1, vec![1.0, 1.0, 1.0]);
        let y = deconv2d(&x, &f, 2, 0, 0);
        assert_eq!(y.shape(), [1, 5, 1, 1]);
        assert_eq!(y.data, vec![1.0, 1.0, 2.0, 1.0, 1.0]);
    }

    #[test]
    fn nzp_equals_deconv() {
        // deconv(x, w, s, p) == conv(zero_insert(x), rot180(w), pad k-1-p)
        let mut rng = Rng::new(9);
        for (i, k, s, p) in [(4, 4, 2, 1), (5, 3, 2, 1), (3, 5, 2, 2), (4, 2, 2, 0)] {
            let x = Tensor::randn(1, i, i, 3, &mut rng);
            let f = Filter::randn(k, k, 3, 2, &mut rng);
            let want = deconv2d(&x, &f, s, p, 0);
            let xd = zero_insert(&x, s);
            let got = conv2d(&xd, &f.rot180(), 1, k - 1 - p);
            assert!(got.allclose(&want, 1e-4));
        }
    }

    #[test]
    fn into_variants_reuse_dirty_buffers_bit_exactly() {
        let mut rng = Rng::new(21);
        let x = Tensor::randn(2, 7, 9, 4, &mut rng);
        let f = Filter::randn(3, 3, 4, 6, &mut rng);
        // start from a deliberately wrong-shaped, dirty buffer
        let mut out = Tensor::from_vec(1, 2, 2, 1, vec![9.0; 4]);
        conv2d_valid_into(&x, &f, 2, &mut out);
        let fresh = conv2d_valid(&x, &f, 2);
        assert_eq!(out.shape(), fresh.shape());
        assert_eq!(out.max_abs_diff(&fresh), 0.0);

        let w: Vec<f32> = (0..x.h * x.w * x.c * 5).map(|_| rng.normal()).collect();
        let mut dout = Tensor::from_vec(1, 1, 1, 3, vec![7.0; 3]);
        dense_into(&x, &w, 5, &mut dout).unwrap();
        let dfresh = dense(&x, &w, 5).unwrap();
        assert_eq!(dout.shape(), dfresh.shape());
        assert_eq!(dout.max_abs_diff(&dfresh), 0.0);
    }

    #[test]
    fn dense_matches_manual() {
        let x = Tensor::from_vec(1, 1, 2, 1, vec![2.0, 3.0]);
        let w = vec![1.0, 10.0, 100.0, 1000.0]; // 2x2
        let y = dense(&x, &w, 2).unwrap();
        assert_eq!(y.data, vec![2.0 + 300.0, 20.0 + 3000.0]);
    }

    #[test]
    fn dense_weight_length_mismatch_is_an_error_not_a_panic() {
        // regression: this used to be a slice-index panic (pre-PR-2 style);
        // it must flow as anyhow::Error like the rest of the kernel sweep
        let x = Tensor::from_vec(1, 1, 2, 1, vec![2.0, 3.0]);
        let short = vec![1.0, 10.0, 100.0]; // needs 2x2 = 4
        assert!(dense(&x, &short, 2).is_err());
        let mut out = Tensor::zeros(0, 0, 0, 0);
        assert!(dense_into(&x, &short, 2, &mut out).is_err());
        // and a correct call after the failed one still works
        let w = vec![1.0, 10.0, 100.0, 1000.0];
        assert!(dense_into(&x, &w, 2, &mut out).is_ok());
        assert_eq!(out.data, vec![302.0, 3020.0]);
    }

    #[test]
    fn activations() {
        let mut x = Tensor::from_vec(1, 1, 1, 3, vec![-1.0, 0.5, 2.0]);
        relu(&mut x);
        assert_eq!(x.data, vec![0.0, 0.5, 2.0]);
        tanh(&mut x);
        assert!((x.data[2] - 2.0f32.tanh()).abs() < 1e-6);
    }
}
