//! Convolution / deconvolution ops over [`Tensor`] / [`Filter`].
//!
//! `conv2d` is the hot path: every deconvolution implementation (SD, NZP,
//! Shi, Chang) lowers to it, the quality evaluation (Table 4, Figs 13/14)
//! runs entire generators through it, and the coordinator's CPU-native
//! executor serves batched traffic on it. The core is [`conv2d_gemm`]:
//! im2col packing into a per-thread scratch panel followed by the
//! microkernel GEMM of [`super::gemm`] (packed-B panels, runtime
//! AVX2/FMA dispatch with a scalar oracle fallback), parallelized over
//! batch x output-row tiles drained from a lock-free atomic cursor by the
//! persistent worker pool (`runtime::pool`). Dense layers run the same
//! GEMM over the batch axis ([`dense_into`] / [`dense_packed_into`]).
//!
//! The scalar reference convolution is retained as [`conv2d_naive`]: the
//! scalar GEMM backend is bit-exact with it (identical per-element
//! operation sequence), and the SIMD backend matches it to the documented
//! ULP bound — see the numerics policy in [`super::gemm`] and DESIGN.md
//! §10. Results are bit-identical for any `SD_CONV_THREADS` and any tile
//! schedule. See EXPERIMENTS.md #Perf for measurements and `cargo bench
//! --bench hotpath` for GFLOP/s on the paper's DCGAN/FST layer shapes.

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::gemm::{self, PackedB, SendPtr};
use super::{Filter, Tensor};

/// Standard cross-correlation convolution (stride, symmetric zero padding).
pub fn conv2d(x: &Tensor, f: &Filter, stride: usize, padding: usize) -> Tensor {
    assert_eq!(x.c, f.ic, "channel mismatch");
    let xp;
    let x = if padding > 0 {
        xp = x.pad(padding, padding, padding, padding);
        &xp
    } else {
        x
    };
    conv2d_valid(x, f, stride)
}

/// Valid convolution — the hot path. Dispatches to the im2col + GEMM kernel
/// ([`conv2d_gemm`]).
pub fn conv2d_valid(x: &Tensor, f: &Filter, stride: usize) -> Tensor {
    conv2d_gemm(x, f, stride)
}

/// [`conv2d_valid`] writing into a caller-provided tensor (reshaped and
/// resized in place) — the arena-backed entry point. Results are
/// bit-identical to [`conv2d_valid`]: same packing, same micro-kernel, same
/// accumulation order; only the output buffer's provenance differs.
pub fn conv2d_valid_into(x: &Tensor, f: &Filter, stride: usize, out: &mut Tensor) {
    conv2d_gemm_into(x, f, stride, out)
}

/// Scalar reference convolution: the numerics oracle for the GEMM kernel
/// (bit-exact vs the scalar backend, ULP-bounded vs the SIMD backend —
/// property-tested in rust/tests/conv_gemm.rs and
/// rust/tests/gemm_numerics.rs) and the baseline the hotpath bench reports
/// speedup over. Deliberately the plain 7-deep loop.
pub fn conv2d_naive(x: &Tensor, f: &Filter, stride: usize) -> Tensor {
    assert_eq!(x.c, f.ic, "channel mismatch");
    assert!(x.h >= f.kh && x.w >= f.kw, "filter larger than input");
    let oh = (x.h - f.kh) / stride + 1;
    let ow = (x.w - f.kw) / stride + 1;
    let mut out = Tensor::zeros(x.n, oh, ow, f.oc);
    for n in 0..x.n {
        for oy in 0..oh {
            for ox in 0..ow {
                for o in 0..f.oc {
                    let mut acc = 0.0;
                    for dy in 0..f.kh {
                        for dx in 0..f.kw {
                            for i in 0..x.c {
                                acc += x.at(n, oy * stride + dy, ox * stride + dx, i)
                                    * f.at(dy, dx, i, o);
                            }
                        }
                    }
                    *out.at_mut(n, oy, ox, o) = acc;
                }
            }
        }
    }
    out
}

/// Per-thread im2col scratch target: keep one tile's panel ~L2-resident.
/// Shared with the int8 kernel (`quant::gemm`), which fits 4x the rows in
/// the same budget (i8 elements).
pub(crate) const PANEL_BYTES: usize = 256 * 1024;

/// MAC count below which threading overhead outweighs the parallel win.
const PARALLEL_MIN_MACS: usize = 1 << 21;

/// Column-panel chunk per dense-GEMM work item (x [`gemm::NR`] columns).
const DENSE_PANEL_CHUNK: usize = 8;

/// Test/bench override of the worker policy (0 = none). Results are
/// thread-count-invariant by construction, so flipping this concurrently
/// can change only scheduling, never bits — which is exactly what the
/// determinism suite uses it to prove.
static WORKER_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Override the worker-count policy (`None` restores the
/// `SD_CONV_THREADS` / available-parallelism default). Process-global;
/// used by the determinism tests and the hotpath bench.
pub fn set_worker_override(workers: Option<usize>) {
    WORKER_OVERRIDE.store(workers.unwrap_or(0), Ordering::Relaxed);
}

/// Worker-pool width: 1 for small problems, else the override hook, else
/// `SD_CONV_THREADS`, else the machine's available parallelism — always
/// capped by the tile count. ONE policy for the f32 and int8 kernels and
/// every caller above them (engine, coordinator workers), so f32-vs-int8
/// benches compare kernels, not thread policies.
pub(crate) fn worker_count(macs: usize, tiles: usize) -> usize {
    if tiles <= 1 || macs < PARALLEL_MIN_MACS {
        return 1;
    }
    let forced = WORKER_OVERRIDE.load(Ordering::Relaxed);
    if forced > 0 {
        return forced.clamp(1, tiles);
    }
    std::env::var("SD_CONV_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        })
        .clamp(1, tiles)
}

/// Batch x output-row tiling of a convolution output: tiles sized so one
/// im2col panel stays ~L2-resident. Tile `t` covers rows
/// `[y0(t), y0(t)+rows(t))` of image `t / per_image`. Shared by the f32
/// and int8 drivers so the two kernels parallelize identically.
#[derive(Clone, Copy)]
pub(crate) struct TileMap {
    pub rows_per_tile: usize,
    pub per_image: usize,
    pub tiles: usize,
    oh: usize,
}

impl TileMap {
    /// `elem_bytes` is the im2col element size (4 for f32, 1 for i8).
    pub fn new(n: usize, oh: usize, ow: usize, kdim: usize, elem_bytes: usize) -> TileMap {
        let rows_per_tile = (PANEL_BYTES / (ow * kdim * elem_bytes).max(1)).clamp(1, oh);
        let per_image = oh.div_ceil(rows_per_tile);
        TileMap { rows_per_tile, per_image, tiles: n * per_image, oh }
    }

    /// (image, first output row, row count) of tile `t`.
    #[inline]
    pub fn tile(&self, t: usize) -> (usize, usize, usize) {
        let img = t / self.per_image;
        let y0 = (t % self.per_image) * self.rows_per_tile;
        (img, y0, self.rows_per_tile.min(self.oh - y0))
    }
}

/// Valid convolution as im2col + packed-panel microkernel GEMM over the
/// persistent worker pool.
///
/// The filter's HWIO layout is the `K x N` GEMM operand (`K = kh*kw*ic`
/// contiguous rows of `N = oc`); it is packed into NR-wide column panels —
/// here, at call time, into a reused thread-local (the engine pre-packs at
/// `Program` compile time and enters below this, at
/// [`conv2d_packed_valid_into`]). Activations are im2col-packed per tile:
/// each output pixel's receptive field is `kh` contiguous `kw*ic`-float
/// row segments. Work is split into batch x output-row tiles drained from
/// an atomic cursor by `worker_count` threads (`SD_CONV_THREADS`
/// overrides); every output element accumulates in ascending-k order with
/// a single accumulator, so results are bit-identical for any thread
/// count — see the numerics policy in [`super::gemm`].
pub fn conv2d_gemm(x: &Tensor, f: &Filter, stride: usize) -> Tensor {
    let mut out = Tensor::zeros(0, 0, 0, 0);
    conv2d_gemm_into(x, f, stride, &mut out);
    out
}

thread_local! {
    /// Call-time weight packing slot of the non-engine conv paths, reused
    /// across calls on each thread.
    static PACK_SLOT: RefCell<PackedB> = RefCell::new(PackedB::empty());

    /// Per-thread im2col panel, persistent across conv calls and pool
    /// jobs — the ~L2-sized scratch would otherwise be reallocated by
    /// every worker on every conv call, exactly the per-call overhead the
    /// persistent pool exists to remove.
    static PANEL_SCRATCH: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

/// [`conv2d_gemm`] into a caller-provided tensor: `out` is reshaped to the
/// convolution output shape and its buffer resized (reusing capacity);
/// every element is overwritten.
pub fn conv2d_gemm_into(x: &Tensor, f: &Filter, stride: usize, out: &mut Tensor) {
    assert_eq!(x.c, f.ic, "channel mismatch");
    let kdim = f.kh * f.kw * f.ic;
    PACK_SLOT.with(|slot| {
        let mut packed = slot.borrow_mut();
        packed.pack_into(&f.data, kdim, f.oc);
        conv2d_packed_valid_into(x, f.kh, f.kw, stride, &packed, out);
    });
}

/// Valid convolution against a **pre-packed** weight operand — the
/// engine's entry point, where every conv / SD-split filter is packed once
/// at `Program` compile time. `packed` must be the [`PackedB::pack`] of a
/// `kh x kw x x.c x oc` filter's HWIO payload. Bit-identical to
/// [`conv2d_valid`] with the unpacked filter.
pub fn conv2d_packed_valid_into(
    x: &Tensor,
    kh: usize,
    kw: usize,
    stride: usize,
    packed: &PackedB,
    out: &mut Tensor,
) {
    assert!(x.h >= kh && x.w >= kw, "filter larger than input");
    let kdim = kh * kw * x.c;
    assert_eq!(packed.k, kdim, "packed weight k mismatch");
    let oh = (x.h - kh) / stride + 1;
    let ow = (x.w - kw) / stride + 1;
    let n_out = packed.n;
    out.n = x.n;
    out.h = oh;
    out.w = ow;
    out.c = n_out;
    // no clear(): resize only zero-fills a grown tail, and every element
    // is overwritten by exactly one tile below — the old full zero-fill
    // wrote the whole buffer twice
    out.data.resize(x.n * oh * ow * n_out, 0.0);
    if out.data.is_empty() {
        return;
    }

    let map = TileMap::new(x.n, oh, ow, kdim, std::mem::size_of::<f32>());
    let macs = x.n * oh * ow * kdim * n_out;
    let workers = worker_count(macs, map.tiles);
    let backend = gemm::active_backend();
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    gemm::parallel_drain(workers, &|cursor| {
        // per-thread persistent im2col scratch (tile tasks never re-enter
        // a conv kernel, so the borrow cannot conflict)
        PANEL_SCRATCH.with(|slot| {
            let mut panel = slot.borrow_mut();
            loop {
                let t = cursor.fetch_add(1, Ordering::Relaxed);
                if t >= map.tiles {
                    break;
                }
                let (img, y0, rows) = map.tile(t);
                let m = rows * ow;
                pack_im2col(x, kh, kw, stride, img, y0, rows, ow, &mut panel);
                // SAFETY: tile t was claimed by exactly one fetch_add
                // winner; its m x n_out output block starts at row
                // (img*oh + y0)*ow and is disjoint from every other
                // tile's block. The pool barrier keeps `out` alive and
                // unread until all tiles finish.
                unsafe {
                    let c = out_ptr.get().add((img * oh + y0) * ow * n_out);
                    gemm::gemm_panels_raw(backend, &panel, packed, m, c, 0, packed.panels());
                }
            }
        });
    });
}

/// Pack one row tile's im2col panel into `panel` (resized, capacity
/// reused; no zero-fill — the loop overwrites every element: kh segments
/// of kw*ic per pixel cover the full kdim).
#[allow(clippy::too_many_arguments)] // internal tile runner
fn pack_im2col(
    x: &Tensor,
    kh: usize,
    kw: usize,
    stride: usize,
    img: usize,
    y0: usize,
    rows: usize,
    ow: usize,
    panel: &mut Vec<f32>,
) {
    let kdim = kh * kw * x.c;
    let seg = kw * x.c; // one contiguous input-row segment per kernel row
    panel.resize(rows * ow * kdim, 0.0);
    for r in 0..rows {
        let oy = y0 + r;
        for ox in 0..ow {
            let dst_base = (r * ow + ox) * kdim;
            for dy in 0..kh {
                let src = x.idx(img, oy * stride + dy, ox * stride, 0);
                let dst = dst_base + dy * seg;
                panel[dst..dst + seg].copy_from_slice(&x.data[src..src + seg]);
            }
        }
    }
}

/// Transposed convolution (scatter semantics, torch ConvTranspose2d),
/// with layer padding `p` and output padding `op`:
/// out side = (i-1)\*s + k - 2p + op.
pub fn deconv2d(x: &Tensor, f: &Filter, stride: usize, padding: usize, out_pad: usize) -> Tensor {
    let full_h = (x.h - 1) * stride + f.kh;
    let full_w = (x.w - 1) * stride + f.kw;
    let mut full = Tensor::zeros(x.n, full_h, full_w, f.oc);
    let oc = f.oc;
    for n in 0..x.n {
        for iy in 0..x.h {
            for ix in 0..x.w {
                let xbase = x.idx(n, iy, ix, 0);
                for dy in 0..f.kh {
                    for dx in 0..f.kw {
                        let obase = full.idx(n, iy * stride + dy, ix * stride + dx, 0);
                        let wbase = f.idx(dy, dx, 0, 0);
                        let acc = &mut full.data[obase..obase + oc];
                        for ic in 0..x.c {
                            let xv = x.data[xbase + ic];
                            if xv == 0.0 {
                                continue;
                            }
                            let ws = &f.data[wbase + ic * oc..wbase + ic * oc + oc];
                            for (a, &w) in acc.iter_mut().zip(ws) {
                                *a += xv * w;
                            }
                        }
                    }
                }
            }
        }
    }
    let out_h = full_h - 2 * padding + out_pad;
    let out_w = full_w - 2 * padding + out_pad;
    full.crop_padded(padding, out_h, padding, out_w)
}

/// Insert (stride-1) zeros between activations (NZP dilation step).
pub fn zero_insert(x: &Tensor, stride: usize) -> Tensor {
    if stride == 1 {
        return x.clone();
    }
    let mut out = Tensor::zeros(x.n, (x.h - 1) * stride + 1, (x.w - 1) * stride + 1, x.c);
    for n in 0..x.n {
        for h in 0..x.h {
            for w in 0..x.w {
                let src = x.idx(n, h, w, 0);
                let dst = out.idx(n, h * stride, w * stride, 0);
                out.data[dst..dst + x.c].copy_from_slice(&x.data[src..src + x.c]);
            }
        }
    }
    out
}

/// Dense (fully-connected) layer: x viewed as (N, H\*W\*C) @ w (in x out),
/// on the same packed-panel GEMM as the conv path (batch on the M axis).
/// A weight buffer whose length disagrees with `n_in * n_out` is an error
/// (not a panic — the serving stack routes it through the coordinator's
/// failed-batch path).
pub fn dense(x: &Tensor, w: &[f32], n_out: usize) -> anyhow::Result<Tensor> {
    let mut out = Tensor::zeros(0, 0, 0, 0);
    dense_into(x, w, n_out, &mut out)?;
    Ok(out)
}

thread_local! {
    /// Call-time dense weight packing slot, reused across calls on each
    /// thread — the interpreter oracle runs whole-matrix dense layers per
    /// forward (GP-GAN's bottleneck is ~131 MB), so a fresh allocation
    /// per call would dominate the oracle's runtime.
    static DENSE_PACK_SLOT: RefCell<PackedB> = RefCell::new(PackedB::empty());
}

/// [`dense`] into a caller-provided tensor (reshaped, resized in place,
/// reusing capacity). Packs the weight matrix per call (reused
/// thread-local); the engine packs once at compile time and calls
/// [`dense_packed_into`].
pub fn dense_into(x: &Tensor, w: &[f32], n_out: usize, out: &mut Tensor) -> anyhow::Result<()> {
    let n_in = x.h * x.w * x.c;
    if w.len() != n_in * n_out {
        anyhow::bail!(
            "dense weight length {} != n_in {} x n_out {}",
            w.len(),
            n_in,
            n_out
        );
    }
    DENSE_PACK_SLOT.with(|slot| {
        let mut packed = slot.borrow_mut();
        packed.pack_into(w, n_in, n_out);
        dense_packed_into(x, &packed, out)
    })
}

/// [`dense_into`] against a **pre-packed** weight matrix — the engine's
/// dense entry point. The GEMM is parallelized over column-panel chunks
/// (disjoint output columns), so wide bottleneck layers (GP-GAN's
/// 8192 x 4000) use the same worker pool as the conv path; per-element
/// accumulation order is panel-local and therefore identical for any
/// worker count.
pub fn dense_packed_into(x: &Tensor, packed: &PackedB, out: &mut Tensor) -> anyhow::Result<()> {
    let n_in = x.h * x.w * x.c;
    if packed.k != n_in {
        anyhow::bail!(
            "dense packed weight expects {} input elements, input has {}",
            packed.k,
            n_in
        );
    }
    let n_out = packed.n;
    out.n = x.n;
    out.h = 1;
    out.w = 1;
    out.c = n_out;
    // no clear(): every element is written by exactly one panel chunk
    out.data.resize(x.n * n_out, 0.0);
    if out.data.is_empty() {
        return Ok(());
    }
    let m = x.n;
    let panels = packed.panels();
    let chunks = panels.div_ceil(DENSE_PANEL_CHUNK);
    let workers = worker_count(m * n_in * n_out, chunks);
    let backend = gemm::active_backend();
    let out_ptr = SendPtr(out.data.as_mut_ptr());
    let a = &x.data;
    gemm::parallel_drain(workers, &|cursor| loop {
        let t = cursor.fetch_add(1, Ordering::Relaxed);
        if t >= chunks {
            break;
        }
        let p_lo = t * DENSE_PANEL_CHUNK;
        let p_hi = (p_lo + DENSE_PANEL_CHUNK).min(panels);
        // SAFETY: chunk t was claimed by exactly one fetch_add winner, and
        // panel ranges write disjoint column sets of the shared output;
        // the pool barrier keeps `out` alive until all chunks finish.
        unsafe { gemm::gemm_panels_raw(backend, a, packed, m, out_ptr.get(), p_lo, p_hi) };
    });
    Ok(())
}

/// In-place ReLU.
pub fn relu(x: &mut Tensor) {
    for v in &mut x.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place tanh.
pub fn tanh(x: &mut Tensor) {
    for v in &mut x.data {
        *v = v.tanh();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn conv_matches_naive() {
        let mut rng = Rng::new(3);
        for (h, w, ic, kh, kw, oc, s) in [
            (6, 6, 3, 3, 3, 4, 1),
            (8, 7, 2, 2, 3, 5, 2),
            (5, 5, 1, 5, 5, 1, 1),
        ] {
            let x = Tensor::randn(2, h, w, ic, &mut rng);
            let f = Filter::randn(kh, kw, ic, oc, &mut rng);
            let a = conv2d_valid(&x, &f, s);
            let b = conv2d_naive(&x, &f, s);
            assert!(a.allclose(&b, 1e-4), "mismatch {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn gemm_tracks_naive_within_numerics_policy() {
        // scalar backend: bit-exact with the 7-loop oracle; SIMD backend:
        // rounding-close (the per-element f64-referenced ULP/forward-bound
        // sweeps live in rust/tests/conv_gemm.rs and
        // rust/tests/gemm_numerics.rs)
        let mut rng = Rng::new(17);
        let x = Tensor::randn(2, 9, 13, 5, &mut rng);
        let f = Filter::randn(3, 2, 5, 7, &mut rng);
        for s in [1, 2] {
            let a = conv2d_gemm(&x, &f, s);
            let b = conv2d_naive(&x, &f, s);
            assert_eq!(a.shape(), b.shape());
            match gemm::active_backend() {
                gemm::GemmBackend::Scalar => {
                    assert_eq!(a.max_abs_diff(&b), 0.0, "stride {s} not bit-exact")
                }
                gemm::GemmBackend::Avx2 => {
                    assert!(a.allclose(&b, 1e-4), "stride {s}: {}", a.max_abs_diff(&b))
                }
            }
        }
    }

    #[test]
    fn packed_conv_entry_matches_unpacked() {
        // the engine's pre-packed path must be bit-identical to the
        // call-time-packing path (same panels, same kernel)
        let mut rng = Rng::new(29);
        let x = Tensor::randn(2, 10, 11, 6, &mut rng);
        let f = Filter::randn(3, 3, 6, 21, &mut rng); // non-multiple-of-NR oc
        let packed = crate::tensor::gemm::PackedB::pack(&f.data, 3 * 3 * 6, 21);
        let mut got = Tensor::zeros(0, 0, 0, 0);
        conv2d_packed_valid_into(&x, 3, 3, 2, &packed, &mut got);
        let want = conv2d_valid(&x, &f, 2);
        assert_eq!(got.shape(), want.shape());
        assert_eq!(got.max_abs_diff(&want), 0.0);
    }

    #[test]
    fn deconv_known_values() {
        // 1x1 input, 2x2 filter, stride 2: output is just the filter scaled.
        let x = Tensor::from_vec(1, 1, 1, 1, vec![3.0]);
        let f = Filter::from_vec(2, 2, 1, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let y = deconv2d(&x, &f, 2, 0, 0);
        assert_eq!(y.shape(), [1, 2, 2, 1]);
        assert_eq!(y.data, vec![3.0, 6.0, 9.0, 12.0]);
    }

    #[test]
    fn deconv_overlap_accumulates() {
        // 2x1 input, k=3 s=2: rows 2 overlaps (0*2+2 == 1*2+0).
        let x = Tensor::from_vec(1, 2, 1, 1, vec![1.0, 1.0]);
        let f = Filter::from_vec(3, 1, 1, 1, vec![1.0, 1.0, 1.0]);
        let y = deconv2d(&x, &f, 2, 0, 0);
        assert_eq!(y.shape(), [1, 5, 1, 1]);
        assert_eq!(y.data, vec![1.0, 1.0, 2.0, 1.0, 1.0]);
    }

    #[test]
    fn nzp_equals_deconv() {
        // deconv(x, w, s, p) == conv(zero_insert(x), rot180(w), pad k-1-p)
        let mut rng = Rng::new(9);
        for (i, k, s, p) in [(4, 4, 2, 1), (5, 3, 2, 1), (3, 5, 2, 2), (4, 2, 2, 0)] {
            let x = Tensor::randn(1, i, i, 3, &mut rng);
            let f = Filter::randn(k, k, 3, 2, &mut rng);
            let want = deconv2d(&x, &f, s, p, 0);
            let xd = zero_insert(&x, s);
            let got = conv2d(&xd, &f.rot180(), 1, k - 1 - p);
            assert!(got.allclose(&want, 1e-4));
        }
    }

    #[test]
    fn into_variants_reuse_dirty_buffers_bit_exactly() {
        let mut rng = Rng::new(21);
        let x = Tensor::randn(2, 7, 9, 4, &mut rng);
        let f = Filter::randn(3, 3, 4, 6, &mut rng);
        // start from a deliberately wrong-shaped, dirty buffer
        let mut out = Tensor::from_vec(1, 2, 2, 1, vec![9.0; 4]);
        conv2d_valid_into(&x, &f, 2, &mut out);
        let fresh = conv2d_valid(&x, &f, 2);
        assert_eq!(out.shape(), fresh.shape());
        assert_eq!(out.max_abs_diff(&fresh), 0.0);

        let w: Vec<f32> = (0..x.h * x.w * x.c * 5).map(|_| rng.normal()).collect();
        let mut dout = Tensor::from_vec(1, 1, 1, 3, vec![7.0; 3]);
        dense_into(&x, &w, 5, &mut dout).unwrap();
        let dfresh = dense(&x, &w, 5).unwrap();
        assert_eq!(dout.shape(), dfresh.shape());
        assert_eq!(dout.max_abs_diff(&dfresh), 0.0);
    }

    #[test]
    fn dense_matches_manual() {
        let x = Tensor::from_vec(1, 1, 2, 1, vec![2.0, 3.0]);
        let w = vec![1.0, 10.0, 100.0, 1000.0]; // 2x2
        let y = dense(&x, &w, 2).unwrap();
        assert_eq!(y.data, vec![2.0 + 300.0, 20.0 + 3000.0]);
    }

    #[test]
    fn dense_packed_matches_per_call_packing_on_wide_output() {
        // wide enough to span many panels and a partial tail panel
        let mut rng = Rng::new(33);
        let x = Tensor::randn(3, 1, 1, 40, &mut rng);
        let n_out = 7 * crate::tensor::gemm::NR + 5;
        let w: Vec<f32> = (0..40 * n_out).map(|_| rng.normal()).collect();
        let packed = crate::tensor::gemm::PackedB::pack(&w, 40, n_out);
        let mut a = Tensor::zeros(0, 0, 0, 0);
        dense_packed_into(&x, &packed, &mut a).unwrap();
        let b = dense(&x, &w, n_out).unwrap();
        assert_eq!(a.shape(), b.shape());
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn dense_weight_length_mismatch_is_an_error_not_a_panic() {
        // regression: this used to be a slice-index panic (pre-PR-2 style);
        // it must flow as anyhow::Error like the rest of the kernel sweep
        let x = Tensor::from_vec(1, 1, 2, 1, vec![2.0, 3.0]);
        let short = vec![1.0, 10.0, 100.0]; // needs 2x2 = 4
        assert!(dense(&x, &short, 2).is_err());
        let mut out = Tensor::zeros(0, 0, 0, 0);
        assert!(dense_into(&x, &short, 2, &mut out).is_err());
        // and a correct call after the failed one still works
        let w = vec![1.0, 10.0, 100.0, 1000.0];
        assert!(dense_into(&x, &w, 2, &mut out).is_ok());
        assert_eq!(out.data, vec![302.0, 3020.0]);
    }

    #[test]
    fn worker_override_forces_width_without_changing_bits() {
        let mut rng = Rng::new(44);
        // large enough to clear PARALLEL_MIN_MACS
        let x = Tensor::randn(1, 40, 40, 32, &mut rng);
        let f = Filter::randn(3, 3, 32, 64, &mut rng);
        set_worker_override(Some(1));
        let one = conv2d_gemm(&x, &f, 1);
        set_worker_override(Some(7));
        let seven = conv2d_gemm(&x, &f, 1);
        set_worker_override(None);
        assert_eq!(one.max_abs_diff(&seven), 0.0, "worker width changed bits");
    }

    #[test]
    fn activations() {
        let mut x = Tensor::from_vec(1, 1, 1, 3, vec![-1.0, 0.5, 2.0]);
        relu(&mut x);
        assert_eq!(x.data, vec![0.0, 0.5, 2.0]);
        tanh(&mut x);
        assert!((x.data[2] - 2.0f32.tanh()).abs() < 1e-6);
    }
}
