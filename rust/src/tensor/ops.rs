//! Convolution / deconvolution ops over [`Tensor`] / [`Filter`].
//!
//! `conv2d` is the hot path: every deconvolution implementation (SD, NZP,
//! Shi, Chang) lowers to it, and the quality evaluation (Table 4, Figs 13/14)
//! runs entire generators through it. The inner loop is written as a
//! channels-last dot/axpy over contiguous slices so the compiler
//! auto-vectorizes it; see EXPERIMENTS.md #Perf for measurements.

use super::{Filter, Tensor};

/// Standard cross-correlation convolution (stride, symmetric zero padding).
pub fn conv2d(x: &Tensor, f: &Filter, stride: usize, padding: usize) -> Tensor {
    assert_eq!(x.c, f.ic, "channel mismatch");
    let xp;
    let x = if padding > 0 {
        xp = x.pad(padding, padding, padding, padding);
        &xp
    } else {
        x
    };
    conv2d_valid(x, f, stride)
}

/// Valid convolution, the vectorized core.
///
/// Accumulates output-channel vectors: for each (output pixel, tap, ic) the
/// contribution `x * w[., oc]` is an axpy over the contiguous OC axis.
pub fn conv2d_valid(x: &Tensor, f: &Filter, stride: usize) -> Tensor {
    assert!(x.h >= f.kh && x.w >= f.kw, "filter larger than input");
    let oh = (x.h - f.kh) / stride + 1;
    let ow = (x.w - f.kw) / stride + 1;
    let mut out = Tensor::zeros(x.n, oh, ow, f.oc);
    let oc = f.oc;
    for n in 0..x.n {
        for oy in 0..oh {
            for ox in 0..ow {
                let obase = out.idx(n, oy, ox, 0);
                let acc = &mut out.data[obase..obase + oc];
                for dy in 0..f.kh {
                    let iy = oy * stride + dy;
                    for dx in 0..f.kw {
                        let ixb = x.idx(n, iy, ox * stride + dx, 0);
                        let xs = &x.data[ixb..ixb + x.c];
                        let wbase = f.idx(dy, dx, 0, 0);
                        for (ic, &xv) in xs.iter().enumerate() {
                            if xv == 0.0 {
                                continue; // free win; also models zero-skip
                            }
                            let ws = &f.data[wbase + ic * oc..wbase + ic * oc + oc];
                            for (a, &w) in acc.iter_mut().zip(ws) {
                                *a += xv * w;
                            }
                        }
                    }
                }
            }
        }
    }
    out
}

/// Transposed convolution (scatter semantics, torch ConvTranspose2d),
/// with layer padding `p` and output padding `op`:
/// out side = (i-1)*s + k - 2p + op.
pub fn deconv2d(x: &Tensor, f: &Filter, stride: usize, padding: usize, out_pad: usize) -> Tensor {
    let full_h = (x.h - 1) * stride + f.kh;
    let full_w = (x.w - 1) * stride + f.kw;
    let mut full = Tensor::zeros(x.n, full_h, full_w, f.oc);
    let oc = f.oc;
    for n in 0..x.n {
        for iy in 0..x.h {
            for ix in 0..x.w {
                let xbase = x.idx(n, iy, ix, 0);
                for dy in 0..f.kh {
                    for dx in 0..f.kw {
                        let obase = full.idx(n, iy * stride + dy, ix * stride + dx, 0);
                        let wbase = f.idx(dy, dx, 0, 0);
                        let acc = &mut full.data[obase..obase + oc];
                        for ic in 0..x.c {
                            let xv = x.data[xbase + ic];
                            if xv == 0.0 {
                                continue;
                            }
                            let ws = &f.data[wbase + ic * oc..wbase + ic * oc + oc];
                            for (a, &w) in acc.iter_mut().zip(ws) {
                                *a += xv * w;
                            }
                        }
                    }
                }
            }
        }
    }
    let out_h = full_h - 2 * padding + out_pad;
    let out_w = full_w - 2 * padding + out_pad;
    full.crop_padded(padding, out_h, padding, out_w)
}

/// Insert (stride-1) zeros between activations (NZP dilation step).
pub fn zero_insert(x: &Tensor, stride: usize) -> Tensor {
    if stride == 1 {
        return x.clone();
    }
    let mut out = Tensor::zeros(x.n, (x.h - 1) * stride + 1, (x.w - 1) * stride + 1, x.c);
    for n in 0..x.n {
        for h in 0..x.h {
            for w in 0..x.w {
                let src = x.idx(n, h, w, 0);
                let dst = out.idx(n, h * stride, w * stride, 0);
                out.data[dst..dst + x.c].copy_from_slice(&x.data[src..src + x.c]);
            }
        }
    }
    out
}

/// Dense (fully-connected) layer: x viewed as (N, H*W*C) @ w (in x out).
pub fn dense(x: &Tensor, w: &[f32], n_out: usize) -> Tensor {
    let n_in = x.h * x.w * x.c;
    assert_eq!(w.len(), n_in * n_out, "dense weight size");
    let mut out = Tensor::zeros(x.n, 1, 1, n_out);
    for n in 0..x.n {
        let xrow = &x.data[n * n_in..(n + 1) * n_in];
        let orow_base = n * n_out;
        for (i, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[i * n_out..(i + 1) * n_out];
            let orow = &mut out.data[orow_base..orow_base + n_out];
            for (o, &wv) in orow.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
    }
    out
}

/// In-place ReLU.
pub fn relu(x: &mut Tensor) {
    for v in &mut x.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// In-place tanh.
pub fn tanh(x: &mut Tensor) {
    for v in &mut x.data {
        *v = v.tanh();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Scalar-loop conv for cross-checking the vectorized one.
    fn conv2d_naive(x: &Tensor, f: &Filter, stride: usize) -> Tensor {
        let oh = (x.h - f.kh) / stride + 1;
        let ow = (x.w - f.kw) / stride + 1;
        let mut out = Tensor::zeros(x.n, oh, ow, f.oc);
        for n in 0..x.n {
            for oy in 0..oh {
                for ox in 0..ow {
                    for o in 0..f.oc {
                        let mut acc = 0.0;
                        for dy in 0..f.kh {
                            for dx in 0..f.kw {
                                for i in 0..x.c {
                                    acc += x.at(n, oy * stride + dy, ox * stride + dx, i)
                                        * f.at(dy, dx, i, o);
                                }
                            }
                        }
                        *out.at_mut(n, oy, ox, o) = acc;
                    }
                }
            }
        }
        out
    }

    #[test]
    fn conv_matches_naive() {
        let mut rng = Rng::new(3);
        for (h, w, ic, kh, kw, oc, s) in [
            (6, 6, 3, 3, 3, 4, 1),
            (8, 7, 2, 2, 3, 5, 2),
            (5, 5, 1, 5, 5, 1, 1),
        ] {
            let x = Tensor::randn(2, h, w, ic, &mut rng);
            let f = Filter::randn(kh, kw, ic, oc, &mut rng);
            let a = conv2d_valid(&x, &f, s);
            let b = conv2d_naive(&x, &f, s);
            assert!(a.allclose(&b, 1e-4), "mismatch {}", a.max_abs_diff(&b));
        }
    }

    #[test]
    fn deconv_known_values() {
        // 1x1 input, 2x2 filter, stride 2: output is just the filter scaled.
        let x = Tensor::from_vec(1, 1, 1, 1, vec![3.0]);
        let f = Filter::from_vec(2, 2, 1, 1, vec![1.0, 2.0, 3.0, 4.0]);
        let y = deconv2d(&x, &f, 2, 0, 0);
        assert_eq!(y.shape(), [1, 2, 2, 1]);
        assert_eq!(y.data, vec![3.0, 6.0, 9.0, 12.0]);
    }

    #[test]
    fn deconv_overlap_accumulates() {
        // 2x1 input, k=3 s=2: rows 2 overlaps (0*2+2 == 1*2+0).
        let x = Tensor::from_vec(1, 2, 1, 1, vec![1.0, 1.0]);
        let f = Filter::from_vec(3, 1, 1, 1, vec![1.0, 1.0, 1.0]);
        let y = deconv2d(&x, &f, 2, 0, 0);
        assert_eq!(y.shape(), [1, 5, 1, 1]);
        assert_eq!(y.data, vec![1.0, 1.0, 2.0, 1.0, 1.0]);
    }

    #[test]
    fn nzp_equals_deconv() {
        // deconv(x, w, s, p) == conv(zero_insert(x), rot180(w), pad k-1-p)
        let mut rng = Rng::new(9);
        for (i, k, s, p) in [(4, 4, 2, 1), (5, 3, 2, 1), (3, 5, 2, 2), (4, 2, 2, 0)] {
            let x = Tensor::randn(1, i, i, 3, &mut rng);
            let f = Filter::randn(k, k, 3, 2, &mut rng);
            let want = deconv2d(&x, &f, s, p, 0);
            let xd = zero_insert(&x, s);
            let got = conv2d(&xd, &f.rot180(), 1, k - 1 - p);
            assert!(got.allclose(&want, 1e-4));
        }
    }

    #[test]
    fn dense_matches_manual() {
        let x = Tensor::from_vec(1, 1, 2, 1, vec![2.0, 3.0]);
        let w = vec![1.0, 10.0, 100.0, 1000.0]; // 2x2
        let y = dense(&x, &w, 2);
        assert_eq!(y.data, vec![2.0 + 300.0, 20.0 + 3000.0]);
    }

    #[test]
    fn activations() {
        let mut x = Tensor::from_vec(1, 1, 1, 3, vec![-1.0, 0.5, 2.0]);
        relu(&mut x);
        assert_eq!(x.data, vec![0.0, 0.5, 2.0]);
        tanh(&mut x);
        assert!((x.data[2] - 2.0f32.tanh()).abs() < 1e-6);
    }
}
