//! The six benchmark networks of the paper's evaluation (Table 1), with
//! layer configurations reverse-engineered so the published deconvolution
//! MAC / parameter counts are matched (exactly for DCGAN, SNGAN, GP-GAN,
//! ArtGAN-deconv, FST; within 3% for MDE — see EXPERIMENTS.md).
//!
//! These tables are mirrored in python/compile/model.py (the AOT side);
//! rust/tests/report_tables.rs asserts both the paper numbers and, via the
//! artifact manifest, consistency with the python copy.

use crate::nn::{LayerSpec, NetworkSpec};

fn d(
    name: &'static str,
    ih: usize,
    iw: usize,
    ic: usize,
    oc: usize,
    k: usize,
    s: usize,
    p: usize,
    op: usize,
) -> LayerSpec {
    LayerSpec::deconv(name, ih, iw, ic, oc, k, s, p, op)
}

fn c(
    name: &'static str,
    ih: usize,
    iw: usize,
    ic: usize,
    oc: usize,
    k: usize,
    s: usize,
    p: usize,
) -> LayerSpec {
    LayerSpec::conv(name, ih, iw, ic, oc, k, s, p)
}

/// DCGAN generator on CelebA, 64x64 output, 5x5 stride-2 deconvs
/// (the filter-expansion case: K_T=3, P_K=1).
pub fn dcgan() -> NetworkSpec {
    NetworkSpec {
        name: "DCGAN",
        layers: vec![
            LayerSpec::dense("project", 100, 8 * 8 * 256),
            d("deconv1", 8, 8, 256, 128, 5, 2, 2, 1),
            d("deconv2", 16, 16, 128, 64, 5, 2, 2, 1),
            d("deconv3", 32, 32, 64, 3, 5, 2, 2, 1),
        ],
    }
}

/// SNGAN generator on CIFAR-10, 32x32 output, 4x4 stride-2 deconvs
/// (divisible case: SD is overhead-free).
pub fn sngan() -> NetworkSpec {
    NetworkSpec {
        name: "SNGAN",
        layers: vec![
            d("deconv1", 4, 4, 512, 256, 4, 2, 1, 0),
            d("deconv2", 8, 8, 256, 128, 4, 2, 1, 0),
            d("deconv3", 16, 16, 128, 64, 4, 2, 1, 0),
            c("to_rgb", 32, 32, 64, 3, 1, 1, 0),
        ],
    }
}

/// ArtGAN on CIFAR-10: mixes stride-2 (k4) and stride-1 (k5) deconvs, which
/// reproduces the paper's 2.47x (not 4x) NZP blow-up.
pub fn artgan() -> NetworkSpec {
    NetworkSpec {
        name: "ArtGAN",
        layers: vec![
            LayerSpec::dense("project", 100, 4 * 4 * 1024),
            d("deconv1", 4, 4, 1024, 512, 4, 2, 1, 0),
            d("deconv2", 8, 8, 512, 256, 4, 2, 1, 0),
            d("deconv3", 16, 16, 256, 256, 5, 1, 2, 0),
            d("deconv4", 16, 16, 256, 128, 4, 2, 1, 0),
            c("conv1", 32, 32, 128, 128, 3, 1, 1),
            c("conv2", 32, 32, 128, 128, 3, 1, 1),
            c("conv3", 32, 32, 128, 64, 3, 1, 1),
            c("to_rgb", 32, 32, 64, 3, 3, 1, 1),
        ],
    }
}

/// GP-GAN blending auto-encoder, 64x64.
pub fn gpgan() -> NetworkSpec {
    NetworkSpec {
        name: "GP-GAN",
        layers: vec![
            c("enc1", 64, 64, 3, 64, 4, 2, 1),
            c("enc2", 32, 32, 64, 128, 4, 2, 1),
            c("enc3", 16, 16, 128, 256, 4, 2, 1),
            c("enc4", 8, 8, 256, 512, 4, 2, 1),
            LayerSpec::dense("bottleneck", 4 * 4 * 512, 4000),
            d("dec1", 4, 4, 512, 256, 4, 2, 1, 0),
            d("dec2", 8, 8, 256, 128, 4, 2, 1, 0),
            d("dec3", 16, 16, 128, 64, 4, 2, 1, 0),
            d("dec4", 32, 32, 64, 3, 4, 2, 1, 0),
        ],
    }
}

/// Monocular Depth Estimation (Godard et al.), KITTI 128x256 mode,
/// VGG encoder + k3 s2 upconv decoder (filter-expansion case K_T=2).
pub fn mde() -> NetworkSpec {
    NetworkSpec {
        name: "MDE",
        layers: vec![
            c("enc1a", 128, 256, 3, 32, 7, 2, 3),
            c("enc1b", 64, 128, 32, 32, 7, 1, 3),
            c("enc2a", 64, 128, 32, 64, 5, 2, 2),
            c("enc2b", 32, 64, 64, 64, 5, 1, 2),
            c("enc3a", 32, 64, 64, 128, 3, 2, 1),
            c("enc3b", 16, 32, 128, 128, 3, 1, 1),
            c("enc4a", 16, 32, 128, 256, 3, 2, 1),
            c("enc4b", 8, 16, 256, 256, 3, 1, 1),
            c("enc5a", 8, 16, 256, 512, 3, 2, 1),
            c("enc5b", 4, 8, 512, 512, 3, 1, 1),
            d("upconv6", 4, 8, 512, 512, 3, 2, 1, 1),
            c("iconv6", 8, 16, 512, 512, 3, 1, 1),
            d("upconv5", 8, 16, 512, 256, 3, 2, 1, 1),
            c("iconv5", 16, 32, 256, 256, 3, 1, 1),
            d("upconv4", 16, 32, 256, 128, 3, 2, 1, 1),
            c("iconv4", 32, 64, 128, 32, 3, 1, 1),
            d("upconv3", 32, 64, 128, 64, 3, 2, 1, 1),
            d("upconv2", 64, 128, 64, 32, 3, 2, 1, 1),
            d("upconv1", 128, 256, 32, 16, 3, 2, 1, 1),
            c("disp", 256, 512, 16, 1, 3, 1, 1),
        ],
    }
}

/// Fast-Style-Transfer transform net, 256x256 (Johnson/Engstrom).
pub fn fst() -> NetworkSpec {
    let mut layers = vec![
        c("conv1", 256, 256, 3, 32, 9, 1, 4),
        c("conv2", 256, 256, 32, 64, 3, 2, 1),
        c("conv3", 128, 128, 64, 128, 3, 2, 1),
    ];
    for i in 1..=5 {
        layers.push(c(
            Box::leak(format!("res{i}a").into_boxed_str()),
            64,
            64,
            128,
            128,
            3,
            1,
            1,
        ));
        layers.push(c(
            Box::leak(format!("res{i}b").into_boxed_str()),
            64,
            64,
            128,
            128,
            3,
            1,
            1,
        ));
    }
    layers.push(d("deconv1", 64, 64, 128, 64, 3, 2, 1, 1));
    layers.push(d("deconv2", 128, 128, 64, 32, 3, 2, 1, 1));
    layers.push(c("to_rgb", 256, 256, 32, 3, 9, 1, 4));
    NetworkSpec { name: "FST", layers }
}

/// All six benchmarks, Table-1 order.
pub fn all() -> Vec<NetworkSpec> {
    vec![dcgan(), artgan(), sngan(), gpgan(), mde(), fst()]
}

/// Canonical CLI slug for a network name: lowercase, `-`/`_` stripped
/// (`"GP-GAN"` -> `"gpgan"`). Artifact prefixes and routing keys should be
/// derived from this, never from a raw user spelling.
pub fn slug(name: &str) -> String {
    name.chars()
        .filter(|c| *c != '-' && *c != '_')
        .map(|c| c.to_ascii_lowercase())
        .collect()
}

/// Lookup by name, ignoring case and `-`/`_` separators, so the CLI accepts
/// both `gpgan` and `GP-GAN`.
pub fn by_name(name: &str) -> Option<NetworkSpec> {
    let want = slug(name);
    all().into_iter().find(|n| slug(n.name) == want)
}

/// [`by_name`], or the standard "unknown model" error listing the known
/// slugs — the single source of that message for the CLI and the serving
/// executor.
pub fn by_name_or_err(name: &str) -> anyhow::Result<NetworkSpec> {
    by_name(name).ok_or_else(|| {
        anyhow::anyhow!("unknown model {name}; expected one of {}", names().join("/"))
    })
}

/// The CLI-facing model names, Table-1 order.
pub fn names() -> Vec<&'static str> {
    vec!["dcgan", "artgan", "sngan", "gpgan", "mde", "fst"]
}

/// Spatially scale a network's layer dims by `1/div` (channels, filters,
/// strides, paddings unchanged): conv inputs clamp to `>= k` (valid conv
/// needs the filter to fit), deconv inputs to `>= 1`. Structure — layer
/// kinds, channel mix, SD geometry — is preserved, so tests and benches can
/// exercise the big benchmarks (FST, MDE, ArtGAN) at tractable resolution
/// through identical code paths.
pub fn scaled(net: &NetworkSpec, div: usize) -> NetworkSpec {
    let layers = net
        .layers
        .iter()
        .map(|l| match l.kind {
            crate::nn::LayerKind::Dense => l.clone(),
            crate::nn::LayerKind::Conv => LayerSpec {
                in_h: (l.in_h / div).max(l.k),
                in_w: (l.in_w / div).max(l.k),
                ..l.clone()
            },
            crate::nn::LayerKind::Deconv => LayerSpec {
                in_h: (l.in_h / div).max(1),
                in_w: (l.in_w / div).max(1),
                ..l.clone()
            },
        })
        .collect();
    NetworkSpec { name: net.name, layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 1 / 2 / 3 targets in M(ACs|params).
    /// (name, total, deconv, nzp, sd, deconv_params)
    const PAPER: &[(&str, f64, f64, f64, f64, f64)] = &[
        ("DCGAN", 111.41, 109.77, 439.09, 158.07, 1.03),
        ("ArtGAN", 1268.77, 822.08, 2030.04, 822.08, 11.01),
        ("SNGAN", 100.86, 100.66, 402.65, 100.66, 2.63),
        ("GP-GAN", 240.39, 103.81, 415.23, 103.81, 2.76),
        ("MDE", 2638.22, 849.35, 3397.39, 1509.95, 3.93),
    ];

    fn rel(a: f64, b: f64) -> f64 {
        (a - b).abs() / b
    }

    #[test]
    fn counts_match_paper_tables() {
        for &(name, total, deconv, nzp, sd, params) in PAPER {
            let net = by_name(name).unwrap();
            let tol_total = if name == "ArtGAN" { 0.16 } else { 0.01 };
            assert!(
                rel(net.total_macs() as f64 / 1e6, total) < tol_total,
                "{name} total {} vs {total}",
                net.total_macs() as f64 / 1e6
            );
            assert!(rel(net.deconv_macs() as f64 / 1e6, deconv) < 0.03, "{name} deconv");
            assert!(rel(net.nzp_macs() as f64 / 1e6, nzp) < 0.03, "{name} nzp");
            assert!(rel(net.sd_macs() as f64 / 1e6, sd) < 0.03, "{name} sd");
            let tol_p = if name == "ArtGAN" { 0.16 } else { 0.05 };
            assert!(
                rel(net.deconv_params() as f64 / 1e6, params) < tol_p,
                "{name} params {}",
                net.deconv_params() as f64 / 1e6
            );
        }
    }

    #[test]
    fn fst_deconv_exact() {
        let net = fst();
        assert!(rel(net.deconv_macs() as f64 / 1e6, 603.98) < 1e-3);
        assert!(rel(net.nzp_macs() as f64 / 1e6, 2415.92) < 1e-3);
        assert!(rel(net.sd_macs() as f64 / 1e6, 1073.74) < 1e-3);
        assert!(rel(net.deconv_params() as f64 / 1e6, 0.0922) < 0.03);
    }

    #[test]
    fn layer_chains_connect() {
        for net in all() {
            let mut prev: Option<&LayerSpec> = None;
            for l in &net.layers {
                if let Some(p) = prev {
                    if l.kind != crate::nn::LayerKind::Dense
                        && p.kind != crate::nn::LayerKind::Dense
                        && l.in_c == p.out_c
                    {
                        assert_eq!(
                            (l.in_h, l.in_w),
                            (p.out_h(), p.out_w()),
                            "{}.{} disconnected",
                            net.name,
                            l.name
                        );
                    }
                }
                prev = Some(l);
            }
        }
    }

    #[test]
    fn by_name_accepts_cli_spellings() {
        // names() must stay the slug-for-slug mirror of all()
        assert_eq!(
            super::names(),
            all().iter().map(|n| super::slug(n.name)).collect::<Vec<_>>(),
            "networks::names() out of sync with networks::all()"
        );
        for name in super::names() {
            assert!(by_name(name).is_some(), "{name} should resolve");
        }
        assert_eq!(by_name("GP-GAN").unwrap().name, "GP-GAN");
        assert_eq!(by_name("gpgan").unwrap().name, "GP-GAN");
        assert!(by_name("vgg").is_none());
    }

    #[test]
    fn scaled_preserves_structure() {
        let net = super::scaled(&fst(), 8);
        let base = fst();
        assert_eq!(net.layers.len(), base.layers.len());
        for (l, b) in net.layers.iter().zip(&base.layers) {
            assert_eq!(
                (l.kind, l.in_c, l.out_c, l.k, l.s, l.p),
                (b.kind, b.in_c, b.out_c, b.k, b.s, b.p)
            );
        }
        // div 8 keeps FST's chain connected
        assert_eq!(net.layers[0].in_h, 32);
    }

    #[test]
    fn compressed_sd_near_original() {
        // Table 3: compression removes nearly all padded-zero weights.
        for net in all() {
            let orig = net.deconv_params();
            let comp = net.sd_compressed_params();
            assert!(comp >= orig);
            assert!((comp - orig) < orig / 100, "{}", net.name);
        }
    }
}
