//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt` emitted by
//! `python -m compile.aot`), compile them once on the PJRT CPU client, and
//! execute them from the rust hot path. Python never runs at request time.
//!
//! Interchange format is HLO *text*: jax >= 0.5 serializes HloModuleProto
//! with 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! This module also hosts [`pool`], the persistent worker pool the native
//! GEMM kernels (f32 and int8) drain their tile queues on.

pub mod pool;

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// One tensor slot in an artifact signature.
#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub bin: PathBuf,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT artifact: an HLO module plus golden inputs/output.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub output: TensorSpec,
    pub kind: String,
    pub network: String,
    pub layer: String,
    pub impl_: String,
    pub batch: usize,
    pub macs: u64,
}

/// The artifact index written by aot.py.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = fs::read_to_string(dir.join("manifest.json")).with_context(|| {
            format!(
                "reading {}/manifest.json (run `make artifacts`)",
                dir.display()
            )
        })?;
        let root = json::parse(&text).map_err(|e| anyhow!("{e}"))?;
        let mut artifacts = Vec::new();
        for a in root
            .get("artifacts")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?
        {
            let tensor = |j: &Json| -> Result<TensorSpec> {
                let shape = j
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| anyhow!("missing shape"))?
                    .iter()
                    .map(|v| v.as_usize().unwrap_or(0))
                    .collect();
                Ok(TensorSpec {
                    shape,
                    bin: dir.join(j.str_or("bin", "")),
                })
            };
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("missing inputs"))?
                .iter()
                .map(tensor)
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactSpec {
                name: a.str_or("name", "").to_string(),
                hlo: dir.join(a.str_or("hlo", "")),
                inputs,
                output: tensor(a.get("output").ok_or_else(|| anyhow!("missing output"))?)?,
                kind: a.str_or("kind", "").to_string(),
                network: a.str_or("network", "").to_string(),
                layer: a.str_or("layer", "").to_string(),
                impl_: a.str_or("impl", "").to_string(),
                batch: a.usize_or("batch", 1),
                macs: a.get("macs").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            });
        }
        Ok(Manifest { dir, artifacts })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn select<'a>(
        &'a self,
        pred: impl Fn(&ArtifactSpec) -> bool + 'a,
    ) -> Vec<&'a ArtifactSpec> {
        self.artifacts.iter().filter(|a| pred(a)).collect()
    }
}

/// Read a raw little-endian f32 binary (the golden tensor format).
pub fn read_bin(path: &Path) -> Result<Vec<f32>> {
    let bytes = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length not a multiple of 4", path.display());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
        .collect())
}

/// A compiled artifact ready to run.
pub struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
    /// raw weight tensors for inputs 1..N (cached from the golden bins)
    fixed: Vec<Vec<f32>>,
}

impl Compiled {
    /// Execute with the caller supplying input 0 (the data input); weight
    /// inputs come from the cached golden bins.
    pub fn run(&self, data: &[f32]) -> Result<Vec<f32>> {
        if data.len() != self.spec.inputs[0].numel() {
            bail!(
                "{}: input 0 expects {} elements, got {}",
                self.spec.name,
                self.spec.inputs[0].numel(),
                data.len()
            );
        }
        let mut args = Vec::with_capacity(1 + self.fixed.len());
        args.push(self.literal(0, data)?);
        for (i, f) in self.fixed.iter().enumerate() {
            args.push(self.literal(i + 1, f)?);
        }
        self.execute(&args)
    }

    /// Execute with ALL inputs supplied (golden-replay path).
    pub fn run_all(&self, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let args = inputs
            .iter()
            .enumerate()
            .map(|(i, d)| self.literal(i, d))
            .collect::<Result<Vec<_>>>()?;
        self.execute(&args)
    }

    fn literal(&self, slot: usize, data: &[f32]) -> Result<xla::Literal> {
        let shape: Vec<i64> = self.spec.inputs[slot].shape.iter().map(|d| *d as i64).collect();
        Ok(xla::Literal::vec1(data).reshape(&shape)?)
    }

    fn execute(&self, args: &[xla::Literal]) -> Result<Vec<f32>> {
        let result = self.exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// The PJRT engine: a CPU client + compiled-executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, Compiled>,
}

impl Engine {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine {
            client,
            manifest,
            compiled: HashMap::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) an artifact by name.
    pub fn load(&mut self, name: &str) -> Result<&Compiled> {
        if !self.compiled.contains_key(name) {
            let spec = self
                .manifest
                .get(name)
                .ok_or_else(|| anyhow!("unknown artifact {name}"))?
                .clone();
            let proto = xla::HloModuleProto::from_text_file(
                spec.hlo.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            let fixed = spec.inputs[1..]
                .iter()
                .map(|t| read_bin(&t.bin))
                .collect::<Result<Vec<_>>>()?;
            self.compiled
                .insert(name.to_string(), Compiled { exe, spec, fixed });
        }
        Ok(&self.compiled[name])
    }

    /// Golden check: run the artifact on its recorded inputs and compare to
    /// the recorded output. Returns the max abs error.
    pub fn verify(&mut self, name: &str) -> Result<f32> {
        let compiled = self.load(name)?;
        let inputs: Vec<Vec<f32>> = compiled
            .spec
            .inputs
            .iter()
            .map(|t| read_bin(&t.bin))
            .collect::<Result<Vec<_>>>()?;
        let want = read_bin(&compiled.spec.output.bin)?;
        let got = compiled.run_all(&inputs)?;
        if got.len() != want.len() {
            bail!("{name}: output length {} != {}", got.len(), want.len());
        }
        Ok(got
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max))
    }
}

/// Default artifact directory: $REPRO_ARTIFACTS or ./artifacts.
pub fn default_artifact_dir() -> PathBuf {
    std::env::var_os("REPRO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// True if artifacts exist (tests skip gracefully otherwise).
pub fn artifacts_available() -> bool {
    default_artifact_dir().join("manifest.json").exists()
}
