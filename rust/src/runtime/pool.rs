//! Persistent shared worker pool for the GEMM kernels.
//!
//! The f32 and int8 convolution kernels used to spawn a fresh
//! `std::thread::scope` pool on **every** conv call — one `clone()` of the
//! thread stack, scheduler handshake, and teardown per layer per forward
//! pass. This module replaces that with ONE process-wide pool ([`global`])
//! whose threads are spawned lazily on first parallel kernel call and then
//! parked between jobs, so the steady-state serving path pays a condvar
//! wake instead of a `pthread_create` per layer. The pool is shared by the
//! f32 kernel, the int8 kernel, and (transitively) every coordinator
//! dispatcher worker executing an engine program — the thread-width policy
//! stays the single `worker_count` / `SD_CONV_THREADS` knob in
//! `tensor::ops`.
//!
//! ## Execution model
//!
//! [`Pool::run`] takes a *work function* and a helper count. The work
//! function is the whole job: internally it drains an atomic tile cursor
//! until no tiles remain (the drain closures the conv/dense drivers in
//! `tensor::ops` and `quant::gemm` hand to `tensor::gemm::parallel_drain`),
//! so it is safe — and cheap — for any number of threads to call it
//! concurrently or repeatedly; a call after the cursor is exhausted
//! returns immediately.
//! `run` hands the function to `helpers` pool threads, calls it once on
//! the caller thread too, and returns only when every helper invocation
//! has finished. Tile ownership (each tile claimed by exactly one
//! `fetch_add` winner) is what makes results independent of how many
//! threads actually participate — the determinism contract of the kernels.
//!
//! ## Why the `unsafe`
//!
//! Pool threads are `'static` but kernel jobs borrow stack data (the
//! input/output tensors of the conv call). `run` erases the borrow's
//! lifetime to hand it to the pool, which is sound for exactly the reason
//! `std::thread::scope` is: `run` does not return until every helper that
//! received the reference has finished with it (the completion latch
//! below), so the borrow never outlives the frame that owns the data.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, OnceLock};

/// Upper bound on pool threads, a sanity cap well above any sane
/// `SD_CONV_THREADS` (the policy already clamps to the tile count).
const MAX_THREADS: usize = 64;

/// One submitted job: a lifetime-erased work function plus the completion
/// latch the submitting thread blocks on.
struct Job {
    /// Lifetime-erased pointer to the caller's `&(dyn Fn() + Sync)` work
    /// function. Valid until `remaining` hits zero — [`Pool::run`] keeps
    /// the referent alive on its stack until then.
    work: *const (dyn Fn() + Sync),
    /// Helper invocations not yet *started* (tickets left to claim).
    tickets: AtomicUsize,
    /// Set if any helper invocation panicked (the submitter re-panics
    /// after the join, mirroring `thread::scope`).
    panicked: AtomicBool,
    /// Helper invocations not yet *finished*; the submitter waits for 0.
    remaining: Mutex<usize>,
    done: Condvar,
}

// SAFETY: `work` points at a `Sync` closure (shared calls are safe), and
// the pointer itself is only dereferenced while the submitter provably
// keeps the referent alive (see module docs). Jobs move between threads
// behind an Arc, never aliased mutably.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct Shared {
    /// Pending jobs. A job stays at the front until its last ticket is
    /// claimed, so helpers drain one job fully before the next.
    queue: Mutex<VecDeque<std::sync::Arc<Job>>>,
    work_ready: Condvar,
    /// Threads spawned so far (monotone, capped at [`MAX_THREADS`]).
    threads: AtomicUsize,
}

/// The persistent pool. One process-wide instance behind [`global`];
/// constructible separately only for isolated tests.
pub struct Pool {
    shared: std::sync::Arc<Shared>,
}

impl Pool {
    pub fn new() -> Pool {
        Pool {
            shared: std::sync::Arc::new(Shared {
                queue: Mutex::new(VecDeque::new()),
                work_ready: Condvar::new(),
                threads: AtomicUsize::new(0),
            }),
        }
    }

    /// Number of pool threads spawned so far (lazily grown by [`Pool::run`]).
    pub fn thread_count(&self) -> usize {
        self.shared.threads.load(Ordering::Relaxed)
    }

    /// Run `work` on `helpers` pool threads *and* the calling thread,
    /// returning when all `helpers + 1` invocations have completed.
    /// `helpers == 0` degenerates to a plain call. `work` must be
    /// re-entrant across threads (drain-a-shared-cursor shaped — see the
    /// module docs).
    pub fn run(&self, helpers: usize, work: &(dyn Fn() + Sync)) {
        if helpers == 0 {
            work();
            return;
        }
        self.ensure_threads(helpers);
        // SAFETY: the transmute erases the borrow lifetime of `work`. The
        // completion wait below guarantees every pool-thread dereference
        // of this pointer happens-before `run` returns, so the referent
        // (and everything it borrows) outlives all uses — the
        // `thread::scope` argument, with the latch playing the role of
        // the scope join.
        let erased: *const (dyn Fn() + Sync) =
            unsafe { std::mem::transmute::<&(dyn Fn() + Sync), &'static (dyn Fn() + Sync)>(work) };
        let job = std::sync::Arc::new(Job {
            work: erased,
            tickets: AtomicUsize::new(helpers),
            panicked: AtomicBool::new(false),
            remaining: Mutex::new(helpers),
            done: Condvar::new(),
        });
        {
            let mut q = self.shared.queue.lock().unwrap();
            q.push_back(job.clone());
        }
        self.shared.work_ready.notify_all();
        // The caller is a full participant, not just a waiter. Its panic
        // (if any) is held until the helpers have joined — unwinding past
        // the borrow while helpers still hold it would be the exact
        // use-after-free the barrier exists to prevent.
        let caller_result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(work));
        {
            let mut remaining = job.remaining.lock().unwrap();
            while *remaining > 0 {
                remaining = job.done.wait(remaining).unwrap();
            }
        }
        if let Err(payload) = caller_result {
            std::panic::resume_unwind(payload);
        }
        if job.panicked.load(Ordering::Relaxed) {
            panic!("a kernel pool worker panicked (see stderr for the worker backtrace)");
        }
    }

    /// Lazily grow the pool to at least `want` threads (capped).
    fn ensure_threads(&self, want: usize) {
        let want = want.min(MAX_THREADS);
        while self.shared.threads.load(Ordering::Relaxed) < want {
            let have = self.shared.threads.fetch_add(1, Ordering::Relaxed);
            if have >= want {
                self.shared.threads.fetch_sub(1, Ordering::Relaxed);
                break;
            }
            let shared = self.shared.clone();
            std::thread::Builder::new()
                .name(format!("gemm-pool-{have}"))
                .spawn(move || worker_loop(shared))
                .expect("spawning a gemm pool thread");
        }
    }
}

impl Default for Pool {
    fn default() -> Pool {
        Pool::new()
    }
}

fn worker_loop(shared: std::sync::Arc<Shared>) {
    loop {
        let job = {
            let mut q = shared.queue.lock().unwrap();
            loop {
                // claim one ticket from the front job; pop it once its
                // last ticket is taken so later jobs become visible
                if let Some(front) = q.front() {
                    let left = front.tickets.fetch_sub(1, Ordering::AcqRel);
                    debug_assert!(left >= 1);
                    let job = front.clone();
                    if left == 1 {
                        q.pop_front();
                    }
                    break job;
                }
                q = shared.work_ready.wait(q).unwrap();
            }
        };
        // SAFETY: the submitter blocks in `Pool::run` until this
        // invocation decrements `remaining`, keeping the referent alive
        // for the duration of this call (see module docs). A panic is
        // caught so `remaining` always reaches 0 (no hung submitter) and
        // re-raised on the submitting thread.
        let result =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| unsafe { (*job.work)() }));
        if result.is_err() {
            job.panicked.store(true, Ordering::Relaxed);
        }
        let mut remaining = job.remaining.lock().unwrap();
        *remaining -= 1;
        if *remaining == 0 {
            job.done.notify_all();
        }
    }
}

static GLOBAL: OnceLock<Pool> = OnceLock::new();

/// The process-wide kernel pool. Threads are spawned on first use and live
/// for the process; between jobs they block on a condvar (no spinning).
pub fn global() -> &'static Pool {
    GLOBAL.get_or_init(Pool::new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_work_on_caller_and_helpers() {
        let pool = Pool::new();
        let calls = AtomicUsize::new(0);
        pool.run(3, &|| {
            calls.fetch_add(1, Ordering::Relaxed);
        });
        // caller + 3 helpers, every invocation completed before return
        assert_eq!(calls.load(Ordering::Relaxed), 4);
        assert!(pool.thread_count() >= 1);
    }

    #[test]
    fn pool_with_zero_helpers_is_a_plain_call() {
        let pool = Pool::new();
        let calls = AtomicUsize::new(0);
        pool.run(0, &|| {
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 1);
        assert_eq!(pool.thread_count(), 0, "no threads spawned for inline runs");
    }

    #[test]
    fn cursor_draining_jobs_complete_exactly() {
        // the kernels' actual usage shape: N tiles, each claimed by exactly
        // one fetch_add winner, any number of threads draining
        let pool = Pool::new();
        for round in 0..50 {
            let tiles = 17 + round % 5;
            let cursor = AtomicUsize::new(0);
            let sum = AtomicU64::new(0);
            pool.run(4, &|| loop {
                let t = cursor.fetch_add(1, Ordering::Relaxed);
                if t >= tiles {
                    break;
                }
                sum.fetch_add(t as u64 + 1, Ordering::Relaxed);
            });
            let want = (tiles * (tiles + 1) / 2) as u64;
            assert_eq!(sum.load(Ordering::Relaxed), want, "round {round}");
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = Pool::new();
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.run(2, &|| panic!("boom"));
        }));
        assert!(result.is_err(), "a panicking job must fail the submitter");
        // the pool must remain functional for the next job
        let calls = AtomicUsize::new(0);
        pool.run(2, &|| {
            calls.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(calls.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn concurrent_submitters_share_one_pool() {
        let pool = std::sync::Arc::new(Pool::new());
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = pool.clone();
                s.spawn(move || {
                    for _ in 0..20 {
                        let cursor = AtomicUsize::new(0);
                        let hits = AtomicUsize::new(0);
                        pool.run(2, &|| loop {
                            if cursor.fetch_add(1, Ordering::Relaxed) >= 8 {
                                break;
                            }
                            hits.fetch_add(1, Ordering::Relaxed);
                        });
                        assert_eq!(hits.load(Ordering::Relaxed), 8);
                    }
                });
            }
        });
        assert!(pool.thread_count() <= MAX_THREADS);
    }
}
