//! `.sdprog` — the serialized compiled-[`Program`] artifact (DESIGN.md §13).
//!
//! Compiling a program re-splits the deconv filters, re-quantizes, and
//! re-packs every weight panel; an artifact makes that work a build step
//! instead of a cold-start cost. The format is a JSON manifest plus aligned
//! binary blobs:
//!
//! ```text
//! offset 0   magic            8 bytes  ("\x89SDPROG\n")
//! offset 8   manifest_len     u64 LE
//! offset 16  manifest         manifest_len bytes of JSON (UTF-8)
//!            zero padding     to the next 64-byte boundary
//!            blob region      every blob at a 64-byte-aligned offset
//! ```
//!
//! * Blob `offset` fields in the manifest are **relative to the blob-region
//!   start** (`align64(16 + manifest_len)`), so the manifest never encodes
//!   its own length.
//! * Every multi-byte value is **little-endian**; blob payloads reuse the
//!   packed in-memory layouts verbatim ([`PackedB`] panels, [`QPackedB`]
//!   pair-interleave, [`QFilter`] HWIO bytes).
//! * Every blob carries its byte length and sha256 in the manifest; a load
//!   verifies the format version, then every bound, checksum, and
//!   geometry-derived length **before** constructing any op, and fails with
//!   a typed [`ArtifactError`] — never a partially-initialized program.
//! * [`LoadMode::ZeroCopy`] borrows the panel payloads in place from one
//!   shared buffer of the whole file (little-endian targets; on big-endian
//!   it silently degrades to a copying load, whose explicit `from_le`
//!   decoding is correct everywhere).
//!
//! Version-bump rules: any change to blob layouts, the checksum scheme, or
//! manifest field meanings increments [`FORMAT_VERSION`]; readers reject
//! other versions outright (no silent best-effort parse). Adding a new
//! *optional* manifest field is the only compatible change.
//!
//! The round-trip contract (asserted by `rust/tests/artifact.rs` and the CI
//! bit-identity gate): `Program::load` of a saved artifact re-serializes to
//! the identical bytes — [`Program::to_artifact_bytes`] is deterministic
//! (sorted-key JSON via [`crate::util::json::Json::encode`], traversal-order
//! blob placement), so byte equality is program equality.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use super::{Act, Op, Program, Step};
use crate::networks;
use crate::nn::LayerKind;
use crate::quant::{Precision, QFilter, QPackedB};
use crate::sd::SdGeometry;
use crate::tensor::gemm::PackedB;
use crate::util::blob::AlignedBytes;
use crate::util::json::{self, Json};
use crate::util::sha256;

/// Artifact format version (see the module docs for bump rules).
pub const FORMAT_VERSION: u64 = 1;

/// File alignment of every blob (and of the blob-region start) — wide
/// enough for any SIMD load the kernels issue, and cache-line tidy.
pub const BLOB_ALIGN: usize = 64;

/// File magic: high-bit byte first (catches ASCII-mode mangling, as PNG
/// does), then the format name, then a newline (catches CRLF translation).
const MAGIC: [u8; 8] = *b"\x89SDPROG\n";

/// Bytes before the manifest: magic + `u64` manifest length.
const HEADER_LEN: usize = 16;

/// How [`Program::load_with`] materializes blob payloads.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum LoadMode {
    /// decode every blob into owned buffers (works on any target)
    #[default]
    Copy,
    /// borrow the packed panel payloads in place from one shared buffer of
    /// the whole file — no per-blob copy; little-endian targets only (on
    /// big-endian this degrades to [`LoadMode::Copy`])
    ZeroCopy,
}

/// Typed failure of artifact encoding/decoding — surfaced through
/// `anyhow::Error` (use `err.downcast_ref::<ArtifactError>()`).
#[derive(Debug)]
pub enum ArtifactError {
    /// the file does not start with the `.sdprog` magic
    BadMagic,
    /// the file ends before a region the header/manifest promises
    Truncated { need: usize, have: usize },
    /// the manifest is not UTF-8 / not JSON / missing a required field
    BadManifest(String),
    /// `format_version` is not [`FORMAT_VERSION`] (checked before any
    /// other manifest field)
    UnsupportedVersion { found: u64 },
    /// the manifest names a network not in the registry
    UnknownNetwork(String),
    /// manifest geometry disagrees with the named network's spec (or a
    /// blob length disagrees with the geometry it must satisfy)
    SpecMismatch(String),
    /// a blob's `offset`/`len` reaches outside the file
    BlobOutOfBounds { kind: String, offset: usize, len: usize },
    /// a blob's bytes do not hash to the manifest's sha256
    ChecksumMismatch { kind: String, offset: usize },
    /// the program holds an op the format cannot carry (reference deconv
    /// lowerings exist as quality baselines, not serving artifacts)
    UnsupportedOp(String),
}

impl fmt::Display for ArtifactError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ArtifactError::BadMagic => write!(f, "not an .sdprog artifact (bad magic)"),
            ArtifactError::Truncated { need, have } => {
                write!(f, "artifact truncated: need {need} bytes, have {have}")
            }
            ArtifactError::BadManifest(msg) => write!(f, "bad artifact manifest: {msg}"),
            ArtifactError::UnsupportedVersion { found } => write!(
                f,
                "unsupported artifact format version {found} (reader supports {FORMAT_VERSION})"
            ),
            ArtifactError::UnknownNetwork(name) => {
                write!(f, "artifact names unknown network {name:?}")
            }
            ArtifactError::SpecMismatch(msg) => {
                write!(f, "artifact disagrees with network spec: {msg}")
            }
            ArtifactError::BlobOutOfBounds { kind, offset, len } => write!(
                f,
                "blob {kind} (offset {offset}, len {len}) reaches outside the file"
            ),
            ArtifactError::ChecksumMismatch { kind, offset } => {
                write!(f, "blob {kind} at offset {offset} fails its sha256 check")
            }
            ArtifactError::UnsupportedOp(msg) => {
                write!(f, "program op not serializable: {msg}")
            }
        }
    }
}

impl std::error::Error for ArtifactError {}

/// Next multiple of [`BLOB_ALIGN`] at or above `n`.
fn align_up(n: usize) -> usize {
    n.div_ceil(BLOB_ALIGN) * BLOB_ALIGN
}

// ---------------------------------------------------------------------------
// payload byte codecs (explicit little-endian; memcpy fast path on LE hosts)
// ---------------------------------------------------------------------------

fn f32_to_le(v: &[f32]) -> Vec<u8> {
    if cfg!(target_endian = "little") {
        let mut out = vec![0u8; std::mem::size_of_val(v)];
        // SAFETY: plain byte copy of POD data into an equal-sized buffer.
        unsafe {
            std::ptr::copy_nonoverlapping(v.as_ptr() as *const u8, out.as_mut_ptr(), out.len())
        };
        out
    } else {
        let mut out = Vec::with_capacity(v.len() * 4);
        for x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }
}

fn u32_to_le(v: &[u32]) -> Vec<u8> {
    if cfg!(target_endian = "little") {
        let mut out = vec![0u8; std::mem::size_of_val(v)];
        // SAFETY: as in `f32_to_le`.
        unsafe {
            std::ptr::copy_nonoverlapping(v.as_ptr() as *const u8, out.as_mut_ptr(), out.len())
        };
        out
    } else {
        let mut out = Vec::with_capacity(v.len() * 4);
        for x in v {
            out.extend_from_slice(&x.to_le_bytes());
        }
        out
    }
}

fn i8_to_bytes(v: &[i8]) -> Vec<u8> {
    let mut out = vec![0u8; v.len()];
    // SAFETY: i8 -> u8 is a bit-identical byte copy.
    unsafe { std::ptr::copy_nonoverlapping(v.as_ptr() as *const u8, out.as_mut_ptr(), v.len()) };
    out
}

fn f32_from_le(b: &[u8]) -> Vec<f32> {
    debug_assert_eq!(b.len() % 4, 0);
    if cfg!(target_endian = "little") {
        let mut v = vec![0f32; b.len() / 4];
        // SAFETY: byte copy into a zero-initialized Vec<f32> of exactly
        // b.len() bytes; any bit pattern is a valid f32.
        unsafe { std::ptr::copy_nonoverlapping(b.as_ptr(), v.as_mut_ptr() as *mut u8, b.len()) };
        v
    } else {
        b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

fn u32_from_le(b: &[u8]) -> Vec<u32> {
    debug_assert_eq!(b.len() % 4, 0);
    if cfg!(target_endian = "little") {
        let mut v = vec![0u32; b.len() / 4];
        // SAFETY: as in `f32_from_le`.
        unsafe { std::ptr::copy_nonoverlapping(b.as_ptr(), v.as_mut_ptr() as *mut u8, b.len()) };
        v
    } else {
        b.chunks_exact(4)
            .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect()
    }
}

fn i8_from_bytes(b: &[u8]) -> Vec<i8> {
    let mut v = vec![0i8; b.len()];
    // SAFETY: u8 -> i8 is a bit-identical byte copy.
    unsafe { std::ptr::copy_nonoverlapping(b.as_ptr(), v.as_mut_ptr() as *mut u8, b.len()) };
    v
}

// ---------------------------------------------------------------------------
// writer
// ---------------------------------------------------------------------------

/// Accumulates the blob region; every `push` places the payload at the next
/// 64-byte-aligned region-relative offset and returns the manifest
/// descriptor fields (`kind`/`offset`/`len`/`sha256`).
#[derive(Default)]
struct BlobWriter {
    region: Vec<u8>,
}

impl BlobWriter {
    fn push(&mut self, kind: &str, payload: &[u8]) -> BTreeMap<String, Json> {
        let padded = align_up(self.region.len());
        self.region.resize(padded, 0);
        let offset = self.region.len();
        self.region.extend_from_slice(payload);
        let mut d = BTreeMap::new();
        d.insert("kind".to_string(), Json::Str(kind.to_string()));
        d.insert("offset".to_string(), Json::Num(offset as f64));
        d.insert("len".to_string(), Json::Num(payload.len() as f64));
        d.insert("sha256".to_string(), Json::Str(sha256::hex_digest(payload)));
        d
    }
}

fn num(n: usize) -> Json {
    Json::Num(n as f64)
}

fn shape_arr(h: usize, w: usize, c: usize) -> Json {
    Json::Arr(vec![num(h), num(w), num(c)])
}

fn packed_b_desc(bw: &mut BlobWriter, pb: &PackedB) -> Json {
    let mut d = bw.push("packed_b_f32", &f32_to_le(pb.raw()));
    d.insert("k".to_string(), num(pb.k));
    d.insert("n".to_string(), num(pb.n));
    Json::Obj(d)
}

fn qfilter_desc(bw: &mut BlobWriter, qf: &QFilter) -> Json {
    let mut d = BTreeMap::new();
    d.insert(
        "scales".to_string(),
        Json::Obj(bw.push("scales_f32", &f32_to_le(&qf.scales))),
    );
    d.insert(
        "data".to_string(),
        Json::Obj(bw.push("qfilter_i8", &i8_to_bytes(&qf.data))),
    );
    d.insert(
        "nz_rows".to_string(),
        Json::Obj(bw.push("nz_rows_u32", &u32_to_le(&qf.nz_rows))),
    );
    Json::Obj(d)
}

fn qpacked_desc(bw: &mut BlobWriter, qp: &QPackedB) -> Json {
    let mut d = BTreeMap::new();
    d.insert(
        "kidx".to_string(),
        Json::Obj(bw.push("q_kidx_u32", &u32_to_le(qp.raw_kidx()))),
    );
    d.insert(
        "data".to_string(),
        Json::Obj(bw.push("q_data_i8", &i8_to_bytes(qp.raw_data()))),
    );
    Json::Obj(d)
}

fn build_manifest(program: &Program, bw: &mut BlobWriter) -> Result<Json, ArtifactError> {
    let mut steps = Vec::with_capacity(program.steps.len());
    for step in &program.steps {
        let mut so = BTreeMap::new();
        so.insert("name".to_string(), Json::Str(step.name.to_string()));
        so.insert("in".to_string(), shape_arr(step.in_h, step.in_w, step.in_c));
        so.insert("out".to_string(), shape_arr(step.out_h, step.out_w, step.out_c));
        match &step.op {
            Op::Dense { packed } => {
                so.insert("op".to_string(), Json::Str("dense".to_string()));
                so.insert("packed".to_string(), Json::Arr(vec![packed_b_desc(bw, packed)]));
            }
            Op::Conv { packed, .. } => {
                so.insert("op".to_string(), Json::Str("conv".to_string()));
                so.insert("packed".to_string(), Json::Arr(vec![packed_b_desc(bw, packed)]));
            }
            Op::SdDeconv { packed, .. } => {
                so.insert("op".to_string(), Json::Str("sd_deconv".to_string()));
                so.insert(
                    "packed".to_string(),
                    Json::Arr(packed.iter().map(|pb| packed_b_desc(bw, pb)).collect()),
                );
            }
            Op::RefDeconv { imp, .. } => {
                return Err(ArtifactError::UnsupportedOp(format!(
                    "{}.{}: reference deconv lowering {imp:?} (compile with the Sd impl)",
                    program.name, step.name
                )));
            }
            Op::QConv { qf, packed, in_scale, .. } => {
                so.insert("op".to_string(), Json::Str("q_conv".to_string()));
                so.insert("in_scale".to_string(), Json::Num(*in_scale as f64));
                so.insert("qfilter".to_string(), qfilter_desc(bw, qf));
                so.insert("packed".to_string(), qpacked_desc(bw, packed));
            }
            Op::QSdDeconv { splits, packed, in_scale, .. } => {
                so.insert("op".to_string(), Json::Str("q_sd_deconv".to_string()));
                so.insert("in_scale".to_string(), Json::Num(*in_scale as f64));
                let entries = splits
                    .iter()
                    .zip(packed)
                    .map(|(qf, qp)| {
                        let mut e = BTreeMap::new();
                        e.insert("qfilter".to_string(), qfilter_desc(bw, qf));
                        e.insert("packed".to_string(), qpacked_desc(bw, qp));
                        Json::Obj(e)
                    })
                    .collect();
                so.insert("splits".to_string(), Json::Arr(entries));
            }
        }
        steps.push(Json::Obj(so));
    }
    let mut m = BTreeMap::new();
    m.insert("blob_align".to_string(), num(BLOB_ALIGN));
    m.insert("format".to_string(), Json::Str("sdprog".to_string()));
    m.insert("format_version".to_string(), Json::Num(FORMAT_VERSION as f64));
    m.insert("network".to_string(), Json::Str(program.name.to_string()));
    m.insert(
        "precision".to_string(),
        Json::Str(program.precision.label().to_string()),
    );
    m.insert(
        "input".to_string(),
        shape_arr(program.in_h, program.in_w, program.in_c),
    );
    m.insert("output_len".to_string(), num(program.out_len));
    m.insert("steps".to_string(), Json::Arr(steps));
    Ok(Json::Obj(m))
}

// ---------------------------------------------------------------------------
// reader
// ---------------------------------------------------------------------------

/// Resolve + checksum one blob descriptor: bounds-check the window against
/// the file, verify the sha256, return (bytes, absolute offset, kind).
fn blob_slice<'a>(
    buf: &'a AlignedBytes,
    region_start: usize,
    desc: &Json,
) -> Result<(&'a [u8], usize, String), ArtifactError> {
    let kind = desc.str_or("kind", "?").to_string();
    let offset = desc
        .get("offset")
        .and_then(Json::as_usize)
        .ok_or_else(|| ArtifactError::BadManifest(format!("blob {kind} missing offset")))?;
    let len = desc
        .get("len")
        .and_then(Json::as_usize)
        .ok_or_else(|| ArtifactError::BadManifest(format!("blob {kind} missing len")))?;
    let oob = ArtifactError::BlobOutOfBounds { kind: kind.clone(), offset, len };
    let abs = match region_start.checked_add(offset) {
        Some(a) => a,
        None => return Err(oob),
    };
    let end = match abs.checked_add(len) {
        Some(e) => e,
        None => return Err(oob),
    };
    if end > buf.len() {
        return Err(oob);
    }
    let bytes = &buf.as_bytes()[abs..end];
    let want = desc
        .get("sha256")
        .and_then(Json::as_str)
        .ok_or_else(|| ArtifactError::BadManifest(format!("blob {kind} missing sha256")))?;
    if sha256::hex_digest(bytes) != want {
        return Err(ArtifactError::ChecksumMismatch { kind, offset });
    }
    Ok((bytes, abs, kind))
}

/// The mode actually used: zero-copy views read native-endian, so the
/// little-endian file format only supports them on little-endian hosts.
fn effective_mode(mode: LoadMode) -> LoadMode {
    if cfg!(target_endian = "little") {
        mode
    } else {
        LoadMode::Copy
    }
}

fn load_packed_b(
    buf: &Arc<AlignedBytes>,
    region_start: usize,
    desc: &Json,
    k: usize,
    n: usize,
    mode: LoadMode,
) -> Result<PackedB, ArtifactError> {
    let (bytes, abs, kind) = blob_slice(buf, region_start, desc)?;
    if desc.usize_or("k", k) != k || desc.usize_or("n", n) != n {
        return Err(ArtifactError::SpecMismatch(format!(
            "{kind}: manifest operand shape {}x{} but the spec requires {k}x{n}",
            desc.usize_or("k", 0),
            desc.usize_or("n", 0),
        )));
    }
    let want_bytes = PackedB::packed_len(k, n) * 4;
    if bytes.len() != want_bytes {
        return Err(ArtifactError::SpecMismatch(format!(
            "{kind}: blob length {} disagrees with the {} bytes a {k}x{n} panel operand requires",
            bytes.len(),
            want_bytes,
        )));
    }
    let made = match effective_mode(mode) {
        LoadMode::Copy => PackedB::from_parts(k, n, f32_from_le(bytes)),
        LoadMode::ZeroCopy => PackedB::from_shared(k, n, buf.clone(), abs),
    };
    made.ok_or_else(|| {
        ArtifactError::SpecMismatch(format!("{kind}: packed operand construction refused"))
    })
}

fn load_qfilter(
    buf: &Arc<AlignedBytes>,
    region_start: usize,
    desc: Option<&Json>,
    kh: usize,
    kw: usize,
    ic: usize,
    oc: usize,
) -> Result<QFilter, ArtifactError> {
    let d = desc.ok_or_else(|| ArtifactError::BadManifest("step missing qfilter".to_string()))?;
    let k = kh * kw * ic;
    let (sb, _, skind) = blob_slice(
        buf,
        region_start,
        d.get("scales")
            .ok_or_else(|| ArtifactError::BadManifest("qfilter missing scales".to_string()))?,
    )?;
    if sb.len() != oc * 4 {
        return Err(ArtifactError::SpecMismatch(format!(
            "{skind}: {} bytes of scales for {oc} output channels",
            sb.len()
        )));
    }
    let (db, _, dkind) = blob_slice(
        buf,
        region_start,
        d.get("data")
            .ok_or_else(|| ArtifactError::BadManifest("qfilter missing data".to_string()))?,
    )?;
    if db.len() != k * oc {
        return Err(ArtifactError::SpecMismatch(format!(
            "{dkind}: blob length {} disagrees with the {k}x{oc} filter payload",
            db.len()
        )));
    }
    let (nb, _, nkind) = blob_slice(
        buf,
        region_start,
        d.get("nz_rows")
            .ok_or_else(|| ArtifactError::BadManifest("qfilter missing nz_rows".to_string()))?,
    )?;
    if nb.len() % 4 != 0 || nb.len() / 4 > k {
        return Err(ArtifactError::SpecMismatch(format!(
            "{nkind}: {} bytes of non-zero-row indices for contraction length {k}",
            nb.len()
        )));
    }
    let nz_rows = u32_from_le(nb);
    if nz_rows.iter().any(|&r| r as usize >= k) {
        return Err(ArtifactError::SpecMismatch(format!(
            "{nkind}: row index out of range for contraction length {k}"
        )));
    }
    Ok(QFilter {
        kh,
        kw,
        ic,
        oc,
        scales: f32_from_le(sb),
        data: i8_from_bytes(db),
        nz_rows,
    })
}

fn load_qpacked(
    buf: &Arc<AlignedBytes>,
    region_start: usize,
    desc: Option<&Json>,
    k: usize,
    n: usize,
    mode: LoadMode,
) -> Result<QPackedB, ArtifactError> {
    let d = desc.ok_or_else(|| ArtifactError::BadManifest("step missing packed".to_string()))?;
    let (kb, kabs, kkind) = blob_slice(
        buf,
        region_start,
        d.get("kidx")
            .ok_or_else(|| ArtifactError::BadManifest("packed missing kidx".to_string()))?,
    )?;
    if kb.len() % 8 != 0 {
        return Err(ArtifactError::SpecMismatch(format!(
            "{kkind}: {} bytes is not a whole number of u32 index pairs",
            kb.len()
        )));
    }
    let elems = kb.len() / 4;
    let (db, dabs, dkind) = blob_slice(
        buf,
        region_start,
        d.get("data")
            .ok_or_else(|| ArtifactError::BadManifest("packed missing data".to_string()))?,
    )?;
    let want = QPackedB::packed_data_len(n, elems / 2);
    if db.len() != want {
        return Err(ArtifactError::SpecMismatch(format!(
            "{dkind}: blob length {} disagrees with the {want} bytes {} index pairs require",
            db.len(),
            elems / 2,
        )));
    }
    let made = match effective_mode(mode) {
        LoadMode::Copy => QPackedB::from_parts(k, n, u32_from_le(kb), i8_from_bytes(db)),
        LoadMode::ZeroCopy => QPackedB::from_shared(k, n, buf.clone(), kabs, elems, dabs),
    };
    made.ok_or_else(|| {
        ArtifactError::SpecMismatch(format!(
            "{kkind}: row index out of range for contraction length {k}"
        ))
    })
}

fn packed_list(sj: &Json, want: usize) -> Result<&[Json], String> {
    let arr = sj
        .get("packed")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing packed operand list".to_string())?;
    if arr.len() != want {
        return Err(format!("{} packed operands, expected {want}", arr.len()));
    }
    Ok(arr)
}

fn shape_of(j: Option<&Json>) -> Option<[usize; 3]> {
    let arr = j?.as_arr()?;
    if arr.len() != 3 {
        return None;
    }
    Some([
        arr[0].as_usize()?,
        arr[1].as_usize()?,
        arr[2].as_usize()?,
    ])
}

fn from_shared(buf: Arc<AlignedBytes>, mode: LoadMode) -> Result<Program> {
    let b = buf.as_bytes();
    if b.len() < HEADER_LEN {
        return Err(ArtifactError::Truncated { need: HEADER_LEN, have: b.len() }.into());
    }
    if b[..8] != MAGIC {
        return Err(ArtifactError::BadMagic.into());
    }
    let mlen = u64::from_le_bytes(b[8..16].try_into().expect("8-byte slice")) as usize;
    let mend = HEADER_LEN
        .checked_add(mlen)
        .ok_or(ArtifactError::Truncated { need: usize::MAX, have: b.len() })?;
    if mend > b.len() {
        return Err(ArtifactError::Truncated { need: mend, have: b.len() }.into());
    }
    let mstr = std::str::from_utf8(&b[HEADER_LEN..mend])
        .map_err(|_| ArtifactError::BadManifest("manifest is not UTF-8".to_string()))?;
    let manifest =
        json::parse(mstr).map_err(|e| ArtifactError::BadManifest(e.to_string()))?;
    // the version gates every other field's meaning: check it first
    let version = manifest
        .get("format_version")
        .and_then(Json::as_f64)
        .ok_or_else(|| ArtifactError::BadManifest("missing format_version".to_string()))?
        as u64;
    if version != FORMAT_VERSION {
        return Err(ArtifactError::UnsupportedVersion { found: version }.into());
    }
    if manifest.str_or("format", "") != "sdprog" {
        return Err(ArtifactError::BadManifest("format is not \"sdprog\"".to_string()).into());
    }
    if manifest.usize_or("blob_align", 0) != BLOB_ALIGN {
        return Err(ArtifactError::BadManifest(format!(
            "blob_align {} (version {FORMAT_VERSION} requires {BLOB_ALIGN})",
            manifest.usize_or("blob_align", 0)
        ))
        .into());
    }
    let net_name = manifest
        .get("network")
        .and_then(Json::as_str)
        .ok_or_else(|| ArtifactError::BadManifest("missing network".to_string()))?;
    let spec = networks::by_name(net_name)
        .ok_or_else(|| ArtifactError::UnknownNetwork(net_name.to_string()))?;
    let precision = Precision::parse(manifest.str_or("precision", ""))
        .ok_or_else(|| ArtifactError::BadManifest("missing/unknown precision".to_string()))?;
    let steps_json = manifest
        .get("steps")
        .and_then(Json::as_arr)
        .ok_or_else(|| ArtifactError::BadManifest("missing steps".to_string()))?;
    if steps_json.len() != spec.layers.len() || spec.layers.is_empty() {
        return Err(ArtifactError::SpecMismatch(format!(
            "{} steps for {} spec layers",
            steps_json.len(),
            spec.layers.len()
        ))
        .into());
    }
    let region_start = align_up(mend);
    let last = spec.layers.len() - 1;
    let mut steps = Vec::with_capacity(spec.layers.len());
    for (i, (l, sj)) in spec.layers.iter().zip(steps_json).enumerate() {
        let fail =
            |msg: String| ArtifactError::SpecMismatch(format!("{}.{}: {msg}", spec.name, l.name));
        if sj.str_or("name", "") != l.name {
            return Err(fail(format!("step named {:?}", sj.str_or("name", ""))).into());
        }
        let want_in = [l.in_h, l.in_w, l.in_c];
        let want_out = [l.out_h(), l.out_w(), l.out_c];
        if shape_of(sj.get("in")) != Some(want_in) || shape_of(sj.get("out")) != Some(want_out) {
            return Err(fail("step shapes disagree with the spec".to_string()).into());
        }
        let want_op = match (l.kind, precision) {
            (LayerKind::Dense, Precision::F32) => "dense",
            (LayerKind::Conv, Precision::F32) => "conv",
            (LayerKind::Deconv, Precision::F32) => "sd_deconv",
            (LayerKind::Dense | LayerKind::Conv, Precision::Int8) => "q_conv",
            (LayerKind::Deconv, Precision::Int8) => "q_sd_deconv",
        };
        let got_op = sj.str_or("op", "");
        if got_op != want_op {
            return Err(fail(format!("op {got_op:?}, expected {want_op:?}")).into());
        }
        let in_scale = || -> Result<f32, ArtifactError> {
            Ok(sj
                .get("in_scale")
                .and_then(Json::as_f64)
                .ok_or_else(|| fail("missing in_scale".to_string()))? as f32)
        };
        let op = match got_op {
            "dense" => {
                let n_in = l.in_h * l.in_w * l.in_c;
                let descs = packed_list(sj, 1).map_err(&fail)?;
                Op::Dense {
                    packed: load_packed_b(&buf, region_start, &descs[0], n_in, l.out_c, mode)?,
                }
            }
            "conv" => {
                let descs = packed_list(sj, 1).map_err(&fail)?;
                let k = l.k * l.k * l.in_c;
                Op::Conv {
                    kh: l.k,
                    kw: l.k,
                    packed: load_packed_b(&buf, region_start, &descs[0], k, l.out_c, mode)?,
                    s: l.s,
                    p: l.p,
                }
            }
            "sd_deconv" => {
                let g = SdGeometry::new(l.k, l.s, l.p);
                let descs = packed_list(sj, g.n_splits()).map_err(&fail)?;
                let k = g.k_t * g.k_t * l.in_c;
                let packed = descs
                    .iter()
                    .map(|d| load_packed_b(&buf, region_start, d, k, l.out_c, mode))
                    .collect::<Result<Vec<_>, _>>()?;
                Op::SdDeconv { packed, g }
            }
            "q_conv" => {
                // a dense layer lowers to a 1x1 conv over its 1x1xn_in view
                let (kh, kw, ic, s, p) = if l.kind == LayerKind::Dense {
                    (1, 1, l.in_h * l.in_w * l.in_c, 1, 0)
                } else {
                    (l.k, l.k, l.in_c, l.s, l.p)
                };
                let qf = load_qfilter(&buf, region_start, sj.get("qfilter"), kh, kw, ic, l.out_c)?;
                let packed = load_qpacked(
                    &buf,
                    region_start,
                    sj.get("packed"),
                    kh * kw * ic,
                    l.out_c,
                    mode,
                )?;
                Op::QConv { qf, packed, in_scale: in_scale()?, s, p }
            }
            "q_sd_deconv" => {
                let g = SdGeometry::new(l.k, l.s, l.p);
                let entries = sj
                    .get("splits")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| fail("missing splits".to_string()))?;
                if entries.len() != g.n_splits() {
                    return Err(fail(format!(
                        "{} splits, expected {}",
                        entries.len(),
                        g.n_splits()
                    ))
                    .into());
                }
                let k = g.k_t * g.k_t * l.in_c;
                let mut splits = Vec::with_capacity(entries.len());
                let mut packed = Vec::with_capacity(entries.len());
                for e in entries {
                    splits.push(load_qfilter(
                        &buf,
                        region_start,
                        e.get("qfilter"),
                        g.k_t,
                        g.k_t,
                        l.in_c,
                        l.out_c,
                    )?);
                    packed.push(load_qpacked(
                        &buf,
                        region_start,
                        e.get("packed"),
                        k,
                        l.out_c,
                        mode,
                    )?);
                }
                Op::QSdDeconv { splits, packed, g, in_scale: in_scale()? }
            }
            _ => return Err(fail(format!("unknown op {got_op:?}")).into()),
        };
        steps.push(Step {
            name: l.name,
            in_h: l.in_h,
            in_w: l.in_w,
            in_c: l.in_c,
            out_h: l.out_h(),
            out_w: l.out_w(),
            out_c: l.out_c,
            op,
            act: if i == last { Act::Tanh } else { Act::Relu },
        });
    }
    let first = &spec.layers[0];
    let last_l = &spec.layers[last];
    let program = Program {
        name: spec.name,
        steps,
        precision,
        in_h: first.in_h,
        in_w: first.in_w,
        in_c: first.in_c,
        out_len: last_l.out_h() * last_l.out_w() * last_l.out_c,
    };
    // top-level redundancy: the manifest's own input/output records
    if shape_of(manifest.get("input")) != Some([program.in_h, program.in_w, program.in_c])
        || manifest.usize_or("output_len", usize::MAX) != program.out_len
    {
        return Err(ArtifactError::SpecMismatch(
            "manifest input/output records disagree with the spec".to_string(),
        )
        .into());
    }
    Ok(program)
}

impl Program {
    /// Serialize to the `.sdprog` byte format (deterministic: equal
    /// programs produce equal bytes — the bit-identity gate's definition
    /// of program equality).
    pub fn to_artifact_bytes(&self) -> Result<Vec<u8>> {
        let mut bw = BlobWriter::default();
        let manifest = build_manifest(self, &mut bw)?;
        let mjson = manifest.encode();
        let region_start = align_up(HEADER_LEN + mjson.len());
        let mut out = Vec::with_capacity(region_start + bw.region.len());
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&(mjson.len() as u64).to_le_bytes());
        out.extend_from_slice(mjson.as_bytes());
        out.resize(region_start, 0);
        out.extend_from_slice(&bw.region);
        Ok(out)
    }

    /// Write the `.sdprog` artifact to `path`.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let bytes = self
            .to_artifact_bytes()
            .with_context(|| format!("serializing {} for {}", self.name, path.display()))?;
        std::fs::write(path, bytes).with_context(|| format!("writing {}", path.display()))
    }

    /// Load a `.sdprog` artifact, validating the format version and every
    /// blob checksum before constructing the program (copying mode).
    pub fn load(path: impl AsRef<Path>) -> Result<Program> {
        Program::load_with(path, LoadMode::Copy)
    }

    /// [`Program::load`] with an explicit [`LoadMode`].
    pub fn load_with(path: impl AsRef<Path>, mode: LoadMode) -> Result<Program> {
        let path = path.as_ref();
        let bytes = (|| -> std::io::Result<AlignedBytes> {
            let mut f = std::fs::File::open(path)?;
            let len = f.metadata()?.len();
            let len = usize::try_from(len).map_err(|_| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, "file too large")
            })?;
            AlignedBytes::read_exact_from(&mut f, len)
        })()
        .with_context(|| format!("reading {}", path.display()))?;
        from_shared(Arc::new(bytes), mode)
            .with_context(|| format!("loading artifact {}", path.display()))
    }

    /// Deserialize from in-memory artifact bytes (tests and corruption
    /// suites; file loads go through [`Program::load_with`]).
    pub fn from_artifact_bytes(bytes: &[u8], mode: LoadMode) -> Result<Program> {
        from_shared(Arc::new(AlignedBytes::from_bytes(bytes)), mode)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::DeconvImpl;

    #[test]
    fn align_up_is_64_multiples() {
        assert_eq!(align_up(0), 0);
        assert_eq!(align_up(1), 64);
        assert_eq!(align_up(64), 64);
        assert_eq!(align_up(65), 128);
    }

    #[test]
    fn codecs_round_trip() {
        let f = [1.5f32, -0.0, f32::MIN_POSITIVE, 3.25e8];
        assert_eq!(f32_from_le(&f32_to_le(&f)), f);
        let u = [0u32, 7, u32::MAX];
        assert_eq!(u32_from_le(&u32_to_le(&u)), u);
        let i = [0i8, -128, 127, -1];
        assert_eq!(i8_from_bytes(&i8_to_bytes(&i)), i);
    }

    #[test]
    fn ref_deconv_programs_are_not_serializable() {
        let net = crate::networks::dcgan();
        let p = Program::from_seed(&net, DeconvImpl::Native, 7).unwrap();
        let err = p.to_artifact_bytes().unwrap_err();
        assert!(
            err.downcast_ref::<ArtifactError>()
                .is_some_and(|e| matches!(e, ArtifactError::UnsupportedOp(_))),
            "{err}"
        );
    }

    #[test]
    fn header_too_short_and_bad_magic_are_typed() {
        let err = Program::from_artifact_bytes(&[0u8; 4], LoadMode::Copy).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ArtifactError>(),
            Some(ArtifactError::Truncated { .. })
        ));
        let err = Program::from_artifact_bytes(&[0u8; 64], LoadMode::Copy).unwrap_err();
        assert!(matches!(
            err.downcast_ref::<ArtifactError>(),
            Some(ArtifactError::BadMagic)
        ));
    }
}
