//! Compiled-plan inference engine: the native execution subsystem behind the
//! serving stack.
//!
//! The compiled state is split along the share/mutate line:
//!
//! * [`Program`] — the **immutable** compilation product (resolved ops,
//!   pre-split + packed SD filters, precomputed shapes). It is `Send +
//!   Sync` (compile-time asserted below) and is shared across dispatcher
//!   workers behind an `Arc`: one compile serves N executors.
//! * [`Scratch`] — the cheap **per-worker** buffer arena (ping-pong
//!   activation buffers, pad scratch, per-split conv outputs). Each worker
//!   owns one and passes it to [`Program::forward`].
//! * [`Plan`] — the single-threaded convenience pairing of the two
//!   (`Arc<Program>` + its own `Scratch`) with the original one-object
//!   API; benches, tests, and the quality evaluation use it.
//!
//! A [`Program`] is built **once** from a [`NetworkSpec`] + weights and then
//! reused for every forward call, the decompose-once-serve-many structure of
//! HUGE² (arXiv 1907.11210) applied to split deconvolution:
//!
//! * every layer is resolved to an op in a small registry — `Op::Dense`,
//!   `Op::Conv` (im2col + GEMM), `Op::SdDeconv`, `Op::RefDeconv` — with
//!   activations (ReLU between layers, tanh after the last) fused into
//!   the step;
//! * **every GEMM weight is packed at plan time**: SD deconvolution
//!   filters are pre-split ([`split_filters`] runs once per layer per
//!   plan) and each split — like every plain conv filter and dense matrix
//!   — is then packed into the microkernel's NR-wide panel operand
//!   ([`crate::tensor::gemm::PackedB`]; int8 programs additionally pack
//!   the SIMD kernel's pair-interleaved [`QPackedB`]), so the per-request
//!   serving path neither re-splits nor re-packs a weight on any forward
//!   call (re-splitting was the dominant per-request overhead of the old
//!   `report::quality` interpreter; per-call packing is what the direct
//!   `tensor::conv2d` paths still pay);
//! * all intermediate shapes are precomputed at build time, and execution
//!   runs inside a reusable per-worker [`Scratch`] arena instead of
//!   allocating per layer per call;
//! * the SD interleave + crop steps are fused into one pass
//!   ([`crate::sd::interleave_crop_into`]), skipping the intermediate
//!   `s * (I + K_T - 1)` grid the interpreter materializes;
//! * a whole dynamic batch executes as ONE pass per layer (batch packed into
//!   the tensor N axis), so the coordinator's batching widens the GEMM.
//!
//! The engine is bit-identical to the retained interpreter oracle
//! `report::quality::run_network_with` (zero-tolerance equivalence across
//! all six benchmarks in rust/tests/engine_equivalence.rs), and
//! `cargo bench --bench engine` measures plan-cached execution against the
//! per-call paths.
//!
//! ## Chain bridging
//!
//! Two of the six reverse-engineered benchmarks are not expressible as a
//! pure layer chain: MDE concatenates encoder skip connections into
//! `upconv3`, and GP-GAN's fc bottleneck (8192 -> 4000) feeds a 4x4x512
//! decoder entry through an unpublished reshape. At those points (and only
//! when flat element counts disagree) both the engine and the oracle apply
//! [`bridge_reshape`]: a deterministic truncate-or-zero-pad of each batch
//! element's flat activation vector. This keeps the published Table 1-3
//! MAC/parameter counts intact while making every benchmark runnable end to
//! end; see DESIGN.md section 6.

pub mod artifact;
pub mod weights;

pub use artifact::{ArtifactError, LoadMode};
pub use weights::{
    build_weights, pack_filter, pack_filters, smooth_filter, DeconvImpl, LayerWeights,
};

pub use crate::quant::Precision;

use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::nn::{LayerKind, NetworkSpec};
use crate::obs::{LayerStages, StageSink};
use crate::quant::{
    conv2d_i8_prepacked_into, quantize_dense, quantize_filter, quantize_into, scale_for_absmax,
    Epilogue, QFilter, QPackedB, QTensor,
};
use crate::sd::{chang::chang_deconv2d, nzp::nzp_deconv2d, shi::shi_deconv2d};
use crate::sd::{interleave_crop_into, split_filters, SdGeometry};
use crate::tensor::gemm::PackedB;
use crate::tensor::{
    conv2d_packed_valid_into, deconv2d, dense_packed_into, relu, tanh, Filter, Tensor,
};
use crate::util::rng::Rng;

/// Activation fused into each step: ReLU between layers, tanh after the
/// last (generator convention — matches the interpreter oracle).
enum Act {
    Relu,
    Tanh,
}

/// The op registry: what a layer lowers to at plan time. Every GEMM-backed
/// op carries its weight operand **pre-packed** into the microkernel's
/// panel layout (`PackedB` / `QPackedB`), built here at compile time — the
/// per-request path never packs a weight.
enum Op {
    /// fully-connected layer on the packed-panel GEMM (batch on the M
    /// axis); the packed operand is the only weight copy the program
    /// keeps, and carries the full geometry (`k` = n_in, `n` = n_out)
    Dense { packed: PackedB },
    /// standard convolution on the im2col + GEMM kernel; the packed
    /// panels are the only weight copy the program keeps (`kh`/`kw` carry
    /// the im2col geometry; channel counts are recoverable from the
    /// operand, and int8 lowering unpacks losslessly)
    Conv { kh: usize, kw: usize, packed: PackedB, s: usize, p: usize },
    /// split deconvolution with the `s*s` split filters pre-split and
    /// packed into panel operands (one per stride-1 sub-convolution;
    /// every split is `g.k_t` square, so — like `Conv` — the packed
    /// operands are the only copy kept)
    SdDeconv { packed: Vec<PackedB>, g: SdGeometry },
    /// reference deconvolution lowerings (native oracle / NZP / Shi /
    /// Chang) — kept in the registry so the quality evaluation runs every
    /// conversion approach through the same execution path
    RefDeconv { f: Filter, imp: DeconvImpl, s: usize, p: usize, out_pad: usize },
    /// int8 lowering of `Dense` and `Conv` (`Precision::Int8`): quantized
    /// constants prepared at compile time (including the SIMD kernel's
    /// pair-interleaved packed operand), activations quantized at the
    /// calibrated `in_scale`, i8 im2col + i32 GEMM with the fused
    /// requantize(+ReLU) epilogue. A dense layer is a 1x1 convolution over
    /// its `1 x 1 x n_in` view, so one quantized op serves both.
    QConv { qf: QFilter, packed: QPackedB, in_scale: f32, s: usize, p: usize },
    /// int8 lowering of `SdDeconv`: the pre-split sub-filters quantized
    /// and packed at compile time, each split running on the int8 conv
    /// kernel — the SD path itself (not just plain conv) runs quantized.
    QSdDeconv { splits: Vec<QFilter>, packed: Vec<QPackedB>, g: SdGeometry, in_scale: f32 },
}

/// One compiled layer: op + fused activation + precomputed shapes.
struct Step {
    name: &'static str,
    in_h: usize,
    in_w: usize,
    in_c: usize,
    out_h: usize,
    out_w: usize,
    out_c: usize,
    op: Op,
    act: Act,
}

/// Reusable per-worker buffers: successive steps ping-pong through `spare`,
/// SD deconvolutions share the `pad` scratch and per-split output slots.
/// Int8 programs additionally use the i8 arenas `qin` / `qpad` (quantized
/// activations and their padded view; the kernel's i32 accumulators live in
/// its own per-thread scratch). Buffers grow to the high-water mark of the
/// program's shapes and are reused across forward calls (no per-layer
/// allocation on the hot path). A `Scratch` is cheap to create (empty
/// buffers) — the serving stack gives each dispatcher worker its own while
/// all workers share one [`Program`].
pub struct Scratch {
    spare: Vec<f32>,
    pad: Tensor,
    splits: Vec<Tensor>,
    qin: QTensor,
    qpad: QTensor,
    /// per-column requantization scales of the current int8 op
    /// (`in_scale * weight_scale[o]`) — rebuilt per step, reusing capacity
    colscale: Vec<f32>,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch {
            spare: Vec::new(),
            pad: Tensor::zeros(0, 0, 0, 0),
            splits: Vec::new(),
            qin: QTensor::empty(),
            qpad: QTensor::empty(),
            colscale: Vec::new(),
        }
    }
}

impl Default for Scratch {
    fn default() -> Scratch {
        Scratch::new()
    }
}

/// A network compiled for repeated execution: resolved ops, pre-split SD
/// filters, precomputed shapes. Immutable after [`Program::build`] — all
/// mutable execution state lives in the caller's [`Scratch`] — so one
/// `Arc<Program>` serves any number of concurrent executors.
pub struct Program {
    name: &'static str,
    steps: Vec<Step>,
    precision: Precision,
    in_h: usize,
    in_w: usize,
    in_c: usize,
    out_len: usize,
}

/// Latents per calibration sweep batch (see [`Program::build_owned_prec`]).
const CALIB_BATCH: usize = 6;

/// Seed of the calibration sweep — fixed, so a model + weight seed always
/// compiles to the same quantized constants.
const CALIB_SEED: u64 = 0xCA11B;

/// Headroom multiplier on the swept activation absmax: serving inputs are
/// not the calibration inputs, and saturating a fresh latent's outlier
/// costs more image quality than spending ~10% of the i8 range on margin.
const CALIB_MARGIN: f32 = 1.1;

// The serving stack shares one compiled Program across dispatcher workers
// behind an `Arc`; a field that silently lost Send + Sync would break that
// at a distance, so lock it down at compile time.
const _: fn() = || {
    fn assert_send_sync<T: Send + Sync>() {}
    assert_send_sync::<Program>();
};

impl Program {
    /// Compile a network + weights into an executable program. Errors
    /// (rather than panicking) on weight-count, weight-kind, and
    /// weight-shape mismatches. This borrowed form clones each weight
    /// buffer once; callers that do not need the weights afterwards should
    /// use [`Program::build_owned`] (or [`Program::from_seed`]), which
    /// moves them.
    pub fn build(
        net: &NetworkSpec,
        weights: &[LayerWeights],
        imp: DeconvImpl,
    ) -> Result<Program> {
        Program::build_owned(net, weights.to_vec(), imp)
    }

    /// [`Program::build`] at an explicit [`Precision`].
    pub fn build_prec(
        net: &NetworkSpec,
        weights: &[LayerWeights],
        imp: DeconvImpl,
        precision: Precision,
    ) -> Result<Program> {
        Program::build_owned_prec(net, weights.to_vec(), imp, precision)
    }

    /// [`Program::build`] consuming the weights — no buffer copies (GP-GAN's
    /// bottleneck matrix alone is ~131 MB).
    pub fn build_owned(
        net: &NetworkSpec,
        weights: Vec<LayerWeights>,
        imp: DeconvImpl,
    ) -> Result<Program> {
        Program::build_owned_prec(net, weights, imp, Precision::F32)
    }

    /// [`Program::build_owned`] at an explicit [`Precision`].
    ///
    /// `Precision::Int8` compiles the **quantized** program: the f32 steps
    /// are built first, a seeded latent sweep (`CALIB_BATCH` latents,
    /// seed `CALIB_SEED`) runs through them once to calibrate each
    /// step's per-tensor activation scale, and every `Dense` / `Conv` /
    /// `SdDeconv` op is then lowered to its int8 form with all quantized
    /// constants (per-output-channel weights, packed SD sub-filters,
    /// activation scales) prepared here, at compile time — the serving hot
    /// path never quantizes a weight or inspects a statistic. Reference
    /// deconvolution lowerings (`DeconvImpl` other than `Sd`) stay f32:
    /// they exist as quality baselines, not serving paths.
    pub fn build_owned_prec(
        net: &NetworkSpec,
        weights: Vec<LayerWeights>,
        imp: DeconvImpl,
        precision: Precision,
    ) -> Result<Program> {
        if weights.len() != net.layers.len() {
            bail!(
                "{}: {} weight entries for {} layers",
                net.name,
                weights.len(),
                net.layers.len()
            );
        }
        let last = match net.layers.len().checked_sub(1) {
            Some(last) => last,
            None => bail!("{}: cannot compile an empty network", net.name),
        };
        let mut steps = Vec::with_capacity(net.layers.len());
        for (i, (l, lw)) in net.layers.iter().zip(weights).enumerate() {
            let op = match (l.kind, lw) {
                (LayerKind::Dense, LayerWeights::Dense(w)) => {
                    let n_in = l.in_h * l.in_w * l.in_c;
                    if w.len() != n_in * l.out_c {
                        bail!(
                            "{}.{}: dense weight length {} != {} x {}",
                            net.name,
                            l.name,
                            w.len(),
                            n_in,
                            l.out_c
                        );
                    }
                    // plan-time packing; the packed panels are the only
                    // copy the program keeps (GP-GAN's bottleneck matrix
                    // is ~131 MB — no second buffer)
                    Op::Dense { packed: PackedB::pack(&w, n_in, l.out_c) }
                }
                (LayerKind::Conv, LayerWeights::Filter(f)) => {
                    check_filter(net.name, l.name, &f, l.k, l.in_c, l.out_c)?;
                    let packed = pack_filter(&f);
                    Op::Conv { kh: f.kh, kw: f.kw, packed, s: l.s, p: l.p }
                }
                (LayerKind::Deconv, LayerWeights::Filter(f)) => {
                    check_filter(net.name, l.name, &f, l.k, l.in_c, l.out_c)?;
                    match imp {
                        DeconvImpl::Sd => {
                            let packed = pack_filters(&split_filters(&f, l.s));
                            Op::SdDeconv { packed, g: SdGeometry::new(l.k, l.s, l.p) }
                        }
                        other => Op::RefDeconv {
                            f,
                            imp: other,
                            s: l.s,
                            p: l.p,
                            out_pad: l.op,
                        },
                    }
                }
                _ => bail!(
                    "{}.{}: weight kind does not match layer kind {:?}",
                    net.name,
                    l.name,
                    l.kind
                ),
            };
            steps.push(Step {
                name: l.name,
                in_h: l.in_h,
                in_w: l.in_w,
                in_c: l.in_c,
                out_h: l.out_h(),
                out_w: l.out_w(),
                out_c: l.out_c,
                op,
                act: if i == last { Act::Tanh } else { Act::Relu },
            });
        }
        let first = &steps[0];
        let (in_h, in_w, in_c) = (first.in_h, first.in_w, first.in_c);
        let last_step = &steps[last];
        let out_len = last_step.out_h * last_step.out_w * last_step.out_c;
        let mut program = Program {
            name: net.name,
            steps,
            precision: Precision::F32,
            in_h,
            in_w,
            in_c,
            out_len,
        };
        if precision == Precision::Int8 {
            program.quantize_steps()?;
        }
        Ok(program)
    }

    /// [`Program::build`] with weights drawn from
    /// [`build_weights`]`(net, seed)`.
    pub fn from_seed(net: &NetworkSpec, imp: DeconvImpl, seed: u64) -> Result<Program> {
        Program::build_owned(net, build_weights(net, seed), imp)
    }

    /// [`Program::from_seed`] at an explicit [`Precision`].
    pub fn from_seed_prec(
        net: &NetworkSpec,
        imp: DeconvImpl,
        seed: u64,
        precision: Precision,
    ) -> Result<Program> {
        Program::build_owned_prec(net, build_weights(net, seed), imp, precision)
    }

    /// Lower every quantizable op to its int8 form (see
    /// [`Program::build_owned_prec`]): calibrate activation scales with a
    /// seeded latent sweep through the still-f32 steps, then replace the
    /// ops with quantized-constant versions.
    fn quantize_steps(&mut self) -> Result<()> {
        // calibration sweep: per-step input absmax over one seeded batch
        let mut rng = Rng::new(CALIB_SEED);
        let mut h = Tensor::from_fn(CALIB_BATCH, self.in_h, self.in_w, self.in_c, || rng.normal());
        let mut scratch = Scratch::new();
        let mut absmaxes = Vec::with_capacity(self.steps.len());
        for step in &self.steps {
            absmaxes.push(crate::quant::absmax(&h.data));
            h = run_step(step, h, &mut scratch, None)?;
        }
        let steps = std::mem::take(&mut self.steps);
        self.steps = steps
            .into_iter()
            .zip(absmaxes)
            .map(|(mut step, am)| {
                let in_scale = scale_for_absmax(am * CALIB_MARGIN);
                step.op = match step.op {
                    Op::Dense { packed } => {
                        // the f32 program keeps only the packed panels;
                        // unpack once here (lossless) to quantize
                        let (n_in, n_out) = (packed.k, packed.n);
                        let qf = quantize_dense(packed.unpack(), n_in, n_out);
                        let qpacked = QPackedB::pack(&qf);
                        Op::QConv { qf, packed: qpacked, in_scale, s: 1, p: 0 }
                    }
                    Op::Conv { kh, kw, packed, s, p } => {
                        // reconstruct the HWIO payload losslessly from the
                        // packed panels (the f32 program keeps no raw copy)
                        let ic = packed.k / (kh * kw);
                        let f = Filter::from_vec(kh, kw, ic, packed.n, packed.unpack());
                        let qf = quantize_filter(&f);
                        let qpacked = QPackedB::pack(&qf);
                        Op::QConv { qf, packed: qpacked, in_scale, s, p }
                    }
                    Op::SdDeconv { packed, g } => {
                        let qsplits: Vec<QFilter> = packed
                            .iter()
                            .map(|pb| {
                                let ic = pb.k / (g.k_t * g.k_t);
                                let w = Filter::from_vec(g.k_t, g.k_t, ic, pb.n, pb.unpack());
                                quantize_filter(&w)
                            })
                            .collect();
                        let qpacked = qsplits.iter().map(QPackedB::pack).collect();
                        Op::QSdDeconv { splits: qsplits, packed: qpacked, g, in_scale }
                    }
                    // reference deconv lowerings stay f32 (quality
                    // baselines, not serving paths)
                    other => other,
                };
                step
            })
            .collect();
        self.precision = Precision::Int8;
        Ok(())
    }

    /// Network name this program was compiled from.
    pub fn name(&self) -> &'static str {
        self.name
    }

    /// Numeric precision this program was compiled at.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Flat per-request input element count (the first layer's input view).
    pub fn input_len(&self) -> usize {
        self.in_h * self.in_w * self.in_c
    }

    /// Flat per-request output element count.
    pub fn output_len(&self) -> usize {
        self.out_len
    }

    /// Execute the whole program on a batched input tensor (batch on the N
    /// axis). One pass per layer; intermediate activations live in the
    /// caller's [`Scratch`]. The *network input* is validated strictly (a
    /// wrong-sized request is an error); [`bridge_reshape`] only ever
    /// applies between layers, at the documented chain-gap points.
    pub fn forward(&self, input: &Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        self.forward_owned(input.clone(), scratch)
    }

    /// [`Program::forward`] consuming the input tensor (no copy) — the
    /// serving path's entry point, where the packed batch has no other
    /// owner.
    pub fn forward_owned(&self, input: Tensor, scratch: &mut Scratch) -> Result<Tensor> {
        self.forward_owned_traced(input, scratch, None)
    }

    /// [`Program::forward_owned`] with an optional per-layer stage sink
    /// (DESIGN.md §12). With `Some(sink)`, every step accumulates its
    /// im2col/GEMM/epilogue/interleave wall time into the sink's row for
    /// that layer; with `None` this is exactly `forward_owned` — every
    /// timing site checks the `Option` **before** touching the clock, so
    /// the untraced path takes zero extra `Instant::now()` calls. Tracing
    /// never changes the computed bits (regression-tested below).
    pub fn forward_owned_traced(
        &self,
        input: Tensor,
        scratch: &mut Scratch,
        mut sink: Option<&mut StageSink>,
    ) -> Result<Tensor> {
        let per = input.h * input.w * input.c;
        if per != self.input_len() {
            bail!(
                "{}: input has {} elements per request, expected {}",
                self.name,
                per,
                self.input_len()
            );
        }
        let mut h = input;
        for step in &self.steps {
            let stages = sink.as_deref_mut().map(|s| s.layer_mut(step.name));
            h = run_step(step, h, scratch, stages)?;
        }
        Ok(h)
    }

    /// Serve a dynamic batch of flat per-request inputs: pack into one
    /// tensor, run [`Program::forward`] once, unpack one image per request.
    pub fn execute_batch(
        &self,
        batch: &[Vec<f32>],
        scratch: &mut Scratch,
    ) -> Result<Vec<Vec<f32>>> {
        self.execute_batch_traced(batch, scratch, None)
    }

    /// [`Program::execute_batch`] with an optional per-layer stage sink —
    /// see [`Program::forward_owned_traced`] for the contract.
    pub fn execute_batch_traced(
        &self,
        batch: &[Vec<f32>],
        scratch: &mut Scratch,
        sink: Option<&mut StageSink>,
    ) -> Result<Vec<Vec<f32>>> {
        if batch.is_empty() {
            return Ok(Vec::new());
        }
        let ilen = self.input_len();
        let mut data = Vec::with_capacity(batch.len() * ilen);
        for z in batch {
            if z.len() != ilen {
                bail!("{}: input length {} != expected {}", self.name, z.len(), ilen);
            }
            data.extend_from_slice(z);
        }
        let input = Tensor::from_vec(batch.len(), self.in_h, self.in_w, self.in_c, data);
        let img = self.forward_owned_traced(input, scratch, sink)?;
        debug_assert_eq!(img.len() / img.n, self.out_len);
        let per = self.out_len;
        Ok((0..batch.len())
            .map(|i| img.data[i * per..(i + 1) * per].to_vec())
            .collect())
    }
}

/// An `Arc<Program>` paired with its own [`Scratch`]: the single-threaded
/// convenience view with the original one-object API. Benches, tests, and
/// the quality evaluation use it; the multi-worker serving stack instead
/// shares the program and gives each worker its own scratch (see
/// [`Plan::from_program`] / [`Plan::program`]).
pub struct Plan {
    program: Arc<Program>,
    scratch: Scratch,
}

impl Plan {
    /// Compile a network + weights. See [`Program::build`].
    pub fn build(net: &NetworkSpec, weights: &[LayerWeights], imp: DeconvImpl) -> Result<Plan> {
        Ok(Plan::from_program(Arc::new(Program::build(net, weights, imp)?)))
    }

    /// [`Plan::build`] consuming the weights. See [`Program::build_owned`].
    pub fn build_owned(
        net: &NetworkSpec,
        weights: Vec<LayerWeights>,
        imp: DeconvImpl,
    ) -> Result<Plan> {
        Ok(Plan::from_program(Arc::new(Program::build_owned(net, weights, imp)?)))
    }

    /// [`Plan::build_owned`] at an explicit [`Precision`]. See
    /// [`Program::build_owned_prec`].
    pub fn build_owned_prec(
        net: &NetworkSpec,
        weights: Vec<LayerWeights>,
        imp: DeconvImpl,
        precision: Precision,
    ) -> Result<Plan> {
        Ok(Plan::from_program(Arc::new(Program::build_owned_prec(
            net, weights, imp, precision,
        )?)))
    }

    /// [`Plan::build`] with weights drawn from [`build_weights`]`(net, seed)`.
    pub fn from_seed(net: &NetworkSpec, imp: DeconvImpl, seed: u64) -> Result<Plan> {
        Ok(Plan::from_program(Arc::new(Program::from_seed(net, imp, seed)?)))
    }

    /// [`Plan::from_seed`] at an explicit [`Precision`].
    pub fn from_seed_prec(
        net: &NetworkSpec,
        imp: DeconvImpl,
        seed: u64,
        precision: Precision,
    ) -> Result<Plan> {
        Ok(Plan::from_program(Arc::new(Program::from_seed_prec(
            net, imp, seed, precision,
        )?)))
    }

    /// Numeric precision of the underlying program.
    pub fn precision(&self) -> Precision {
        self.program.precision()
    }

    /// Pair an already-compiled (possibly shared) program with a fresh
    /// scratch. This is how sibling executors are spawned: `Arc` clones of
    /// one program, one scratch each.
    pub fn from_program(program: Arc<Program>) -> Plan {
        Plan {
            program,
            scratch: Scratch::new(),
        }
    }

    /// The shared compiled program behind this plan.
    pub fn program(&self) -> &Arc<Program> {
        &self.program
    }

    /// Network name this plan was compiled from.
    pub fn name(&self) -> &'static str {
        self.program.name()
    }

    /// Flat per-request input element count (the first layer's input view).
    pub fn input_len(&self) -> usize {
        self.program.input_len()
    }

    /// Flat per-request output element count.
    pub fn output_len(&self) -> usize {
        self.program.output_len()
    }

    /// [`Program::forward`] against this plan's own scratch.
    pub fn forward(&mut self, input: &Tensor) -> Result<Tensor> {
        self.program.forward(input, &mut self.scratch)
    }

    /// [`Program::forward_owned`] against this plan's own scratch.
    pub fn forward_owned(&mut self, input: Tensor) -> Result<Tensor> {
        self.program.forward_owned(input, &mut self.scratch)
    }

    /// [`Program::execute_batch`] against this plan's own scratch.
    pub fn execute_batch(&mut self, batch: &[Vec<f32>]) -> Result<Vec<Vec<f32>>> {
        self.program.execute_batch(batch, &mut self.scratch)
    }

    /// [`Program::execute_batch_traced`] against this plan's own scratch.
    pub fn execute_batch_traced(
        &mut self,
        batch: &[Vec<f32>],
        sink: Option<&mut StageSink>,
    ) -> Result<Vec<Vec<f32>>> {
        self.program.execute_batch_traced(batch, &mut self.scratch, sink)
    }
}

fn check_filter(net: &str, layer: &str, f: &Filter, k: usize, ic: usize, oc: usize) -> Result<()> {
    if (f.kh, f.kw, f.ic, f.oc) != (k, k, ic, oc) {
        bail!(
            "{net}.{layer}: filter shape {}x{}x{}x{} != spec {k}x{k}x{ic}x{oc}",
            f.kh,
            f.kw,
            f.ic,
            f.oc
        );
    }
    Ok(())
}

/// Names of the layers whose declared input disagrees with the previous
/// layer's declared output — the spec's chain gaps, and therefore the ONLY
/// points where [`bridge_reshape`] can fire at run time (both the engine
/// and the oracle validate every op's output against its own layer spec,
/// so a kernel regression errors instead of bridging). For the canonical
/// six benchmarks this is exactly `GP-GAN.dec1` and `MDE.upconv3`, locked
/// by `engine_equivalence::only_the_documented_chain_gaps_bridge` — a
/// layer-table typo that opened a new silent gap would fail that test.
pub fn chain_gaps(net: &NetworkSpec) -> Vec<&'static str> {
    let mut gaps = Vec::new();
    let mut prev_out: Option<usize> = None;
    for l in &net.layers {
        let in_count = l.in_h * l.in_w * l.in_c;
        if let Some(po) = prev_out {
            if po != in_count {
                gaps.push(l.name);
            }
        }
        prev_out = Some(l.out_h() * l.out_w() * l.out_c);
    }
    gaps
}

/// Adapt an activation to the `ih x iw x ic` view the next layer expects.
/// Matching flat counts reshape in place (no copy). Mismatched counts —
/// the chain-spec's skip-connection / bottleneck-reshape points, see the
/// module docs — truncate or zero-pad each batch element's flat vector,
/// deterministically. Shared by the engine and the interpreter oracle so
/// both paths stay bit-identical.
pub fn bridge_reshape(h: Tensor, ih: usize, iw: usize, ic: usize) -> Tensor {
    let want = ih * iw * ic;
    let per = h.h * h.w * h.c;
    if per == want {
        return Tensor { n: h.n, h: ih, w: iw, c: ic, data: h.data };
    }
    let copy = per.min(want);
    let mut out = Tensor::zeros(h.n, ih, iw, ic);
    for n in 0..h.n {
        out.data[n * want..n * want + copy].copy_from_slice(&h.data[n * per..n * per + copy]);
    }
    out
}

/// Wrap the scratch's spare buffer as an (empty) tensor; the `*_into` ops
/// reshape and fill it. The previous step's input buffer is returned to the
/// scratch at the end of [`run_step`], so successive steps ping-pong.
fn take_tensor(slot: &mut Vec<f32>) -> Tensor {
    Tensor { n: 0, h: 0, w: 0, c: 0, data: std::mem::take(slot) }
}

fn run_ref_deconv(
    x: &Tensor,
    f: &Filter,
    imp: DeconvImpl,
    s: usize,
    p: usize,
    op: usize,
) -> Tensor {
    match imp {
        DeconvImpl::Native => deconv2d(x, f, s, p, op),
        DeconvImpl::Nzp => nzp_deconv2d(x, f, s, p, op),
        DeconvImpl::Shi => shi_deconv2d(x, f, s, p, op),
        DeconvImpl::Chang => chang_deconv2d(x, f, s, p, op),
        DeconvImpl::Sd => unreachable!("SD lowers to Op::SdDeconv at plan time"),
    }
}

/// Execute one compiled step: bridge the input view, run the op into
/// scratch buffers, apply the fused activation, recycle the input buffer.
/// Quantized ops fuse their mid-layer ReLU into the kernel's requantize
/// epilogue (`act_done`); every other op gets the activation applied here.
///
/// `stages` is the optional per-layer trace row (DESIGN.md §12): when
/// `Some`, the op's phases accumulate wall time into it under the
/// taxonomy documented on [`LayerStages`] (explicit input prep —
/// padding/quantization — under `im2col_us`, kernel calls under
/// `gemm_us`, the activation pass under `epilogue_us`, SD scatter under
/// `interleave_us`). When `None`, no `Instant::now()` is taken anywhere
/// in this function: tracing is strictly zero-cost when disabled, and it
/// never changes the computed bits either way.
fn run_step(
    step: &Step,
    h: Tensor,
    a: &mut Scratch,
    mut stages: Option<&mut LayerStages>,
) -> Result<Tensor> {
    // Time `$work` into the `$slot` field of the trace row, iff tracing
    // is on. The clock is only consulted when `stages` is `Some`.
    macro_rules! stage {
        ($slot:ident, $work:expr) => {{
            let t0 = if stages.is_some() { Some(Instant::now()) } else { None };
            let r = $work;
            if let Some(t0) = t0 {
                if let Some(s) = stages.as_deref_mut() {
                    s.$slot += t0.elapsed().as_micros() as u64;
                }
            }
            r
        }};
    }
    let n = h.n;
    let h = bridge_reshape(h, step.in_h, step.in_w, step.in_c);
    let (mut out, act_done) = match &step.op {
        Op::Dense { packed } => {
            let mut out = take_tensor(&mut a.spare);
            stage!(gemm_us, dense_packed_into(&h, packed, &mut out))?;
            (out, false)
        }
        Op::Conv { kh, kw, packed, s, p } => {
            let mut out = take_tensor(&mut a.spare);
            if *p > 0 {
                stage!(im2col_us, h.pad_into(*p, *p, *p, *p, &mut a.pad));
                stage!(gemm_us, conv2d_packed_valid_into(&a.pad, *kh, *kw, *s, packed, &mut out));
            } else {
                stage!(gemm_us, conv2d_packed_valid_into(&h, *kh, *kw, *s, packed, &mut out));
            }
            (out, false)
        }
        Op::SdDeconv { packed, g } => {
            stage!(im2col_us, h.pad_into(g.p_i, g.p_i, g.p_i, g.p_i, &mut a.pad));
            if a.splits.len() < packed.len() {
                a.splits.resize_with(packed.len(), || Tensor::zeros(0, 0, 0, 0));
            }
            stage!(
                gemm_us,
                for (pb, slot) in packed.iter().zip(a.splits.iter_mut()) {
                    // every SD split filter is g.k_t square (Eq. 1)
                    conv2d_packed_valid_into(&a.pad, g.k_t, g.k_t, 1, pb, slot);
                }
            );
            let mut out = take_tensor(&mut a.spare);
            stage!(
                interleave_us,
                interleave_crop_into(
                    &a.splits[..packed.len()],
                    g.s,
                    g.crop(),
                    step.out_h,
                    step.out_w,
                    &mut out,
                )
            );
            (out, false)
        }
        Op::RefDeconv { f, imp, s, p, out_pad } => {
            let out = stage!(gemm_us, run_ref_deconv(&h, f, *imp, *s, *p, *out_pad));
            (out, false)
        }
        Op::QConv { qf, packed, in_scale, s, p } => {
            // quantize at the calibrated per-tensor scale, convolve on the
            // int8 kernel with the mid-layer ReLU fused into the
            // requantize epilogue; the per-column scales go into a reused
            // scratch buffer (compile-time constants, no per-layer alloc)
            stage!(im2col_us, quantize_into(&h, *in_scale, &mut a.qin));
            a.colscale.clear();
            a.colscale.extend(qf.scales.iter().map(|&sc| *in_scale * sc));
            let epi = match step.act {
                Act::Relu => Epilogue::relu(),
                Act::Tanh => Epilogue::none(),
            };
            let mut out = take_tensor(&mut a.spare);
            if *p > 0 {
                stage!(im2col_us, a.qin.pad_into(*p, *p, *p, *p, &mut a.qpad));
                stage!(
                    gemm_us,
                    conv2d_i8_prepacked_into(&a.qpad, qf, packed, *s, &a.colscale, epi, &mut out)
                );
            } else {
                stage!(
                    gemm_us,
                    conv2d_i8_prepacked_into(&a.qin, qf, packed, *s, &a.colscale, epi, &mut out)
                );
            }
            (out, matches!(step.act, Act::Relu))
        }
        Op::QSdDeconv { splits, packed, g, in_scale } => {
            // one quantize + pad of the input, then every packed int8
            // sub-filter runs a stride-1 int8 convolution; the splits
            // requantize to f32 and interleave exactly like the f32 path
            stage!(im2col_us, quantize_into(&h, *in_scale, &mut a.qin));
            stage!(im2col_us, a.qin.pad_into(g.p_i, g.p_i, g.p_i, g.p_i, &mut a.qpad));
            if a.splits.len() < splits.len() {
                a.splits.resize_with(splits.len(), || Tensor::zeros(0, 0, 0, 0));
            }
            stage!(
                gemm_us,
                for ((w, pb), slot) in splits.iter().zip(packed).zip(a.splits.iter_mut()) {
                    a.colscale.clear();
                    a.colscale.extend(w.scales.iter().map(|&sc| *in_scale * sc));
                    conv2d_i8_prepacked_into(
                        &a.qpad,
                        w,
                        pb,
                        1,
                        &a.colscale,
                        Epilogue::none(),
                        slot,
                    );
                }
            );
            let mut out = take_tensor(&mut a.spare);
            stage!(
                interleave_us,
                interleave_crop_into(
                    &a.splits[..splits.len()],
                    g.s,
                    g.crop(),
                    step.out_h,
                    step.out_w,
                    &mut out,
                )
            );
            (out, false)
        }
    };
    if out.n != n || out.h != step.out_h || out.w != step.out_w || out.c != step.out_c {
        bail!(
            "{}: produced {:?}, plan expected [{n}, {}, {}, {}]",
            step.name,
            out.shape(),
            step.out_h,
            step.out_w,
            step.out_c
        );
    }
    match step.act {
        Act::Relu if !act_done => stage!(epilogue_us, relu(&mut out)),
        Act::Relu => {}
        Act::Tanh => stage!(epilogue_us, tanh(&mut out)),
    }
    a.spare = h.data; // recycle the input buffer for the step after next
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::networks;
    use crate::util::rng::Rng;

    #[test]
    fn plan_reports_io_shapes() {
        let net = networks::dcgan();
        let plan = Plan::from_seed(&net, DeconvImpl::Sd, 1).unwrap();
        assert_eq!(plan.input_len(), 100);
        assert_eq!(plan.output_len(), 64 * 64 * 3);
        assert_eq!(plan.name(), "DCGAN");
    }

    #[test]
    fn build_rejects_mismatched_weights() {
        let net = networks::dcgan();
        let mut w = build_weights(&net, 1);
        w.pop();
        assert!(Plan::build(&net, &w, DeconvImpl::Sd).is_err());
        // kind mismatch: dense weights on a deconv layer
        let mut w = build_weights(&net, 1);
        w[1] = LayerWeights::Dense(vec![0.0; 4]);
        assert!(Plan::build(&net, &w, DeconvImpl::Sd).is_err());
    }

    #[test]
    fn execute_batch_validates_input_length() {
        let net = networks::dcgan();
        let mut plan = Plan::from_seed(&net, DeconvImpl::Sd, 1).unwrap();
        assert!(plan.execute_batch(&[vec![0.0; 7]]).is_err());
        assert!(plan.execute_batch(&[]).unwrap().is_empty());
    }

    #[test]
    fn bridge_reshape_pads_and_truncates() {
        let x = Tensor::from_vec(2, 1, 1, 3, vec![1., 2., 3., 4., 5., 6.]);
        // exact count: pure reshape, same data
        let r = bridge_reshape(x.clone(), 3, 1, 1);
        assert_eq!(r.shape(), [2, 3, 1, 1]);
        assert_eq!(r.data, x.data);
        // pad: per-element zero fill
        let p = bridge_reshape(x.clone(), 1, 1, 5);
        assert_eq!(p.data, vec![1., 2., 3., 0., 0., 4., 5., 6., 0., 0.]);
        // truncate: per-element prefix
        let t = bridge_reshape(x, 1, 1, 2);
        assert_eq!(t.data, vec![1., 2., 4., 5.]);
    }

    #[test]
    fn shared_program_with_fresh_scratch_matches() {
        let net = networks::dcgan();
        let mut plan = Plan::from_seed(&net, DeconvImpl::Sd, 3).unwrap();
        let mut rng = Rng::new(8);
        let z = vec![rng.normal_vec(100)];
        let want = plan.execute_batch(&z).unwrap();
        // a sibling executor: same Arc<Program>, its own fresh Scratch
        let mut sibling = Plan::from_program(plan.program().clone());
        assert_eq!(sibling.execute_batch(&z).unwrap(), want);
        // and the raw Program + Scratch API underneath
        let mut scratch = Scratch::new();
        assert_eq!(plan.program().execute_batch(&z, &mut scratch).unwrap(), want);
    }

    #[test]
    fn int8_plan_compiles_and_tracks_f32() {
        let net = networks::scaled(&networks::dcgan(), 2);
        let mut f32_plan = Plan::from_seed(&net, DeconvImpl::Sd, 3).unwrap();
        let mut i8_plan = Plan::from_seed_prec(&net, DeconvImpl::Sd, 3, Precision::Int8).unwrap();
        assert_eq!(f32_plan.precision(), Precision::F32);
        assert_eq!(i8_plan.precision(), Precision::Int8);
        assert_eq!(i8_plan.input_len(), f32_plan.input_len());
        assert_eq!(i8_plan.output_len(), f32_plan.output_len());
        let mut rng = Rng::new(12);
        let z = vec![rng.normal_vec(i8_plan.input_len())];
        let a = f32_plan.execute_batch(&z).unwrap();
        let b = i8_plan.execute_batch(&z).unwrap();
        // same geometry; values close but NOT identical (it really
        // quantized). The strict accuracy bar is the SSIM gate in
        // rust/tests/quant.rs.
        assert_eq!(a[0].len(), b[0].len());
        let max = a[0]
            .iter()
            .zip(&b[0])
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max > 0.0, "int8 path produced bit-identical f32 output");
        assert!(max < 0.25, "int8 drifted {max} from f32 on tanh output");
    }

    #[test]
    fn int8_batch_rows_equal_single_rows() {
        // the quantized path must stay deterministic and batch-invariant:
        // per-tensor scales are calibrated constants, not batch statistics
        let net = networks::scaled(&networks::dcgan(), 2);
        let mut plan = Plan::from_seed_prec(&net, DeconvImpl::Sd, 3, Precision::Int8).unwrap();
        let mut rng = Rng::new(9);
        let zs: Vec<Vec<f32>> = (0..2).map(|_| rng.normal_vec(100)).collect();
        let batched = plan.execute_batch(&zs).unwrap();
        for (i, z) in zs.iter().enumerate() {
            let single = plan.execute_batch(std::slice::from_ref(z)).unwrap();
            assert_eq!(batched[i], single[0], "int8 request {i} differs");
        }
    }

    #[test]
    fn traced_execution_is_bit_identical_and_fills_the_sink() {
        // The StageSink only *observes*: turning it on must not change a
        // single output bit, on the f32 path or the int8 path.
        for precision in [Precision::F32, Precision::Int8] {
            let net = networks::scaled(&networks::dcgan(), 2);
            let mut plan = Plan::from_seed_prec(&net, DeconvImpl::Sd, 3, precision).unwrap();
            let mut rng = Rng::new(21);
            let zs: Vec<Vec<f32>> = (0..2).map(|_| rng.normal_vec(100)).collect();
            let untraced = plan.execute_batch(&zs).unwrap();
            let mut sink = StageSink::new();
            let traced = plan.execute_batch_traced(&zs, Some(&mut sink)).unwrap();
            assert_eq!(untraced, traced, "{precision:?}: tracing changed output bits");
            // one row per layer, in execution order, with the kernel
            // stage populated everywhere and the SD stages populated on
            // deconv layers
            assert_eq!(sink.layers.len(), net.layers.len());
            for (row, l) in sink.layers.iter().zip(&net.layers) {
                assert_eq!(row.layer, l.name);
            }
            let deconv_rows: Vec<_> = sink
                .layers
                .iter()
                .zip(&net.layers)
                .filter(|(_, l)| matches!(l.kind, LayerKind::Deconv))
                .map(|(row, _)| row)
                .collect();
            assert!(!deconv_rows.is_empty());
            // wall-clock micros can legitimately be 0 on a fast machine,
            // so assert structure (totals add up) rather than positivity
            for row in &sink.layers {
                assert_eq!(
                    row.total_us(),
                    row.im2col_us + row.gemm_us + row.epilogue_us + row.interleave_us
                );
            }
            assert!(sink.to_json().contains("\"layer\""));
        }
    }

    #[test]
    fn forward_batch_rows_equal_single_rows() {
        // batch packing must not change per-request results (bitwise)
        let net = networks::dcgan();
        let mut plan = Plan::from_seed(&net, DeconvImpl::Sd, 3).unwrap();
        let mut rng = Rng::new(8);
        let zs: Vec<Vec<f32>> = (0..2).map(|_| rng.normal_vec(100)).collect();
        let batched = plan.execute_batch(&zs).unwrap();
        for (i, z) in zs.iter().enumerate() {
            let single = plan.execute_batch(std::slice::from_ref(z)).unwrap();
            assert_eq!(batched[i], single[0], "request {i} differs");
        }
    }
}
