//! Network weight substrate shared by the engine and the quality oracle.
//!
//! Weights are seeded-random (the repo carries no trained checkpoints — see
//! DESIGN.md section 6): conversion *exactness*, the property both the
//! serving path and Table 4 rely on, is weight-independent. [`build_weights`]
//! seeds per layer index, so every consumer (compiled plans, the retained
//! interpreter oracle, the quality evaluation) draws bit-identical weights
//! for the same network + seed.

use crate::nn::{LayerKind, NetworkSpec};
use crate::tensor::gemm::PackedB;
use crate::tensor::Filter;
use crate::util::rng::Rng;

/// Deconvolution implementation used when executing a network.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeconvImpl {
    /// direct transposed convolution (the oracle)
    Native,
    /// split deconvolution (the paper; exact)
    Sd,
    /// naive zero padding (exact, redundant)
    Nzp,
    /// Shi et al. [30] fixed right/bottom padding (wrong on boundaries)
    Shi,
    /// Chang & Kang [31] approximate conversion
    Chang,
}

impl DeconvImpl {
    pub fn label(&self) -> &'static str {
        match self {
            DeconvImpl::Native => "native",
            DeconvImpl::Sd => "SD",
            DeconvImpl::Nzp => "NZP",
            DeconvImpl::Shi => "Shi [30]",
            DeconvImpl::Chang => "Chang [31]",
        }
    }
}

/// Pre-built weights of one layer (see [`build_weights`]).
#[derive(Clone)]
pub enum LayerWeights {
    /// dense-layer weight matrix, n_in x n_out row-major
    Dense(Vec<f32>),
    /// conv / deconv filter
    Filter(Filter),
}

/// Smooth, trained-like filter: gaussian spatial profile x near-identity
/// channel mixing + moderate noise. Purely random filters decorrelate any
/// perturbation within one layer, which collapses every inexact baseline to
/// SSIM ~ 0 regardless of how wrong it is; trained generators are smooth
/// upsamplers, where conversion errors stay local and SSIM grades severity
/// — the regime Table 4 measures. Normalized so E[|out|] ~ E[|in|].
pub fn smooth_filter(k: usize, ic: usize, oc: usize, s: usize, rng: &mut Rng) -> Filter {
    let mut f = Filter::zeros(k, k, ic, oc);
    let c = (k as f32 - 1.0) / 2.0;
    let sigma = (k as f32 / 2.5).max(0.8);
    let mut spatial_sum = 0.0;
    let mut profile = vec![0.0f32; k * k];
    for y in 0..k {
        for x in 0..k {
            let d2 = (y as f32 - c).powi(2) + (x as f32 - c).powi(2);
            let v = (-d2 / (2.0 * sigma * sigma)).exp();
            profile[y * k + x] = v;
            spatial_sum += v;
        }
    }
    for v in &mut profile {
        *v /= spatial_sum; // spatial profile sums to 1
    }
    // deconv scatter divides each output among s^2 phases; compensate
    let gain = (s * s) as f32;
    for y in 0..k {
        for x in 0..k {
            for i in 0..ic {
                for o in 0..oc {
                    // near-identity channel routing with noise
                    let ident = if i % oc == o { 1.0 } else { 0.0 };
                    let mix = (ident * 0.8 + 0.4 * rng.normal()) / (ic as f32 / oc.min(ic) as f32);
                    *f.at_mut(y, x, i, o) = profile[y * k + x] * mix * gain;
                }
            }
        }
    }
    f
}

/// Pack a filter's HWIO payload into the GEMM microkernel's panel operand
/// (`K = kh*kw*ic` rows of `N = oc`) — the plan-time weight-packing step:
/// run once per conv / SD-split filter at `Program` compile time, so the
/// serving hot path streams panel-contiguous weights instead of repacking
/// (or striding across) the raw HWIO buffer on every call.
pub fn pack_filter(f: &Filter) -> PackedB {
    PackedB::pack(&f.data, f.kh * f.kw * f.ic, f.oc)
}

/// [`pack_filter`] over a pre-split SD filter bank (one packed operand per
/// stride-1 sub-convolution), stored beside the splits in the compiled
/// program.
pub fn pack_filters(splits: &[Filter]) -> Vec<PackedB> {
    splits.iter().map(pack_filter).collect()
}

/// Build every layer's weights for a network, seeded per layer index — the
/// exact draws the quality evaluation makes, factored out so long-lived
/// callers ([`super::Plan`], the coordinator's native executor) pay weight
/// generation once instead of per forward call.
pub fn build_weights(net: &NetworkSpec, seed: u64) -> Vec<LayerWeights> {
    net.layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let mut rng = Rng::new(seed.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9));
            match l.kind {
                LayerKind::Dense => {
                    let n_in = l.in_h * l.in_w * l.in_c;
                    let scale = std::f32::consts::SQRT_2 / (n_in as f32).sqrt();
                    LayerWeights::Dense(
                        (0..n_in * l.out_c).map(|_| rng.normal() * scale).collect(),
                    )
                }
                LayerKind::Conv => {
                    LayerWeights::Filter(smooth_filter(l.k, l.in_c, l.out_c, 1, &mut rng))
                }
                LayerKind::Deconv => {
                    LayerWeights::Filter(smooth_filter(l.k, l.in_c, l.out_c, l.s, &mut rng))
                }
            }
        })
        .collect()
}
