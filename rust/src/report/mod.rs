//! Table / figure generators: every table and figure of the paper's
//! evaluation section, produced from this repo's own modules and printed in
//! the paper's row format. Used by the CLI (`repro report ...`), the bench
//! harness (rust/benches/), and the integration tests.

pub mod quality;

use anyhow::Result;

use crate::commodity::{edge_tpu::EdgeTpu, ncs2, nzp_time_s, sd_time_s, EfficiencyModel};
use crate::networks;
use crate::nn::NetworkSpec;
use crate::sim::energy::{energy, EnergyBreakdown, EnergyModel};
use crate::sim::workload::{lower_network_deconvs, Lowering};
use crate::sim::{dot_array, fcn_engine, pe2d, ProcessorConfig, RunStats, SkipPolicy};
use crate::util::geomean;

/// Host-side output-reorganization bandwidth (GB/s) used by the commodity
/// models (one pass over output bytes; measured-class DDR4 copy rate).
pub const HOST_REORG_GBPS: f64 = 8.0;

// ---------------------------------------------------------------------------
// Tables 1-3 (operation & parameter counts)
// ---------------------------------------------------------------------------

pub struct Table1Row {
    pub name: &'static str,
    pub total_m: f64,
    pub deconv_m: f64,
    pub pct: f64,
}

pub fn table1() -> Vec<Table1Row> {
    networks::all()
        .iter()
        .map(|n| {
            let t = n.total_macs() as f64 / 1e6;
            let d = n.deconv_macs() as f64 / 1e6;
            Table1Row {
                name: n.name,
                total_m: t,
                deconv_m: d,
                pct: 100.0 * d / t,
            }
        })
        .collect()
}

pub struct Table2Row {
    pub name: &'static str,
    pub original_m: f64,
    pub nzp_m: f64,
    pub sd_m: f64,
}

pub fn table2() -> Vec<Table2Row> {
    networks::all()
        .iter()
        .map(|n| Table2Row {
            name: n.name,
            original_m: n.deconv_macs() as f64 / 1e6,
            nzp_m: n.nzp_macs() as f64 / 1e6,
            sd_m: n.sd_macs() as f64 / 1e6,
        })
        .collect()
}

pub struct Table3Row {
    pub name: &'static str,
    pub original_m: f64,
    pub sd_general_m: f64,
    pub sd_compressed_m: f64,
}

pub fn table3() -> Vec<Table3Row> {
    networks::all()
        .iter()
        .map(|n| Table3Row {
            name: n.name,
            original_m: n.deconv_params() as f64 / 1e6,
            sd_general_m: n.sd_params() as f64 / 1e6,
            sd_compressed_m: n.sd_compressed_params() as f64 / 1e6,
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Figures 8-11 (simulated processors)
// ---------------------------------------------------------------------------

/// One benchmark's simulated runs across the schemes of a figure.
pub struct SimRow {
    pub name: &'static str,
    /// (scheme label, stats)
    pub runs: Vec<(&'static str, RunStats)>,
}

impl SimRow {
    /// Normalized performance (1/cycles), NZP = 1.0 (the paper's figures).
    pub fn normalized_perf(&self) -> Vec<(&'static str, f64)> {
        let base = self.runs[0].1.cycles as f64;
        self.runs
            .iter()
            .map(|(l, s)| (*l, base / s.cycles as f64))
            .collect()
    }

    /// Normalized energy, NZP = 1.0.
    pub fn normalized_energy(&self, m: &EnergyModel) -> Vec<(&'static str, EnergyBreakdown, f64)> {
        let base = energy(&self.runs[0].1, m).total_uj();
        self.runs
            .iter()
            .map(|(l, s)| {
                let e = energy(s, m);
                let rel = e.total_uj() / base;
                (*l, e, rel)
            })
            .collect()
    }
}

/// Figure 8: deconvolutional layers on the dot-production PE array.
/// Schemes: NZP (legacy, no skip), SD (no skip), SD-Asparse.
pub fn fig8(seed: u64) -> Result<Vec<SimRow>> {
    let cfg = ProcessorConfig::default();
    let mut rows = Vec::new();
    for n in networks::all() {
        let nzp_ops = lower_network_deconvs(&n, Lowering::Nzp, seed)?;
        let sd_ops = lower_network_deconvs(&n, Lowering::Sd, seed)?;
        rows.push(SimRow {
            name: n.name,
            runs: vec![
                ("NZP", dot_array::simulate(&nzp_ops, &cfg, SkipPolicy::None)),
                ("SD", dot_array::simulate(&sd_ops, &cfg, SkipPolicy::None)),
                (
                    "SD-Asparse",
                    dot_array::simulate(&sd_ops, &cfg, SkipPolicy::ASparse),
                ),
            ],
        });
    }
    Ok(rows)
}

/// Figure 9: deconvolutional layers on the regular 2D PE array.
/// Schemes: NZP, SD-Asparse, SD-Wsparse, SD-WAsparse, FCN-Engine.
pub fn fig9(seed: u64) -> Result<Vec<SimRow>> {
    let cfg = ProcessorConfig::default();
    let mut rows = Vec::new();
    for n in networks::all() {
        let nzp_ops = lower_network_deconvs(&n, Lowering::Nzp, seed)?;
        let sd_ops = lower_network_deconvs(&n, Lowering::Sd, seed)?;
        rows.push(SimRow {
            name: n.name,
            runs: vec![
                ("NZP", pe2d::simulate(&nzp_ops, &cfg, SkipPolicy::None)),
                (
                    "SD-Asparse",
                    pe2d::simulate(&sd_ops, &cfg, SkipPolicy::ASparse),
                ),
                (
                    "SD-Wsparse",
                    pe2d::simulate(&sd_ops, &cfg, SkipPolicy::WSparse),
                ),
                (
                    "SD-WAsparse",
                    pe2d::simulate(&sd_ops, &cfg, SkipPolicy::AWSparse),
                ),
                ("FCN", fcn_engine::simulate_network(&n, &cfg)),
            ],
        });
    }
    Ok(rows)
}

/// Figures 10/11 reuse the fig8/fig9 stats with the energy model.
pub fn fig10(seed: u64) -> Result<Vec<SimRow>> {
    fig8(seed)
}

pub fn fig11(seed: u64) -> Result<Vec<SimRow>> {
    fig9(seed)
}

// ---------------------------------------------------------------------------
// Tables 5-8 + Figures 15/17 (commodity devices)
// ---------------------------------------------------------------------------

pub struct EffRow {
    pub x: usize,
    pub normalized: f64,
}

pub fn table5() -> Vec<EffRow> {
    // Edge TPU, fmap sweep at k=3
    let t = EdgeTpu;
    [8usize, 16, 32, 64, 128]
        .iter()
        .map(|&s| EffRow {
            x: s,
            normalized: t.gmacps(s, 3) / t.gmacps(8, 3),
        })
        .collect()
}

pub fn table6() -> Vec<EffRow> {
    let t = EdgeTpu;
    [2usize, 3, 4, 5]
        .iter()
        .map(|&k| EffRow {
            x: k,
            normalized: t.gmacps(128, k) / t.gmacps(128, 2),
        })
        .collect()
}

pub fn table7() -> Vec<EffRow> {
    let t = ncs2::Ncs2;
    [8usize, 16, 32, 64, 128]
        .iter()
        .map(|&s| EffRow {
            x: s,
            normalized: t.gmacps(s, 3) / t.gmacps(8, 3),
        })
        .collect()
}

pub fn table8() -> Vec<EffRow> {
    let t = ncs2::Ncs2;
    [2usize, 3, 4, 5]
        .iter()
        .map(|&k| EffRow {
            x: k,
            normalized: t.gmacps(128, k) / t.gmacps(128, 2),
        })
        .collect()
}

pub struct SpeedupRow {
    pub name: &'static str,
    /// (scheme, time seconds) — first entry is the normalization baseline
    pub times: Vec<(&'static str, f64)>,
}

impl SpeedupRow {
    pub fn speedups(&self) -> Vec<(&'static str, f64)> {
        let base = self.times[0].1;
        self.times.iter().map(|(l, t)| (*l, base / t)).collect()
    }
}

/// Figure 15: NZP vs SD on the Edge TPU model.
pub fn fig15() -> Vec<SpeedupRow> {
    let t = EdgeTpu;
    networks::all()
        .iter()
        .map(|n| SpeedupRow {
            name: n.name,
            times: vec![
                ("NZP", nzp_time_s(&t, n)),
                ("SD", sd_time_s(&t, n, HOST_REORG_GBPS)),
            ],
        })
        .collect()
}

/// Figure 17: NZP vs SD vs native deconvolution on the NCS2 model.
pub fn fig17() -> Vec<SpeedupRow> {
    let t = ncs2::Ncs2;
    networks::all()
        .iter()
        .map(|n| SpeedupRow {
            name: n.name,
            times: vec![
                ("NZP", nzp_time_s(&t, n)),
                ("Native", ncs2::native_deconv_time_s(n)),
                ("SD", sd_time_s(&t, n, HOST_REORG_GBPS)),
            ],
        })
        .collect()
}

/// Average SD-over-NZP speedup of a figure (geomean, the paper's "average").
pub fn average_speedup(rows: &[SpeedupRow], scheme: &str) -> f64 {
    let v: Vec<f64> = rows
        .iter()
        .map(|r| {
            let base = r.times[0].1;
            let t = r.times.iter().find(|(l, _)| *l == scheme).unwrap().1;
            base / t
        })
        .collect();
    geomean(&v)
}

// ---------------------------------------------------------------------------
// Printing (paper-style rows)
// ---------------------------------------------------------------------------

pub fn print_table1() {
    println!("Table 1: multiply-add operations in the inference phase");
    println!("{:<10} {:>12} {:>14} {:>7}", "Benchmark", "Total (M)", "Deconv (M)", "%");
    for r in table1() {
        println!(
            "{:<10} {:>12.2} {:>14.2} {:>6.1}%",
            r.name, r.total_m, r.deconv_m, r.pct
        );
    }
}

pub fn print_table2() {
    println!("Table 2: deconv-layer MACs by implementation (M)");
    println!("{:<10} {:>12} {:>12} {:>12}", "Benchmark", "Original", "NZP", "SD");
    for r in table2() {
        println!(
            "{:<10} {:>12.2} {:>12.2} {:>12.2}",
            r.name, r.original_m, r.nzp_m, r.sd_m
        );
    }
}

pub fn print_table3() {
    println!("Table 3: deconv-layer weight parameters (M)");
    println!(
        "{:<10} {:>12} {:>14} {:>16}",
        "Benchmark", "Orig [29]", "General SD", "Compressed SD"
    );
    for r in table3() {
        println!(
            "{:<10} {:>12.2} {:>14.2} {:>16.2}",
            r.name, r.original_m, r.sd_general_m, r.sd_compressed_m
        );
    }
}

pub fn print_sim_figure(title: &str, rows: &[SimRow]) {
    println!("{title} (performance normalized to NZP = 1.0)");
    for row in rows {
        print!("{:<10}", row.name);
        for (label, perf) in row.normalized_perf() {
            print!("  {label}={perf:.2}x");
        }
        println!();
    }
}

pub fn print_energy_figure(title: &str, rows: &[SimRow]) {
    let m = EnergyModel::default();
    println!("{title} (energy normalized to NZP = 1.0; breakdown PE/buffer/DRAM uJ)");
    for row in rows {
        print!("{:<10}", row.name);
        for (label, e, rel) in row.normalized_energy(&m) {
            print!(
                "  {label}={rel:.2} ({:.0}/{:.0}/{:.0})",
                e.pe_uj, e.buffer_uj, e.dram_uj
            );
        }
        println!();
    }
}

pub fn print_eff_table(title: &str, rows: &[EffRow], unit: &str) {
    println!("{title}");
    for r in rows {
        println!("  {}{}  {:.2}x", r.x, unit, r.normalized);
    }
}

pub fn print_speedup_figure(title: &str, rows: &[SpeedupRow]) {
    println!("{title} (normalized to NZP = 1.0)");
    for row in rows {
        print!("{:<10}", row.name);
        for (label, s) in row.speedups() {
            print!("  {label}={s:.2}x");
        }
        println!();
    }
}

pub fn print_table4(fst_div: usize) -> Result<()> {
    println!("Table 4: SSIM vs native deconvolution");
    println!("{:<10} {:>8} {:>10} {:>12}", "Benchmark", "SD", "Shi [30]", "Chang [31]");
    for r in quality::table4(fst_div)? {
        println!(
            "{:<10} {:>8.3} {:>10.3} {:>12.3}",
            r.benchmark, r.ssim_sd, r.ssim_shi, r.ssim_chang
        );
    }
    Ok(())
}

/// Int8 accuracy table: SSIM of the int8-quantized engine against the f32
/// engine on all six benchmarks (the quantized serving mode's quality
/// check; gated >= 0.97 in rust/tests/quant.rs).
pub fn print_quant_table(big_div: usize) -> Result<()> {
    println!("Quantization: int8 engine vs f32 engine (SSIM, SD path)");
    println!("{:<10} {:>12}", "Benchmark", "SSIM int8");
    for r in quality::quant_table(7, big_div)? {
        println!("{:<10} {:>12.4}", r.benchmark, r.ssim);
    }
    Ok(())
}

/// Networks helper re-export for benches.
pub fn all_networks() -> Vec<NetworkSpec> {
    networks::all()
}
