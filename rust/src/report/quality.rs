//! Deconvolution-conversion quality evaluation (paper Table 4, Figures
//! 13–14): run full generator networks with every conversion approach and
//! compare the produced images against the native-deconvolution output with
//! SSIM.
//!
//! Forward passes run on the compiled-plan engine ([`crate::engine::Plan`]):
//! every conversion approach is an op in the engine's registry, so there is
//! ONE execution path from the quality evaluation to the serving stack. The
//! pre-engine layer-by-layer interpreter is retained as
//! [`run_network_with`], the bit-exactness oracle the engine is tested
//! against (rust/tests/engine_equivalence.rs).
//!
//! Weights are seeded-random (we have no trained checkpoints — see DESIGN.md
//! section 6): conversion *exactness* is weight-independent, which is the
//! property Table 4 measures (SD == 1.0 exactly; Shi/Chang < 1 with the gap
//! shrinking on larger images).

use anyhow::{bail, Result};

use crate::engine::{bridge_reshape, Plan, Precision};
use crate::nn::{LayerKind, LayerSpec, NetworkSpec};
use crate::sd::{chang::chang_deconv2d, nzp::nzp_deconv2d, sd_deconv2d, shi::shi_deconv2d};
use crate::tensor::{conv2d, deconv2d, dense, relu, tanh, Filter, Tensor};
use crate::util::rng::Rng;

pub use crate::engine::{build_weights, DeconvImpl, LayerWeights};

fn run_deconv(x: &Tensor, f: &Filter, l: &LayerSpec, imp: DeconvImpl) -> Tensor {
    match imp {
        DeconvImpl::Native => deconv2d(x, f, l.s, l.p, l.op),
        DeconvImpl::Sd => sd_deconv2d(x, f, l.s, l.p, l.op),
        DeconvImpl::Nzp => nzp_deconv2d(x, f, l.s, l.p, l.op),
        DeconvImpl::Shi => shi_deconv2d(x, f, l.s, l.p, l.op),
        DeconvImpl::Chang => chang_deconv2d(x, f, l.s, l.p, l.op),
    }
}

/// Execute a network on a given input with deconvolutions computed by
/// `imp`, through a freshly compiled [`Plan`]. Weights are seeded per layer
/// index, so different `imp` runs see identical weights. Long-lived callers
/// should build the plan once and call [`Plan::forward`] directly.
pub fn run_network(
    net: &NetworkSpec,
    imp: DeconvImpl,
    seed: u64,
    input: &Tensor,
) -> Result<Tensor> {
    Plan::build_owned(net, build_weights(net, seed), imp)?.forward(input)
}

/// The retained layer-by-layer interpreter — the engine's bit-exactness
/// oracle. Executes a network with pre-built weights (from
/// [`build_weights`]), no plan compilation, re-deriving SD splits on every
/// call. Weight-count and weight-kind mismatches are errors (not panics),
/// and the same [`bridge_reshape`] chain bridging as the engine applies
/// (see `engine` module docs), so oracle and engine agree bit for bit.
pub fn run_network_with(
    net: &NetworkSpec,
    imp: DeconvImpl,
    weights: &[LayerWeights],
    input: &Tensor,
) -> Result<Tensor> {
    if weights.len() != net.layers.len() {
        bail!(
            "{}: {} weight entries for {} layers",
            net.name,
            weights.len(),
            net.layers.len()
        );
    }
    if net.layers.is_empty() {
        bail!("{}: cannot run an empty network", net.name);
    }
    // strict network-input validation, mirroring Plan::forward: bridging is
    // for the documented *between-layer* chain gaps only
    let per = input.h * input.w * input.c;
    if per != net.input_elems() {
        bail!(
            "{}: input has {} elements per request, expected {}",
            net.name,
            per,
            net.input_elems()
        );
    }
    let mut h = input.clone();
    let last = net.layers.len() - 1;
    for (i, (l, lw)) in net.layers.iter().zip(weights).enumerate() {
        let hv = bridge_reshape(h, l.in_h, l.in_w, l.in_c);
        h = match (l.kind, lw) {
            (LayerKind::Dense, LayerWeights::Dense(w)) => {
                if w.len() != l.in_h * l.in_w * l.in_c * l.out_c {
                    bail!("{}.{}: dense weight size mismatch", net.name, l.name);
                }
                dense(&hv, w, l.out_c)?
            }
            (LayerKind::Conv, LayerWeights::Filter(f)) => conv2d(&hv, f, l.s, l.p),
            (LayerKind::Deconv, LayerWeights::Filter(f)) => run_deconv(&hv, f, l, imp),
            _ => bail!(
                "{}.{}: weight kind does not match layer kind {:?}",
                net.name,
                l.name,
                l.kind
            ),
        };
        // post-op shape validation (mirrors the engine's run_step check):
        // every layer must produce its spec's declared output, so the
        // between-layer bridge can only ever absorb gaps the layer table
        // itself declares — a kernel regression is an error, not a bridge
        if (h.h, h.w, h.c) != (l.out_h(), l.out_w(), l.out_c) {
            bail!(
                "{}.{}: produced {:?}, spec declares [{}, {}, {}]",
                net.name,
                l.name,
                h.shape(),
                l.out_h(),
                l.out_w(),
                l.out_c
            );
        }
        // dense outputs reshape into the next layer's map implicitly (NHWC
        // flat layout already matches)
        if i == last {
            tanh(&mut h);
        } else {
            relu(&mut h);
        }
    }
    Ok(h)
}

/// Generate a DCGAN image (64x64x3, values in [-1,1]) with seeded z.
pub fn dcgan_image(imp: DeconvImpl, weight_seed: u64, z_seed: u64) -> Result<Tensor> {
    let net = crate::networks::dcgan();
    let mut rng = Rng::new(z_seed);
    let z = Tensor::randn(1, 1, 1, 100, &mut rng);
    run_network(&net, imp, weight_seed, &z)
}

/// A reduced-scale FST network (spatial dims divided by `div`) so quality
/// evaluation stays tractable; structure/filters identical.
pub fn fst_scaled(div: usize) -> NetworkSpec {
    crate::networks::scaled(&crate::networks::fst(), div)
}

/// Run FST (scaled) on a seeded content image.
pub fn fst_image(imp: DeconvImpl, weight_seed: u64, div: usize) -> Result<Tensor> {
    let net = fst_scaled(div);
    let l0 = &net.layers[0];
    let mut rng = Rng::new(77);
    // smooth synthetic content image in [-1, 1]
    let mut img = Tensor::zeros(1, l0.in_h, l0.in_w, 3);
    let (fx, fy) = (0.11 + rng.uniform() * 0.02, 0.07 + rng.uniform() * 0.02);
    for y in 0..l0.in_h {
        for x in 0..l0.in_w {
            for c in 0..3 {
                *img.at_mut(0, y, x, c) =
                    0.5 * ((y as f32 * fy + c as f32).sin() + (x as f32 * fx).cos()) * 0.9;
            }
        }
    }
    run_network(&net, imp, weight_seed, &img)
}

/// One Table-4 row: SSIM of each conversion approach vs native deconv.
pub struct QualityRow {
    pub benchmark: &'static str,
    pub ssim_sd: f64,
    pub ssim_shi: f64,
    pub ssim_chang: f64,
}

/// Compute Table 4 (SSIM on DCGAN and FST). `fst_div` trades fidelity of the
/// FST row for wall-clock (2 = 128x128 input; the paper used 256x256 — the
/// ordering is scale-robust, see rust/tests/report_tables.rs).
pub fn table4(fst_div: usize) -> Result<Vec<QualityRow>> {
    let mut rows = Vec::new();
    {
        let native = dcgan_image(DeconvImpl::Native, 1, 2)?;
        let sd = dcgan_image(DeconvImpl::Sd, 1, 2)?;
        let shi = dcgan_image(DeconvImpl::Shi, 1, 2)?;
        let chang = dcgan_image(DeconvImpl::Chang, 1, 2)?;
        rows.push(QualityRow {
            benchmark: "DCGAN",
            ssim_sd: crate::metrics::ssim_tensor(&sd, &native, 2.0),
            ssim_shi: crate::metrics::ssim_tensor(&shi, &native, 2.0),
            ssim_chang: crate::metrics::ssim_tensor(&chang, &native, 2.0),
        });
    }
    {
        let native = fst_image(DeconvImpl::Native, 1, fst_div)?;
        let sd = fst_image(DeconvImpl::Sd, 1, fst_div)?;
        let shi = fst_image(DeconvImpl::Shi, 1, fst_div)?;
        let chang = fst_image(DeconvImpl::Chang, 1, fst_div)?;
        rows.push(QualityRow {
            benchmark: "FST",
            ssim_sd: crate::metrics::ssim_tensor(&sd, &native, 2.0),
            ssim_shi: crate::metrics::ssim_tensor(&shi, &native, 2.0),
            ssim_chang: crate::metrics::ssim_tensor(&chang, &native, 2.0),
        });
    }
    Ok(rows)
}

/// One int8-accuracy row: SSIM of the int8-quantized engine output against
/// the f32 engine output (SD path both sides, identical weights and input).
pub struct QuantRow {
    pub benchmark: &'static str,
    pub ssim: f64,
}

/// SSIM of the int8 engine vs the f32 engine for one network on a seeded
/// input: both programs compile from the same weights, the int8 side with
/// its compile-time calibration, and run the same forward. Dynamic range 2
/// (tanh outputs in [-1, 1]).
pub fn int8_vs_f32_ssim(net: &NetworkSpec, weight_seed: u64, z_seed: u64) -> Result<f64> {
    let l0 = &net.layers[0];
    let mut rng = Rng::new(z_seed);
    let input = Tensor::randn(1, l0.in_h, l0.in_w, l0.in_c, &mut rng);
    let weights = build_weights(net, weight_seed);
    let mut fplan = Plan::build(net, &weights, DeconvImpl::Sd)?;
    let mut qplan = Plan::build_owned_prec(net, weights, DeconvImpl::Sd, Precision::Int8)?;
    let f = fplan.forward(&input)?;
    let q = qplan.forward(&input)?;
    Ok(crate::metrics::ssim_tensor(&q, &f, 2.0))
}

/// The int8 accuracy table (EXPERIMENTS.md #Quantization): int8-vs-f32
/// SSIM for all six benchmarks. MDE and FST run spatially scaled by
/// `big_div` (structure, channel mix, and SD geometry identical) to keep
/// the full-resolution pair tractable; pass 1 for full scale.
pub fn quant_table(weight_seed: u64, big_div: usize) -> Result<Vec<QuantRow>> {
    let nets = vec![
        crate::networks::dcgan(),
        crate::networks::artgan(),
        crate::networks::sngan(),
        crate::networks::gpgan(),
        crate::networks::scaled(&crate::networks::mde(), big_div),
        crate::networks::scaled(&crate::networks::fst(), big_div),
    ];
    nets.iter()
        .map(|net| {
            Ok(QuantRow {
                benchmark: net.name,
                ssim: int8_vs_f32_ssim(net, weight_seed, 2)?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dcgan_sd_exact_nzp_exact() {
        let native = dcgan_image(DeconvImpl::Native, 3, 4).unwrap();
        assert_eq!(native.shape(), [1, 64, 64, 3]);
        let sd = dcgan_image(DeconvImpl::Sd, 3, 4).unwrap();
        assert!(sd.allclose(&native, 1e-3), "SD diff {}", sd.max_abs_diff(&native));
        let nzp = dcgan_image(DeconvImpl::Nzp, 3, 4).unwrap();
        assert!(nzp.allclose(&native, 1e-3));
    }

    #[test]
    fn dcgan_shi_chang_not_exact() {
        let native = dcgan_image(DeconvImpl::Native, 3, 4).unwrap();
        let shi = dcgan_image(DeconvImpl::Shi, 3, 4).unwrap();
        let chang = dcgan_image(DeconvImpl::Chang, 3, 4).unwrap();
        assert!(shi.max_abs_diff(&native) > 1e-2);
        assert!(chang.max_abs_diff(&native) > 1e-2);
    }

    #[test]
    fn oracle_rejects_mismatched_weights() {
        let net = crate::networks::dcgan();
        let mut w = build_weights(&net, 1);
        w.pop();
        let z = Tensor::zeros(1, 1, 1, 100);
        assert!(run_network_with(&net, DeconvImpl::Sd, &w, &z).is_err());
        let mut w = build_weights(&net, 1);
        w[1] = LayerWeights::Dense(vec![0.0; 4]);
        assert!(run_network_with(&net, DeconvImpl::Sd, &w, &z).is_err());
    }
}
