//! Deconvolution-conversion quality evaluation (paper Table 4, Figures
//! 13–14): run full generator networks with every conversion approach and
//! compare the produced images against the native-deconvolution output with
//! SSIM.
//!
//! Weights are seeded-random (we have no trained checkpoints — see DESIGN.md
//! section 6): conversion *exactness* is weight-independent, which is the
//! property Table 4 measures (SD == 1.0 exactly; Shi/Chang < 1 with the gap
//! shrinking on larger images).

use crate::nn::{LayerKind, LayerSpec, NetworkSpec};
use crate::sd::{chang::chang_deconv2d, nzp::nzp_deconv2d, sd_deconv2d, shi::shi_deconv2d};
use crate::tensor::{conv2d, deconv2d, dense, relu, tanh, Filter, Tensor};
use crate::util::rng::Rng;

/// Deconvolution implementation used when executing a network.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeconvImpl {
    /// direct transposed convolution (the oracle)
    Native,
    /// split deconvolution (the paper; exact)
    Sd,
    /// naive zero padding (exact, redundant)
    Nzp,
    /// Shi et al. [30] fixed right/bottom padding (wrong on boundaries)
    Shi,
    /// Chang & Kang [31] approximate conversion
    Chang,
}

impl DeconvImpl {
    pub fn label(&self) -> &'static str {
        match self {
            DeconvImpl::Native => "native",
            DeconvImpl::Sd => "SD",
            DeconvImpl::Nzp => "NZP",
            DeconvImpl::Shi => "Shi [30]",
            DeconvImpl::Chang => "Chang [31]",
        }
    }
}

fn run_deconv(x: &Tensor, f: &Filter, l: &LayerSpec, imp: DeconvImpl) -> Tensor {
    match imp {
        DeconvImpl::Native => deconv2d(x, f, l.s, l.p, l.op),
        DeconvImpl::Sd => sd_deconv2d(x, f, l.s, l.p, l.op),
        DeconvImpl::Nzp => nzp_deconv2d(x, f, l.s, l.p, l.op),
        DeconvImpl::Shi => shi_deconv2d(x, f, l.s, l.p, l.op),
        DeconvImpl::Chang => chang_deconv2d(x, f, l.s, l.p, l.op),
    }
}

/// Smooth, trained-like filter: gaussian spatial profile x near-identity
/// channel mixing + moderate noise. Purely random filters decorrelate any
/// perturbation within one layer, which collapses every inexact baseline to
/// SSIM ~ 0 regardless of how wrong it is; trained generators are smooth
/// upsamplers, where conversion errors stay local and SSIM grades severity
/// — the regime Table 4 measures. Normalized so E[|out|] ~ E[|in|].
fn smooth_filter(k: usize, ic: usize, oc: usize, s: usize, rng: &mut Rng) -> Filter {
    let mut f = Filter::zeros(k, k, ic, oc);
    let c = (k as f32 - 1.0) / 2.0;
    let sigma = (k as f32 / 2.5).max(0.8);
    let mut spatial_sum = 0.0;
    let mut profile = vec![0.0f32; k * k];
    for y in 0..k {
        for x in 0..k {
            let d2 = (y as f32 - c).powi(2) + (x as f32 - c).powi(2);
            let v = (-d2 / (2.0 * sigma * sigma)).exp();
            profile[y * k + x] = v;
            spatial_sum += v;
        }
    }
    for v in &mut profile {
        *v /= spatial_sum; // spatial profile sums to 1
    }
    // deconv scatter divides each output among s^2 phases; compensate
    let gain = (s * s) as f32;
    for y in 0..k {
        for x in 0..k {
            for i in 0..ic {
                for o in 0..oc {
                    // near-identity channel routing with noise
                    let ident = if i % oc == o { 1.0 } else { 0.0 };
                    let mix = (ident * 0.8 + 0.4 * rng.normal()) / (ic as f32 / oc.min(ic) as f32);
                    *f.at_mut(y, x, i, o) = profile[y * k + x] * mix * gain;
                }
            }
        }
    }
    f
}

/// Pre-built weights of one layer (see [`build_weights`]).
pub enum LayerWeights {
    /// dense-layer weight matrix, n_in x n_out row-major
    Dense(Vec<f32>),
    /// conv / deconv filter
    Filter(Filter),
}

/// Build every layer's weights for a network, seeded per layer index — the
/// exact draws [`run_network`] makes, factored out so long-lived callers
/// (the coordinator's native executor) pay weight generation once instead
/// of per batch.
pub fn build_weights(net: &NetworkSpec, seed: u64) -> Vec<LayerWeights> {
    net.layers
        .iter()
        .enumerate()
        .map(|(i, l)| {
            let mut rng = Rng::new(seed.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9));
            match l.kind {
                LayerKind::Dense => {
                    let n_in = l.in_h * l.in_w * l.in_c;
                    let scale = std::f32::consts::SQRT_2 / (n_in as f32).sqrt();
                    LayerWeights::Dense(
                        (0..n_in * l.out_c).map(|_| rng.normal() * scale).collect(),
                    )
                }
                LayerKind::Conv => {
                    LayerWeights::Filter(smooth_filter(l.k, l.in_c, l.out_c, 1, &mut rng))
                }
                LayerKind::Deconv => {
                    LayerWeights::Filter(smooth_filter(l.k, l.in_c, l.out_c, l.s, &mut rng))
                }
            }
        })
        .collect()
}

/// Execute a chain-structured network (DCGAN / SNGAN / ArtGAN / FST) on a
/// given input, with deconvolutions computed by `imp`. Weights are seeded
/// per layer index, so different `imp` runs see identical weights.
/// Activation policy: ReLU between layers, tanh after the last (generator
/// convention).
pub fn run_network(net: &NetworkSpec, imp: DeconvImpl, seed: u64, input: &Tensor) -> Tensor {
    run_network_with(net, imp, &build_weights(net, seed), input)
}

/// [`run_network`] with pre-built weights (from [`build_weights`]).
pub fn run_network_with(
    net: &NetworkSpec,
    imp: DeconvImpl,
    weights: &[LayerWeights],
    input: &Tensor,
) -> Tensor {
    assert_eq!(weights.len(), net.layers.len(), "{}: weight count", net.name);
    let mut h = input.clone();
    let last = net.layers.len() - 1;
    for (i, (l, lw)) in net.layers.iter().zip(weights).enumerate() {
        h = match (l.kind, lw) {
            (LayerKind::Dense, LayerWeights::Dense(w)) => {
                let n_in = l.in_h * l.in_w * l.in_c;
                assert_eq!(h.len() / h.n, n_in, "{}.{}: dense input mismatch", net.name, l.name);
                dense(&h, w, l.out_c)
            }
            (LayerKind::Conv, LayerWeights::Filter(f)) => conv2d(&h, f, l.s, l.p),
            (LayerKind::Deconv, LayerWeights::Filter(f)) => {
                // reshape dense output into the deconv's expected map
                if h.h * h.w * h.c != l.in_h * l.in_w * l.in_c {
                    panic!("{}.{}: shape mismatch", net.name, l.name);
                }
                let hv = Tensor::from_vec(h.n, l.in_h, l.in_w, l.in_c, h.data.clone());
                run_deconv(&hv, f, l, imp)
            }
            _ => panic!("{}.{}: weight kind mismatch", net.name, l.name),
        };
        // dense outputs reshape into the next layer's map implicitly (NHWC
        // flat layout already matches)
        if i == last {
            tanh(&mut h);
        } else {
            relu(&mut h);
        }
    }
    h
}

/// Generate a DCGAN image (64x64x3, values in [-1,1]) with seeded z.
pub fn dcgan_image(imp: DeconvImpl, weight_seed: u64, z_seed: u64) -> Tensor {
    let net = crate::networks::dcgan();
    let mut rng = Rng::new(z_seed);
    let z = Tensor::randn(1, 1, 1, 100, &mut rng);
    run_network(&net, imp, weight_seed, &z)
}

/// A reduced-scale FST network (spatial dims divided by `div`) so quality
/// evaluation stays tractable; structure/filters identical.
pub fn fst_scaled(div: usize) -> NetworkSpec {
    let base = crate::networks::fst();
    let layers = base
        .layers
        .iter()
        .map(|l| LayerSpec {
            in_h: (l.in_h / div).max(l.k),
            in_w: (l.in_w / div).max(l.k),
            ..l.clone()
        })
        .collect();
    NetworkSpec { name: "FST", layers }
}

/// Run FST (scaled) on a seeded content image.
pub fn fst_image(imp: DeconvImpl, weight_seed: u64, div: usize) -> Tensor {
    let net = fst_scaled(div);
    let l0 = &net.layers[0];
    let mut rng = Rng::new(77);
    // smooth synthetic content image in [-1, 1]
    let mut img = Tensor::zeros(1, l0.in_h, l0.in_w, 3);
    let (fx, fy) = (0.11 + rng.uniform() * 0.02, 0.07 + rng.uniform() * 0.02);
    for y in 0..l0.in_h {
        for x in 0..l0.in_w {
            for c in 0..3 {
                *img.at_mut(0, y, x, c) =
                    0.5 * ((y as f32 * fy + c as f32).sin() + (x as f32 * fx).cos()) * 0.9;
            }
        }
    }
    run_network(&net, imp, weight_seed, &img)
}

/// One Table-4 row: SSIM of each conversion approach vs native deconv.
pub struct QualityRow {
    pub benchmark: &'static str,
    pub ssim_sd: f64,
    pub ssim_shi: f64,
    pub ssim_chang: f64,
}

/// Compute Table 4 (SSIM on DCGAN and FST). `fst_div` trades fidelity of the
/// FST row for wall-clock (2 = 128x128 input; the paper used 256x256 — the
/// ordering is scale-robust, see rust/tests/report_tables.rs).
pub fn table4(fst_div: usize) -> Vec<QualityRow> {
    let mut rows = Vec::new();
    {
        let native = dcgan_image(DeconvImpl::Native, 1, 2);
        let sd = dcgan_image(DeconvImpl::Sd, 1, 2);
        let shi = dcgan_image(DeconvImpl::Shi, 1, 2);
        let chang = dcgan_image(DeconvImpl::Chang, 1, 2);
        rows.push(QualityRow {
            benchmark: "DCGAN",
            ssim_sd: crate::metrics::ssim_tensor(&sd, &native, 2.0),
            ssim_shi: crate::metrics::ssim_tensor(&shi, &native, 2.0),
            ssim_chang: crate::metrics::ssim_tensor(&chang, &native, 2.0),
        });
    }
    {
        let native = fst_image(DeconvImpl::Native, 1, fst_div);
        let sd = fst_image(DeconvImpl::Sd, 1, fst_div);
        let shi = fst_image(DeconvImpl::Shi, 1, fst_div);
        let chang = fst_image(DeconvImpl::Chang, 1, fst_div);
        rows.push(QualityRow {
            benchmark: "FST",
            ssim_sd: crate::metrics::ssim_tensor(&sd, &native, 2.0),
            ssim_shi: crate::metrics::ssim_tensor(&shi, &native, 2.0),
            ssim_chang: crate::metrics::ssim_tensor(&chang, &native, 2.0),
        });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dcgan_sd_exact_nzp_exact() {
        let native = dcgan_image(DeconvImpl::Native, 3, 4);
        assert_eq!(native.shape(), [1, 64, 64, 3]);
        let sd = dcgan_image(DeconvImpl::Sd, 3, 4);
        assert!(sd.allclose(&native, 1e-3), "SD diff {}", sd.max_abs_diff(&native));
        let nzp = dcgan_image(DeconvImpl::Nzp, 3, 4);
        assert!(nzp.allclose(&native, 1e-3));
    }

    #[test]
    fn dcgan_shi_chang_not_exact() {
        let native = dcgan_image(DeconvImpl::Native, 3, 4);
        let shi = dcgan_image(DeconvImpl::Shi, 3, 4);
        let chang = dcgan_image(DeconvImpl::Chang, 3, 4);
        assert!(shi.max_abs_diff(&native) > 1e-2);
        assert!(chang.max_abs_diff(&native) > 1e-2);
    }
}
