//! # split-deconv
//!
//! Reproduction of *"Accelerating Generative Neural Networks on Unmodified
//! Deep Learning Processors — A Software Approach"* (Xu, Wang, Tu, Liu, He,
//! Zhang; 2019) as a three-layer rust + JAX + Pallas system:
//!
//! * **L1** (python, build time): Pallas stride-1 convolution kernel — the
//!   compute shape every split deconvolution lowers to.
//! * **L2** (python, build time): JAX generator models, AOT-lowered to HLO
//!   text under `artifacts/`.
//! * **L3** (this crate): the [`coordinator`] serving stack — a shared
//!   bounded queue feeding a pool of dynamic-batching dispatcher workers —
//!   over the [`engine`] compiled executor (one immutable `Program` per
//!   model, SD filters pre-split at compile time, shared across workers
//!   with per-worker `Scratch`; all six benchmark networks) or the
//!   [`runtime`] PJRT engine, the
//!   [`sd`] transform and its baselines, the cycle-accurate [`sim`]
//!   processor simulators, the [`commodity`] device models, and the
//!   [`report`] generators for every table and figure in the paper.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

pub mod commodity;
pub mod coordinator;
pub mod engine;
pub mod metrics;
pub mod networks;
pub mod nn;
pub mod obs;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod server;
pub mod sd;
pub mod sim;
pub mod tensor;
pub mod util;
