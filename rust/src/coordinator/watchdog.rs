//! The serving watchdog: a low-frequency scanner over the flight
//! recorder and the live queue/metrics that turns "the server went
//! quiet" into a structured diagnosis (DESIGN.md §14).
//!
//! Every `interval` it looks for two failure shapes:
//!
//! * **stalled worker** — a dispatcher thread that has emitted no
//!   journal event for longer than `stall_after` while work is queued.
//!   A healthy idle pool is silent too, so the queue-non-empty condition
//!   is what separates "nothing to do" from "not doing it".
//! * **over-age in-flight request** — a trace id that enqueued longer
//!   than `max_request_age` ago with no terminal event (respond, expiry,
//!   disconnect) in the journal: the request is stuck inside a batch,
//!   usually behind a wedged executor.
//!
//! Each detection logs one `obs::log` warning per scan with the
//! offending thread/trace id and increments
//! `Metrics.watchdog_stalls` (exported as
//! `repro_watchdog_stalls_total`) — the counter keeps growing while the
//! condition persists, so its *rate* is the alarm signal.
//!
//! The watchdog requires a journal: it is spawned by
//! [`super::Server::start_multi_with`] only when both
//! `ServerConfig.journal` and `ServerConfig.watchdog` are set.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::obs::journal::{monotonic_us, EventKind, Journal};
use crate::obs::log;

use super::metrics::Metrics;
use super::queue::LaneQueue;

/// Watchdog thresholds. Defaults are deliberately conservative for
/// production (a 5 s silent worker with queued work is wedged, not
/// slow); tests shrink them to milliseconds to exercise detection.
#[derive(Clone, Copy, Debug)]
pub struct WatchdogConfig {
    /// how often to scan
    pub interval: Duration,
    /// a dispatcher silent for longer than this, while work is queued,
    /// is reported as stalled
    pub stall_after: Duration,
    /// an in-flight request older than this with no terminal journal
    /// event is reported as stuck
    pub max_request_age: Duration,
}

impl Default for WatchdogConfig {
    fn default() -> WatchdogConfig {
        WatchdogConfig {
            interval: Duration::from_millis(500),
            stall_after: Duration::from_secs(5),
            max_request_age: Duration::from_secs(30),
        }
    }
}

/// One scan's findings (returned for tests; the thread loop logs and
/// counts them).
#[derive(Clone, Debug, Default)]
pub struct ScanReport {
    /// (thread name, idle µs) per stalled dispatcher
    pub stalled_workers: Vec<(String, u64)>,
    /// (trace id, age µs) per over-age in-flight request
    pub overage_requests: Vec<(u64, u64)>,
}

/// One watchdog scan over the journal. Pure with respect to the journal
/// (read-only snapshot); `queued` is the live queue depth and `born_us`
/// the watchdog's start time — a dispatcher that has never emitted is
/// judged idle since `born_us`, not since the process epoch, so a
/// freshly started server cannot false-positive.
pub fn scan(journal: &Journal, cfg: &WatchdogConfig, queued: usize, born_us: u64) -> ScanReport {
    let now = monotonic_us();
    let events = journal.snapshot();
    let stall_us = cfg.stall_after.as_micros() as u64;
    let max_age_us = cfg.max_request_age.as_micros() as u64;

    let mut last_by_tid: BTreeMap<u16, u64> = BTreeMap::new();
    for e in &events {
        let t = last_by_tid.entry(e.tid).or_insert(0);
        *t = (*t).max(e.ts_us);
    }

    let mut report = ScanReport::default();
    if queued > 0 {
        for (tid, name) in journal.thread_names() {
            if !name.starts_with("sd-dispatcher") {
                continue;
            }
            let last = last_by_tid.get(&tid).copied().unwrap_or(0).max(born_us);
            let idle = now.saturating_sub(last);
            if idle > stall_us {
                report.stalled_workers.push((name, idle));
            }
        }
    }

    // Over-age in-flight: enqueued, no terminal event. The journal is a
    // bounded window, so a very old Enqueue can have been evicted — the
    // watchdog then under-reports, never false-positives.
    let mut open: BTreeMap<u64, u64> = BTreeMap::new(); // trace id -> enqueue ts
    let mut closed: BTreeSet<u64> = BTreeSet::new();
    for e in &events {
        if e.trace_id == 0 {
            continue;
        }
        match e.kind {
            EventKind::Enqueue => {
                open.entry(e.trace_id).or_insert(e.ts_us);
            }
            EventKind::Respond | EventKind::DeadlineExpire | EventKind::Disconnect => {
                closed.insert(e.trace_id);
            }
            _ => {}
        }
    }
    for (trace_id, ts) in open {
        if closed.contains(&trace_id) {
            continue;
        }
        let age = now.saturating_sub(ts);
        if age > max_age_us {
            report.overage_requests.push((trace_id, age));
        }
    }
    report
}

/// The watchdog thread body: scan every `cfg.interval` until `stop` is
/// set, logging and counting each finding. Sleeps in short chunks so
/// shutdown never waits a full interval.
pub(crate) fn run<T>(
    queue: &LaneQueue<T>,
    metrics: &Metrics,
    journal: &Journal,
    cfg: WatchdogConfig,
    stop: &AtomicBool,
) {
    let born_us = monotonic_us();
    let chunk = Duration::from_millis(25).min(cfg.interval);
    loop {
        let mut slept = Duration::ZERO;
        while slept < cfg.interval {
            if stop.load(Ordering::Relaxed) {
                return;
            }
            std::thread::sleep(chunk);
            slept += chunk;
        }
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let queued = queue.total_len();
        let report = scan(journal, &cfg, queued, born_us);
        for (name, idle_us) in &report.stalled_workers {
            metrics.record_watchdog_stall();
            log::warn(
                "watchdog",
                "stalled worker: no journal event while work is queued",
                &[
                    ("worker", name.clone()),
                    ("idle_us", idle_us.to_string()),
                    ("queued", queued.to_string()),
                    ("stall_after_us", (cfg.stall_after.as_micros()).to_string()),
                ],
            );
        }
        for (trace_id, age_us) in &report.overage_requests {
            metrics.record_watchdog_stall();
            log::warn(
                "watchdog",
                "over-age in-flight request: enqueued but never resolved",
                &[
                    ("trace_id", trace_id.to_string()),
                    ("age_us", age_us.to_string()),
                    (
                        "max_request_age_us",
                        (cfg.max_request_age.as_micros()).to_string(),
                    ),
                ],
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::journal::JournalConfig;

    fn tiny_cfg() -> WatchdogConfig {
        WatchdogConfig {
            interval: Duration::from_millis(10),
            stall_after: Duration::from_millis(1),
            max_request_age: Duration::from_millis(1),
        }
    }

    #[test]
    fn scan_flags_silent_dispatcher_only_when_work_is_queued() {
        let j = Journal::new(JournalConfig {
            rings: 2,
            ring_capacity: 64,
        });
        // Emit one event from a thread named like a dispatcher, then go
        // silent past the stall threshold.
        let j2 = j.clone();
        std::thread::Builder::new()
            .name("sd-dispatcher-0".to_string())
            .spawn(move || j2.emit(EventKind::Dispatch, 0, 1, 0, 0))
            .unwrap()
            .join()
            .unwrap();
        std::thread::sleep(Duration::from_millis(5));
        let report = scan(&j, &tiny_cfg(), 3, 0);
        assert_eq!(report.stalled_workers.len(), 1, "{report:?}");
        assert!(report.stalled_workers[0].0.starts_with("sd-dispatcher"));
        // Same silence with an empty queue is a healthy idle pool.
        let report = scan(&j, &tiny_cfg(), 0, 0);
        assert!(report.stalled_workers.is_empty(), "{report:?}");
    }

    #[test]
    fn scan_flags_unresolved_overage_request() {
        let j = Journal::new(JournalConfig {
            rings: 1,
            ring_capacity: 64,
        });
        j.emit(EventKind::Enqueue, 0, 0, 1, 77); // never resolves
        j.emit(EventKind::Enqueue, 0, 0, 2, 78);
        j.emit(EventKind::Respond, 0, 0, 500, 78); // resolves
        std::thread::sleep(Duration::from_millis(5));
        let report = scan(&j, &tiny_cfg(), 0, 0);
        let ids: Vec<u64> = report.overage_requests.iter().map(|(id, _)| *id).collect();
        assert_eq!(ids, vec![77], "{report:?}");
    }

    #[test]
    fn fresh_watchdog_does_not_flag_a_worker_that_never_emitted() {
        let j = Journal::new(JournalConfig {
            rings: 1,
            ring_capacity: 64,
        });
        let j2 = j.clone();
        std::thread::Builder::new()
            .name("sd-dispatcher-1".to_string())
            .spawn(move || j2.emit(EventKind::Dispatch, 0, 1, 0, 0))
            .unwrap()
            .join()
            .unwrap();
        // born "now": even though the dispatcher's one event is old by
        // the tiny threshold, a watchdog born this instant must wait a
        // full stall_after before judging.
        std::thread::sleep(Duration::from_millis(5));
        let report = scan(&j, &tiny_cfg(), 1, monotonic_us());
        assert!(report.stalled_workers.is_empty(), "{report:?}");
    }
}
