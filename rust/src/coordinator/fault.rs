//! Fault-tolerance primitives for the serving plane (DESIGN.md §15):
//! typed request faults, per-lane circuit breakers, and the seeded
//! deterministic chaos-injection plan the recovery tests drive.
//!
//! Everything here is std-only and deliberately boring: the breaker is
//! a three-state machine behind one tiny mutex (poison-recovering — a
//! breaker must keep working *after* a panic, that is its whole job),
//! and the chaos plan is a pure function of `(seed, tick)` so a failing
//! CI run replays bit-identically from its spec string.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

/// Recover a possibly-poisoned mutex guard: the data behind every lock
/// in this module is valid after any panic (plain counters and enums),
/// so a poisoned lock degrades to the inner guard instead of cascading.
fn lock_sweep<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

/// Why a request came back without an image. Carried on
/// [`super::Response::fault`]; the front door maps it to a typed 500.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// The batch this request rode in panicked the worker; the request
    /// also failed its individual containment retry.
    WorkerPanic,
    /// The request panicked a worker on its own (twice in a row): it is
    /// a poison pill and was quarantined so the lane keeps serving.
    Quarantined,
}

impl FaultKind {
    /// Stable wire label, used as the JSON `error` kind in responses.
    pub fn label(self) -> &'static str {
        match self {
            FaultKind::WorkerPanic => "worker_panic",
            FaultKind::Quarantined => "quarantined",
        }
    }
}

/// A typed failure attached to a [`super::Response`] instead of an
/// image. The responder channel still fires — panic containment means
/// *no stranded receivers*, not silent drops.
#[derive(Clone, Debug)]
pub struct Fault {
    pub kind: FaultKind,
    /// Human-readable detail (the panic payload, truncated).
    pub msg: String,
}

/// Circuit-breaker tuning. `None` in `ServerConfig.breaker` disables
/// breakers entirely (the default: unit suites keep exact legacy
/// error semantics).
#[derive(Clone, Copy, Debug)]
pub struct BreakerConfig {
    /// Consecutive batch failures on a lane that open its breaker.
    pub threshold: u32,
    /// How long an open breaker rejects before half-opening, and how
    /// long a half-open probe may stay unresolved before re-probing.
    pub cooldown: Duration,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        BreakerConfig {
            threshold: 5,
            cooldown: Duration::from_secs(1),
        }
    }
}

/// Observable breaker state, surfaced in `/healthz` and Prometheus.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    HalfOpen,
    Open,
}

impl BreakerState {
    pub fn label(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::HalfOpen => "half_open",
            BreakerState::Open => "open",
        }
    }

    /// Gauge encoding for Prometheus: 0 closed, 1 half-open, 2 open.
    pub fn code(self) -> u64 {
        match self {
            BreakerState::Closed => 0,
            BreakerState::HalfOpen => 1,
            BreakerState::Open => 2,
        }
    }
}

#[derive(Clone, Copy, Debug)]
enum State {
    Closed { failures: u32 },
    Open { until: Instant },
    HalfOpen { probe_deadline: Instant },
}

/// Per-lane circuit breaker: `threshold` consecutive batch failures
/// open it (submissions bounce with [`super::SubmitError::LaneDown`]
/// before touching the queue), after `cooldown` ONE probe request is
/// admitted half-open, and that probe's outcome closes or re-opens the
/// breaker. A probe whose outcome never lands (its request expired in
/// queue, say) is replaced after another `cooldown`.
#[derive(Debug)]
pub struct Breaker {
    cfg: BreakerConfig,
    state: Mutex<State>,
}

impl Breaker {
    pub fn new(cfg: BreakerConfig) -> Breaker {
        Breaker {
            cfg,
            state: Mutex::new(State::Closed { failures: 0 }),
        }
    }

    /// Admission check at submit time. `true` lets the request through
    /// (and, half-open, marks it the probe); `false` means the lane is
    /// down and the caller should return `LaneDown`.
    pub fn admit(&self, now: Instant) -> bool {
        let mut s = lock_sweep(&self.state);
        match *s {
            State::Closed { .. } => true,
            State::Open { until } => {
                if now >= until {
                    *s = State::HalfOpen {
                        probe_deadline: now + self.cfg.cooldown,
                    };
                    true
                } else {
                    false
                }
            }
            State::HalfOpen { probe_deadline } => {
                if now >= probe_deadline {
                    // the previous probe never reported back; send another
                    *s = State::HalfOpen {
                        probe_deadline: now + self.cfg.cooldown,
                    };
                    true
                } else {
                    false
                }
            }
        }
    }

    /// A batch on this lane completed successfully.
    pub fn record_success(&self) {
        *lock_sweep(&self.state) = State::Closed { failures: 0 };
    }

    /// A batch on this lane failed (executor error or contained panic).
    pub fn record_failure(&self, now: Instant) {
        let mut s = lock_sweep(&self.state);
        match *s {
            State::Closed { failures } => {
                let failures = failures + 1;
                *s = if failures >= self.cfg.threshold {
                    State::Open {
                        until: now + self.cfg.cooldown,
                    }
                } else {
                    State::Closed { failures }
                };
            }
            State::HalfOpen { .. } => {
                // the probe failed: back to fully open
                *s = State::Open {
                    until: now + self.cfg.cooldown,
                };
            }
            State::Open { .. } => {}
        }
    }

    pub fn state(&self) -> BreakerState {
        match *lock_sweep(&self.state) {
            State::Closed { .. } => BreakerState::Closed,
            State::Open { .. } => BreakerState::Open,
            State::HalfOpen { .. } => BreakerState::HalfOpen,
        }
    }
}

/// What the chaos plan tells a dispatcher to do with one batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosAction {
    /// `panic!` inside the contained execute region (exercises the real
    /// containment path, not a simulation of it).
    Panic,
    /// Return an executor error (drives the plain-Err / breaker path).
    Error,
    /// Sleep this long before the real execute (stall injection for the
    /// watchdog false-positive guard).
    Slow(Duration),
}

/// A deterministic seeded fault-injection schedule, shared by every
/// dispatcher thread. Each batch dispatch draws one *tick*; the action
/// for tick `t` is a pure function of `(seed, t)`, so a plan replays
/// identically from its spec string regardless of thread interleaving
/// (ticks are claimed atomically — which worker gets which tick may
/// vary, but the multiset of injected faults never does).
///
/// Spec grammar (comma-separated `key=value`, all keys optional except
/// `seed`):
///
/// ```text
/// seed=42,panic=10,error=5,slow=20:30,ticks=200
/// ```
///
/// `panic`/`error` are percent probabilities; `slow` is
/// `percent[:millis]` (default 50 ms); `ticks` caps how many dispatches
/// draw faults at all — after `ticks` draws the plan goes quiet, which
/// is what lets tests assert *recovery* deterministically. `ticks=0`
/// (or absent) means unlimited.
#[derive(Debug)]
pub struct FaultPlan {
    seed: u64,
    panic_pct: u64,
    error_pct: u64,
    slow_pct: u64,
    slow: Duration,
    ticks: u64,
    tick: AtomicU64,
}

/// SplitMix64 finalizer: the statelessly-seedable mixer `util::rng`
/// seeds from, reused here so one well-tested constant set serves both.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl FaultPlan {
    /// Parse a `--chaos` / `REPRO_CHAOS` spec string. Errors are typed
    /// and name the offending key.
    pub fn from_spec(spec: &str) -> Result<FaultPlan> {
        let mut seed: Option<u64> = None;
        let mut panic_pct = 0u64;
        let mut error_pct = 0u64;
        let mut slow_pct = 0u64;
        let mut slow_ms = 50u64;
        let mut ticks = 0u64;
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| anyhow::anyhow!("chaos spec: `{part}` is not key=value"))?;
            match key {
                "seed" => {
                    seed = Some(value.parse().map_err(|_| {
                        anyhow::anyhow!("chaos spec: seed `{value}` is not a u64")
                    })?)
                }
                "panic" => panic_pct = parse_pct(key, value)?,
                "error" => error_pct = parse_pct(key, value)?,
                "slow" => {
                    let (pct, ms) = match value.split_once(':') {
                        Some((p, m)) => (
                            parse_pct(key, p)?,
                            m.parse().map_err(|_| {
                                anyhow::anyhow!("chaos spec: slow millis `{m}` is not a u64")
                            })?,
                        ),
                        None => (parse_pct(key, value)?, slow_ms),
                    };
                    slow_pct = pct;
                    slow_ms = ms;
                }
                "ticks" => {
                    ticks = value.parse().map_err(|_| {
                        anyhow::anyhow!("chaos spec: ticks `{value}` is not a u64")
                    })?
                }
                other => bail!("chaos spec: unknown key `{other}` (seed/panic/error/slow/ticks)"),
            }
        }
        let seed = seed.ok_or_else(|| anyhow::anyhow!("chaos spec: missing seed=N"))?;
        if panic_pct + error_pct + slow_pct > 100 {
            bail!(
                "chaos spec: panic+error+slow = {}% exceeds 100%",
                panic_pct + error_pct + slow_pct
            );
        }
        Ok(FaultPlan {
            seed,
            panic_pct,
            error_pct,
            slow_pct,
            slow: Duration::from_millis(slow_ms),
            ticks,
            tick: AtomicU64::new(0),
        })
    }

    /// Build a plan directly (tests); percentages must sum ≤ 100.
    pub fn new(seed: u64, panic_pct: u64, error_pct: u64, slow_pct: u64) -> FaultPlan {
        assert!(panic_pct + error_pct + slow_pct <= 100);
        FaultPlan {
            seed,
            panic_pct,
            error_pct,
            slow_pct,
            slow: Duration::from_millis(50),
            ticks: 0,
            tick: AtomicU64::new(0),
        }
    }

    /// Cap the number of fault-drawing ticks (builder style).
    pub fn with_ticks(mut self, ticks: u64) -> FaultPlan {
        self.ticks = ticks;
        self
    }

    /// Set the slow-injection stall duration (builder style).
    pub fn with_slow(mut self, slow: Duration) -> FaultPlan {
        self.slow = slow;
        self
    }

    /// Claim the next tick and return its scheduled action, if any.
    /// Returns `None` forever once the tick cap is exhausted.
    pub fn next(&self) -> Option<ChaosAction> {
        let t = self.tick.fetch_add(1, Ordering::Relaxed);
        if self.ticks != 0 && t >= self.ticks {
            return None;
        }
        let draw = mix(self.seed ^ mix(t)) % 100;
        if draw < self.panic_pct {
            Some(ChaosAction::Panic)
        } else if draw < self.panic_pct + self.error_pct {
            Some(ChaosAction::Error)
        } else if draw < self.panic_pct + self.error_pct + self.slow_pct {
            Some(ChaosAction::Slow(self.slow))
        } else {
            None
        }
    }

    /// Ticks drawn so far (monitoring/tests).
    pub fn ticks_drawn(&self) -> u64 {
        self.tick.load(Ordering::Relaxed)
    }

    /// One-line description for startup logs.
    pub fn describe(&self) -> String {
        format!(
            "seed={} panic={}% error={}% slow={}%:{}ms ticks={}",
            self.seed,
            self.panic_pct,
            self.error_pct,
            self.slow_pct,
            self.slow.as_millis(),
            if self.ticks == 0 {
                "unlimited".to_string()
            } else {
                self.ticks.to_string()
            }
        )
    }
}

fn parse_pct(key: &str, value: &str) -> Result<u64> {
    let pct: u64 = value
        .parse()
        .map_err(|_| anyhow::anyhow!("chaos spec: {key} `{value}` is not a percentage"))?;
    if pct > 100 {
        bail!("chaos spec: {key}={pct} exceeds 100%");
    }
    Ok(pct)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_round_trips_every_key() {
        let p = FaultPlan::from_spec("seed=42, panic=10,error=5,slow=20:30,ticks=200").unwrap();
        assert_eq!(p.seed, 42);
        assert_eq!(p.panic_pct, 10);
        assert_eq!(p.error_pct, 5);
        assert_eq!(p.slow_pct, 20);
        assert_eq!(p.slow, Duration::from_millis(30));
        assert_eq!(p.ticks, 200);
    }

    #[test]
    fn spec_rejects_garbage() {
        assert!(FaultPlan::from_spec("panic=10").is_err(), "missing seed");
        assert!(FaultPlan::from_spec("seed=1,panic=60,error=60").is_err(), "sum > 100");
        assert!(FaultPlan::from_spec("seed=1,frob=3").is_err(), "unknown key");
        assert!(FaultPlan::from_spec("seed=x").is_err(), "non-numeric seed");
        assert!(FaultPlan::from_spec("seed=1,panic=200").is_err(), "pct > 100");
        assert!(FaultPlan::from_spec("seed").is_err(), "not key=value");
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let a = FaultPlan::from_spec("seed=7,panic=15,error=10,slow=25:5").unwrap();
        let b = FaultPlan::from_spec("seed=7,panic=15,error=10,slow=25:5").unwrap();
        let sa: Vec<_> = (0..256).map(|_| a.next()).collect();
        let sb: Vec<_> = (0..256).map(|_| b.next()).collect();
        assert_eq!(sa, sb, "same spec => same schedule");
        assert!(sa.iter().any(|x| *x == Some(ChaosAction::Panic)));
        assert!(sa.iter().any(|x| *x == Some(ChaosAction::Error)));
        assert!(sa.iter().any(|x| x.is_none()), "most ticks draw nothing");

        let c = FaultPlan::from_spec("seed=8,panic=15,error=10,slow=25:5").unwrap();
        let sc: Vec<_> = (0..256).map(|_| c.next()).collect();
        assert_ne!(sa, sc, "different seed => different schedule");
    }

    #[test]
    fn tick_cap_silences_the_plan() {
        let p = FaultPlan::new(3, 100, 0, 0).with_ticks(4);
        for _ in 0..4 {
            assert_eq!(p.next(), Some(ChaosAction::Panic));
        }
        for _ in 0..32 {
            assert_eq!(p.next(), None, "past the cap the plan is quiet forever");
        }
    }

    #[test]
    fn draw_rates_track_the_requested_percentages() {
        let p = FaultPlan::new(11, 10, 10, 10);
        let n = 20_000u64;
        let mut counts = [0u64; 3];
        let mut none = 0u64;
        for _ in 0..n {
            match p.next() {
                Some(ChaosAction::Panic) => counts[0] += 1,
                Some(ChaosAction::Error) => counts[1] += 1,
                Some(ChaosAction::Slow(_)) => counts[2] += 1,
                None => none += 1,
            }
        }
        for (i, c) in counts.iter().enumerate() {
            let pct = 100.0 * *c as f64 / n as f64;
            assert!((8.0..12.0).contains(&pct), "action {i}: {pct:.1}% not near 10%");
        }
        assert!(none > n / 2);
    }

    #[test]
    fn breaker_opens_after_threshold_and_recovers_via_probe() {
        let b = Breaker::new(BreakerConfig {
            threshold: 3,
            cooldown: Duration::from_millis(100),
        });
        let t0 = Instant::now();
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.admit(t0));

        b.record_failure(t0);
        b.record_failure(t0);
        assert_eq!(b.state(), BreakerState::Closed, "below threshold stays closed");
        b.record_failure(t0);
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.admit(t0), "open rejects immediately");
        assert!(!b.admit(t0 + Duration::from_millis(50)), "still cooling down");

        // past the cooldown: exactly one probe is admitted half-open
        let t1 = t0 + Duration::from_millis(150);
        assert!(b.admit(t1), "first post-cooldown admit is the probe");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(!b.admit(t1), "second admit while the probe is in flight is rejected");

        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed, "probe success closes");
        assert!(b.admit(t1));
    }

    #[test]
    fn breaker_probe_failure_reopens_and_lost_probe_is_replaced() {
        let cooldown = Duration::from_millis(100);
        let b = Breaker::new(BreakerConfig {
            threshold: 1,
            cooldown,
        });
        let t0 = Instant::now();
        b.record_failure(t0);
        assert_eq!(b.state(), BreakerState::Open);

        let t1 = t0 + cooldown;
        assert!(b.admit(t1));
        b.record_failure(t1);
        assert_eq!(b.state(), BreakerState::Open, "probe failure reopens");

        // a probe whose outcome never lands is replaced after a cooldown
        let t2 = t1 + cooldown;
        assert!(b.admit(t2), "half-open probe");
        assert!(!b.admit(t2));
        let t3 = t2 + cooldown;
        assert!(b.admit(t3), "expired probe slot is re-armed");
    }

    #[test]
    fn breaker_counts_consecutive_failures_only() {
        let b = Breaker::new(BreakerConfig {
            threshold: 2,
            cooldown: Duration::from_millis(10),
        });
        let t = Instant::now();
        b.record_failure(t);
        b.record_success();
        b.record_failure(t);
        assert_eq!(b.state(), BreakerState::Closed, "success resets the streak");
        b.record_failure(t);
        assert_eq!(b.state(), BreakerState::Open);
    }
}
