//! Serving metrics: throughput, latency distribution (p50/p95/p99),
//! batch-size histogram, per-worker and per-model-lane batch/request
//! counters, admission-control counters (sheds, expired-deadline drops),
//! and the queue depth high-water mark. One `Metrics` is shared by every
//! dispatcher worker (and the submitting side) behind an `Arc`.
//!
//! Latency recording is O(1) memory and lock-free: observations go into
//! fixed-bucket log-scaled [`obs::Histogram`]s (DESIGN.md §12), not an
//! unbounded `Vec`. The `p50_us`/`p95_us`/`p99_us` snapshot fields are
//! histogram quantile *upper bounds*: they overestimate the true order
//! statistic by at most one bucket width (≤ 25% + 1us, the documented
//! [`obs::histogram::GROWTH`] bound).

use crate::obs::{Histogram, HistogramSnapshot};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

#[derive(Debug)]
struct Inner {
    started: Instant,
    served: u64,
    batches: u64,
    errors: u64,
    batch_hist: [u64; 65], // index = batch size (cap 64)
    compute_us_total: u64,
    worker_batches: Vec<u64>,
    worker_served: Vec<u64>,
    /// per-worker time spent forming + computing batches (µs) — the
    /// cumulative numerator of the busy-fraction gauges
    worker_busy_us: Vec<u64>,
    lane_served: Vec<u64>,
}

impl Default for Inner {
    fn default() -> Self {
        Inner {
            started: Instant::now(),
            served: 0,
            batches: 0,
            errors: 0,
            batch_hist: [0; 65],
            compute_us_total: 0,
            worker_batches: Vec::new(),
            worker_served: Vec::new(),
            worker_busy_us: Vec::new(),
            lane_served: Vec::new(),
        }
    }
}

/// How many per-lane shed/expired slots `Metrics::new` pre-sizes when the
/// lane count is not given explicitly — enough for the six benchmark
/// models with headroom. Lanes beyond the pre-sized slots still count in
/// the global totals.
const DEFAULT_LANE_SLOTS: usize = 8;

/// Shared, thread-safe metrics sink.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    /// queue-depth high-water mark, kept OUT of the mutex: it is updated
    /// on every submit, and the scaled submit hot path must not serialize
    /// on the same lock the N workers take per batch
    max_queue_depth: AtomicU64,
    /// requests refused at admission because the lane queue was full —
    /// every one of these was ANSWERED with an explicit shed response
    /// (never silently dropped). Lock-free: sheds happen on the submit
    /// hot path.
    shed: AtomicU64,
    /// requests dropped by a dispatcher because their deadline expired
    /// BEFORE compute (the request never reached the executor)
    expired: AtomicU64,
    /// per-lane shed counters (index = lane id; fixed at construction so
    /// the shed path stays lock-free — lanes beyond the pre-sized slots
    /// fall back to the global counter only)
    lane_shed: Vec<AtomicU64>,
    /// per-lane expired-deadline counters (same layout as `lane_shed`)
    lane_expired: Vec<AtomicU64>,
    /// requests currently inside the coordinator: incremented on accepted
    /// submit, decremented at each resolution (response, expiry,
    /// batch-failure disconnect). Lock-free: both ends are hot paths.
    in_flight: AtomicU64,
    /// stall observations by the serving watchdog (one per stalled worker
    /// per scan — keeps counting while the stall persists)
    watchdog_stalls: AtomicU64,
    /// panics caught out of executing batches (contained + retry +
    /// supervisor catches — every one was converted to typed responses,
    /// never a dead thread). Lock-free: recorded on the recovery path,
    /// which must not depend on the metrics lock being healthy.
    worker_panics: AtomicU64,
    /// poison-pill requests quarantined after repeatedly killing a
    /// worker (each got a typed fault response)
    quarantined: AtomicU64,
    /// submissions refused because the lane's circuit breaker was open
    /// (each answered with `SubmitError::LaneDown`)
    lane_down: AtomicU64,
    /// dispatcher workers currently alive — a live gauge proving the
    /// pool is at configured strength (inc once ready, dec on exit)
    live_workers: AtomicU64,
    /// end-to-end latency per request (submit → response send), the
    /// distribution behind p50/p95/p99. Lock-free, fixed footprint.
    latency: Histogram,
    /// pre-compute wait per request (end-to-end minus executor time:
    /// lane-queue wait + batch formation)
    queue_wait: Histogram,
    /// executor time observed per request (each request in a batch
    /// observes its batch's compute time)
    compute: Histogram,
}

impl Metrics {
    /// A sink with the per-worker counters pre-sized to `workers` (they
    /// also grow on demand, so `Metrics::default()` still works for one-off
    /// use) and [`DEFAULT_LANE_SLOTS`] per-lane shed/expired slots.
    pub fn new(workers: usize) -> Metrics {
        Metrics::with_lanes(workers, DEFAULT_LANE_SLOTS)
    }

    /// [`Metrics::new`] with an explicit per-lane counter count — the
    /// multi-tenant server passes its real lane count.
    pub fn with_lanes(workers: usize, lanes: usize) -> Metrics {
        let m = Metrics {
            lane_shed: (0..lanes).map(|_| AtomicU64::new(0)).collect(),
            lane_expired: (0..lanes).map(|_| AtomicU64::new(0)).collect(),
            ..Metrics::default()
        };
        {
            let mut i = m.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
            i.worker_batches = vec![0; workers];
            i.worker_served = vec![0; workers];
            i.worker_busy_us = vec![0; workers];
        }
        m
    }

    /// Record one executed batch of `size` requests from model lane
    /// `lane`, dispatched by `worker`. `busy_us` is the worker's wall
    /// time on this batch (form + compute) for the busy-fraction gauges;
    /// callers without a form sample pass `compute_us` again.
    pub fn record_batch(
        &self,
        worker: usize,
        lane: usize,
        size: usize,
        compute_us: u64,
        busy_us: u64,
    ) {
        // Poison-recovering lock: metrics must keep counting after any
        // worker panic (the counters are plain integers — always valid).
        let mut m = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        m.batches += 1;
        m.served += size as u64;
        m.batch_hist[size.min(64)] += 1;
        m.compute_us_total += compute_us;
        if m.worker_batches.len() <= worker {
            m.worker_batches.resize(worker + 1, 0);
            m.worker_served.resize(worker + 1, 0);
            m.worker_busy_us.resize(worker + 1, 0);
        }
        m.worker_batches[worker] += 1;
        m.worker_served[worker] += size as u64;
        m.worker_busy_us[worker] += busy_us;
        if m.lane_served.len() <= lane {
            m.lane_served.resize(lane + 1, 0);
        }
        m.lane_served[lane] += size as u64;
    }

    /// Record an observed queue depth (called by the submit path with the
    /// post-push depth); the snapshot keeps the high-water mark. Lock-free.
    pub fn note_queue_depth(&self, depth: usize) {
        self.max_queue_depth.fetch_max(depth as u64, Ordering::Relaxed);
    }

    /// Count one admission-control shed (queue full at submit) against
    /// `lane`. Lock-free.
    pub fn record_shed(&self, lane: usize) {
        self.shed.fetch_add(1, Ordering::Relaxed);
        if let Some(c) = self.lane_shed.get(lane) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Count one expired-deadline drop (request dropped before compute)
    /// against `lane`. Lock-free.
    pub fn record_expired(&self, lane: usize) {
        self.expired.fetch_add(1, Ordering::Relaxed);
        if let Some(c) = self.lane_expired.get(lane) {
            c.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One request entered the coordinator (accepted submit). Lock-free.
    pub fn inc_in_flight(&self) {
        self.in_flight.fetch_add(1, Ordering::Relaxed);
    }

    /// One request left the coordinator (response sent, deadline expiry,
    /// or batch-failure disconnect). Lock-free; saturates at zero so a
    /// stray double-decrement can never wrap the gauge.
    pub fn dec_in_flight(&self) {
        let _ = self
            .in_flight
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }

    /// Count one watchdog stall observation. Lock-free.
    pub fn record_watchdog_stall(&self) {
        self.watchdog_stalls.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one caught worker panic (contained batch, quarantining
    /// retry, or supervisor catch). Lock-free.
    pub fn record_worker_panic(&self) {
        self.worker_panics.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one quarantined poison-pill request. Lock-free.
    pub fn record_quarantined(&self) {
        self.quarantined.fetch_add(1, Ordering::Relaxed);
    }

    /// Count one breaker-rejected submission. Lock-free.
    pub fn record_lane_down(&self) {
        self.lane_down.fetch_add(1, Ordering::Relaxed);
    }

    /// One dispatcher worker came up (or respawned). Lock-free.
    pub fn inc_live_workers(&self) {
        self.live_workers.fetch_add(1, Ordering::Relaxed);
    }

    /// One dispatcher worker exited. Saturates at zero. Lock-free.
    pub fn dec_live_workers(&self) {
        let _ = self
            .live_workers
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1));
    }

    /// Record one request's end-to-end latency. Lock-free, O(1) memory:
    /// one bucket increment, never an allocation.
    pub fn record_latency(&self, us: u64) {
        self.latency.record(us);
    }

    /// Record one request's full latency decomposition: end-to-end total,
    /// pre-compute wait (queue + batch formation) and executor time.
    pub fn record_request_latency(&self, total_us: u64, queue_us: u64, compute_us: u64) {
        self.latency.record(total_us);
        self.queue_wait.record(queue_us);
        self.compute.record(compute_us);
    }

    pub fn record_error(&self) {
        self.inner
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
            .errors += 1;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let latency_hist = self.latency.snapshot();
        let queue_hist = self.queue_wait.snapshot();
        let compute_hist = self.compute.snapshot();
        let m = self.inner.lock().unwrap_or_else(std::sync::PoisonError::into_inner);
        let elapsed = m.started.elapsed().as_secs_f64();
        MetricsSnapshot {
            served: m.served,
            batches: m.batches,
            errors: m.errors,
            throughput_rps: if elapsed > 0.0 {
                m.served as f64 / elapsed
            } else {
                0.0
            },
            mean_batch: if m.batches > 0 {
                m.served as f64 / m.batches as f64
            } else {
                0.0
            },
            p50_us: latency_hist.quantile_us(0.50),
            p95_us: latency_hist.quantile_us(0.95),
            p99_us: latency_hist.quantile_us(0.99),
            batch_hist: m.batch_hist,
            mean_compute_us: if m.batches > 0 {
                m.compute_us_total as f64 / m.batches as f64
            } else {
                0.0
            },
            worker_batches: m.worker_batches.clone(),
            worker_served: m.worker_served.clone(),
            worker_busy_us: m.worker_busy_us.clone(),
            uptime_s: elapsed,
            lane_served: m.lane_served.clone(),
            lane_shed: self
                .lane_shed
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            lane_expired: self
                .lane_expired
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
            lane_depth: Vec::new(),
            max_queue_depth: self.max_queue_depth.load(Ordering::Relaxed),
            shed: self.shed.load(Ordering::Relaxed),
            expired: self.expired.load(Ordering::Relaxed),
            in_flight: self.in_flight.load(Ordering::Relaxed),
            watchdog_stalls: self.watchdog_stalls.load(Ordering::Relaxed),
            worker_panics: self.worker_panics.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            lane_down: self.lane_down.load(Ordering::Relaxed),
            live_workers: self.live_workers.load(Ordering::Relaxed),
            latency_hist,
            queue_hist,
            compute_hist,
        }
    }
}

/// A point-in-time view of the metrics.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    pub served: u64,
    pub batches: u64,
    pub errors: u64,
    pub throughput_rps: f64,
    pub mean_batch: f64,
    /// Histogram quantile upper bounds (≤ 25% + 1us overestimate; see
    /// [`crate::obs::histogram`]). 0.0 until the first request completes.
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    pub batch_hist: [u64; 65],
    pub mean_compute_us: f64,
    /// executable calls per dispatcher worker (index = worker id)
    pub worker_batches: Vec<u64>,
    /// requests served per dispatcher worker (index = worker id)
    pub worker_served: Vec<u64>,
    /// cumulative µs each worker spent forming + computing batches —
    /// divided by `uptime_s` this is the lifetime busy fraction (the
    /// journal-backed rolling-window variant lives on `/metrics` when a
    /// flight recorder is attached)
    pub worker_busy_us: Vec<u64>,
    /// seconds since the metrics sink was created
    pub uptime_s: f64,
    /// requests served per model lane (index = lane id; empty until the
    /// first batch of that lane completes)
    pub lane_served: Vec<u64>,
    /// admission-control sheds per lane (index = lane id)
    pub lane_shed: Vec<u64>,
    /// expired-deadline drops per lane (index = lane id)
    pub lane_expired: Vec<u64>,
    /// CURRENT queued requests per lane — a live gauge, not a watermark.
    /// Filled by [`crate::coordinator::Server::metrics`] from the lane
    /// queue (empty when the snapshot came straight from `Metrics`).
    pub lane_depth: Vec<u64>,
    /// highest queue depth observed at submit time (<= `queue_cap` always)
    pub max_queue_depth: u64,
    /// admission-control sheds (queue full at submit; each one answered)
    pub shed: u64,
    /// expired-deadline drops (removed before compute)
    pub expired: u64,
    /// requests currently inside the coordinator (accepted, not yet
    /// resolved) — a live gauge
    pub in_flight: u64,
    /// stall observations by the serving watchdog (0 when no watchdog
    /// is attached)
    pub watchdog_stalls: u64,
    /// caught worker panics (contained batches + quarantining retries +
    /// supervisor catches)
    pub worker_panics: u64,
    /// poison-pill requests quarantined with a typed fault response
    pub quarantined: u64,
    /// submissions bounced by an open per-lane circuit breaker
    pub lane_down: u64,
    /// dispatcher workers currently alive (the pool-strength gauge)
    pub live_workers: u64,
    /// end-to-end latency distribution (bucket counts; Prometheus
    /// exposition renders these as cumulative `_bucket` series)
    pub latency_hist: HistogramSnapshot,
    /// pre-compute wait distribution (queue + batch formation)
    pub queue_hist: HistogramSnapshot,
    /// per-request executor-time distribution
    pub compute_hist: HistogramSnapshot,
}

impl MetricsSnapshot {
    pub fn summary(&self) -> String {
        let workers: Vec<String> = self.worker_batches.iter().map(|b| b.to_string()).collect();
        format!(
            "served={} batches={} errors={} shed={} expired={} mean_batch={:.2} p50={:.0}us p95={:.0}us p99={:.0}us mean_compute={:.0}us worker_batches=[{}] max_queue_depth={}",
            self.served, self.batches, self.errors, self.shed, self.expired, self.mean_batch,
            self.p50_us, self.p95_us, self.p99_us, self.mean_compute_us,
            workers.join(","), self.max_queue_depth
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::histogram::{GROWTH, NUM_BUCKETS};

    #[test]
    fn batch_accounting() {
        let m = Metrics::new(2);
        m.record_batch(0, 0, 4, 100, 120);
        m.record_batch(1, 1, 2, 50, 50);
        m.record_latency(10);
        m.record_latency(20);
        m.record_latency(30);
        m.note_queue_depth(3);
        m.note_queue_depth(1);
        let s = m.snapshot();
        assert_eq!(s.served, 6);
        assert_eq!(s.batches, 2);
        assert_eq!(s.batch_hist[4], 1);
        assert_eq!(s.batch_hist[2], 1);
        assert!((s.mean_batch - 3.0).abs() < 1e-9);
        // Quantiles are histogram bucket upper bounds: within the
        // documented ≤ 25% + 1us of the exact order statistics (20, 30).
        assert!(s.p50_us >= 20.0 && s.p50_us <= 20.0 * GROWTH + 1.0, "p50 {}", s.p50_us);
        assert!(s.p99_us >= 30.0 && s.p99_us <= 30.0 * GROWTH + 1.0, "p99 {}", s.p99_us);
        assert_eq!(s.latency_hist.count, 3);
        assert_eq!(s.latency_hist.sum_us, 60);
        assert_eq!(s.worker_batches, vec![1, 1]);
        assert_eq!(s.worker_served, vec![4, 2]);
        assert_eq!(s.worker_busy_us, vec![120, 50]);
        assert_eq!(s.lane_served, vec![4, 2]);
        assert_eq!(s.max_queue_depth, 3);
        assert_eq!(s.shed, 0);
        assert_eq!(s.expired, 0);
        assert_eq!(s.in_flight, 0);
        assert_eq!(s.watchdog_stalls, 0);
        assert!(s.uptime_s >= 0.0);
    }

    #[test]
    fn worker_counters_grow_on_demand() {
        let m = Metrics::default();
        m.record_batch(3, 2, 5, 10, 12);
        let s = m.snapshot();
        assert_eq!(s.worker_batches, vec![0, 0, 0, 1]);
        assert_eq!(s.worker_served, vec![0, 0, 0, 5]);
        assert_eq!(s.worker_busy_us, vec![0, 0, 0, 12]);
        assert_eq!(s.lane_served, vec![0, 0, 5]);
    }

    #[test]
    fn shed_and_expired_counters() {
        let m = Metrics::new(1);
        m.record_shed(0);
        m.record_shed(1);
        m.record_expired(1);
        let s = m.snapshot();
        assert_eq!(s.shed, 2);
        assert_eq!(s.expired, 1);
        assert_eq!(&s.lane_shed[..2], &[1, 1]);
        assert_eq!(&s.lane_expired[..2], &[0, 1]);
        assert!(s.summary().contains("shed=2"));
        assert!(s.summary().contains("expired=1"));
    }

    #[test]
    fn lane_counters_out_of_range_fall_back_to_global() {
        let m = Metrics::with_lanes(1, 2);
        m.record_shed(99);
        let s = m.snapshot();
        assert_eq!(s.shed, 1, "global total always counts");
        assert_eq!(s.lane_shed, vec![0, 0]);
    }

    #[test]
    fn in_flight_gauge_never_wraps() {
        let m = Metrics::new(1);
        m.inc_in_flight();
        m.inc_in_flight();
        m.dec_in_flight();
        assert_eq!(m.snapshot().in_flight, 1);
        m.dec_in_flight();
        m.dec_in_flight(); // extra decrement saturates at zero
        assert_eq!(m.snapshot().in_flight, 0);
        m.record_watchdog_stall();
        assert_eq!(m.snapshot().watchdog_stalls, 1);
    }

    #[test]
    fn fault_tolerance_counters() {
        let m = Metrics::new(2);
        m.inc_live_workers();
        m.inc_live_workers();
        m.record_worker_panic();
        m.record_quarantined();
        m.record_lane_down();
        m.record_lane_down();
        let s = m.snapshot();
        assert_eq!(s.live_workers, 2);
        assert_eq!(s.worker_panics, 1);
        assert_eq!(s.quarantined, 1);
        assert_eq!(s.lane_down, 2);
        m.dec_live_workers();
        m.dec_live_workers();
        m.dec_live_workers(); // extra decrement saturates at zero
        assert_eq!(m.snapshot().live_workers, 0);
    }

    #[test]
    fn latency_decomposition_is_recorded() {
        let m = Metrics::new(1);
        m.record_request_latency(1000, 400, 600);
        m.record_request_latency(2000, 500, 1500);
        let s = m.snapshot();
        assert_eq!(s.latency_hist.count, 2);
        assert_eq!(s.latency_hist.sum_us, 3000);
        assert_eq!(s.queue_hist.count, 2);
        assert_eq!(s.queue_hist.sum_us, 900);
        assert_eq!(s.compute_hist.count, 2);
        assert_eq!(s.compute_hist.sum_us, 2100);
    }

    /// Regression: latency recording must be O(1) memory. One million
    /// observations leave `Metrics` exactly the same size (the histogram
    /// is a fixed inline array — no heap allocation on the record path)
    /// and `snapshot()` stays a fixed-size counter copy, NOT an O(n log n)
    /// sort of everything ever recorded.
    #[test]
    fn one_million_latency_records_keep_metrics_size_constant() {
        // The whole Metrics struct is inline + three small Vecs that do
        // not grow with observations; the histogram footprint is a
        // compile-time constant.
        assert!(std::mem::size_of::<Metrics>() < 4096);
        assert!(crate::obs::Histogram::footprint_bytes() < 1024);

        let m = Metrics::new(1);
        let small = m.snapshot();
        for i in 0..1_000_000u64 {
            // Sweep the full bucket range so every bucket gets traffic.
            m.record_latency((i % 1_000_000) + 1);
        }
        let big = m.snapshot();
        // Snapshot shape is identical regardless of observation count.
        assert_eq!(big.latency_hist.buckets.len(), small.latency_hist.buckets.len());
        assert_eq!(big.latency_hist.buckets.len(), NUM_BUCKETS + 1);
        assert_eq!(big.latency_hist.count, 1_000_000);
        // Snapshot cost is flat: a ~100-slot counter copy. Even on a
        // loaded CI machine this is microseconds; 50ms is a 1000x margin
        // that still catches any return to sort-the-Vec behaviour
        // (sorting 1M u64s takes well over 50ms under that regime's
        // allocation traffic, and the old Vec would also fail the size
        // assertions above by holding 8MB of samples).
        let t0 = Instant::now();
        let _ = m.snapshot();
        assert!(t0.elapsed() < std::time::Duration::from_millis(50));
        // Quantiles stay correct within the documented bound: p50 of
        // 1..=1e6 uniform is ~5e5.
        assert!(big.p50_us >= 500_000.0 * 0.8 && big.p50_us <= 500_000.0 * GROWTH + 1.0);
    }
}
