//! L3 coordinator: the serving stack around the compiled generator.
//!
//! A shared bounded request queue ([`queue::BoundedQueue`]) feeds a pool of
//! `ServerConfig.workers` dispatcher threads. Each worker owns its own
//! compute backend — executors are constructed *inside* the worker thread
//! from a `Send + Sync` factory called once per worker (PJRT handles are
//! not `Send`; the native path shares ONE immutable
//! [`crate::engine::Program`] behind an `Arc` and gives every worker its
//! own `Scratch`). Each worker independently implements *dynamic
//! batching*: block for the first request, drain the queue up to
//! `max_batch` or until `batch_timeout` elapses, pack the latents, run one
//! executable call, fan responses back out. Backpressure is the bounded
//! queue: [`Server::submit`] fails fast when full.
//!
//! Invariants (tested in rust/tests/coordinator.rs and
//! rust/tests/coordinator_stress.rs, at any worker count):
//! * every submitted request gets exactly one response (no drop/dup) —
//!   including requests already accepted when [`Server::shutdown`] is
//!   called (close-then-drain);
//! * responses carry the request's own image (order-independent identity);
//! * queue depth never exceeds `queue_cap`;
//! * batch sizes never exceed `max_batch`;
//! * a failed batch disconnects exactly its own requests' responders and
//!   the pool keeps serving subsequent batches.

pub mod executor;
pub mod metrics;
pub mod queue;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::engine::{DeconvImpl, Precision, Program};

pub use executor::{chunk_batches, plan_batch, BatchExecutor, NativeExecutor, PjrtExecutor};
pub use metrics::{Metrics, MetricsSnapshot};
pub use queue::{BoundedQueue, PopDeadline, PushError};

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// maximum requests packed into one executable call
    pub max_batch: usize,
    /// how long a worker waits to fill a batch after the first arrival
    pub batch_timeout: Duration,
    /// bounded queue depth (backpressure limit), shared by all workers
    pub queue_cap: usize,
    /// which benchmark model the *native* backend serves (any spelling
    /// [`crate::networks::by_name`] accepts: dcgan, artgan, sngan, gpgan,
    /// mde, fst) — [`Server::start_native`] compiles it ONCE into an
    /// `engine::Program` shared by every worker. The PJRT backend takes an
    /// explicit artifact prefix instead (artifact families can outnumber
    /// models, e.g. `dcgan_sd` vs `dcgan_nzp`); callers should derive it
    /// from [`crate::networks::slug`], as the CLI does.
    pub model: String,
    /// dispatcher threads draining the shared queue (clamped to >= 1).
    /// Each owns its own executor: its own `Scratch` on the native path,
    /// its own PJRT client on the artifact path.
    pub workers: usize,
    /// numeric precision of the *native* backend's compiled program
    /// ([`Precision::Int8`] = the quantized serving mode: int8 weights and
    /// activations, i32 accumulate, prepared once at compile time and
    /// shared across workers like any other program). The PJRT backend
    /// ignores this — its precision is baked into the artifacts.
    pub precision: Precision,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            max_batch: 4,
            batch_timeout: Duration::from_millis(2),
            queue_cap: 64,
            model: "dcgan".to_string(),
            workers: 1,
            precision: Precision::F32,
        }
    }
}

/// A generation request: latent vector in, image out.
struct Request {
    id: u64,
    z: Vec<f32>,
    submitted: Instant,
    resp: mpsc::Sender<Response>,
}

/// A completed generation.
#[derive(Clone, Debug)]
pub struct Response {
    pub id: u64,
    pub image: Vec<f32>,
    /// time spent waiting in queue + batcher (total latency minus the
    /// batch's compute time)
    pub queue_us: u64,
    /// executable wall time for the whole batch
    pub compute_us: u64,
    /// how many requests shared the executable call
    pub batch_size: usize,
}

/// Handle to a running coordinator.
pub struct Server {
    queue: Arc<BoundedQueue<Request>>,
    next_id: AtomicU64,
    metrics: Arc<Metrics>,
    handles: Mutex<Vec<JoinHandle<()>>>,
}

impl Server {
    /// Start a worker pool with a backend factory. The factory runs once
    /// *inside each* dispatcher thread (`cfg.workers` times, receiving the
    /// worker index); startup fails if any worker's backend fails to
    /// construct.
    pub fn start_with<F, E>(cfg: ServerConfig, factory: F) -> Result<Server>
    where
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
        E: BatchExecutor,
    {
        let workers = cfg.workers.max(1);
        let queue = Arc::new(BoundedQueue::new(cfg.queue_cap));
        let metrics = Arc::new(Metrics::new(workers));
        let factory = Arc::new(factory);
        let cfg = Arc::new(cfg);
        // report backend construction success/failure synchronously
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let queue2 = queue.clone();
            let metrics2 = metrics.clone();
            let factory2 = factory.clone();
            let cfg2 = cfg.clone();
            let ready = ready_tx.clone();
            let spawned = std::thread::Builder::new()
                .name(format!("sd-dispatcher-{w}"))
                .spawn(move || {
                    let exec = match (*factory2)(w) {
                        Ok(e) => {
                            let _ = ready.send(Ok(()));
                            e
                        }
                        Err(e) => {
                            let _ = ready.send(Err(e));
                            return;
                        }
                    };
                    dispatch_loop(w, &queue2, exec, &cfg2, &metrics2);
                });
            match spawned {
                Ok(h) => handles.push(h),
                Err(e) => {
                    queue.close();
                    for h in handles {
                        let _ = h.join();
                    }
                    return Err(e.into());
                }
            }
        }
        drop(ready_tx);
        for _ in 0..workers {
            let failed = match ready_rx.recv() {
                Ok(Ok(())) => None,
                Ok(Err(e)) => Some(e),
                Err(_) => Some(anyhow!("dispatcher died during startup")),
            };
            if let Some(e) = failed {
                queue.close();
                for h in handles {
                    let _ = h.join();
                }
                return Err(e);
            }
        }
        Ok(Server {
            queue,
            next_id: AtomicU64::new(0),
            metrics,
            handles: Mutex::new(handles),
        })
    }

    /// Start the production PJRT server for a model artifact prefix. Every
    /// worker constructs its own engine inside its thread (PJRT handles
    /// are not `Send`).
    pub fn start_pjrt(
        cfg: ServerConfig,
        artifact_dir: std::path::PathBuf,
        prefix: String,
    ) -> Result<Server> {
        Self::start_with(cfg, move |_worker| {
            PjrtExecutor::new(artifact_dir.clone(), &prefix)
        })
    }

    /// Start a server over the CPU-native engine executor: the generator
    /// selected by `cfg.model` is compiled ONCE into an immutable
    /// `engine::Program` (SD filters pre-split and packed at compile time,
    /// at `cfg.precision` — int8 constants and calibration included) and
    /// shared by all `cfg.workers` workers via `Arc` — each worker
    /// gets its own `Scratch`. Works from a fresh checkout (no artifacts
    /// needed); all six benchmark networks route here.
    pub fn start_native(cfg: ServerConfig, weight_seed: u64) -> Result<Server> {
        let net = crate::networks::by_name_or_err(&cfg.model)?;
        let program = Arc::new(Program::from_seed_prec(
            &net,
            DeconvImpl::Sd,
            weight_seed,
            cfg.precision,
        )?);
        Self::start_native_program(cfg, program)
    }

    /// [`Server::start_native`] over an already-compiled (possibly shared,
    /// possibly custom) program — one compile, N workers.
    pub fn start_native_program(cfg: ServerConfig, program: Arc<Program>) -> Result<Server> {
        Self::start_with(cfg, move |_worker| {
            Ok(NativeExecutor::from_program(program.clone()))
        })
    }

    /// Submit a latent vector. Returns a receiver for the response, or an
    /// error immediately if the queue is full (backpressure) or closed.
    pub fn submit(&self, z: Vec<f32>) -> Result<Receiver<Response>> {
        let (resp_tx, resp_rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            z,
            submitted: Instant::now(),
            resp: resp_tx,
        };
        match self.queue.try_push(req) {
            Ok(depth) => {
                self.metrics.note_queue_depth(depth);
                Ok(resp_rx)
            }
            Err(PushError::Full(_)) => Err(anyhow!("queue full (backpressure)")),
            Err(PushError::Closed(_)) => Err(anyhow!("server stopped")),
        }
    }

    /// Submit, blocking while the queue is full.
    pub fn submit_blocking(&self, z: Vec<f32>) -> Result<Receiver<Response>> {
        let (resp_tx, resp_rx) = mpsc::channel();
        let req = Request {
            id: self.next_id.fetch_add(1, Ordering::Relaxed),
            z,
            submitted: Instant::now(),
            resp: resp_tx,
        };
        match self.queue.push(req) {
            Ok(depth) => {
                self.metrics.note_queue_depth(depth);
                Ok(resp_rx)
            }
            Err(_) => Err(anyhow!("server stopped")),
        }
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Stop accepting new requests, then wait for the workers to drain the
    /// queue: every already-accepted request still gets its response
    /// (close-then-drain). Idempotent, and callable from any thread while
    /// others still hold `&Server` (mid-flight shutdown is exercised in
    /// rust/tests/coordinator_stress.rs).
    pub fn shutdown(&self) {
        self.queue.close();
        let handles: Vec<JoinHandle<()>> = std::mem::take(&mut *self.handles.lock().unwrap());
        for h in handles {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.queue.close();
        if let Ok(handles) = self.handles.get_mut() {
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// One worker's dispatch loop: pop the first request (blocking), fill the
/// batch until `max_batch` or the deadline, execute, fan out. Exits only
/// when the queue is closed *and* drained, so accepted requests are never
/// dropped by shutdown.
fn dispatch_loop<E: BatchExecutor>(
    worker: usize,
    queue: &BoundedQueue<Request>,
    mut exec: E,
    cfg: &ServerConfig,
    metrics: &Metrics,
) {
    loop {
        let first = match queue.pop() {
            Some(r) => r,
            None => return, // closed and fully drained
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.batch_timeout;
        while batch.len() < cfg.max_batch {
            match queue.pop_deadline(deadline) {
                PopDeadline::Item(r) => batch.push(r),
                PopDeadline::Timeout | PopDeadline::Closed => break,
            }
        }

        let zs: Vec<Vec<f32>> = batch.iter().map(|r| r.z.clone()).collect();
        let t0 = Instant::now();
        match exec.execute(&zs) {
            Ok(images) => {
                let compute_us = t0.elapsed().as_micros() as u64;
                metrics.record_batch(worker, batch.len(), compute_us);
                for (req, image) in batch.into_iter().zip(images) {
                    // sample elapsed() exactly once per request and derive
                    // queue time from it — re-sampling could attribute the
                    // batcher wait to neither bucket (regression-tested by
                    // coordinator::queue_time_accounts_for_batch_wait)
                    let total_us = req.submitted.elapsed().as_micros() as u64;
                    let queue_us = total_us.saturating_sub(compute_us);
                    metrics.record_latency(total_us);
                    let _ = req.resp.send(Response {
                        id: req.id,
                        image,
                        queue_us,
                        compute_us,
                        batch_size: zs.len(),
                    });
                }
            }
            Err(e) => {
                metrics.record_error();
                // drop the responders: receivers observe disconnection,
                // and only THIS batch's requests are affected — the loop
                // (and the rest of the pool) keeps serving
                eprintln!("worker {worker}: batch execution failed: {e:#}");
            }
        }
    }
}
